"""Fault-tolerant training loop.

The loop owns: jit'd train_step, sharded data, periodic async checkpoints,
restart-from-latest on failure, and a step-deadline watchdog (straggler
mitigation).  Failure handling is the checkpoint/restart contract used at
pod scale: any step may raise (device loss, preemption — simulated in
tests via ``failure_hook``), the loop reloads the last complete checkpoint
and replays; determinism of the data pipeline (batch ``i`` is a pure
function of ``i``) makes the replay exact.

Straggler/watchdog: if a step exceeds ``deadline_factor ×`` the median of
recent steps, the loop records a straggler event; after
``max_stragglers_in_row`` the prescription at scale is restart-on-spare
(here: raise → restart path), which is what the watchdog test asserts.
"""
from __future__ import annotations

import dataclasses
import statistics
import time
from typing import Any, Callable

import jax

from repro.checkpoint import ckpt as C
from repro.optim.adamw import AdamWConfig, init_opt_state
from repro.train.step import make_train_step


@dataclasses.dataclass
class LoopConfig:
    total_steps: int = 100
    ckpt_every: int = 20
    ckpt_dir: str = "/tmp/repro_ckpt"
    keep: int = 3
    log_every: int = 10
    max_restarts: int = 3
    deadline_factor: float = 10.0
    max_stragglers_in_row: int = 3
    microbatches: int = 1


@dataclasses.dataclass
class LoopResult:
    losses: list
    restarts: int
    straggler_events: int
    final_step: int
    params: Any
    opt_state: Any


def train_loop(cfg, opt_cfg: AdamWConfig, loop: LoopConfig, params, batch_fn,
               *, failure_hook: Callable[[int], None] | None = None,
               logger: Callable[[str], None] = print) -> LoopResult:
    """Run (and if needed re-run) training to ``loop.total_steps``."""
    step_fn = jax.jit(make_train_step(cfg, opt_cfg,
                                      microbatches=loop.microbatches))
    saver = C.AsyncCheckpointer(loop.ckpt_dir, keep=loop.keep)
    opt_state = init_opt_state(params)
    losses: list[float] = []
    restarts = 0
    stragglers = 0
    step_times: list[float] = []

    # resume if a checkpoint exists
    start = C.latest_step(loop.ckpt_dir)
    if start is not None:
        state = C.restore(loop.ckpt_dir, start,
                          {"params": params, "opt": opt_state})
        params, opt_state = state["params"], state["opt"]
        logger(f"[loop] resumed from step {start}")
    step = (start or 0)

    while step < loop.total_steps:
        try:
            t0 = time.perf_counter()
            if failure_hook is not None:
                failure_hook(step)
            batch = batch_fn(step)
            params, opt_state, metrics = step_fn(params, opt_state, batch)
            loss = float(metrics["loss"])
            dt = time.perf_counter() - t0
            # --- straggler watchdog -------------------------------------
            if len(step_times) >= 5:
                med = statistics.median(step_times[-20:])
                if dt > loop.deadline_factor * med:
                    stragglers += 1
                    logger(f"[loop] straggler at step {step}: "
                           f"{dt:.3f}s vs median {med:.3f}s")
                    if stragglers >= loop.max_stragglers_in_row:
                        raise RuntimeError("straggler threshold exceeded")
                else:
                    stragglers = 0
            step_times.append(dt)
            losses.append(loss)
            step += 1
            if step % loop.log_every == 0:
                logger(f"[loop] step {step} loss {loss:.4f} ({dt:.3f}s)")
            if step % loop.ckpt_every == 0 or step == loop.total_steps:
                saver.save(step, {"params": params, "opt": opt_state},
                           metadata={"loss": loss})
        except (RuntimeError, jax.errors.JaxRuntimeError) as e:
            restarts += 1
            logger(f"[loop] FAILURE at step {step}: {e} "
                   f"(restart {restarts}/{loop.max_restarts})")
            if restarts > loop.max_restarts:
                raise
            saver.wait()
            last = C.latest_step(loop.ckpt_dir)
            if last is None:
                # no checkpoint yet: restart from scratch
                opt_state = init_opt_state(params)
                step = 0
            else:
                state = C.restore(loop.ckpt_dir, last,
                                  {"params": params, "opt": opt_state})
                params, opt_state = state["params"], state["opt"]
                step = last
            stragglers = 0

    saver.wait()
    return LoopResult(losses=losses, restarts=restarts,
                      straggler_events=stragglers, final_step=step,
                      params=params, opt_state=opt_state)
