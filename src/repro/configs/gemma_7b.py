"""gemma-7b [arXiv:2403.08295; hf] — GeGLU, head_dim=256."""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="gemma-7b", family="dense",
    num_layers=28, d_model=3072, num_heads=16, num_kv_heads=16,
    head_dim=256, d_ff=24576, vocab_size=256000,
    ffn_kind="geglu", temporal_pattern=("attn",),
    tie_embeddings=True,
    source="arXiv:2403.08295; GeGLU, head_dim=256",
)
