"""Depthwise/grouped merged-conv kernel certification (this PR's tentpole).

The depthwise kernel puts MobileNetV2's merged segments on the Pallas
fast path: channel-blocked grid, per-group fp32 accumulators, the shared
phase-major DMA-halo pipeline.  Everything here runs the kernel in
interpret mode on CPU against the ``lax.conv_general_dilated`` grouped
oracle:

* the acceptance matrix — strides {1, 2} × kernel sizes {1, 3, 5} at a
  channel count that is NOT a multiple of 8 (group-padding path);
* a hypothesis property sweep over ``(stride, k, channels, tiles,
  dtype)`` including ragged last tiles and channel-multiplier weights;
* grouped (``feature_group_count < Cin``, ``Cin_g > 1``) cases, with
  explicit multi-group blocks;
* the grouped 2-D VMEM planner and the group-block chooser;
* no-oracle-fallback under ``force_backend('pallas')``;
* tiling as pure scheduling (exact float equality across tile splits).
"""
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp import given, settings, st

from repro import kernels
from repro.kernels.depthwise_conv import (choose_group_block,
                                          choose_tiles_grouped,
                                          depthwise_conv)
from repro.kernels.merged_conv import _VMEM_BUDGET

TOL = {jnp.float32: dict(rtol=2e-5, atol=2e-5),
       jnp.bfloat16: dict(rtol=2e-2, atol=2e-2)}


def _oracle(x, w, b, stride, groups, act=None):
    y = kernels.depthwise_conv_ref(x, w, b, stride=stride, groups=groups)
    return kernels.apply_activation(y, act)


# ---------------------------------------------------------------------------
# acceptance matrix: strides {1, 2} × k {1, 3, 5}, C=13 (not a multiple of 8)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("stride", [1, 2])
@pytest.mark.parametrize("k", [1, 3, 5])
def test_depthwise_matrix(stride, k):
    rng = np.random.default_rng(stride * 100 + k)
    c = 13
    x = jnp.asarray(rng.standard_normal((2, 15, 13, c)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((k, k, 1, c)) * 0.1, jnp.float32)
    b = jnp.asarray(rng.standard_normal(c), jnp.float32)
    y = kernels.depthwise_conv_op(x, w, b, stride=stride, activation="relu6",
                                  interpret=True)
    yr = _oracle(x, w, b, stride, c, "relu6")
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr),
                               rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("stride", [1, 2])
def test_depthwise_no_oracle_fallback(stride):
    """With the backend forced to 'pallas', depthwise convs must go through
    pl.pallas_call (interpret on CPU) — not the jnp fallback."""
    rng = np.random.default_rng(7 + stride)
    x = jnp.asarray(rng.standard_normal((1, 12, 12, 6)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((3, 3, 1, 6)) * 0.1, jnp.float32)
    with kernels.force_backend("pallas"):
        y = kernels.depthwise_conv_op(x, w, stride=stride, interpret=True)
    np.testing.assert_allclose(np.asarray(y),
                               np.asarray(_oracle(x, w, None, stride, 6)),
                               rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------------------------
# property sweep: (stride, k, channels, cout_mult, tiles, dtype)
# ---------------------------------------------------------------------------

@given(stride=st.integers(1, 2), k=st.sampled_from([1, 3, 5]),
       c=st.integers(3, 19), cout_mult=st.sampled_from([1, 1, 1, 2]),
       tile_ho=st.integers(1, 6), tile_wo=st.integers(1, 6),
       h=st.integers(8, 18), w=st.integers(8, 18), bf16=st.booleans())
@settings(max_examples=24, deadline=None)
def test_depthwise_property(stride, k, c, cout_mult, tile_ho, tile_wo, h, w,
                            bf16):
    if h < k or w < k:
        return
    dtype = jnp.bfloat16 if bf16 else jnp.float32
    rng = np.random.default_rng(stride * 1009 + k * 131 + c * 17
                                + tile_ho * 7 + tile_wo * 3 + h * 29 + w
                                + cout_mult)
    x = jnp.asarray(rng.standard_normal((1, h, w, c)), dtype)
    wt = jnp.asarray(rng.standard_normal((k, k, 1, c * cout_mult)) * 0.1,
                     dtype)
    b = jnp.asarray(rng.standard_normal(c * cout_mult), dtype)
    y = kernels.depthwise_conv_op(x, wt, b, stride=stride, groups=c,
                                  tile_ho=tile_ho, tile_wo=tile_wo,
                                  activation="relu6", interpret=True)
    yr = _oracle(x, wt, b, stride, c, "relu6")
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(yr, np.float32), **TOL[dtype])


# ---------------------------------------------------------------------------
# grouped (feature_group_count < Cin): per-group MXU contractions
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("groups,cin_g,cout_g,bgroups", [
    (4, 6, 6, 1), (4, 6, 6, 2), (4, 6, 6, 4),
    (6, 2, 2, 4),                         # group padding: 6 → 8
    (2, 8, 4, 1),                         # cout_g != cin_g
])
def test_grouped_conv(groups, cin_g, cout_g, bgroups):
    rng = np.random.default_rng(groups * 31 + cin_g * 7 + bgroups)
    cin, cout = groups * cin_g, groups * cout_g
    x = jnp.asarray(rng.standard_normal((2, 12, 11, cin)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((3, 3, cin_g, cout)) * 0.1,
                    jnp.float32)
    b = jnp.asarray(rng.standard_normal(cout), jnp.float32)
    for s in (1, 2):
        y = depthwise_conv(x, w, b, stride=s, groups=groups, bgroups=bgroups,
                           interpret=True)
        np.testing.assert_allclose(np.asarray(y),
                                   np.asarray(_oracle(x, w, b, s, groups)),
                                   rtol=2e-5, atol=2e-5)


def test_grouped_op_dispatch():
    """depthwise_conv_op with explicit groups routes grouped weights."""
    rng = np.random.default_rng(5)
    x = jnp.asarray(rng.standard_normal((1, 10, 10, 12)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((3, 3, 3, 8)) * 0.1, jnp.float32)
    y = kernels.depthwise_conv_op(x, w, stride=1, groups=4, interpret=True)
    np.testing.assert_allclose(np.asarray(y),
                               np.asarray(_oracle(x, w, None, 1, 4)),
                               rtol=2e-5, atol=2e-5)


def test_tiling_is_pure_scheduling():
    """Any (tile_ho, tile_wo, bgroups) split produces the same floats per
    output element — accumulation order per element never changes."""
    rng = np.random.default_rng(3)
    x = jnp.asarray(rng.standard_normal((2, 13, 14, 8)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((3, 3, 1, 8)) * 0.1, jnp.float32)
    for s in (1, 2):
        whole = depthwise_conv(x, w, stride=s, groups=8, bgroups=8,
                               tile_ho=64, tile_wo=64, interpret=True)
        for tho, two, bg in ((1, 64, 8), (64, 1, 8), (2, 3, 8), (5, 4, 4)):
            tiled = depthwise_conv(x, w, stride=s, groups=8, bgroups=bg,
                                   tile_ho=tho, tile_wo=two, interpret=True)
            np.testing.assert_array_equal(np.asarray(whole),
                                          np.asarray(tiled))


# ---------------------------------------------------------------------------
# grouped VMEM planner + group-block chooser
# ---------------------------------------------------------------------------

def _working_set(tho, two, cin_g, cout_g, kh, kw, s, itemsize, bg):
    shi = s * tho + kh - 1
    swi = s * two + kw - 1
    bcin = bg * cin_g
    return (2 * shi * swi * bcin * itemsize             # double-buffered in
            + kh * kw * bg * cin_g * cout_g * itemsize  # weight block
            + tho * two * bg * cout_g * (4 + itemsize))  # fp32 acc + out


@pytest.mark.parametrize("h,w,cin_g,cout_g,k,s,bg", [
    (224, 224, 1, 1, 7, 1, 128), (224, 224, 1, 1, 7, 2, 128),
    (112, 112, 1, 1, 5, 2, 32),
    (8, 8192, 1, 1, 3, 1, 128),             # panorama: single very wide row
    (16, 16, 1, 1, 3, 1, 8),
    (56, 56, 8, 8, 3, 1, 1),                # grouped footprint
])
def test_choose_tiles_grouped_bounds_working_set(h, w, cin_g, cout_g, k, s,
                                                 bg):
    tho, two = choose_tiles_grouped(h, w, cin_g, cout_g, k, k, s, 4,
                                    bgroups=bg)
    ho = (h - k) // s + 1
    wo = (w - k) // s + 1
    assert 1 <= tho <= ho and 1 <= two <= wo
    assert _working_set(tho, two, cin_g, cout_g, k, k, s, 4, bg) \
        <= _VMEM_BUDGET or (tho == 1 and two == 1)


def test_choose_group_block():
    # depthwise: lane-friendly channel tile, multiple of 8, ≤ 128 lanes
    assert choose_group_block(32, 1, 1) == 32
    assert choose_group_block(13, 1, 1) == 16
    assert choose_group_block(960, 1, 1) == 128
    # channel multiplier folds into the lane width
    assert choose_group_block(32, 1, 4) * 4 <= 128
    assert choose_group_block(32, 1, 4) >= 1
    # grouped MXU path: one group per step
    assert choose_group_block(4, 6, 6) == 1


def test_depthwise_traffic_model_reports_halo_saving():
    """Depthwise rows report halo_bytes_saved (group-blocking invariant:
    same aggregate DMA traffic as a dense kernel over the same image)."""
    from repro.kernels.merged_conv import input_traffic_model
    dense = input_traffic_model(230, 230, 64, 7, 7, 1, 2,
                                tile_ho=8, tile_wo=224)
    dw = input_traffic_model(230, 230, 64, 7, 7, 1, 2,
                             tile_ho=8, tile_wo=224, groups=64)
    assert dw["dma_bytes"] == dense["dma_bytes"]
    assert dw["halo_bytes_saved"] == dense["halo_bytes_saved"]
    assert dw["halo_bytes_saved"] > 0
    # default-tiles path consults the grouped planner, still well-formed
    auto = input_traffic_model(114, 114, 32, 3, 3, 2, 2, groups=32)
    assert auto["dma_bytes"] > 0 and auto["relayout_bytes"] > 0
    assert auto["halo_bytes_saved"] == (auto["gather_bytes"]
                                        - auto["dma_bytes"])
