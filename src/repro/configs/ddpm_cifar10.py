"""The paper's own DDPM CIFAR-10 UNet (Ho et al. 2020) — CNN path."""
from repro.models import zoo

CONFIG = zoo.ddpm_unet()
