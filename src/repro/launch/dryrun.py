import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# The two lines above MUST run before any other import (jax locks the device
# count at first init).  Everything below is ordinary.
"""Multi-pod dry-run: ``.lower().compile()`` every (arch × shape × mesh) cell.

For each cell this driver:
  1. builds the production mesh (16×16 single-pod / 2×16×16 multi-pod),
  2. builds ShapeDtypeStruct inputs (specs.py) and FSDP+TP shardings
     (sharding/rules.py) for params, optimizer state, batch and cache,
  3. jits the train_step / serve_step / prefill_step with explicit
     in/out_shardings and donation, lowers, compiles,
  4. records memory_analysis, cost_analysis, and the collective bytes parsed
     from the compiled (post-SPMD) HLO into results/dryrun/<cell>.json.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun [--arch a] [--shape s]
      [--mesh single|multi|both] [--out results/dryrun]
      [--no-fsdp] [--seq-parallel] [--microbatches N] [--tag name]
"""
import argparse
import dataclasses
import json
import re
import sys
import time
import traceback

import jax
import jax.numpy as jnp

from repro.configs.base import (ARCH_IDS, LONG_CONTEXT_OK, SHAPES,
                                get_config)
from repro.launch import specs as S
from repro.launch.mesh import make_production_mesh, mesh_info
from repro.models import transformer as T
from repro.optim.adamw import AdamWConfig, init_opt_state, opt_state_axes
from repro.sharding.rules import (make_rules, param_shardings_with_shapes,
                                  use_rules)
from repro.train.step import (make_prefill_step, make_serve_step,
                              make_train_step)

DTYPE_BYTES = {"f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1,
               "f8e5m2": 1, "s64": 8, "u64": 8, "s32": 4, "u32": 4,
               "s16": 2, "u16": 2, "s8": 1, "u8": 1, "pred": 1, "c64": 8,
               "c128": 16}

COLLECTIVE_OPS = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                  "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(shape_str):
        if dt not in DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * DTYPE_BYTES[dt]
    return total


def parse_collectives(hlo_text: str) -> dict:
    """Sum result-shape bytes of every collective op in a post-SPMD HLO."""
    out = {k: {"count": 0, "bytes": 0} for k in COLLECTIVE_OPS}
    for line in hlo_text.splitlines():
        line = line.strip()
        m = re.match(r"%?[\w.\-]+ = ([^=]+?) (\w[\w\-]*)\(", line)
        if not m:
            continue
        shape_str, opname = m.groups()
        base = opname.rstrip("-start").rstrip("-done") if False else opname
        for k in COLLECTIVE_OPS:
            if opname == k or opname == k + "-start":
                out[k]["count"] += 1
                out[k]["bytes"] += _shape_bytes(shape_str)
                break
    out["total_bytes"] = sum(v["bytes"] for v in out.values()
                             if isinstance(v, dict))
    return out


def _memory_dict(compiled) -> dict:
    try:
        ma = compiled.memory_analysis()
    except Exception as e:          # pragma: no cover
        return {"error": str(e)}
    if ma is None:
        return {}
    d = {}
    for field in ("argument_size_in_bytes", "output_size_in_bytes",
                  "temp_size_in_bytes", "generated_code_size_in_bytes",
                  "alias_size_in_bytes", "host_argument_size_in_bytes",
                  "peak_memory_in_bytes"):
        v = getattr(ma, field, None)
        if v is not None:
            d[field] = int(v)
    if not d:
        d["repr"] = str(ma)
    return d


def _cost_dict(compiled) -> dict:
    try:
        ca = compiled.cost_analysis()
    except Exception as e:          # pragma: no cover
        return {"error": str(e)}
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    return {k: float(v) for k, v in ca.items()
            if isinstance(v, (int, float)) and not k.startswith("utilization")}


@dataclasses.dataclass
class CellOptions:
    fsdp: bool = True
    seq_parallel: bool = False
    microbatches: int = 1
    remat: bool = True
    decode_kv_model: bool = True
    scan_layers: bool = True
    flash_decode: bool = False
    layermerge_budget: float | None = None  # lower the LayerMerge-compressed
                                            # network at this latency budget
                                            # (plan from analytic tables)
    depth_override: int | None = None   # depth-probe (see roofline.py):
                                        # XLA cost analysis counts while-loop
                                        # bodies ONCE, so per-layer costs are
                                        # extrapolated from unrolled shallow
                                        # probes at depth p and 2p.


def run_cell(arch: str, shape_name: str, multi_pod: bool,
             opts: CellOptions = CellOptions()) -> dict:
    cfg = get_config(arch)
    cfg = dataclasses.replace(cfg, remat=opts.remat,
                              scan_layers=opts.scan_layers,
                              decode_flash=opts.flash_decode)
    if opts.depth_override is not None:
        cfg = dataclasses.replace(cfg, num_layers=opts.depth_override,
                                  scan_layers=False)
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    seq_par = opts.seq_parallel or (shape.mode == "prefill"
                                    and shape.seq_len >= 32768)
    rules = make_rules(mesh, fsdp=opts.fsdp, seq_parallel=seq_par,
                       decode_kv_model=opts.decode_kv_model)

    units_spec = None
    if opts.layermerge_budget is not None:
        from repro.models import transformer_host as TH
        env = TH.CostEnv(batch=shape.global_batch, seq=shape.seq_len,
                         chips=int(mesh.devices.size))
        cres = TH.abstract_plan(cfg, budget_ratio=opts.layermerge_budget,
                                env=env)
        if cres is None:
            raise RuntimeError("no feasible LayerMerge plan at this budget")
        units_spec = TH.plan_units_spec(cfg, cres.plan)
        rec_plan = {"budget": opts.layermerge_budget,
                    "predicted_speedup": cres.speedup,
                    "units": [u[0] if u[0] == "merged" else u[2]
                              for u in units_spec],
                    "merged_ranks": [u[1] for u in units_spec
                                     if u[0] == "merged"]}

    if units_spec is not None:
        abstract_params = jax.eval_shape(
            lambda: __import__("repro.models.transformer_host",
                               fromlist=["init_compressed_model"])
            .init_compressed_model(cfg, units_spec, jax.random.PRNGKey(0)))
        from repro.models import transformer_host as TH
        axes = TH.compressed_model_axes(cfg, units_spec)
    else:
        abstract_params, axes = S.param_specs(cfg)
    p_shard = param_shardings_with_shapes(rules, axes, abstract_params)
    batch_ax = S.batch_axes(cfg, shape,
                            with_targets=(shape.mode == "train"))
    rec = {
        "arch": arch, "shape": shape_name,
        "mesh": mesh_info(mesh), "mode": shape.mode,
        "seq_len": shape.seq_len, "global_batch": shape.global_batch,
        "options": dataclasses.asdict(opts),
        "params": int(cfg.param_count()),
        "active_params": int(cfg.active_param_count()),
        "num_layers": cfg.num_layers,
    }

    forward_fn = None
    if units_spec is not None:
        from repro.models import transformer_host as TH
        rec["compression"] = rec_plan
        forward_fn = (lambda p, b: TH.forward_compressed_spec(
            cfg, units_spec, p, b))
        if shape.mode == "decode":
            raise RuntimeError("compressed decode cells are out of scope; "
                               "use train/prefill shapes with --budget")

    t0 = time.time()
    with use_rules(rules):
        if shape.mode == "train":
            opt_cfg = AdamWConfig()
            abstract_opt = jax.eval_shape(init_opt_state, abstract_params)
            # optimizer moments: always fully sharded (ZeRO); when params
            # are TP-only (--no-fsdp) this is the ZeRO-1 layout
            o_rules = make_rules(mesh, fsdp=True, seq_parallel=seq_par,
                                 decode_kv_model=opts.decode_kv_model,
                                 opt_state=True)
            m_shard = param_shardings_with_shapes(o_rules, axes,
                                                  abstract_params)
            o_shard = {"mu": m_shard, "nu": m_shard,
                       "step": jax.sharding.NamedSharding(
                           mesh, jax.sharding.PartitionSpec())}
            step = make_train_step(cfg, opt_cfg,
                                   microbatches=opts.microbatches,
                                   forward_fn=forward_fn,
                                   grad_shardings=m_shard)
            b_specs = S.batch_specs(cfg, shape, with_targets=True)
            b_shard = {k: rules.named(batch_ax[k], b_specs[k].shape)
                       for k in b_specs}
            jitted = jax.jit(step, in_shardings=(p_shard, o_shard, b_shard),
                             out_shardings=(p_shard, o_shard, None),
                             donate_argnums=(0, 1))
            lowered = jitted.lower(abstract_params, abstract_opt, b_specs)
        elif shape.mode == "prefill":
            step = forward_fn if forward_fn is not None \
                else make_prefill_step(cfg)
            b_specs = S.batch_specs(cfg, shape, with_targets=False)
            b_shard = {k: rules.named(batch_ax[k], b_specs[k].shape)
                       for k in b_specs}
            jitted = jax.jit(step, in_shardings=(p_shard, b_shard))
            lowered = jitted.lower(abstract_params, b_specs)
        else:  # decode
            step = make_serve_step(cfg)
            cache_specs = S.cache_specs(cfg, shape)
            cache_ax = T.cache_axes(cfg)
            c_shard = jax.tree.map(
                lambda spec_leaf, ax_leaf: rules.named(
                    tuple(ax_leaf), spec_leaf.shape),
                cache_specs,
                jax.tree.map(lambda a: a, cache_ax,
                             is_leaf=lambda x: isinstance(x, tuple)),
                is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))
            b_specs = S.batch_specs(cfg, shape, with_targets=False)
            b_shard = {k: rules.named(batch_ax[k], b_specs[k].shape)
                       for k in b_specs}
            jitted = jax.jit(step, in_shardings=(p_shard, c_shard, b_shard),
                             out_shardings=(None, c_shard),
                             donate_argnums=(1,))
            lowered = jitted.lower(abstract_params, cache_specs, b_specs)
        rec["lower_s"] = round(time.time() - t0, 2)

        t1 = time.time()
        compiled = lowered.compile()
        rec["compile_s"] = round(time.time() - t1, 2)

    rec["memory"] = _memory_dict(compiled)
    rec["cost"] = _cost_dict(compiled)
    hlo = compiled.as_text()
    rec["collectives"] = parse_collectives(hlo)
    rec["hlo_bytes"] = len(hlo)
    return rec


def cell_list(args):
    archs = [args.arch] if args.arch else list(ARCH_IDS)
    shapes = [args.shape] if args.shape else list(SHAPES)
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]
    cells = []
    for a in archs:
        for s in shapes:
            if s == "long_500k" and a not in LONG_CONTEXT_OK:
                continue  # documented skip (DESIGN §2.3)
            for m in meshes:
                cells.append((a, s, m))
    return cells


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="both",
                    choices=["single", "multi", "both"])
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--no-fsdp", action="store_true")
    ap.add_argument("--no-remat", action="store_true")
    ap.add_argument("--no-scan", action="store_true")
    ap.add_argument("--no-decode-kv-model", action="store_true")
    ap.add_argument("--seq-parallel", action="store_true")
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--flash-decode", action="store_true",
                    help="decode attention via shard_map LSE combine")
    ap.add_argument("--budget", type=float, default=None,
                    help="lower the LayerMerge-compressed net at this "
                         "latency-budget ratio (train/prefill shapes)")
    ap.add_argument("--tag", default="")
    ap.add_argument("--probe", action="store_true",
                    help="depth-probe pass: compile each cell unrolled at "
                         "pattern depth p and 2p (per-layer cost "
                         "extrapolation for scanned cells)")
    args = ap.parse_args(argv)

    os.makedirs(args.out, exist_ok=True)
    opts = CellOptions(fsdp=not args.no_fsdp,
                       seq_parallel=args.seq_parallel,
                       microbatches=args.microbatches,
                       remat=not args.no_remat,
                       decode_kv_model=not args.no_decode_kv_model,
                       scan_layers=not args.no_scan,
                       flash_decode=args.flash_decode,
                       layermerge_budget=args.budget)
    failures = 0
    jobs = []
    for arch, shape, multi in cell_list(args):
        if args.probe:
            p = len(get_config(arch).temporal_pattern)
            suffix = f"-{args.tag}" if args.tag else ""
            jobs.append((arch, shape, multi, p, f"probe{p}{suffix}"))
            if p < get_config(arch).num_layers:
                jobs.append((arch, shape, multi, 2 * p,
                             f"probe{2 * p}{suffix}"))
        else:
            jobs.append((arch, shape, multi, None, args.tag))
    for arch, shape, multi, depth, tag in jobs:
        mesh_tag = "multi" if multi else "single"
        name = f"{arch}__{shape}__{mesh_tag}"
        if tag:
            name += f"__{tag}"
        path = os.path.join(args.out, name + ".json")
        print(f"[dryrun] {name} ...", flush=True)
        try:
            rec = run_cell(arch, shape, multi,
                           dataclasses.replace(opts, depth_override=depth))
            rec["status"] = "ok"
            print(f"[dryrun] {name}: OK lower={rec['lower_s']}s "
                  f"compile={rec['compile_s']}s "
                  f"flops={rec['cost'].get('flops', 0):.3e} "
                  f"coll={rec['collectives']['total_bytes']:.3e}B",
                  flush=True)
        except Exception as e:
            failures += 1
            rec = {"arch": arch, "shape": shape, "mesh": mesh_tag,
                   "status": "fail", "error": str(e),
                   "traceback": traceback.format_exc()}
            print(f"[dryrun] {name}: FAIL {e}", flush=True)
        with open(path, "w") as f:
            json.dump(rec, f, indent=2)
    print(f"[dryrun] done, {failures} failures")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
