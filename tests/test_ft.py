"""Fault-tolerance tests: failure-restart, resume, elastic reshard,
straggler watchdog — the contracts the 1000-node deployment relies on."""
import dataclasses
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import ckpt as C
from repro.testing.subproc import run_code
from repro.configs import get_config
from repro.data.pipeline import GlobalBatcher, SyntheticTokens
from repro.models import transformer as T
from repro.optim.adamw import AdamWConfig
from repro.train.loop import LoopConfig, train_loop


@pytest.fixture(scope="module")
def tiny():
    cfg = dataclasses.replace(
        get_config("smollm-135m"), num_layers=2, d_model=32, num_heads=2,
        num_kv_heads=1, head_dim=16, d_ff=64, vocab_size=64,
        dtype="float32", remat=False)
    params, _ = T.init_model(cfg, jax.random.PRNGKey(0))
    data = SyntheticTokens(cfg.vocab_size, 4, 16, seed=0)
    return cfg, params, GlobalBatcher(data)


def test_loop_trains_and_checkpoints(tiny, tmp_path):
    cfg, params, batcher = tiny
    res = train_loop(cfg, AdamWConfig(lr=2e-3, total_steps=40),
                     LoopConfig(total_steps=40, ckpt_every=10,
                                ckpt_dir=str(tmp_path), log_every=100),
                     params, batcher, logger=lambda s: None)
    assert res.final_step == 40
    assert C.latest_step(str(tmp_path)) == 40
    assert np.mean(res.losses[-5:]) < np.mean(res.losses[:5])


def test_failure_restart_recovers(tiny, tmp_path):
    """A simulated node failure at step 23 restarts from the step-20
    checkpoint and completes; the final state matches a failure-free run
    exactly (deterministic data + replay)."""
    cfg, params, batcher = tiny
    fired = {"done": False}

    def bomb(step):
        if step == 23 and not fired["done"]:
            fired["done"] = True
            raise RuntimeError("simulated device loss")

    res = train_loop(cfg, AdamWConfig(lr=2e-3, total_steps=30),
                     LoopConfig(total_steps=30, ckpt_every=10,
                                ckpt_dir=str(tmp_path), log_every=100),
                     params, batcher, failure_hook=bomb,
                     logger=lambda s: None)
    assert res.restarts == 1 and res.final_step == 30

    clean = train_loop(cfg, AdamWConfig(lr=2e-3, total_steps=30),
                       LoopConfig(total_steps=30, ckpt_every=10,
                                  ckpt_dir=str(tmp_path) + "_clean",
                                  log_every=100),
                       params, batcher, logger=lambda s: None)
    for a, b in zip(jax.tree.leaves(res.params),
                    jax.tree.leaves(clean.params)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-5, atol=1e-6)


def test_resume_from_checkpoint(tiny, tmp_path):
    """Killing the loop and re-invoking it resumes at the saved step."""
    cfg, params, batcher = tiny
    train_loop(cfg, AdamWConfig(lr=2e-3, total_steps=20),
               LoopConfig(total_steps=20, ckpt_every=10,
                          ckpt_dir=str(tmp_path), log_every=100),
               params, batcher, logger=lambda s: None)
    logs = []
    res = train_loop(cfg, AdamWConfig(lr=2e-3, total_steps=35),
                     LoopConfig(total_steps=35, ckpt_every=10,
                                ckpt_dir=str(tmp_path), log_every=100),
                     params, batcher, logger=logs.append)
    assert any("resumed from step 20" in l for l in logs)
    assert res.final_step == 35


def test_straggler_watchdog(tiny, tmp_path):
    """Persistently slow steps trip the watchdog → restart path."""
    import time
    cfg, params, batcher = tiny
    slow = {"n": 0}

    def laggard(step):
        if 25 <= step < 28 and slow["n"] < 3:
            slow["n"] += 1
            time.sleep(1.0)

    logs = []
    res = train_loop(cfg, AdamWConfig(lr=2e-3, total_steps=32),
                     LoopConfig(total_steps=32, ckpt_every=10,
                                ckpt_dir=str(tmp_path), log_every=100,
                                deadline_factor=6.0,
                                max_stragglers_in_row=3),
                     params, batcher, failure_hook=laggard,
                     logger=logs.append)
    assert any("straggler" in l for l in logs)
    assert res.final_step == 32


def test_elastic_reshard_restore(tmp_path):
    """A checkpoint saved from one topology restores onto another mesh
    (subprocess with 8 forced host devices; save was unsharded)."""
    tree = {"w": jnp.arange(32.0).reshape(8, 4), "b": jnp.ones(4)}
    C.save(str(tmp_path), 1, tree)
    code = textwrap.dedent(f"""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.checkpoint import ckpt as C
        mesh = jax.make_mesh((4, 2), ("data", "model"))
        like = {{"w": jnp.zeros((8, 4)), "b": jnp.zeros(4)}}
        sh = {{"w": NamedSharding(mesh, P("data", "model")),
              "b": NamedSharding(mesh, P("model"))}}
        out = C.restore({str(tmp_path)!r}, 1, like, shardings=sh)
        assert out["w"].sharding.spec == P("data", "model"), out["w"].sharding
        np.testing.assert_array_equal(
            np.asarray(out["w"]), np.arange(32.0).reshape(8, 4))
        print("ELASTIC_OK")
    """)
    r = run_code(code, devices=8, timeout=300)
    assert "ELASTIC_OK" in r.stdout, r.stdout + r.stderr
