"""Algorithm 1 — the exact DP for the surrogate problem (Problem 5).

Also provides:

* :func:`solve_knapsack` — the paper's *LayerOnly* baseline (Problem 8), a
  0-1 knapsack over whole layers solved exactly on the same latency grid;
* :func:`brute_force` — an exponential reference solver used by the property
  tests to certify Theorem 3.1 (DP == optimum) on small instances.

Latency discretization follows the paper: every table latency is floored to
the grid ``{T0/P, 2·T0/P, …, T0}`` (integer units of ``T0/P``).  With integer
unit latencies the DP is exact; with real latencies it is exact for the
floored instance, as in the paper.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Callable, Mapping

import numpy as np

from .plan import CompressionPlan, Segment

NEG = -math.inf

# TableFn: (i, j) -> {k: (importance I[i,j,k], latency T[i,j,k], kept ids)}
TableFn = Callable[[int, int], Mapping[int, tuple[float, float, tuple[int, ...]]]]


@dataclasses.dataclass
class DPResult:
    plan: CompressionPlan
    objective: float
    latency: float          # true (undiscretized) latency sum
    table_M: np.ndarray     # the DP value table, for inspection/tests


def _discretize(t: float, unit: float) -> int:
    """Floor a latency to grid units (paper §3.3 / Appendix C)."""
    return int(math.floor(t / unit + 1e-9))


def solve_dp(
    L: int,
    table: TableFn,
    T0: float,
    P: int,
    *,
    method: str = "layermerge",
    original_k: Callable[[int], int] | None = None,
) -> DPResult | None:
    """Exact DP of Algorithm 1.

    ``table(i, j)`` returns the merged-segment options for span ``(i, j]``
    (empty if the span is not mergeable).  Returns ``None`` when no feasible
    plan exists within ``T0`` (budget too tight even for the cheapest plan).
    """
    if T0 <= 0 or P <= 0:
        raise ValueError("T0 and P must be positive")
    unit = T0 / P

    # M[l, t]: best Σ I over the first l layers with budget index t (0..P).
    M = np.full((L + 1, P + 1), NEG, dtype=np.float64)
    M[0, :] = 0.0
    # Backpointers: for (l, t) store (l*, k*) and bookkeeping for reconstruction.
    back: dict[tuple[int, int], tuple[int, int, int, float, tuple[int, ...]]] = {}
    # cache span options so reconstruction does not recompute tables
    span_opts: dict[tuple[int, int], Mapping[int, tuple[float, float, tuple[int, ...]]]] = {}

    for j in range(1, L + 1):
        for i in range(j - 1, -1, -1):
            opts = table(i, j)
            if opts:
                span_opts[(i, j)] = opts

    for l in range(1, L + 1):
        for lp in range(l):
            opts = span_opts.get((lp, l))
            if not opts:
                continue
            for k, (imp, lat, kept) in opts.items():
                td = _discretize(lat, unit)
                if td > P:
                    continue
                lo = max(td, 0)
                for t in range(lo, P + 1):
                    prev = M[lp, t - td]
                    if prev == NEG:
                        continue
                    cand = prev + imp
                    if cand > M[l, t]:
                        M[l, t] = cand
                        back[(l, t)] = (lp, k, td, lat, kept)

    if M[L, P] == NEG:
        return None

    # -- reconstruct A*, C*, k* ----------------------------------------------
    segs: list[Segment] = []
    l, t = L, P
    true_lat = 0.0
    while l > 0:
        lp, k, td, lat, kept = back[(l, t)]
        orig = (original_k is not None and l - lp == 1
                and k == original_k(l) and set(kept) == {l})
        segs.append(Segment(i=lp, j=l, k=k, kept=kept, original=orig))
        true_lat += lat
        l, t = lp, t - td
    segs.reverse()
    plan = CompressionPlan(num_layers=L, segments=tuple(segs),
                           objective=float(M[L, P]), latency=true_lat,
                           budget=T0, method=method)
    return DPResult(plan=plan, objective=float(M[L, P]), latency=true_lat,
                    table_M=M)


def solve_knapsack(
    L: int,
    importance: Mapping[int, float],
    latency: Mapping[int, float],
    T0: float,
    P: int,
    *,
    forced: tuple[int, ...] = (),
) -> tuple[tuple[int, ...], float, float] | None:
    """*LayerOnly* baseline (Problem 8): exact 0-1 knapsack on the grid.

    Returns ``(C*, objective, true_latency)`` — the kept layer set — or
    ``None`` if even the forced set exceeds the budget.
    """
    unit = T0 / P
    forced_set = set(forced)
    M = np.full(P + 1, NEG)
    M[0:] = 0.0
    keep: dict[tuple[int, int], bool] = {}
    # classic knapsack, layer by layer
    for l in range(1, L + 1):
        imp, lat = importance[l], latency[l]
        td = _discretize(lat, unit)
        Mn = np.full(P + 1, NEG)
        for t in range(P + 1):
            skip = M[t] if l not in forced_set else NEG
            take = M[t - td] + imp if t - td >= 0 and M[t - td] != NEG else NEG
            if take >= skip:
                Mn[t], keep[(l, t)] = take, True
            else:
                Mn[t], keep[(l, t)] = skip, False
        M = Mn
    if M[P] == NEG:
        return None
    C: list[int] = []
    t = P
    true_lat = 0.0
    for l in range(L, 0, -1):
        if keep[(l, t)]:
            C.append(l)
            true_lat += latency[l]
            t -= _discretize(latency[l], unit)
    C.reverse()
    return tuple(C), float(M[P]), true_lat


def brute_force(
    L: int,
    table: TableFn,
    T0: float,
    P: int,
) -> tuple[float, list[Segment]] | None:
    """Exponential reference solver for Theorem 3.1 property tests.

    Enumerates every segmentation of ``(0, L]`` and every ``k`` per segment,
    using the same floored-latency feasibility test as :func:`solve_dp`.
    """
    unit = T0 / P
    best: list[tuple[float, list[Segment]]] = [(NEG, [])]

    def rec(pos: int, used: int, imp: float, segs: list[Segment]):
        if pos == L:
            if imp > best[0][0]:
                best[0] = (imp, list(segs))
            return
        for j in range(pos + 1, L + 1):
            opts = table(pos, j)
            for k, (i_val, lat, kept) in opts.items():
                td = _discretize(lat, unit)
                if used + td <= P:
                    segs.append(Segment(i=pos, j=j, k=k, kept=kept))
                    rec(j, used + td, imp + i_val, segs)
                    segs.pop()

    rec(0, 0, 0.0, [])
    if best[0][0] == NEG:
        return None
    return best[0]
