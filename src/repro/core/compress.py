"""Algorithm 2 — the full LayerMerge procedure, plus the two baselines.

``compress(host, ...)`` runs: build tables → DP (Algorithm 1) → replace →
(optionally fine-tune) → merge.  ``method``:

* ``'layermerge'`` — the paper's joint optimization (activations + layers);
* ``'depth'``      — Kim et al. 2023 baseline: activations only (C = [L]);
* ``'layeronly'``  — whole-layer knapsack (Problem 8), no merging.

All per-layer probes (the ``T_orig`` pass and the knapsack's latency
column) route through :mod:`repro.core.probe_engine`, so they share the
same shape-signature bucketing as the table build instead of re-timing
every layer ad hoc.

The merge step itself lives in the runtime layer: results are
artifact-backed (``CompressResult.save(path)`` lowers the plan via
``host.lower_plan`` and publishes a portable merged-model artifact that
``repro.runtime.load`` reopens anywhere — serving, benchmarks,
fine-tuning).  ``python -m repro.compress`` wraps the whole pipeline in
one command.
"""
from __future__ import annotations

import dataclasses
import math
import time
from typing import Callable

from . import probe_engine
from .dp import DPResult, solve_dp, solve_knapsack
from .importance import ImportanceSpec, measure_importance
from .latency import AnalyticTPUOracle, LatencyOracle, WallClockOracle
from .plan import CompressionPlan, Segment
from .tables import Tables, build_tables, one_segment_plan


def _resolve_oracle(latency_oracle) -> LatencyOracle:
    """THE oracle-default resolution point — resolved once per pipeline
    run and threaded through :class:`CompressResult`, so the artifact can
    record which oracle certified its latency numbers."""
    return latency_oracle or AnalyticTPUOracle()


@dataclasses.dataclass
class CompressResult:
    plan: CompressionPlan
    tables: Tables | None
    original_latency: float
    compressed_latency: float
    dp_seconds: float
    oracle: LatencyOracle | None = None   # the resolved latency oracle
    host: object = None                   # the host that planned (for lowering)
    params: object = None                 # params the plan was built against
    dist_report: object = None            # DistReport when the table build
    #                                       fanned out across workers

    @property
    def speedup(self) -> float:
        return self.original_latency / max(self.compressed_latency, 1e-12)

    # -- artifact export -------------------------------------------------------
    def lower(self):
        """Lower the plan to the shared unit IR (merged, deployable form)."""
        return self.host.lower_plan(self.plan, self.params)

    def save(self, path: str, extra_meta: dict | None = None) -> str:
        """Publish a portable merged-model artifact (see
        :mod:`repro.runtime.artifact`).  Records the plan, the merged
        unit graph + weights, the certifying oracle, and the measured
        latency numbers.  Returns the artifact's content fingerprint."""
        from repro import runtime
        from . import table_cache

        meta = {
            "oracle": (table_cache.oracle_token(self.oracle)
                       if self.oracle is not None else None),
            "original_latency": self.original_latency,
            "compressed_latency": self.compressed_latency,
            "predicted_speedup": self.speedup,
            "method": self.plan.method,
            "quantized_units": sum(1 for s in self.plan.segments
                                   if s.quant != "none"),
            # Latency entries that were NOT clean first-shot measurements
            # ("retimed"/"quarantined") — deployers can see exactly which
            # numbers the plan rests on (empty list: all clean).
            "probe_provenance": (
                [{"i": i, "j": j, "k": k, "flag": flag}
                 for (i, j, k), flag
                 in sorted(self.tables.provenance.items())]
                if self.tables is not None else []),
        }
        meta.update(extra_meta or {})
        return runtime.save(path, self.lower(), plan=self.plan, meta=meta)


def original_latency(host, latency_oracle=None, params=None, *,
                     engine: str = "batched") -> float:
    """Σ per-layer latency of the untouched network (the paper's T_orig)."""
    oracle = _resolve_oracle(latency_oracle)
    return sum(probe_engine.layer_latencies(host, oracle, params,
                                            engine=engine))


def compress(
    host,
    *,
    budget_ratio: float,
    P: int = 200,
    method: str = "layermerge",
    latency_oracle: LatencyOracle | None = None,
    importance: ImportanceSpec | str = "magnitude",
    base_perf: float | None = None,
    params=None,
    engine: str = "batched",
    cache_dir: str | None = None,
    probe_config: probe_engine.ProbeConfig | None = None,
    resume: bool = True,
    workers: int = 0,
    host_spec: dict | None = None,
    work_dir: str | None = None,
    quantize: str | None = None,
) -> CompressResult | None:
    """Run LayerMerge (or a baseline) at ``T0 = budget_ratio · T_orig``.

    The result is artifact-backed: it carries the host, params, and the
    resolved oracle, so ``result.save(path)`` publishes a portable
    merged-model artifact without re-deriving any of them.

    ``probe_config`` / ``resume`` are the crash-safety knobs threaded to
    :func:`repro.core.tables.build_tables`: probe retry/timeout/
    quarantine policy, and journal-based resumption of an interrupted
    table build (requires ``cache_dir``).

    ``workers > 0`` fans the latency probes out across subprocess workers
    (:func:`repro.core.dist_build.dist_build_tables` — requires
    ``cache_dir`` plus a ``host_spec`` naming a factory that rebuilds
    this host in another process); the fan-out's :class:`DistReport`
    lands on ``result.dist_report``.  The merged tables are bit-identical
    to ``workers=0``, so every downstream number is unchanged.

    ``quantize`` ('int8' | 'w8a8') widens every span's candidate row with
    derived precision siblings (:func:`repro.core.tables.
    quant_sibling_entries`), so the DP co-optimizes merge structure ×
    per-unit precision under the one budget; segments it picks quantized
    lower to narrow-weight units.  ``None``/'none' leaves tables, DP
    visit order, and plans bit-identical to an fp-only run.
    """
    oracle = _resolve_oracle(latency_oracle)
    layer_lats = probe_engine.layer_latencies(host, oracle, params,
                                              engine=engine,
                                              probe_config=probe_config)
    t_orig = sum(layer_lats)
    T0 = budget_ratio * t_orig
    L = len(host.descs())

    if method == "layeronly":
        if quantize and quantize != "none":
            raise ValueError("quantize is a merged-segment feature; "
                             "method='layeronly' has no merged units")
        return _layer_only(host, T0, P, oracle, importance, base_perf, params,
                           t_orig, layer_lats)

    dist_report = None
    if workers > 0:
        from .dist_build import DistBuildError, dist_build_tables

        if cache_dir is None:
            raise DistBuildError(
                "workers > 0 requires cache_dir (worker results merge "
                "through the build journal)")
        tables, dist_report = dist_build_tables(
            host, cache_dir=cache_dir, workers=workers,
            host_spec=host_spec, method=method, latency_oracle=oracle,
            importance=importance, base_perf=base_perf, params=params,
            engine=engine, probe_config=probe_config, resume=resume,
            work_dir=work_dir)
        # Precision siblings are derived AFTER the distributed merge: the
        # worker manifest/journal stay fp-only, so fan-out bit-identity
        # (and resume) are untouched by quantization.
        from .tables import with_quant_siblings
        tables = with_quant_siblings(tables, host, quantize)
    else:
        tables = build_tables(host, method=method, latency_oracle=oracle,
                              importance=importance, base_perf=base_perf,
                              params=params, engine=engine,
                              cache_dir=cache_dir,
                              probe_config=probe_config, resume=resume,
                              quantize=quantize)
    t0 = time.perf_counter()
    res = solve_dp(L, tables.fn(), T0, P, method=method,
                   original_k=host.original_k)
    dp_s = time.perf_counter() - t0
    if res is None:
        return None
    return CompressResult(plan=res.plan, tables=tables,
                          original_latency=t_orig,
                          compressed_latency=res.latency,
                          dp_seconds=dp_s, oracle=oracle, host=host,
                          params=params, dist_report=dist_report)


def _layer_only(host, T0, P, oracle, importance, base_perf, params, t_orig,
                layer_lats):
    """Problem 8: latency-aware layer pruning (knapsack).

    ``layer_lats`` comes from the caller's probe pass — the same engine
    walk that produced ``T_orig`` — so each layer is probed exactly once.
    """
    descs = host.descs()
    L = len(descs)
    imp: dict[int, float] = {}
    lat: dict[int, float] = dict(zip(range(1, L + 1), layer_lats))
    forced = tuple(d.index for d in descs if not d.prunable)
    total = sum(d.value for d in descs) or 1.0
    for l in range(1, L + 1):
        # I[l] — importance of KEEPING l: exp(perf drop when l is removed).
        if not descs[l - 1].prunable:
            imp[l] = 1.0
        elif isinstance(importance, ImportanceSpec):
            probe = Segment(i=l - 1, j=l, k=host.pruned_k(l), kept=())
            apply_fn, p = host.replaced_apply(
                one_segment_plan(host, probe), params)
            removed = measure_importance(apply_fn, p, importance,
                                         base_perf or 0.0)
            imp[l] = 1.0 / max(removed, 1e-12)
        else:
            imp[l] = math.exp(descs[l - 1].value / total)
    t0 = time.perf_counter()
    sol = solve_knapsack(L, imp, lat, T0, P, forced=forced)
    dp_s = time.perf_counter() - t0
    if sol is None:
        return None
    C, obj, true_lat = sol
    kept = set(C)
    segs = tuple(
        Segment(i=l - 1, j=l,
                k=host.original_k(l) if l in kept else host.pruned_k(l),
                kept=(l,) if l in kept else (),
                original=l in kept)
        for l in range(1, L + 1))
    plan = CompressionPlan(num_layers=L, segments=segs, objective=obj,
                           latency=true_lat, budget=T0, method="layeronly")
    return CompressResult(plan=plan, tables=None, original_latency=t_orig,
                          compressed_latency=true_lat, dp_seconds=dp_s,
                          oracle=oracle, host=host, params=params)
