"""Pallas TPU kernel: merged-segment convolution (VALID, stride 1, NHWC).

The paper's hot spot: after LayerMerge, a segment executes as ONE conv
whose kernel has grown (Eq. 1).  TPU adaptation: instead of im2col (which
materializes the k²-unrolled input in HBM), each grid step keeps one
*output-row tile* of the image in VMEM and accumulates the k_h·k_w shifted
GEMMs — (tile_ho·Wo, Cin) @ (Cin, bCout) per tap — on the MXU, so the grown
kernel costs FLOPs but no extra HBM traffic (that is exactly the trade the
DP's latency table models).

Grid: ``(batch, ho-tiles, cout-tiles)``.  Each input block carries a
``k_h − 1``-row halo so neighbouring output tiles need no communication;
the halo'd row tiles are materialized host-side, which keeps the BlockSpec
index maps blocked and static at the price of one extra input-sized HBM
copy per call (the gather rewrites the whole image plus halo rows whenever
more than one row tile is needed — a zero-copy halo needs manual DMA from
an HBM-resident input; see ROADMAP).  VMEM per step: input
``(tile_ho + k_h − 1)·W·Cin``, weights
``k²·Cin·bCout``, fp32 accumulator ``tile_ho·Wo·bCout`` — bounded by the
tile chooser regardless of image height, so 224×224-class inputs no longer
require full-image VMEM residency.  Bias add and the boundary activation
σ_j run in the kernel epilogue (fp32, before the store), eliminating the
extra HBM round-trip the unfused epilogue paid.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl

from .ref import apply_activation

# VMEM budget for one halo'd input tile; ~1.5 MiB leaves room for the
# weight block, fp32 accumulator and double buffering inside ~16 MiB/core.
_TILE_IN_BYTES = 1.5 * 2 ** 20


def choose_tile_ho(h: int, w: int, cin: int, kh: int, itemsize: int,
                   budget_bytes: float = _TILE_IN_BYTES) -> int:
    """Largest output-row tile whose halo'd input block fits the budget.

    Prefers multiples of 8 (the fp32 sublane count) and collapses to the
    full image when it already fits — then the kernel degenerates to the
    untiled fast path with a single ho-tile.
    """
    ho = h - kh + 1
    row_bytes = max(w * cin * itemsize, 1)
    tile = int(budget_bytes // row_bytes) - (kh - 1)
    if tile >= ho:
        return max(ho, 1)
    tile = max(tile, 1)
    if tile > 8:
        tile -= tile % 8
    return tile


def _kernel(x_ref, w_ref, b_ref, o_ref, *, kh: int, kw: int,
            activation: str | None):
    tho, wo, bcout = o_ref.shape
    cin = x_ref.shape[-1]
    acc = jnp.zeros((tho * wo, bcout), jnp.float32)
    for u in range(kh):
        for v in range(kw):
            xs = x_ref[u:u + tho, v:v + wo, :].astype(jnp.float32)
            ws = w_ref[u, v].astype(jnp.float32)          # (Cin, bCout)
            acc = acc + jnp.dot(xs.reshape(tho * wo, cin), ws,
                                preferred_element_type=jnp.float32)
    acc = acc + b_ref[0].astype(jnp.float32)              # (bCout,) broadcast
    # fused epilogue: σ_j on the fp32 accumulator, shared with the oracle
    acc = apply_activation(acc, activation)
    o_ref[...] = acc.reshape(tho, wo, bcout).astype(o_ref.dtype)


def merged_conv(x, w, b=None, *, bcout: int = 128, tile_ho: int | None = None,
                activation: str | None = None, interpret: bool = False):
    """x: (N, H, W, Cin); w: (kh, kw, Cin, Cout) → (N, Ho, Wo, Cout).

    ``tile_ho`` is the output-row tile height (default: chosen to bound the
    VMEM working set); ``b``/``activation`` fuse the segment epilogue.
    """
    n, h, wdt, cin = x.shape
    kh, kw, _, cout = w.shape
    ho, wo = h - kh + 1, wdt - kw + 1
    bcout = min(bcout, cout)
    assert cout % bcout == 0, "pad channels at the ops layer"
    if tile_ho is None:
        tile_ho = choose_tile_ho(h, wdt, cin, kh, x.dtype.itemsize)
    tile_ho = max(1, min(tile_ho, ho))
    n_th = -(-ho // tile_ho)
    ho_p = n_th * tile_ho
    tile_hi = tile_ho + kh - 1

    # Halo'd row tiles, materialized host-side: tile t covers input rows
    # [t·tile_ho, t·tile_ho + tile_hi).  Rows past H (only in the ragged
    # last tile) are zero-padded and the garbage output rows sliced off.
    need_h = ho_p + kh - 1
    if need_h > h:
        x = jnp.pad(x, ((0, 0), (0, need_h - h), (0, 0), (0, 0)))
    if n_th == 1:
        xt = x[:, None]
    else:
        rows = (np.arange(n_th)[:, None] * tile_ho
                + np.arange(tile_hi)[None, :]).reshape(-1)
        xt = x[:, rows].reshape(n, n_th, tile_hi, wdt, cin)

    bias = jnp.zeros((1, cout), x.dtype) if b is None else b.reshape(1, cout)

    grid = (n, n_th, cout // bcout)
    out = pl.pallas_call(
        functools.partial(_kernel, kh=kh, kw=kw, activation=activation),
        grid=grid,
        in_specs=[
            pl.BlockSpec((None, None, tile_hi, wdt, cin),
                         lambda bb, th, co: (bb, th, 0, 0, 0)),
            pl.BlockSpec((kh, kw, cin, bcout), lambda bb, th, co: (0, 0, 0, co)),
            pl.BlockSpec((1, bcout), lambda bb, th, co: (0, co)),
        ],
        out_specs=pl.BlockSpec((None, tile_ho, wo, bcout),
                               lambda bb, th, co: (bb, th, 0, co)),
        out_shape=jax.ShapeDtypeStruct((n, ho_p, wo, cout), x.dtype),
        interpret=interpret,
    )(xt, w, bias)
    return out[:, :ho] if ho_p != ho else out
