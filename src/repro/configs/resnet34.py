"""The paper's own ResNet-34 (He et al. 2016) — CNN path."""
from repro.models import zoo

CONFIG = zoo.resnet34()
