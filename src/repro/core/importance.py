"""Importance values ``I[i,j,k]`` (Eq. 4) — fine-tune-and-measure.

The paper defines the importance of a merged layer as::

    I[i,j,k] = exp( Perf(net with segment (i,j] replaced, few-step FT)
                    − Perf(pre-trained net) )

with performance = accuracy (classification) or −diffusion-loss (DDPM,
further divided by the pre-trained loss for stability — Appendix A).  The
``exp`` keeps importances positive, which the paper observes favours keeping
more activation layers.

Fine-tuning uses a small random subset of the training set (4 % ImageNet /
1 % CIFAR10 in the paper) and evaluates on a held-out subset of the same
size.  In this offline container the data pipeline supplies synthetic
batches, and an additional *self-distillation* mode (match the pre-trained
network's outputs on random inputs) is provided — a data-free proxy with the
same structure.  Both run through this module.
"""
from __future__ import annotations

import dataclasses
import math
import weakref
from typing import Callable, Sequence

import jax
import jax.numpy as jnp


@dataclasses.dataclass
class ImportanceSpec:
    """How to fine-tune and score a candidate replaced network."""

    loss_fn: Callable          # (apply_fn, params, batch) -> scalar loss
    perf_fn: Callable          # (apply_fn, params, batches) -> float (higher=better)
    train_batches: Sequence    # few batches for the short fine-tune
    eval_batches: Sequence
    steps: int = 8
    lr: float = 1e-3
    normalize_by_base: bool = False   # DDPM trick: divide by base loss
    cache_token: str | None = None    # stable workload name enabling the
                                      # on-disk table cache (closures are
                                      # not content-addressable)


# -- per-apply_fn compilation caches -----------------------------------------
#
# Every probe builds a fresh replaced network, but the SAME apply_fn is
# driven many times within one probe (grad per fine-tune step, eval per
# batch) and across repeated probes on shared networks.  Keyed weakly on
# apply_fn so caches die with the closure: builders receive a *weak*
# dereference of apply_fn, because a cached jitted closure that strongly
# referenced its own cache key would make the WeakKeyDictionary immortal
# and leak one XLA executable per probe.  Values hold a strong ref to the
# auxiliary function (loss_fn) so its id() cannot be recycled while cached.

_FN_CACHE: "weakref.WeakKeyDictionary" = weakref.WeakKeyDictionary()


def _cached(apply_fn, key, build):
    """``build(get_apply)`` → ``(strong_refs, fn)``; returns cached ``fn``."""
    try:
        per = _FN_CACHE.setdefault(apply_fn, {})
    except TypeError:            # non-weakrefable callable: no caching
        return build(lambda: apply_fn)[1]
    hit = per.get(key)
    if hit is None:
        ref = weakref.ref(apply_fn)
        hit = build(ref)
        per[key] = hit
    return hit[1]


def _cached_grad_fn(apply_fn, loss_fn):
    """Jitted ``grad`` of the fine-tune loss, cached per (apply_fn, loss)."""
    return _cached(
        apply_fn, ("grad", id(loss_fn)),
        lambda get: (loss_fn,
                     jax.jit(jax.grad(lambda p, b: loss_fn(get(), p, b)))))


def _adam_finetune(apply_fn, params, spec: ImportanceSpec):
    """Minimal Adam used only for the few-step Eq. 4 fine-tune."""
    b1, b2, eps = 0.9, 0.999, 1e-8
    m = jax.tree.map(jnp.zeros_like, params)
    v = jax.tree.map(jnp.zeros_like, params)
    grad_fn = _cached_grad_fn(apply_fn, spec.loss_fn)

    for step in range(spec.steps):
        batch = spec.train_batches[step % len(spec.train_batches)]
        g = grad_fn(params, batch)
        t = step + 1
        m = jax.tree.map(lambda mm, gg: b1 * mm + (1 - b1) * gg, m, g)
        v = jax.tree.map(lambda vv, gg: b2 * vv + (1 - b2) * gg * gg, v, g)
        lr_t = spec.lr * math.sqrt(1 - b2 ** t) / (1 - b1 ** t)
        params = jax.tree.map(
            lambda p, mm, vv: p - lr_t * mm / (jnp.sqrt(vv) + eps),
            params, m, v)
    return params


def adam_finetune_batched(apply_fn, stacked_params, spec: ImportanceSpec,
                          grad_mask=None):
    """Vmapped few-step Adam over a stacked probe axis (probe engine path).

    ``stacked_params`` is one pytree whose leaves carry a leading probe
    axis; ``apply_fn`` is shared by every lane (the host guarantees the
    candidates are apply-compatible).  ``grad_mask`` (same structure,
    stacked 0/1 scalars) freezes leaves that must stay exactly at their
    candidate value — e.g. the Dirac kernels standing in for pruned convs,
    whose update would otherwise turn "no layer" into a free extra layer.

    One fine-tune step for ALL lanes is a single vmapped grad + update;
    with more than one local device the probe axis is additionally
    pmap-sharded, so the per-entry Adam loops of the sequential path
    collapse into ``spec.steps`` device-parallel launches per bucket.
    """
    b1, b2, eps = 0.9, 0.999, 1e-8
    grad_fn = jax.grad(lambda p, b: spec.loss_fn(apply_fn, p, b))
    if grad_mask is None:
        grad_mask = jax.tree.map(
            lambda x: jnp.ones((x.shape[0],), x.dtype), stacked_params)

    def step(params, m, v, mask, batch, lr_t):
        g = grad_fn(params, batch)
        g = jax.tree.map(lambda gg, mm: gg * mm, g, mask)
        m = jax.tree.map(lambda mm, gg: b1 * mm + (1 - b1) * gg, m, g)
        v = jax.tree.map(lambda vv, gg: b2 * vv + (1 - b2) * gg * gg, v, g)
        params = jax.tree.map(
            lambda p, mm, vv: p - lr_t * mm / (jnp.sqrt(vv) + eps),
            params, m, v)
        return params, m, v

    n = jax.tree.leaves(stacked_params)[0].shape[0]
    axes = (0, 0, 0, 0, None, None)
    ndev = jax.local_device_count()
    shard = ndev > 1 and n > 1
    if shard:
        # Shard the probe axis across local devices: pad to a multiple of
        # the device count (replicating lane 0 — discarded on unpad) and
        # run the vmapped step under pmap.
        pad = (-n) % ndev
        stacked_params, grad_mask = (
            jax.tree.map(lambda x: jnp.concatenate(
                [x, jnp.repeat(x[:1], pad, axis=0)]) if pad else x, t)
            for t in (stacked_params, grad_mask))
        reshape = lambda t: jax.tree.map(
            lambda x: x.reshape((ndev, -1) + x.shape[1:]), t)
        unshape = lambda t: jax.tree.map(
            lambda x: x.reshape((-1,) + x.shape[2:])[:n], t)
        stacked_params = reshape(stacked_params)
        grad_mask = reshape(grad_mask)
        step_fn = jax.pmap(jax.vmap(step, in_axes=axes), in_axes=axes)
    else:
        step_fn = jax.jit(jax.vmap(step, in_axes=axes))

    m = jax.tree.map(jnp.zeros_like, stacked_params)
    v = jax.tree.map(jnp.zeros_like, stacked_params)
    for s in range(spec.steps):
        batch = spec.train_batches[s % len(spec.train_batches)]
        t = s + 1
        lr_t = spec.lr * math.sqrt(1 - b2 ** t) / (1 - b1 ** t)
        stacked_params, m, v = step_fn(stacked_params, m, v, grad_mask,
                                       batch, lr_t)
    return unshape(stacked_params) if shard else stacked_params


def perf_to_importance(perf: float, base_perf: float,
                       spec: ImportanceSpec) -> float:
    """Eq. 4 scoring shared by the scalar and batched probe paths."""
    delta = perf - base_perf
    if spec.normalize_by_base and base_perf != 0:
        delta = delta / abs(base_perf)
    # clamp for numerical sanity (perf deltas are small by construction)
    return float(jnp.exp(jnp.clip(delta, -30.0, 30.0)))


def measure_importance(apply_fn, params, spec: ImportanceSpec,
                       base_perf: float) -> float:
    """One table entry: fine-tune the replaced net, return exp(ΔPerf)."""
    tuned = _adam_finetune(apply_fn, params, spec)
    perf = spec.perf_fn(apply_fn, tuned, spec.eval_batches)
    return perf_to_importance(perf, base_perf, spec)


def magnitude_importance(value_kept: float, value_total: float,
                         num_pruned: int, temperature: float = 1.0) -> float:
    """Cheap deterministic proxy (beyond-paper, for fast sweeps): exp of the
    negative pruned-ℓ1 fraction.  Clearly flagged — the paper's Eq. 4 path is
    the default everywhere correctness matters."""
    if value_total <= 0:
        return 1.0
    drop = (value_total - value_kept) / value_total
    return math.exp(-temperature * drop)


# -- ready-made loss/perf functions -----------------------------------------

def xent_loss(apply_fn, params, batch):
    x, y = batch
    logits = apply_fn(params, x)
    logp = jax.nn.log_softmax(logits)
    return -jnp.mean(jnp.take_along_axis(logp, y[:, None], axis=1))


def accuracy_perf(apply_fn, params, batches):
    step = _cached(
        apply_fn, ("acc",),
        lambda get: (None, jax.jit(lambda p, x, y: jnp.sum(
            jnp.argmax(get()(p, x), axis=-1) == y))))
    correct = total = 0
    for x, y in batches:
        correct += float(step(params, x, y))
        total += y.shape[0]
    return correct / max(total, 1)


def neg_loss_perf(loss_fn):
    def perf(apply_fn, params, batches):
        step = _cached(
            apply_fn, ("negloss", id(loss_fn)),
            lambda get: (loss_fn,
                         jax.jit(lambda p, b: loss_fn(get(), p, b))))
        tot = 0.0
        for b in batches:
            tot += float(step(params, b))
        return -tot / max(len(batches), 1)
    return perf


def distill_loss(teacher_fn):
    """Self-distillation: match the pre-trained network's outputs (data-free)."""
    def loss(apply_fn, params, batch):
        x = batch[0] if isinstance(batch, tuple) else batch
        target = teacher_fn(x)
        out = apply_fn(params, x)
        return jnp.mean((out - target) ** 2)
    return loss
