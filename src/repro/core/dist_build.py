"""Fault-tolerant distributed table construction (lease-based fan-out).

Table construction is the paper's wall-clock bottleneck and is
embarrassingly parallel (§3.2): every latency bucket is independent.
This module shards the bucket list of a single build across worker
processes and merges their results into tables **bit-identical** to a
single-process build, no matter which workers died when.

Architecture — files, not RPC
-----------------------------
Coordination happens entirely through a shared ``work_dir`` (POSIX
atomic-rename + ``O_EXCL`` primitives), so the same code runs under CI
subprocesses and a multi-host fleet with a shared filesystem:

* ``manifest.json`` — the ordered work-item list (one key per latency
  bucket), written once, atomically, by the coordinator.  An item's id
  is its manifest index; ids name lease and done files.
* ``leases/<id>.json`` — claim = ``O_CREAT|O_EXCL`` create (atomic);
  the lease carries an expiry ``lease_s`` out, renewed only between
  probe attempts — the lease IS the heartbeat deadline.  Stealing an
  expired lease is a tmp-write + ``os.replace`` + read-back
  verification; the loser of a steal race sees the winner's identity on
  read-back and walks away.
* ``shards/<worker>.jsonl`` — each worker's results, fsync'd line
  appends in the exact ``BuildJournal`` record format
  (``{"k","v","p"}``), plus ``{"evt": "steal", ...}`` audit records.
* ``done/<id>`` — completion markers (result durably in a shard).

Execution is **at-least-once** (a straggler may finish an item that was
already stolen and re-done); attribution is **exactly-once**: the merge
reads shards in a fixed order (w0, w1, …, coordinator) and keeps the
first record per key, so the merged record set is a deterministic
function of the shard contents — and under the analytic oracle every
duplicate carries the identical value anyway.  The merged records land
in the coordinator's real :class:`~repro.core.table_cache.BuildJournal`
and the build finishes through ``build_tables(resume=True)``, so
bit-identity with a single-process build follows from the journal-resume
contract already certified in :mod:`repro.core.table_cache`.

Liveness: after every worker has exited (or the deadline passed), the
coordinator executes any unfinished items inline — ignoring leases,
since their holders are dead — and re-executes items whose done marker
exists but whose shard record was lost or corrupted (``repaired``).  A
build therefore completes even if every worker dies instantly.

Fault points (:mod:`repro.testing.faults`): ``dist.claim`` (after a
successful claim), ``dist.item`` (after claim, before execution — a kill
here leaves a lease held with no result: the canonical mid-bucket
death), ``dist.done`` (after the done marker), and
``dist.shard.append`` / ``dist.shard.append.done`` inside every shard
write (``corrupt-shard`` garbles here).  Worker-targeted process actions
(``kill-worker:<idx>@point``) are translated into each worker's
``REPRO_FAULTS`` environment by :func:`repro.testing.faults.worker_env_spec`.
"""
from __future__ import annotations

import dataclasses
import json
import os
import shutil
import subprocess
import sys
import time

from repro.testing import faults

from . import probe_engine, table_cache
from .latency import AnalyticTPUOracle
from .tables import build_tables, enumerate_probes

#: Module spawned as ``python -m`` for subprocess workers (the launch
#: layer owns the CLI; referenced here as data only).
WORKER_MODULE = "repro.launch.distributed"


class DistBuildError(RuntimeError):
    """A distributed build could not proceed (bad specs, drift, deadline)."""


@dataclasses.dataclass(frozen=True)
class WorkItem:
    """One distributable unit: a journal key plus its representative
    segment (first-in-enumeration-order for the bucket)."""

    key: str
    seg: object


def latency_work_items(host, method: str = "layermerge",
                       engine: str = "batched") -> list[WorkItem]:
    """The build's latency work-item list, in deterministic order.

    Derived from the SAME enumeration ``build_tables`` uses
    (:func:`repro.core.tables.enumerate_probes`) and keyed exactly as the
    build journal keys its records — ``latb:<sig>`` per shape bucket
    (batched) or ``lat:<i>:<j>:<k>`` per entry (sequential) — so a merged
    shard record is indistinguishable from one the coordinator journaled
    itself.
    """
    probes = enumerate_probes(host, method)
    items: list[WorkItem] = []
    seen: set = set()
    for p in probes:
        seg = p[5]
        if engine == "sequential":
            key = f"lat:{seg.i}:{seg.j}:{seg.k}"
        else:
            key = f"latb:{probe_engine._signature(host, seg)!r}"
        if key not in seen:
            seen.add(key)
            items.append(WorkItem(key, seg))
    return items


# ---------------------------------------------------------------------------
# Cross-process specs (hosts/oracles close over live arrays — they are
# re-created in each worker from a JSON description)
# ---------------------------------------------------------------------------

def resolve_host_spec(spec: dict):
    """``{"factory": "module:function", "kwargs": {...}}`` → (host, params).

    Factories must be seed-deterministic (see :mod:`repro.testing.hosts`);
    the worker cross-checks the rebuilt host's fingerprint against the
    coordinator's manifest, so silent drift fails loudly instead of
    merging garbage.
    """
    factory = str(spec.get("factory", ""))
    mod_name, sep, fn_name = factory.partition(":")
    if not sep or not fn_name:
        raise DistBuildError(
            f'host spec factory must be "module:function", got {factory!r}')
    import importlib

    try:
        fn = getattr(importlib.import_module(mod_name), fn_name)
    except (ImportError, AttributeError) as e:
        raise DistBuildError(f"cannot resolve host factory {factory!r}: {e}")
    return fn(**spec.get("kwargs", {}))


def oracle_spec(oracle) -> dict:
    cfg = dataclasses.asdict(oracle) if dataclasses.is_dataclass(oracle) \
        else {}
    return {"cls": type(oracle).__name__, "cfg": cfg}


def resolve_oracle_spec(spec: dict | None):
    from . import latency

    spec = spec or {"cls": "AnalyticTPUOracle"}
    cls = getattr(latency, str(spec.get("cls", "")), None)
    if not (isinstance(cls, type) and issubclass(cls, latency.LatencyOracle)):
        raise DistBuildError(f"unknown oracle class {spec.get('cls')!r}")
    return cls(**spec.get("cfg", {}))


def probe_spec(cfg) -> dict | None:
    """ProbeConfig → JSON-able dict.  ``fallback_oracle`` does not ship
    (workers journal ``None`` for quarantined buckets; the coordinator's
    resume re-derives the fallback estimate, so the policy object only
    ever matters on the coordinator)."""
    if cfg is None:
        return None
    d = dataclasses.asdict(cfg)
    d.pop("fallback_oracle", None)
    return d


def resolve_probe_spec(spec: dict | None):
    if not spec:
        return None
    return probe_engine.ProbeConfig(**spec)


# ---------------------------------------------------------------------------
# Work-dir primitives: manifest, leases, shards
# ---------------------------------------------------------------------------

def _manifest_path(work_dir: str) -> str:
    return os.path.join(work_dir, "manifest.json")


def read_manifest(work_dir: str) -> dict | None:
    try:
        with open(_manifest_path(work_dir)) as f:
            return json.load(f)
    except FileNotFoundError:
        return None
    except (OSError, json.JSONDecodeError, ValueError) as e:
        raise DistBuildError(f"corrupt manifest in {work_dir!r}: {e}")


def write_manifest(work_dir: str, cache_key: str, items, *,
                   engine: str, method: str,
                   host_fp: str | None = None) -> dict:
    """Publish the ordered work list once, atomically; idempotent for the
    same build, loud for a different one (a stale work dir must not
    silently mix two builds' shards)."""
    payload = {"cache_key": cache_key, "engine": engine, "method": method,
               "host_fp": host_fp, "items": [it.key for it in items]}
    existing = read_manifest(work_dir)
    if existing is not None:
        if existing != payload:
            raise DistBuildError(
                f"work dir {work_dir!r} already holds a manifest for a "
                "different build — use a fresh work dir")
        return existing
    from repro.checkpoint.ckpt import atomic_write_text

    atomic_write_text(_manifest_path(work_dir), json.dumps(payload))
    return payload


def _await_manifest(work_dir: str, wait_s: float = 15.0,
                    poll_s: float = 0.1) -> dict:
    deadline = time.monotonic() + wait_s
    while True:
        m = read_manifest(work_dir)
        if m is not None:
            return m
        if time.monotonic() > deadline:
            raise DistBuildError(f"no manifest appeared in {work_dir!r}")
        time.sleep(poll_s)


class LeaseStore:
    """File-based work-item leases with expiry-driven reassignment.

    A lease is a JSON file ``{"owner", "expires", "epoch"}``.  Claiming a
    free item is atomic (``O_CREAT|O_EXCL``); stealing an expired lease
    bumps the epoch through a tmp-write + ``os.replace`` and then
    re-reads the file — if the read-back shows a different owner/epoch,
    another stealer won the race and this one walks away.  Leases are an
    ordering *optimization*: correctness never depends on mutual
    exclusion (duplicate execution is merged deterministically), so the
    unavoidable read-then-replace window is harmless.
    """

    def __init__(self, work_dir: str, owner: str, lease_s: float):
        self.lease_dir = os.path.join(work_dir, "leases")
        self.done_dir = os.path.join(work_dir, "done")
        os.makedirs(self.lease_dir, exist_ok=True)
        os.makedirs(self.done_dir, exist_ok=True)
        self.owner = owner
        self.lease_s = float(lease_s)

    def _lease(self, item_id: int) -> str:
        return os.path.join(self.lease_dir, f"{item_id}.json")

    @staticmethod
    def _read(path: str) -> dict | None:
        try:
            with open(path) as f:
                rec = json.load(f)
            return rec if isinstance(rec, dict) else None
        except (OSError, json.JSONDecodeError, ValueError):
            return None

    def holder(self, item_id: int) -> str | None:
        rec = self._read(self._lease(item_id))
        return rec.get("owner") if rec else None

    def claim(self, item_id: int) -> tuple[bool, str | None]:
        """Try to lease ``item_id``; returns ``(claimed, stolen_from)``.

        ``stolen_from`` names the previous holder when the claim
        reassigned an expired (or unreadable) lease — the caller records
        that as a ``steal`` event.
        """
        path = self._lease(item_id)
        rec = {"owner": self.owner,
               "expires": time.time() + self.lease_s, "epoch": 1}
        try:
            fd = os.open(path, os.O_CREAT | os.O_EXCL | os.O_WRONLY)
        except FileExistsError:
            cur = self._read(path)
            if cur is not None and cur.get("owner") == self.owner:
                self.renew(item_id)          # our own lease: just extend
                faults.hit("dist.claim")
                return True, None
            if cur is not None and \
                    float(cur.get("expires", 0.0)) > time.time():
                return False, None           # live lease held elsewhere
            rec["epoch"] = (int(cur.get("epoch", 0)) + 1) if cur else 1
            tmp = f"{path}.{self.owner}.tmp"
            try:
                with open(tmp, "w") as f:
                    json.dump(rec, f)
                os.replace(tmp, path)
            except OSError:
                return False, None
            back = self._read(path)
            if not back or back.get("owner") != self.owner \
                    or back.get("epoch") != rec["epoch"]:
                return False, None           # lost the steal race
            faults.hit("dist.claim")
            return True, (cur.get("owner", "?") if cur else "?")
        with os.fdopen(fd, "w") as f:
            json.dump(rec, f)
        faults.hit("dist.claim")
        return True, None

    def renew(self, item_id: int) -> bool:
        """Extend our own lease (between probe attempts — the heartbeat).
        False when the lease was stolen from us meanwhile."""
        path = self._lease(item_id)
        cur = self._read(path)
        if cur is None or cur.get("owner") != self.owner:
            return False
        cur["expires"] = time.time() + self.lease_s
        tmp = f"{path}.{self.owner}.tmp"
        try:
            with open(tmp, "w") as f:
                json.dump(cur, f)
            os.replace(tmp, path)
        except OSError:
            return False
        return True

    def release(self, item_id: int) -> None:
        cur = self._read(self._lease(item_id))
        if cur is not None and cur.get("owner") != self.owner:
            return                           # not ours to release
        try:
            os.remove(self._lease(item_id))
        except OSError:
            pass

    def mark_done(self, item_id: int) -> None:
        try:
            with open(os.path.join(self.done_dir, str(item_id)), "w") as f:
                f.write(self.owner)
        except OSError:
            pass

    def is_done(self, item_id: int) -> bool:
        return os.path.exists(os.path.join(self.done_dir, str(item_id)))

    def count_done(self, n: int) -> int:
        return sum(1 for i in range(n) if self.is_done(i))


def shard_path(work_dir: str, name: str) -> str:
    return os.path.join(work_dir, "shards", f"{name}.jsonl")


class ShardJournal:
    """One worker's fsync'd result shard (append-only JSONL).

    Result records use the exact :class:`~repro.core.table_cache.BuildJournal`
    format ``{"k","v","p"}`` so the merge drops them straight into the
    coordinator's journal; ``{"evt": ...}`` records share the file as the
    steal/repair audit trail.  Appends go through
    :func:`repro.checkpoint.ckpt.append_journal_line` at fault point
    ``dist.shard.append`` (where ``corrupt-shard`` garbles).
    """

    def __init__(self, work_dir: str, name: str):
        self.name = name
        self.path = shard_path(work_dir, name)
        self._keys: set[str] = set()

    def put(self, key: str, value, provenance: str = "measured") -> None:
        from repro.checkpoint.ckpt import append_journal_line

        append_journal_line(self.path, json.dumps(
            {"k": key, "v": value, "p": provenance}),
            point="dist.shard.append")
        self._keys.add(key)

    def has(self, key: str) -> bool:
        return key in self._keys

    def event(self, kind: str, **fields) -> None:
        from repro.checkpoint.ckpt import append_journal_line

        append_journal_line(self.path, json.dumps({"evt": kind, **fields}),
                            point="dist.shard.append")


def merge_shards(work_dir: str, names) -> tuple[dict, list, int]:
    """Deterministic first-wins merge of shards in the given order.

    Returns ``(records, events, corrupt)`` where ``records`` maps
    journal key → ``(value, provenance, shard_name)``; the first record
    for a key — in shard order, then file order — wins, so the merge is
    a pure function of the shard set (duplicate executions from lease
    steals collapse identically on every rerun).  Unparsable lines
    (torn by a kill, garbled by ``corrupt-shard``) are counted, not
    trusted — the coordinator re-executes whatever they were.
    """
    from repro.checkpoint.ckpt import read_journal_lines

    records: dict[str, tuple] = {}
    events: list[dict] = []
    corrupt = 0
    for name in names:
        for line in read_journal_lines(shard_path(work_dir, name)):
            try:
                rec = json.loads(line)
            except (json.JSONDecodeError, ValueError):
                corrupt += 1
                continue
            if not isinstance(rec, dict):
                corrupt += 1
                continue
            if "evt" in rec:
                events.append(dict(rec, shard=name))
                continue
            if "k" not in rec or "v" not in rec:
                corrupt += 1
                continue
            records.setdefault(
                rec["k"], (rec["v"], rec.get("p", "measured"), name))
    return records, events, corrupt


# ---------------------------------------------------------------------------
# Worker loop
# ---------------------------------------------------------------------------

def run_worker(work_dir: str, worker_id: int, host, params, oracle, *,
               engine: str = "batched", method: str = "layermerge",
               probe_config=None, lease_s: float = 30.0,
               poll_s: float = 0.2, deadline_s: float = 600.0) -> int:
    """Claim-execute-journal until every manifest item is done.

    The worker re-derives the work list from its own rebuilt host and
    cross-checks the manifest (unknown item keys or a fingerprint
    mismatch mean host-spec drift → :class:`DistBuildError`, exit 3 at
    the CLI).  Traversal starts at a per-worker rotation of the manifest
    so concurrent workers mostly claim disjoint items; expired leases
    encountered on later sweeps are stolen and the steal journaled.
    Returns the number of items this worker completed.
    """
    manifest = _await_manifest(work_dir)
    items = latency_work_items(host, method=method, engine=engine)
    by_key = {it.key: it for it in items}
    unknown = [k for k in manifest["items"] if k not in by_key]
    if unknown:
        raise DistBuildError(
            f"worker host does not produce {len(unknown)} manifest "
            f"item(s) (first: {unknown[0]!r}) — host spec drift?")
    fp_fn = getattr(host, "fingerprint", None)
    if fp_fn is not None and manifest.get("host_fp") \
            and fp_fn() != manifest["host_fp"]:
        raise DistBuildError(
            "worker host fingerprint differs from the coordinator's — "
            "host spec drift?")

    n = len(manifest["items"])
    nw = max(1, int(os.environ.get("REPRO_NUM_PROCESSES", "2")) - 1)
    start = (worker_id * n) // nw if n else 0
    order = list(range(start, n)) + list(range(start))

    cfg = probe_config or probe_engine.ProbeConfig()
    stats = probe_engine.EngineStats(engine=engine)
    shard = ShardJournal(work_dir, f"w{worker_id}")
    store = LeaseStore(work_dir, f"w{worker_id}", lease_s)
    completed = 0
    deadline = time.monotonic() + deadline_s
    while True:
        progressed = False
        remaining = [i for i in order if not store.is_done(i)]
        if not remaining:
            return completed
        for i in remaining:
            if store.is_done(i):
                continue
            got, stolen_from = store.claim(i)
            if not got:
                continue
            if store.is_done(i):             # raced with the finisher
                store.release(i)
                continue
            key = manifest["items"][i]
            if stolen_from is not None:
                shard.event("steal", item=key, id=i, prev=stolen_from)
            # A kill here dies holding the lease with no result — the
            # canonical mid-bucket worker death the protocol must absorb.
            faults.hit("dist.item")
            val, flag = probe_engine.probe_segment(
                host, by_key[key].seg, params, oracle,
                probe_config=cfg, stats=stats)
            store.renew(i)
            shard.put(key, None if val is None else float(val), flag)
            store.mark_done(i)
            faults.hit("dist.done")
            store.release(i)
            completed += 1
            progressed = True
        if not progressed:
            if time.monotonic() > deadline:
                raise DistBuildError(
                    "worker deadline exceeded with items still leased "
                    "elsewhere")
            time.sleep(poll_s)


# ---------------------------------------------------------------------------
# Coordinator
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class DistReport:
    """What the fan-out did — who completed what, who died, what was
    reassigned or repaired.  ``dead_workers`` includes stragglers killed
    at shutdown after the build completed without them."""

    workers: int = 0
    items: int = 0                     # total work items this build
    journal_prefilled: int = 0         # resumed from the build journal
    completed_by: dict = dataclasses.field(default_factory=dict)
    reassigned: list = dataclasses.field(default_factory=list)
    repaired: list = dataclasses.field(default_factory=list)
    dead_workers: list = dataclasses.field(default_factory=list)
    corrupt_records: int = 0
    coordinator_items: int = 0         # inline fallback executions
    cache_hit: bool = False
    wall_s: float = 0.0

    def as_dict(self) -> dict:
        return dataclasses.asdict(self)


def worker_log_path(work_dir: str, w: int) -> str:
    """Where worker ``w``'s combined stdout/stderr lands — the first
    place to look when a worker shows up in ``DistReport.dead_workers``."""
    return os.path.join(work_dir, "logs", f"w{w}.log")


def _spawn_worker(work_dir: str, w: int, workers: int, host_spec: dict,
                  oracle, probe_config, *, engine: str, method: str,
                  lease_s: float, deadline_s: float,
                  devices: int | None, platform: str):
    from repro.testing.subproc import REPO_ROOT, subprocess_env

    env = subprocess_env(devices=devices, platform=platform,
                         process_id=w + 1, num_processes=workers + 1,
                         faults_spec=faults.worker_env_spec(w))
    argv = [sys.executable, "-m", WORKER_MODULE, "--worker",
            "--dir", work_dir, "--worker-id", str(w),
            "--host-spec", json.dumps(host_spec),
            "--oracle-spec", json.dumps(oracle_spec(oracle)),
            "--engine", engine, "--method", method,
            "--lease-s", str(lease_s), "--deadline-s", str(deadline_s)]
    ps = probe_spec(probe_config)
    if ps:
        argv += ["--probe-spec", json.dumps(ps)]
    log_path = worker_log_path(work_dir, w)
    os.makedirs(os.path.dirname(log_path), exist_ok=True)
    log = open(log_path, "w")
    proc = subprocess.Popen(argv, env=env, cwd=REPO_ROOT, stdout=log,
                            stderr=subprocess.STDOUT, text=True)
    proc._log_file = log
    return proc


def _reap(proc, grace_s: float) -> int:
    try:
        proc.communicate(timeout=grace_s)
    except subprocess.TimeoutExpired:
        proc.kill()
        proc.communicate()
    log = getattr(proc, "_log_file", None)
    if log is not None:
        log.close()
    return proc.returncode


def dist_build_tables(host, *, cache_dir: str, workers: int = 2,
                      host_spec: dict | None = None,
                      method: str = "layermerge", latency_oracle=None,
                      importance="magnitude", base_perf=None, params=None,
                      prune: bool = True, engine: str = "batched",
                      probe_config=None, resume: bool = True,
                      progress=None, work_dir: str | None = None,
                      lease_s: float = 30.0, poll_s: float = 0.2,
                      deadline_s: float = 600.0,
                      serial_spawn: bool = False,
                      worker_devices: int | None = None,
                      worker_platform: str = "cpu",
                      keep_work_dir: bool = False):
    """Build tables with the latency fan-out sharded across ``workers``
    subprocesses; returns ``(Tables, DistReport)``.

    The flow: enumerate work items → skip ones already in the build
    journal (resume) → publish the manifest → spawn workers (each with a
    non-zero process index, so :func:`repro.launch.distributed.is_main`
    gates them out of every publish) → wait for done markers or worker
    exits → execute leftovers inline → merge shards deterministically →
    append the merged records to the real build journal in ONE fsync →
    finish through ``build_tables(resume=True)``, whose journal-replay
    contract makes the result bit-identical to a single-process build.

    Requires a content-addressable build (``host.fingerprint`` + a
    nameable importance): the merge lands under the build's cache key.
    Measured-importance probes (unserializable closures) always run
    coordinator-side inside the final ``build_tables`` — only the
    latency column fans out.  ``workers=0`` degenerates to the local
    build.  ``serial_spawn`` starts worker ``w+1`` only after worker
    ``w`` exited — used by the fault smokes to make kill/steal timing
    deterministic.
    """
    oracle = latency_oracle or AnalyticTPUOracle()
    key = table_cache.cache_key(host, oracle, method, importance,
                                prune=prune, base_perf=base_perf,
                                engine=engine)
    if key is None:
        raise DistBuildError(
            "distributed builds require a content-addressable cache key "
            "(host.fingerprint + nameable importance): worker results "
            "merge through the build journal under that key")
    report = DistReport(workers=workers)
    t0 = time.perf_counter()

    cached = table_cache.load(cache_dir, key)
    if cached is not None:
        table_cache.discard_journal(cache_dir, key)
        report.cache_hit = True
        report.wall_s = time.perf_counter() - t0
        return cached, report
    if not resume:
        table_cache.discard_journal(cache_dir, key)
    journal = table_cache.BuildJournal(cache_dir, key)

    items = latency_work_items(host, method=method, engine=engine)
    report.items = len(items)
    todo = [it for it in items if journal.get(it.key) is None]
    report.journal_prefilled = len(items) - len(todo)

    if todo and workers > 0:
        # Absolute: workers run with cwd=REPO_ROOT, so a relative
        # coordinator path (e.g. CLI --cache-dir cache) would resolve to
        # a DIFFERENT directory there and every worker would die waiting
        # for a manifest.
        wd = os.path.abspath(work_dir
                             or os.path.join(cache_dir, f"dist_{key[:16]}"))
        os.makedirs(wd, exist_ok=True)
        fp_fn = getattr(host, "fingerprint", None)
        manifest = write_manifest(wd, key, todo, engine=engine,
                                  method=method,
                                  host_fp=fp_fn() if fp_fn else None)
        if host_spec is None:
            raise DistBuildError(
                'spawning workers requires host_spec ({"factory": '
                '"module:function", "kwargs": {...}})')
        n = len(manifest["items"])
        store = LeaseStore(wd, "coord", lease_s)
        spawn = lambda w: _spawn_worker(
            wd, w, workers, host_spec, oracle, probe_config,
            engine=engine, method=method, lease_s=lease_s,
            deadline_s=deadline_s, devices=worker_devices,
            platform=worker_platform)
        rcs: dict[int, int] = {}
        deadline = time.monotonic() + deadline_s
        if serial_spawn:
            for w in range(workers):
                if store.count_done(n) == n:
                    break
                rcs[w] = _reap(spawn(w), deadline_s)
        else:
            procs = {w: spawn(w) for w in range(workers)}
            while store.count_done(n) < n:
                if all(p.poll() is not None for p in procs.values()):
                    break
                if time.monotonic() > deadline:
                    for p in procs.values():
                        if p.poll() is None:
                            p.kill()
                    break
                time.sleep(poll_s)
            for w, p in procs.items():
                rcs[w] = _reap(p, grace_s=5.0)
        report.dead_workers = sorted(w for w, rc in rcs.items() if rc != 0)
        if progress:
            progress(f"dist: {store.count_done(n)}/{n} items done by "
                     f"{workers} worker(s); dead={report.dead_workers}")

        # Inline fallback: every worker has exited, so any surviving
        # lease belongs to a dead worker — execute regardless of it.
        cfg = probe_config or probe_engine.ProbeConfig()
        stats = probe_engine.EngineStats(engine=engine)
        coord = ShardJournal(wd, "coord")
        by_key = {it.key: it for it in todo}
        for i, k in enumerate(manifest["items"]):
            if store.is_done(i):
                continue
            holder = store.holder(i)
            if holder and holder != "coord":
                coord.event("steal", item=k, id=i, prev=holder)
            faults.hit("dist.item")
            val, flag = probe_engine.probe_segment(
                host, by_key[k].seg, params, oracle,
                probe_config=cfg, stats=stats)
            coord.put(k, None if val is None else float(val), flag)
            store.mark_done(i)
            report.coordinator_items += 1

        names = [f"w{w}" for w in range(workers)] + ["coord"]
        records, events, corrupt = merge_shards(wd, names)
        report.corrupt_records = corrupt
        # Repair: done-marked items whose shard record was lost or
        # garbled re-execute here — a done marker is a claim, the shard
        # record is the evidence.
        for k in manifest["items"]:
            if k in records:
                continue
            val, flag = probe_engine.probe_segment(
                host, by_key[k].seg, params, oracle,
                probe_config=cfg, stats=stats)
            v = None if val is None else float(val)
            coord.put(k, v, flag)
            records[k] = (v, flag, "coord")
            report.repaired.append(k)
        report.reassigned = sorted(
            {e["item"] for e in events if e.get("evt") == "steal"})
        wins: dict[str, int] = {}
        for _k, (_v, _p, shard_name) in records.items():
            wins[shard_name] = wins.get(shard_name, 0) + 1
        report.completed_by = wins
        journal.put_many(
            [(k,) + records[k][:2] for k in manifest["items"]])
    else:
        wd = None

    tables = build_tables(host, method=method, latency_oracle=oracle,
                          importance=importance, base_perf=base_perf,
                          params=params, progress=progress, prune=prune,
                          engine=engine, cache_dir=cache_dir,
                          probe_config=probe_config, resume=True)
    if wd is not None and not keep_work_dir:
        shutil.rmtree(wd, ignore_errors=True)
    report.wall_s = time.perf_counter() - t0
    return tables, report
