"""Production mesh construction.

``make_production_mesh`` is a FUNCTION (not a module-level constant) so that
importing this module never touches jax device state — the dry-run sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before any jax
import, and smoke tests/benches must keep seeing 1 device.

Topology (TPU v5e): one pod = 16×16 = 256 chips → axes ('data', 'model');
two pods = 512 chips → axes ('pod', 'data', 'model').  The 'pod' axis is
DCN-connected (slower links); by default it carries data parallelism (the
gradient all-reduce tolerates DCN latency); the launcher can instead run
pipeline stages over it (train/pipeline.py).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(*, model: int = 1):
    """Whatever devices exist on this host, as a ('data','model') mesh.

    ``model`` splits off a tensor-parallel axis (must divide the device
    count); the default keeps everything data-parallel — used by CPU
    examples, forced-host-device tests, and the sharded serve smoke.
    """
    n = len(jax.devices())
    if model < 1 or n % model != 0:
        raise ValueError(f"model={model} does not divide {n} devices")
    return jax.make_mesh((n // model, model), ("data", "model"))


def mesh_info(mesh) -> dict:
    return {"shape": dict(mesh.shape),
            "devices": int(mesh.devices.size),
            "axis_names": list(mesh.axis_names)}
