"""Lookup-table construction benchmark — the probe engine's scoreboard.

Measures batched (shape-bucketed, compile-overlapped, vmapped-importance)
table construction against the sequential entry-at-a-time reference on a
deep uniform conv chain — the shape-dedup regime the engine targets — and
writes ``results/BENCH_tables.json`` with build time, #compiles, #timings,
cache hit rate, batched-vs-sequential parity deltas, and the journaled
kill-and-resume overhead so the perf trajectory is trackable across PRs.

  PYTHONPATH=src python -m benchmarks.bench_tables [--smoke] [--out PATH]

``--smoke`` runs the correctness/accounting assertions on a tiny instance
in seconds (wired into ``make verify`` via scripts/verify.sh) without the
slow sequential wall-clock baseline; the full run also measures the
wall-clock speedup headline.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import tempfile
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), os.pardir, "src"))

import jax                                              # noqa: E402
import jax.numpy as jnp                                 # noqa: E402

from repro.core import (AnalyticTPUOracle, ImportanceSpec,     # noqa: E402
                        WallClockOracle, accuracy_perf, build_tables,
                        solve_dp, xent_loss)
from repro.models import cnn, cnn_host                  # noqa: E402
from repro.models.cnn import ConvNet, ConvSpec          # noqa: E402


def probe_chain(L: int, width: int = 16, in_hw: int = 16,
                k: int = 3) -> ConvNet:
    """Uniform stride-1 conv chain: maximal shape dedup, no barriers."""
    specs = [ConvSpec(3, width, k, 1, act="relu")]
    specs += [ConvSpec(width, width, k, 1, act="relu")
              for _ in range(L - 1)]
    return ConvNet(tuple(specs), (), in_hw=in_hw, in_ch=3,
                   head="classifier", num_classes=4)


def make_host(L: int, max_span: int, width: int = 16, in_hw: int = 16):
    net = probe_chain(L, width=width, in_hw=in_hw)
    params = cnn.init_params(net, jax.random.PRNGKey(0))
    return cnn_host.CNNHost(net, params, batch=4, max_span=max_span), params


def build(host, params, oracle, engine, **kw):
    t0 = time.perf_counter()
    tables = build_tables(host, latency_oracle=oracle, params=params,
                          engine=engine, **kw)
    return time.perf_counter() - t0, tables


def bench_analytic_parity(host, params) -> dict:
    """Batched must be BIT-identical to sequential under the analytic
    oracle — entries, Pareto drops, and the resulting DP plan."""
    oracle = AnalyticTPUOracle()
    tb_s, seq = build(host, params, oracle, "sequential")
    tb_b, bat = build(host, params, oracle, "batched")
    assert bat.entries == seq.entries, "analytic entries diverged"
    assert bat.num_pruned == seq.num_pruned
    L = len(host.descs())
    budget = 0.7 * sum(
        seq.entries[(l - 1, l)][host.original_k(l)][1]
        for l in range(1, L + 1))
    rs = solve_dp(L, seq.fn(), budget, 200, original_k=host.original_k)
    rb = solve_dp(L, bat.fn(), budget, 200, original_k=host.original_k)
    plans_identical = (rs is None and rb is None) or \
        (rs is not None and rb is not None and rs.plan == rb.plan)
    assert plans_identical, "analytic DP plans diverged"
    return {
        "entries": seq.num_entries,
        "buckets": bat.stats.num_latency_buckets,
        "sequential_s": tb_s,
        "batched_s": tb_b,
        "bit_identical": True,
        "plans_identical": True,
    }


def bench_wallclock(host, params, *, run_sequential: bool,
                    oracle: WallClockOracle | None = None) -> dict:
    # Full runs scale the paper's 300-warmup/200-timed Appendix C protocol
    # down but keep timing (not compilation — JAX dedups identical
    # executables) as the dominant per-entry cost, which is exactly what
    # shape bucketing removes.
    oracle = oracle or WallClockOracle(warmup=5, iters=40, groups=5)
    t_b, bat = build(host, params, oracle, "batched")
    row = {
        "entries": bat.num_entries + bat.num_pruned,
        "buckets": bat.stats.num_latency_buckets,
        "batched_s": t_b,
        "batched_compiles": bat.stats.num_compiles,
        "batched_timings": bat.stats.num_timings,
    }
    assert bat.stats.num_compiles == bat.stats.num_latency_buckets
    assert bat.stats.num_timings == bat.stats.num_latency_buckets
    if run_sequential:
        import statistics

        from repro.core.plan import Segment

        t_s, seq = build(host, params, oracle, "sequential")
        # Parity per BUCKET against the median of that bucket's sequential
        # entries: individual sequential timings of ~100µs probes jitter
        # by integer factors themselves, so entrywise deltas measure timer
        # noise, not attribution errors.
        by_sig: dict = {}
        for sp in seq.entries:
            for k, (_, lat_s, kept) in seq.entries[sp].items():
                if sp not in bat.entries or k not in bat.entries[sp]:
                    continue
                sig = host.probe_signature(
                    Segment(i=sp[0], j=sp[1], k=k, kept=kept))
                by_sig.setdefault(sig, ([], []))
                by_sig[sig][0].append(lat_s)
                by_sig[sig][1].append(bat.entries[sp][k][1])
        deltas = [abs(lb[0] - statistics.median(ls))
                  / max(statistics.median(ls), 1e-12)
                  for ls, lb in by_sig.values()]
        row.update(
            sequential_s=t_s,
            sequential_compiles=seq.stats.num_compiles,
            sequential_timings=seq.stats.num_timings,
            speedup=t_s / max(t_b, 1e-12),
            parity_max_rel_delta=max(deltas) if deltas else 0.0,
            parity_mean_rel_delta=(sum(deltas) / len(deltas)) if deltas
            else 0.0,
        )
    return row


def bench_importance(host, params, *, run_sequential: bool) -> dict:
    """Measured Eq. 4 importance: vmapped span batches vs scalar probes."""
    net = host.net
    key = jax.random.PRNGKey(1)
    x = jax.random.normal(key, (16, net.in_hw, net.in_hw, 3))
    y = jax.random.randint(jax.random.PRNGKey(2), (16,), 0, 4)
    spec = ImportanceSpec(loss_fn=xent_loss, perf_fn=accuracy_perf,
                          train_batches=[(x, y)], eval_batches=[(x, y)],
                          steps=3, lr=1e-3)
    base = accuracy_perf(lambda p, xx: cnn.apply_replaced(net, p, xx),
                         params, [(x, y)])
    oracle = AnalyticTPUOracle()
    t_b, bat = build(host, params, oracle, "batched", importance=spec,
                     base_perf=base)
    row = {
        "probes": bat.stats.num_importance_probes,
        "vmapped_batches": bat.stats.num_importance_batches,
        "sequential_fallbacks": bat.stats.num_importance_sequential,
        "batched_s": t_b,
    }
    if run_sequential:
        t_s, seq = build(host, params, oracle, "sequential",
                         importance=spec, base_perf=base)
        deltas = [abs(bat.entries[sp][k][0] - seq.entries[sp][k][0])
                  for sp in seq.entries for k in seq.entries[sp]
                  if sp in bat.entries and k in bat.entries[sp]]
        row.update(sequential_s=t_s, speedup=t_s / max(t_b, 1e-12),
                   parity_max_abs_delta=max(deltas) if deltas else 0.0)
    return row


def bench_cache(host, params) -> dict:
    oracle = AnalyticTPUOracle()
    with tempfile.TemporaryDirectory() as d:
        t_cold, cold = build(host, params, oracle, "batched", cache_dir=d)
        t_warm, warm = build(host, params, oracle, "batched", cache_dir=d)
        assert not cold.stats.cache_hit and warm.stats.cache_hit
        assert warm.entries == cold.entries, "cache round-trip diverged"
        return {"cold_s": t_cold, "warm_s": t_warm,
                "hit_rate": 0.5,         # 1 hit / 2 builds in this probe
                "warm_speedup": t_cold / max(t_warm, 1e-12)}


def bench_dist(*, workers: int, smoke: bool) -> dict:
    """Multi-process fan-out scaling + lease-reassignment overhead.

    Two subprocess builds on the conv-chain instance: a clean ``workers``-
    way fan-out (vs the in-process batched baseline) and one with worker 0
    SIGKILLed mid-bucket so a survivor must steal the expired lease.  Both
    merged tables must stay bit-identical to the local build — the fan-out
    buys wall-clock only, never numbers."""
    from repro.core.dist_build import dist_build_tables
    from repro.testing import faults
    from repro.testing.hosts import conv_chain_host

    kw = (dict(L=5, max_span=3, width=8, in_hw=8) if smoke
          else dict(L=8, max_span=3, width=16, in_hw=16))
    spec = {"factory": "repro.testing.hosts:conv_chain_host", "kwargs": kw}
    host, params = conv_chain_host(**kw)
    oracle = AnalyticTPUOracle()
    t_local, ref = build(host, params, oracle, "batched")
    with tempfile.TemporaryDirectory() as d:
        t0 = time.perf_counter()
        tables, rep = dist_build_tables(host, params=params, cache_dir=d,
                                        workers=workers, host_spec=spec,
                                        latency_oracle=oracle)
        t_dist = time.perf_counter() - t0
        assert tables.entries == ref.entries, "fan-out diverged from local"
        assert rep.dead_workers == []
    with tempfile.TemporaryDirectory() as d:
        with faults.inject(faults.Fault("dist.item", "kill-worker", nth=2,
                                        widx=0)):
            t0 = time.perf_counter()
            t2, rep2 = dist_build_tables(host, params=params, cache_dir=d,
                                         workers=workers, host_spec=spec,
                                         latency_oracle=oracle, lease_s=0.5,
                                         serial_spawn=True)
            t_fault = time.perf_counter() - t0
        assert t2.entries == ref.entries, "reassigned build diverged"
        assert 0 in rep2.dead_workers
    return {
        "workers": workers,
        "items": rep.items,
        "local_s": t_local,
        "dist_s": t_dist,
        # Subprocess spawn + JAX warm-up dominates on toy instances, so
        # <1 here is expected; the metric exists to track the trajectory
        # as probe cost grows, not to win on a 5-layer chain.
        "fanout_speedup": t_local / max(t_dist, 1e-12),
        "completed_by": rep.completed_by,
        "fault_dist_s": t_fault,
        "reassigned": len(rep2.reassigned),
        "dead_workers": rep2.dead_workers,
        "reassignment_overhead": t_fault / max(t_dist, 1e-12),
        "bit_identical": True,
    }


def bench_resume(host, params, *, kill_at_bucket: int = 4) -> dict:
    """Journaled kill-and-resume: a build killed at the Nth bucket must
    resume BIT-identically, and the resume must not cost a full rebuild
    — journaled buckets replay from the WAL instead of re-probing."""
    from repro.testing import faults

    oracle = AnalyticTPUOracle()
    with tempfile.TemporaryDirectory() as d:
        t_cold, ref = build(host, params, oracle, "batched")
        with faults.inject(faults.Fault("tables.bucket", "kill",
                                        nth=kill_at_bucket)):
            t0 = time.perf_counter()
            try:
                build(host, params, oracle, "batched", cache_dir=d)
                raise AssertionError("injected kill never fired")
            except faults.FaultKill:
                t_interrupted = time.perf_counter() - t0
        t_resume, resumed = build(host, params, oracle, "batched",
                                  cache_dir=d)
        assert resumed.entries == ref.entries, "resume diverged from cold"
        assert resumed.num_pruned == ref.num_pruned
        assert resumed.stats.num_journal_hits >= kill_at_bucket - 1
        return {
            "killed_at_bucket": kill_at_bucket,
            "interrupted_s": t_interrupted,
            "cold_s": t_cold,
            "resume_s": t_resume,
            "resume_overhead": t_resume / max(t_cold, 1e-12),
            "journal_hits_on_resume": resumed.stats.num_journal_hits,
            "bit_identical": True,
        }


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="fast correctness/accounting pass (CI)")
    ap.add_argument("--out", default=os.path.join(
        os.path.dirname(__file__), os.pardir, "results",
        "BENCH_tables.json"))
    ap.add_argument("--workers", type=int, default=2,
                    help="fan-out width for the distributed leg "
                         "(0 skips it)")
    args = ap.parse_args(argv)

    if args.smoke:
        host, params = make_host(L=5, max_span=3, width=8, in_hw=8)
        oracle = WallClockOracle(warmup=1, iters=4, groups=2)
    else:
        host, params = make_host(L=12, max_span=4, width=32, in_hw=32)
        oracle = None
    imp_host, imp_params = (host, params) if args.smoke else \
        make_host(L=6, max_span=3, width=8, in_hw=8)

    report = {
        "instance": {"L": len(host.descs()), "max_span": host.max_span,
                     "smoke": args.smoke},
        "analytic": bench_analytic_parity(host, params),
        "wallclock": bench_wallclock(host, params, oracle=oracle,
                                     run_sequential=not args.smoke),
        "importance": bench_importance(imp_host, imp_params,
                                       run_sequential=not args.smoke),
        "cache": bench_cache(host, params),
        "resume": bench_resume(host, params),
    }
    if args.workers > 0:
        report["dist"] = bench_dist(workers=args.workers, smoke=args.smoke)
    if not args.smoke:
        speedup = report["wallclock"]["speedup"]
        assert speedup >= 5.0, (
            f"wall-clock table build speedup regressed below 5x: {speedup}")
        from repro.launch.distributed import publish_json

        out = os.path.abspath(args.out)
        if publish_json(out, report) is not None:
            print(f"wrote {out}")
    print(json.dumps(report, indent=2))


if __name__ == "__main__":
    main()
