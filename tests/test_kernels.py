"""Pallas kernel validation: every kernel, swept over shapes and dtypes,
against the kernels/ref.py pure-jnp oracle, in interpret mode on CPU.

Property tests (hypothesis) fuzz odd shapes through the kernels/ops.py padding
layer; fixed parametrized sweeps cover the tile-aligned fast paths.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp import given, settings, st

from repro import kernels
from repro.kernels.flash_attention import flash_attention
from repro.kernels.merged_conv import merged_conv
from repro.kernels.merged_ffn import merged_ffn
from repro.kernels.rglru_scan import rglru_scan
from repro.kernels.rmsnorm import rmsnorm

TOL = {jnp.float32: dict(rtol=2e-5, atol=2e-5),
       jnp.bfloat16: dict(rtol=2e-2, atol=2e-2)}


def _rand(key, shape, dtype, scale=1.0):
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)


# ---------------------------------------------------------------------------
# merged_ffn
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("m,d,r,bm,bn,bk,bd", [
    (256, 256, 128, 128, 128, 128, 128),
    (512, 512, 256, 256, 256, 128, 256),
    (128, 512, 512, 128, 256, 256, 512),
])
def test_merged_ffn_kernel(dtype, m, d, r, bm, bn, bk, bd):
    ks = jax.random.split(jax.random.PRNGKey(m + r), 3)
    x = _rand(ks[0], (m, d), dtype, 0.5)
    u = _rand(ks[1], (d, r), dtype, 0.05)
    v = _rand(ks[2], (r, d), dtype, 0.05)
    y = merged_ffn(x, u, v, bm=bm, bn=bn, bk=bk, bd=bd, interpret=True)
    yr = kernels.merged_ffn_ref(x, u, v)
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(yr, np.float32), **TOL[dtype])


@given(m=st.integers(1, 200), d=st.sampled_from([96, 128, 200]),
       r=st.integers(1, 160))
@settings(max_examples=8, deadline=None)
def test_merged_ffn_op_padding(m, d, r):
    """kernels/ops.py pads ragged shapes correctly (property test)."""
    ks = jax.random.split(jax.random.PRNGKey(m * 7 + r), 3)
    x = _rand(ks[0], (m, d), jnp.float32, 0.5)
    u = _rand(ks[1], (d, r), jnp.float32, 0.05)
    v = _rand(ks[2], (r, d), jnp.float32, 0.05)
    y = kernels.merged_ffn_op(x, u, v, interpret=True)
    np.testing.assert_allclose(y, kernels.merged_ffn_ref(x, u, v),
                               rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("causal", [True, False])
@pytest.mark.parametrize("bh,s,d,bq", [(4, 256, 64, 128), (2, 512, 128, 256),
                                       (1, 128, 64, 64)])
def test_flash_attention_kernel(dtype, causal, bh, s, d, bq):
    ks = jax.random.split(jax.random.PRNGKey(s + d), 3)
    q = _rand(ks[0], (bh, s, d), dtype)
    k = _rand(ks[1], (bh, s, d), dtype)
    v = _rand(ks[2], (bh, s, d), dtype)
    o = flash_attention(q, k, v, causal=causal, bq=bq, bk=bq, interpret=True)
    oref = kernels.flash_attention_ref(q[:, :, None], k[:, :, None],
                                   v[:, :, None], causal=causal)[:, :, 0]
    np.testing.assert_allclose(np.asarray(o, np.float32),
                               np.asarray(oref, np.float32), **TOL[dtype])


def test_flash_attention_op_grad():
    """custom_vjp backward matches the pure-jnp gradient."""
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = _rand(ks[0], (1, 64, 2, 32), jnp.float32)
    k = _rand(ks[1], (1, 64, 2, 32), jnp.float32)
    v = _rand(ks[2], (1, 64, 2, 32), jnp.float32)

    def f_op(q, k, v):
        return jnp.sum(kernels.flash_attention_op(q, k, v, True, True) ** 2)

    def f_ref(q, k, v):
        return jnp.sum(kernels.flash_attention_ref(q, k, v, causal=True) ** 2)
    g_op = jax.grad(f_op, argnums=(0, 1, 2))(q, k, v)
    g_ref = jax.grad(f_ref, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(g_op, g_ref):
        np.testing.assert_allclose(a, b, rtol=2e-4, atol=2e-4)


# ---------------------------------------------------------------------------
# rglru scan
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("b,s,c,bc,bt", [(2, 64, 256, 128, 16),
                                         (1, 128, 128, 128, 64),
                                         (3, 32, 512, 256, 32)])
def test_rglru_scan_kernel(b, s, c, bc, bt):
    ks = jax.random.split(jax.random.PRNGKey(b * s), 2)
    a = jax.random.uniform(ks[0], (b, s, c), minval=0.4, maxval=0.999)
    x = jax.random.normal(ks[1], (b, s, c)) * 0.2
    h = rglru_scan(a, x, bc=bc, bt=bt, interpret=True)
    np.testing.assert_allclose(h, kernels.rglru_scan_ref(a, x),
                               rtol=1e-5, atol=1e-5)


@given(s=st.integers(1, 100), c=st.sampled_from([32, 100, 130]))
@settings(max_examples=6, deadline=None)
def test_rglru_op_padding(s, c):
    ks = jax.random.split(jax.random.PRNGKey(s * 3 + c), 2)
    a = jax.random.uniform(ks[0], (2, s, c), minval=0.4, maxval=0.99)
    x = jax.random.normal(ks[1], (2, s, c)) * 0.2
    h = kernels.rglru_scan_op(a, x, interpret=True)
    np.testing.assert_allclose(h, kernels.rglru_scan_ref(a, x),
                               rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# rmsnorm
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("m,d,bm", [(128, 512, 64), (256, 1024, 128),
                                    (64, 256, 64)])
def test_rmsnorm_kernel(dtype, m, d, bm):
    ks = jax.random.split(jax.random.PRNGKey(m + d), 2)
    x = _rand(ks[0], (m, d), dtype)
    g = _rand(ks[1], (d,), dtype, 0.1)
    y = rmsnorm(x, g, bm=bm, interpret=True)
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(kernels.rmsnorm_ref(x, g), np.float32),
                               **TOL[dtype])


# ---------------------------------------------------------------------------
# merged conv
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("k,cin,cout,hw", [(3, 16, 32, 12), (5, 8, 16, 14),
                                           (7, 4, 8, 16), (1, 16, 16, 8)])
def test_merged_conv_kernel(dtype, k, cin, cout, hw):
    """Sweep merged kernel sizes — including the grown (k=5,7) kernels that
    LayerMerge produces via Eq. 1."""
    ks = jax.random.split(jax.random.PRNGKey(k * cin), 2)
    x = _rand(ks[0], (2, hw, hw, cin), dtype)
    w = _rand(ks[1], (k, k, cin, cout), dtype, 0.1)
    y = merged_conv(x, w, bcout=min(cout, 128), interpret=True)
    yr = kernels.merged_conv_ref(x, w)
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(yr, np.float32), **TOL[dtype])


def test_merged_conv_matches_eq1_composition():
    """End-to-end: Eq.1-merged weights through the Pallas kernel equal the
    original two-conv chain."""
    from repro.core import merge as M
    ks = jax.random.split(jax.random.PRNGKey(9), 3)
    x = jax.random.normal(ks[0], (1, 12, 12, 8))
    w1 = jax.random.normal(ks[1], (3, 3, 8, 8)) * 0.2
    w2 = jax.random.normal(ks[2], (3, 3, 8, 8)) * 0.2
    chain = kernels.merged_conv_ref(kernels.merged_conv_ref(x, w1), w2)
    wm, _ = M.merge_conv_pair(w1, w2)
    y = merged_conv(x, wm, bcout=8, interpret=True)
    np.testing.assert_allclose(y, chain, rtol=1e-4, atol=1e-4)
