"""Eq. 3 subset-selection and segment-enumeration property tests."""
import itertools

import pytest
from _hyp import given, settings, st

from repro.core.plan import LayerDesc
from repro.core.segments import SegmentEnumerator, subset_selection


@given(seed=st.integers(0, 500), n=st.integers(1, 8))
@settings(max_examples=40, deadline=None)
def test_subset_selection_is_exact(seed, n):
    """For every achievable weight, the returned subset has maximal value —
    checked against exhaustive enumeration."""
    import numpy as np
    rng = np.random.default_rng(seed)
    items = [(i, int(rng.integers(0, 5)), float(rng.random()))
             for i in range(n)]
    forced = [i for i in range(n) if rng.random() < 0.25]
    got = subset_selection(items, forced=forced)
    best = {}
    for r in range(n + 1):
        for combo in itertools.combinations(range(n), r):
            if any(f not in combo for f in forced):
                continue
            w = sum(items[i][1] for i in combo)
            v = sum(items[i][2] for i in combo)
            if w not in best or v > best[w][0]:
                best[w] = (v, tuple(sorted(combo)))
    assert set(got) == set(best)
    for w in best:
        assert got[w][0] == pytest.approx(best[w][0])
        # the kept set achieves the claimed value and weight
        ids = got[w][1]
        assert sum(items[i][1] for i in ids) == w
        assert sum(items[i][2] for i in ids) == pytest.approx(got[w][0])
        assert set(forced) <= set(ids)


def test_subset_selection_cap_groups_max():
    items = [(0, 3, 1.0), (1, 3, 2.0), (2, 3, 0.5)]
    got = subset_selection(items, cap=4)
    # weights 6 and 9 clamp to 4: best value among them must win
    assert got[4][0] == pytest.approx(3.5)   # all three (w=9 → 4, v=3.5)


def _descs(spec):
    """spec: list of (growth, prunable, linearizable)."""
    return [LayerDesc(index=i + 1, kind="x", growth=g, value=float(i + 1),
                      prunable=p, linearizable=lin)
            for i, (g, p, lin) in enumerate(spec)]


def test_depth_mode_single_k_per_span():
    descs = _descs([(2, True, True), (2, True, True), (4, True, True)])
    enum = SegmentEnumerator(descs, offset=1, depth_mode=True)
    for i, j, opts in enum.all_spans():
        assert len(opts) == 1
        (k, (val, kept)), = opts.items()
        assert set(kept) == set(range(i + 1, j + 1))   # C = [L]


def test_nonlinearizable_interior_requires_prunable():
    descs = _descs([(2, True, True), (0, False, False), (2, True, True)])
    enum = SegmentEnumerator(descs, offset=1)
    assert enum.options(0, 3) == {}          # barrier inside, not prunable
    # singleton fallback keeps the barrier as-is
    opts = enum.options(1, 2)
    assert list(opts) == [1] and opts[1][1] == (2,)


def test_transformer_convention_boundary_kept():
    descs = _descs([(8, True, True), (0, True, False), (8, True, True)])
    enum = SegmentEnumerator(descs, offset=0, cap=12)
    opts = enum.options(0, 3)
    # interior = layers 1,2 (ffn growth 8 + non-linearizable prunable attn);
    # boundary layer 3 is always kept
    assert set(opts) == {0, 8}
    for k, (val, kept) in opts.items():
        assert 3 in kept


def test_irreducible_forced_in_every_subset():
    descs = _descs([(2, False, True), (2, True, True)])
    enum = SegmentEnumerator(descs, offset=1)
    for k, (val, kept) in enum.options(0, 2).items():
        assert 1 in kept                     # layer 1 ∈ R is always kept
