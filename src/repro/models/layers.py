"""Transformer building blocks — pure functions over explicit param pytrees.

Every ``init_*`` returns ``(params, axes)`` where ``axes`` mirrors the param
tree with *logical axis name tuples* (MaxText-style).  ``sharding/rules.py``
maps logical names → mesh axes to build PartitionSpecs for pjit, so the same
model definition serves 1-device smoke tests and 512-chip dry-runs.

Logical axis vocabulary:
  'embed'   — d_model;          'heads' — query heads;   'kv'   — kv heads
  'head'    — head_dim;         'ffn'   — ffn hidden;    'vocab'— vocabulary
  'experts' — MoE expert count; 'rank'  — merged-FFN rank (LayerMerge)
  'layers'  — stacked-scan layer axis (never sharded)
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any

import jax
import jax.numpy as jnp
from jax import lax


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def rms_norm(x, scale, eps: float = 1e-6):
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    y = x * lax.rsqrt(var + eps).astype(x.dtype)
    return y * (1.0 + scale.astype(x.dtype))


def init_rmsnorm(d, dtype):
    return jnp.zeros((d,), dtype), ("embed",)


# ---------------------------------------------------------------------------
# Rotary embeddings (RoPE and multimodal M-RoPE)
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float):
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2,
                                       dtype=jnp.float32) / head_dim))


def apply_rope(x, positions, theta: float = 10000.0):
    """x: (..., S, H, D); positions: broadcastable to (..., S)."""
    d = x.shape[-1]
    freqs = rope_freqs(d, theta)                       # (D/2,)
    ang = positions[..., None].astype(jnp.float32) * freqs   # (..., S, D/2)
    cos, sin = jnp.cos(ang)[..., None, :], jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def apply_mrope(x, positions3, theta: float = 10000.0,
                sections=(0.25, 0.375, 0.375)):
    """Qwen2-VL M-RoPE: head_dim split into (temporal, height, width)
    sections, each rotated by its own position stream.

    x: (B, S, H, D); positions3: (3, B, S).
    """
    d = x.shape[-1]
    half = d // 2
    sec = [int(round(s * half)) for s in sections]
    sec[-1] = half - sum(sec[:-1])
    freqs = rope_freqs(d, theta)                       # (half,)
    # build per-frequency position stream by section
    pos_parts = []
    for i, n in enumerate(sec):
        pos_parts.append(jnp.broadcast_to(positions3[i][..., None],
                                          positions3[i].shape + (n,)))
    pos = jnp.concatenate(pos_parts, axis=-1)          # (B, S, half)
    ang = pos.astype(jnp.float32) * freqs              # (B, S, half)
    cos, sin = jnp.cos(ang)[..., None, :], jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Attention (GQA / MQA, causal, optional local window, KV cache)
# ---------------------------------------------------------------------------

def attention_axes(cfg):
    ax = {"wq": ("embed", "heads", "head"), "wk": ("embed", "kv", "head"),
          "wv": ("embed", "kv", "head"), "wo": ("heads", "head", "embed")}
    if cfg.qkv_bias:
        ax.update({"bq": ("heads", "head"), "bk": ("kv", "head"),
                   "bv": ("kv", "head")})
    return ax


def init_attention(cfg, key, dtype):
    d, h, kvh, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    ks = jax.random.split(key, 4)
    s = 1.0 / math.sqrt(d)
    p = {"wq": jax.random.normal(ks[0], (d, h, hd), dtype) * s,
         "wk": jax.random.normal(ks[1], (d, kvh, hd), dtype) * s,
         "wv": jax.random.normal(ks[2], (d, kvh, hd), dtype) * s,
         "wo": jax.random.normal(ks[3], (h, hd, d), dtype) * s}
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((h, hd), dtype)
        p["bk"] = jnp.zeros((kvh, hd), dtype)
        p["bv"] = jnp.zeros((kvh, hd), dtype)
    return p, attention_axes(cfg)


def _qkv(p, x, cfg, positions, mrope_positions=None):
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"])
    if "bq" in p:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    if cfg.rope_kind == "mrope" and mrope_positions is not None:
        q = apply_mrope(q, mrope_positions, cfg.rope_theta)
        k = apply_mrope(k, mrope_positions, cfg.rope_theta)
    elif cfg.rope_kind != "none":
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def _sdpa(q, k, v, mask, cfg):
    """Reference scaled-dot-product attention with GQA head grouping.

    q: (B, Sq, H, D); k, v: (B, Skv, KVH, D); mask: (B|1, 1, Sq, Skv) bool.
    The Pallas flash-attention kernel (kernels/flash_attention.py) replaces
    this on TPU; XLA fuses this form acceptably for the dry-run.
    """
    b, sq, h, d = q.shape
    kvh = k.shape[2]
    group = h // kvh
    qg = q.reshape(b, sq, kvh, group, d)
    logits = jnp.einsum("bskgd,btkd->bkgst", qg, k) / math.sqrt(d)
    logits = logits.astype(jnp.float32)
    neg = jnp.finfo(jnp.float32).min
    logits = jnp.where(mask[:, :, None] if mask.ndim == 4 else mask,
                       logits, neg)
    w = jax.nn.softmax(logits, axis=-1).astype(v.dtype)
    out = jnp.einsum("bkgst,btkd->bskgd", w, v)
    return out.reshape(b, sq, h, d)


def causal_mask(sq, skv, offset=0, window: int = 0):
    """(1, 1, sq, skv) bool; ``offset`` = absolute position of q[0]."""
    qpos = jnp.arange(sq)[:, None] + offset
    kpos = jnp.arange(skv)[None, :]
    m = kpos <= qpos
    if window > 0:
        m &= kpos > qpos - window
    return m[None, None]


def attention(p, x, cfg, positions, *, window: int = 0,
              mrope_positions=None):
    """Full (training / prefill) causal attention."""
    q, k, v = _qkv(p, x, cfg, positions, mrope_positions)
    mask = causal_mask(x.shape[1], x.shape[1], 0, window)
    out = _sdpa(q, k, v, mask, cfg)
    return jnp.einsum("bshk,hkd->bsd", out, p["wo"])


def attention_decode(p, x, cfg, cache, *, window: int = 0,
                     mrope_positions=None):
    """One-token decode against a KV cache.

    cache: {"k": (B, S, KVH, D), "v": ..., "pos": ()} — ``pos`` is the number
    of tokens already in the cache.  For windowed attention the cache is a
    ring buffer of size ``window``.
    """
    pos = cache["pos"]
    positions = jnp.full((x.shape[0], 1), pos, jnp.int32)
    q, k, v = _qkv(p, x, cfg, positions, mrope_positions)
    size = cache["k"].shape[1]
    slot = (pos % size) if window > 0 else pos
    ck = lax.dynamic_update_slice_in_dim(cache["k"], k, slot, axis=1)
    cv = lax.dynamic_update_slice_in_dim(cache["v"], v, slot, axis=1)
    kpos = jnp.arange(size)
    if window > 0:
        # ring buffer: entry i holds absolute position derived from slot
        abs_pos = jnp.where(kpos <= slot, pos - slot + kpos,
                            pos - slot - size + kpos)
        valid = (abs_pos >= 0) & (abs_pos <= pos) & (abs_pos > pos - size)
    else:
        valid = kpos <= pos
    from repro.sharding.rules import current_rules
    rules = current_rules()
    if getattr(cfg, "decode_flash", False) and rules is not None \
            and rules.mesh is not None and "model" in rules.mesh.shape:
        # flash-decoding: seq-sharded cache, distributed LSE combine (§Perf)
        from repro.sharding.collectives import flash_decode_attention
        vmask = jnp.broadcast_to(valid[None, :], (x.shape[0], size))
        out = flash_decode_attention(q[:, 0], ck, cv, vmask,
                                     mesh=rules.mesh, axis="model")
        out = out[:, None]
    else:
        mask = valid[None, None, None, :]
        out = _sdpa(q, ck, cv, mask, cfg)
    y = jnp.einsum("bshk,hkd->bsd", out, p["wo"])
    return y, {"k": ck, "v": cv, "pos": pos + 1}


def init_cache(cfg, batch, seq_len, dtype, window: int = 0):
    size = min(seq_len, window) if window > 0 else seq_len
    shape = (batch, size, cfg.num_kv_heads, cfg.head_dim)
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype),
            "pos": jnp.zeros((), jnp.int32)}


CACHE_AXES = {"k": ("batch", "kv_seq", "kv", "head"),
              "v": ("batch", "kv_seq", "kv", "head"), "pos": ()}


# ---------------------------------------------------------------------------
# FFN family (GeGLU / SwiGLU / GELU) + LayerMerge rank-merged FFN
# ---------------------------------------------------------------------------

def ffn_axes(kind):
    ax = {"w_up": ("embed", "ffn"), "w_down": ("ffn", "embed")}
    if kind in ("geglu", "swiglu"):
        ax["w_gate"] = ("embed", "ffn")
    return ax


def init_ffn(d, dff, kind, key, dtype):
    ks = jax.random.split(key, 3)
    s_in = 1.0 / math.sqrt(d)
    s_out = 1.0 / math.sqrt(dff)
    p = {"w_up": jax.random.normal(ks[0], (d, dff), dtype) * s_in,
         "w_down": jax.random.normal(ks[1], (dff, d), dtype) * s_out}
    if kind in ("geglu", "swiglu"):
        p["w_gate"] = jax.random.normal(ks[2], (d, dff), dtype) * s_in
    return p, ffn_axes(kind)


def ffn(p, x, kind):
    up = x @ p["w_up"]
    if kind == "geglu":
        h = jax.nn.gelu(x @ p["w_gate"]) * up
    elif kind == "swiglu":
        h = jax.nn.silu(x @ p["w_gate"]) * up
    else:
        h = jax.nn.gelu(up)
    return h @ p["w_down"]


def merged_ffn(u, v, x):
    """LayerMerge rank-``r`` residual map: ``x + (x·U)·V`` (see DESIGN §2.1).

    The Pallas kernel (kernels/merged_ffn.py) fuses both GEMMs + the residual
    add; this jnp form is the oracle and the dry-run path.
    """
    return x + (x @ u) @ v


def init_embedding(vocab, d, key, dtype):
    p = jax.random.normal(key, (vocab, d), dtype) / math.sqrt(d)
    return p, ("vocab", "embed")
