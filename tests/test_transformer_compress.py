"""LayerMerge on transformers (DESIGN §2.1): host, rank-merge equality,
abstract planning, compressed-spec forward."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import compress
from repro.models import transformer as T
from repro.models.transformer_host import (CostEnv, TransformerHost,
                                           abstract_plan,
                                           forward_compressed_spec,
                                           init_compressed_model,
                                           plan_units_spec)


@pytest.fixture(scope="module")
def setup():
    cfg = dataclasses.replace(
        get_config("smollm-135m").reduced(), num_layers=4)
    params, _ = T.init_model(cfg, jax.random.PRNGKey(0))
    B, S = 2, 16
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (B, S), 0,
                                          cfg.vocab_size),
             "positions": jnp.broadcast_to(jnp.arange(S)[None], (B, S))}
    return cfg, params, batch


@pytest.mark.parametrize("method", ["layermerge", "depth", "layeronly"])
def test_transformer_replaced_equals_merged(setup, method):
    """The factored rank-merge is exact: replaced ≡ merged forward."""
    cfg, params, batch = setup
    host = TransformerHost(cfg, params, env=CostEnv(batch=2, seq=16))
    tested = 0
    for ratio in (0.5, 0.7, 0.9):
        res = compress(host, budget_ratio=ratio, P=200, method=method)
        if res is None:
            continue
        ra, _ = host.replaced_apply(res.plan)
        ma, _ = host.merged_apply(res.plan)
        yr, ym = ra(params, batch), ma(params, batch)
        scale = float(jnp.abs(yr).max()) + 1e-9
        assert float(jnp.abs(yr - ym).max()) / scale < 1e-4
        tested += 1
    assert tested > 0


def test_layermerge_beats_depth_at_tight_budget(setup):
    """The paper's core claim, on transformers: joint pruning reaches
    budgets activation-only Depth cannot (attention blocks must be PRUNED
    to merge across them — Depth has no such move)."""
    cfg, params, batch = setup
    host = TransformerHost(cfg, params, env=CostEnv(batch=2, seq=16))
    lm = compress(host, budget_ratio=0.5, P=200, method="layermerge")
    depth = compress(host, budget_ratio=0.5, P=200, method="depth")
    assert lm is not None
    assert depth is None        # Depth is infeasible at 50 % here


def test_merged_segments_have_bounded_rank(setup):
    cfg, params, batch = setup
    host = TransformerHost(cfg, params, env=CostEnv(batch=2, seq=16))
    res = compress(host, budget_ratio=0.5, P=200)
    for seg in res.plan.segments:
        assert seg.k <= cfg.d_model   # Eq.1-analogue cap


def test_abstract_plan_and_compressed_spec():
    """Production-scale planning path (no parameter materialization) and
    the compressed-spec forward used by the dry-run --budget cells."""
    cfg = dataclasses.replace(get_config("smollm-135m").reduced(),
                              num_layers=4)
    res = abstract_plan(cfg, budget_ratio=0.6,
                        env=CostEnv(batch=2, seq=16, chips=1))
    assert res is not None and res.speedup > 1.2
    spec = plan_units_spec(cfg, res.plan)
    assert any(u[0] == "merged" for u in spec)
    params = init_compressed_model(cfg, spec, jax.random.PRNGKey(0))
    batch = {"tokens": jnp.zeros((2, 8), jnp.int32),
             "positions": jnp.broadcast_to(jnp.arange(8)[None], (2, 8))}
    logits = forward_compressed_spec(cfg, spec, params, batch)
    assert logits.shape == (2, 8, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits)))


def test_compressed_forward_is_differentiable(setup):
    cfg, params, batch = setup
    host = TransformerHost(cfg, params, env=CostEnv(batch=2, seq=16))
    res = compress(host, budget_ratio=0.6, P=200)
    ra, _ = host.replaced_apply(res.plan)

    def loss(p):
        logits = ra(p, batch).astype(jnp.float32)
        return jnp.mean(logits ** 2)
    g = jax.grad(loss)(params)
    gn = sum(float(jnp.sum(x.astype(jnp.float32) ** 2))
             for x in jax.tree.leaves(g))
    assert np.isfinite(gn) and gn > 0
