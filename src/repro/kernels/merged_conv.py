"""Pallas TPU kernel: merged-segment convolution (VALID, stride s, NHWC).

The paper's hot spot: after LayerMerge, a segment executes as ONE conv
whose kernel has grown (Eq. 1) and whose stride is the product of the
segment's strides.  TPU adaptation: instead of im2col (which materializes
the k²-unrolled input in HBM), each grid step keeps one *output tile* of
the image in VMEM and accumulates the k_h·k_w shifted GEMMs —
(tile_ho·tile_wo, Cin) @ (Cin, bCout) per tap — on the MXU, so the grown
kernel costs FLOPs but no extra HBM traffic (exactly the trade the DP's
latency table models).

Grid: ``(batch, ho-tiles, wo-tiles, cout-tiles)`` with the channel axis
innermost so one input tile serves every output-channel block.

Phase-major input layout (the stride-s contract, shared with the
depthwise kernel in :mod:`repro.kernels.depthwise_conv`).  A stride-s
VALID conv reads, for output row ``t`` and tap ``u``, input row
``s·t + u`` — row *phase* ``u mod s``, phase-local index ``t + u//s``.
The wrapper therefore re-lays the image out **phase-major** before the
kernel::

    x (N, H, W, C)  →  x_pm (N, pʜ, p𝑤, H/s, W/s, C)
    x_pm[n, p, q, t, r, c] = x[n, s·t + p, s·r + q, c]

(pʜ = min(s, k_h), p𝑤 = min(s, k_w): taps can only touch the first
``k`` phases, so unused phases are never laid out or copied.)  Under
this layout each tap ``(u, v)`` of each tile is a *contiguous* window —
``x_pm[p, q][t₀ + u//s : t₀ + u//s + tile_ho, …]`` — so phase selection
is a static VMEM slice instead of the former reshape-and-index
decimation, and the tile's DMA is one rectangular window per step
covering every phase at once.  For s = 1 the layout is the identity
(pʜ = p𝑤 = 1) and the kernel degenerates bit-for-bit to the dense path;
the relayout itself is one XLA transpose (HBM read + write of the
image) charged by :func:`input_traffic_model` as ``relayout_bytes`` and
priced by ``conv2d_cost`` — only strided segments pay it.

Zero-copy halos.  The phase-major input stays HBM-resident
(``memory_space=ANY``); each grid step DMAs its halo'd window straight
into VMEM scratch with ``pltpu.make_async_copy`` over ``pl.ds``
windows::

    step t   (co == 0):  start DMA[t+1] → slot (t+1)%2     (prefetch)
                         wait  DMA[t]   ← slot t%2
    step t   (co  > 0):  reuse slot t%2 (already resident)

    HBM x_pm ───DMA──▶ VMEM xs[2, pʜ, p𝑤, tile_ho+δʜ, tile_wo+δ𝑤, Cin]
    HBM w ──spec──▶ VMEM (kh, kw, Cin, bCout)
                    fp32 acc (tile_ho·tile_wo, bCout) ──▶ out block

where ``δʜ = (k_h−1)//s`` / ``δ𝑤 = (k_w−1)//s`` are the per-phase halo
extents.  Input HBM traffic per call is one read of the image plus the
halo rows/cols re-read at tile seams (see :func:`input_traffic_model`).

VMEM per step (bounded by :func:`choose_tiles` regardless of image
size): double-buffered input scratch ``2·pʜ·p𝑤·(tile_ho + δʜ)·
(tile_wo + δ𝑤)·Cin`` — never larger than the dense-window bound
``2·(s·tile_ho + k_h − 1)·(s·tile_wo + k_w − 1)·Cin`` the planner
accounts — plus the weight block ``k²·Cin·bCout`` and the fp32
accumulator + output block ``tile_ho·tile_wo·bCout``.  Very wide
single-row images (panorama / NLP-grid) shrink ``tile_wo`` instead of
overflowing VMEM.  Bias add and the boundary activation σ_j run in the
kernel epilogue (fp32, before the store), eliminating the extra HBM
round-trip the unfused epilogue paid.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .ref import apply_activation

# Full working-set budget for the 2-D planner: double-buffered input
# scratch + weight block + fp32 accumulator + output block, inside
# ~16 MiB/core with room for Mosaic's own spills.
_VMEM_BUDGET = 6 * 2 ** 20


def phase_extents(kh: int, kw: int, stride: int) -> tuple[int, int, int, int]:
    """``(pʜ, p𝑤, δʜ, δ𝑤)`` of the phase-major layout: phases touched per
    spatial axis (``min(s, k)``) and per-phase halo extents
    (``(k−1)//s``).  For s = 1 this is ``(1, 1, k_h−1, k_w−1)`` — the
    dense window."""
    s = max(stride, 1)
    return min(s, kh), min(s, kw), (kh - 1) // s, (kw - 1) // s


def phase_major(x, kh: int, kw: int, stride: int, hs: int, ws: int):
    """Lay an NHWC image out phase-major: ``(N, pʜ, p𝑤, hs, ws, C)``.

    ``hs``/``ws`` are the per-phase spatial extents the kernel's tiling
    requires; the image is zero-padded up to ``(s·hs, s·ws)`` first
    (ragged last tiles / s∤H).  One XLA transpose — the only HBM
    relayout a strided segment pays; s = 1 is a free reshape.
    """
    n, h, w, c = x.shape
    s = max(stride, 1)
    ph, pw, _, _ = phase_extents(kh, kw, s)
    pad_h, pad_w = s * hs - h, s * ws - w
    assert pad_h >= 0 and pad_w >= 0, (x.shape, hs, ws, s)
    if pad_h or pad_w:
        x = jnp.pad(x, ((0, 0), (0, pad_h), (0, pad_w), (0, 0)))
    x = x.reshape(n, hs, s, ws, s, c).transpose(0, 2, 4, 1, 3, 5)
    return x[:, :ph, :pw]


def _round8(t: int, cap: int) -> int:
    """Clamp a tile extent to [1, cap], preferring multiples of 8."""
    t = max(min(t, cap), 1)
    if t < cap and t > 8:
        t -= t % 8
    return t


def choose_tiles(h: int, w: int, cin: int, kh: int, kw: int, stride: int,
                 itemsize: int, bcout: int = 128,
                 budget_bytes: float = _VMEM_BUDGET) -> tuple[int, int]:
    """2-D ``(tile_ho, tile_wo)`` VMEM planner for the merged conv.

    Accounts the whole per-step working set: double-buffered input
    scratch via the dense-window bound ``2·(s·tho + k_h − 1)·(s·two +
    k_w − 1)·Cin·itemsize`` (an upper bound on the phase-major scratch
    ``2·pʜ·p𝑤·(tho + δʜ)·(two + δ𝑤)·Cin`` actually allocated — equal
    whenever ``s | k−1``, e.g. every odd kernel at stride 2), the weight
    block ``k_h·k_w·Cin·bCout·itemsize`` and the fp32 accumulator plus
    output block ``tho·two·bCout·(4 + itemsize)``.  Starts from the full
    output width and grows the row tile; only when a single full-width
    output row overflows (very wide images) does it shrink ``tile_wo``
    with ``tile_ho = 1``.  Prefers multiples of 8 on the tiled axis.
    """
    s = max(stride, 1)
    ho = max((h - kh) // s + 1, 1)
    wo = max((w - kw) // s + 1, 1)
    fixed = kh * kw * cin * bcout * itemsize          # weight block
    acc_b = bcout * (4 + itemsize)                    # per output element

    # Single full-width output row: does it fit?
    shi1 = s + kh - 1
    a_w = 2 * shi1 * s * cin * itemsize + acc_b
    b_w = fixed + 2 * shi1 * (kw - 1) * cin * itemsize
    if a_w * wo + b_w > budget_bytes:
        tile_wo = int((budget_bytes - b_w) // a_w)
        return 1, _round8(tile_wo, wo)

    # Full width fits: grow the row tile.
    swi = s * wo + kw - 1
    a_h = 2 * s * swi * cin * itemsize + wo * acc_b
    b_h = fixed + 2 * (kh - 1) * swi * cin * itemsize
    tile_ho = int((budget_bytes - b_h) // a_h)
    return _round8(tile_ho, ho), wo


def input_traffic_model(h: int, w: int, cin: int, kh: int, kw: int,
                        stride: int, itemsize: int,
                        tile_ho: int | None = None,
                        tile_wo: int | None = None,
                        bcout: int = 128,
                        groups: int = 1) -> dict[str, float]:
    """Per-image input HBM bytes of the DMA kernel vs the PR-1 host gather.

    ``dma_bytes`` is what the zero-copy kernel moves: every tile's
    phase-major halo'd window read once straight out of the HBM-resident
    image (one image read plus the halo rows/cols re-read at tile seams).
    The total is *group-blocking invariant*: the depthwise/grouped kernel
    DMAs each spatial window once per channel block, but each block
    carries only its own channels, so the aggregate equals the dense
    kernel's — ``groups`` only affects which tile planner picks the
    default tiles.  ``relayout_bytes`` is the one-off phase-major
    transpose strided segments pay (HBM read + write of the padded
    image; zero at stride 1).  ``gather_bytes`` is what the deleted
    host-side gather paid whenever more than one row tile was needed:
    read the image, write the halo'd row-tile tensor, read it back in
    the kernel.  ``saved_bytes`` is the reclaimed bandwidth net of the
    relayout.
    """
    s = max(stride, 1)
    if tile_ho is None or tile_wo is None:
        if groups > 1:
            # grouped/depthwise path: channel-blocked tiles from the
            # grouped planner (cost queries are always pure depthwise,
            # cin_g = cout_g = 1; the layering note in conv2d_cost
            # applies — kernels never import core, no cycle)
            from .depthwise_conv import choose_tiles_grouped
            from .ops import channel_tile
            a_ho, a_wo = choose_tiles_grouped(
                h, w, 1, 1, kh, kw, s, itemsize,
                bgroups=channel_tile(groups, None))
        else:
            a_ho, a_wo = choose_tiles(h, w, cin, kh, kw, s, itemsize, bcout)
        tile_ho = tile_ho or a_ho
        tile_wo = tile_wo or a_wo
    ho = max((h - kh) // s + 1, 1)
    wo = max((w - kw) // s + 1, 1)
    tile_ho = max(1, min(tile_ho, ho))
    tile_wo = max(1, min(tile_wo, wo))
    n_th, n_tw = -(-ho // tile_ho), -(-wo // tile_wo)
    ph, pw, dh, dw = phase_extents(kh, kw, s)
    tile_elems = ph * pw * (tile_ho + dh) * (tile_wo + dw)
    image = h * w * cin * itemsize
    dma = n_th * n_tw * tile_elems * cin * itemsize
    relayout = 0.0
    if s > 1:
        hs = max(n_th * tile_ho + dh, -(-h // s))
        ws = max(n_tw * tile_wo + dw, -(-w // s))
        relayout = 2.0 * s * hs * s * ws * cin * itemsize
    # PR-1 path: stride-1 only, full-width row tiles; xt was materialized
    # (and re-read) whenever n_th > 1.
    tile_hi = s * (tile_ho - 1) + kh
    xt = n_th * tile_hi * w * cin * itemsize
    gather = image + 2 * xt if n_th > 1 else xt
    return {"image_bytes": float(image), "dma_bytes": float(dma),
            "relayout_bytes": float(relayout),
            "gather_bytes": float(gather),
            # halo-gather traffic reclaimed (dense and depthwise rows
            # alike; group-blocking invariant), before the relayout charge
            "halo_bytes_saved": float(gather - dma),
            "saved_bytes": float(gather - dma - relayout),
            "tile_ho": tile_ho, "tile_wo": tile_wo}


def _kernel(x_hbm, w_ref, b_ref, *rest, kh: int, kw: int,
            stride: int, n_th: int, n_tw: int, activation: str | None,
            quant: bool = False):
    # Quantized path: one extra (1, bCout) fp32 scale operand (per-output-
    # channel symmetric weight scale; w8a8 folds the activation scale in
    # at the ops layer).  Applied AFTER the fp32 accumulation — exactly
    # equal to dequantizing each weight before the dot, since the scale
    # is constant over the (kh, kw, Cin) contraction.
    if quant:
        ws_ref, o_ref, xs, sem = rest
    else:
        ws_ref, (o_ref, xs, sem) = None, rest
    tho, two, bcout = o_ref.shape
    cin = w_ref.shape[2]
    s = stride
    shp, swp = xs.shape[3], xs.shape[4]       # per-phase halo'd tile extents
    bb, th, tw, co = (pl.program_id(i) for i in range(4))
    step = (bb * n_th + th) * n_tw + tw
    n_steps = pl.num_programs(0) * n_th * n_tw

    def dma(step_idx, slot):
        b2 = step_idx // (n_th * n_tw)
        r = step_idx % (n_th * n_tw)
        return pltpu.make_async_copy(
            x_hbm.at[b2, :, :, pl.ds((r // n_tw) * tho, shp),
                     pl.ds((r % n_tw) * two, swp), :],
            xs.at[slot], sem.at[slot])

    @pl.when((step == 0) & (co == 0))
    def _():                                   # pipeline prologue
        dma(0, 0).start()

    @pl.when((co == 0) & (step + 1 < n_steps))
    def _():                                   # prefetch next tile window
        dma(step + 1, (step + 1) % 2).start()

    @pl.when(co == 0)
    def _():                                   # await this step's window
        dma(step, step % 2).wait()

    acc = jnp.zeros((tho * two, bcout), jnp.float32)
    for u in range(kh):
        for v in range(kw):
            # Phase-major tap selection: tap (u, v) is the contiguous
            # window [u//s : u//s + tho, v//s : v//s + two] of phase
            # (u % s, v % s) — a static VMEM slice, no reshape-and-index.
            xsel = xs[step % 2, u % s, v % s, pl.ds(u // s, tho),
                      pl.ds(v // s, two), :]              # (tho, two, Cin)
            acc = acc + jnp.dot(
                xsel.reshape(tho * two, cin).astype(jnp.float32),
                w_ref[u, v].astype(jnp.float32),
                preferred_element_type=jnp.float32)
    if ws_ref is not None:
        acc = acc * ws_ref[0].astype(jnp.float32)        # dequant epilogue
    acc = acc + b_ref[0].astype(jnp.float32)             # (bCout,) broadcast
    # fused epilogue: σ_j on the fp32 accumulator, shared with the oracle
    acc = apply_activation(acc, activation)
    o_ref[...] = acc.reshape(tho, two, bcout).astype(o_ref.dtype)


def merged_conv(x, w, b=None, *, stride: int = 1, bcout: int = 128,
                tile_ho: int | None = None, tile_wo: int | None = None,
                activation: str | None = None, w_scale=None,
                out_dtype=None, interpret: bool = False):
    """x: (N, H, W, Cin); w: (kh, kw, Cin, Cout) → (N, Ho, Wo, Cout).

    VALID convolution with ``stride`` on both spatial axes.  ``tile_ho`` /
    ``tile_wo`` are the output tile dims (default: the 2-D VMEM planner);
    ``b``/``activation`` fuse the segment epilogue.  The input is laid
    out phase-major (see module docstring) before the kernel; at stride 1
    that is a free reshape.

    Quantized weights: pass ``w`` narrow (int8 / fp8) with ``w_scale`` —
    a per-output-channel ``(Cout,)`` fp32 scale applied in the fp32
    epilogue.  w8a8 additionally passes ``x`` int8 with the activation
    scale pre-folded into ``w_scale``; set ``out_dtype`` to keep the
    output fp.  The narrow blocks ride the same zero-copy DMA pipeline
    (VMEM scratch takes its dtype from ``x``).
    """
    n, h, wdt, cin = x.shape
    kh, kw, _, cout = w.shape
    s = stride
    assert s >= 1 and h >= kh and wdt >= kw, (x.shape, w.shape, s)
    ho = (h - kh) // s + 1
    wo = (wdt - kw) // s + 1
    bcout = min(bcout, cout)
    assert cout % bcout == 0, "pad channels at the ops layer"
    if tile_ho is None or tile_wo is None:
        a_ho, a_wo = choose_tiles(h, wdt, cin, kh, kw, s, x.dtype.itemsize,
                                  bcout)
        tile_ho = a_ho if tile_ho is None else tile_ho
        tile_wo = a_wo if tile_wo is None else tile_wo
    tile_ho = max(1, min(tile_ho, ho))
    tile_wo = max(1, min(tile_wo, wo))
    n_th, n_tw = -(-ho // tile_ho), -(-wo // tile_wo)
    ho_p, wo_p = n_th * tile_ho, n_tw * tile_wo
    ph, pw, dh, dw = phase_extents(kh, kw, s)
    shp, swp = tile_ho + dh, tile_wo + dw     # per-phase halo'd tile extents

    # Phase-major relayout; per-phase extents padded so every DMA window
    # is full (static copy sizes) — ragged last tiles read zero rows/cols
    # whose outputs are sliced off below.
    hs = max(n_th * tile_ho + dh, -(-h // s))
    ws = max(n_tw * tile_wo + dw, -(-wdt // s))
    x = phase_major(x, kh, kw, s, hs, ws)

    bias = (jnp.zeros((1, cout), jnp.float32) if b is None
            else b.reshape(1, cout))
    odt = jnp.dtype(out_dtype) if out_dtype is not None else x.dtype

    in_specs = [
        pl.BlockSpec(memory_space=pltpu.ANY),     # HBM phase-major image
        pl.BlockSpec((kh, kw, cin, bcout),
                     lambda bb, th, tw, co: (0, 0, 0, co)),
        pl.BlockSpec((1, bcout), lambda bb, th, tw, co: (0, co)),
    ]
    operands = [x, w, bias]
    if w_scale is not None:
        in_specs.append(pl.BlockSpec((1, bcout),
                                     lambda bb, th, tw, co: (0, co)))
        operands.append(w_scale.reshape(1, cout).astype(jnp.float32))

    grid = (n, n_th, n_tw, cout // bcout)
    out = pl.pallas_call(
        functools.partial(_kernel, kh=kh, kw=kw, stride=s, n_th=n_th,
                          n_tw=n_tw, activation=activation,
                          quant=w_scale is not None),
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((None, tile_ho, tile_wo, bcout),
                               lambda bb, th, tw, co: (bb, th, tw, co)),
        out_shape=jax.ShapeDtypeStruct((n, ho_p, wo_p, cout), odt),
        scratch_shapes=[pltpu.VMEM((2, ph, pw, shp, swp, cin), x.dtype),
                        pltpu.SemaphoreType.DMA((2,))],
        interpret=interpret,
    )(*operands)
    return out[:, :ho, :wo] if (ho_p, wo_p) != (ho, wo) else out
