"""Deterministic host factories resolvable by name across processes.

The distributed table build (:mod:`repro.core.dist_build`) re-creates the
host inside each worker process from a JSON ``host spec`` —
``{"factory": "module:function", "kwargs": {...}}`` — because hosts close
over live arrays and cannot be shipped over a pipe.  Every factory here
is seed-deterministic: called with the same kwargs in any process it
yields a host with the same fingerprint, which is what lets per-worker
probe results merge into tables bit-identical to a single-process build.

Factories return ``(host, params)``.
"""
from __future__ import annotations


def tiny_resnet_host(*, num_classes: int = 4, in_hw: int = 8,
                     width: int = 4, blocks=(2,), batch: int = 4,
                     max_span=None, seed: int = 0):
    """The fault-smoke CNN host (same instance the kill-and-resume smoke
    in :mod:`repro.testing.faults` builds)."""
    import jax

    from repro.models import cnn, cnn_host, zoo

    net = zoo.tiny_resnet(num_classes=num_classes, in_hw=in_hw,
                          width=width, blocks=tuple(blocks))
    params = cnn.init_params(net, jax.random.PRNGKey(seed))
    return cnn_host.CNNHost(net, params, batch=batch,
                            max_span=max_span), params


def conv_chain_host(*, L: int = 5, max_span: int = 3, width: int = 8,
                    in_hw: int = 8, k: int = 3, batch: int = 4,
                    seed: int = 0):
    """Uniform stride-1 conv chain — maximal shape dedup, the regime the
    probe engine (and its distributed fan-out) targets."""
    import jax

    from repro.models import cnn, cnn_host
    from repro.models.cnn import ConvNet, ConvSpec

    specs = [ConvSpec(3, width, k, 1, act="relu")]
    specs += [ConvSpec(width, width, k, 1, act="relu")
              for _ in range(L - 1)]
    net = ConvNet(tuple(specs), (), in_hw=in_hw, in_ch=3,
                  head="classifier", num_classes=4)
    params = cnn.init_params(net, jax.random.PRNGKey(seed))
    return cnn_host.CNNHost(net, params, batch=batch,
                            max_span=max_span), params


def cli_host(*, arch: str, seed: int = 0, batch: int = 8, seq: int = 128,
             full: bool = False, max_span=None):
    """Adapter for the ``python -m repro.compress`` arch zoo, so CLI
    builds (``--workers N``) distribute through the same spec protocol."""
    from repro.compress import build_host

    host, _source = build_host(arch, seed=seed, batch=batch, seq=seq,
                               full=full, max_span=max_span)
    return host, host.params
