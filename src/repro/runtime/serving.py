"""Serve-loop driver: teacher-forced prefill + greedy KV-cache decode.

ONE timing loop for every consumer of a one-token serve step — the
original stack (:func:`repro.train.step.make_serve_step`) and the
artifact-backed compressed executor (:func:`repro.runtime.executor.
make_serve_step`) — so ``examples/serve_lm.py`` and
``benchmarks/bench_serve.py`` measure exactly the same protocol.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp


def serve_loop(step, params, cache, prompt, tokens: int):
    """Drive ``step(params, cache, batch) → (logits, cache)``.

    Feeds ``prompt`` token by token (prefill), then greedily decodes
    ``tokens`` ids.  Returns ``(prefill_s, decode_s, logits, seqs)`` —
    wall-clock seconds for each phase, the final logits, and the
    ``(B, tokens)`` generated ids.
    """
    logits = None
    t0 = time.perf_counter()
    for t in range(prompt.shape[1]):
        logits, cache = step(params, cache, {"tokens": prompt[:, t:t + 1]})
    jax.block_until_ready(logits)
    prefill_s = time.perf_counter() - t0

    tok = jnp.argmax(logits[:, -1], axis=-1)[:, None]
    out = [tok]
    t0 = time.perf_counter()
    for _ in range(tokens - 1):
        logits, cache = step(params, cache, {"tokens": tok})
        tok = jnp.argmax(logits[:, -1], axis=-1)[:, None]
        out.append(tok)
    jax.block_until_ready(tok)
    decode_s = time.perf_counter() - t0
    return prefill_s, decode_s, logits, jnp.concatenate(out, axis=1)
