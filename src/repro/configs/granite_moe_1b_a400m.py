"""granite-moe-1b-a400m [hf:ibm-granite/granite-3.0-1b-a400m-base; hf]."""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="granite-moe-1b-a400m", family="moe",
    num_layers=24, d_model=1024, num_heads=16, num_kv_heads=8,
    d_ff=0, vocab_size=49155,
    num_experts=32, experts_per_token=8, moe_dff=512,
    ffn_kind="swiglu", temporal_pattern=("attn",),
    tie_embeddings=True,
    source="hf:ibm-granite/granite-3.0-1b-a400m-base; 32 experts top-8",
)
