"""Compression plan datastructures shared by every LayerMerge host.

A *plan* is the output of the DP solver (Algorithm 1 of the paper): the kept
activation-boundary set ``A*``, the kept layer set ``C*`` and the merged-size
``k_i*`` of every segment.  Positions follow the paper's convention:

* layers are ``1..L``; boundary positions are ``0..L``,
* a segment is the half-open interval ``(i, j]`` — it *owns* layers
  ``i+1 .. j``,
* ``A* = {a_1 < ... < a_m} ⊆ [L-1]`` with ``a_0 = 0`` and ``a_{m+1} = L``
  implied,
* ``C* ⊆ [L]`` (always a superset of the irreducible set ``R``).

``k`` is the merged-size coordinate of the lookup tables: merged *kernel
size* on the CNN instantiation, merged *rank* on the transformer
instantiation (see DESIGN.md §2.1).

A plan is pure data: hosts lower it to an executable
:class:`repro.runtime.ir.UnitGraph` via ``host.lower_plan(plan,
params)``, and its JSON form travels inside merged-model artifacts
(:mod:`repro.runtime.artifact`) so a deployment can verify exactly which
``(A*, C*, k*)`` solution it is running.
"""
from __future__ import annotations

import dataclasses
import json
from typing import Any, Mapping, Sequence


@dataclasses.dataclass(frozen=True)
class LayerDesc:
    """Static description of one compressible layer (paper's (f_l, σ_l))."""

    index: int                  # 1-based position in the chain
    kind: str                   # 'conv' | 'dwconv' | 'ffn' | 'glu_ffn' | 'attn'
                                # | 'moe' | 'rglru' | 'mlstm' | 'slstm' | ...
    growth: int                 # contribution to merged size when KEPT inside a
                                # merged segment: Ker-1 for convs, rank r=d_ff for
                                # linearizable FFNs, 0 for identity.
    value: float                # ℓ1-norm of the parameters (Eq. 3 objective)
    prunable: bool              # can be replaced by the identity (l ∉ R)
    linearizable: bool          # σ_l can be removed (convs: always True —
                                # the conv itself is linear; attention/MoE: False)
    meta: Mapping[str, Any] = dataclasses.field(default_factory=dict)


@dataclasses.dataclass(frozen=True)
class Segment:
    """One merged segment ``(i, j]`` with its chosen merged size and kept set."""

    i: int
    j: int
    k: int                      # merged size (kernel size / rank)
    kept: tuple[int, ...]       # Ĉ_ijk — kept layer indices within (i, j]
    original: bool = False      # True ⇔ singleton kept exactly as in the source
                                # network (no activation removed)
    quant: str = "none"         # per-unit precision the DP chose for the
                                # merged weights: 'none' | 'int8' | 'w8a8'
                                # | 'fp8' (orthogonal to `original`)

    @property
    def layers(self) -> tuple[int, ...]:
        return tuple(range(self.i + 1, self.j + 1))

    @property
    def pruned(self) -> tuple[int, ...]:
        kept = set(self.kept)
        return tuple(l for l in self.layers if l not in kept)


@dataclasses.dataclass(frozen=True)
class CompressionPlan:
    """Full solution ``(A*, C*, (k_i*))`` as an ordered list of segments."""

    num_layers: int
    segments: tuple[Segment, ...]
    objective: float = 0.0          # Σ I achieved by the DP
    latency: float = 0.0            # Σ T (true, undiscretized) of the plan
    budget: float = 0.0             # T0 handed to the solver
    method: str = "layermerge"      # 'layermerge' | 'depth' | 'layeronly'

    def __post_init__(self):
        # Validate that segments tile (0, L] exactly.
        pos = 0
        for s in self.segments:
            if s.i != pos or s.j <= s.i:
                raise ValueError(f"segments do not tile (0, L]: {self.segments}")
            pos = s.j
        if pos != self.num_layers:
            raise ValueError(
                f"segments end at {pos}, expected L={self.num_layers}")

    # -- paper-notation views ------------------------------------------------
    @property
    def A(self) -> tuple[int, ...]:
        """Kept activation boundaries, ascending (excludes 0 and L)."""
        return tuple(s.j for s in self.segments[:-1])

    @property
    def C(self) -> tuple[int, ...]:
        """Kept layer indices, ascending."""
        out: list[int] = []
        for s in self.segments:
            out.extend(s.kept)
        return tuple(sorted(out))

    @property
    def ks(self) -> tuple[int, ...]:
        return tuple(s.k for s in self.segments)

    def segment_of(self, layer: int) -> Segment:
        for s in self.segments:
            if s.i < layer <= s.j:
                return s
        raise KeyError(layer)

    # -- serialization --------------------------------------------------------
    def to_json(self) -> str:
        return json.dumps({
            "num_layers": self.num_layers,
            "objective": self.objective,
            "latency": self.latency,
            "budget": self.budget,
            "method": self.method,
            "segments": [
                {"i": s.i, "j": s.j, "k": s.k, "kept": list(s.kept),
                 "original": s.original,
                 **({"quant": s.quant} if s.quant != "none" else {})}
                for s in self.segments
            ],
        }, indent=2)

    @staticmethod
    def from_json(text: str) -> "CompressionPlan":
        d = json.loads(text)
        return CompressionPlan(
            num_layers=d["num_layers"],
            segments=tuple(
                Segment(i=s["i"], j=s["j"], k=s["k"], kept=tuple(s["kept"]),
                        original=s.get("original", False),
                        quant=s.get("quant", "none"))
                for s in d["segments"]),
            objective=d.get("objective", 0.0),
            latency=d.get("latency", 0.0),
            budget=d.get("budget", 0.0),
            method=d.get("method", "layermerge"),
        )


def identity_plan(num_layers: int, descs: Sequence[LayerDesc]) -> CompressionPlan:
    """The no-op plan: every layer its own original segment."""
    segs = tuple(
        Segment(i=l - 1, j=l, k=d.growth + 1, kept=(l,), original=True)
        for l, d in zip(range(1, num_layers + 1), descs))
    return CompressionPlan(num_layers=num_layers, segments=segs,
                           method="identity")
