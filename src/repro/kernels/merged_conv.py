"""Pallas TPU kernel: merged-segment convolution (VALID, stride s, NHWC).

The paper's hot spot: after LayerMerge, a segment executes as ONE conv
whose kernel has grown (Eq. 1) and whose stride is the product of the
segment's strides.  TPU adaptation: instead of im2col (which materializes
the k²-unrolled input in HBM), each grid step keeps one *output tile* of
the image in VMEM and accumulates the k_h·k_w shifted GEMMs —
(tile_ho·tile_wo, Cin) @ (Cin, bCout) per tap — on the MXU, so the grown
kernel costs FLOPs but no extra HBM traffic (exactly the trade the DP's
latency table models).

Grid: ``(batch, ho-tiles, wo-tiles, cout-tiles)`` with the channel axis
innermost so one input tile serves every output-channel block.

Zero-copy halos.  The input stays HBM-resident (``memory_space=ANY``); each
grid step DMAs its halo'd input window straight into VMEM scratch with
``pltpu.make_async_copy`` over ``pl.ds`` row/col windows::

    step t   (co == 0):  start DMA[t+1] → slot (t+1)%2     (prefetch)
                         wait  DMA[t]   ← slot t%2
    step t   (co  > 0):  reuse slot t%2 (already resident)

    HBM x ───DMA──▶ VMEM xs[2, Hi, Wi, Cin]   (double-buffered)
    HBM w ──spec──▶ VMEM (kh, kw, Cin, bCout)
                    fp32 acc (tile_ho·tile_wo, bCout) ──▶ out block

The former host-side halo'd-row-tile gather (one extra input-sized HBM
copy per call whenever more than one row tile was needed) is gone: input
HBM traffic per call is one read of the image plus the ``k−1`` halo
rows/cols re-read at tile seams (see :func:`input_traffic_model`).

Strided segments run on the MXU via phase selection: the scratch window
holds the dense input rows/cols and each tap slices the stride-s phase by
a reshape-and-index (``(s·t, …) → (t, s, …)[:, 0]``), so the output index
map stays blocked and static while the MXU contraction sees only the
decimated elements — no jnp-oracle fallback for stride > 1.

VMEM per step (bounded by :func:`choose_tiles` regardless of image size):
double-buffered input scratch ``2·(s·tile_ho + k_h − 1)·(s·tile_wo +
k_w − 1)·Cin``, weight block ``k²·Cin·bCout``, fp32 accumulator + output
block ``tile_ho·tile_wo·bCout``.  Very wide single-row images (panorama /
NLP-grid) shrink ``tile_wo`` instead of overflowing VMEM.  Bias add and
the boundary activation σ_j run in the kernel epilogue (fp32, before the
store), eliminating the extra HBM round-trip the unfused epilogue paid.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .ref import apply_activation

# Full working-set budget for the 2-D planner: double-buffered input
# scratch + weight block + fp32 accumulator + output block, inside
# ~16 MiB/core with room for Mosaic's own spills.
_VMEM_BUDGET = 6 * 2 ** 20


def choose_tiles(h: int, w: int, cin: int, kh: int, kw: int, stride: int,
                 itemsize: int, bcout: int = 128,
                 budget_bytes: float = _VMEM_BUDGET) -> tuple[int, int]:
    """2-D ``(tile_ho, tile_wo)`` VMEM planner for the merged conv.

    Accounts the whole per-step working set: double-buffered input scratch
    ``2·(s·tho + k_h − 1)·(s·two + k_w − 1)·Cin·itemsize``, the weight
    block ``k_h·k_w·Cin·bCout·itemsize`` and the fp32 accumulator plus
    output block ``tho·two·bCout·(4 + itemsize)``.  Starts from the full
    output width and grows the row tile; only when a single full-width
    output row overflows (very wide images) does it shrink ``tile_wo``
    with ``tile_ho = 1``.  Prefers multiples of 8 on the tiled axis.
    """
    s = max(stride, 1)
    ho = max((h - kh) // s + 1, 1)
    wo = max((w - kw) // s + 1, 1)
    fixed = kh * kw * cin * bcout * itemsize          # weight block
    acc_b = bcout * (4 + itemsize)                    # per output element

    def round8(t, cap):
        t = max(min(t, cap), 1)
        if t < cap and t > 8:
            t -= t % 8
        return t

    # Single full-width output row: does it fit?
    shi1 = s + kh - 1
    a_w = 2 * shi1 * s * cin * itemsize + acc_b
    b_w = fixed + 2 * shi1 * (kw - 1) * cin * itemsize
    if a_w * wo + b_w > budget_bytes:
        tile_wo = int((budget_bytes - b_w) // a_w)
        return 1, round8(tile_wo, wo)

    # Full width fits: grow the row tile.
    swi = s * wo + kw - 1
    a_h = 2 * s * swi * cin * itemsize + wo * acc_b
    b_h = fixed + 2 * (kh - 1) * swi * cin * itemsize
    tile_ho = int((budget_bytes - b_h) // a_h)
    return round8(tile_ho, ho), wo


def input_traffic_model(h: int, w: int, cin: int, kh: int, kw: int,
                        stride: int, itemsize: int,
                        tile_ho: int | None = None,
                        tile_wo: int | None = None,
                        bcout: int = 128) -> dict[str, float]:
    """Per-image input HBM bytes of the DMA kernel vs the PR-1 host gather.

    ``dma_bytes`` is what the zero-copy kernel moves: every halo'd tile
    window read once straight out of the HBM-resident image (one image
    read plus the ``k−1`` seam rows/cols).  ``gather_bytes`` is what the
    deleted host-side gather paid whenever more than one row tile was
    needed: read the image, write the halo'd row-tile tensor, read it back
    in the kernel.  ``saved_bytes`` is the reclaimed bandwidth.
    """
    s = max(stride, 1)
    if tile_ho is None or tile_wo is None:
        a_ho, a_wo = choose_tiles(h, w, cin, kh, kw, s, itemsize, bcout)
        tile_ho = tile_ho or a_ho
        tile_wo = tile_wo or a_wo
    ho = max((h - kh) // s + 1, 1)
    wo = max((w - kw) // s + 1, 1)
    tile_ho = max(1, min(tile_ho, ho))
    tile_wo = max(1, min(tile_wo, wo))
    n_th, n_tw = -(-ho // tile_ho), -(-wo // tile_wo)
    tile_hi = s * (tile_ho - 1) + kh
    tile_wi = s * (tile_wo - 1) + kw
    image = h * w * cin * itemsize
    dma = n_th * n_tw * tile_hi * tile_wi * cin * itemsize
    # PR-1 path: stride-1 only, full-width row tiles; xt was materialized
    # (and re-read) whenever n_th > 1.
    xt = n_th * tile_hi * w * cin * itemsize
    gather = image + 2 * xt if n_th > 1 else xt
    return {"image_bytes": float(image), "dma_bytes": float(dma),
            "gather_bytes": float(gather),
            "saved_bytes": float(gather - dma),
            "tile_ho": tile_ho, "tile_wo": tile_wo}


def _kernel(x_hbm, w_ref, b_ref, o_ref, xs, sem, *, kh: int, kw: int,
            stride: int, n_th: int, n_tw: int, activation: str | None):
    tho, two, bcout = o_ref.shape
    cin = w_ref.shape[2]
    s = stride
    tile_hi = s * (tho - 1) + kh
    tile_wi = s * (two - 1) + kw
    swi = xs.shape[2]
    bb, th, tw, co = (pl.program_id(i) for i in range(4))
    step = (bb * n_th + th) * n_tw + tw
    n_steps = pl.num_programs(0) * n_th * n_tw

    def dma(step_idx, slot):
        b2 = step_idx // (n_th * n_tw)
        r = step_idx % (n_th * n_tw)
        return pltpu.make_async_copy(
            x_hbm.at[b2, pl.ds((r // n_tw) * tho * s, tile_hi),
                     pl.ds((r % n_tw) * two * s, tile_wi), :],
            xs.at[slot, pl.ds(0, tile_hi), pl.ds(0, tile_wi), :],
            sem.at[slot])

    @pl.when((step == 0) & (co == 0))
    def _():                                   # pipeline prologue
        dma(0, 0).start()

    @pl.when((co == 0) & (step + 1 < n_steps))
    def _():                                   # prefetch next tile window
        dma(step + 1, (step + 1) % 2).start()

    @pl.when(co == 0)
    def _():                                   # await this step's window
        dma(step, step % 2).wait()

    acc = jnp.zeros((tho * two, bcout), jnp.float32)
    for u in range(kh):
        for v in range(kw):
            # Phase selection: slice the dense window, then keep phase 0 of
            # each stride-s group via reshape-and-index (no strided loads;
            # garbage beyond the DMA'd region lands only in dropped phases).
            blk = xs[step % 2, pl.ds(u, s * tho)]        # (s·tho, swi, Cin)
            rows = blk.reshape(tho, s, swi, cin)[:, 0]   # (tho, swi, Cin)
            xsel = rows[:, v:v + s * two]                # (tho, s·two, Cin)
            xsel = xsel.reshape(tho, two, s, cin)[:, :, 0]
            acc = acc + jnp.dot(
                xsel.reshape(tho * two, cin).astype(jnp.float32),
                w_ref[u, v].astype(jnp.float32),
                preferred_element_type=jnp.float32)
    acc = acc + b_ref[0].astype(jnp.float32)             # (bCout,) broadcast
    # fused epilogue: σ_j on the fp32 accumulator, shared with the oracle
    acc = apply_activation(acc, activation)
    o_ref[...] = acc.reshape(tho, two, bcout).astype(o_ref.dtype)


def merged_conv(x, w, b=None, *, stride: int = 1, bcout: int = 128,
                tile_ho: int | None = None, tile_wo: int | None = None,
                activation: str | None = None, interpret: bool = False):
    """x: (N, H, W, Cin); w: (kh, kw, Cin, Cout) → (N, Ho, Wo, Cout).

    VALID convolution with ``stride`` on both spatial axes.  ``tile_ho`` /
    ``tile_wo`` are the output tile dims (default: the 2-D VMEM planner);
    ``b``/``activation`` fuse the segment epilogue.
    """
    n, h, wdt, cin = x.shape
    kh, kw, _, cout = w.shape
    s = stride
    assert s >= 1 and h >= kh and wdt >= kw, (x.shape, w.shape, s)
    ho = (h - kh) // s + 1
    wo = (wdt - kw) // s + 1
    bcout = min(bcout, cout)
    assert cout % bcout == 0, "pad channels at the ops layer"
    if tile_ho is None or tile_wo is None:
        a_ho, a_wo = choose_tiles(h, wdt, cin, kh, kw, s, x.dtype.itemsize,
                                  bcout)
        tile_ho = a_ho if tile_ho is None else tile_ho
        tile_wo = a_wo if tile_wo is None else tile_wo
    tile_ho = max(1, min(tile_ho, ho))
    tile_wo = max(1, min(tile_wo, wo))
    n_th, n_tw = -(-ho // tile_ho), -(-wo // tile_wo)
    ho_p, wo_p = n_th * tile_ho, n_tw * tile_wo
    tile_hi = s * (tile_ho - 1) + kh
    tile_wi = s * (tile_wo - 1) + kw
    # Scratch is padded so every tap's dense slice stays in bounds; the
    # DMA fills only the (tile_hi, tile_wi) window, and elements beyond it
    # are never selected (they fall in dropped stride phases).
    shi = s * tile_ho + kh - 1
    swi = s * tile_wo + kw - 1

    # Ragged last tiles: zero-pad the image so every DMA window is full
    # (static copy sizes); the garbage output rows/cols are sliced off.
    # Unlike the deleted gather this touches HBM only when ragged.
    pad_h = max(0, (n_th - 1) * tile_ho * s + tile_hi - h)
    pad_w = max(0, (n_tw - 1) * tile_wo * s + tile_wi - wdt)
    if pad_h or pad_w:
        x = jnp.pad(x, ((0, 0), (0, pad_h), (0, pad_w), (0, 0)))

    bias = jnp.zeros((1, cout), x.dtype) if b is None else b.reshape(1, cout)

    grid = (n, n_th, n_tw, cout // bcout)
    out = pl.pallas_call(
        functools.partial(_kernel, kh=kh, kw=kw, stride=s, n_th=n_th,
                          n_tw=n_tw, activation=activation),
        grid=grid,
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.ANY),     # HBM-resident image
            pl.BlockSpec((kh, kw, cin, bcout),
                         lambda bb, th, tw, co: (0, 0, 0, co)),
            pl.BlockSpec((1, bcout), lambda bb, th, tw, co: (0, co)),
        ],
        out_specs=pl.BlockSpec((None, tile_ho, tile_wo, bcout),
                               lambda bb, th, tw, co: (bb, th, tw, co)),
        out_shape=jax.ShapeDtypeStruct((n, ho_p, wo_p, cout), x.dtype),
        scratch_shapes=[pltpu.VMEM((2, shi, swi, cin), x.dtype),
                        pltpu.SemaphoreType.DMA((2,))],
        interpret=interpret,
    )(x, w, bias)
    return out[:, :ho, :wo] if (ho_p, wo_p) != (ho, wo) else out
