"""Plan-aware CNNs in pure JAX — the paper-faithful substrate.

A network is a flat chain of 1-indexed :class:`ConvSpec` units plus skip
annotations.  The same definition can be *applied* three ways:

* original            — ``apply_replaced(net, params, x, identity_plan)``;
* replaced (pruned, unmerged) — ``apply_replaced(net, params, x, plan)``:
  activations outside ``A`` are dropped, convs outside ``C`` become the
  identity, padding is re-ordered to the front of every merged group
  (paper Appendix A), GroupNorms are moved to group ends;
* merged              — ``CNNHost.lower_plan(plan, params)`` folds every
  segment into a single convolution (Eq. 1 composition via
  :func:`merge_segment`: BN folding, skip-add Dirac fusion) and lowers
  the result to a :class:`repro.runtime.ir.UnitGraph` that the shared
  executor (:mod:`repro.runtime.executor`) runs.

``apply_replaced(plan)`` and the executed merged graph are *exactly
equal* (same function, same floats up to accumulation order) — asserted
by ``tests/test_merge.py`` and ``tests/test_runtime.py``; this equality
is the cornerstone of the paper's method.

Skip blocks may carry a projection shortcut (ResNet downsample blocks);
those blocks cannot be Dirac-fused, so spans may only sit *inside* them.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.core import merge as M
from repro.core.plan import CompressionPlan, LayerDesc, Segment, identity_plan


# ---------------------------------------------------------------------------
# Specs
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ConvSpec:
    cin: int
    cout: int
    k: int = 3
    stride: int = 1
    depthwise: bool = False
    act: str = "relu"            # 'relu' | 'relu6' | 'silu' | 'none'
    norm: str | None = None      # None | 'bn' (frozen, foldable) | 'gn'
    gn_groups: int = 8
    bias: bool = True
    kind: str = "conv"           # 'conv' | 'pool' (avg) | 'upsample' | 'attn'

    @property
    def shape_preserving(self) -> bool:
        return (self.kind == "conv" and self.stride == 1
                and self.cin == self.cout)


@dataclasses.dataclass(frozen=True)
class SkipSpec:
    kind: str                    # 'add' | 'concat'
    start: int                   # boundary position (block = layers start+1..end)
    end: int
    proj: bool = False           # 1x1 projection shortcut (stride = block stride)


@dataclasses.dataclass(frozen=True)
class ConvNet:
    specs: tuple[ConvSpec, ...]
    skips: tuple[SkipSpec, ...] = ()
    in_hw: int = 32
    in_ch: int = 3
    head: str = "classifier"     # 'classifier' | 'none'
    num_classes: int = 10
    act_after_merge: bool = False   # paper's MobileNetV2 trick (Appendix A)

    @property
    def L(self) -> int:
        return len(self.specs)

    def spec(self, l: int) -> ConvSpec:
        return self.specs[l - 1]

    # -- compressibility metadata -------------------------------------------
    def irreducible(self) -> tuple[int, ...]:
        """R — layers whose input/output shapes differ (plus non-convs)."""
        return tuple(l for l in range(1, self.L + 1)
                     if not self.spec(l).shape_preserving)

    def layer_descs(self, params=None) -> list[LayerDesc]:
        descs = []
        for l in range(1, self.L + 1):
            s = self.spec(l)
            w = (params or {}).get("layers", [{}] * self.L)[l - 1].get("w") \
                if params else None
            val = float(jnp.sum(jnp.abs(w))) if w is not None else 0.0
            descs.append(LayerDesc(
                index=l, kind="dwconv" if s.depthwise else s.kind,
                growth=(s.k - 1) if s.kind == "conv" else 0,
                value=val,
                prunable=s.shape_preserving,
                linearizable=(s.kind == "conv"),
                meta={"stride": s.stride, "k": s.k},
            ))
        return descs

    def allowed_span(self, i: int, j: int) -> bool:
        """Span predicate: skip-block consistency + barrier units
        (pool/upsample/attn) must not sit strictly inside.

        Strided interiors are *allowed*: the paper's Appendix A ban (don't
        merge a strided conv with a following k>1 conv) guarded against a
        kernel blow-up the old stride-1 Pallas fast path could not execute.
        The merged-conv kernel now runs strided segments on the MXU and the
        enumerator's stride-aware growth keeps the k coordinate exact, so
        the blow-up is a latency trade the DP prices from the table instead
        of a hard ban.
        """
        if j - i > 1:
            for l in range(i + 1, j + 1):
                if self.spec(l).kind != "conv":
                    return False
        for sk in self.skips:
            inter = max(0, min(j, sk.end) - max(i, sk.start))
            if inter == 0:
                continue
            inside = (sk.start <= i and j <= sk.end)
            whole_block = (i <= sk.start and sk.end <= j)
            if sk.kind == "concat" or sk.proj:
                # never merge across (or Dirac-fuse) these blocks
                if not inside:
                    return False
            else:  # plain skip-add
                if not (whole_block or inside):
                    return False
                if whole_block:
                    # Dirac fusion needs stride-1, odd kernels in the block
                    for l in range(sk.start + 1, sk.end + 1):
                        sl = self.spec(l)
                        if sl.stride > 1 or sl.k % 2 == 0 or sl.kind != "conv":
                            return False
        return True

    # -- shape inference ------------------------------------------------------
    def boundary_shapes(self) -> list[tuple[int, int, int]]:
        """(h, w, c) at every boundary position 0..L (post-concat)."""
        shapes = [(self.in_hw, self.in_hw, self.in_ch)]
        h = w = self.in_hw
        c = self.in_ch
        concat_at = {sk.end: sk.start for sk in self.skips
                     if sk.kind == "concat"}
        for l in range(1, self.L + 1):
            s = self.spec(l)
            if s.kind == "conv":
                h, w = -(-h // s.stride), -(-w // s.stride)
                c = s.cout
            elif s.kind == "pool":
                h, w = -(-h // s.stride), -(-w // s.stride)
            elif s.kind == "upsample":
                h, w = h * s.stride, w * s.stride
            if l in concat_at:
                c += shapes[concat_at[l]][2]
            shapes.append((h, w, c))
        return shapes


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------

def init_params(net: ConvNet, key: jax.Array, dtype=jnp.float32):
    params = []
    keys = jax.random.split(key, net.L + len(net.skips) + 2)
    shapes = net.boundary_shapes()
    for l in range(1, net.L + 1):
        s = net.spec(l)
        p = {}
        if s.kind == "conv":
            cin_eff = shapes[l - 1][2]
            if s.depthwise:
                wshape = (s.k, s.k, 1, s.cout)
                fan_in = s.k * s.k
            else:
                wshape = (s.k, s.k, cin_eff, s.cout)
                fan_in = s.k * s.k * cin_eff
            w = jax.random.normal(keys[l], wshape, dtype) * math.sqrt(2.0 / fan_in)
            p["w"] = w
            if s.bias:
                p["b"] = jnp.zeros((s.cout,), dtype)
            if s.norm == "bn":
                p["bn"] = {"gamma": jnp.ones((s.cout,), dtype),
                           "beta": jnp.zeros((s.cout,), dtype),
                           "mean": jnp.zeros((s.cout,), dtype),
                           "var": jnp.ones((s.cout,), dtype)}
            elif s.norm == "gn":
                p["gn"] = {"gamma": jnp.ones((s.cout,), dtype),
                           "beta": jnp.zeros((s.cout,), dtype)}
        elif s.kind == "attn":
            c = shapes[l - 1][2]
            sub = jax.random.split(keys[l], 4)
            p = {n: jax.random.normal(kk, (c, c), dtype) / math.sqrt(c)
                 for n, kk in zip(("wq", "wk", "wv", "wo"), sub)}
        params.append(p)
    skip_params = []
    for idx, sk in enumerate(net.skips):
        if sk.proj:
            cin = shapes[sk.start][2]
            cout = shapes[sk.end][2]
            stride = 1
            for l in range(sk.start + 1, sk.end + 1):
                stride *= net.spec(l).stride
            w = jax.random.normal(keys[net.L + 1 + idx], (1, 1, cin, cout),
                                  dtype) * math.sqrt(2.0 / cin)
            skip_params.append({"w": w, "b": jnp.zeros((cout,), dtype)})
        else:
            skip_params.append({})
    head = {}
    if net.head == "classifier":
        c_final = shapes[-1][2]
        head["w"] = jax.random.normal(keys[0], (c_final, net.num_classes),
                                      dtype) * math.sqrt(1.0 / c_final)
        head["b"] = jnp.zeros((net.num_classes,), dtype)
    return {"layers": params, "skips": skip_params, "head": head}


# ---------------------------------------------------------------------------
# Primitive application
# ---------------------------------------------------------------------------

def _act(x, name):
    if name == "relu":
        return jax.nn.relu(x)
    if name == "relu6":
        return jnp.clip(x, 0.0, 6.0)
    if name == "silu":
        return jax.nn.silu(x)
    return x


def _conv(x, w, stride, depthwise, padding="VALID"):
    groups = w.shape[-1] if depthwise else 1
    return lax.conv_general_dilated(
        x, w, window_strides=(stride, stride), padding=padding,
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
        feature_group_count=groups)


def _frozen_bn(x, bn, eps=1e-5):
    scale = bn["gamma"] / jnp.sqrt(bn["var"] + eps)
    return x * scale + (bn["beta"] - bn["mean"] * scale)


def _gn(x, gn, groups, eps=1e-5):
    n, h, w, c = x.shape
    g = math.gcd(groups, c)
    xg = x.reshape(n, h, w, g, c // g)
    mu = xg.mean(axis=(1, 2, 4), keepdims=True)
    var = xg.var(axis=(1, 2, 4), keepdims=True)
    xg = (xg - mu) / jnp.sqrt(var + eps)
    return xg.reshape(n, h, w, c) * gn["gamma"] + gn["beta"]


def _folded_wb(spec: ConvSpec, p):
    """Conv weight/bias with frozen BN folded in (exact at inference)."""
    w = p["w"]
    b = p.get("b")
    if "bn" in p:
        bn = p["bn"]
        w, b = M.fold_batchnorm(w, b, bn["gamma"], bn["beta"], bn["mean"],
                                bn["var"])
    return w, (jnp.zeros((spec.cout,), w.dtype) if b is None else b)


def _center_crop_to(src, like):
    """Center-crop ``src`` spatially to the shape of ``like`` (Dirac tap)."""
    dh = src.shape[1] - like.shape[1]
    dw = src.shape[2] - like.shape[2]
    if dh == 0 and dw == 0:
        return src
    assert dh >= 0 and dw >= 0 and dh % 2 == 0 and dw % 2 == 0, (
        src.shape, like.shape)
    return src[:, dh // 2: src.shape[1] - dh // 2,
               dw // 2: src.shape[2] - dw // 2, :]


def segment_geometry(net: ConvNet, seg: Segment) -> tuple[int, int]:
    """(merged kernel size, merged stride) of a segment under its kept set."""
    K, S = 1, 1
    kept = set(seg.kept)
    for l in seg.layers:
        s = net.spec(l)
        if s.kind != "conv":
            continue
        k_eff = s.k if l in kept else 1
        K = K + (k_eff - 1) * S
        S *= s.stride
    return K, S


def _skip_stride(net: ConvNet, sk: SkipSpec) -> int:
    s = 1
    for l in range(sk.start + 1, sk.end + 1):
        if net.spec(l).kind in ("conv", "pool"):
            s *= net.spec(l).stride
        elif net.spec(l).kind == "upsample":
            s //= net.spec(l).stride
    return s


def _apply_proj(saved, skp, stride):
    return _conv(saved, skp["w"], stride, False, padding="SAME") + skp["b"]


def _segment_gn(net: ConvNet, layers, seg: Segment):
    """GN moved to segment end (paper Appendix A): the last kept conv's GN
    whose channel count matches the segment output; None otherwise."""
    kept = set(seg.kept)
    out_c = None
    for l in reversed(seg.layers):
        s = net.spec(l)
        if l in kept and s.kind == "conv":
            out_c = s.cout
            break
    if out_c is None:
        return None, 8
    for l in reversed(seg.layers):
        s = net.spec(l)
        if l in kept and s.kind == "conv" and "gn" in layers[l - 1] \
                and s.cout == out_c:
            return layers[l - 1]["gn"], s.gn_groups
        if l in kept and s.kind == "conv" and s.cout != out_c:
            break
    return None, 8


# ---------------------------------------------------------------------------
# Replaced (pruned, unmerged) forward
# ---------------------------------------------------------------------------

def apply_replaced(net: ConvNet, params, x, plan: CompressionPlan | None = None):
    """Forward pass of the pruned-but-unmerged network under ``plan``."""
    if plan is None:
        plan = identity_plan(net.L, net.layer_descs())
    layers = params["layers"]
    add_end = {sk.end: (sk.start, i) for i, sk in enumerate(net.skips)
               if sk.kind == "add"}
    cat_end = {sk.end: sk.start for i, sk in enumerate(net.skips)
               if sk.kind == "concat"}
    need_save = {sk.start for sk in net.skips}

    saved: dict[int, jax.Array] = {}     # true boundary values (post-act)
    if 0 in need_save:
        saved[0] = x

    for seg in plan.segments:
        Km, _ = segment_geometry(net, seg)
        lo = (Km - 1) // 2
        hi = Km - 1 - lo
        if Km > 1:
            x = jnp.pad(x, ((0, 0), (lo, hi), (lo, hi), (0, 0)))
        local: dict[int, jax.Array] = {seg.i: x}   # halo'd in-segment values
        kept = set(seg.kept)
        gn, gn_groups = _segment_gn(net, layers, seg)
        for l in seg.layers:
            s = net.spec(l)
            p = layers[l - 1]
            if s.kind == "conv":
                if l in kept:
                    w, b = _folded_wb(s, p)
                    x = _conv(x, w, s.stride, s.depthwise) + b
            elif s.kind == "pool":
                x = lax.reduce_window(
                    x, 0.0, lax.add, (1, s.k, s.k, 1),
                    (1, s.stride, s.stride, 1), "SAME") / (s.k * s.k)
            elif s.kind == "upsample":
                n, h, w_, c = x.shape
                x = jax.image.resize(x, (n, h * s.stride, w_ * s.stride, c),
                                     "nearest")
            elif s.kind == "attn":
                x = _tiny_self_attention(x, p)
            if l in add_end:
                src, ski = add_end[l]
                sk = net.skips[ski]
                if sk.proj:
                    # proj blocks are never Dirac-fused: src is always a true
                    # segment boundary (allowed_span guarantees it)
                    base = _apply_proj(saved[src], params["skips"][ski],
                                       _skip_stride(net, sk))
                else:
                    base = local[src] if src >= seg.i else saved[src]
                x = x + _center_crop_to(base, x)
            if l in cat_end:
                x = jnp.concatenate([x, saved[cat_end[l]]], axis=-1)
            local[l] = x
        if gn is not None:
            x = _gn(x, gn, gn_groups)
        # boundary activation σ_j (σ_L is the identity, paper §2)
        if seg.j < net.L:
            bspec = net.spec(seg.j)
            act = bspec.act
            if (net.act_after_merge and not seg.original
                    and bspec.kind == "conv" and act == "none"):
                act = "relu6"
            x = _act(x, act)
        if seg.j in need_save:
            saved[seg.j] = x
    return _apply_head(net, params, x)


def _tiny_self_attention(x, p):
    """Single-head self-attention over spatial positions (DDPM barrier)."""
    n, h, w, c = x.shape
    t = x.reshape(n, h * w, c)
    q = t @ p["wq"]
    k = t @ p["wk"]
    v = t @ p["wv"]
    a = jax.nn.softmax(q @ jnp.swapaxes(k, -1, -2) / math.sqrt(c), axis=-1)
    return (t + (a @ v) @ p["wo"]).reshape(n, h, w, c)


def _apply_head(net: ConvNet, params, x):
    if net.head == "classifier":
        x = x.mean(axis=(1, 2))
        return x @ params["head"]["w"] + params["head"]["b"]
    return x


# ---------------------------------------------------------------------------
# Merge (Algorithm 2 final step)
# ---------------------------------------------------------------------------
# A merged network is no longer applied here: ``CNNHost.lower_plan``
# lowers a plan into the shared unit IR (repro.runtime.ir) using
# :func:`merge_segment` below, and repro.runtime.executor runs it.

def merge_segment(net: ConvNet, layers_params, seg: Segment):
    """Fold one segment into a single conv: returns (w, b, stride, dw)."""
    kept = set(seg.kept)
    add_blocks = {sk.start: sk.end for sk in net.skips
                  if sk.kind == "add" and not sk.proj}

    def compose(acc, w, b, stride, dw):
        if acc is None:
            return (w, b, stride, dw)
        w_a, b_a, s_a, dw_a = acc
        w_m, dw_m = M.merge_conv_pair(w_a, w, stride1=s_a, dw1=dw_a, dw2=dw)
        b_m = M.merge_bias_through(w, b_a, b, dw2=dw)
        return (w_m, b_m, s_a * stride, dw_m)

    def chain(lo: int, hi: int, as_branch: bool = False):
        acc = None
        l = lo + 1
        while l <= hi:
            blk_end = add_blocks.get(l - 1)
            # fuse a complete block inside (lo, hi]; when this call IS the
            # block's own branch ((lo,hi) == (start,end)), compose plainly
            if blk_end is not None and blk_end <= hi and l - 1 >= lo \
                    and not (as_branch and l - 1 == lo and blk_end == hi):
                wb, bb, sb, dwb = chain(l - 1, blk_end, as_branch=True)
                assert sb == 1, "Dirac fusion requires stride-1 block"
                wb = M.fuse_skip_add(wb, depthwise=dwb)
                acc = compose(acc, wb, bb, 1, dwb)
                l = blk_end + 1
                continue
            s = net.spec(l)
            assert s.kind == "conv", f"cannot merge unit kind {s.kind}"
            if l in kept:
                w, b = _folded_wb(s, layers_params[l - 1])
                acc = compose(acc, w, b, s.stride, s.depthwise)
            l += 1
        if acc is None:   # fully pruned segment — identity conv
            c = net.boundary_shapes()[lo][2]
            w0 = M.identity_kernel(c)
            return (w0, jnp.zeros((c,), w0.dtype), 1, True)
        return acc

    return chain(seg.i, seg.j)
