"""Content-addressed on-disk cache for the ``T``/``I`` lookup tables.

Table construction is the pipeline's dominant offline cost, and its inputs
are fully content-addressable: the host (network structure + shapes +
parameter bytes + probe workload), the latency oracle configuration, the
table method, and the importance mode.  A build keyed by the digest of all
of those can therefore be reused verbatim — repeated ``compress()`` calls
at different budgets, benchmark reruns, and sweep restarts become
incremental instead of rebuilding ``O(L² K₀)`` entries from scratch.

Keys
----
``cache_key`` hashes together:

* the **host fingerprint** (``host.fingerprint()`` — structure, boundary
  shapes, probe workload, parameter digest, and for wall-clock builds the
  machine identity, since measured latencies do not transfer);
* the **oracle config** (class name + dataclass fields);
* the **method** and the **importance token** (``"magnitude"``, or
  ``ImportanceSpec.cache_token`` — measured-importance specs close over
  arbitrary callables/data, so they are only cacheable when the caller
  names the workload explicitly);
* a format version, so stale layouts miss instead of mis-parse.

Returns ``None`` (caching disabled) whenever any component is not
content-addressable.  Entries publish atomically via the checkpoint
package's tmp-then-rename contract, so a crashed build never leaves a
half-written table behind.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import os

import jax
import numpy as np

FORMAT_VERSION = 1


def pytree_digest(tree) -> str:
    """sha256 over every leaf's path, dtype, shape, and raw bytes."""
    h = hashlib.sha256()
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    for path, leaf in flat:
        arr = np.asarray(jax.device_get(leaf))
        h.update(jax.tree_util.keystr(path).encode())
        h.update(str(arr.dtype).encode())
        h.update(str(arr.shape).encode())
        h.update(np.ascontiguousarray(arr).tobytes())
    return h.hexdigest()


def machine_token() -> str:
    """Identity of the timing host — wall-clock tables do not transfer."""
    import platform

    dev = jax.devices()[0]
    return "|".join((platform.machine(), jax.default_backend(),
                     str(getattr(dev, "device_kind", "?"))))


def oracle_token(oracle) -> str:
    cfg = dataclasses.asdict(oracle) if dataclasses.is_dataclass(oracle) \
        else {}
    return json.dumps({"cls": type(oracle).__name__, "cfg": cfg},
                      sort_keys=True)


def importance_token(importance) -> str | None:
    """Stable name of the importance workload, or None (not cacheable).

    For a measured :class:`~repro.core.importance.ImportanceSpec`, the
    user's ``cache_token`` only needs to name the non-addressable parts
    (loss/perf closures and their data); the hashable fine-tune
    hyperparameters are folded in here so changing ``steps``/``lr``/
    ``normalize_by_base`` under the same token misses instead of serving
    stale importances."""
    if isinstance(importance, str):
        return importance
    token = getattr(importance, "cache_token", None)
    if token is None:
        return None
    return "|".join((token, f"steps={importance.steps}",
                     f"lr={importance.lr!r}",
                     f"norm={importance.normalize_by_base}"))


def cache_key(host, oracle, method: str, importance, *,
              prune: bool = True, base_perf: float | None = None,
              engine: str = "batched") -> str | None:
    """Digest of every table-build input, or None when not addressable.

    ``engine`` is deliberately EXCLUDED: batched and sequential builds are
    certified to agree (tests/test_probe_engine.py), so either may serve a
    hit for the other.  ``prune`` and ``base_perf`` ARE included — both
    change the stored table contents.
    """
    fp_fn = getattr(host, "fingerprint", None)
    imp = importance_token(importance)
    if fp_fn is None or imp is None:
        return None
    h = hashlib.sha256()
    h.update(f"v{FORMAT_VERSION}".encode())
    h.update(fp_fn().encode())
    h.update(oracle_token(oracle).encode())
    h.update(method.encode())
    h.update(imp.encode())
    h.update(repr((bool(prune), base_perf)).encode())
    return h.hexdigest()


def _path(cache_dir: str, key: str) -> str:
    return os.path.join(cache_dir, f"tables_{key}.json")


def save(cache_dir: str, key: str, tables) -> str:
    """Atomically publish a built :class:`~repro.core.tables.Tables`."""
    from repro.checkpoint.ckpt import atomic_write_text

    payload = {
        "format": FORMAT_VERSION,
        "build_seconds_latency": tables.build_seconds_latency,
        "build_seconds_importance": tables.build_seconds_importance,
        "num_pruned": tables.num_pruned,
        "stats": tables.stats.as_dict() if tables.stats else None,
        "spans": [
            {"i": i, "j": j,
             "opts": [{"k": k, "imp": imp, "lat": lat, "kept": list(kept)}
                      for k, (imp, lat, kept) in sorted(row.items())]}
            for (i, j), row in sorted(tables.entries.items())
        ],
    }
    return atomic_write_text(_path(cache_dir, key), json.dumps(payload))


def load(cache_dir: str, key: str):
    """Cached :class:`~repro.core.tables.Tables`, or None on a miss."""
    from .probe_engine import EngineStats
    from .tables import Tables

    path = _path(cache_dir, key)
    if not os.path.exists(path):
        return None
    try:
        with open(path) as f:
            payload = json.load(f)
    except (OSError, json.JSONDecodeError):   # torn/corrupt entry: miss
        return None
    if payload.get("format") != FORMAT_VERSION:
        return None
    entries = {
        (sp["i"], sp["j"]): {
            o["k"]: (o["imp"], o["lat"], tuple(o["kept"]))
            for o in sp["opts"]}
        for sp in payload["spans"]
    }
    stats = EngineStats(**payload["stats"]) if payload.get("stats") \
        else EngineStats()
    stats.cache_hit = True
    return Tables(entries=entries,
                  build_seconds_latency=payload["build_seconds_latency"],
                  build_seconds_importance=payload[
                      "build_seconds_importance"],
                  num_pruned=payload["num_pruned"],
                  stats=stats)
