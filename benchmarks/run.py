"""Benchmark harness — one function per paper table/figure + kernels +
roofline.  Prints ``name,us_per_call,derived`` CSV and writes
results/bench.csv plus machine-readable results/BENCH_kernels.json
(name → µs + parsed derived fields) so the perf trajectory is trackable
across PRs.

  PYTHONPATH=src python -m benchmarks.run [--only fig1,table1,...]
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

sys.path.insert(0, os.path.dirname(__file__))


def _timeit(fn, n=10):
    import jax
    jax.block_until_ready(fn())
    t0 = time.perf_counter()
    for _ in range(n):
        jax.block_until_ready(fn())
    return (time.perf_counter() - t0) / n * 1e6


def bench_kernels():
    """Micro-bench each Pallas kernel's jnp path on this host + record the
    interpret-mode max|Δ| vs oracle (TPU wall-time needs real hardware)."""
    import jax
    import jax.numpy as jnp
    from repro import kernels

    rows = []
    key = jax.random.PRNGKey(0)
    ks = jax.random.split(key, 4)
    timeit = _timeit

    x = jax.random.normal(ks[0], (1024, 512))
    u = jax.random.normal(ks[1], (512, 256)) * 0.05
    v = jax.random.normal(ks[2], (256, 512)) * 0.05
    f = jax.jit(lambda: kernels.merged_ffn_ref(x, u, v))
    err = float(jnp.abs(kernels.merged_ffn_op(x, u, v, interpret=True)
                        - kernels.merged_ffn_ref(x, u, v)).max())
    rows.append(("kernel,merged_ffn_1024x512_r256", timeit(f),
                 f"interpret_maxdiff={err:.2e}"))

    q = jax.random.normal(ks[0], (2, 256, 4, 64))
    kk = jax.random.normal(ks[1], (2, 256, 4, 64))
    vv = jax.random.normal(ks[2], (2, 256, 4, 64))
    f = jax.jit(lambda: kernels.flash_attention_ref(q, kk, vv))
    err = float(jnp.abs(kernels.flash_attention_op(q, kk, vv, True, True)
                        - kernels.flash_attention_ref(q, kk, vv)).max())
    rows.append(("kernel,flash_attn_b2s256h4d64", timeit(f),
                 f"interpret_maxdiff={err:.2e}"))

    a = jax.random.uniform(ks[0], (4, 512, 256), minval=0.5, maxval=0.99)
    b = jax.random.normal(ks[1], (4, 512, 256)) * 0.1
    f = jax.jit(lambda: kernels.rglru_scan_ref(a, b))
    err = float(jnp.abs(kernels.rglru_scan_op(a, b, interpret=True)
                        - kernels.rglru_scan_ref(a, b)).max())
    rows.append(("kernel,rglru_scan_b4s512c256", timeit(f),
                 f"interpret_maxdiff={err:.2e}"))

    g = jax.random.normal(ks[3], (512,)) * 0.1
    f = jax.jit(lambda: kernels.rmsnorm_ref(x, g))
    err = float(jnp.abs(kernels.rmsnorm_op(x, g, interpret=True)
                        - kernels.rmsnorm_ref(x, g)).max())
    rows.append(("kernel,rmsnorm_1024x512", timeit(f),
                 f"interpret_maxdiff={err:.2e}"))

    xc = jax.random.normal(ks[0], (8, 20, 20, 32))
    wc = jax.random.normal(ks[1], (5, 5, 32, 32)) * 0.1
    f = jax.jit(lambda: kernels.merged_conv_ref(xc, wc))
    err = float(jnp.abs(kernels.merged_conv_op(xc, wc, interpret=True)
                        - kernels.merged_conv_ref(xc, wc)).max())
    rows.append(("kernel,merged_conv_k5_c32", timeit(f),
                 f"interpret_maxdiff={err:.2e}"))
    return rows


def bench_conv_sweep():
    """Stride × k × (tile_ho, tile_wo) sweep of the generalized merged conv.

    For each point: jnp-oracle wall time on this host, interpret-mode
    max|Δ| vs the oracle, and the input-HBM bytes the zero-copy DMA halos
    reclaim over the deleted host-side gather (``halo_bytes_saved``).
    Depthwise rows (``conv_sweep_dw``) additionally time the jitted lax
    grouped conv the executor used to fall back to (``lax_us``) and
    record the v5e roofline's predicted speedup of the DMA-halo traffic
    model over the lax-gather one.  Delegates to the canonical sweeps in
    ``bench_dp`` so the two benches cannot drift; this wrapper only
    formats the CSV rows.
    """
    import numpy as np

    from bench_dp import conv_tile_sweep, depthwise_tile_sweep

    rows = []
    for r in conv_tile_sweep(np.random.default_rng(7), ks=(3, 5, 7),
                             strides=(1, 2),
                             tiles=((8, None), (8, 16), (None, None))):
        rows.append((
            f"conv_sweep,s{r['stride']}_k{r['k']}_tile{r['tile_ho']}"
            f"x{r['tile_wo']}{'_auto' if r['auto'] else ''}",
            r["oracle_us"],
            f"halo_bytes_saved={r['halo_bytes_saved']:.0f};"
            f"dma_bytes={r['dma_bytes']:.0f};"
            f"interpret_maxdiff={r['maxdiff_vs_oracle']:.2e}"))
    for r in depthwise_tile_sweep(np.random.default_rng(7), ks=(3, 5),
                                  strides=(1, 2),
                                  tiles=((8, None), (None, None))):
        rows.append((
            f"conv_sweep_dw,s{r['stride']}_k{r['k']}_tile{r['tile_ho']}"
            f"x{r['tile_wo']}{'_auto' if r['auto'] else ''}",
            r["lax_us"],
            f"predicted_speedup_v5e={r['predicted_speedup_v5e']:.3f};"
            f"halo_bytes_saved={r['halo_bytes_saved']:.0f};"
            f"dma_bytes={r['dma_bytes']:.0f};"
            f"relayout_bytes={r['relayout_bytes']:.0f};"
            f"interpret_maxdiff={r['maxdiff_vs_oracle']:.2e}"))
    return rows


def bench_roofline():
    import roofline
    rows = []
    try:
        cells = roofline.load()
    except Exception as e:          # dry-run artifacts missing
        return [("roofline,missing", 0.0, str(e))]
    for r in sorted(cells, key=lambda r: (r["arch"], r["shape"])):
        rows.append((f"roofline,{r['arch']},{r['shape']}",
                     max(r["compute_s"], r["analytic_memory_s"],
                         r["collective_s"]) * 1e6,
                     f"dominant={r['dominant_tpu']};"
                     f"rf_tpu={r['roofline_fraction_tpu']:.3f};"
                     f"rf_hlo={r['roofline_fraction']:.3f};"
                     f"useful={r['useful_ratio']:.3f}"))
    return rows


def bench_dp_speed():
    """Paper claim: the DP itself completes within seconds on CPU."""
    import numpy as np
    from repro.core.dp import solve_dp
    rng = np.random.default_rng(0)
    rows = []
    for L, P in ((34, 1000), (53, 1000), (120, 2000)):
        table = {}
        for i in range(L):
            for j in range(i + 1, min(i + 12, L) + 1):
                table[(i, j)] = {k: (float(rng.random()),
                                     float(rng.integers(1, 30)), ())
                                 for k in range(1, 8)}
        fn = lambda i, j: table.get((i, j), {})
        t0 = time.perf_counter()
        res = solve_dp(L, fn, float(P), P)
        dt = time.perf_counter() - t0
        rows.append((f"dp,L{L}_P{P}", dt * 1e6,
                     f"objective={res.objective:.3f};entries={len(table)*7}"))
    return rows


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated benchmark names")
    args = ap.parse_args(argv)
    import tables
    benches = {
        "fig1": tables.fig1_kernel_growth,
        "table1": tables.table1_resnet34,
        "table23": tables.table23_mobilenetv2,
        "table45": tables.table45_ddpm,
        "table6": tables.table6_ablation,
        "table78": tables.table78_cost,
        "kernels": bench_kernels,
        "conv_sweep": bench_conv_sweep,
        "dp": bench_dp_speed,
        "roofline": bench_roofline,
    }
    picked = (args.only.split(",") if args.only else list(benches))
    all_rows = []
    print("name,us_per_call,derived")
    for name in picked:
        t0 = time.perf_counter()
        try:
            rows = benches[name]()
        except Exception as e:
            import traceback
            traceback.print_exc()
            rows = [(f"{name},ERROR", 0.0, repr(e)[:200])]
        for r in rows:
            print(f"{r[0]},{r[1]:.2f},{r[2]}", flush=True)
        all_rows.extend(rows)
        print(f"# {name} done in {time.perf_counter()-t0:.1f}s", flush=True)
    from repro.launch.distributed import publish_json, publish_text

    csv = "name,us_per_call,derived\n" + "".join(
        f"{r[0]},{r[1]:.2f},{r[2]}\n" for r in all_rows)
    publish_text("results/bench.csv", csv)
    publish_json("results/BENCH_kernels.json",
                 {r[0]: _json_row(r) for r in all_rows})


def _json_row(row):
    """(name, µs, derived) → {us_per_call, **parsed derived k=v fields}."""
    out = {"us_per_call": row[1]}
    for field in str(row[2]).split(";"):
        if "=" in field:
            k, v = field.split("=", 1)
            try:
                out[k] = float(v)
            except ValueError:
                out[k] = v
        elif field:
            out["derived"] = field
    return out


if __name__ == "__main__":
    main()
