# Convenience targets; everything pins JAX_PLATFORMS=cpu (see
# scripts/verify.sh for why).

PY := python
ENV := JAX_PLATFORMS=cpu PYTHONPATH=src

.PHONY: verify test bench bench-dp

verify:
	bash scripts/verify.sh

test:
	$(ENV) $(PY) -m pytest -x -q

bench:
	$(ENV) $(PY) -m benchmarks.run

bench-dp:
	$(ENV) $(PY) -m benchmarks.bench_dp
