"""Builders for the paper's CNN families (full + reduced variants).

``resnet34`` / ``mobilenetv2`` / ``ddpm_unet`` mirror the paper's networks
at full scale (used by the analytic latency tables and entry-count
benchmarks); the ``tiny_*`` variants keep the same *structure* (skip kinds,
strides, depthwise patterns, norms) at toy width/depth so that the measured
pipeline — importance fine-tuning, wall-clock latency tables, DP, merging —
runs on CPU in seconds.
"""
from __future__ import annotations

from .cnn import ConvNet, ConvSpec, SkipSpec


def _res_block(specs, skips, c, stride=1, cin=None, norm="bn"):
    cin = cin or c
    start = len(specs)
    specs.append(ConvSpec(cin, c, 3, stride, act="relu", norm=norm))
    specs.append(ConvSpec(c, c, 3, 1, act="relu", norm=norm))
    skips.append(SkipSpec("add", start, start + 2,
                          proj=(stride != 1 or cin != c)))


def resnet34(num_classes: int = 1000, in_hw: int = 224,
             width: int = 64, norm: str = "bn") -> ConvNet:
    specs: list[ConvSpec] = []
    skips: list[SkipSpec] = []
    w = width
    specs.append(ConvSpec(3, w, 7, 2, act="relu", norm=norm))      # stem
    specs.append(ConvSpec(w, w, 3, 2, kind="pool", act="none"))    # maxpool→avg
    for n, (c, s) in zip((3, 4, 6, 3),
                         ((w, 1), (2 * w, 2), (4 * w, 2), (8 * w, 2))):
        for b in range(n):
            _res_block(specs, skips, c, s if b == 0 else 1,
                       cin=None if b else specs[-1].cout, norm=norm)
    return ConvNet(tuple(specs), tuple(skips), in_hw=in_hw, in_ch=3,
                   head="classifier", num_classes=num_classes)


def tiny_resnet(num_classes: int = 10, in_hw: int = 16, width: int = 8,
                blocks=(2, 2), norm=None) -> ConvNet:
    specs: list[ConvSpec] = []
    skips: list[SkipSpec] = []
    w = width
    specs.append(ConvSpec(3, w, 3, 1, act="relu", norm=norm))
    for stage, n in enumerate(blocks):
        c = w * (2 ** stage)
        for b in range(n):
            stride = 2 if (stage > 0 and b == 0) else 1
            _res_block(specs, skips, c, stride,
                       cin=specs[-1].cout, norm=norm)
    return ConvNet(tuple(specs), tuple(skips), in_hw=in_hw, in_ch=3,
                   head="classifier", num_classes=num_classes)


def _inverted_residual(specs, skips, cin, cout, stride, expand, norm="bn"):
    mid = cin * expand
    start = len(specs)
    if expand != 1:
        specs.append(ConvSpec(cin, mid, 1, 1, act="relu6", norm=norm))
    specs.append(ConvSpec(mid, mid, 3, stride, depthwise=True, act="relu6",
                          norm=norm))
    specs.append(ConvSpec(mid, cout, 1, 1, act="none", norm=norm))
    if stride == 1 and cin == cout:
        skips.append(SkipSpec("add", start, len(specs)))


def mobilenetv2(num_classes: int = 1000, in_hw: int = 224,
                width_mult: float = 1.0, norm: str = "bn") -> ConvNet:
    def c(ch):
        return max(8, int(ch * width_mult + 4) // 8 * 8)
    cfg = [  # t, c, n, s  (paper table)
        (1, 16, 1, 1), (6, 24, 2, 2), (6, 32, 3, 2), (6, 64, 4, 2),
        (6, 96, 3, 1), (6, 160, 3, 2), (6, 320, 1, 1)]
    specs: list[ConvSpec] = []
    skips: list[SkipSpec] = []
    specs.append(ConvSpec(3, c(32), 3, 2, act="relu6", norm=norm))
    cin = c(32)
    for t, ch, n, s in cfg:
        for b in range(n):
            _inverted_residual(specs, skips, cin, c(ch), s if b == 0 else 1,
                               t, norm=norm)
            cin = c(ch)
    specs.append(ConvSpec(cin, c(1280), 1, 1, act="relu6", norm=norm))
    return ConvNet(tuple(specs), tuple(skips), in_hw=in_hw, in_ch=3,
                   head="classifier", num_classes=num_classes,
                   act_after_merge=True)


def tiny_mobilenet(num_classes: int = 10, in_hw: int = 16, width: int = 8,
                   norm=None) -> ConvNet:
    specs: list[ConvSpec] = []
    skips: list[SkipSpec] = []
    specs.append(ConvSpec(3, width, 3, 1, act="relu6", norm=norm))
    cin = width
    for t, ch, n, s in [(2, width, 2, 1), (2, 2 * width, 2, 2)]:
        for b in range(n):
            _inverted_residual(specs, skips, cin, ch, s if b == 0 else 1, t,
                               norm=norm)
            cin = ch
    return ConvNet(tuple(specs), tuple(skips), in_hw=in_hw, in_ch=3,
                   head="classifier", num_classes=num_classes,
                   act_after_merge=True)


def ddpm_unet(in_hw: int = 32, base: int = 128) -> ConvNet:
    """DDPM-shaped UNet chain: down/up with skip-concat, GN, attn barrier."""
    return _unet(in_hw, base, depth=2, blocks=2, norm="gn", attn=True)


def tiny_unet(in_hw: int = 16, base: int = 8, norm="gn", attn=True) -> ConvNet:
    return _unet(in_hw, base, depth=1, blocks=2, norm=norm, attn=attn)


def _unet(in_hw, base, depth, blocks, norm, attn) -> ConvNet:
    specs: list[ConvSpec] = []
    skips: list[SkipSpec] = []
    enc_boundaries: list[tuple[int, int]] = []  # (boundary, channels)
    specs.append(ConvSpec(4, base, 3, 1, act="silu", norm=norm))  # img + t chan
    c = base
    # encoder
    for d in range(depth):
        for _ in range(blocks):
            specs.append(ConvSpec(c, c, 3, 1, act="silu", norm=norm))
        enc_boundaries.append((len(specs), c))
        specs.append(ConvSpec(c, 2 * c, 3, 2, act="silu", norm=norm))
        c = 2 * c
    # middle (+ attention barrier, as in DDPM at 16×16)
    specs.append(ConvSpec(c, c, 3, 1, act="silu", norm=norm))
    if attn:
        specs.append(ConvSpec(c, c, 1, 1, kind="attn", act="none"))
    specs.append(ConvSpec(c, c, 3, 1, act="silu", norm=norm))
    # decoder
    for d in reversed(range(depth)):
        specs.append(ConvSpec(c, c, 2, 2, kind="upsample", act="none"))
        src, src_c = enc_boundaries[d]
        skips.append(SkipSpec("concat", src, len(specs)))
        specs.append(ConvSpec(c + src_c, c // 2, 3, 1, act="silu", norm=norm))
        c = c // 2
        for _ in range(blocks - 1):
            specs.append(ConvSpec(c, c, 3, 1, act="silu", norm=norm))
    specs.append(ConvSpec(c, 3, 3, 1, act="none", norm=None))  # out conv
    return ConvNet(tuple(specs), tuple(skips), in_hw=in_hw, in_ch=4,
                   head="none")
