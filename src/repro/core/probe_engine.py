"""Batched, device-parallel probe engine for ``T[i,j,k]`` / ``I[i,j,k]``.

The paper's dominant offline cost is table construction: every latency
probe and every fine-tune probe is independent ("embarrassingly parallel",
§3.2), yet a naive builder walks all ``O(L² K₀)`` entries one at a time —
one XLA compile + one warmup/timing loop per latency entry and one scalar
Adam fine-tune per importance entry.  This module replaces that inner loop:

* **Latency bucketing** — a metadata-only pass enumerates all probes and
  buckets them by *shape signature* (``host.probe_signature(seg)``: for
  CNNs ``(h, w, cin, cout, K, stride, depthwise, …)``).  Latency depends on
  the signature only — never on the weight values — so one callable per
  bucket is compiled and timed and the result is attributed to every entry
  in the bucket, dropping compiles + timings from ``O(L² K₀)`` to
  ``O(#shape buckets)``.
* **Compile/timing overlap** — wall-clock bucket representatives are
  pre-compiled ahead of time on a single worker thread (a warm jit call;
  see :func:`_prepare_probe` for why not AOT ``lower().compile()``), so
  bucket ``b+1`` compiles while bucket ``b`` warms up; the timed loops
  run in a quiet window after the last compile retires.
* **Batched importance** — hosts that implement ``importance_batch`` hand
  the engine one shared ``apply_fn`` plus stacked candidate params (same
  pytree structure within a span bucket); the few-step Eq. 4 Adam
  fine-tune then runs **vmapped** over the probe axis (``pmap``-sharded
  across local devices when more than one is present).  Hosts without a
  batchable formulation fall back to the sequential per-probe path.

``engine="sequential"`` preserves the original entry-at-a-time walk as the
certified reference; ``tests/test_probe_engine.py`` asserts the batched
path is *bit-identical* to it under the analytic oracle and within
tolerance under :class:`~repro.core.latency.WallClockOracle`.
"""
from __future__ import annotations

import dataclasses
from concurrent.futures import ThreadPoolExecutor
from typing import Callable, Sequence

import jax

from .importance import (adam_finetune_batched, measure_importance,
                         perf_to_importance)
from .latency import LatencyOracle, WallClockOracle
from .plan import Segment

ENGINES = ("batched", "sequential")


@dataclasses.dataclass(frozen=True)
class ProbeCallable:
    """One batchable latency probe: a jittable ``fn`` plus example ``args``.

    Exposing the function and its arguments separately (instead of a
    zero-arg closure) is what lets the engine pre-compile the probe on a
    worker thread (and would equally support AOT
    ``jax.jit(fn).lower(*args).compile()`` — see :func:`_prepare_probe`
    for why the warm-call path is used instead).
    """

    fn: Callable
    args: tuple


@dataclasses.dataclass
class EngineStats:
    """Build accounting surfaced through :class:`repro.core.tables.Tables`."""

    engine: str = "batched"
    num_latency_probes: int = 0
    num_latency_buckets: int = 0
    num_compiles: int = 0            # XLA compiles issued (wall-clock path)
    num_timings: int = 0             # warmup/timing loops run
    num_importance_probes: int = 0
    num_importance_batches: int = 0  # vmapped fine-tune launches
    num_importance_sequential: int = 0
    cache_hit: bool = False

    def as_dict(self) -> dict:
        return dataclasses.asdict(self)


def _signature(host, seg: Segment):
    """Bucketing key for ``seg``; hosts without ``probe_signature`` get a
    unique key per entry (no batching win, but the engine still runs)."""
    sig_fn = getattr(host, "probe_signature", None)
    if sig_fn is None:
        return ("_unbucketed", seg.i, seg.j, seg.k, seg.kept)
    return sig_fn(seg)


def _prepare_probe(host, seg: Segment, params):
    """Build + pre-compile one bucket representative (worker-thread safe).

    Compilation goes through a warm jit call rather than AOT
    ``fn.lower(*args).compile()``: on current JAX the AOT executable does
    not share the jit dispatch cache (the first ``fn()`` call would
    compile a second time) and ``Compiled.__call__`` bypasses the C++
    dispatch fastpath, inflating sub-millisecond probes by ~2× relative
    to the sequential reference.  One warm call compiles the same
    executable once and leaves timing on the exact dispatch path the
    sequential engine uses.
    """
    probe_fn = getattr(host, "segment_probe", None)
    if probe_fn is None:
        call = host.segment_callable(seg, params)
    else:
        probe = probe_fn(seg, params)
        call = lambda: probe.fn(*probe.args)
    jax.block_until_ready(call())
    return call


def measure_latencies(
    host,
    segs: Sequence[Segment],
    oracle: LatencyOracle,
    params=None,
    *,
    engine: str = "batched",
    stats: EngineStats | None = None,
    progress: Callable[[str], None] | None = None,
) -> list[float]:
    """``T`` value for every segment in ``segs`` (order preserved).

    ``batched``: one oracle evaluation per distinct shape signature —
    analytic costs are computed once per bucket; wall-clock callables are
    compiled once per bucket (the next bucket pre-compiling on a worker
    thread while the current one warms up) and timed once per bucket in a
    quiet window after the last compile.
    ``sequential``: the certified reference — one evaluation per entry,
    byte-for-byte the pre-engine behavior.
    """
    if engine not in ENGINES:
        raise ValueError(f"unknown engine {engine!r}; expected {ENGINES}")
    stats = stats if stats is not None else EngineStats(engine=engine)
    stats.num_latency_probes += len(segs)
    wallclock = isinstance(oracle, WallClockOracle)

    if engine == "sequential":
        out = []
        for n, seg in enumerate(segs):
            if wallclock:
                out.append(oracle.time_callable(
                    host.segment_callable(seg, params)))
                stats.num_compiles += 1
                stats.num_timings += 1
                if progress and (n % 10 == 9 or n == len(segs) - 1):
                    progress(f"latency probe {n + 1}/{len(segs)}")
            else:
                out.append(oracle.segment_latency(host.segment_cost(seg)))
        stats.num_latency_buckets += len(segs)
        return out

    order: list = []                       # first-appearance bucket order
    buckets: dict = {}                     # sig -> representative Segment
    sigs = []
    for seg in segs:
        sig = _signature(host, seg)
        sigs.append(sig)
        if sig not in buckets:
            buckets[sig] = seg
            order.append(sig)
    stats.num_latency_buckets += len(order)

    per_bucket: dict = {}
    if not wallclock:
        for sig in order:
            per_bucket[sig] = oracle.segment_latency(
                host.segment_cost(buckets[sig]))
    else:
        # Overlap compilation with warmup: a single worker thread lowers
        # and compiles bucket representatives while the main thread warms
        # the already-compiled ones.  The *timed* loops only start once
        # the last compile has retired — warmup calls tolerate the CPU
        # contention of a concurrent XLA compile, timed calls do not (a
        # compile running beside the timing loop inflates cheap buckets
        # by integer factors).
        warmed = []
        with ThreadPoolExecutor(max_workers=1) as ex:
            futures = [(sig, ex.submit(_prepare_probe, host, buckets[sig],
                                       params)) for sig in order]
            for bi, (sig, fut) in enumerate(futures):
                call = fut.result()
                for _ in range(oracle.warmup):
                    jax.block_until_ready(call())
                warmed.append((sig, call))
                if progress:
                    progress(f"compiled+warmed bucket {bi + 1}/{len(order)}"
                             f" ({len(segs)} probes)")
        for sig, call in warmed:           # quiet window: compiles done
            per_bucket[sig] = oracle.time_callable(call, warmup=0)
        stats.num_compiles += len(order)
        stats.num_timings += len(order)
    return [per_bucket[sig] for sig in sigs]


def layer_latencies(
    host,
    oracle: LatencyOracle,
    params=None,
    *,
    engine: str = "batched",
    stats: EngineStats | None = None,
) -> list[float]:
    """Per-layer latency of the untouched network via one engine pass.

    Shared by ``original_latency`` and the layer-only knapsack so each
    layer is probed exactly once per call instead of once per caller.
    """
    segs = [Segment(i=l - 1, j=l, k=host.original_k(l), kept=(l,),
                    original=True)
            for l in range(1, len(host.descs()) + 1)]
    return measure_latencies(host, segs, oracle, params, engine=engine,
                             stats=stats)


# Single-device vmapped fine-tunes win only while probes are dispatch-
# bound: the shared all-kept graph pays real FLOPs for every Dirac
# stand-in that a scalar probe would simply skip, so once the per-step
# workload is compute-bound, batching buys nothing and costs the pruned
# layers' compute.  Above this many input elements per fine-tune step the
# engine prefers scalar probes unless local devices can shard the lanes.
DISPATCH_BOUND_ELEMS = 65536


def _batching_pays(spec) -> bool:
    if jax.local_device_count() > 1:
        return True                       # pmap shards lanes: parallel win
    try:
        first = spec.train_batches[0]
        elems = sum(getattr(leaf, "size", 0)
                    for leaf in jax.tree.leaves(first))
    except Exception:                     # unsized workload: assume tiny
        return True
    return elems <= DISPATCH_BOUND_ELEMS


def measure_importances(
    host,
    segs: Sequence[Segment],
    spec,
    base_perf: float,
    params=None,
    *,
    engine: str = "batched",
    stats: EngineStats | None = None,
    force_batching: bool | None = None,
    progress: Callable[[str], None] | None = None,
) -> list[float]:
    """Eq. 4 importance for every (non-original) segment in ``segs``.

    ``batched``: segments are grouped by span ``(i, j]`` and handed to
    ``host.importance_batch`` — if the host can express the whole span
    bucket as one shared ``apply_fn`` over stacked candidate params, the
    few-step Adam fine-tune runs vmapped (and pmap-sharded across local
    devices) over the probe axis; the tuned candidates are then unstacked
    and scored through the (jitted) ``perf_fn`` path.  Buckets the host
    declines — and, unless ``force_batching`` overrides the
    :func:`_batching_pays` heuristic, compute-bound single-device
    workloads — fall back to the sequential per-probe path.
    """
    from .tables import one_segment_plan   # local import: tables imports us

    if engine not in ENGINES:
        raise ValueError(f"unknown engine {engine!r}; expected {ENGINES}")
    stats = stats if stats is not None else EngineStats(engine=engine)
    stats.num_importance_probes += len(segs)
    out: list[float | None] = [None] * len(segs)

    def sequential(indices):
        for n in indices:
            seg = segs[n]
            apply_fn, p = host.replaced_apply(
                one_segment_plan(host, seg), params)
            out[n] = measure_importance(apply_fn, p, spec, base_perf)
            stats.num_importance_sequential += 1
            if progress:
                progress(f"importance probe ({seg.i},{seg.j}] k={seg.k}")

    batch_fn = getattr(host, "importance_batch", None)
    use_batches = force_batching if force_batching is not None \
        else _batching_pays(spec)
    if engine == "sequential" or batch_fn is None or not use_batches:
        sequential(range(len(segs)))
        return out

    groups: dict[tuple[int, int], list[int]] = {}
    for n, seg in enumerate(segs):
        groups.setdefault((seg.i, seg.j), []).append(n)
    for span, indices in groups.items():
        if len(indices) < 2:
            # A vmap of one lane only adds overhead over the scalar probe
            # (and the Dirac stand-ins cost real FLOPs) — not worth it.
            sequential(indices)
            continue
        batch = batch_fn([segs[n] for n in indices], params)
        if batch is None:
            sequential(indices)
            continue
        apply_fn, stacked, grad_mask = batch
        tuned = adam_finetune_batched(apply_fn, stacked, spec,
                                      grad_mask=grad_mask)
        stats.num_importance_batches += 1
        for lane, n in enumerate(indices):
            p_n = jax.tree.map(lambda x: x[lane], tuned)
            perf = spec.perf_fn(apply_fn, p_n, spec.eval_batches)
            out[n] = perf_to_importance(perf, base_perf, spec)
        if progress:
            progress(f"importance batch ({span[0]},{span[1]}]: "
                     f"{len(indices)} lanes vmapped")
    return out
