"""LayerMerge on a transformer — the paper's technique on the assigned
architectures (DESIGN §2.1 rank-merge).

Pre-trains a small smollm-family LM on synthetic text, runs LayerMerge /
Depth / LayerOnly at several latency budgets (analytic v5e oracle), fine-
tunes each plan, and prints a Pareto mini-table (the transformer analogue
of the paper's Tables 1–3).

Run:  PYTHONPATH=src python examples/compress_transformer.py
"""
import dataclasses

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.core import ImportanceSpec, compress, neg_loss_perf
from repro.core.importance import _adam_finetune
from repro.data.pipeline import SyntheticTokens
from repro.models import transformer as T
from repro.models.transformer_host import CostEnv, TransformerHost


def main():
    cfg = dataclasses.replace(
        get_config("smollm-135m"), name="smollm-mini", num_layers=6,
        d_model=96, num_heads=4, num_kv_heads=2, head_dim=24, d_ff=256,
        vocab_size=256, dtype="float32", remat=False)
    params, _ = T.init_model(cfg, jax.random.PRNGKey(0))
    data = SyntheticTokens(cfg.vocab_size, 16, 64, seed=0)
    batches = [data.batch_at(i) for i in range(8)]
    batches = [{k: jnp.asarray(v) for k, v in b.items()} for b in batches]

    def loss_fn(apply_fn, p, batch):
        logits = apply_fn(p, batch).astype(jnp.float32)
        logp = jax.nn.log_softmax(logits)
        nll = -jnp.take_along_axis(logp, batch["targets"][..., None],
                                   axis=-1)[..., 0]
        return jnp.mean(nll)

    plain_apply = lambda p, b: T.forward(cfg, p, b)
    spec = ImportanceSpec(loss_fn=loss_fn, perf_fn=neg_loss_perf(loss_fn),
                          train_batches=batches[:6], eval_batches=batches[6:],
                          steps=120, lr=2e-3)
    params = _adam_finetune(plain_apply, params, spec)
    base = neg_loss_perf(loss_fn)(plain_apply, params, batches[6:])
    print(f"pre-trained eval loss: {-base:.3f}")

    host = TransformerHost(cfg, params, env=CostEnv(batch=16, seq=64))
    ispec = dataclasses.replace(spec, steps=8, lr=1e-3)
    export = None
    print(f"{'method':12s} {'budget':>6s} {'speedup':>8s} {'eval loss':>10s}")
    for method in ("layermerge", "depth", "layeronly"):
        for ratio in (0.8, 0.6, 0.45):
            res = compress(host, budget_ratio=ratio, P=300, method=method,
                           importance=ispec, base_perf=base, params=params)
            if res is None:
                print(f"{method:12s} {ratio:6.2f} {'infeasible':>8s}")
                continue
            ra, _ = host.replaced_apply(res.plan)
            ft = dataclasses.replace(spec, steps=120)
            tuned = _adam_finetune(ra, params, ft)
            ev = -neg_loss_perf(loss_fn)(ra, tuned, batches[6:])
            print(f"{method:12s} {ratio:6.2f} {res.speedup:8.2f} {ev:10.3f}")
            if method == "layermerge" and ratio == 0.6:
                export = (res, tuned)

    # export the fine-tuned LayerMerge@0.6 plan as a portable artifact and
    # verify the reloaded executor reproduces the merged forward exactly
    import os
    import tempfile

    from repro import runtime

    if export is None:
        return
    res, tuned = export
    res.params = tuned
    ma, _ = host.merged_apply(res.plan, tuned)
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "smollm_mini.npz")
        fp = res.save(path)
        art = runtime.load(path)
        y_live = ma(tuned, batches[-1])
        y_art = art.apply(batches[-1])
        assert float(jnp.abs(y_live - y_art).max()) < 1e-5
        print(f"artifact: fingerprint {fp[:16]}, reload exact "
              f"({os.path.getsize(path)/1024:.1f} KiB)")


if __name__ == "__main__":
    main()
