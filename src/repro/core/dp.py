"""Algorithm 1 — the exact DP for the surrogate problem (Problem 5).

Also provides:

* :func:`solve_dp_reference` — the original scalar solver, kept as the
  bit-exact oracle for the vectorized fast path;
* :func:`solve_knapsack` — the paper's *LayerOnly* baseline (Problem 8), a
  0-1 knapsack over whole layers solved exactly on the same latency grid;
* :func:`brute_force` — an exponential reference solver used by the property
  tests to certify Theorem 3.1 (DP == optimum) on small instances.

Latency discretization follows the paper: every table latency is floored to
the grid ``{T0/P, 2·T0/P, …, T0}`` (integer units of ``T0/P``).  With integer
unit latencies the DP is exact; with real latencies it is exact for the
floored instance, as in the paper.

The fast path vectorizes the budget axis: for each layer ``l`` and candidate
``(l', k)`` the whole row ``M[l', :]`` is shifted by the discretized latency
and folded into a running max, so the per-budget Python loop of the scalar
solver becomes two NumPy ops per candidate.  Candidates are visited in the
scalar solver's order and only strictly-greater values replace the running
max, so plans (not just objectives) are bit-identical to the reference.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Callable, Mapping

import numpy as np

from .plan import CompressionPlan, Segment

NEG = -math.inf

# TableFn: (i, j) -> {k: (importance I[i,j,k], latency T[i,j,k], kept ids)}.
# The per-segment candidate axis is widened for precision planning: a key
# is either the plain merged-size int ``k`` (fp) or a ``(k, mode)`` tuple
# naming a quantized sibling (mode ∈ repro.kernels.quant.MODES).  Every
# solver splits keys through :func:`split_key`; fp-only tables keep int
# keys and visit order, so their plans stay bit-identical.
TableFn = Callable[[int, int], Mapping[int, tuple[float, float, tuple[int, ...]]]]


def split_key(key) -> tuple[int, str]:
    """``(k, quant-mode)`` from a table option key (int k ⇒ fp 'none')."""
    if isinstance(key, tuple):
        return int(key[0]), key[1]
    return int(key), "none"


@dataclasses.dataclass
class DPResult:
    plan: CompressionPlan
    objective: float
    latency: float          # true (undiscretized) latency sum
    table_M: np.ndarray     # the DP value table, for inspection/tests


def _discretize(t: float, unit: float) -> int:
    """Floor a latency to grid units (paper §3.3 / Appendix C)."""
    return int(math.floor(t / unit + 1e-9))


def _collect_span_opts(L: int, table: TableFn):
    """Materialize non-empty span options once, for solve + reconstruction."""
    span_opts: dict[tuple[int, int], Mapping[int, tuple[float, float, tuple[int, ...]]]] = {}
    for j in range(1, L + 1):
        for i in range(j - 1, -1, -1):
            opts = table(i, j)
            if opts:
                span_opts[(i, j)] = opts
    return span_opts


@dataclasses.dataclass
class _FlatSpanOpts:
    """All (i, j, k) candidates as flat arrays, in the scalar solver's visit
    order: for each end layer ``l``, spans by ascending start ``lp``, then
    table insertion order.  ``offsets[l] : offsets[l + 1]`` indexes layer
    ``l``'s candidates; ``kept`` is the one per-candidate Python object
    (needed only at reconstruction, never in the hot loop).
    """

    lp: np.ndarray          # int32  (n,) span start
    k: np.ndarray           # int32  (n,) merged-size coordinate
    imp: np.ndarray         # float64 (n,) importance I[i,j,k]
    lat: np.ndarray         # float64 (n,) true latency T[i,j,k]
    kept: list              # tuple[int, ...] per candidate
    quant: list             # str quant mode per candidate ('none' for fp)
    offsets: np.ndarray     # int64 (L + 2,)


def _flatten_span_opts(L: int, table: TableFn) -> _FlatSpanOpts:
    """One span walk → flat candidate arrays (the only Python-loop pass)."""
    lp: list[int] = []
    ks: list[int] = []
    imp: list[float] = []
    lat: list[float] = []
    kept: list = []
    quant: list = []
    offsets = np.zeros(L + 2, dtype=np.int64)
    for l in range(1, L + 1):
        for i in range(l):
            for key, (iv, tv, kv) in table(i, l).items():
                k, mode = split_key(key)
                lp.append(i)
                ks.append(k)
                imp.append(iv)
                lat.append(tv)
                kept.append(kv)
                quant.append(mode)
        offsets[l + 1] = len(lp)
    return _FlatSpanOpts(
        lp=np.asarray(lp, dtype=np.int32),
        k=np.asarray(ks, dtype=np.int32),
        imp=np.asarray(imp, dtype=np.float64),
        lat=np.asarray(lat, dtype=np.float64),
        kept=kept,
        quant=quant,
        offsets=offsets)


def _build_result(L, T0, P, M, segs_rev, method) -> DPResult:
    segs = list(reversed(segs_rev))
    true_lat = sum(s_lat for _, s_lat in segs)
    plan = CompressionPlan(num_layers=L, segments=tuple(s for s, _ in segs),
                           objective=float(M[L, P]), latency=true_lat,
                           budget=T0, method=method)
    return DPResult(plan=plan, objective=float(M[L, P]), latency=true_lat,
                    table_M=M)


def solve_dp(
    L: int,
    table: TableFn,
    T0: float,
    P: int,
    *,
    method: str = "layermerge",
    original_k: Callable[[int], int] | None = None,
) -> DPResult | None:
    """Exact DP of Algorithm 1 — vectorized over the budget axis.

    ``table(i, j)`` returns the merged-segment options for span ``(i, j]``
    (empty if the span is not mergeable).  Returns ``None`` when no feasible
    plan exists within ``T0`` (budget too tight even for the cheapest plan).
    Bit-identical to :func:`solve_dp_reference`, including tie-breaking.
    """
    if T0 <= 0 or P <= 0:
        raise ValueError("T0 and P must be positive")
    unit = T0 / P
    flat = _flatten_span_opts(L, table)
    # Vectorized latency discretization: same floor + epsilon as
    # _discretize, over every candidate at once.
    td_all = np.floor(flat.lat / unit + 1e-9).astype(np.int64)

    # M[l, t]: best Σ I over the first l layers with budget index t (0..P).
    M = np.full((L + 1, P + 1), NEG, dtype=np.float64)
    M[0, :] = 0.0
    # choice[l, t]: flat candidate index of the winning candidate.
    choice = np.full((L + 1, P + 1), -1, dtype=np.int64)
    row_reachable = np.zeros(L + 1, dtype=bool)
    row_reachable[0] = True

    lp_all, imp_all, off = flat.lp, flat.imp, flat.offsets
    cand = np.empty(P + 1, dtype=np.float64)
    for l in range(1, L + 1):
        lo, hi = off[l], off[l + 1]
        best = M[l]
        if hi > lo:
            # feasibility + reachability filtered as one vector op; skipped
            # candidates could never win (all-NEG rows, off-grid budgets),
            # so the visit order of the survivors matches the reference.
            live = np.nonzero((td_all[lo:hi] <= P)
                              & row_reachable[lp_all[lo:hi]])[0] + lo
            ch = choice[l]
            for ci in live:
                td = td_all[ci]
                # cand[t] = M[lp, t - td] + imp for t >= td, NEG below — the
                # scalar solver's inner t-loop as one shifted vector add.
                cand[:td] = NEG
                np.add(M[lp_all[ci], :P + 1 - td], imp_all[ci], out=cand[td:])
                upd = cand > best                  # strict: first max wins,
                best[upd] = cand[upd]              # matching the reference
                ch[upd] = ci
        row_reachable[l] = bool(np.max(best) != NEG)

    if M[L, P] == NEG:
        return None

    # -- reconstruct A*, C*, k* ----------------------------------------------
    segs_rev: list[tuple[Segment, float]] = []
    l, t = L, P
    while l > 0:
        ci = choice[l, t]
        lp, k = int(flat.lp[ci]), int(flat.k[ci])
        lat, kept = float(flat.lat[ci]), flat.kept[ci]
        orig = (original_k is not None and l - lp == 1
                and k == original_k(l) and set(kept) == {l})
        segs_rev.append((Segment(i=lp, j=l, k=k, kept=kept, original=orig,
                                 quant=flat.quant[ci]), lat))
        l, t = lp, t - int(td_all[ci])
    return _build_result(L, T0, P, M, segs_rev, method)


def solve_dp_reference(
    L: int,
    table: TableFn,
    T0: float,
    P: int,
    *,
    method: str = "layermerge",
    original_k: Callable[[int], int] | None = None,
) -> DPResult | None:
    """The original scalar DP — the certification oracle for :func:`solve_dp`."""
    if T0 <= 0 or P <= 0:
        raise ValueError("T0 and P must be positive")
    unit = T0 / P
    span_opts = _collect_span_opts(L, table)

    M = np.full((L + 1, P + 1), NEG, dtype=np.float64)
    M[0, :] = 0.0
    back: dict[tuple[int, int], tuple] = {}

    for l in range(1, L + 1):
        for lp in range(l):
            opts = span_opts.get((lp, l))
            if not opts:
                continue
            for key, (imp, lat, kept) in opts.items():
                k, mode = split_key(key)
                td = _discretize(lat, unit)
                if td > P:
                    continue
                for t in range(td, P + 1):
                    prev = M[lp, t - td]
                    if prev == NEG:
                        continue
                    cand = prev + imp
                    if cand > M[l, t]:
                        M[l, t] = cand
                        back[(l, t)] = (lp, k, td, lat, kept, mode)

    if M[L, P] == NEG:
        return None

    segs_rev: list[tuple[Segment, float]] = []
    l, t = L, P
    while l > 0:
        lp, k, td, lat, kept, mode = back[(l, t)]
        orig = (original_k is not None and l - lp == 1
                and k == original_k(l) and set(kept) == {l})
        segs_rev.append((Segment(i=lp, j=l, k=k, kept=kept, original=orig,
                                 quant=mode), lat))
        l, t = lp, t - td
    return _build_result(L, T0, P, M, segs_rev, method)


def solve_knapsack(
    L: int,
    importance: Mapping[int, float],
    latency: Mapping[int, float],
    T0: float,
    P: int,
    *,
    forced: tuple[int, ...] = (),
) -> tuple[tuple[int, ...], float, float] | None:
    """*LayerOnly* baseline (Problem 8): exact 0-1 knapsack on the grid.

    ``M[t]`` is the best value with discretized weight ≤ ``t``; with zero
    layers processed every budget holds value 0, and forced layers replace
    the row outright (no skip branch), so forced-infeasible budgets carry an
    explicit ``NEG`` instead of a keep-flag recorded off a ``NEG``
    predecessor.  Returns ``(C*, objective, true_latency)`` — the kept layer
    set — or ``None`` if the forced set cannot fit the budget.
    """
    if T0 <= 0 or P <= 0:
        raise ValueError("T0 and P must be positive")
    unit = T0 / P
    forced_set = set(forced)
    M = np.zeros(P + 1, dtype=np.float64)     # zero layers: 0 at every budget
    keep = np.zeros((L + 1, P + 1), dtype=bool)
    tds = {}
    for l in range(1, L + 1):
        imp, lat = importance[l], latency[l]
        td = tds[l] = _discretize(lat, unit)
        take = np.full(P + 1, NEG)
        if td <= P:
            np.add(M[:P + 1 - td], imp, out=take[td:])
        if l in forced_set:
            # forced: the skip branch does not exist; infeasible stays NEG.
            keep[l] = take != NEG
            M = take
        else:
            # tie prefers take, but never records keep on an infeasible take.
            upd = (take >= M) & (take != NEG)
            keep[l] = upd
            M = np.where(upd, take, M)
    if M[P] == NEG:
        return None
    C: list[int] = []
    t = P
    true_lat = 0.0
    for l in range(L, 0, -1):
        if keep[l, t]:
            C.append(l)
            true_lat += latency[l]
            t -= tds[l]
    C.reverse()
    return tuple(C), float(M[P]), true_lat


def brute_force(
    L: int,
    table: TableFn,
    T0: float,
    P: int,
) -> tuple[float, list[Segment]] | None:
    """Exponential reference solver for Theorem 3.1 property tests.

    Enumerates every segmentation of ``(0, L]`` and every ``k`` per segment,
    using the same floored-latency feasibility test as :func:`solve_dp`.
    """
    unit = T0 / P
    best: list[tuple[float, list[Segment]]] = [(NEG, [])]

    def rec(pos: int, used: int, imp: float, segs: list[Segment]):
        if pos == L:
            if imp > best[0][0]:
                best[0] = (imp, list(segs))
            return
        for j in range(pos + 1, L + 1):
            opts = table(pos, j)
            for key, (i_val, lat, kept) in opts.items():
                k, mode = split_key(key)
                td = _discretize(lat, unit)
                if used + td <= P:
                    segs.append(Segment(i=pos, j=j, k=k, kept=kept,
                                        quant=mode))
                    rec(j, used + td, imp + i_val, segs)
                    segs.pop()

    rec(0, 0, 0.0, [])
    if best[0][0] == NEG:
        return None
    return best[0]
