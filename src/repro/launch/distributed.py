"""Process-aware distributed runtime: init, gated I/O, worker entry point.

``launch/mesh.py`` builds meshes over the devices one controller sees;
this module adds the *process* layer above it: who am I in a multi-process
job, who is allowed to publish artifacts, and how CI runs a fleet of
workers on one machine.  Two modes share every code path:

* **``jax.distributed`` mode** — a real multi-host job calls
  :func:`init_runtime` with a coordinator address; process identity comes
  from ``jax.distributed.initialize``.
* **Subprocess-worker mode (CI)** — the coordinator spawns plain
  subprocesses with ``REPRO_PROCESS_ID`` / ``REPRO_NUM_PROCESSES`` set
  (and ``--xla_force_host_platform_device_count`` faking a multi-chip
  host, like the existing pmap subprocess test).  No coordinator service
  is needed: coordination happens through a shared work directory
  (:mod:`repro.core.dist_build`).

:func:`process_index` / :func:`process_count` / :func:`is_main` answer
identity questions without touching jax (env vars win, then explicit
:func:`init_runtime` state, then the single-process default), so the
publish-gating call sites in :mod:`repro.core.table_cache` and
:mod:`repro.runtime.artifact` stay import-cycle-free and near-free.

Failure semantics (the distributed half of the crash-safety contract)
---------------------------------------------------------------------
The fault-tolerant table build this module launches
(:func:`repro.core.dist_build.dist_build_tables`) makes three promises:

* **Lease timeouts** — every work item (one latency-probe bucket) is
  claimed by writing a lease file with an expiry ``lease_s`` seconds
  out (``O_CREAT|O_EXCL`` — claims are atomic).  The lease IS the
  heartbeat deadline: a worker renews only between probe attempts, so a
  worker that is SIGKILLed, wedged, or stalled simply stops renewing and
  its leases expire.
* **Reassignment** — any live worker that finds an expired lease steals
  it (atomic ``os.replace`` + read-back verification) and re-executes
  the item; the steal is recorded in the stealing worker's journal
  shard.  Execution is therefore *at-least-once* — duplicate results are
  possible when a straggler finishes after being stolen from — while
  attribution is *exactly-once*: the merge reads shards in a fixed
  worker order and keeps the first record per item, so the merged tables
  are a deterministic function of the shard set and BIT-identical to a
  single-process build regardless of which workers died when.  Items
  still unfinished after every worker exited (or whose shard records
  were corrupted) are re-executed inline by the coordinator, so a build
  with zero surviving workers still completes.
* **At-most-once publish** — every durable publish (merged table cache
  entries, build journals, artifacts, bench JSON) is gated on
  :func:`is_main`: worker processes write only their own journal shards
  inside the work directory, and exactly one process — the coordinator,
  ``process_index() == 0`` — merges and publishes.  Workers are spawned
  with a non-zero ``REPRO_PROCESS_ID`` precisely so a buggy worker that
  reaches a publish call writes nothing.

Each spawned worker's combined stdout/stderr is kept at
``<work_dir>/logs/w<idx>.log`` (:func:`repro.core.dist_build.
worker_log_path`) — the first place to look when ``DistReport.
dead_workers`` is non-empty.

The serve-side counterpart (worker loss mid-decode → drain, re-form on
survivors, replay in-flight requests) lives in
:func:`repro.runtime.serving.serve_with_failover`.

CLI::

  # one worker of a distributed table build (normally spawned by the
  # coordinator, but runnable by hand against a shared work dir):
  python -m repro.launch.distributed --worker --dir WORK \\
      --host-spec '{"factory": "repro.testing.hosts:tiny_resnet_host"}'

  # deterministic coordinator+2-worker fault smoke (verify.sh leg):
  python -m repro.launch.distributed --fault-smoke
"""
from __future__ import annotations

import json
import os

_STATE = {"process_id": None, "num_processes": None}

ENV_PROCESS_ID = "REPRO_PROCESS_ID"
ENV_NUM_PROCESSES = "REPRO_NUM_PROCESSES"


def init_runtime(coordinator_address: str | None = None,
                 num_processes: int | None = None,
                 process_id: int | None = None,
                 local_device_ids=None) -> int:
    """Initialize process identity; returns this process's index.

    With ``coordinator_address`` this is a thin wrapper over
    ``jax.distributed.initialize`` (real multi-host jobs).  Without it,
    identity comes from explicit arguments or the ``REPRO_PROCESS_ID`` /
    ``REPRO_NUM_PROCESSES`` environment (subprocess-worker CI mode),
    defaulting to the single-process ``(0, 1)``.
    """
    if coordinator_address is not None:
        import jax

        jax.distributed.initialize(
            coordinator_address=coordinator_address,
            num_processes=num_processes, process_id=process_id,
            local_device_ids=local_device_ids)
        _STATE["process_id"] = jax.process_index()
        _STATE["num_processes"] = jax.process_count()
        return _STATE["process_id"]
    _STATE["process_id"] = (
        process_id if process_id is not None
        else int(os.environ.get(ENV_PROCESS_ID, "0")))
    _STATE["num_processes"] = (
        num_processes if num_processes is not None
        else int(os.environ.get(ENV_NUM_PROCESSES, "1")))
    return _STATE["process_id"]


def process_index() -> int:
    """This process's index in the job (0 = coordinator/main).

    Resolution order: explicit :func:`init_runtime` state, then the
    ``REPRO_PROCESS_ID`` environment, then 0.  Deliberately does NOT
    call ``jax.process_index()`` unless :func:`init_runtime` ran — the
    call sites gating I/O must never trigger a backend init.
    """
    if _STATE["process_id"] is not None:
        return _STATE["process_id"]
    return int(os.environ.get(ENV_PROCESS_ID, "0"))


def process_count() -> int:
    """Total processes in the job (same resolution as
    :func:`process_index`)."""
    if _STATE["num_processes"] is not None:
        return _STATE["num_processes"]
    return int(os.environ.get(ENV_NUM_PROCESSES, "1"))


def is_main() -> bool:
    """True iff this process may publish (process index 0).

    THE I/O gate for multi-process runs: artifact saves, table-cache
    publishes, build-journal appends, and bench-JSON reports all check
    it, so a job of any size publishes each output exactly once.
    """
    return process_index() == 0


def publish_text(path: str, text: str) -> str | None:
    """``is_main``-gated atomic text publish (bench reports, summaries).

    Returns the path, or ``None`` when this process is not the
    publisher (nothing is written).
    """
    if not is_main():
        return None
    from repro.checkpoint.ckpt import atomic_write_text

    return atomic_write_text(path, text)


def publish_json(path: str, payload) -> str | None:
    """``is_main``-gated atomic JSON publish."""
    return publish_text(path, json.dumps(payload, indent=2))


def worker_env(worker_id: int, num_workers: int, *,
               devices: int | None = None, platform: str = "cpu",
               faults_spec: str | None = None,
               extra: dict | None = None) -> dict:
    """Environment for spawning worker ``worker_id`` of ``num_workers``.

    Workers get process index ``worker_id + 1`` (the coordinator is 0),
    so :func:`is_main` is False in every worker and publish-gated writes
    are inert there.  ``platform`` defaults to cpu for CI (see
    :mod:`repro.testing.subproc` for why pinning matters); a real fleet
    passes its accelerator platform.
    """
    from repro.testing.subproc import subprocess_env

    return subprocess_env(devices=devices, platform=platform,
                          process_id=worker_id + 1,
                          num_processes=num_workers + 1,
                          faults_spec=faults_spec, extra=extra)


def survivor_mesh(exclude=(), axes: tuple[str, ...] = ("data",)):
    """Re-form a mesh over the devices that survive a worker loss.

    ``exclude``: device ids to drop (the lost worker's).  The result is
    a 1-D mesh over the remaining devices on the first axis name (the
    data/slot axis serving shards over).  Raises when nothing survives.
    """
    import jax
    import numpy as np

    excluded = set(exclude)
    devs = [d for d in jax.devices() if d.id not in excluded]
    if not devs:
        raise RuntimeError("no surviving devices to re-form a mesh on")
    shape = (len(devs),) + (1,) * (len(axes) - 1)
    return jax.sharding.Mesh(np.array(devs).reshape(shape), axes)


# ---------------------------------------------------------------------------
# Worker entry point + deterministic fault smoke
# ---------------------------------------------------------------------------

def _run_worker_cli(args) -> int:
    from repro.core import dist_build

    init_runtime()
    host_spec = json.loads(args.host_spec)
    host, params = dist_build.resolve_host_spec(host_spec)
    oracle = dist_build.resolve_oracle_spec(json.loads(args.oracle_spec))
    cfg = dist_build.resolve_probe_spec(
        json.loads(args.probe_spec) if args.probe_spec else None)
    try:
        done = dist_build.run_worker(
            args.dir, args.worker_id, host, params, oracle,
            engine=args.engine, method=args.method, probe_config=cfg,
            lease_s=args.lease_s, deadline_s=args.deadline_s)
    except dist_build.DistBuildError as e:
        print(f"worker {args.worker_id}: {e}", flush=True)
        return 3
    print(json.dumps({"worker": args.worker_id, "items_done": done}),
          flush=True)
    return 0


def dist_fault_smoke() -> dict:
    """Coordinator + 2 workers; worker 0 SIGKILLed mid-bucket (holding a
    lease); the merged tables must be BIT-identical to a single-process
    build and the reassignment must be recorded.

    Workers spawn serially (worker 1 starts after worker 0 exits) so the
    kill is deterministic: worker 0 always claims its second item and
    dies holding its lease, worker 1 always finds that lease expired and
    steals it.
    """
    import tempfile

    from repro.core import build_tables, dist_build
    from repro.testing import faults, hosts

    host, params = hosts.tiny_resnet_host()
    reference = build_tables(host, params=params)
    with tempfile.TemporaryDirectory() as cache_dir:
        with faults.inject(faults.Fault("dist.item", "kill-worker",
                                        nth=2, widx=0)):
            tables, rep = dist_build.dist_build_tables(
                host, params=params, cache_dir=cache_dir, workers=2,
                host_spec={"factory":
                           "repro.testing.hosts:tiny_resnet_host",
                           "kwargs": {}},
                lease_s=0.5, serial_spawn=True)
    if tables.entries != reference.entries:
        raise AssertionError("distributed tables diverged from the "
                             "single-process build")
    if tables.num_pruned != reference.num_pruned:
        raise AssertionError("distributed Pareto drops diverged")
    if 0 not in rep.dead_workers:
        raise AssertionError(
            f"worker 0 was expected to die (exit 17), report: "
            f"{rep.as_dict()}")
    if not rep.reassigned:
        raise AssertionError(
            f"the killed worker's lease was never reassigned: "
            f"{rep.as_dict()}")
    return {
        "items": rep.items,
        "dead_workers": rep.dead_workers,
        "reassigned": rep.reassigned,
        "completed_by": rep.completed_by,
        "coordinator_items": rep.coordinator_items,
        "bit_identical": True,
    }


def serve_failover_smoke() -> dict:
    """Worker loss mid-decode → drain, re-form on survivors, replay: every
    request ends with a disposition (zero lost) and the generated tokens
    are BIT-identical to an uninterrupted run."""
    import dataclasses

    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.configs import get_config
    from repro.models import transformer as T
    from repro.runtime import serving
    from repro.testing import faults
    from repro.train.step import make_serve_step

    cfg = dataclasses.replace(
        get_config("smollm-135m").reduced(), num_layers=2, d_model=64,
        num_heads=4, num_kv_heads=2, head_dim=16, d_ff=128, vocab_size=128)
    params, _ = T.init_model(cfg, jax.random.PRNGKey(0))
    step = make_serve_step(cfg)

    def mk(b, s):
        return T.init_cache(cfg, b, s)

    prompt = serving.random_prompts(0, 5, 5, cfg.vocab_size)
    lens = jnp.full((5,), 5, jnp.int32)
    kw = dict(tokens=6, slots=2, chunk=3)
    clean = serving.serve_continuous(step, params, mk, prompt, lens,
                                     clock=faults.TickClock(), **kw)
    with faults.inject(faults.Fault("serve.worker", "raise", nth=3)):
        out = serving.serve_with_failover(step, params, mk, prompt, lens,
                                          clock=faults.TickClock(), **kw)
    rep = out.report
    if rep.failovers != 1 or not rep.replayed:
        raise AssertionError(f"expected one failover with replays, got "
                             f"failovers={rep.failovers} "
                             f"replayed={rep.replayed}")
    if sorted(rep.dispositions) != list(range(5)):
        raise AssertionError(
            f"request(s) lost in failover: dispositions="
            f"{sorted(rep.dispositions)}")
    if not np.array_equal(np.asarray(out[0]), np.asarray(clean[0])):
        raise AssertionError("replayed tokens diverged from the "
                             "uninterrupted run")
    return {"failovers": rep.failovers, "lost_workers": rep.lost_workers,
            "replayed": rep.replayed, "completed": sorted(rep.completed),
            "bit_identical": True}


def dist_smoke() -> dict:
    """Clean 2-worker parallel build ≡ single-process build."""
    import tempfile

    from repro.core import build_tables, dist_build
    from repro.testing import hosts

    host, params = hosts.tiny_resnet_host()
    reference = build_tables(host, params=params)
    with tempfile.TemporaryDirectory() as cache_dir:
        tables, rep = dist_build.dist_build_tables(
            host, params=params, cache_dir=cache_dir, workers=2,
            host_spec={"factory": "repro.testing.hosts:tiny_resnet_host",
                       "kwargs": {}},
            lease_s=5.0)
    if tables.entries != reference.entries:
        raise AssertionError("distributed tables diverged from the "
                             "single-process build")
    return {"items": rep.items, "completed_by": rep.completed_by,
            "dead_workers": rep.dead_workers, "bit_identical": True}


def main(argv=None):
    import argparse

    ap = argparse.ArgumentParser(prog="python -m repro.launch.distributed")
    ap.add_argument("--worker", action="store_true",
                    help="run one distributed-build worker loop")
    ap.add_argument("--dir", default=None, help="shared work directory")
    ap.add_argument("--worker-id", type=int, default=0)
    ap.add_argument("--host-spec", default=None,
                    help='JSON {"factory": "module:function", "kwargs": {}}')
    ap.add_argument("--oracle-spec", default='{"cls": "AnalyticTPUOracle"}')
    ap.add_argument("--probe-spec", default=None,
                    help="JSON ProbeConfig fields (timeout_s, retries, ...)")
    ap.add_argument("--engine", default="batched",
                    choices=("batched", "sequential"))
    ap.add_argument("--method", default="layermerge")
    ap.add_argument("--lease-s", type=float, default=30.0)
    ap.add_argument("--deadline-s", type=float, default=600.0)
    ap.add_argument("--smoke", action="store_true",
                    help="clean 2-worker build ≡ single-process build")
    ap.add_argument("--fault-smoke", action="store_true",
                    help="kill worker 0 mid-bucket; assert bit-identical "
                         "merged tables + a recorded lease reassignment, "
                         "then a serve-failover replay with zero lost "
                         "requests")
    args = ap.parse_args(argv)
    if args.worker:
        if not (args.dir and args.host_spec):
            ap.error("--worker requires --dir and --host-spec")
        raise SystemExit(_run_worker_cli(args))
    if args.fault_smoke:
        print(json.dumps(dist_fault_smoke(), indent=2))
        print(json.dumps(serve_failover_smoke(), indent=2))
        print("DIST_FAULT_SMOKE_OK")
        return
    if args.smoke:
        print(json.dumps(dist_smoke(), indent=2))
        print("DIST_SMOKE_OK")
        return
    ap.print_help()


if __name__ == "__main__":
    main()
