"""Backend-neutral unit IR for merged (compressed) networks.

A :class:`UnitGraph` is the executable form of a compression plan: an
ordered chain of typed *units*, each a record of STATIC configuration
(strides, kernel geometry, activation epilogue, skip wiring) plus a
``params`` pytree of arrays (merged weights).  Hosts lower plans into
this IR (``host.lower_plan(plan, params) → UnitGraph``); the shared
interpreter in :mod:`repro.runtime.executor` runs it; the artifact layer
in :mod:`repro.runtime.artifact` serializes it.

Design rules:

* Static fields are plain JSON-able Python values — they round-trip
  through the artifact spec unchanged.  Arrays live only in ``params``.
* Units never reference host objects (``ConvNet``, ``ArchConfig``
  instances, parameter dicts of the *uncompressed* network): everything
  the executor needs is in the unit record or ``UnitGraph.meta``.
* Skip/branch wiring is expressed through boundary ids: a unit may
  ``save_at`` a boundary and later units may ``add_from`` /
  ``concat_from`` it — the executor keeps the saved-activation table.
* Sharding is DATA, not code: every unit carries an ``axes`` record
  mapping param key-paths to *logical axis names* (MaxText-style, see
  :mod:`repro.sharding.rules`), and ``UnitGraph.axes`` does the same for
  graph-level params.  The executor resolves names → ``NamedSharding``
  through whatever :class:`ShardingRules` it is given; an artifact
  therefore ships its own sharding contract and a loader can
  ``device_put`` weights straight to their mesh placement.  Hosts
  populate the annotations at lowering time via :func:`annotate_axes`;
  empty ``axes`` simply means fully replicated.

CNN unit semantics (epilogue order matches the merged forward that the
merge-equality tests certify): conv → skip-add → concat → group-norm →
boundary activation → save.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Mapping


@dataclasses.dataclass
class ConvUnit:
    """One merged conv segment: VALID conv at the merged kernel size.

    ``params``: ``w`` (Kh,Kw,Cin|1,Cout), ``b`` (Cout,), optional
    ``gn`` {gamma, beta} (group-norm moved to the segment end, paper
    Appendix A) and optional ``proj`` {w, b} (1×1 projection shortcut of
    a skip-add ending at this unit's boundary).

    ``quant`` != 'none' marks a low-precision unit (artifact format v3):
    ``w`` is stored narrow (int8 / fp8) and ``params`` carries the
    symmetric per-output-channel ``w_scale`` (Cout,) — scales are DATA,
    serialized alongside the weights like every other array.  'w8a8'
    additionally quantizes the activation per-tensor at run time.
    """

    kind = "conv"
    stride: int = 1
    depthwise: bool = False
    act: str = "none"               # boundary activation σ_j ('none' at σ_L)
    gn_groups: int = 8
    proj_stride: int = 1
    add_from: int | None = None     # skip-add source boundary id
    concat_from: int | None = None  # U-Net concat source boundary id
    save_at: int | None = None      # boundary id to save the output under
    quant: str = "none"             # 'none' | 'int8' | 'w8a8' | 'fp8'
    axes: dict = dataclasses.field(default_factory=dict)
    params: dict = dataclasses.field(default_factory=dict)


@dataclasses.dataclass
class PoolUnit:
    """Average-pool barrier unit (parameter-free)."""

    kind = "pool"
    k: int = 2
    stride: int = 2
    concat_from: int | None = None
    save_at: int | None = None
    axes: dict = dataclasses.field(default_factory=dict)
    params: dict = dataclasses.field(default_factory=dict)


@dataclasses.dataclass
class UpsampleUnit:
    """Nearest-neighbour upsample barrier unit (parameter-free)."""

    kind = "upsample"
    factor: int = 2
    concat_from: int | None = None
    save_at: int | None = None
    axes: dict = dataclasses.field(default_factory=dict)
    params: dict = dataclasses.field(default_factory=dict)


@dataclasses.dataclass
class AttnUnit:
    """Single-head spatial self-attention barrier (DDPM middle block).

    ``params``: ``wq``, ``wk``, ``wv``, ``wo`` — passed through unmerged
    (attention is never linearizable).
    """

    kind = "attn"
    save_at: int | None = None
    axes: dict = dataclasses.field(default_factory=dict)
    params: dict = dataclasses.field(default_factory=dict)


@dataclasses.dataclass
class LowRankUnit:
    """Rank-``r`` residual map ``x + (x·U)·V`` — a merged FFN segment.

    ``params``: ``u`` (D,r), ``v`` (r,D).  Runs through the Pallas
    ``merged_ffn`` kernel on TPU.  ``quant`` != 'none' (artifact v3):
    ``u``/``v`` stored narrow plus per-output-channel ``u_scale`` (r,)
    and ``v_scale`` (D,); 'w8a8' also quantizes the activation feeding
    the two dots (the residual always adds the exact fp input).
    """

    kind = "lowrank"
    quant: str = "none"             # 'none' | 'int8' | 'w8a8' | 'fp8'
    axes: dict = dataclasses.field(default_factory=dict)
    params: dict = dataclasses.field(default_factory=dict)


@dataclasses.dataclass
class SublayerUnit:
    """One kept transformer sublayer: pre-norm → block → residual add.

    ``sub_kind``: 'attn' | 'attn_local' | 'ffn' | 'moe' | 'rglru' |
    'mlstm' | 'slstm'.  ``params``: {'norm': rmsnorm scale, 'p': the
    block's parameter pytree}.  Temporal kinds carry decode state (KV
    cache / recurrent state) in the executor's serve path.
    """

    kind = "sublayer"
    sub_kind: str = "ffn"
    axes: dict = dataclasses.field(default_factory=dict)
    params: dict = dataclasses.field(default_factory=dict)


UNIT_TYPES = {
    "conv": ConvUnit,
    "pool": PoolUnit,
    "upsample": UpsampleUnit,
    "attn": AttnUnit,
    "lowrank": LowRankUnit,
    "sublayer": SublayerUnit,
}

#: temporal sublayer kinds that carry decode state in the serve path
TEMPORAL_KINDS = ("attn", "attn_local", "rglru", "mlstm", "slstm")


@dataclasses.dataclass
class UnitGraph:
    """Executable form of a plan: ordered units + graph-level params.

    ``family``: 'cnn' | 'transformer' — selects the executor loop.

    ``params`` (graph-level, outside any unit):
      cnn          — optional ``head`` {w, b} (classifier);
      transformer  — optional ``embed``, ``final_norm``, optional
                     ``unembed``.

    ``meta`` (static):
      cnn          — ``save_input`` (bool: boundary 0 feeds a skip),
                     ``head`` ('classifier' | 'none');
      transformer  — ``config`` (the :class:`ArchConfig`; serialized as
                     a plain dict in the artifact spec).
    """

    family: str
    units: tuple
    params: dict = dataclasses.field(default_factory=dict)
    meta: dict = dataclasses.field(default_factory=dict)
    #: logical axes of graph-level params (flat keypath → name list);
    #: same contract as the per-unit ``axes`` records
    axes: dict = dataclasses.field(default_factory=dict)


# ---------------------------------------------------------------------------
# Static spec <-> unit records (artifact serialization support)
# ---------------------------------------------------------------------------

def unit_static(unit) -> dict:
    """JSON-able static record of one unit (everything but ``params``)."""
    out = {"kind": unit.kind}
    for f in dataclasses.fields(unit):
        if f.name == "params":
            continue
        out[f.name] = getattr(unit, f.name)
    return out


def unit_from_static(static: dict, params: dict):
    cls = UNIT_TYPES[static["kind"]]
    kwargs = {k: v for k, v in static.items() if k != "kind"}
    return cls(params=params, **kwargs)


# ---------------------------------------------------------------------------
# Params as a pytree (jit / fine-tune / checkpoint support)
# ---------------------------------------------------------------------------

def graph_params(graph: UnitGraph) -> dict:
    """The graph's arrays as one pytree: {'units': [...], 'globals': {...}}."""
    return {"units": [u.params for u in graph.units],
            "globals": graph.params}


def bind_params(graph: UnitGraph, params: dict) -> UnitGraph:
    """A structurally-identical graph with its arrays replaced.

    ``params`` must match :func:`graph_params` of the same graph — this
    is how the executor exposes a pure ``fn(params, x)`` signature while
    unit records stay the single source of static truth.
    """
    units = tuple(dataclasses.replace(u, params=p)
                  for u, p in zip(graph.units, params["units"]))
    return UnitGraph(family=graph.family, units=units,
                     params=params["globals"], meta=graph.meta,
                     axes=graph.axes)


def count_units(graph: UnitGraph) -> dict[str, int]:
    """Unit census (for benchmarks / reports): kind → count."""
    out: dict[str, int] = {}
    for u in graph.units:
        key = u.kind if u.kind != "sublayer" else f"sublayer:{u.sub_kind}"
        out[key] = out.get(key, 0) + 1
    return out


# ---------------------------------------------------------------------------
# Logical-axis annotations (the artifact sharding contract)
# ---------------------------------------------------------------------------
#
# ``axes`` records are flat dicts {param keypath → [logical names]} — the
# keypath uses '/'-joined keys exactly like the artifact array layout, and
# a name entry of ``None`` (JSON null) means "this dim is never sharded".
# Key-paths absent from the record resolve to fully replicated, so partial
# annotations (and the empty v1-artifact record) are always valid.

def axes_tree(params, flat_axes: Mapping, prefix: str = ""):
    """Axes pytree aligned leaf-for-leaf with ``params``.

    Each array leaf becomes a tuple of logical names (or ``None`` for
    replicated) looked up by its '/'-joined keypath — the shape
    :func:`repro.sharding.rules.param_shardings_with_shapes` consumes.
    """
    if isinstance(params, dict):
        return {k: axes_tree(v, flat_axes, f"{prefix}{k}/")
                for k, v in params.items()}
    if isinstance(params, (list, tuple)):
        return [axes_tree(v, flat_axes, f"{prefix}{i}/")
                for i, v in enumerate(params)]
    names = flat_axes.get(prefix[:-1])
    return tuple(names) if names else None


def unit_axes(unit):
    """Logical-axes pytree matching ``unit.params``."""
    return axes_tree(unit.params, unit.axes)


def graph_axes(graph: UnitGraph) -> dict:
    """Logical-axes pytree matching :func:`graph_params`."""
    return {"units": [unit_axes(u) for u in graph.units],
            "globals": axes_tree(graph.params, graph.axes)}


def _flat_names(tree, prefix: str = "") -> dict:
    """Flatten a nested {key: names-tuple} tree to the flat-dict form."""
    out: dict = {}
    for k, v in tree.items():
        if isinstance(v, dict):
            out.update(_flat_names(v, f"{prefix}{k}/"))
        elif v:
            out[f"{prefix}{k}"] = list(v)
    return out


# channels of a merged conv play the role the ffn dim plays in a
# transformer: the model-parallel axis of the unit graph
_CONV_W = [None, None, "conv_in", "conv_out"]
_CONV_W_DW = [None, None, None, "conv_out"]        # (K,K,1,C) depthwise


def _conv_axes(u) -> dict:
    ax = {"w": list(_CONV_W_DW if u.depthwise else _CONV_W),
          "b": ["conv_out"]}
    if "w_scale" in u.params:
        ax["w_scale"] = ["conv_out"]
    if "gn" in u.params:
        ax["gn/gamma"] = ["conv_out"]
        ax["gn/beta"] = ["conv_out"]
    if "proj" in u.params:
        ax["proj/w"] = list(_CONV_W)
        ax["proj/b"] = ["conv_out"]
    return ax


def _sublayer_axes(u, cfg) -> dict:
    from repro.models import layers as L
    from repro.models import moe as MOE
    from repro.models import rglru as RG
    from repro.models import xlstm as XL

    kind = u.sub_kind
    if kind in ("attn", "attn_local"):
        block = L.attention_axes(cfg)
    elif kind == "ffn":
        block = L.ffn_axes(cfg.ffn_kind)
    elif kind == "moe":
        block = MOE.moe_axes()
    elif kind == "rglru":
        block = RG.rglru_axes()
    elif kind == "mlstm":
        block = XL.mlstm_axes()
    elif kind == "slstm":
        block = XL.slstm_axes()
    else:
        block = {}
    ax = {"norm": ["embed"]}
    ax.update(_flat_names({"p": block}))
    return ax


def default_unit_axes(unit, cfg=None) -> dict:
    """The canonical logical-axes record for one unit.

    ``cfg`` (the transformer :class:`ArchConfig`) is required only for
    sublayer units — their block axes come from the model's own axes
    functions, so the artifact contract never drifts from the training
    annotations.
    """
    if unit.kind == "conv":
        return _conv_axes(unit)
    if unit.kind == "attn":
        return {k: ["conv_in", "conv_out"] for k in ("wq", "wk", "wv", "wo")
                if k in unit.params}
    if unit.kind == "lowrank":
        ax = {"u": ["embed", "rank"], "v": ["rank", "embed"]}
        if "u_scale" in unit.params:
            ax["u_scale"] = ["rank"]
        if "v_scale" in unit.params:
            ax["v_scale"] = ["embed"]
        return ax
    if unit.kind == "sublayer":
        return _sublayer_axes(unit, cfg)
    return {}


def graph_global_axes(graph: UnitGraph) -> dict:
    """Canonical logical-axes record for the graph-level params."""
    out: dict = {}
    if graph.family == "transformer":
        if "embed" in graph.params:
            out["embed"] = ["vocab", "embed"]
        out["final_norm"] = ["embed"]
        if "unembed" in graph.params:
            out["unembed"] = ["embed", "vocab"]
    elif "head" in graph.params:
        out["head/w"] = ["conv_in", "vocab"]
        out["head/b"] = ["vocab"]
    return out


def annotate_axes(graph: UnitGraph) -> UnitGraph:
    """Fill in the canonical axes records on a freshly-lowered graph.

    Units that already carry annotations (e.g. loaded from an artifact)
    are left untouched — the artifact's recorded contract wins.  Mutates
    the unit records in place and returns ``graph`` for chaining.
    """
    cfg = graph.meta.get("config")
    for u in graph.units:
        if not u.axes:
            u.axes = default_unit_axes(u, cfg)
    if not graph.axes:
        graph.axes = graph_global_axes(graph)
    return graph
