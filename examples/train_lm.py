"""End-to-end training driver: a SmolLM-family model on synthetic data.

Runs the full production stack — config → init → sharded data pipeline →
jit'd train_step (loss/grad/clip/AdamW) → fault-tolerant loop with async
checkpoints — for a few hundred steps and reports the loss curve.

Presets:
  tiny (default) — ~3 M params, runs on CPU in ~2 min (CI / this container)
  100m           — the full smollm-135m config (use on real accelerators)

Run:  PYTHONPATH=src python examples/train_lm.py [--preset tiny]
          [--steps 300] [--resume]
"""
import argparse
import dataclasses
import shutil

import jax

from repro.configs import get_config
from repro.data.pipeline import GlobalBatcher, SyntheticTokens
from repro.models import transformer as T
from repro.optim.adamw import AdamWConfig
from repro.train.loop import LoopConfig, train_loop


def preset_config(name):
    base = get_config("smollm-135m")
    if name == "100m":
        return base, 8, 1024
    cfg = dataclasses.replace(
        base, name="smollm-tiny", num_layers=4, d_model=128, num_heads=4,
        num_kv_heads=2, head_dim=32, d_ff=384, vocab_size=512,
        dtype="float32", remat=False)
    return cfg, 16, 64


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", default="tiny", choices=["tiny", "100m"])
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    ap.add_argument("--resume", action="store_true")
    args = ap.parse_args()

    cfg, batch, seq = preset_config(args.preset)
    if not args.resume:
        shutil.rmtree(args.ckpt_dir, ignore_errors=True)
    params, _ = T.init_model(cfg, jax.random.PRNGKey(0))
    n = sum(x.size for x in jax.tree.leaves(params))
    print(f"[train_lm] {cfg.name}: {n/1e6:.1f}M params, "
          f"batch={batch} seq={seq}")

    data = SyntheticTokens(cfg.vocab_size, batch, seq, seed=0)
    batcher = GlobalBatcher(data)
    opt_cfg = AdamWConfig(lr=1e-3, warmup_steps=20, total_steps=args.steps,
                          weight_decay=0.01)
    loop_cfg = LoopConfig(total_steps=args.steps, ckpt_every=100,
                          ckpt_dir=args.ckpt_dir, log_every=25)
    result = train_loop(cfg, opt_cfg, loop_cfg, params, batcher)
    first = sum(result.losses[:10]) / max(len(result.losses[:10]), 1)
    last = sum(result.losses[-10:]) / max(len(result.losses[-10:]), 1)
    print(f"[train_lm] loss {first:.3f} -> {last:.3f} over "
          f"{result.final_step} steps ({result.restarts} restarts)")
    assert last < first, "training must reduce the loss"


if __name__ == "__main__":
    main()
