"""Pallas TPU kernel: depthwise/grouped merged-segment conv (VALID, NHWC).

LayerMerge's headline efficiency results are on MobileNetV2 — inverted-
residual networks whose merged segments are dominated by *depthwise*
convolutions (``feature_group_count == channels``), which the dense
merged-conv kernel cannot express (its MXU contraction mixes every input
channel into every output channel).  This kernel runs those segments —
and general grouped convs with ``feature_group_count > 1`` — on the
fast path, reusing the zero-copy double-buffered DMA-halo pipeline and
the **phase-major input layout** of :mod:`repro.kernels.merged_conv`
(see that module's docstring for the layout contract; the tap loop here
is the same static-slice phase selection).

Grid and accumulators.  Because a grouped conv never mixes channels
across groups, the channel axis is *blocked jointly with the input*:

    grid ``(batch, ho-tiles, wo-tiles, group-blocks)``

with ``bgroups`` groups per block (``choose_group_block``: for
depthwise, a lane-friendly channel tile via ``ops.channel_tile``; for
``cin_g > 1`` one group per step so each tap is one dense
``(tile·tile, cin_g) @ (cin_g, cout_g)`` MXU contraction).  Unlike the
dense kernel — where one input tile is reused across every
output-channel block — each grid step here DMAs its *own* channel slice
of the halo'd window (``bgroups·cin_g`` channels), so the channel axis
rides in the innermost grid position purely to keep the double-buffered
pipeline dense; aggregate input traffic is identical to the dense
kernel's (each channel of each window read exactly once — the
group-blocking invariance ``input_traffic_model`` relies on).

Per-group fp32 accumulators.  The accumulator is
``(tile_ho·tile_wo, bgroups·cout_g)`` in fp32; each tap contributes

* depthwise (``cin_g == cout_g == 1``): a VPU broadcast
  multiply-accumulate ``acc += x_tap · w[u, v]`` — no MXU, no
  channel-mixing GEMM;
* channel-multiplier depthwise (``cin_g == 1, cout_g > 1``): the same
  broadcast against ``(bgroups, cout_g)`` weights;
* grouped (``cin_g > 1``): one small MXU dot per group in the block,
  accumulated into the group's column slice.

Bias + boundary activation σ_j fuse into the epilogue exactly as in the
dense kernel.  VMEM per step is bounded by :func:`choose_tiles_grouped`
— the 2-D planner extended to the grouped footprint: double-buffered
input scratch carries only the block's ``bgroups·cin_g`` channels, the
weight block is ``k_h·k_w·bgroups·cin_g·cout_g`` (a factor ``groups``
smaller than the dense kernel's ``k²·Cin·bCout``), and the fp32
accumulator + output block is ``tho·two·bgroups·cout_g``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .merged_conv import _VMEM_BUDGET, _round8, phase_extents, phase_major
from .ref import apply_activation


def choose_group_block(groups: int, cin_g: int, cout_g: int,
                       requested: int | None = None) -> int:
    """Groups per grid step (the channel-block width in group units).

    Depthwise-shaped convs (``cin_g == 1``) get a lane-friendly channel
    tile: ``bgroups·cout_g`` rounded by :func:`repro.kernels.ops.
    channel_tile` (a multiple of 8, at most one 128-lane width; the
    group axis is padded *up*, never searched down).  General grouped
    convs (``cin_g > 1``) take one group per step — each group is its
    own dense MXU contraction, so blocking more would only serialize
    python-unrolled dots inside the kernel.
    """
    if cin_g == 1:
        from .ops import channel_tile                 # lazy: ops imports us
        bc = channel_tile(groups * cout_g, requested)
        return max(1, bc // cout_g)
    return 1


def choose_tiles_grouped(h: int, w: int, cin_g: int, cout_g: int,
                         kh: int, kw: int, stride: int, itemsize: int,
                         bgroups: int = 1,
                         budget_bytes: float = _VMEM_BUDGET
                         ) -> tuple[int, int]:
    """``(tile_ho, tile_wo)`` planner for the grouped kernel's footprint.

    Same two-branch structure as ``merged_conv.choose_tiles`` (grow the
    row tile at full output width; shrink ``tile_wo`` only for panorama
    images), with the working set re-derived for the grouped grid: the
    double-buffered input scratch holds the block's ``bgroups·cin_g``
    channels (dense-window upper bound on the phase-major scratch), the
    weight block is ``k_h·k_w·bgroups·cin_g·cout_g`` and the fp32
    accumulator + output block ``tho·two·bgroups·cout_g·(4+itemsize)``.
    """
    s = max(stride, 1)
    ho = max((h - kh) // s + 1, 1)
    wo = max((w - kw) // s + 1, 1)
    bcin = bgroups * cin_g
    fixed = kh * kw * bgroups * cin_g * cout_g * itemsize   # weight block
    acc_b = bgroups * cout_g * (4 + itemsize)               # per output elem

    shi1 = s + kh - 1
    a_w = 2 * shi1 * s * bcin * itemsize + acc_b
    b_w = fixed + 2 * shi1 * (kw - 1) * bcin * itemsize
    if a_w * wo + b_w > budget_bytes:
        tile_wo = int((budget_bytes - b_w) // a_w)
        return 1, _round8(tile_wo, wo)

    swi = s * wo + kw - 1
    a_h = 2 * s * swi * bcin * itemsize + wo * acc_b
    b_h = fixed + 2 * (kh - 1) * swi * bcin * itemsize
    tile_ho = int((budget_bytes - b_h) // a_h)
    return _round8(tile_ho, ho), wo


def _kernel(x_hbm, w_ref, b_ref, *rest, kh: int, kw: int,
            stride: int, n_th: int, n_tw: int, n_tc: int, cin_g: int,
            cout_g: int, activation: str | None, quant: bool = False):
    # Quantized path: one extra (1, bc) fp32 per-output-channel weight
    # scale operand, applied after the fp32 accumulation (see
    # merged_conv._kernel — same contract, group-blocked layout).
    if quant:
        ws_ref, o_ref, xs, sem = rest
    else:
        ws_ref, (o_ref, xs, sem) = None, rest
    tho, two, bc = o_ref.shape
    bgroups = bc // cout_g
    bcin = bgroups * cin_g
    s = stride
    shp, swp = xs.shape[3], xs.shape[4]       # per-phase halo'd tile extents
    bb, th, tw, tc = (pl.program_id(i) for i in range(4))
    tiles = n_th * n_tw * n_tc
    step = ((bb * n_th + th) * n_tw + tw) * n_tc + tc
    n_steps = pl.num_programs(0) * tiles

    def dma(step_idx, slot):
        b2 = step_idx // tiles
        r = step_idx % tiles
        rs, rc = r // n_tc, r % n_tc
        return pltpu.make_async_copy(
            x_hbm.at[b2, :, :, pl.ds((rs // n_tw) * tho, shp),
                     pl.ds((rs % n_tw) * two, swp),
                     pl.ds(rc * bcin, bcin)],
            xs.at[slot], sem.at[slot])

    # Every step owns its (spatial tile, channel block) window — there is
    # no cross-step reuse to exploit, so the pipeline double-buffers over
    # the flat step counter directly.
    @pl.when(step == 0)
    def _():                                   # pipeline prologue
        dma(0, 0).start()

    @pl.when(step + 1 < n_steps)
    def _():                                   # prefetch next window
        dma(step + 1, (step + 1) % 2).start()

    dma(step, step % 2).wait()                 # await this step's window

    p = tho * two
    acc = jnp.zeros((p, bc), jnp.float32)
    for u in range(kh):
        for v in range(kw):
            # Phase-major tap selection (static slice — see merged_conv).
            xsel = xs[step % 2, u % s, v % s, pl.ds(u // s, tho),
                      pl.ds(v // s, two), :]              # (tho, two, bcin)
            xsel = xsel.reshape(p, bcin).astype(jnp.float32)
            wtap = w_ref[u, v].astype(jnp.float32)  # (bgroups, cin_g·cout_g)
            if cin_g == 1 and cout_g == 1:
                # depthwise: per-channel VPU multiply-accumulate
                acc = acc + xsel * wtap.reshape(1, bc)
            elif cin_g == 1:
                # channel-multiplier depthwise: broadcast over cout_g
                acc = acc + (xsel.reshape(p, bgroups, 1)
                             * wtap.reshape(bgroups, cout_g)[None]
                             ).reshape(p, bc)
            else:
                # grouped: one dense contraction per group in the block
                # (concatenated, not scatter-updated — Pallas tracing
                # rejects the constant index arrays `.at[].add` captures)
                xg = xsel.reshape(p, bgroups, cin_g)
                blks = [jnp.dot(xg[:, g], wtap[g].reshape(cin_g, cout_g),
                                preferred_element_type=jnp.float32)
                        for g in range(bgroups)]
                acc = acc + (blks[0] if bgroups == 1
                             else jnp.concatenate(blks, axis=1))
    if ws_ref is not None:
        acc = acc * ws_ref[0].astype(jnp.float32)        # dequant epilogue
    acc = acc + b_ref[0].astype(jnp.float32)             # (bc,) broadcast
    # fused epilogue: σ_j on the fp32 accumulator, shared with the oracle
    acc = apply_activation(acc, activation)
    o_ref[...] = acc.reshape(tho, two, bc).astype(o_ref.dtype)


def depthwise_conv(x, w, b=None, *, stride: int = 1, groups: int,
                   bgroups: int = 1, tile_ho: int | None = None,
                   tile_wo: int | None = None,
                   activation: str | None = None, w_scale=None,
                   out_dtype=None, interpret: bool = False):
    """x: (N, H, W, Cin); w: (kh, kw, Cin/g, Cout) → (N, Ho, Wo, Cout).

    VALID grouped convolution with ``feature_group_count = groups`` and
    ``stride`` on both spatial axes (depthwise = ``groups == Cin`` with
    a ``(kh, kw, 1, Cin)`` kernel).  ``bgroups`` groups execute per grid
    step (default: :func:`choose_group_block` at the ops layer); the
    group axis is zero-padded up to a ``bgroups`` multiple here, and the
    padded output channels sliced back off.  ``tile_ho``/``tile_wo``
    default to :func:`choose_tiles_grouped`; ``b``/``activation`` fuse
    the segment epilogue.  ``w_scale``/``out_dtype``: quantized-weight
    path, same contract as :func:`repro.kernels.merged_conv.merged_conv`
    (``w_scale`` is per-output-channel ``(Cout,)``, re-laid group-blocked
    alongside the bias).
    """
    n, h, wdt, cin = x.shape
    kh, kw, cin_g, cout = w.shape
    s = stride
    assert s >= 1 and h >= kh and wdt >= kw, (x.shape, w.shape, s)
    assert cin == groups * cin_g and cout % groups == 0, \
        (x.shape, w.shape, groups)
    cout_g = cout // groups
    ho = (h - kh) // s + 1
    wo = (wdt - kw) // s + 1
    if tile_ho is None or tile_wo is None:
        a_ho, a_wo = choose_tiles_grouped(h, wdt, cin_g, cout_g, kh, kw, s,
                                          x.dtype.itemsize, bgroups)
        tile_ho = a_ho if tile_ho is None else tile_ho
        tile_wo = a_wo if tile_wo is None else tile_wo
    tile_ho = max(1, min(tile_ho, ho))
    tile_wo = max(1, min(tile_wo, wo))
    n_th, n_tw = -(-ho // tile_ho), -(-wo // tile_wo)
    ho_p, wo_p = n_th * tile_ho, n_tw * tile_wo
    ph, pw, dh, dw = phase_extents(kh, kw, s)
    shp, swp = tile_ho + dh, tile_wo + dw

    # Pad the group axis to a bgroups multiple.  Channels are group-major
    # (lax HWIO grouped layout), so padded input channels and padded
    # output channels are one contiguous tail each.
    pad_g = (-groups) % bgroups
    g_p = groups + pad_g
    if pad_g:
        x = jnp.pad(x, ((0, 0), (0, 0), (0, 0), (0, pad_g * cin_g)))
    # (kh, kw, cin_g, G·cout_g) → (kh, kw, G_p, cin_g·cout_g): the 4-D
    # group-blocked weight layout the kernel's BlockSpec tiles over.
    w4 = w.reshape(kh, kw, cin_g, groups, cout_g).transpose(0, 1, 3, 2, 4)
    if pad_g:
        w4 = jnp.pad(w4, ((0, 0), (0, 0), (0, pad_g), (0, 0), (0, 0)))
    w4 = w4.reshape(kh, kw, g_p, cin_g * cout_g)
    bias = jnp.zeros((groups, cout_g), jnp.float32) if b is None \
        else b.reshape(groups, cout_g)
    bias = jnp.pad(bias, ((0, pad_g), (0, 0))).reshape(1, g_p * cout_g)
    if w_scale is not None:
        # per-cout scale follows the bias's group-blocked layout
        scale_b = w_scale.astype(jnp.float32).reshape(groups, cout_g)
        scale_b = jnp.pad(scale_b,
                          ((0, pad_g), (0, 0))).reshape(1, g_p * cout_g)

    # Phase-major relayout (shared contract with merged_conv; free at
    # stride 1, one XLA transpose otherwise).
    hs = max(n_th * tile_ho + dh, -(-h // s))
    ws = max(n_tw * tile_wo + dw, -(-wdt // s))
    x = phase_major(x, kh, kw, s, hs, ws)

    bcin = bgroups * cin_g
    bc = bgroups * cout_g
    n_tc = g_p // bgroups
    odt = jnp.dtype(out_dtype) if out_dtype is not None else x.dtype
    in_specs = [
        pl.BlockSpec(memory_space=pltpu.ANY),     # HBM phase-major image
        pl.BlockSpec((kh, kw, bgroups, cin_g * cout_g),
                     lambda bb, th, tw, tc: (0, 0, tc, 0)),
        pl.BlockSpec((1, bc), lambda bb, th, tw, tc: (0, tc)),
    ]
    operands = [x, w4, bias]
    if w_scale is not None:
        in_specs.append(pl.BlockSpec((1, bc),
                                     lambda bb, th, tw, tc: (0, tc)))
        operands.append(scale_b)
    grid = (n, n_th, n_tw, n_tc)
    out = pl.pallas_call(
        functools.partial(_kernel, kh=kh, kw=kw, stride=s, n_th=n_th,
                          n_tw=n_tw, n_tc=n_tc, cin_g=cin_g, cout_g=cout_g,
                          activation=activation, quant=w_scale is not None),
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((None, tile_ho, tile_wo, bc),
                               lambda bb, th, tw, tc: (bb, th, tw, tc)),
        out_shape=jax.ShapeDtypeStruct((n, ho_p, wo_p, g_p * cout_g), odt),
        scratch_shapes=[pltpu.VMEM((2, ph, pw, shp, swp, bcin), x.dtype),
                        pltpu.SemaphoreType.DMA((2,))],
        interpret=interpret,
    )(*operands)
    if (ho_p, wo_p) != (ho, wo) or g_p != groups:
        out = out[:, :ho, :wo, :cout]
    return out
