"""Decoder stack assembly — scan-over-layers, KV-cache decode, and the
LayerMerge-compressed variant.

The stack is a sequence of *layer groups*: maximal runs of layers with the
same temporal kind (attn / attn_local / rglru / mlstm / slstm).  Params are
stacked per group and applied with ``lax.scan`` so tracing cost is O(#groups)
not O(#layers) — essential for the 512-device dry-run.

Three entry points:
* ``forward(cfg, params, batch)``            — train/prefill logits
* ``decode_step(cfg, params, cache, batch)`` — one-token serve step
* ``forward_compressed(...)``                — plan-aware compressed net
  (merged rank-FFN segments + pruned blocks), used by the LayerMerge host.
"""
from __future__ import annotations

import dataclasses
import functools
import math
from typing import Any

import jax
import jax.numpy as jnp
from jax import lax

from repro.sharding.rules import logical_constraint

from . import layers as L
from . import moe as MOE
from . import rglru as RG
from . import xlstm as XL


# ---------------------------------------------------------------------------
# Layer groups
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class GroupSpec:
    kind: str
    count: int
    start: int      # first layer index (0-based)


def layer_groups(cfg) -> tuple[GroupSpec, ...]:
    kinds = cfg.layer_kinds()
    groups = []
    i = 0
    while i < len(kinds):
        j = i
        while j < len(kinds) and kinds[j] == kinds[i]:
            j += 1
        groups.append(GroupSpec(kind=kinds[i], count=j - i, start=i))
        i = j
    return tuple(groups)


def _dtype(cfg):
    return jnp.dtype(cfg.dtype)


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------

def _init_temporal(cfg, kind, key, dtype):
    if kind in ("attn", "attn_local"):
        return L.init_attention(cfg, key, dtype)
    if kind == "rglru":
        return RG.init_rglru(cfg, key, dtype)
    if kind == "mlstm":
        return XL.init_mlstm(cfg, key, dtype)
    if kind == "slstm":
        return XL.init_slstm(cfg, key, dtype)
    raise ValueError(kind)


def _init_layer(cfg, kind, key, dtype):
    k1, k2 = jax.random.split(key)
    n1, n1_ax = L.init_rmsnorm(cfg.d_model, dtype)
    p = {"norm1": n1}
    ax = {"norm1": n1_ax}
    p["temporal"], ax["temporal"] = _init_temporal(cfg, kind, k1, dtype)
    if cfg.has_ffn:
        n2, n2_ax = L.init_rmsnorm(cfg.d_model, dtype)
        p["norm2"] = n2
        ax["norm2"] = n2_ax
        if cfg.is_moe:
            p["ffn"], ax["ffn"] = MOE.init_moe(cfg, k2, dtype)
        else:
            p["ffn"], ax["ffn"] = L.init_ffn(cfg.d_model, cfg.d_ff,
                                             cfg.ffn_kind, k2, dtype)
    return p, ax


def _stack(trees):
    return jax.tree.map(lambda *xs: jnp.stack(xs), *trees)


def _stack_axes(ax):
    """Prepend the scan 'layers' axis to every logical-axes tuple."""
    return jax.tree.map(
        lambda a: ("layers",) + tuple(a) if a is not None else ("layers",),
        ax, is_leaf=lambda x: isinstance(x, tuple) or x is None)


def _layer_axes(cfg, kind):
    ax = {"norm1": ("embed",)}
    if kind in ("attn", "attn_local"):
        ax["temporal"] = L.attention_axes(cfg)
    elif kind == "rglru":
        ax["temporal"] = RG.rglru_axes()
    elif kind == "mlstm":
        ax["temporal"] = XL.mlstm_axes()
    elif kind == "slstm":
        ax["temporal"] = XL.slstm_axes()
    else:
        raise ValueError(kind)
    if cfg.has_ffn:
        ax["norm2"] = ("embed",)
        ax["ffn"] = MOE.moe_axes() if cfg.is_moe else L.ffn_axes(cfg.ffn_kind)
    return ax


def model_axes(cfg):
    """Static logical-axes tree mirroring init_model's params (no tracing)."""
    axes = {"groups": [_stack_axes(_layer_axes(cfg, g.kind))
                       for g in layer_groups(cfg)],
            "final_norm": ("embed",)}
    if cfg.frontend == "tokens":
        axes["embed"] = ("vocab", "embed")
    if not cfg.tie_embeddings or cfg.frontend != "tokens":
        axes["unembed"] = ("embed", "vocab")
    return axes


def init_model(cfg, key):
    dtype = _dtype(cfg)
    groups = layer_groups(cfg)
    keys = jax.random.split(key, len(groups) + 2)
    gparams = []
    for gi, g in enumerate(groups):
        lkeys = jax.random.split(keys[gi], g.count)
        ps = [_init_layer(cfg, g.kind, k, dtype)[0] for k in lkeys]
        gparams.append(_stack(ps))
    params = {"groups": gparams}
    params["final_norm"], _ = L.init_rmsnorm(cfg.d_model, dtype)
    if cfg.frontend == "tokens":
        params["embed"], _ = L.init_embedding(
            cfg.vocab_size, cfg.d_model, keys[-1], dtype)
    if not cfg.tie_embeddings or cfg.frontend != "tokens":
        params["unembed"] = jax.random.normal(
            keys[-2], (cfg.d_model, cfg.vocab_size), dtype) \
            / math.sqrt(cfg.d_model)
    return params, model_axes(cfg)


# ---------------------------------------------------------------------------
# Forward (train / prefill)
# ---------------------------------------------------------------------------

def _temporal_apply(cfg, kind, lp, h, positions, mrope_positions):
    if kind in ("attn", "attn_local"):
        window = cfg.local_window if kind == "attn_local" else 0
        return L.attention(lp, h, cfg, positions, window=window,
                           mrope_positions=mrope_positions)
    if kind == "rglru":
        return RG.rglru_block(lp, h, cfg)
    if kind == "mlstm":
        return XL.mlstm_block(lp, h, cfg)
    if kind == "slstm":
        return XL.slstm_block(lp, h, cfg)
    raise ValueError(kind)


def _layer_fn(cfg, kind, positions, mrope_positions, lp, x):
    h = L.rms_norm(x, lp["norm1"], cfg.norm_eps)
    t = _temporal_apply(cfg, kind, lp["temporal"], h, positions,
                        mrope_positions)
    x = logical_constraint(x + t, ("batch", "seq", "act_embed"))
    if cfg.has_ffn:
        h = L.rms_norm(x, lp["norm2"], cfg.norm_eps)
        if cfg.is_moe:
            f = MOE.moe_dispatch(lp["ffn"], h, cfg,
                                 capacity_factor=cfg.capacity_factor)
        else:
            f = L.ffn(lp["ffn"], h, cfg.ffn_kind)
        x = logical_constraint(x + f, ("batch", "seq", "act_embed"))
    return x


def _embed_in(cfg, params, batch):
    if cfg.frontend == "tokens":
        x = params["embed"][batch["tokens"]]
    else:
        x = batch["embeds"].astype(_dtype(cfg))
    return logical_constraint(x, ("batch", "seq", "act_embed"))


def _unembed(cfg, params, x):
    if cfg.tie_embeddings and cfg.frontend == "tokens":
        logits = x @ params["embed"].T
    else:
        logits = x @ params["unembed"]
    return logical_constraint(logits, ("batch", "seq", "act_vocab"))


def forward(cfg, params, batch):
    """Logits for train/prefill.  batch: tokens|embeds, positions[, mrope]."""
    x = _embed_in(cfg, params, batch)
    positions = batch.get("positions")
    if positions is None:
        positions = jnp.arange(x.shape[1])[None, :]
    mrope = batch.get("mrope_positions")
    for g, gp in zip(layer_groups(cfg), params["groups"]):
        fn = functools.partial(_layer_fn, cfg, g.kind, positions, mrope)
        if cfg.remat:
            fn = jax.checkpoint(
                fn, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)

        def body(carry, lp, fn=fn):
            return fn(lp, carry), None
        if cfg.scan_layers and g.count > 1:
            x, _ = lax.scan(body, x, gp)
        else:
            for i in range(g.count):
                x = fn(jax.tree.map(lambda t: t[i], gp), x)
    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    return _unembed(cfg, params, x)


@jax.custom_vjp
def upcast_for_loss(x):
    """f32 view of bf16 logits whose COTANGENT stays bf16.

    Without this, the f32 loss cast promotes the entire backward pass to
    f32 — every TP activation psum and dL/dx all-reduce doubles in bytes
    (measured: ~3.6 GB/layer of f32[16,4096,2048] all-reduce at qwen3-moe
    train_4k; see EXPERIMENTS §Perf iteration 4)."""
    return x.astype(jnp.float32)


def _upcast_fwd(x):
    return x.astype(jnp.float32), jnp.zeros((0,), x.dtype)


def _upcast_bwd(res, g):
    return (g.astype(res.dtype),)


upcast_for_loss.defvjp(_upcast_fwd, _upcast_bwd)


def lm_loss(cfg, params, batch):
    """Causal LM cross-entropy (fp32 softmax, bf16 cotangents)."""
    logits = upcast_for_loss(forward(cfg, params, batch))
    targets = batch["targets"]
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, targets[..., None], axis=-1)[..., 0]
    mask = batch.get("loss_mask")
    if mask is not None:
        return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    return jnp.mean(nll)


# ---------------------------------------------------------------------------
# Decode (serving)
# ---------------------------------------------------------------------------

def init_cache(cfg, batch_size, seq_len):
    """Cache pytree aligned with layer groups (stacked per group)."""
    dtype = _dtype(cfg)
    caches = []
    for g in layer_groups(cfg):
        if g.kind in ("attn", "attn_local"):
            window = cfg.local_window if g.kind == "attn_local" else 0
            one = L.init_cache(cfg, batch_size, seq_len, dtype, window=window)
        elif g.kind == "rglru":
            one = RG.init_rglru_state(cfg, batch_size, dtype)
        elif g.kind == "mlstm":
            one = XL.init_mlstm_state(cfg, batch_size)
        elif g.kind == "slstm":
            one = XL.init_slstm_state(cfg, batch_size)
        else:
            one = {}
        caches.append(jax.tree.map(
            lambda t: jnp.broadcast_to(t, (g.count,) + t.shape), one))
    return caches


def cache_axes(cfg):
    """Logical axes for the cache pytree (for dry-run in_shardings)."""
    out = []
    for g in layer_groups(cfg):
        if g.kind in ("attn", "attn_local"):
            ax = dict(L.CACHE_AXES)
        elif g.kind == "rglru":
            ax = dict(RG.RGLRU_STATE_AXES)
        elif g.kind == "mlstm":
            ax = dict(XL.MLSTM_STATE_AXES)
        elif g.kind == "slstm":
            ax = dict(XL.SLSTM_STATE_AXES)
        else:
            ax = {}
        out.append(jax.tree.map(
            lambda a: ("layers",) + tuple(a),
            ax, is_leaf=lambda x: isinstance(x, tuple)))
    return out


def _decode_layer_fn(cfg, kind, mrope_positions, lp, cache, x):
    h = L.rms_norm(x, lp["norm1"], cfg.norm_eps)
    if kind in ("attn", "attn_local"):
        window = cfg.local_window if kind == "attn_local" else 0
        t, cache = L.attention_decode(lp["temporal"], h, cfg, cache,
                                      window=window,
                                      mrope_positions=mrope_positions)
    elif kind == "rglru":
        t, cache = RG.rglru_decode(lp["temporal"], h, cfg, cache)
    elif kind == "mlstm":
        t, cache = XL.mlstm_decode(lp["temporal"], h, cfg, cache)
    elif kind == "slstm":
        t, cache = XL.slstm_decode(lp["temporal"], h, cfg, cache)
    else:
        raise ValueError(kind)
    x = logical_constraint(x + t, ("batch", "seq", "act_embed"))
    if cfg.has_ffn:
        h = L.rms_norm(x, lp["norm2"], cfg.norm_eps)
        if cfg.is_moe:
            f = MOE.moe_dispatch(lp["ffn"], h, cfg,
                                 capacity_factor=cfg.capacity_factor)
        else:
            f = L.ffn(lp["ffn"], h, cfg.ffn_kind)
        x = logical_constraint(x + f, ("batch", "seq", "act_embed"))
    return x, cache


def decode_step(cfg, params, cache, batch):
    """One-token decode: batch {'tokens': (B,1)|'embeds': (B,1,D)} → logits."""
    x = _embed_in(cfg, params, batch)
    mrope = batch.get("mrope_positions")
    new_cache = []
    for g, gp, gc in zip(layer_groups(cfg), params["groups"], cache):
        fn = functools.partial(_decode_layer_fn, cfg, g.kind, mrope)

        def body(carry, xs, fn=fn):
            lp, c = xs
            x, c = fn(lp, c, carry)
            return x, c
        if cfg.scan_layers and g.count > 1:
            x, gc = lax.scan(body, x, (gp, gc))
        else:
            outs = []
            for i in range(g.count):
                x, ci = fn(jax.tree.map(lambda t: t[i], gp),
                           jax.tree.map(lambda t: t[i], gc), x)
                outs.append(ci)
            gc = _stack(outs)
        new_cache.append(gc)
    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    return _unembed(cfg, params, x), new_cache


# ---------------------------------------------------------------------------
# LayerMerge-compressed forward (plan-aware)
# ---------------------------------------------------------------------------

def sublayer_kinds(cfg) -> tuple[str, ...]:
    """Flattened sublayer chain: temporal and FFN blocks interleaved —
    this is the 1-based layer indexing the compression plan refers to."""
    out = []
    for kind in cfg.layer_kinds():
        out.append(kind)
        if cfg.has_ffn:
            out.append("moe" if cfg.is_moe else "ffn")
    return tuple(out)


def sublayer_params(cfg, params):
    """Unstacked per-sublayer param list aligned with sublayer_kinds."""
    out = []
    for g, gp in zip(layer_groups(cfg), params["groups"]):
        for i in range(g.count):
            lp = jax.tree.map(lambda t: t[i], gp)
            out.append({"norm": lp["norm1"], "p": lp["temporal"],
                        "kind": g.kind})
            if cfg.has_ffn:
                out.append({"norm": lp["norm2"], "p": lp["ffn"],
                            "kind": "moe" if cfg.is_moe else "ffn"})
    return out


def forward_compressed(cfg, params, units, batch):
    """Forward through compressed units (see transformer_host.build_units).

    ``units`` is a list of ('orig', sub) | ('merged', (u, v)) | ('skip',)
    produced from a CompressionPlan; python loop is fine — compressed nets
    are shallow by construction.
    """
    x = _embed_in(cfg, params, batch)
    positions = batch.get("positions")
    if positions is None:
        positions = jnp.arange(x.shape[1])[None, :]
    mrope = batch.get("mrope_positions")
    for unit in units:
        if unit[0] == "skip":
            continue
        if unit[0] == "merged":
            u, v = unit[1]
            x = L.merged_ffn(u, v, x)
            continue
        sub = unit[1]
        h = L.rms_norm(x, sub["norm"], cfg.norm_eps)
        kind = sub["kind"]
        if kind in ("attn", "attn_local", "rglru", "mlstm", "slstm"):
            t = _temporal_apply(cfg, kind, sub["p"], h, positions, mrope)
        elif kind == "moe":
            t = MOE.moe_ffn(sub["p"], h, cfg,
                            capacity_factor=cfg.capacity_factor)
        else:
            t = L.ffn(sub["p"], h, cfg.ffn_kind)
        x = x + t
    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    return _unembed(cfg, params, x)
