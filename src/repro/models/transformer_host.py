"""Host adapter: transformer stacks → the generic LayerMerge core.

Sublayer chain (1-based): temporal and FFN blocks interleaved
(``transformer.sublayer_kinds``), plus a virtual ``head`` boundary at the
end (growth 0, zero latency, always kept) so segments may end at the top of
the stack.  Block capability model per DESIGN §2.3:

* FFN / GLU-FFN — prunable, linearizable with growth = min(d_ff, d): the
  rank of the residual map (the Eq. 1 analogue).  Linearization folds the
  pre-norm scale into W_up and (for GLU) keeps the value path.
* attention / MoE / RG-LRU / mLSTM / sLSTM — prunable, NOT linearizable.

Merged segments execute as one fused rank-k residual layer (the Pallas
``merged_ffn`` kernel on TPU).  Plans lower to the shared unit IR via
``lower_plan`` and run through :mod:`repro.runtime.executor` — the same
path ``examples/serve_lm.py --artifact`` serves.
"""
from __future__ import annotations

import dataclasses
import functools
import hashlib

import jax
import jax.numpy as jnp

from repro.core import table_cache
from repro.core import merge as M
from repro.kernels import quant as Q
from repro.core.latency import CostBreakdown, matmul_cost, rank_ffn_cost
from repro.core.plan import CompressionPlan, LayerDesc, Segment
from repro.core.probe_engine import ProbeCallable
from repro.core.segments import SegmentEnumerator
from repro.runtime import executor, ir

from . import transformer as T

LINEARIZABLE = ("ffn",)
HEAD_KIND = "head"


@dataclasses.dataclass
class CostEnv:
    """Workload/hardware context for the analytic latency table.

    ``w_bytes``/``act_bytes`` split the merged rank maps' weight vs.
    activation byte widths (None → ``dtype_bytes``, bit-identical to the
    historical single scalar); per-segment quantization overrides both
    via ``segment_cost(seg, quant=...)``.
    """
    batch: int = 8
    seq: int = 2048
    chips: int = 1
    dtype_bytes: int = 2
    w_bytes: int | None = None
    act_bytes: int | None = None


@dataclasses.dataclass
class TransformerHost:
    cfg: object
    params: dict
    env: CostEnv = dataclasses.field(default_factory=CostEnv)
    max_span: int | None = None

    def __post_init__(self):
        self.kinds = T.sublayer_kinds(self.cfg) + (HEAD_KIND,)
        self.subparams = T.sublayer_params(self.cfg, self.params) + [None]
        self._descs = self._build_descs()

    # -- chain description -----------------------------------------------------
    def _build_descs(self):
        d = self.cfg.d_model
        descs = []
        for i, kind in enumerate(self.kinds):
            idx = i + 1
            if kind == HEAD_KIND:
                descs.append(LayerDesc(index=idx, kind=kind, growth=0,
                                       value=0.0, prunable=False,
                                       linearizable=False))
                continue
            sp = self.subparams[i]
            val = float(sum(jnp.sum(jnp.abs(x))
                            for x in jax.tree.leaves(sp["p"])))
            if kind == "ffn":
                descs.append(LayerDesc(
                    index=idx, kind=kind, growth=min(self.cfg.d_ff, d),
                    value=val, prunable=True, linearizable=True))
            else:
                descs.append(LayerDesc(index=idx, kind=kind, growth=0,
                                       value=val, prunable=True,
                                       linearizable=False))
        return descs

    def descs(self):
        return self._descs

    def enumerator(self, method: str = "layermerge") -> SegmentEnumerator:
        return SegmentEnumerator(
            self._descs, offset=0, cap=self.cfg.d_model,
            depth_mode=(method == "depth"), max_span=self.max_span)

    def original_k(self, l: int) -> int:
        return 0        # offset-0 convention: singleton original has k = 0

    def pruned_k(self, l: int) -> int:
        return 0

    # -- latency ------------------------------------------------------------
    def _block_cost(self, kind, idx=None) -> CostBreakdown:
        cfg, env = self.cfg, self.env
        d = cfg.d_model
        tokens = env.batch * env.seq / max(env.chips, 1)
        by = env.dtype_bytes
        if kind == HEAD_KIND:
            return CostBreakdown(0.0, 0.0)
        if kind in ("attn", "attn_local"):
            hd = cfg.head_dim
            qk = matmul_cost(tokens, d, (cfg.num_heads + cfg.num_kv_heads * 2)
                             * hd, by) + matmul_cost(tokens, cfg.num_heads * hd,
                                                     d, by)
            span = min(cfg.local_window or env.seq, env.seq)
            attn_flops = 4.0 * tokens * span * cfg.num_heads * hd
            return qk + CostBreakdown(attn_flops, tokens * span * by / 64)
        if kind == "ffn":
            mult = 3 if cfg.ffn_kind in ("swiglu", "geglu") else 2
            return CostBreakdown(*[x * mult / 2 for x in
                                   dataclasses.astuple(
                                       matmul_cost(tokens, d, cfg.d_ff, by)
                                       + matmul_cost(tokens, cfg.d_ff, d, by))])
        if kind == "moe":
            active = cfg.experts_per_token * 3
            c = matmul_cost(tokens, d, cfg.moe_dff, by)
            return CostBreakdown(c.flops * active, c.hbm_bytes * active,
                                 2.0 * tokens * d * by)   # a2a dispatch
        if kind in ("rglru",):
            dr = cfg.rnn_width or d
            return (matmul_cost(tokens, d, dr, by) * 2
                    + matmul_cost(tokens, dr, 2 * dr, by)
                    + CostBreakdown(8.0 * tokens * dr, 2 * tokens * dr * by))
        if kind in ("mlstm", "slstm"):
            return (matmul_cost(tokens, d, 4 * d, by)
                    + CostBreakdown(12.0 * tokens * d, 4 * tokens * d * by))
        raise ValueError(kind)

    def segment_cost(self, seg: Segment, quant: str = "none"
                     ) -> CostBreakdown | None:
        """Analytic segment cost; ``quant`` prices the merged rank maps
        at narrow byte widths.  Returns ``None`` when a quantized cost is
        requested for a segment with no merged low-rank part (the kept
        boundary sublayer is never quantized) — the table builder's
        ineligibility signal."""
        cfg, env = self.cfg, self.env
        q = quant if quant != "none" else seg.quant
        tokens = env.batch * env.seq / max(env.chips, 1)
        boundary_kind = self.kinds[seg.j - 1]
        cost = self._block_cost(boundary_kind)
        interior_kept = [l for l in seg.kept if l != seg.j]
        rank = 0
        if interior_kept or seg.j - seg.i > 1:
            rank = min(seg.k, cfg.d_model)
        if rank > 0:
            wb = Q.weight_bytes(q) or env.w_bytes
            ab = Q.act_bytes(q) or env.act_bytes
            cost = cost + rank_ffn_cost(tokens, cfg.d_model, rank,
                                        env.dtype_bytes, w_bytes=wb,
                                        act_bytes=ab)
        elif q != "none":
            return None
        return cost

    def probe_signature(self, seg: Segment):
        """Latency-bucketing signature: boundary kind + effective rank.

        Both ``segment_cost`` and the timed unit chain depend on the
        segment only through the boundary block's kind and the merged
        residual rank (``min(k, d_model)``; 0 when nothing is merged) —
        weight values never enter, so one probe serves the whole bucket.
        """
        interior_kept = [l for l in seg.kept if l != seg.j]
        rank = min(seg.k, self.cfg.d_model) \
            if (interior_kept or seg.j - seg.i > 1) else 0
        return ("tseg", self.kinds[seg.j - 1], rank, self.env.batch,
                self.env.seq, self.env.chips, self.env.dtype_bytes,
                self.env.w_bytes, self.env.act_bytes, self.cfg.d_model)

    def segment_probe(self, seg: Segment, params=None) -> ProbeCallable:
        """Jitted merged-segment forward as (fn, args) — AOT-lowerable."""
        params = params or self.params
        units = self._segment_units(seg, params)
        x = jnp.zeros((max(self.env.batch, 1), max(self.env.seq, 8),
                       self.cfg.d_model), jnp.float32)

        @jax.jit
        def fn(x):
            return executor.run_units(self.cfg, units, x)
        return ProbeCallable(fn, (x,))

    def segment_callable(self, seg: Segment, params=None):
        """Zero-arg jitted merged-segment forward for wall-clock timing."""
        probe = self.segment_probe(seg, params)
        return lambda: probe.fn(*probe.args)

    def fingerprint(self) -> str:
        """Content digest for the on-disk table cache (see CNNHost)."""
        h = hashlib.sha256()
        h.update(repr((self.cfg, dataclasses.astuple(self.env),
                       self.max_span, self.kinds)).encode())
        h.update(table_cache.pytree_digest(self.params).encode())
        h.update(table_cache.machine_token().encode())
        return h.hexdigest()

    # -- unit construction -----------------------------------------------------
    def _linear_factors(self, sub):
        """(U, V) of one linearized FFN: norm scale folded into W_up."""
        g = sub["norm"]
        u = sub["p"]["w_up"] * (1.0 + g)[:, None]
        v = sub["p"]["w_down"]
        return u, v

    def _sublayer_unit(self, sub) -> ir.SublayerUnit:
        return ir.SublayerUnit(sub_kind=sub["kind"],
                               params={"norm": sub["norm"], "p": sub["p"]})

    def _segment_units(self, seg: Segment, params, merged: bool = True):
        """Lower one segment to IR units: the merged (or unmerged) rank
        maps of its kept linearizable interior + the kept boundary block."""
        units: list = []
        kept = set(seg.kept)
        subs = T.sublayer_params(self.cfg, params) + [None]
        boundary = None if self.kinds[seg.j - 1] == HEAD_KIND else seg.j
        factors = []
        for l in seg.layers:
            if l == boundary or self.kinds[l - 1] == HEAD_KIND:
                continue
            if l in kept:
                factors.append(self._linear_factors(subs[l - 1]))
        if factors:
            if merged:
                u, v = M.merge_linear_residual_chain(factors)
                u, v = M.truncate_rank(u, v, self.cfg.d_model)
                qp = {"u": u, "v": v}
                if seg.quant != "none":
                    # Deployed form only: narrow u/v + per-output-channel
                    # scales (the replaced/fine-tune path stays fp).
                    uq, us = Q.quantize_weight(u, seg.quant, axis=1)
                    vq, vs = Q.quantize_weight(v, seg.quant, axis=1)
                    qp = {"u": uq, "v": vq, "u_scale": us, "v_scale": vs}
                units.append(ir.LowRankUnit(quant=seg.quant, params=qp))
            else:
                for u, v in factors:                   # unmerged rank maps
                    units.append(ir.LowRankUnit(params={"u": u, "v": v}))
        if boundary is not None and boundary in kept:
            units.append(self._sublayer_unit(subs[boundary - 1]))
        return units

    def build_units(self, plan: CompressionPlan, params, merged: bool = True):
        units: list = []
        for seg in plan.segments:
            if seg.original:
                if self.kinds[seg.j - 1] != HEAD_KIND:
                    units.append(self._sublayer_unit(
                        T.sublayer_params(self.cfg, params)[seg.j - 1]))
                continue
            units.extend(self._segment_units(seg, params, merged=merged))
        return units

    # -- plan lowering / network builders ------------------------------------------
    def lower_plan(self, plan: CompressionPlan, params=None,
                   merged: bool = True) -> ir.UnitGraph:
        """Lower a plan to the shared unit IR, with frontend/head attached.

        ``merged=False`` keeps each kept FFN as its own rank map (the
        *replaced* network of Algorithm 2 — what fine-tuning trains);
        ``merged=True`` composes them per segment (the deployed form).
        """
        params = params or self.params
        cfg = self.cfg
        units = tuple(self.build_units(plan, params, merged=merged))
        gparams = {"final_norm": params["final_norm"]}
        if cfg.frontend == "tokens":
            gparams["embed"] = params["embed"]
        if not cfg.tie_embeddings or cfg.frontend != "tokens":
            gparams["unembed"] = params["unembed"]
        return ir.annotate_axes(ir.UnitGraph(
            family="transformer", units=units, params=gparams,
            meta={"config": cfg}))

    def replaced_apply(self, plan: CompressionPlan, params=None):
        params = params or self.params

        def apply_fn(p, batch):
            return executor.execute(
                self.lower_plan(plan, p, merged=False), batch)
        return apply_fn, params

    def merged_apply(self, plan: CompressionPlan, params=None):
        params = params or self.params

        def apply_fn(p, batch):
            return executor.execute(
                self.lower_plan(plan, p, merged=True), batch)
        return apply_fn, params


def abstract_plan(cfg, *, budget_ratio: float, env: CostEnv,
                  P: int = 500, method: str = "layermerge"):
    """Compute a compression plan WITHOUT materializing parameters.

    Uses growth-proportional ℓ1 proxies (value = growth per sublayer) and
    the analytic v5e latency oracle — exactly the table machinery of the
    paper, minus measured importance.  This is how the dry-run lowers a
    LayerMerge-compressed network at full production scale (§Perf)."""
    from repro.core.compress import compress as _compress

    kinds = T.sublayer_kinds(cfg) + (HEAD_KIND,)
    d = cfg.d_model
    descs = []
    for i, kind in enumerate(kinds):
        idx = i + 1
        if kind == HEAD_KIND:
            descs.append(LayerDesc(idx, kind, 0, 0.0, False, False))
        elif kind == "ffn":
            descs.append(LayerDesc(idx, kind, min(cfg.d_ff, d),
                                   float(min(cfg.d_ff, d)), True, True))
        else:
            descs.append(LayerDesc(idx, kind, 0, float(d), True, False))
    proto = TransformerHost.__new__(TransformerHost)
    proto.cfg = cfg
    proto.env = env
    proto.kinds = kinds
    proto._descs = descs
    proto.max_span = None
    host = proto
    return _compress(host, budget_ratio=budget_ratio, P=P, method=method,
                     importance="magnitude")


def plan_units_spec(cfg, plan) -> list:
    """Static unit descriptors for a plan: ('merged', rank) |
    ('orig', sublayer_index, kind).  Abstractly instantiable."""
    kinds = T.sublayer_kinds(cfg) + (HEAD_KIND,)
    out = []
    for seg in plan.segments:
        kept = set(seg.kept)
        boundary = None if kinds[seg.j - 1] == HEAD_KIND else seg.j
        if seg.original:
            if boundary is not None:
                out.append(("orig", seg.j, kinds[seg.j - 1]))
            continue
        rank = 0
        for l in seg.layers:
            if l != boundary and kinds[l - 1] == "ffn" and l in kept:
                rank += min(cfg.d_ff, cfg.d_model)
        rank = min(rank, cfg.d_model)
        if rank > 0:
            out.append(("merged", rank))
        if boundary is not None and boundary in kept:
            out.append(("orig", boundary, kinds[boundary - 1]))
    return out


def init_compressed_model(cfg, units_spec, key):
    """Real (or eval_shape-abstract) params for a compressed unit chain."""
    import jax.random as jrandom

    from . import layers as L
    dtype = T._dtype(cfg)
    keys = jrandom.split(key, len(units_spec) + 2)
    unit_params = []
    for i, spec in enumerate(units_spec):
        if spec[0] == "merged":
            r = spec[1]
            d = cfg.d_model
            unit_params.append({
                "u": jrandom.normal(keys[i], (d, r), dtype) * 0.02,
                "v": jrandom.normal(keys[i], (r, d), dtype) * 0.02})
        else:
            _, _, kind = spec
            p, _ = T._init_layer(
                cfg, kind if kind not in ("ffn", "moe") else
                cfg.layer_kinds()[0], keys[i], dtype)
            if kind in ("ffn", "moe"):
                unit_params.append({"norm": p["norm2"], "p": p["ffn"]})
            else:
                unit_params.append({"norm": p["norm1"], "p": p["temporal"]})
    params = {"units": unit_params}
    params["final_norm"], _ = L.init_rmsnorm(cfg.d_model, dtype)
    if cfg.frontend == "tokens":
        params["embed"], _ = L.init_embedding(cfg.vocab_size, cfg.d_model,
                                              keys[-1], dtype)
    if not cfg.tie_embeddings or cfg.frontend != "tokens":
        import math
        params["unembed"] = jrandom.normal(
            keys[-2], (cfg.d_model, cfg.vocab_size), dtype) \
            / math.sqrt(cfg.d_model)
    return params


def compressed_model_axes(cfg, units_spec):
    from . import layers as L
    from . import moe as MOE
    from . import rglru as RG
    from . import xlstm as XL
    ax_units = []
    for spec in units_spec:
        if spec[0] == "merged":
            ax_units.append({"u": ("embed", "rank"), "v": ("rank", "embed")})
        else:
            kind = spec[2]
            if kind in ("attn", "attn_local"):
                a = L.attention_axes(cfg)
            elif kind == "moe":
                a = MOE.moe_axes()
            elif kind == "ffn":
                a = L.ffn_axes(cfg.ffn_kind)
            elif kind == "rglru":
                a = RG.rglru_axes()
            elif kind == "mlstm":
                a = XL.mlstm_axes()
            else:
                a = XL.slstm_axes()
            ax_units.append({"norm": ("embed",), "p": a})
    axes = {"units": ax_units, "final_norm": ("embed",)}
    if cfg.frontend == "tokens":
        axes["embed"] = ("vocab", "embed")
    if not cfg.tie_embeddings or cfg.frontend != "tokens":
        axes["unembed"] = ("embed", "vocab")
    return axes


def forward_compressed_spec(cfg, units_spec, params, batch):
    """Plan-aware forward from spec + params (dry-run / production path)."""
    units = []
    for spec, p in zip(units_spec, params["units"]):
        if spec[0] == "merged":
            units.append(("merged", (p["u"], p["v"])))
        else:
            units.append(("orig", {"norm": p["norm"], "p": p["p"],
                                   "kind": spec[2]}))
    return T.forward_compressed(cfg, params, units, batch)


