"""Distribution-layer tests — run in subprocesses with forced host device
counts (the main test process must keep seeing 1 device)."""
import pytest

from repro.testing.subproc import run_code, run_module


def run_sub(code, devices=8, timeout=600):
    return run_code(code, devices=devices, timeout=timeout).stdout


def test_flash_decode_lse_combine():
    """Seq-sharded decode attention (flash-decoding) equals the full-cache
    oracle on a 2×4 mesh."""
    out = run_sub("""
        import jax, jax.numpy as jnp
        from repro.sharding.collectives import (flash_decode_attention,
                                                flash_decode_reference)
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        ks = jax.random.split(jax.random.PRNGKey(0), 3)
        q = jax.random.normal(ks[0], (2, 4, 16))
        k = jax.random.normal(ks[1], (2, 32, 4, 16))
        v = jax.random.normal(ks[2], (2, 32, 4, 16))
        valid = jnp.broadcast_to(jnp.arange(32)[None] < 23, (2, 32))
        out = flash_decode_attention(q, k, v, valid, mesh=mesh, axis="model")
        ref = flash_decode_reference(q, k, v, valid)
        assert float(jnp.abs(out - ref).max()) < 1e-5
        print("LSE_OK")
    """)
    assert "LSE_OK" in out


def test_gpipe_pipeline_forward():
    out = run_sub("""
        import jax, jax.numpy as jnp
        from repro.sharding.collectives import gpipe_forward
        mesh = jax.make_mesh((4, 2), ("pod", "model"))
        wp = jax.random.normal(jax.random.PRNGKey(0), (4, 8, 8)) * 0.4
        x = jax.random.normal(jax.random.PRNGKey(1), (8, 8))
        stage = lambda w, xm: jnp.tanh(xm @ w)
        y = gpipe_forward(stage, wp, x, mesh=mesh, axis="pod", num_micro=4)
        ref = x
        for i in range(4):
            ref = stage(wp[i], ref)
        assert float(jnp.abs(y - ref).max()) < 1e-5
        print("GPIPE_OK")
    """)
    assert "GPIPE_OK" in out


def test_sharded_train_step_matches_single_device():
    """FSDP+TP sharded train_step produces the same loss/params as the
    unsharded single-device step (SPMD correctness)."""
    out = run_sub("""
        import dataclasses
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs import get_config
        from repro.models import transformer as T
        from repro.optim.adamw import AdamWConfig, init_opt_state
        from repro.train.step import make_train_step
        from repro.sharding.rules import (make_rules, use_rules,
                                          param_shardings_with_shapes)
        cfg = dataclasses.replace(
            get_config("smollm-135m"), num_layers=2, d_model=64, num_heads=4,
            num_kv_heads=2, head_dim=16, d_ff=128, vocab_size=64,
            dtype="float32", remat=False)
        params, axes = T.init_model(cfg, jax.random.PRNGKey(0))
        opt = init_opt_state(params)
        batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1),
                                              (8, 16), 0, 64),
                 "targets": jax.random.randint(jax.random.PRNGKey(2),
                                               (8, 16), 0, 64),
                 "positions": jnp.broadcast_to(jnp.arange(16)[None], (8, 16))}
        step = make_train_step(cfg, AdamWConfig(lr=1e-2))
        p1, o1, m1 = jax.jit(step)(params, opt, batch)

        mesh = jax.make_mesh((4, 2), ("data", "model"))
        rules = make_rules(mesh, fsdp=True)
        pshard = param_shardings_with_shapes(rules, axes, params)
        with use_rules(rules):
            jitted = jax.jit(step, in_shardings=(pshard, None, None),
                             out_shardings=(pshard, None, None))
            p2, o2, m2 = jitted(params, opt, batch)
        assert abs(float(m1["loss"]) - float(m2["loss"])) < 1e-4
        for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=2e-4, atol=2e-4)
        print("SPMD_OK")
    """)
    assert "SPMD_OK" in out


def test_compressed_allreduce_matches_exact():
    out = run_sub("""
        import jax, jax.numpy as jnp
        from repro.sharding.collectives import compressed_allreduce
        mesh = jax.make_mesh((8,), ("data",))
        g = {"w": jax.random.normal(jax.random.PRNGKey(0), (8, 64))}
        out = compressed_allreduce(g, mesh=mesh, axis="data")
        exact = jnp.broadcast_to(g["w"].sum(0, keepdims=True), (8, 64))
        rel = float(jnp.abs(out["w"] - exact).max()
                    / (jnp.abs(exact).max() + 1e-9))
        assert rel < 0.02, rel
        print("CAR_OK")
    """)
    assert "CAR_OK" in out


@pytest.mark.slow
def test_dryrun_single_cell_end_to_end():
    """The dry-run driver itself: one full cell at 512 devices, both meshes
    (this is the minimum multi-pod acceptance check inside CI)."""
    r = run_module("repro.launch.dryrun", "--arch", "smollm-135m",
                   "--shape", "decode_32k", "--mesh", "both", "--out",
                   "/tmp/dryrun_test", timeout=1200)
    assert "0 failures" in r.stdout