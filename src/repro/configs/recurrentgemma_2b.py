"""recurrentgemma-2b [arXiv:2402.19427; hf] — RG-LRU + local attn, 1:2.

Pattern (Griffin): (recurrent, recurrent, local-attention) repeating; MQA
(kv=1) on the attention blocks, GeGLU FFN, local window 2048.
"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="recurrentgemma-2b", family="hybrid",
    num_layers=26, d_model=2560, num_heads=10, num_kv_heads=1,
    d_ff=7680, vocab_size=256000,
    ffn_kind="geglu",
    temporal_pattern=("rglru", "rglru", "attn_local"),
    local_window=2048, rnn_width=2560,
    tie_embeddings=True,
    source="arXiv:2402.19427; RG-LRU + local attn 1:2, window 2048",
)
