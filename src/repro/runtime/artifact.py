"""Portable merged-model artifacts — compress once, deploy everywhere.

An artifact is ONE ``.npz`` file holding everything a consumer needs to
run a compressed network without re-running the pipeline:

* ``__spec__``  — JSON: format version, graph family, static unit
  records (:func:`repro.runtime.ir.unit_static`), graph meta (the
  transformer ``ArchConfig`` as a plain dict), the compression plan
  (``CompressionPlan.to_json`` payload), and caller metadata (which
  latency oracle certified the plan, measured latencies, source seed);
* ``u<i>/<keypath>`` — the merged weights of unit ``i``, flattened by
  key-path exactly like :mod:`repro.checkpoint.ckpt`;
* ``g/<keypath>`` — graph-level params (embed / final norm / unembed /
  classifier head);
* ``__fingerprint__`` — sha256 over the canonical spec JSON plus every
  array's key, dtype, shape, and raw bytes (the same content-hash style
  as :func:`repro.core.table_cache.pytree_digest`).

Publish is atomic (write ``path + '.tmp'``, then rename — the
checkpoint/table-cache crash contract), and :func:`load` re-verifies the
fingerprint, so a reader never observes a torn or bit-rotted artifact as
valid: corruption raises :class:`ArtifactError` instead of mis-parsing.

Format v2 adds the sharding contract: each unit static carries its
``axes`` record and the spec carries ``global_axes`` (logical axis names
per param keypath, see :mod:`repro.runtime.ir`).  ``load(path, rules=)``
resolves those names through a :class:`ShardingRules` and ``device_put``s
every array STRAIGHT to its ``NamedSharding`` — no replicated host-side
copy is materialized on the devices first.  v1 artifacts (no
annotations) still load, as fully replicated graphs.

Format v3 adds per-unit precision: a quantized unit's static record
carries ``quant`` ('int8' | 'w8a8' | 'fp8'), its weights are stored
narrow, and its symmetric per-output-channel scales travel as ordinary
param arrays (``w_scale`` / ``u_scale`` / ``v_scale``) with their own
logical-axes annotations — no side-channel blobs, so the fingerprint,
sharding, and crash contracts cover them unchanged.  v1/v2 artifacts
(no ``quant`` field) still load: the unit dataclass default 'none' is
exactly the fp semantics they were saved with.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import os
import zipfile
from typing import Any

import jax
import numpy as np

from . import ir

FORMAT_VERSION = 3
SUPPORTED_FORMATS = (1, 2, 3)


class ArtifactError(RuntimeError):
    """Raised when an artifact is missing, torn, corrupt, or stale."""


# ---------------------------------------------------------------------------
# Pytree <-> flat key-path arrays
# ---------------------------------------------------------------------------

def _flatten(tree) -> dict[str, np.ndarray]:
    from repro.checkpoint.ckpt import flatten_leaves
    return flatten_leaves(tree)


def _listify(node):
    if not isinstance(node, dict):
        return node
    node = {k: _listify(v) for k, v in node.items()}
    if node and all(k.isdigit() for k in node):
        return [node[str(i)] for i in range(len(node))]
    return node


def _unflatten(flat: dict[str, Any]):
    """Rebuild the nested pytree from key-paths (digit components are
    list indices — parameter dict keys are never all-digit strings)."""
    root: dict = {}
    for key, val in flat.items():
        parts = key.split("/")
        node = root
        for p in parts[:-1]:
            node = node.setdefault(p, {})
        node[parts[-1]] = val
    return _listify(root)


# ---------------------------------------------------------------------------
# Spec construction
# ---------------------------------------------------------------------------

def _meta_to_spec(meta: dict) -> dict:
    out = dict(meta)
    cfg = out.get("config")
    if cfg is not None and dataclasses.is_dataclass(cfg):
        out["config"] = dataclasses.asdict(cfg)
    return out


def _meta_from_spec(spec_meta: dict) -> dict:
    out = dict(spec_meta)
    if "config" in out and isinstance(out["config"], dict):
        from repro.configs.base import ArchConfig

        d = dict(out["config"])
        d["temporal_pattern"] = tuple(d.get("temporal_pattern", ("attn",)))
        out["config"] = ArchConfig(**d)
    return out


def _payload(graph: ir.UnitGraph, plan=None, meta: dict | None = None):
    spec = {
        "format": FORMAT_VERSION,
        "family": graph.family,
        "graph_meta": _meta_to_spec(graph.meta),
        "global_axes": graph.axes,
        "meta": meta or {},
        "plan": json.loads(plan.to_json()) if plan is not None else None,
        "units": [ir.unit_static(u) for u in graph.units],
    }
    arrays: dict[str, np.ndarray] = {}
    for i, u in enumerate(graph.units):
        for k, v in _flatten(u.params).items():
            arrays[f"u{i:04d}/{k}"] = v
    for k, v in _flatten(graph.params).items():
        arrays[f"g/{k}"] = v
    return spec, arrays


def _digest(spec: dict, arrays: dict[str, np.ndarray]) -> str:
    h = hashlib.sha256()
    h.update(json.dumps(spec, sort_keys=True).encode())
    for key in sorted(arrays):
        arr = arrays[key]
        h.update(key.encode())
        h.update(str(arr.dtype).encode())
        h.update(str(arr.shape).encode())
        h.update(np.ascontiguousarray(arr).tobytes())
    return h.hexdigest()


def fingerprint(graph: ir.UnitGraph, plan=None, meta: dict | None = None
                ) -> str:
    """Content fingerprint of the artifact ``save`` would publish."""
    spec, arrays = _payload(graph, plan, meta)
    return _digest(spec, arrays)


# ---------------------------------------------------------------------------
# Save / load
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class CompressedArtifact:
    """A loaded merged-model artifact: the certified deployable object."""

    graph: ir.UnitGraph
    plan: Any                        # CompressionPlan | None
    fingerprint: str
    meta: dict                       # caller metadata recorded at save time
    path: str = ""

    def apply(self, inputs):
        """Forward pass (CNN image batch / transformer prefill batch)."""
        from . import executor
        return executor.execute(self.graph, inputs)

    def make_serve_step(self):
        """Jittable one-token decode step (transformer family)."""
        from . import executor
        return executor.make_serve_step(self.graph)

    def init_cache(self, batch_size: int, seq_len: int):
        from . import executor
        return executor.init_cache(self.graph, batch_size, seq_len)

    def executor(self, rules=None):
        """Mesh-aware jitted executor (see :class:`GraphExecutor`);
        pass the same ``rules`` the artifact was loaded with."""
        from . import executor
        return executor.GraphExecutor(self.graph, rules)


def save(path: str, graph: ir.UnitGraph, plan=None,
         meta: dict | None = None) -> str:
    """Atomically publish ``graph`` (+ plan + metadata) to ``path``.

    Returns the content fingerprint.  A crash mid-write leaves only a
    ``path + '.tmp'`` orphan, never a half-written artifact.  In a
    multi-process run only the main process writes the file
    (:func:`repro.launch.distributed.is_main` — the at-most-once publish
    contract); non-main processes still compute and return the
    fingerprint, so every process agrees on the artifact identity.
    """
    from repro.checkpoint.ckpt import atomic_writer
    from repro.launch.distributed import is_main

    spec, arrays = _payload(graph, plan, meta)
    fp = _digest(spec, arrays)
    if not is_main():
        return fp
    with atomic_writer(path) as f:
        np.savez(f, __spec__=np.array(json.dumps(spec)),
                 __fingerprint__=np.array(fp), **arrays)
    return fp


def _key_axes(spec: dict, key: str):
    """Recorded logical names of one array key ('u<i>/…' or 'g/…')."""
    if key.startswith("g/"):
        return spec.get("global_axes", {}).get(key[2:])
    idx, sub = key.split("/", 1)
    return spec["units"][int(idx[1:])].get("axes", {}).get(sub)


def _corrupt(path: str, msg: str) -> ArtifactError:
    """Quarantine a provably-corrupt artifact and build the error.

    The bad file is renamed to ``<path>.corrupt`` (the table-cache
    quarantine contract) so the next deploy/publish to the same path
    starts clean instead of tripping over the same bytes forever; the
    raised error names the quarantine destination and the recovery path.
    """
    from repro.core.table_cache import quarantine

    dst = quarantine(path)
    where = f" (quarantined to {dst})" if dst else ""
    return ArtifactError(
        f"{msg}{where}; re-publish with repro.runtime.save(...) or "
        "CompressResult.save(...)")


def load(path: str, rules=None) -> CompressedArtifact:
    """Load + verify an artifact; raises :class:`ArtifactError` when the
    file is missing, torn, corrupt, or from an unknown format version.

    Self-healing: a torn/corrupt/tampered file is **quarantined** —
    renamed to ``<path>.corrupt`` — before the error is raised, so the
    bad bytes cannot wedge every subsequent load or block a re-publish
    to the same path (the error message names the quarantine file and
    the recovery command).  A file from an *unsupported format version*
    is left in place — it may be valid under a different code version.

    With ``rules`` (a :class:`ShardingRules` over a live mesh), every
    array is ``device_put`` DIRECTLY to the ``NamedSharding`` its
    recorded logical axes resolve to — each device receives only its
    shard, instead of a replicated host copy being committed first.
    v1 artifacts carry no annotations and load fully replicated.
    """
    if not os.path.exists(path):
        raise ArtifactError(f"no artifact at {path}")
    try:
        with np.load(path, allow_pickle=False) as z:
            data = {k: z[k] for k in z.files}
    except (OSError, ValueError, zipfile.BadZipFile, KeyError) as e:
        raise _corrupt(path,
                       f"torn or unreadable artifact {path}: {e}") from e
    try:
        spec = json.loads(data.pop("__spec__").item())
        stored_fp = data.pop("__fingerprint__").item()
    except (KeyError, json.JSONDecodeError, ValueError) as e:
        raise _corrupt(path,
                       f"artifact {path} has no valid spec: {e}") from e
    if spec.get("format") not in SUPPORTED_FORMATS:
        raise ArtifactError(
            f"artifact {path} format {spec.get('format')!r} not in "
            f"{SUPPORTED_FORMATS}")
    if _digest(spec, data) != stored_fp:
        raise _corrupt(
            path, f"artifact {path} failed fingerprint verification "
            "(corrupt weights or tampered spec)")

    sharded = rules is not None and rules.mesh is not None
    unit_arrays: list[dict] = [{} for _ in spec["units"]]
    global_arrays: dict = {}
    for key, arr in data.items():
        if sharded:
            names = tuple(_key_axes(spec, key) or ())
            val = jax.device_put(arr, rules.named(names, arr.shape))
        else:
            val = jax.numpy.asarray(arr)
        if key.startswith("g/"):
            global_arrays[key[2:]] = val
        else:
            idx, sub = key.split("/", 1)
            unit_arrays[int(idx[1:])][sub] = val
    units = tuple(
        ir.unit_from_static(static, _unflatten(flat))
        for static, flat in zip(spec["units"], unit_arrays))
    graph = ir.UnitGraph(family=spec["family"], units=units,
                         params=_unflatten(global_arrays),
                         meta=_meta_from_spec(spec["graph_meta"]),
                         axes=spec.get("global_axes", {}))
    plan = None
    if spec.get("plan") is not None:
        from repro.core.plan import CompressionPlan
        plan = CompressionPlan.from_json(json.dumps(spec["plan"]))
    return CompressedArtifact(graph=graph, plan=plan, fingerprint=stored_fp,
                              meta=spec.get("meta", {}), path=path)
