"""Pallas TPU kernel: fused LayerMerge rank-r residual layer.

Computes ``y = x + (x @ U) @ V`` — the merged segment produced by the
rank-merge (DESIGN §2.1) — in ONE kernel: the intermediate ``P = x@U``
(shape bm×r) never round-trips to HBM, and the residual add is fused into
the second GEMM's epilogue.  This is the transformer analogue of the
paper's merged convolution: one launch for the whole merged segment.

Tiling: grid (i over m-tiles, j over d_out-tiles, k over rank-tiles), k
innermost.  For each m-row-panel the P panel (bm × r, fp32) is computed
once during the j==0 sweep and cached in VMEM scratch across the remaining
j sweeps (TPU grid iteration is sequential per core; scratch persists).
MXU-aligned tiles (multiples of 128), fp32 accumulation.

VMEM budget per step (bm=bn=bk=256, bd=512, r≤2048, bf16 operands):
  x panel 256×d·2 (streamed by blocks of bd), U tile d×256·2 (blocked),
  V tile 256×256·2, P scratch 256×2048·4 = 2 MiB, acc 256×256·4 = 256 KiB
  → well under the 16 MiB v5e VMEM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(x_ref, u_ref, v_ref, *rest, bd: int, n_dblocks: int, bk: int,
            bn: int, quant: bool = False, aq: bool = False):
    # Quantized path (quant=True): U/V are narrow (int8/fp8) with
    # per-channel fp32 scale operands — u_scale over the rank axis
    # (applied to the P panel at its phase-1 write; constant over the D
    # contraction) and v_scale over the output-embed axis (applied in the
    # epilogue; constant over the rank contraction).  aq=True (w8a8)
    # additionally takes an int8 activation panel ``xq`` whose per-tensor
    # scale is pre-folded into u_scale at the ops layer — the fp ``x``
    # operand stays for the exact residual add.
    if quant:
        if aq:
            us_ref, vs_ref, xq_ref, o_ref, p_ref, acc_ref = rest
        else:
            us_ref, vs_ref, o_ref, p_ref, acc_ref = rest
            xq_ref = x_ref
    else:
        us_ref = vs_ref = None
        xq_ref, (o_ref, p_ref, acc_ref) = x_ref, rest
    j = pl.program_id(1)
    k = pl.program_id(2)
    nk = pl.num_programs(2)

    # phase 1 (j == 0): build this m-panel's P[:, k-tile] = x @ U[:, k-tile]
    @pl.when(j == 0)
    def _():
        acc = jnp.zeros((x_ref.shape[0], bk), jnp.float32)
        for d in range(n_dblocks):
            xs = xq_ref[:, d * bd:(d + 1) * bd]
            us = u_ref[d * bd:(d + 1) * bd, :]
            acc = acc + jnp.dot(xs.astype(jnp.float32),
                                us.astype(jnp.float32),
                                preferred_element_type=jnp.float32)
        if us_ref is not None:
            acc = acc * us_ref[0].astype(jnp.float32)    # dequant P panel
        p_ref[:, pl.ds(k * bk, bk)] = acc

    # phase 2: acc += P[:, k-tile] @ V[k-tile, j-tile]
    @pl.when(k == 0)
    def _():
        acc_ref[...] = jnp.zeros_like(acc_ref)
    pk = p_ref[:, pl.ds(k * bk, bk)]
    acc_ref[...] += jnp.dot(pk, v_ref[...].astype(jnp.float32),
                            preferred_element_type=jnp.float32)

    # epilogue (last k): fused residual add + downcast
    @pl.when(k == nk - 1)
    def _():
        acc = acc_ref[...]
        if vs_ref is not None:
            acc = acc * vs_ref[0].astype(jnp.float32)    # dequant epilogue
        xj = x_ref[:, pl.ds(j * bn, bn)]
        o_ref[...] = (acc + xj.astype(jnp.float32)).astype(o_ref.dtype)


def merged_ffn(x, u, v, *, bm: int = 256, bn: int = 256, bk: int = 256,
               bd: int = 512, u_scale=None, v_scale=None, xq=None,
               interpret: bool = False):
    """x: (M, D); u: (D, R); v: (R, D) → (M, D).

    Shapes must tile evenly (``ops.merged_ffn_op`` pads); D and R should be
    multiples of 128 for MXU alignment.

    Quantized factors: pass ``u``/``v`` narrow (int8/fp8) with
    ``u_scale`` (per-rank-column, shape ``(R,)``) and ``v_scale``
    (per-output-embed-column, shape ``(D,)``) fp32 scales; both applied
    after the fp32 accumulations.  w8a8 adds ``xq`` — the int8 activation
    panel (its per-tensor scale pre-folded into ``u_scale``); the fp
    ``x`` stays the exact residual.
    """
    m, d = x.shape
    r = u.shape[1]
    assert u.shape[0] == d and v.shape == (r, d), (x.shape, u.shape, v.shape)
    quant = u_scale is not None
    assert quant == (v_scale is not None), "pass both scales or neither"
    assert xq is None or (quant and xq.shape == x.shape)
    bm, bn, bk, bd = min(bm, m), min(bn, d), min(bk, r), min(bd, d)
    assert m % bm == 0 and d % bn == 0 and r % bk == 0 and d % bd == 0, (
        "shapes must tile evenly; pad at the ops.py layer")
    grid = (m // bm, d // bn, r // bk)

    in_specs = [
        pl.BlockSpec((bm, d), lambda i, j, k: (i, 0)),       # x row panel
        pl.BlockSpec((d, bk), lambda i, j, k: (0, k)),       # U col tile
        pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),      # V tile
    ]
    operands = [x, u, v]
    if quant:
        in_specs += [pl.BlockSpec((1, bk), lambda i, j, k: (0, k)),
                     pl.BlockSpec((1, bn), lambda i, j, k: (0, j))]
        operands += [u_scale.reshape(1, r).astype(jnp.float32),
                     v_scale.reshape(1, d).astype(jnp.float32)]
        if xq is not None:
            in_specs.append(pl.BlockSpec((bm, d), lambda i, j, k: (i, 0)))
            operands.append(xq)

    kernel = functools.partial(_kernel, bd=bd, n_dblocks=d // bd, bk=bk,
                               bn=bn, quant=quant, aq=xq is not None)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, d), x.dtype),
        scratch_shapes=[
            pltpu.VMEM((bm, r), jnp.float32),     # P panel, persists over j
            pltpu.VMEM((bm, bn), jnp.float32),    # output accumulator
        ],
        interpret=interpret,
    )(*operands)
