"""qwen3-moe-30b-a3b [hf:Qwen/Qwen3-30B-A3B; hf]."""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="qwen3-moe-30b-a3b", family="moe",
    num_layers=48, d_model=2048, num_heads=32, num_kv_heads=4,
    head_dim=128,
    d_ff=0, vocab_size=151936,
    num_experts=128, experts_per_token=8, moe_dff=768,
    ffn_kind="swiglu", temporal_pattern=("attn",),
    source="hf:Qwen/Qwen3-30B-A3B; 128 experts top-8",
)
