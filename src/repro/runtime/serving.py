"""Serving: jitted chunked prefill, ``lax.scan`` decode, slot batching.

ONE protocol for every consumer of a one-token serve step — the original
stack (:func:`repro.train.step.make_serve_step`) and the artifact-backed
compressed executor (:func:`repro.runtime.executor.make_serve_step` /
:meth:`GraphExecutor.serve_step`) — so ``examples/serve_lm.py`` and
``benchmarks/bench_serve.py`` measure exactly the same thing for both
stacks.

Three layers, each built on the one below:

* :func:`serve_loop` — single-batch prefill + greedy decode.  Prefill is
  ONE jitted chunked call (a ``lax.scan`` over the prompt — not a Python
  dispatch per token) and decode is one jitted ``lax.scan`` that feeds
  each greedy argmax back in; the host touches the device twice, not
  ``P + N`` times.  :func:`serve_loop_pertoken` keeps the PR-4-era
  unjitted per-token loop as the dispatch-bound reference the serve
  bench compares against.
* :func:`generate_fused` — ONE scan over a slot batch with *per-slot*
  prompt lengths: while slot ``b`` still has prompt left the scan
  teacher-forces ``prompt[b, t]``, afterwards it feeds the slot's own
  previous greedy token — so a padded batch of ragged prompts runs
  prefill and decode in the same compiled program with no pad token
  ever entering a KV cache (exactness is tested against single-prompt
  serving).
* :func:`serve_requests` — the fixed-size slot scheduler: admit up to
  ``slots`` prompts per round into a padded batch, run the fused scan,
  retire the round, admit the next.  Under a mesh the slot axis is the
  'data' axis — many concurrent prompts decode data-parallel.

Every entry point takes ``rules=`` (a :class:`ShardingRules`) and traces
under it, so the same code serves one CPU device and a sharded mesh.

Failure semantics (the serving half of the crash-safety contract):

* **Non-finite guard** — the fused scan tracks, per slot, the first step
  whose logits went non-finite; that slot is *aborted* (its tokens from
  the failure on are deterministically zeroed, its greedy feedback is
  pinned so no NaN-argmax garbage re-enters the cache) while every other
  slot is bit-untouched — slots are batch-independent, so one poisoned
  request can never corrupt its round.
* **Budgets** — ``serve_requests`` accepts a per-request token budget
  (caps generated tokens) and a wall-clock budget; when the deadline
  passes, the scheduler **drains cleanly**: in-flight rounds retire
  normally, no new round is admitted, and never-admitted requests come
  back zeroed and named in the report.
* **Reporting** — ``serve_requests`` still unpacks as ``(gen, seconds)``
  (the return is a tuple subclass) but carries a :class:`ServeReport`
  on ``.report``: which requests completed / aborted (and at which
  token) / were never admitted.

The greedy-argmax / prompt-encoding glue the example and the bench used
to duplicate lives here too: :func:`greedy_token`, :func:`random_prompts`,
:func:`decode_tok_s`.
"""
from __future__ import annotations

import dataclasses
import time

import jax
import jax.numpy as jnp
from jax import lax

from repro.sharding.rules import use_rules


# ---------------------------------------------------------------------------
# Shared glue (hoisted from examples/serve_lm.py + benchmarks/bench_serve.py)
# ---------------------------------------------------------------------------

def greedy_token(logits):
    """Greedy sampling: ``(B, S, V)`` logits → ``(B,)`` next-token ids."""
    return jnp.argmax(logits[:, -1], axis=-1)


def random_prompts(seed: int, batch: int, prompt_len: int, vocab_size: int):
    """The example/bench prompt encoding: ``(B, P)`` random token ids."""
    return jax.random.randint(jax.random.PRNGKey(seed), (batch, prompt_len),
                              0, vocab_size)


def ragged_prompts(seed: int, n: int, min_len: int, max_len: int,
                   vocab_size: int):
    """``n`` random prompts of random lengths in ``[min_len, max_len]`` —
    the scheduler-workload encoding (list of 1-D int32 id arrays; feed
    through :func:`pad_prompts`)."""
    import numpy as np

    if not 1 <= min_len <= max_len:
        raise ValueError(f"need 1 <= min_len <= max_len, got "
                         f"[{min_len}, {max_len}]")
    rng = np.random.RandomState(seed)
    return [jnp.asarray(rng.randint(0, vocab_size,
                                    size=rng.randint(min_len, max_len + 1)),
                        jnp.int32)
            for _ in range(n)]


def decode_tok_s(tokens: int, batch: int, seconds: float) -> float:
    """Decode throughput; guards the div by tiny smoke timings."""
    return tokens * batch / max(seconds, 1e-9)


# ---------------------------------------------------------------------------
# Jitted single-batch serve loop (chunked prefill + scan decode)
# ---------------------------------------------------------------------------

def _prefill_chunk(step, params, cache, prompt):
    """One chunked prefill call: scan the step over the prompt axis.

    Returns the last-position logits ``(B, V)`` and the filled cache.
    """
    def body(cache, tok):
        logits, cache = step(params, cache, {"tokens": tok[:, None]})
        return cache, logits[:, -1]
    cache, logits = lax.scan(body, cache, prompt.T)
    return logits[-1], cache


def _decode_scan(step, params, cache, tok0, n: int):
    """Greedy decode scan: ``n`` tokens from ``tok0`` ``(B,)`` on."""
    def body(carry, _):
        tok, cache = carry
        logits, cache = step(params, cache, {"tokens": tok[:, None]})
        nxt = greedy_token(logits)
        return (nxt, cache), nxt
    (_, cache), toks = lax.scan(body, (tok0, cache), None, length=n)
    return toks.T, cache                                   # (B, n)


def serve_loop(step, params, cache, prompt, tokens: int, *, rules=None,
               warm: bool = True):
    """Drive ``step(params, cache, batch) → (logits, cache)``.

    Prefill is ONE jitted chunked call over the whole prompt; decode is
    ONE jitted ``lax.scan`` issuing ``tokens - 1`` greedy steps.  With
    ``warm`` (the benchmarking contract) both programs run once
    unmeasured first, so ``(prefill_s, decode_s)`` report steady-state
    serving, not compilation; pass ``warm=False`` to serve without the
    extra pass.  Returns
    ``(prefill_s, decode_s, last_logits (B, V), seqs (B, tokens))``.
    """
    prefill = jax.jit(lambda p, c, t: _prefill_chunk(step, p, c, t))
    decode = jax.jit(lambda p, c, t0: _decode_scan(step, p, c, t0,
                                                   tokens - 1))
    with use_rules(rules):
        if warm:
            jax.block_until_ready(prefill(params, cache, prompt))
        t0 = time.perf_counter()
        logits, cache = prefill(params, cache, prompt)
        jax.block_until_ready(logits)
        prefill_s = time.perf_counter() - t0

        tok = greedy_token(logits[:, None])
        if warm:
            jax.block_until_ready(decode(params, cache, tok))
        t0 = time.perf_counter()
        out, _ = decode(params, cache, tok)
        jax.block_until_ready(out)
        decode_s = time.perf_counter() - t0
    seqs = jnp.concatenate([tok[:, None], out], axis=1)
    return prefill_s, decode_s, logits, seqs


def serve_loop_pertoken(step, params, cache, prompt, tokens: int, *,
                        rules=None):
    """The PR-4 reference loop: a host round-trip per token, per prompt
    position (pass a ``jax.jit``-ed step to make each one exactly one
    XLA dispatch).  Kept so the serve bench can report how much the
    chunked/scan protocol buys on the same step."""
    logits = None
    with use_rules(rules):
        t0 = time.perf_counter()
        for t in range(prompt.shape[1]):
            logits, cache = step(params, cache,
                                 {"tokens": prompt[:, t:t + 1]})
        jax.block_until_ready(logits)
        prefill_s = time.perf_counter() - t0
        last = logits[:, -1]

        tok = greedy_token(logits)[:, None]
        out = [tok]
        t0 = time.perf_counter()
        for _ in range(tokens - 1):
            logits, cache = step(params, cache, {"tokens": tok})
            tok = greedy_token(logits)[:, None]
            out.append(tok)
        jax.block_until_ready(tok)
        decode_s = time.perf_counter() - t0
    return prefill_s, decode_s, last, jnp.concatenate(out, axis=1)


# ---------------------------------------------------------------------------
# Fused ragged-prompt generation (one scan = prefill + decode)
# ---------------------------------------------------------------------------

def generate_fused(step, params, cache, prompts, lengths, tokens: int, *,
                   logit_hook=None, with_report: bool = False):
    """One scan over a padded slot batch with per-slot prompt lengths.

    ``prompts``: ``(B, P)`` right-padded ids; ``lengths``: ``(B,)`` with
    ``1 <= lengths[b] <= P``.  At scan step ``t`` slot ``b`` consumes
    ``prompts[b, t]`` while ``t < lengths[b]`` (teacher-forced prefill)
    and its own previous greedy token afterwards (decode) — pad ids are
    never fed, so every slot's cache holds exactly its own sequence and
    the result matches serving that prompt alone.  Returns
    ``(gen (B, tokens), cache)``; the cache must cover ``P + tokens``
    positions.

    Non-finite guard: each step tracks, per slot, whether the logits are
    all-finite; a slot that goes bad feeds a pinned token 0 back (never a
    NaN-argmax) so the remaining slots of the batch are bit-untouched.
    With ``with_report`` the return gains a third element ``fail_idx
    (B,)``: the generation index at which each slot first saw non-finite
    logits (``tokens`` = never — healthy), with the aborted slot's tokens
    deterministically zeroed from that index on.

    ``logit_hook(logits, t) → logits`` runs inside the (jitted) scan just
    before the argmax — the deterministic injection point used by
    :func:`repro.testing.faults.nan_logits_hook`.
    """
    prompts = prompts.astype(jnp.int32)    # match the argmax carry dtype
    B, P = prompts.shape
    steps = P + tokens - 1
    toks_in = jnp.pad(prompts, ((0, 0), (0, steps - P)))   # (B, steps)

    def body(carry, xs):
        prev, cache = carry
        tok_t, t = xs
        inp = jnp.where(t < lengths, tok_t, prev)
        logits, cache = step(params, cache, {"tokens": inp[:, None]})
        if logit_hook is not None:
            logits = logit_hook(logits, t)
        ok = jnp.isfinite(logits).all(
            axis=tuple(range(1, logits.ndim)))             # (B,)
        nxt = jnp.where(ok, greedy_token(logits), 0)
        return (nxt, cache), (nxt, ok)

    init = (jnp.zeros((B,), prompts.dtype), cache)
    (_, cache), (samples, ok) = lax.scan(
        body, init, (toks_in.T, jnp.arange(steps)))
    # slot b's generation starts at the step that consumed its last
    # prompt token: samples[lengths[b] - 1 + i, b]
    idx = (lengths - 1)[:, None] + jnp.arange(tokens)[None, :]
    gen = jnp.take_along_axis(samples.T, idx, axis=1)
    if not with_report:
        return gen, cache
    bad = ~ok.T                                            # (B, steps)
    first_bad = jnp.where(bad.any(axis=1),
                          jnp.argmax(bad, axis=1), steps)  # scan step
    # A failure while the slot was still teacher-forcing (its cache is
    # poisoned before the first generated token) clips to index 0.
    fail_idx = jnp.clip(first_bad - (lengths - 1), 0, tokens)
    keep = jnp.arange(tokens)[None, :] < fail_idx[:, None]
    return jnp.where(keep, gen, 0), cache, fail_idx


# ---------------------------------------------------------------------------
# Fixed-slot batched request scheduler
# ---------------------------------------------------------------------------

def pad_prompts(prompts, pad_to: int | None = None):
    """Encode a list of 1-D id arrays as ``(R, P)`` padded ids + lengths.

    ``pad_to`` pins ``P`` (e.g. to keep one compiled scheduler program
    across calls); it must cover the longest prompt.
    """
    lengths = jnp.asarray([len(p) for p in prompts], jnp.int32)
    longest = int(lengths.max())
    P = longest if pad_to is None else pad_to
    if P < longest:
        raise ValueError(f"pad_to={pad_to} shorter than the longest "
                         f"prompt ({longest} tokens)")
    mat = jnp.stack([
        jnp.pad(jnp.asarray(p, jnp.int32), (0, P - len(p)))
        for p in prompts])
    return mat, lengths


@dataclasses.dataclass
class ServeReport:
    """Per-request outcome accounting for one :func:`serve_requests` call.

    ``aborted`` maps a request index to the generation index at which its
    logits first went non-finite (its tokens are zeroed from there on);
    ``unserved`` lists requests never admitted because the wall-clock
    budget expired (their rows are all zeros); everything else
    ``completed`` normally.  ``tokens_per_request`` is the effective
    generation length after the token budget.
    """

    completed: list[int] = dataclasses.field(default_factory=list)
    aborted: dict[int, int] = dataclasses.field(default_factory=dict)
    unserved: list[int] = dataclasses.field(default_factory=list)
    rounds: int = 0
    tokens_per_request: int = 0
    deadline_hit: bool = False

    @property
    def ok(self) -> bool:
        return not self.aborted and not self.unserved


class ServeOutput(tuple):
    """``(gen, seconds)`` (unpacks like the pre-report return) carrying
    the :class:`ServeReport` on ``.report``."""

    report: ServeReport

    def __new__(cls, gen, seconds, report):
        out = super().__new__(cls, (gen, seconds))
        out.report = report
        return out


def serve_requests(step, params, make_cache, prompts, lengths=None, *,
                   tokens: int, slots: int | None = None, rules=None,
                   warm: bool = True, token_budget: int | None = None,
                   time_budget_s: float | None = None, logit_hook=None):
    """Serve many prompts through fixed-size slot batching.

    ``prompts``: ``(R, P)`` padded ids (or a list of 1-D id arrays, in
    which case ``lengths`` is derived).  Up to ``slots`` prompts are
    admitted per round into a padded batch; one jitted
    :func:`generate_fused` program serves every round (short final
    rounds re-admit slot 0's prompt as filler and drop the duplicate
    results), then the round retires and the next is admitted.
    ``make_cache(batch_size, seq_len)`` builds a fresh per-round cache.

    Under mesh ``rules`` the slot axis is the 'data' mesh axis — rounds
    decode data-parallel.  Returns a :class:`ServeOutput` — unpacks as
    ``(gen (R, T), seconds)`` exactly like before, with the
    :class:`ServeReport` on ``.report`` — where ``seconds`` is
    steady-state wall clock with ``warm`` (one unmeasured pass over
    round 0's shapes first — the benchmarking contract; pass
    ``warm=False`` to serve without it).

    Hardening: ``token_budget`` caps generated tokens per request
    (``T = min(tokens, token_budget)``); ``time_budget_s`` bounds the
    measured serving wall clock — once exceeded, the scheduler drains
    cleanly (the in-flight round retires, no new round is admitted,
    never-admitted requests come back zeroed and listed in
    ``report.unserved``).  A slot whose logits go non-finite is aborted
    at that token (see :func:`generate_fused`) and recorded in
    ``report.aborted``; the other slots of its round are bit-untouched.
    ``logit_hook`` is threaded into the fused scan (fault injection).
    """
    if lengths is None:
        if getattr(prompts, "ndim", None) == 2:
            # a padded matrix has no recoverable lengths — deriving them
            # here would silently teacher-force pad tokens into caches
            raise ValueError("pass lengths= with a padded (R, P) matrix "
                             "(or pass the list of 1-D prompts)")
        if len(prompts) == 0:              # zero requests: nothing to pad
            prompts = jnp.zeros((0, 1), jnp.int32)
            lengths = jnp.zeros((0,), jnp.int32)
        else:
            prompts, lengths = pad_prompts(prompts)
    R, P = prompts.shape
    eff_tokens = tokens if token_budget is None \
        else max(1, min(tokens, token_budget))
    report = ServeReport(tokens_per_request=eff_tokens)
    if R == 0:                             # zero requests: nothing to trace
        return ServeOutput(jnp.zeros((0, eff_tokens), jnp.int32), 0.0,
                           report)
    slots = min(slots or R, R)

    fused = jax.jit(
        lambda p, c, pr, ln: generate_fused(step, p, c, pr, ln, eff_tokens,
                                            logit_hook=logit_hook,
                                            with_report=True))

    def round_batch(start):
        # short final round: re-admit request 0 as filler, results dropped
        idx = [start + i if start + i < R else 0 for i in range(slots)]
        return prompts[jnp.asarray(idx)], lengths[jnp.asarray(idx)]

    outs = []
    fails = []                             # (start, n, fail_idx) per round
    with use_rules(rules):
        if warm:
            pr0, ln0 = round_batch(0)
            jax.block_until_ready(
                fused(params, make_cache(slots, P + eff_tokens), pr0, ln0))
        t0 = time.perf_counter()
        for start in range(0, R, slots):
            if time_budget_s is not None \
                    and time.perf_counter() - t0 > time_budget_s:
                report.deadline_hit = True
                report.unserved.extend(range(start, R))
                outs.append(jnp.zeros((R - start, eff_tokens), jnp.int32))
                break
            pr, ln = round_batch(start)
            cache = make_cache(slots, P + eff_tokens)
            gen, _, fail_idx = fused(params, cache, pr, ln)
            n = min(slots, R - start)
            outs.append(gen[:n])
            fails.append((start, n, fail_idx))
            report.rounds += 1
        jax.block_until_ready(outs)
        seconds = time.perf_counter() - t0
    for start, n, fail_idx in fails:
        fail_np = jax.device_get(fail_idx)
        for b in range(n):
            if int(fail_np[b]) < eff_tokens:
                report.aborted[start + b] = int(fail_np[b])
            else:
                report.completed.append(start + b)
    return ServeOutput(jnp.concatenate(outs, axis=0), seconds, report)
