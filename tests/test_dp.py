"""Theorem 3.1 property tests: the DP solves Problem (5) exactly.

Random surrogate instances (tables with integer latencies so that the
discretization is lossless) are solved by both Algorithm 1 and exhaustive
enumeration; objectives must match exactly, and the DP's plan must be
feasible and achieve its reported objective.
"""
import math

import numpy as np
import pytest
from _hyp import given, settings, st

from repro.core.dp import brute_force, solve_dp, solve_knapsack


def make_instance(rng, L, max_k_opts=3, max_lat=10):
    """Random (i, j) -> {k: (I, T, kept)} table with integer latencies."""
    table = {}
    for i in range(L):
        for j in range(i + 1, L + 1):
            if j - i > 1 and rng.random() < 0.3:
                continue  # some spans unmergeable
            opts = {}
            for k in rng.choice(range(1, 12), size=rng.integers(1, max_k_opts + 1),
                                replace=False):
                imp = float(rng.random())
                lat = int(rng.integers(1, max_lat + 1))
                opts[int(k)] = (imp, float(lat), ())
            table[(i, j)] = opts
    return lambda i, j: table.get((i, j), {})


@given(seed=st.integers(0, 10_000), L=st.integers(2, 5),
       budget=st.integers(3, 40))
@settings(max_examples=60, deadline=None)
def test_dp_matches_brute_force(seed, L, budget):
    rng = np.random.default_rng(seed)
    table = make_instance(rng, L)
    P = budget          # unit latency grid: discretization is exact
    dp = solve_dp(L, table, float(budget), P)
    bf = brute_force(L, table, float(budget), P)
    if bf is None:
        assert dp is None
        return
    assert dp is not None
    assert dp.objective == pytest.approx(bf[0], rel=1e-9)


@given(seed=st.integers(0, 10_000), L=st.integers(2, 5),
       budget=st.integers(3, 40))
@settings(max_examples=40, deadline=None)
def test_dp_plan_is_feasible_and_consistent(seed, L, budget):
    rng = np.random.default_rng(seed)
    table = make_instance(rng, L)
    dp = solve_dp(L, table, float(budget), budget)
    if dp is None:
        return
    # segments tile (0, L]
    assert dp.plan.segments[0].i == 0
    assert dp.plan.segments[-1].j == L
    # reported objective & latency recompute from the table
    tot_i = tot_t = 0.0
    for s in dp.plan.segments:
        opts = table(s.i, s.j)
        assert s.k in opts
        tot_i += opts[s.k][0]
        tot_t += opts[s.k][1]
    assert tot_i == pytest.approx(dp.objective)
    assert tot_t == pytest.approx(dp.latency)
    # discretized feasibility (integer latencies: exact)
    assert tot_t <= budget + 1e-9


@given(seed=st.integers(0, 5_000), L=st.integers(1, 8),
       budget=st.integers(1, 30))
@settings(max_examples=40, deadline=None)
def test_knapsack_matches_enumeration(seed, L, budget):
    rng = np.random.default_rng(seed)
    imp = {l: float(rng.random()) for l in range(1, L + 1)}
    lat = {l: float(rng.integers(1, 8)) for l in range(1, L + 1)}
    forced = tuple(l for l in range(1, L + 1) if rng.random() < 0.2)
    sol = solve_knapsack(L, imp, lat, float(budget), budget, forced=forced)
    # exhaustive reference
    best = None
    for mask in range(2 ** L):
        C = [l for l in range(1, L + 1) if mask >> (l - 1) & 1]
        if any(f not in C for f in forced):
            continue
        t = sum(lat[l] for l in C)
        if t <= budget:
            v = sum(imp[l] for l in C)
            if best is None or v > best:
                best = v
    if best is None:
        assert sol is None
        return
    assert sol is not None
    assert sol[1] == pytest.approx(best, rel=1e-9)
    assert set(forced) <= set(sol[0])


def test_dp_respects_budget_monotonicity():
    rng = np.random.default_rng(0)
    table = make_instance(rng, 4)
    prev = -math.inf
    for budget in range(2, 30):
        dp = solve_dp(4, table, float(budget), budget)
        if dp is None:
            continue
        assert dp.objective >= prev - 1e-12
        prev = dp.objective


def test_infeasible_returns_none():
    table = lambda i, j: ({1: (1.0, 100.0, ())} if j - i == 1 else {})
    assert solve_dp(3, table, 10.0, 10) is None
