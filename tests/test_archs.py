"""Per-architecture smoke tests (reduced configs, CPU) + full-config sanity.

Each assigned arch instantiates a REDUCED config of the same family and runs
one forward + one train-grad + one decode step, asserting shapes and
finiteness.  KV-cache decode is checked against prefill logits for every
temporal-block family (full attention, local-window attention, RG-LRU,
mLSTM, sLSTM).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config
from repro.models import transformer as T


def _batch(cfg, B, S, key=0):
    k = jax.random.PRNGKey(key)
    batch = {"positions": jnp.broadcast_to(jnp.arange(S)[None], (B, S))}
    if cfg.frontend == "tokens":
        batch["tokens"] = jax.random.randint(k, (B, S), 0, cfg.vocab_size)
    else:
        batch["embeds"] = jax.random.normal(k, (B, S, cfg.d_model)) * 0.3
    if cfg.rope_kind == "mrope":
        batch["mrope_positions"] = jnp.broadcast_to(
            jnp.arange(S)[None, None], (3, B, S))
    batch["targets"] = jax.random.randint(jax.random.PRNGKey(key + 1),
                                          (B, S), 0, cfg.vocab_size)
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_reduced_smoke(arch):
    cfg = get_config(arch).reduced()
    params, axes = T.init_model(cfg, jax.random.PRNGKey(0))
    # axes tree mirrors params tree
    assert jax.tree.structure(jax.tree.map(lambda _: 0, params)) == \
        jax.tree.structure(jax.tree.map(
            lambda _: 0, axes, is_leaf=lambda x: isinstance(x, tuple)))
    B, S = 2, 16
    batch = _batch(cfg, B, S)
    logits = T.forward(cfg, params, batch)
    assert logits.shape == (B, S, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits)))
    loss, grads = jax.value_and_grad(
        lambda p: T.lm_loss(cfg, p, batch))(params)
    assert np.isfinite(float(loss))
    for leaf in jax.tree.leaves(grads):
        assert bool(jnp.all(jnp.isfinite(leaf)))


@pytest.mark.parametrize("arch", ["smollm-135m", "recurrentgemma-2b",
                                  "xlstm-125m", "qwen2-7b",
                                  "granite-moe-1b-a400m"])
def test_decode_matches_prefill(arch):
    """Sequential KV-cache decode reproduces teacher-forced prefill logits.

    MoE note: capacity-based routing drops tokens *competitively across the
    batch*, so prefill≡decode only holds when capacity is large enough that
    nothing drops — we pin capacity_factor high here (the artifact is
    inherent to capacity routing, not a bug; see models/moe.py).
    """
    import dataclasses
    cfg = get_config(arch).reduced()
    if cfg.is_moe:
        cfg = dataclasses.replace(cfg, capacity_factor=8.0)
    params, _ = T.init_model(cfg, jax.random.PRNGKey(1))
    B, S = 2, 10
    batch = _batch(cfg, B, S, key=3)
    ref = T.forward(cfg, params, batch)
    cache = T.init_cache(cfg, B, S)
    outs = []
    for t in range(S):
        db = {}
        if cfg.frontend == "tokens":
            db["tokens"] = batch["tokens"][:, t:t + 1]
        else:
            db["embeds"] = batch["embeds"][:, t:t + 1]
        if cfg.rope_kind == "mrope":
            db["mrope_positions"] = batch["mrope_positions"][:, :, t:t + 1]
        lg, cache = T.decode_step(cfg, params, cache, db)
        outs.append(lg)
    dec = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(ref),
                               rtol=2e-2, atol=2e-2)


def test_local_window_cache_ring_buffer():
    """Windowed decode with a ring buffer matches windowed prefill."""
    cfg = get_config("recurrentgemma-2b").reduced()
    assert cfg.local_window == 8
    params, _ = T.init_model(cfg, jax.random.PRNGKey(2))
    B, S = 1, 20          # longer than the window: buffer must wrap
    batch = _batch(cfg, B, S, key=5)
    ref = T.forward(cfg, params, batch)
    cache = T.init_cache(cfg, B, S)
    outs = []
    for t in range(S):
        db = {"tokens": batch["tokens"][:, t:t + 1]}
        lg, cache = T.decode_step(cfg, params, cache, db)
        outs.append(lg)
    dec = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(ref),
                               rtol=3e-2, atol=3e-2)


# -- full-config sanity (no allocation: counts only) --------------------------

EXPECTED_PARAMS = {
    "smollm-135m": (110e6, 180e6),
    "command-r-plus-104b": (90e9, 118e9),
    "qwen2-7b": (6.0e9, 8.5e9),
    "gemma-7b": (7.0e9, 10.0e9),
    "qwen3-moe-30b-a3b": (25e9, 34e9),
    "granite-moe-1b-a400m": (0.9e9, 1.6e9),
    "recurrentgemma-2b": (2.0e9, 3.4e9),
    "musicgen-large": (1.6e9, 2.6e9),
    "qwen2-vl-7b": (6.0e9, 8.5e9),
    "xlstm-125m": (0.05e9, 0.22e9),
}

ACTIVE_PARAMS = {
    "granite-moe-1b-a400m": (0.25e9, 0.60e9),
    "qwen3-moe-30b-a3b": (2.0e9, 4.5e9),
}


@pytest.mark.parametrize("arch", sorted(EXPECTED_PARAMS))
def test_full_config_param_count(arch):
    cfg = get_config(arch)
    lo, hi = EXPECTED_PARAMS[arch]
    n = cfg.param_count()
    assert lo <= n <= hi, f"{arch}: {n / 1e9:.2f}B outside [{lo/1e9},{hi/1e9}]B"


@pytest.mark.parametrize("arch", sorted(ACTIVE_PARAMS))
def test_moe_active_params(arch):
    cfg = get_config(arch)
    lo, hi = ACTIVE_PARAMS[arch]
    n = cfg.active_param_count()
    assert lo <= n <= hi, f"{arch}: active {n/1e9:.2f}B outside range"


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_exact_assigned_geometry(arch):
    """The config files carry the exact assigned geometry."""
    cfg = get_config(arch)
    expected = {
        "granite-moe-1b-a400m": (24, 1024, 16, 8, 49155),
        "qwen3-moe-30b-a3b": (48, 2048, 32, 4, 151936),
        "gemma-7b": (28, 3072, 16, 16, 256000),
        "command-r-plus-104b": (64, 12288, 96, 8, 256000),
        "qwen2-7b": (28, 3584, 28, 4, 152064),
        "smollm-135m": (30, 576, 9, 3, 49152),
        "recurrentgemma-2b": (26, 2560, 10, 1, 256000),
        "musicgen-large": (48, 2048, 32, 32, 2048),
        "qwen2-vl-7b": (28, 3584, 28, 4, 152064),
        "xlstm-125m": (12, 768, 4, 4, 50304),
    }[arch]
    got = (cfg.num_layers, cfg.d_model, cfg.num_heads, cfg.num_kv_heads,
           cfg.vocab_size)
    assert got == expected
