"""smollm-135m [hf:HuggingFaceTB/SmolLM-135M; hf] — llama-arch small."""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="smollm-135m", family="dense",
    num_layers=30, d_model=576, num_heads=9, num_kv_heads=3,
    d_ff=1536, vocab_size=49152,
    ffn_kind="swiglu", temporal_pattern=("attn",),
    tie_embeddings=True,
    source="hf:HuggingFaceTB/SmolLM-135M",
)
