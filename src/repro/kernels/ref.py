"""Pure-jnp oracles for every Pallas kernel in this package.

Each ``*_ref`` is the semantic ground truth: kernels are validated against
these in ``tests/test_kernels.py`` over shape/dtype sweeps (interpret=True
on CPU; compiled on real TPU).
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp
from jax import lax


def rmsnorm_ref(x, scale, eps: float = 1e-6):
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    y = x.astype(jnp.float32) * lax.rsqrt(var + eps)
    return (y * (1.0 + scale.astype(jnp.float32))).astype(x.dtype)


def merged_ffn_ref(x, u, v):
    """LayerMerge rank-r residual: x + (x@U)@V, fp32 accumulation."""
    h = jnp.dot(x.astype(jnp.float32), u.astype(jnp.float32))
    y = jnp.dot(h, v.astype(jnp.float32))
    return (x.astype(jnp.float32) + y).astype(x.dtype)


def merged_conv_ref(x, w, b=None, stride: int = 1):
    """VALID NHWC conv (stride ``s``) + bias — the merged-segment layer."""
    y = lax.conv_general_dilated(
        x.astype(jnp.float32), w.astype(jnp.float32), (stride, stride),
        "VALID", dimension_numbers=("NHWC", "HWIO", "NHWC"))
    if b is not None:
        y = y + b.astype(jnp.float32)
    return y.astype(x.dtype)


def depthwise_conv_ref(x, w, b=None, stride: int = 1,
                       groups: int | None = None):
    """VALID NHWC grouped conv + bias — depthwise when ``groups == Cin``.

    ``w`` is HWIO ``(kh, kw, Cin/g, Cout)``; ``groups`` defaults to the
    depthwise reading ``Cin // Cin_g``.  Certification oracle for the
    Pallas ``depthwise_conv`` kernel (tests only off-TPU dispatch).
    """
    if groups is None:
        groups = x.shape[-1] // w.shape[2]
    y = lax.conv_general_dilated(
        x.astype(jnp.float32), w.astype(jnp.float32), (stride, stride),
        "VALID", dimension_numbers=("NHWC", "HWIO", "NHWC"),
        feature_group_count=groups)
    if b is not None:
        y = y + b.astype(jnp.float32)
    return y.astype(x.dtype)


def merged_ffn_qref(x, uq, vq, u_scale, v_scale, *, act_quant="none"):
    """Dequantizing oracle for the quantized ``merged_ffn`` path.

    ``uq``/``vq`` are narrow (int8/fp8) with per-channel scales over the
    rank / output-embed axes.  w8a8 fake-quantizes the activation for the
    two dots only — the residual add stays the exact fp ``x`` (matching
    the kernel, which keeps the fp panel for the epilogue).  Certification
    against the *fp32* oracle :func:`merged_ffn_ref` is bounded by
    :func:`repro.kernels.quant.error_budget`.
    """
    from . import quant
    u = quant.dequantize(uq, u_scale, axis=1)
    v = quant.dequantize(vq, v_scale, axis=1)
    xd = x
    if act_quant == "w8a8":
        xq, xs = quant.quantize_int8(x)
        xd = quant.dequantize(xq, xs)
    h = jnp.dot(xd.astype(jnp.float32), u)
    y = jnp.dot(h, v)
    return (x.astype(jnp.float32) + y).astype(x.dtype)


def merged_conv_qref(x, wq, b, w_scale, *, stride: int = 1,
                     act_quant: str = "none"):
    """Dequantizing oracle for the quantized ``merged_conv`` path
    (``wq`` narrow HWIO, ``w_scale`` per-output-channel, axis 3)."""
    from . import quant
    w = quant.dequantize(wq, w_scale, axis=3)
    if act_quant == "w8a8":
        xq, xs = quant.quantize_int8(x)
        x = quant.dequantize(xq, xs)
    return merged_conv_ref(x, w, b, stride=stride)


def depthwise_conv_qref(x, wq, b, w_scale, *, stride: int = 1,
                        groups: int | None = None,
                        act_quant: str = "none"):
    """Dequantizing oracle for the quantized grouped/depthwise path."""
    from . import quant
    w = quant.dequantize(wq, w_scale, axis=3)
    if act_quant == "w8a8":
        xq, xs = quant.quantize_int8(x)
        x = quant.dequantize(xq, xs)
    return depthwise_conv_ref(x, w, b, stride=stride, groups=groups)


def apply_activation(y, name=None):
    """Boundary activation σ_j of a merged segment (oracle for the fused
    kernel epilogue); fp32 math regardless of storage dtype."""
    if name is None or name == "none":
        return y
    z = y.astype(jnp.float32)
    if name == "relu":
        z = jnp.maximum(z, 0.0)
    elif name == "relu6":
        z = jnp.clip(z, 0.0, 6.0)
    elif name == "silu":
        z = jax.nn.silu(z)
    else:
        raise ValueError(f"unknown activation {name!r}")
    return z.astype(y.dtype)


def flash_attention_ref(q, k, v, *, causal: bool = True):
    """(B, S, H, D) GQA-free attention oracle, fp32 softmax."""
    b, s, h, d = q.shape
    logits = jnp.einsum("bshd,bthd->bhst", q.astype(jnp.float32),
                        k.astype(jnp.float32)) / math.sqrt(d)
    if causal:
        mask = jnp.tril(jnp.ones((s, s), bool))
        logits = jnp.where(mask[None, None], logits,
                           jnp.finfo(jnp.float32).min)
    w = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhst,bthd->bshd", w, v.astype(jnp.float32))
    return out.astype(q.dtype)


def rglru_scan_ref(a, gated, h0=None):
    """h_t = a_t ⊙ h_{t-1} + gated_t over axis 1 (fp32)."""
    def step(h, xs):
        at, gt = xs
        h = at * h + gt
        return h, h
    b, s, d = a.shape
    h0 = jnp.zeros((b, d), jnp.float32) if h0 is None else h0
    _, hs = lax.scan(step, h0,
                     (jnp.moveaxis(a, 1, 0).astype(jnp.float32),
                      jnp.moveaxis(gated, 1, 0).astype(jnp.float32)))
    return jnp.moveaxis(hs, 0, 1)
