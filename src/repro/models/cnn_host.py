"""Host adapter: plan-aware CNNs → the generic LayerMerge core."""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp

from repro.core.latency import CostBreakdown, conv2d_cost
from repro.core.plan import CompressionPlan, LayerDesc, Segment
from repro.core.segments import SegmentEnumerator
from repro.kernels import ops

from . import cnn


@dataclasses.dataclass
class CNNHost:
    net: cnn.ConvNet
    params: dict                      # pre-trained parameters
    batch: int = 8                    # batch size for cost/latency accounting
    dtype_bytes: int = 2
    max_span: int | None = None

    def __post_init__(self):
        self._descs = self.net.layer_descs(self.params)
        self._shapes = self.net.boundary_shapes()

    # -- core protocol ---------------------------------------------------------
    def descs(self) -> list[LayerDesc]:
        return self._descs

    def enumerator(self, method: str = "layermerge") -> SegmentEnumerator:
        return SegmentEnumerator(
            self._descs, offset=1, cap=None,
            allowed_span=self.net.allowed_span,
            depth_mode=(method == "depth"),
            max_span=self.max_span)

    def original_k(self, l: int) -> int:
        return self._descs[l - 1].growth + 1

    def pruned_k(self, l: int) -> int:
        return 1

    # -- latency ----------------------------------------------------------------
    def segment_cost(self, seg: Segment) -> CostBreakdown:
        """Analytic cost of the merged segment at its true input shape."""
        h, w, cin = self._shapes[seg.i]
        _, _, cout = self._shapes[seg.j]
        s_last = self.net.spec(seg.j)
        if s_last.kind != "conv":
            if s_last.kind == "attn":
                n = h * w
                c = cin
                flops = 4 * 2 * n * c * c + 2 * n * n * c * 2
                return CostBreakdown(flops * self.batch,
                                     4 * n * c * self.dtype_bytes * self.batch)
            return CostBreakdown(0.0, h * w * cin * self.dtype_bytes
                                 * self.batch * 2)
        K, S = cnn.segment_geometry(self.net, seg)
        kept = set(seg.kept)
        dw = all(self.net.spec(l).depthwise for l in seg.layers
                 if l in kept and self.net.spec(l).kind == "conv") and kept
        return conv2d_cost(h, w, cin, cout, K, stride=S, depthwise=bool(dw),
                           dtype_bytes=self.dtype_bytes, batch=self.batch)

    def segment_callable(self, seg: Segment, params=None):
        """Zero-arg jitted merged-segment forward for wall-clock timing."""
        params = params or self.params
        h, w, cin = self._shapes[seg.i]
        x = jnp.zeros((self.batch, h, w, cin), jnp.float32)
        s_last = self.net.spec(seg.j)
        if s_last.kind != "conv":
            p = params["layers"][seg.j - 1]

            @jax.jit
            def barrier_fn(x):
                if s_last.kind == "attn":
                    return cnn._tiny_self_attention(x, p)
                if s_last.kind == "pool":
                    return jax.lax.reduce_window(
                        x, 0.0, jax.lax.add, (1, s_last.k, s_last.k, 1),
                        (1, s_last.stride, s_last.stride, 1),
                        "SAME") / (s_last.k * s_last.k)
                n, hh, ww, c = x.shape
                return jax.image.resize(
                    x, (n, hh * s_last.stride, ww * s_last.stride, c),
                    "nearest")
            return lambda: barrier_fn(x)
        wgt, b, stride, dw = cnn.merge_segment(self.net, params["layers"], seg)
        K = wgt.shape[0]
        lo, hi = (K - 1) // 2, (K - 1) - (K - 1) // 2

        @jax.jit
        def fn(x, wgt, b):
            xp = jnp.pad(x, ((0, 0), (lo, hi), (lo, hi), (0, 0))) if K > 1 else x
            if dw:
                return cnn._conv(xp, wgt, stride, True) + b
            # Time the segment exactly as it deploys: through the Pallas
            # fast path on TPU (strided segments included), oracle off-TPU.
            return ops.merged_conv_op(xp, wgt, b, stride=stride)
        return lambda: fn(x, wgt, b)

    # -- network builders ---------------------------------------------------------
    def replaced_apply(self, plan: CompressionPlan, params=None):
        params = params or self.params

        def apply_fn(p, x):
            return cnn.apply_replaced(self.net, p, x, plan)
        return apply_fn, params

    def merged_apply(self, plan: CompressionPlan, params=None):
        params = params or self.params
        units = cnn.merge_network(self.net, params, plan)

        def apply_fn(p, x):
            return cnn.apply_merged(self.net, p, units, x)
        return apply_fn, params
