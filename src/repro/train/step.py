"""train_step / serve_step factories — the functions the dry-run lowers and
the launcher runs.

``make_train_step`` returns a pure function
``(params, opt_state, batch) → (params, opt_state, metrics)`` with loss →
grad → clip → AdamW inside one jit (microbatch gradient accumulation
optional).  ``make_serve_step`` returns the one-token decode
``(params, cache, batch) → (logits, cache)``.
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.models import transformer as T
from repro.optim.adamw import AdamWConfig, adamw_update


def make_loss_fn(cfg, forward_fn=None):
    """LM loss; ``forward_fn(params, batch)`` overrides the stack forward
    (used for LayerMerge-compressed networks)."""
    if forward_fn is None:
        def loss_fn(params, batch):
            return T.lm_loss(cfg, params, batch)
        return loss_fn

    def loss_fn(params, batch):
        logits = T.upcast_for_loss(forward_fn(params, batch))
        logp = jax.nn.log_softmax(logits, axis=-1)
        nll = -jnp.take_along_axis(logp, batch["targets"][..., None],
                                   axis=-1)[..., 0]
        return jnp.mean(nll)
    return loss_fn


def make_train_step(cfg, opt_cfg: AdamWConfig, *, microbatches: int = 1,
                    forward_fn=None, grad_shardings=None):
    """``grad_shardings``: optional pytree of NamedShardings (usually the
    optimizer-state shardings) constrained onto the gradients — this turns
    the data-parallel gradient all-reduce into reduce-scatter + local update
    (ZeRO), a large collective win measured in EXPERIMENTS §Perf."""
    loss_fn = make_loss_fn(cfg, forward_fn)

    def _constrain(grads):
        if grad_shardings is None:
            return grads
        return jax.tree.map(
            lambda g, s: jax.lax.with_sharding_constraint(g, s),
            grads, grad_shardings)

    def train_step(params, opt_state, batch):
        if microbatches <= 1:
            loss, grads = jax.value_and_grad(loss_fn)(params, batch)
            grads = _constrain(grads)
        else:
            # gradient accumulation: split the batch on the leading axis and
            # lax.scan over microbatches (keeps the HLO small and lets XLA
            # overlap the per-microbatch reduce with the next compute)
            def split(x):
                b = x.shape[0] if x.ndim >= 1 else None
                if b is None or b % microbatches != 0:
                    return None
                return x.reshape(microbatches, b // microbatches, *x.shape[1:])
            mb = {k: split(v) for k, v in batch.items() if v is not None}
            # mrope positions carry a leading (3,...) axis — handle specially
            if "mrope_positions" in batch:
                m = batch["mrope_positions"]
                mb["mrope_positions"] = jnp.moveaxis(
                    m.reshape(m.shape[0], microbatches, -1, m.shape[-1]),
                    1, 0)

            def body(acc, micro):
                l, g = jax.value_and_grad(loss_fn)(params, micro)
                acc_l, acc_g = acc
                return (acc_l + l,
                        jax.tree.map(jnp.add, acc_g, g)), None
            zero = (jnp.zeros(()),
                    jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                                 params))
            (loss, grads), _ = jax.lax.scan(body, zero, mb)
            loss = loss / microbatches
            grads = jax.tree.map(lambda g: g / microbatches, grads)
        params, opt_state, metrics = adamw_update(opt_cfg, grads, opt_state,
                                                  params)
        metrics["loss"] = loss
        return params, opt_state, metrics

    return train_step


def make_serve_step(cfg):
    def serve_step(params, cache, batch):
        logits, cache = T.decode_step(cfg, params, cache, batch)
        return logits, cache
    return serve_step


def make_compressed_forward(graph):
    """``forward_fn(params, batch)`` over a lowered unit graph.

    The artifact-backed fine-tuning consumer: pass to
    :func:`make_train_step` as ``forward_fn`` with ``params =
    repro.runtime.graph_params(graph)`` (and the matching AdamW state) to
    continue training a compressed model loaded from an artifact —
    compression runs once, training resumes from the same certified
    object serving uses.
    """
    from repro.runtime import execute

    def forward_fn(params, batch):
        return execute(graph, batch, params=params)
    return forward_fn


def make_prefill_step(cfg):
    def prefill_step(params, batch):
        return T.forward(cfg, params, batch)
    return prefill_step
