"""Serving: jitted chunked prefill, ``lax.scan`` decode, slot batching.

ONE protocol for every consumer of a one-token serve step — the original
stack (:func:`repro.train.step.make_serve_step`) and the artifact-backed
compressed executor (:func:`repro.runtime.executor.make_serve_step` /
:meth:`GraphExecutor.serve_step`) — so ``examples/serve_lm.py`` and
``benchmarks/bench_serve.py`` measure exactly the same thing for both
stacks.

Four layers, each built on the one below:

* :func:`serve_loop` — single-batch prefill + greedy decode.  Prefill is
  ONE jitted chunked call (a ``lax.scan`` over the prompt — not a Python
  dispatch per token) and decode is one jitted ``lax.scan`` that feeds
  each greedy argmax back in; the host touches the device twice, not
  ``P + N`` times.  :func:`serve_loop_pertoken` keeps the PR-4-era
  unjitted per-token loop as the dispatch-bound reference the serve
  bench compares against.
* :func:`generate_fused` — ONE scan over a slot batch with *per-slot*
  prompt lengths: while slot ``b`` still has prompt left the scan
  teacher-forces ``prompt[b, t]``, afterwards it feeds the slot's own
  previous greedy token — so a padded batch of ragged prompts runs
  prefill and decode in the same compiled program with no pad token
  ever entering a KV cache (exactness is tested against single-prompt
  serving).
* :func:`serve_requests` — the fixed-size slot scheduler: admit up to
  ``slots`` prompts per round into a padded batch, run the fused scan
  (as equal-length jitted segments so the wall-clock deadline is
  enforced *per decode chunk*, not per round), retire the round, admit
  the next.  Under a mesh the slot axis is the 'data' axis — many
  concurrent prompts decode data-parallel.
* :class:`ContinuousEngine` / :func:`serve_continuous` — the
  continuous-batching engine: per-slot generation state (sequence
  position, remaining prompt, token budget, deadline) is carried
  through a jitted *vmapped* multi-slot chunk step, and a host-driven
  dispatch loop admits new requests into vacated slots **mid-stream**
  (the admitted slot chunk-prefills while live slots keep decoding) and
  retires slots individually on EOS / token budget / deadline /
  NaN-abort — no round barrier.  Each slot's KV cache carries its OWN
  scalar position, so a slot's tokens are independent of when its
  neighbours were admitted: the engine is certified token-identical to
  single-prompt serving under arbitrary arrival traces.

Every entry point takes ``rules=`` (a :class:`ShardingRules`) and traces
under it, so the same code serves one CPU device and a sharded mesh.
(The continuous engine accepts ``rules=`` but its exactness bar is
certified on a single device; under a mesh prefer ``serve_requests``.)

Failure semantics (the serving half of the crash-safety contract):

* **Non-finite guard** — the scan tracks, per slot, the first step whose
  logits went non-finite; that slot is *aborted* (its tokens from the
  failure on are deterministically zeroed, its greedy feedback is pinned
  so no NaN-argmax garbage re-enters the cache) while every other slot
  is bit-untouched — slots are batch-independent, so one poisoned
  request can never corrupt its round.
* **Budgets and deadlines** — both engines accept a per-request token
  budget and a wall-clock budget.  ``serve_requests`` checks the
  deadline after every ``deadline_chunk`` decode steps (not only
  between rounds): a deadline hit mid-round retires the partial round —
  slots whose generation was cut short get a ``deadline_miss``
  disposition with the tokens generated so far, never-admitted requests
  come back zeroed and ``unserved``.  The continuous engine additionally
  honours *per-request* deadlines (``deadline_s`` relative to arrival).
* **Load shedding** — the continuous engine's admission queue is
  bounded (``max_queue``); an arrival that would overflow it, or whose
  deadline cannot be met at the current sustained decode rate (EWMA of
  steps/s), is rejected up front with a ``shed`` disposition instead of
  being admitted and half-served.  With no rate estimate yet the engine
  admits optimistically.
* **Circuit breakers** — a slot that NaN-aborts ``slot_nan_limit``
  times is *quarantined*: it is never refilled, its id lands in
  ``report.quarantined_slots``, and if every slot is quarantined the
  remaining requests are reported ``unserved`` rather than retried
  forever.
* **Drain** — on a wall-clock budget hit (or an explicit
  :meth:`ContinuousEngine.drain`) the engine finishes every in-flight
  request, admits nothing new, and reports the still-waiting ones
  ``unserved``.
* **Cross-host failover** — under a multi-process mesh a serving worker
  can die mid-decode.  The engine surfaces that as :class:`WorkerLost`
  (a ``health_check`` callable polled before every chunk, or the
  deterministic ``serve.worker`` fault point);
  :func:`serve_with_failover` catches it, harvests every request that
  already finished, re-forms the engine on the surviving capacity (by
  default halving the slot count per failover — the stand-in for
  re-forming the mesh on survivors), and **replays** the in-flight
  requests from their recorded prompts under their original request
  ids.  Decode is deterministic and slots are batch-independent, so a
  replayed request's tokens are bit-identical to an uninterrupted run.
  The :class:`ServeReport` records the event (``failovers``,
  ``lost_workers``, ``replayed``) — requests never silently vanish:
  every submitted rid carries a disposition even after a worker loss.
* **Reporting** — both engines still unpack as ``(gen, seconds)`` (the
  return is a tuple subclass) but carry a :class:`ServeReport` on
  ``.report``: one disposition per request (:data:`DISPOSITIONS` —
  ``completed`` / ``aborted`` / ``shed`` / ``deadline_miss`` /
  ``unserved``), per-request latency, queue high-water mark, and the
  sustained decode rate.

Deterministic fault hooks (:mod:`repro.testing.faults`): the continuous
engine calls ``hit('serve.arrival')`` per ingested arrival,
``hit('serve.admit')`` per slot admission, and ``hit('serve.chunk')``
before every chunk dispatch (``delay`` rules there model stragglers);
``raise@serve.worker`` surfaces as a :class:`WorkerLost` (the failover
trigger); declarative ``nan@serve.nan:rid=R,t=G`` rules poison request
``R``'s logits at generation index ``G`` inside the jitted chunk.

The greedy-argmax / prompt-encoding glue the example and the bench used
to duplicate lives here too: :func:`greedy_token`, :func:`random_prompts`,
:func:`decode_tok_s`.
"""
from __future__ import annotations

import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.sharding.rules import use_rules
from repro.testing import faults as _faults


# ---------------------------------------------------------------------------
# Shared glue (hoisted from examples/serve_lm.py + benchmarks/bench_serve.py)
# ---------------------------------------------------------------------------

def greedy_token(logits):
    """Greedy sampling: ``(B, S, V)`` logits → ``(B,)`` next-token ids."""
    return jnp.argmax(logits[:, -1], axis=-1)


def random_prompts(seed: int, batch: int, prompt_len: int, vocab_size: int):
    """The example/bench prompt encoding: ``(B, P)`` random token ids."""
    return jax.random.randint(jax.random.PRNGKey(seed), (batch, prompt_len),
                              0, vocab_size)


def ragged_prompts(seed: int, n: int, min_len: int, max_len: int,
                   vocab_size: int):
    """``n`` random prompts of random lengths in ``[min_len, max_len]`` —
    the scheduler-workload encoding (list of 1-D int32 id arrays; feed
    through :func:`pad_prompts`)."""
    if not 1 <= min_len <= max_len:
        raise ValueError(f"need 1 <= min_len <= max_len, got "
                         f"[{min_len}, {max_len}]")
    rng = np.random.RandomState(seed)
    return [jnp.asarray(rng.randint(0, vocab_size,
                                    size=rng.randint(min_len, max_len + 1)),
                        jnp.int32)
            for _ in range(n)]


def decode_tok_s(tokens: int, batch: int, seconds: float) -> float:
    """Decode throughput; guards the div by tiny smoke timings."""
    return tokens * batch / max(seconds, 1e-9)


# ---------------------------------------------------------------------------
# Jitted single-batch serve loop (chunked prefill + scan decode)
# ---------------------------------------------------------------------------

def _prefill_chunk(step, params, cache, prompt):
    """One chunked prefill call: scan the step over the prompt axis.

    Returns the last-position logits ``(B, V)`` and the filled cache.
    """
    def body(cache, tok):
        logits, cache = step(params, cache, {"tokens": tok[:, None]})
        return cache, logits[:, -1]
    cache, logits = lax.scan(body, cache, prompt.T)
    return logits[-1], cache


def _decode_scan(step, params, cache, tok0, n: int):
    """Greedy decode scan: ``n`` tokens from ``tok0`` ``(B,)`` on."""
    def body(carry, _):
        tok, cache = carry
        logits, cache = step(params, cache, {"tokens": tok[:, None]})
        nxt = greedy_token(logits)
        return (nxt, cache), nxt
    (_, cache), toks = lax.scan(body, (tok0, cache), None, length=n)
    return toks.T, cache                                   # (B, n)


def serve_loop(step, params, cache, prompt, tokens: int, *, rules=None,
               warm: bool = True):
    """Drive ``step(params, cache, batch) → (logits, cache)``.

    Prefill is ONE jitted chunked call over the whole prompt; decode is
    ONE jitted ``lax.scan`` issuing ``tokens - 1`` greedy steps.  With
    ``warm`` (the benchmarking contract) both programs run once
    unmeasured first, so ``(prefill_s, decode_s)`` report steady-state
    serving, not compilation; pass ``warm=False`` to serve without the
    extra pass.  Returns
    ``(prefill_s, decode_s, last_logits (B, V), seqs (B, tokens))``.
    """
    prefill = jax.jit(lambda p, c, t: _prefill_chunk(step, p, c, t))
    decode = jax.jit(lambda p, c, t0: _decode_scan(step, p, c, t0,
                                                   tokens - 1))
    with use_rules(rules):
        if warm:
            jax.block_until_ready(prefill(params, cache, prompt))
        t0 = time.perf_counter()
        logits, cache = prefill(params, cache, prompt)
        jax.block_until_ready(logits)
        prefill_s = time.perf_counter() - t0

        tok = greedy_token(logits[:, None])
        if warm:
            jax.block_until_ready(decode(params, cache, tok))
        t0 = time.perf_counter()
        out, _ = decode(params, cache, tok)
        jax.block_until_ready(out)
        decode_s = time.perf_counter() - t0
    seqs = jnp.concatenate([tok[:, None], out], axis=1)
    return prefill_s, decode_s, logits, seqs


def serve_loop_pertoken(step, params, cache, prompt, tokens: int, *,
                        rules=None):
    """The PR-4 reference loop: a host round-trip per token, per prompt
    position (pass a ``jax.jit``-ed step to make each one exactly one
    XLA dispatch).  Kept so the serve bench can report how much the
    chunked/scan protocol buys on the same step."""
    logits = None
    with use_rules(rules):
        t0 = time.perf_counter()
        for t in range(prompt.shape[1]):
            logits, cache = step(params, cache,
                                 {"tokens": prompt[:, t:t + 1]})
        jax.block_until_ready(logits)
        prefill_s = time.perf_counter() - t0
        last = logits[:, -1]

        tok = greedy_token(logits)[:, None]
        out = [tok]
        t0 = time.perf_counter()
        for _ in range(tokens - 1):
            logits, cache = step(params, cache, {"tokens": tok})
            tok = greedy_token(logits)[:, None]
            out.append(tok)
        jax.block_until_ready(tok)
        decode_s = time.perf_counter() - t0
    return prefill_s, decode_s, last, jnp.concatenate(out, axis=1)


# ---------------------------------------------------------------------------
# Fused ragged-prompt generation (one scan = prefill + decode)
# ---------------------------------------------------------------------------

def generate_fused(step, params, cache, prompts, lengths, tokens: int, *,
                   logit_hook=None, with_report: bool = False):
    """One scan over a padded slot batch with per-slot prompt lengths.

    ``prompts``: ``(B, P)`` right-padded ids; ``lengths``: ``(B,)`` with
    ``1 <= lengths[b] <= P``.  At scan step ``t`` slot ``b`` consumes
    ``prompts[b, t]`` while ``t < lengths[b]`` (teacher-forced prefill)
    and its own previous greedy token afterwards (decode) — pad ids are
    never fed, so every slot's cache holds exactly its own sequence and
    the result matches serving that prompt alone.  Returns
    ``(gen (B, tokens), cache)``; the cache must cover ``P + tokens``
    positions.

    Non-finite guard: each step tracks, per slot, whether the logits are
    all-finite; a slot that goes bad feeds a pinned token 0 back (never a
    NaN-argmax) so the remaining slots of the batch are bit-untouched.
    With ``with_report`` the return gains a third element ``fail_idx
    (B,)``: the generation index at which each slot first saw non-finite
    logits (``tokens`` = never — healthy), with the aborted slot's tokens
    deterministically zeroed from that index on.

    ``logit_hook(logits, t) → logits`` runs inside the (jitted) scan just
    before the argmax — the deterministic injection point used by
    :func:`repro.testing.faults.nan_logits_hook`.
    """
    prompts = prompts.astype(jnp.int32)    # match the argmax carry dtype
    B, P = prompts.shape
    steps = P + tokens - 1
    toks_in = jnp.pad(prompts, ((0, 0), (0, steps - P)))   # (B, steps)

    def body(carry, xs):
        prev, cache = carry
        tok_t, t = xs
        inp = jnp.where(t < lengths, tok_t, prev)
        logits, cache = step(params, cache, {"tokens": inp[:, None]})
        if logit_hook is not None:
            logits = logit_hook(logits, t)
        ok = jnp.isfinite(logits).all(
            axis=tuple(range(1, logits.ndim)))             # (B,)
        nxt = jnp.where(ok, greedy_token(logits), 0)
        return (nxt, cache), (nxt, ok)

    init = (jnp.zeros((B,), prompts.dtype), cache)
    (_, cache), (samples, ok) = lax.scan(
        body, init, (toks_in.T, jnp.arange(steps)))
    # slot b's generation starts at the step that consumed its last
    # prompt token: samples[lengths[b] - 1 + i, b]
    idx = (lengths - 1)[:, None] + jnp.arange(tokens)[None, :]
    gen = jnp.take_along_axis(samples.T, idx, axis=1)
    if not with_report:
        return gen, cache
    bad = ~ok.T                                            # (B, steps)
    first_bad = jnp.where(bad.any(axis=1),
                          jnp.argmax(bad, axis=1), steps)  # scan step
    # A failure while the slot was still teacher-forcing (its cache is
    # poisoned before the first generated token) clips to index 0.
    fail_idx = jnp.clip(first_bad - (lengths - 1), 0, tokens)
    keep = jnp.arange(tokens)[None, :] < fail_idx[:, None]
    return jnp.where(keep, gen, 0), cache, fail_idx


def _make_segment_fn(step, logit_hook):
    """The fused prefill+decode scan, cut into equal-length segments.

    Same per-step math as :func:`generate_fused` (teacher-force while
    ``t < lengths``, pinned greedy feedback on non-finite logits), but
    callable segment by segment: carry ``(prev, cache)`` lives on the
    host between calls, ``tsteps`` carries the *global* step indices of
    the segment so ``t < lengths`` and the ``logit_hook`` see exactly
    the indices the single-scan program would.  One compiled program
    serves every segment of every round (step indices are runtime data).
    """
    def seg_fn(params, cache, prev, feed, lengths, tsteps):
        def body(carry, xs):
            prev, cache = carry
            tok_t, t = xs
            inp = jnp.where(t < lengths, tok_t, prev)
            logits, cache = step(params, cache, {"tokens": inp[:, None]})
            if logit_hook is not None:
                logits = logit_hook(logits, t)
            ok = jnp.isfinite(logits).all(
                axis=tuple(range(1, logits.ndim)))         # (B,)
            nxt = jnp.where(ok, greedy_token(logits), 0)
            return (nxt, cache), (nxt, ok)
        (prev, cache), (samples, ok) = lax.scan(
            body, (prev, cache), (feed, tsteps))
        return prev, cache, samples, ok                    # samples (seg, B)
    return jax.jit(seg_fn)


# ---------------------------------------------------------------------------
# Request encoding shared by both schedulers
# ---------------------------------------------------------------------------

def pad_prompts(prompts, pad_to: int | None = None):
    """Encode a list of 1-D id arrays as ``(R, P)`` padded ids + lengths.

    ``pad_to`` pins ``P`` (e.g. to keep one compiled scheduler program
    across calls); it must cover the longest prompt.
    """
    lengths = jnp.asarray([len(p) for p in prompts], jnp.int32)
    longest = int(lengths.max())
    P = longest if pad_to is None else pad_to
    if P < longest:
        raise ValueError(f"pad_to={pad_to} shorter than the longest "
                         f"prompt ({longest} tokens)")
    mat = jnp.stack([
        jnp.pad(jnp.asarray(p, jnp.int32), (0, P - len(p)))
        for p in prompts])
    return mat, lengths


def _normalize_requests(prompts, lengths):
    """``(prompts (R, P) int32, lengths (R,) int32)`` from either a padded
    matrix + lengths or a list of 1-D prompts (zero requests OK)."""
    if lengths is None:
        if getattr(prompts, "ndim", None) == 2:
            # a padded matrix has no recoverable lengths — deriving them
            # here would silently teacher-force pad tokens into caches
            raise ValueError("pass lengths= with a padded (R, P) matrix "
                             "(or pass the list of 1-D prompts)")
        if len(prompts) == 0:              # zero requests: nothing to pad
            return jnp.zeros((0, 1), jnp.int32), jnp.zeros((0,), jnp.int32)
        prompts, lengths = pad_prompts(prompts)
    return jnp.asarray(prompts, jnp.int32), jnp.asarray(lengths, jnp.int32)


#: Every per-request outcome a :class:`ServeReport` can assign.
DISPOSITIONS = ("completed", "aborted", "shed", "deadline_miss", "unserved")


@dataclasses.dataclass
class ServeReport:
    """Per-request outcome accounting for one serve call.

    ``aborted`` maps a request index to the generation index at which its
    logits first went non-finite (its tokens are zeroed from there on);
    ``unserved`` lists requests never admitted because the wall-clock
    budget expired (their rows are all zeros); everything else
    ``completed`` normally.  ``tokens_per_request`` is the effective
    generation length after the token budget.

    Overload-safety fields (all default-empty, so PR-6 callers keep
    working):

    * ``shed`` — requests rejected at admission (queue overflow, or the
      deadline-aware load shedder predicted a miss); row all zeros.
    * ``deadline_miss`` — requests admitted but cut short by a deadline:
      request index → tokens actually generated (kept in the row).
    * ``latency_s`` — arrival → finish wall clock per served request.
    * ``queue_peak`` / ``admitted`` — admission-queue high-water mark
      and total admissions (continuous engine).
    * ``quarantined_slots`` — slots retired by the NaN circuit breaker.
    * ``sustained_tok_s`` — generated tokens / serving wall clock.
    * ``engine`` — ``"fixed"`` (round scheduler) or ``"continuous"``.
    """

    completed: list[int] = dataclasses.field(default_factory=list)
    aborted: dict[int, int] = dataclasses.field(default_factory=dict)
    unserved: list[int] = dataclasses.field(default_factory=list)
    rounds: int = 0
    tokens_per_request: int = 0
    deadline_hit: bool = False
    shed: list[int] = dataclasses.field(default_factory=list)
    deadline_miss: dict[int, int] = dataclasses.field(default_factory=dict)
    latency_s: dict[int, float] = dataclasses.field(default_factory=dict)
    queue_peak: int = 0
    admitted: int = 0
    quarantined_slots: list[int] = dataclasses.field(default_factory=list)
    sustained_tok_s: float = 0.0
    engine: str = "fixed"
    # Cross-host failover accounting (serve_with_failover; default-empty
    # so every earlier caller keeps working):
    failovers: int = 0                 # engine re-formations after losses
    lost_workers: list = dataclasses.field(default_factory=list)
    replayed: list[int] = dataclasses.field(default_factory=list)

    @property
    def ok(self) -> bool:
        return not (self.aborted or self.unserved or self.shed
                    or self.deadline_miss or self.quarantined_slots)

    @property
    def dispositions(self) -> dict[int, str]:
        """request index → one of :data:`DISPOSITIONS`."""
        d: dict[int, str] = {r: "completed" for r in self.completed}
        d.update({r: "aborted" for r in self.aborted})
        d.update({r: "shed" for r in self.shed})
        d.update({r: "deadline_miss" for r in self.deadline_miss})
        d.update({r: "unserved" for r in self.unserved})
        return d


class ServeOutput(tuple):
    """``(gen, seconds)`` (unpacks like the pre-report return) carrying
    the :class:`ServeReport` on ``.report``."""

    report: ServeReport

    def __new__(cls, gen, seconds, report):
        out = super().__new__(cls, (gen, seconds))
        out.report = report
        return out


class WorkerLost(RuntimeError):
    """A serving worker (process/device) died mid-decode.

    Raised from the engine's chunk dispatch — either the ``health_check``
    callable reported lost workers, or the deterministic ``serve.worker``
    fault point fired.  Carries the lost worker ids on ``.lost``.
    :func:`serve_with_failover` catches it, harvests finished requests,
    re-forms the engine on surviving capacity, and replays the in-flight
    requests; an uncaught ``WorkerLost`` from a bare
    :class:`ContinuousEngine` leaves every unfinished request with
    ``disposition None`` — visibly incomplete, never silently dropped.
    """

    def __init__(self, msg: str, lost=()):
        super().__init__(msg)
        self.lost = list(lost)


# ---------------------------------------------------------------------------
# Fixed-slot batched request scheduler (round barrier, per-chunk deadline)
# ---------------------------------------------------------------------------

def serve_requests(step, params, make_cache, prompts, lengths=None, *,
                   tokens: int, slots: int | None = None, rules=None,
                   warm: bool = True, token_budget: int | None = None,
                   time_budget_s: float | None = None, logit_hook=None,
                   deadline_chunk: int = 8, clock=None):
    """Serve many prompts through fixed-size slot batching.

    ``prompts``: ``(R, P)`` padded ids (or a list of 1-D id arrays, in
    which case ``lengths`` is derived).  Up to ``slots`` prompts are
    admitted per round into a padded batch; one jitted fused
    prefill+decode scan serves every round (short final rounds re-admit
    slot 0's prompt as filler and drop the duplicate results), then the
    round retires and the next is admitted.
    ``make_cache(batch_size, seq_len)`` builds a fresh per-round cache.

    Under mesh ``rules`` the slot axis is the 'data' mesh axis — rounds
    decode data-parallel.  Returns a :class:`ServeOutput` — unpacks as
    ``(gen (R, T), seconds)`` exactly like before, with the
    :class:`ServeReport` on ``.report`` — where ``seconds`` is
    steady-state wall clock with ``warm`` (one unmeasured pass over
    round 0's shapes first — the benchmarking contract; pass
    ``warm=False`` to serve without it).

    Hardening: ``token_budget`` caps generated tokens per request
    (``T = min(tokens, token_budget)``); ``time_budget_s`` bounds the
    measured serving wall clock and is enforced **per decode chunk**:
    with a budget set, each round runs as equal ``deadline_chunk``-step
    jitted segments with a host deadline check between them, so a long
    round cannot blow past the budget by more than one chunk.  On a
    deadline hit the scheduler drains cleanly — the in-flight round
    stops at its current chunk (slots whose generation was cut short are
    recorded in ``report.deadline_miss`` with their token counts, their
    rows keep the tokens generated so far; slots that had already
    finished complete normally) and never-admitted requests come back
    zeroed and listed in ``report.unserved``.  A slot whose logits go
    non-finite is aborted at that token (see :func:`generate_fused`) and
    recorded in ``report.aborted``; the other slots of its round are
    bit-untouched.  ``logit_hook`` is threaded into the fused scan
    (fault injection).  ``clock`` (default ``time.perf_counter``)
    injects a virtual clock for deterministic deadline tests — e.g.
    :class:`repro.testing.faults.TickClock`.
    """
    prompts, lengths = _normalize_requests(prompts, lengths)
    R, P = prompts.shape
    eff_tokens = tokens if token_budget is None \
        else max(1, min(tokens, token_budget))
    report = ServeReport(tokens_per_request=eff_tokens)
    if R == 0:                             # zero requests: nothing to trace
        return ServeOutput(jnp.zeros((0, eff_tokens), jnp.int32), 0.0,
                           report)
    slots = min(slots or R, R)
    clk = clock if clock is not None else time.perf_counter

    # One round = `steps` scan steps; with a wall-clock budget the round
    # is cut into equal `seg`-step segments (padded with discarded tail
    # steps) so ONE compiled program serves every segment and the host
    # checks the deadline between segments.
    steps = P + eff_tokens - 1
    seg = steps if time_budget_s is None \
        else max(1, min(deadline_chunk, steps))
    nseg = -(-steps // seg)
    pad_steps = nseg * seg
    cache_len = P + eff_tokens + (pad_steps - steps)
    seg_fn = _make_segment_fn(step, logit_hook)
    tsteps = [jnp.arange(s * seg, (s + 1) * seg) for s in range(nseg)]

    def round_batch(start):
        # short final round: re-admit request 0 as filler, results dropped
        idx = [start + i if start + i < R else 0 for i in range(slots)]
        return prompts[jnp.asarray(idx)], lengths[jnp.asarray(idx)]

    def round_feed(pr):
        return jnp.pad(pr, ((0, 0), (0, pad_steps - P)))   # (slots, pad)

    rounds_data = []                   # (start, n, ln, done, samples, oks)
    deadline_hit = False
    with use_rules(rules):
        if warm:
            pr0, ln0 = round_batch(0)
            jax.block_until_ready(seg_fn(
                params, make_cache(slots, cache_len),
                jnp.zeros((slots,), jnp.int32),
                round_feed(pr0)[:, :seg].T, ln0, tsteps[0]))
        t0 = clk()
        for start in range(0, R, slots):
            if deadline_hit or (time_budget_s is not None
                                and clk() - t0 > time_budget_s):
                report.deadline_hit = True
                report.unserved.extend(range(start, R))
                break
            pr, ln = round_batch(start)
            feed = round_feed(pr)
            cache = make_cache(slots, cache_len)
            prev = jnp.zeros((slots,), jnp.int32)
            samples, oks = [], []
            executed = 0
            for s in range(nseg):
                prev, cache, sm, ok = seg_fn(
                    params, cache, prev,
                    feed[:, s * seg:(s + 1) * seg].T, ln, tsteps[s])
                samples.append(sm)
                oks.append(ok)
                executed += seg
                if time_budget_s is not None:
                    jax.block_until_ready(sm)
                    if clk() - t0 > time_budget_s and executed < pad_steps:
                        deadline_hit = True
                        break
            n = min(slots, R - start)
            rounds_data.append((start, n, ln, min(executed, steps),
                                samples, oks))
            report.rounds += 1
        jax.block_until_ready([r[4] for r in rounds_data])
        seconds = clk() - t0
    if deadline_hit:
        report.deadline_hit = True
        # never-admitted requests after a mid-round deadline hit
        tail = rounds_data[-1][0] + slots if rounds_data else 0
        report.unserved.extend(r for r in range(tail, R)
                               if r not in report.unserved)

    gen = np.zeros((R, eff_tokens), np.int32)
    for start, n, ln, done, samples, oks in rounds_data:
        sm = np.concatenate([np.asarray(jax.device_get(s))
                             for s in samples], axis=0)[:done]
        ok = np.concatenate([np.asarray(jax.device_get(o))
                             for o in oks], axis=0)[:done]
        ln_np = np.asarray(jax.device_get(ln))
        smT, badT = sm.T, ~ok.T                            # (slots, done)
        for b in range(n):
            rid = start + b
            L = int(ln_np[b])
            served = int(np.clip(done - (L - 1), 0, eff_tokens))
            bad = badT[b]
            first_bad = int(np.argmax(bad)) if bad.any() else done
            fail = int(np.clip(first_bad - (L - 1), 0, eff_tokens))
            keep = min(fail, served)
            if keep > 0:
                gen[rid, :keep] = smT[b, (L - 1) + np.arange(keep)]
            if fail < min(served, eff_tokens):
                report.aborted[rid] = fail
            elif served < eff_tokens:
                report.deadline_miss[rid] = served
            else:
                report.completed.append(rid)
    return ServeOutput(jnp.asarray(gen), seconds, report)


# ---------------------------------------------------------------------------
# Continuous-batching engine: per-slot state, mid-stream admission
# ---------------------------------------------------------------------------

def stack_cache(cache, slots: int):
    """Stack one fresh single-request cache into per-slot engine state.

    Every leaf gains a leading ``(slots,)`` axis; crucially the cache's
    scalar ``pos`` becomes ``(slots,)`` — each slot carries its OWN
    sequence position, which is what makes mid-stream admission exact:
    resetting one slot (:func:`jax.tree.map` ``full.at[b].set(fresh)``)
    rewinds only that slot's sequence.
    """
    return jax.tree.map(
        lambda x: jnp.broadcast_to(jnp.asarray(x)[None],
                                   (slots,) + tuple(jnp.shape(x))), cache)


def _make_chunk_fn(step, logit_hook):
    """Jitted multi-slot chunk: ``chunk`` scan steps over vmapped slots.

    The serve step runs under ``jax.vmap`` over the slot axis, so every
    slot advances its own cache position independently — slot ``b`` may
    be teacher-forcing prompt token 3 while slot ``c`` decodes token 40.
    ``feed (C, B)`` holds prompt tokens for slots still prefilling;
    ``fp (B,)`` is the number of leading steps each slot teacher-forces
    this chunk (``C`` for idle slots, which harmlessly decode a dummy
    sequence that admission resets); ``poison (B,)`` is the local step
    at which a slot's logits are forced non-finite (``-1`` = never — the
    deterministic ``serve.nan`` fault); ``t0`` is the engine-global step
    index handed to ``logit_hook``.
    """
    def vstep(params, cache, toks):
        return jax.vmap(
            lambda c, t: step(params, c, {"tokens": t[None, None]}))(
                cache, toks)

    def chunk_fn(params, cache, prev, feed, fp, poison, t0):
        def body(carry, xs):
            prev, cache = carry
            tok_t, i = xs
            inp = jnp.where(i < fp, tok_t, prev)
            logits, cache = vstep(params, cache, inp)
            logits = logits[:, 0]                          # (B, 1, V)
            if logit_hook is not None:
                logits = logit_hook(logits, t0 + i)
            logits = jnp.where((i == poison)[:, None, None], jnp.nan,
                               logits)
            ok = jnp.isfinite(logits).all(axis=(1, 2))     # (B,)
            nxt = jnp.where(ok, greedy_token(logits), 0).astype(jnp.int32)
            return (nxt, cache), (nxt, ok)
        C = feed.shape[0]
        (prev, cache), (toks, oks) = lax.scan(
            body, (prev, cache), (feed, jnp.arange(C)))
        return prev, cache, toks, oks                      # toks (C, B)
    return jax.jit(chunk_fn)


@dataclasses.dataclass
class _Request:
    """Host-side lifecycle record of one submitted request."""

    rid: int
    prompt: np.ndarray                 # 1-D int32 token ids
    budget: int                        # tokens to generate
    arrival: float
    deadline: float | None = None      # absolute (arrival + deadline_s)
    admitted_at: float | None = None
    finished_at: float | None = None
    tokens: list = dataclasses.field(default_factory=list)
    disposition: str | None = None


@dataclasses.dataclass
class _Slot:
    """Host-side view of one device slot."""

    rid: int = -1                      # -1 = idle
    consumed: int = 0                  # scan steps run for this request
    aborts: int = 0                    # NaN aborts since construction
    quarantined: bool = False


class ContinuousEngine:
    """Persistent continuous-batching decode loop over ``slots`` slots.

    ``step(params, cache, batch) → (logits, cache)`` is the same
    one-token protocol every other entry point uses;
    ``make_cache(batch_size, seq_len)`` must build the matching fresh
    cache (the engine builds ONE ``make_cache(1, max_seq)`` cache and
    stacks it per slot via :func:`stack_cache`).

    Lifecycle: :meth:`submit` requests (with arrival times and optional
    per-request deadlines), then :meth:`run` — the host loop ingests due
    arrivals into a bounded queue (overflow → ``shed``), admits queued
    requests into idle slots (deadline-aware shedding, see module
    docstring), dispatches one jitted ``chunk``-step multi-slot scan,
    and retires slots individually on EOS / budget / deadline /
    NaN-abort.  A slot that NaN-aborts ``slot_nan_limit`` times is
    quarantined (circuit breaker).  :meth:`drain` finishes in-flight
    requests without admitting more.

    ``clock`` (default ``time.perf_counter``) injects a virtual clock —
    :class:`repro.testing.faults.TickClock` makes shedding/deadline
    behavior fully deterministic (the loop reads the clock once per
    chunk).  With a virtual clock the engine never sleeps while waiting
    for arrivals; virtual time advances one tick per idle iteration.
    """

    def __init__(self, step, params, make_cache, *, slots: int,
                 max_seq: int, chunk: int = 8, rules=None, eos_id=None,
                 logit_hook=None, clock=None, max_queue: int | None = None,
                 slot_nan_limit: int = 2, warm: bool = True,
                 health_check=None):
        if slots < 1:
            raise ValueError(f"need at least one slot, got {slots}")
        if chunk < 1:
            raise ValueError(f"need chunk >= 1, got {chunk}")
        self.slots = slots
        self.chunk = chunk
        self.max_seq = max_seq
        self.eos_id = eos_id
        self.rules = rules
        self._params = params
        self._clock = clock if clock is not None else time.perf_counter
        self._virtual = clock is not None
        self._max_queue = max_queue
        self._nan_limit = slot_nan_limit
        self._health_check = health_check
        with use_rules(rules):
            self._fresh = make_cache(1, max_seq)
            self._cache = stack_cache(self._fresh, slots)
        self._prev = jnp.zeros((slots,), jnp.int32)
        self._chunk_fn = _make_chunk_fn(step, logit_hook)
        self._reset_fn = jax.jit(lambda full, fr, b: jax.tree.map(
            lambda f, x: f.at[b].set(x), full, fr))
        self._slots = [_Slot() for _ in range(slots)]
        self.requests: dict[int, _Request] = {}
        self._pending: list[_Request] = []     # not yet arrived
        self._queue: list[_Request] = []       # arrived, awaiting a slot
        self._rate: float | None = None        # EWMA decode steps/s
        self._next_rid = 0
        self._now: float | None = None
        self._epoch: float | None = None
        self._t_global = 0
        self._total_tokens = 0
        self.report = ServeReport(engine="continuous")
        if warm:
            self._warmup()

    # -- submission ---------------------------------------------------------

    def submit(self, prompt, *, tokens: int, arrival: float = 0.0,
               deadline_s: float | None = None, rid: int | None = None):
        """Queue one request; returns its request id.

        ``arrival`` is the (clock-relative) time the request becomes
        visible to the engine; ``deadline_s`` is relative to arrival.
        """
        prompt = np.asarray(jax.device_get(prompt), np.int32).reshape(-1)
        if len(prompt) < 1:
            raise ValueError("empty prompt")
        if tokens < 1:
            raise ValueError(f"need tokens >= 1, got {tokens}")
        if len(prompt) + tokens > self.max_seq:
            raise ValueError(
                f"prompt ({len(prompt)}) + tokens ({tokens}) exceeds the "
                f"engine window max_seq={self.max_seq}")
        if rid is None:
            rid = self._next_rid
        if rid in self.requests:
            raise ValueError(f"duplicate request id {rid}")
        self._next_rid = max(self._next_rid, rid + 1)
        req = _Request(rid=rid, prompt=prompt, budget=int(tokens),
                       arrival=float(arrival),
                       deadline=None if deadline_s is None
                       else float(arrival) + float(deadline_s))
        if self._epoch is not None:      # mid-run submit: anchor now
            req.arrival += self._epoch
            if req.deadline is not None:
                req.deadline += self._epoch
        self.requests[rid] = req
        self._pending.append(req)
        return rid

    def _anchor(self):
        """Pin clock-relative arrivals/deadlines to the clock's frame.

        ``submit`` takes times relative to the engine epoch (t=0 at the
        first clock read); a real monotonic clock does not start at 0,
        so the first ``run``/``drain`` shifts every pending timestamp
        into the clock's frame.  Latencies stay epoch-relative because
        both ends of the subtraction carry the same offset.
        """
        if self._now is not None:
            return
        self._now = self._epoch = self._clock()
        if self._epoch:
            for req in self._pending:
                req.arrival += self._epoch
                if req.deadline is not None:
                    req.deadline += self._epoch

    # -- main loop ----------------------------------------------------------

    def run(self, *, time_budget_s: float | None = None) -> ServeReport:
        """Serve every submitted request (or until the budget expires)."""
        self._pending.sort(key=lambda r: (r.arrival, r.rid))
        with use_rules(self.rules):
            self._anchor()
            start = self._now
            while True:
                now = self._now
                if time_budget_s is not None \
                        and now - start >= time_budget_s:
                    self._drain_live()
                    self._flush_waiting(deadline_hit=True)
                    break
                self._ingest(now)
                self._admit(now)
                if not any(s.rid >= 0 for s in self._slots):
                    if not self._queue and not self._pending:
                        break
                    if all(s.quarantined for s in self._slots):
                        self._flush_waiting()
                        break
                    if not self._virtual and self._pending:
                        wait = self._pending[0].arrival - now
                        if wait > 0:
                            time.sleep(min(wait, 0.05))
                    self._now = self._clock()
                    continue
                self._run_chunk()
            elapsed = max(self._now - start, 1e-9)
            self.report.sustained_tok_s = self._total_tokens / elapsed
        return self.report

    def drain(self) -> ServeReport:
        """Finish in-flight requests, admit nothing new; waiting requests
        are reported ``unserved`` (graceful shutdown)."""
        with use_rules(self.rules):
            self._anchor()
            self._drain_live()
            self._flush_waiting()
        return self.report

    # -- internal: admission ------------------------------------------------

    def _ingest(self, now):
        while self._pending and self._pending[0].arrival <= now:
            req = self._pending.pop(0)
            _faults.hit("serve.arrival")
            if self._max_queue is not None \
                    and len(self._queue) >= self._max_queue:
                self._finish(req, "shed", now)
                continue
            self._queue.append(req)
            self.report.queue_peak = max(self.report.queue_peak,
                                         len(self._queue))

    def _shed(self, req, now) -> bool:
        """Deadline-aware load shedding: reject up front what cannot be
        served in time at the sustained decode rate (optimistic when no
        rate estimate exists yet)."""
        if req.deadline is None:
            return False
        if now >= req.deadline:
            return True
        if self._rate:
            steps = len(req.prompt) - 1 + req.budget
            if now + steps / self._rate > req.deadline:
                return True
        return False

    def _admit(self, now):
        for b in range(self.slots):
            slot = self._slots[b]
            if slot.rid >= 0 or slot.quarantined:
                continue
            while self._queue:
                req = self._queue.pop(0)
                if self._shed(req, now):
                    self._finish(req, "shed", now)
                    continue
                _faults.hit("serve.admit")
                slot.rid = req.rid
                slot.consumed = 0
                req.admitted_at = now
                self.report.admitted += 1
                self._cache = self._reset_fn(self._cache, self._fresh,
                                             jnp.int32(b))
                break

    # -- internal: chunk dispatch + retirement ------------------------------

    def _build_feed(self):
        C, B = self.chunk, self.slots
        feed = np.zeros((C, B), np.int32)
        fp = np.full((B,), C, np.int32)        # idle slots: inert zeros
        poison = np.full((B,), -1, np.int32)
        spec = _faults.serve_nan_spec()
        for b, slot in enumerate(self._slots):
            if slot.rid < 0:
                continue
            req = self.requests[slot.rid]
            L = len(req.prompt)
            left = max(0, L - slot.consumed)
            fp[b] = left
            if left > 0:
                k = min(C, left)
                feed[:k, b] = req.prompt[slot.consumed:slot.consumed + k]
            if spec and req.rid in spec:
                # poison at generation index g ⇒ global step (L - 1 + g)
                i = (L - 1 + spec[req.rid]) - slot.consumed
                if 0 <= i < C:
                    poison[b] = i
        return jnp.asarray(feed), jnp.asarray(fp), jnp.asarray(poison)

    def _check_workers(self):
        """Surface a worker loss BEFORE dispatching the next chunk.

        ``health_check()`` (when given) returns the ids of lost workers
        (empty/None ⇒ healthy); the ``serve.worker`` fault point injects
        the same condition deterministically in tests.  Either raises
        :class:`WorkerLost` — in-flight slots keep their partial state
        untouched so the failover layer can replay their requests.
        """
        try:
            _faults.hit("serve.worker")
        except _faults.FaultError as e:
            raise WorkerLost(str(e)) from e
        if self._health_check is not None:
            lost = self._health_check()
            if lost:
                raise WorkerLost(f"worker(s) lost: {sorted(lost)}",
                                 lost=lost)

    def _run_chunk(self):
        self._check_workers()
        _faults.hit("serve.chunk")
        feed, fp, poison = self._build_feed()
        prev, cache, toks, oks = self._chunk_fn(
            self._params, self._cache, self._prev, feed, fp, poison,
            jnp.int32(self._t_global))
        self._prev, self._cache = prev, cache
        toks = np.asarray(jax.device_get(toks))            # (C, B)
        oks = np.asarray(jax.device_get(oks))
        self._t_global += self.chunk
        before = self._now
        self._now = self._clock()
        obs = self.chunk / max(self._now - before, 1e-9)
        self._rate = obs if self._rate is None \
            else 0.5 * self._rate + 0.5 * obs
        self._retire(toks, oks, self._now)

    def _retire(self, toks, oks, now):
        for b, slot in enumerate(self._slots):
            if slot.rid < 0:
                continue
            req = self.requests[slot.rid]
            L = len(req.prompt)
            c0 = slot.consumed
            finished = None
            for i in range(self.chunk):
                s = c0 + i
                if not oks[i, b]:
                    # abort at generation index (clipped to 0 while the
                    # failure happened during this slot's prefill)
                    g_bad = min(max(s - (L - 1), 0), req.budget)
                    del req.tokens[g_bad:]
                    finished = "aborted"
                    break
                if s >= L - 1:
                    req.tokens.append(int(toks[i, b]))
                    if self.eos_id is not None \
                            and req.tokens[-1] == self.eos_id:
                        finished = "completed"
                        break
                    if len(req.tokens) >= req.budget:
                        finished = "completed"
                        break
            slot.consumed = c0 + self.chunk
            if finished is None and req.deadline is not None \
                    and now > req.deadline:
                finished = "deadline_miss"
            if finished is None:
                continue
            slot.rid = -1
            slot.consumed = 0
            if finished == "aborted":
                slot.aborts += 1
                if slot.aborts >= self._nan_limit and not slot.quarantined:
                    slot.quarantined = True
                    self.report.quarantined_slots.append(b)
            self._finish(req, finished, now)

    def _drain_live(self):
        while any(s.rid >= 0 for s in self._slots):
            self._run_chunk()

    def _flush_waiting(self, deadline_hit: bool = False):
        now = self._now if self._now is not None else 0.0
        for req in self._queue + self._pending:
            self._finish(req, "unserved", now)
        self._queue.clear()
        self._pending.clear()
        if deadline_hit:
            self.report.deadline_hit = True

    def _finish(self, req, disposition, now):
        req.disposition = disposition
        req.finished_at = now
        r = self.report
        if disposition == "completed":
            r.completed.append(req.rid)
        elif disposition == "aborted":
            r.aborted[req.rid] = len(req.tokens)
        elif disposition == "shed":
            r.shed.append(req.rid)
        elif disposition == "deadline_miss":
            r.deadline_miss[req.rid] = len(req.tokens)
        else:
            r.unserved.append(req.rid)
        if disposition in ("completed", "aborted", "deadline_miss"):
            r.latency_s[req.rid] = now - req.arrival
            self._total_tokens += len(req.tokens)

    def _warmup(self):
        """Compile the chunk + slot-reset programs off the serving clock
        (on a scratch cache — the live per-slot state is untouched)."""
        with use_rules(self.rules):
            scratch = stack_cache(self._fresh, self.slots)
            feed = jnp.zeros((self.chunk, self.slots), jnp.int32)
            fp = jnp.full((self.slots,), self.chunk, jnp.int32)
            poison = jnp.full((self.slots,), -1, jnp.int32)
            jax.block_until_ready(self._chunk_fn(
                self._params, scratch, self._prev, feed, fp, poison,
                jnp.int32(0)))
            jax.block_until_ready(self._reset_fn(scratch, self._fresh,
                                                 jnp.int32(0)))


def serve_continuous(step, params, make_cache, prompts, lengths=None, *,
                     tokens: int, slots: int | None = None, chunk: int = 8,
                     rules=None, warm: bool = True,
                     token_budget: int | None = None,
                     time_budget_s: float | None = None, eos_id=None,
                     logit_hook=None, arrivals=None, deadlines=None,
                     max_queue: int | None = None, slot_nan_limit: int = 2,
                     clock=None, max_seq: int | None = None):
    """Serve many prompts through the continuous-batching engine.

    Drop-in counterpart of :func:`serve_requests` (same request
    encoding, same :class:`ServeOutput` return with rows zero-padded to
    the effective token count) built on :class:`ContinuousEngine`:
    requests are admitted into slots as they vacate mid-stream, so one
    long request never stalls the others.  Extras over the fixed
    scheduler: ``arrivals`` (per-request arrival times — a seeded
    Poisson trace in the bench), ``deadlines`` (per-request ``deadline_s``
    relative to arrival; enables shedding + ``deadline_miss``),
    ``eos_id`` (per-request early retirement), ``max_queue`` /
    ``slot_nan_limit`` / ``clock`` (see :class:`ContinuousEngine`), and
    ``chunk`` (scan steps per engine iteration — the deadline/admission
    granularity).  ``max_seq`` pins the engine window (default
    ``P + tokens``).
    """
    prompts, lengths = _normalize_requests(prompts, lengths)
    R, P = prompts.shape
    eff = tokens if token_budget is None else max(1, min(tokens,
                                                         token_budget))
    if R == 0:
        return ServeOutput(jnp.zeros((0, eff), jnp.int32), 0.0,
                           ServeReport(tokens_per_request=eff,
                                       engine="continuous"))
    n_slots = min(slots or min(4, R), R)
    window = max_seq if max_seq is not None else P + eff
    eng = ContinuousEngine(step, params, make_cache, slots=n_slots,
                           max_seq=window, chunk=chunk, rules=rules,
                           eos_id=eos_id, logit_hook=logit_hook,
                           clock=clock, max_queue=max_queue,
                           slot_nan_limit=slot_nan_limit, warm=warm)
    pn = np.asarray(jax.device_get(prompts))
    ln = np.asarray(jax.device_get(lengths))
    for r in range(R):
        eng.submit(pn[r, :int(ln[r])], tokens=eff,
                   arrival=0.0 if arrivals is None else float(arrivals[r]),
                   deadline_s=None if deadlines is None
                   else deadlines[r], rid=r)
    t0 = time.perf_counter()
    report = eng.run(time_budget_s=time_budget_s)
    seconds = time.perf_counter() - t0
    report.tokens_per_request = eff
    gen = np.zeros((R, eff), np.int32)
    for r in range(R):
        tk = eng.requests[r].tokens[:eff]
        gen[r, :len(tk)] = tk
    return ServeOutput(jnp.asarray(gen), seconds, report)


def serve_with_failover(step, params, make_cache, prompts, lengths=None, *,
                        tokens: int, slots: int | None = None,
                        chunk: int = 8, rules=None, warm: bool = True,
                        token_budget: int | None = None,
                        time_budget_s: float | None = None, eos_id=None,
                        logit_hook=None, arrivals=None, deadlines=None,
                        max_queue: int | None = None,
                        slot_nan_limit: int = 2, clock=None,
                        max_seq: int | None = None, max_failovers: int = 2,
                        health_check=None, engine_factory=None):
    """:func:`serve_continuous` with cross-host failover.

    Runs the continuous engine; when a worker loss surfaces
    (:class:`WorkerLost` — from ``health_check`` or the ``serve.worker``
    fault point) the coordinator **drains** what finished, **re-forms**
    the engine on surviving capacity, and **replays** every in-flight
    request from its recorded prompt under its original rid.  Decode is
    deterministic and slots are batch-independent, so replayed tokens
    are bit-identical to an uninterrupted run.

    ``engine_factory(attempt) -> dict`` customizes the re-formed engine
    (any :class:`ContinuousEngine` keyword, e.g. ``slots``/``rules`` for
    a survivor mesh from
    :func:`repro.launch.distributed.survivor_mesh`); the default halves
    the slot count per failover.  After ``max_failovers`` re-formations
    the remaining in-flight requests are reported ``unserved`` — every
    rid always carries a disposition.  The merged report records the
    history: ``failovers``, ``lost_workers``, ``replayed`` (rids, with
    repeats if a request was replayed more than once).

    Replay caveat: a replayed request restarts its latency/deadline
    clock at the re-formed engine's epoch (its original arrival offset
    is not re-applied), so with ``deadlines=`` a replay gets a fresh
    deadline rather than an immediate miss.
    """
    prompts, lengths = _normalize_requests(prompts, lengths)
    R, P = prompts.shape
    eff = tokens if token_budget is None else max(1, min(tokens,
                                                         token_budget))
    master = ServeReport(tokens_per_request=eff,
                         engine="continuous+failover")
    if R == 0:
        return ServeOutput(jnp.zeros((0, eff), jnp.int32), 0.0, master)
    base_slots = min(slots or min(4, R), R)
    window = max_seq if max_seq is not None else P + eff
    pn = np.asarray(jax.device_get(prompts))
    ln = np.asarray(jax.device_get(lengths))

    def default_factory(attempt: int) -> dict:
        # survivor capacity stand-in: half the slots per failover (slots
        # are batch-independent, so shrinking never changes tokens)
        return {"slots": max(1, base_slots >> attempt)}

    factory = engine_factory or default_factory
    outstanding = list(range(R))
    tokens_final: dict[int, list[int]] = {}
    seconds = 0.0
    attempt = 0
    while outstanding:
        kw = dict(factory(attempt))
        n_slots = max(1, min(int(kw.pop("slots", base_slots)),
                             len(outstanding)))
        eng = ContinuousEngine(
            step, params, make_cache, slots=n_slots,
            max_seq=kw.pop("max_seq", window), chunk=kw.pop("chunk", chunk),
            rules=kw.pop("rules", rules), eos_id=kw.pop("eos_id", eos_id),
            logit_hook=kw.pop("logit_hook", logit_hook),
            clock=kw.pop("clock", clock),
            max_queue=kw.pop("max_queue", max_queue),
            slot_nan_limit=kw.pop("slot_nan_limit", slot_nan_limit),
            warm=kw.pop("warm", warm),
            health_check=kw.pop("health_check", health_check), **kw)
        replaying = attempt > 0
        for r in outstanding:
            eng.submit(pn[r, :int(ln[r])], tokens=eff,
                       arrival=0.0 if (replaying or arrivals is None)
                       else float(arrivals[r]),
                       deadline_s=None if deadlines is None
                       else deadlines[r], rid=r)
        t0 = time.perf_counter()
        lost = None
        try:
            eng.run(time_budget_s=time_budget_s)
        except WorkerLost as e:
            lost = e
        seconds += time.perf_counter() - t0
        rep = eng.report
        master.completed.extend(rep.completed)
        master.aborted.update(rep.aborted)
        master.shed.extend(rep.shed)
        master.deadline_miss.update(rep.deadline_miss)
        master.unserved.extend(rep.unserved)
        master.latency_s.update(rep.latency_s)
        master.queue_peak = max(master.queue_peak, rep.queue_peak)
        master.admitted += rep.admitted
        master.deadline_hit = master.deadline_hit or rep.deadline_hit
        master.quarantined_slots.extend(rep.quarantined_slots)
        still = []
        for r in outstanding:
            req = eng.requests[r]
            if req.disposition is None:        # in flight at the loss
                still.append(r)
            else:
                tokens_final[r] = list(req.tokens)[:eff]
        outstanding = still
        if lost is None:
            break                              # clean run: all disposed
        master.failovers += 1
        master.lost_workers.extend(lost.lost if lost.lost else [attempt])
        master.replayed.extend(outstanding)
        attempt += 1
        if attempt > max_failovers:
            for r in outstanding:              # give up, but never drop
                master.unserved.append(r)
                tokens_final[r] = []
            outstanding = []
    gen = np.zeros((R, eff), np.int32)
    total = 0
    for r, tk in tokens_final.items():
        gen[r, :len(tk)] = tk
        total += len(tk)
    master.sustained_tok_s = total / max(seconds, 1e-9)
    return ServeOutput(jnp.asarray(gen), seconds, master)
