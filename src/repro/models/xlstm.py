"""xLSTM blocks (arXiv:2405.04517) — mLSTM (matrix memory, chunkwise
parallel) and sLSTM (scalar memory, sequential scan).

mLSTM per head: C_t = f_t C_{t-1} + i_t v_t k_tᵀ ;  n_t = f_t n_{t-1} + i_t k_t
               h_t = C_t q_t / max(|n_tᵀ q_t|, 1)
with log-space stabilization (m_t running max).  Train/prefill uses the
chunkwise-parallel form (intra-chunk quadratic + inter-chunk state carry via
``lax.scan``), the standard linear-attention chunking adapted to exp gates.
Decode is a single fused state update.

sLSTM is inherently sequential — ``lax.scan`` over time.

LayerMerge note: both are prunable-only (input-dependent gates).
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp
from jax import lax


def mlstm_axes():
    return {"wq": ("embed", "heads", "head"), "wk": ("embed", "heads", "head"),
            "wv": ("embed", "heads", "head"), "wi": ("embed", "heads"),
            "wf": ("embed", "heads"), "bf": ("heads",), "bi": ("heads",),
            "wo": ("heads", "head", "embed"), "skip": ("embed", "embed")}


def init_mlstm(cfg, key, dtype):
    d, h = cfg.d_model, cfg.num_heads
    hd = d // h
    ks = jax.random.split(key, 7)
    s = 1.0 / math.sqrt(d)
    p = {"wq": jax.random.normal(ks[0], (d, h, hd), dtype) * s,
         "wk": jax.random.normal(ks[1], (d, h, hd), dtype) * s,
         "wv": jax.random.normal(ks[2], (d, h, hd), dtype) * s,
         "wi": jax.random.normal(ks[3], (d, h), dtype) * s,
         "wf": jax.random.normal(ks[4], (d, h), dtype) * s,
         "bf": jnp.full((h,), 3.0, dtype),       # forget-gate bias (keep)
         "bi": jnp.zeros((h,), dtype),
         "wo": jax.random.normal(ks[5], (h, hd, d), dtype) * s,
         "skip": jax.random.normal(ks[6], (d, d), dtype) * s}
    return p, mlstm_axes()


def _mlstm_chunk_scan(q, k, v, log_i, log_f, chunk: int):
    """Chunkwise-parallel mLSTM.  q,k,v: (B,S,H,D); gates: (B,S,H) logs."""
    b, s, h, d = q.shape
    assert s % chunk == 0, (s, chunk)
    nc = s // chunk
    q = q.reshape(b, nc, chunk, h, d)
    k = k.reshape(b, nc, chunk, h, d)
    v = v.reshape(b, nc, chunk, h, d)
    log_i = log_i.reshape(b, nc, chunk, h).astype(jnp.float32)
    log_f = log_f.reshape(b, nc, chunk, h).astype(jnp.float32)
    csum_f = jnp.cumsum(log_f, axis=2)                     # within-chunk
    total_f = csum_f[:, :, -1]                             # (B,NC,H)

    # intra-chunk decay matrix: D[t,u] = sum_{u<τ<=t} logf + logi_u  (u <= t)
    dmat = csum_f[:, :, :, None, :] - csum_f[:, :, None, :, :] \
        + log_i[:, :, None, :, :]                          # (B,NC,T,U,H)
    tri = jnp.tril(jnp.ones((chunk, chunk), bool))
    dmat = jnp.where(tri[None, None, :, :, None], dmat, -jnp.inf)

    def body(carry, xs):
        C, n, m = carry            # (B,H,D,D), (B,H,D), (B,H)
        qc, kc, vc, d_c, csf, lgi, tot = xs
        # stabilizer: max over inter (m + csf) and intra (row max of dmat)
        intra_max = jnp.max(d_c, axis=2)                   # (B,T,H) over U
        m_new = jnp.maximum(m[:, None] + csf, intra_max)   # (B,T,H)
        inter_w = jnp.exp(m[:, None] + csf - m_new)        # (B,T,H)
        intra_w = jnp.exp(d_c - m_new[:, :, None])         # (B,T,U,H)
        # intra-chunk attention
        scores = jnp.einsum("bthd,buhd->btuh", qc, kc) / math.sqrt(d)
        att = scores * intra_w
        out_intra = jnp.einsum("btuh,buhd->bthd", att, vc)
        # inter-chunk contribution
        # C is (value_dim d, key_dim e): contract q against the key dim
        out_inter = jnp.einsum("bthe,bhde->bthd", qc, C) / math.sqrt(d)
        out_inter = out_inter * inter_w[..., None]
        den_intra = jnp.sum(att, axis=2)                   # Σ_u w·(kᵀq/√d)
        den_inter = jnp.einsum("bthd,bhd->bth", qc, n) / math.sqrt(d) * inter_w
        den = jnp.abs(den_intra + den_inter)
        out = (out_intra + out_inter) / jnp.maximum(den, 1.0)[..., None]
        # carry state to end of chunk (stabilized by the new running max)
        m_end = jnp.maximum(m + tot, jnp.max(d_c[:, -1], axis=1))
        decay_old = jnp.exp(m + tot - m_end)               # (B,H)
        kw_st = jnp.exp(csf[:, -1][:, None] - csf + lgi - m_end[:, None])
        C_new = C * decay_old[..., None, None] \
            + jnp.einsum("buh,buhd,buhe->bhde", kw_st, vc, kc)
        n_new = n * decay_old[..., None] \
            + jnp.einsum("buh,buhd->bhd", kw_st, kc)
        return (C_new, n_new, m_end), out

    C0 = jnp.zeros((b, h, d, d), jnp.float32)
    n0 = jnp.zeros((b, h, d), jnp.float32)
    m0 = jnp.full((b, h), -1e30, jnp.float32)
    xs = (jnp.moveaxis(q, 1, 0).astype(jnp.float32),
          jnp.moveaxis(k, 1, 0).astype(jnp.float32),
          jnp.moveaxis(v, 1, 0).astype(jnp.float32),
          jnp.moveaxis(dmat, 1, 0),
          jnp.moveaxis(csum_f, 1, 0),
          jnp.moveaxis(log_i, 1, 0),
          jnp.moveaxis(total_f, 1, 0))
    _, out = lax.scan(body, (C0, n0, m0), xs)
    out = jnp.moveaxis(out, 0, 1).reshape(b, s, h, d)
    return out


def mlstm_block(p, x, cfg, chunk: int = 64):
    b, s, d = x.shape
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"])
    log_i = jax.nn.log_sigmoid((x @ p["wi"] + p["bi"]).astype(jnp.float32))
    log_f = jax.nn.log_sigmoid((x @ p["wf"] + p["bf"]).astype(jnp.float32))
    chunk = min(chunk, s)
    out = _mlstm_chunk_scan(q, k, v, log_i, log_f, chunk)
    y = jnp.einsum("bshk,hkd->bsd", out.astype(x.dtype), p["wo"])
    return y + jax.nn.silu(x @ p["skip"])


def mlstm_decode(p, x, cfg, state):
    """state: {"C": (B,H,D,D) f32, "n": (B,H,D) f32, "m": (B,H) f32}."""
    b = x.shape[0]
    d = x.shape[-1]
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])[:, 0]
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"])[:, 0]
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"])[:, 0]
    log_i = jax.nn.log_sigmoid((x @ p["wi"] + p["bi"]))[:, 0].astype(jnp.float32)
    log_f = jax.nn.log_sigmoid((x @ p["wf"] + p["bf"]))[:, 0].astype(jnp.float32)
    m_new = jnp.maximum(state["m"] + log_f, log_i)
    decay = jnp.exp(state["m"] + log_f - m_new)
    inw = jnp.exp(log_i - m_new)
    kf, vf, qf = (t.astype(jnp.float32) for t in (k, v, q))
    C = state["C"] * decay[..., None, None] \
        + inw[..., None, None] * vf[..., :, None] * kf[..., None, :]
    n = state["n"] * decay[..., None] + inw[..., None] * kf
    hd = q.shape[-1]
    num = jnp.einsum("bhde,bhe->bhd", C, qf) / math.sqrt(hd)
    den = jnp.abs(jnp.einsum("bhd,bhd->bh", n, qf)) / math.sqrt(hd)
    out = (num / jnp.maximum(den, 1.0)[..., None]).astype(x.dtype)
    y = jnp.einsum("bhk,hkd->bd", out, p["wo"])[:, None]
    return y + jax.nn.silu(x @ p["skip"]), \
        {"C": C, "n": n, "m": m_new}


def init_mlstm_state(cfg, batch):
    h = cfg.num_heads
    hd = cfg.d_model // h
    return {"C": jnp.zeros((batch, h, hd, hd), jnp.float32),
            "n": jnp.zeros((batch, h, hd), jnp.float32),
            "m": jnp.full((batch, h), -1e30, jnp.float32)}


MLSTM_STATE_AXES = {"C": ("batch", "heads", None, None),
                    "n": ("batch", "heads", None), "m": ("batch", "heads")}


# ---------------------------------------------------------------------------
# sLSTM
# ---------------------------------------------------------------------------

def slstm_axes():
    return {"wz": ("embed", "heads", "head"), "wi": ("embed", "heads", "head"),
            "wf": ("embed", "heads", "head"),
            "wo_gate": ("embed", "heads", "head"), "bf": ("heads", "head"),
            "wo": ("heads", "head", "embed")}


def init_slstm(cfg, key, dtype):
    d, h = cfg.d_model, cfg.num_heads
    hd = d // h
    ks = jax.random.split(key, 5)
    s = 1.0 / math.sqrt(d)
    p = {"wz": jax.random.normal(ks[0], (d, h, hd), dtype) * s,
         "wi": jax.random.normal(ks[1], (d, h, hd), dtype) * s,
         "wf": jax.random.normal(ks[2], (d, h, hd), dtype) * s,
         "wo_gate": jax.random.normal(ks[3], (d, h, hd), dtype) * s,
         "bf": jnp.full((h, hd), 3.0, dtype),
         "wo": jax.random.normal(ks[4], (h, hd, d), dtype) * s}
    return p, slstm_axes()


def _slstm_step(carry, gates):
    c, n, m = carry
    z, i_log, f_log, o = gates
    m_new = jnp.maximum(f_log + m, i_log)
    i_w = jnp.exp(i_log - m_new)
    f_w = jnp.exp(f_log + m - m_new)
    c_new = f_w * c + i_w * jnp.tanh(z)
    n_new = f_w * n + i_w
    h = o * c_new / jnp.maximum(n_new, 1.0)
    return (c_new, n_new, m_new), h


def slstm_block(p, x, cfg):
    b, s, d = x.shape
    z = jnp.einsum("bsd,dhk->bshk", x, p["wz"]).astype(jnp.float32)
    i_log = jnp.einsum("bsd,dhk->bshk", x, p["wi"]).astype(jnp.float32)
    f_log = jax.nn.log_sigmoid(
        jnp.einsum("bsd,dhk->bshk", x, p["wf"]).astype(jnp.float32)
        + p["bf"].astype(jnp.float32))
    o = jax.nn.sigmoid(
        jnp.einsum("bsd,dhk->bshk", x, p["wo_gate"]).astype(jnp.float32))
    zeros = jnp.zeros((b,) + z.shape[2:], jnp.float32)
    carry0 = (zeros, zeros, jnp.full_like(zeros, -1e30))
    xs = tuple(jnp.moveaxis(t, 1, 0) for t in (z, i_log, f_log, o))
    _, h = lax.scan(_slstm_step, carry0, xs)
    h = jnp.moveaxis(h, 0, 1).astype(x.dtype)              # (B,S,H,D)
    return jnp.einsum("bshk,hkd->bsd", h, p["wo"])


def slstm_decode(p, x, cfg, state):
    z = jnp.einsum("bsd,dhk->bshk", x, p["wz"])[:, 0].astype(jnp.float32)
    i_log = jnp.einsum("bsd,dhk->bshk", x, p["wi"])[:, 0].astype(jnp.float32)
    f_log = jax.nn.log_sigmoid(
        jnp.einsum("bsd,dhk->bshk", x, p["wf"])[:, 0].astype(jnp.float32)
        + p["bf"].astype(jnp.float32))
    o = jax.nn.sigmoid(
        jnp.einsum("bsd,dhk->bshk", x, p["wo_gate"])[:, 0].astype(jnp.float32))
    carry = (state["c"], state["n"], state["m"])
    carry, h = _slstm_step(carry, (z, i_log, f_log, o))
    y = jnp.einsum("bhk,hkd->bd", h.astype(x.dtype), p["wo"])[:, None]
    return y, {"c": carry[0], "n": carry[1], "m": carry[2]}


def init_slstm_state(cfg, batch):
    h = cfg.num_heads
    hd = cfg.d_model // h
    z = jnp.zeros((batch, h, hd), jnp.float32)
    return {"c": z, "n": z, "m": jnp.full_like(z, -1e30)}


SLSTM_STATE_AXES = {"c": ("batch", "heads", None),
                    "n": ("batch", "heads", None),
                    "m": ("batch", "heads", None)}
