"""Segment enumeration — ``K_ij`` and the Eq. 3 kept-set selection.

The paper's key structural insight is that the latency of a merged segment
depends on the kept-layer subset ``C ∩ (i, j]`` *only through the merged
size* ``k = 1 + Σ_{l∈C∩(i,j]} (Ker(θ_l) − 1)`` (kernel size for convs; the
``+1``-free rank sum for transformer blocks).  So for each segment ``(i, j]``
we enumerate the achievable sizes ``K_ij`` and, for every ``k ∈ K_ij``,
select *one* representative kept subset ``Ĉ_ijk`` — the one of maximal total
parameter ℓ1-norm (Eq. 3), which is the standard magnitude criterion of the
channel/layer-pruning literature.

The selection is an exact small DP over (layer, partial size): weights are
the per-layer growths (``Ker−1`` / rank), values are the ℓ1 norms, layers in
the irreducible set ``R`` are *forced*.  Complexity ``O(n · K₀)`` per
segment, matching the paper's ``O(L² K₀)`` table bound overall.
"""
from __future__ import annotations

import math
from typing import Mapping, Sequence

import numpy as np

from .plan import LayerDesc

_NEG = -math.inf


def subset_selection(
    items: Sequence[tuple[int, int, float]],
    forced: Sequence[int] = (),
    cap: int | None = None,
) -> dict[int, tuple[float, tuple[int, ...]]]:
    """Exact max-value subset per achievable weight sum.

    The weight axis is a flat NumPy array (one float row plus an
    items × weights take-bit matrix for reconstruction) rather than a dict of
    partial states, so each item is two vector ops instead of a Python loop
    over states — the same recurrence, same floats, same tie-breaking (an
    equal-value candidate never displaces the skip branch).

    Args:
      items: ``(id, weight, value)`` triples; weights are non-negative ints.
      forced: ids that must be included (the paper's ``R ∩ (i, j]``).
      cap: if given, weight sums are clamped to ``cap`` (the transformer rank
        saturates at ``d_model``); the max-value subset is kept per clamped
        key.

    Returns:
      ``{weight_sum: (total_value, kept_ids)}`` — for every achievable
      (clamped) weight sum, the maximum total value and one argmax subset.
    """
    forced_set = set(forced)
    n = len(items)
    W = sum(w for _, w, _ in items)
    values = np.full(W + 1, _NEG, dtype=np.float64)
    values[0] = 0.0
    took = np.zeros((n, W + 1), dtype=bool)
    for idx, (ident, w, v) in enumerate(items):
        shifted = np.full(W + 1, _NEG)
        np.add(values[:W + 1 - w], v, out=shifted[w:])
        if ident in forced_set:
            took[idx] = shifted != _NEG
            values = shifted
        else:
            upd = shifted > values          # strict: ties keep the skip branch
            took[idx] = upd
            values = np.where(upd, shifted, values)

    def backtrack(wt: int) -> tuple[int, ...]:
        ids = []
        t = wt
        for idx in range(n - 1, -1, -1):
            ident, w, _v = items[idx]
            if took[idx, t]:
                ids.append(ident)
                t -= w
        return tuple(sorted(ids))

    reachable = [int(wt) for wt in np.nonzero(values != _NEG)[0]]
    if cap is None:
        return {wt: (float(values[wt]), backtrack(wt)) for wt in reachable}
    clamped: dict[int, int] = {}
    for wt in reachable:                    # ascending: ties keep smallest wt
        key = min(wt, cap)
        if key not in clamped or values[wt] > values[clamped[key]]:
            clamped[key] = wt
    return {key: (float(values[wt]), backtrack(wt))
            for key, wt in clamped.items()}


def pareto_prune_options(
    opts: Mapping[int, tuple[float, float, tuple[int, ...]]],
) -> dict[int, tuple[float, float, tuple[int, ...]]]:
    """Drop dominated ``k → (I, T, kept)`` options within one span.

    Option ``a`` dominates ``b`` when ``I_a ≥ I_b`` and ``T_a ≤ T_b`` (ties
    resolved toward the smaller ``k``).  Dominated options can never appear
    in an optimal plan of Problem 5 — the DP maximizes ΣI under a ΣT budget,
    so swapping a dominated pick for its dominator keeps feasibility and
    does not lower the objective.  Pruning therefore preserves the DP's
    optimum exactly while shrinking the candidate set it sweeps.

    Keys may be plain ints or ``(k, quant-mode)`` precision siblings (see
    :data:`repro.core.dp.TableFn`); the tie-break key normalizes both so
    mixed tables sort deterministically — fp before quantized at equal
    ``(T, I, k)``, identical order to before on fp-only tables.
    """
    def keyf(kv):
        from .dp import split_key
        k, mode = split_key(kv[0])
        return (kv[1][1], -kv[1][0], k, mode != "none", mode)

    ordered = sorted(opts.items(), key=keyf)
    out: dict[int, tuple[float, float, tuple[int, ...]]] = {}
    best_i = _NEG
    for k, (imp, lat, kept) in ordered:
        if imp > best_i:
            out[k] = (imp, lat, kept)
            best_i = imp
    return out


class SegmentEnumerator:
    """Computes ``K_ij`` and ``Ĉ_ijk`` for a chain of :class:`LayerDesc`.

    Two conventions, selected by ``offset``:

    * CNN (``offset=1``): merged size ``k = 1 + Σ (Ker−1)`` over kept convs —
      the interior of ``(i, j]`` is *all* of ``i+1..j`` and the boundary
      activation ``σ_j`` is kept (Eq. 1 of the paper).
    * Transformer (``offset=0``): merged size = Σ rank over kept linearized
      blocks, clamped at ``cap=d_model``.

    ``barriers`` lets a host forbid segment spans (skip-concat boundaries,
    strided-conv restriction, attention kept-blocks, …) via a predicate.
    """

    def __init__(
        self,
        descs: Sequence[LayerDesc],
        *,
        offset: int = 1,
        cap: int | None = None,
        allowed_span=None,        # (i, j) -> bool
        depth_mode: bool = False,  # Depth baseline (Kim et al. 2023): C = [L]
        max_span: int | None = None,
    ):
        self.descs = list(descs)
        self.L = len(self.descs)
        self.offset = offset
        self.cap = cap
        self.allowed_span = allowed_span or (lambda i, j: True)
        self.depth_mode = depth_mode
        self.max_span = max_span

    def options(self, i: int, j: int) -> dict[int, tuple[float, tuple[int, ...]]]:
        """All ``k → (ℓ1 value, Ĉ_ijk)`` choices for segment ``(i, j]``.

        Returns an empty dict when the span is not mergeable (a
        non-linearizable, non-prunable layer sits strictly inside, or the
        host's span predicate rejects it).
        """
        if not (0 <= i < j <= self.L):
            raise ValueError(f"bad segment ({i}, {j}]")
        if self.max_span is not None and (j - i) > self.max_span:
            return {}
        if not self.allowed_span(i, j):
            return {}
        layers = self.descs[i:j]            # descs are 0-indexed; layer l = descs[l-1]
        interior = layers[:-1] if self.offset == 0 else layers
        boundary = layers[-1] if self.offset == 0 else None

        # Singleton fallback (CNN convention): a barrier unit (pool /
        # upsample / attention) can only be kept exactly as-is.
        if self.offset == 1 and j - i == 1 and not layers[0].linearizable:
            d = layers[0]
            return {d.growth + self.offset: (d.value, (d.index,))}

        items: list[tuple[int, int, float]] = []
        forced: list[int] = []
        # Stride-aware growth (CNN convention): a kept conv after a stride-s
        # prefix grows the merged kernel by (Ker−1)·s (Eq. 1 with strides),
        # so the k coordinate stays the *true* merged kernel size on strided
        # spans.  Strided layers are never prunable (not shape-preserving),
        # hence the prefix product is deterministic per span.  Hosts without
        # stride metadata (transformers) see s ≡ 1 — weights unchanged.
        s_prefix = 1
        for d in interior:
            if d.linearizable:
                items.append((d.index, d.growth * s_prefix, d.value))
                if not d.prunable:
                    forced.append(d.index)
            s_prefix *= int(d.meta.get("stride", 1)) if d.meta else 1
            if not d.linearizable:
                # Non-linearizable layer strictly inside a merged segment: it
                # must be pruned; if it cannot be pruned the span is invalid.
                if not d.prunable:
                    return {}
        if self.depth_mode:
            # Depth baseline: every layer is kept — exactly one k per span.
            forced = [d.index for d in interior if d.linearizable]
            if any(not d.linearizable for d in interior):
                return {}

        sel = subset_selection(items, forced=forced, cap=self.cap)
        out: dict[int, tuple[float, tuple[int, ...]]] = {}
        for w, (val, kept) in sel.items():
            k = w + self.offset
            kept_ids = kept
            if boundary is not None:
                # Transformer convention: the boundary block j is kept as-is.
                kept_ids = tuple(sorted(kept + (boundary.index,)))
                val = val + boundary.value
            out[k] = (val, kept_ids)
        if self.depth_mode and len(out) > 1:   # defensive: must be single-k
            k = max(out)
            out = {k: out[k]}
        return out

    def singleton_original_k(self, j: int) -> int:
        """The ``k`` coordinate of keeping layer ``j`` exactly as-is."""
        return self.descs[j - 1].growth + self.offset

    def all_spans(self):
        for i in range(self.L):
            for j in range(i + 1, self.L + 1):
                opts = self.options(i, j)
                if opts:
                    yield i, j, opts


def table_entry_count(enum: SegmentEnumerator) -> int:
    """Number of (i, j, k) lookup-table entries (paper Table 7/8 metric)."""
    return sum(len(opts) for _, _, opts in enum.all_spans())
