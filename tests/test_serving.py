"""Serving-protocol tests: jitted scan loops + the slot scheduler.

The jitted chunked-prefill/scan-decode loop must reproduce the PR-4
per-token reference token for token, and the fused ragged-prompt scan
behind ``serve_requests`` must serve every slot EXACTLY as if its prompt
were served alone (no pad token may ever enter a KV cache).
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import transformer as T
from repro.runtime import serving
from repro.train.step import make_serve_step


@pytest.fixture(scope="module")
def lm():
    cfg = dataclasses.replace(
        get_config("smollm-135m").reduced(), num_layers=2, d_model=64,
        num_heads=4, num_kv_heads=2, head_dim=16, d_ff=128, vocab_size=128)
    params, _ = T.init_model(cfg, jax.random.PRNGKey(0))
    return cfg, params, make_serve_step(cfg)


def test_scan_loop_matches_pertoken(lm):
    """ONE jitted chunked prefill + ONE scan decode ≡ the per-token
    dispatch loop: same ids, same last-prompt-position logits."""
    cfg, params, step = lm
    B, P, N = 3, 10, 6
    prompt = serving.random_prompts(1, B, P, cfg.vocab_size)
    _, _, lg1, s1 = serving.serve_loop(
        step, params, T.init_cache(cfg, B, P + N), prompt, N)
    _, _, lg2, s2 = serving.serve_loop_pertoken(
        step, params, T.init_cache(cfg, B, P + N), prompt, N)
    np.testing.assert_array_equal(np.asarray(s1), np.asarray(s2))
    np.testing.assert_allclose(np.asarray(lg1), np.asarray(lg2), rtol=1e-5)
    assert s1.shape == (B, N)


def test_single_token_generation(lm):
    """tokens=1 degenerates to prefill + argmax (scan of length 0)."""
    cfg, params, step = lm
    prompt = serving.random_prompts(2, 2, 5, cfg.vocab_size)
    _, _, logits, seqs = serving.serve_loop(
        step, params, T.init_cache(cfg, 2, 6), prompt, 1)
    assert seqs.shape == (2, 1)
    np.testing.assert_array_equal(np.asarray(seqs[:, 0]),
                                  np.asarray(jnp.argmax(logits, axis=-1)))


def test_scheduler_exact_on_ragged_prompts(lm):
    """Every slot of the fused mixed-length scan reproduces single-prompt
    serving bit for bit — teacher-forcing ends per slot at its own
    length, so pads never pollute a cache."""
    cfg, params, step = lm
    N = 6
    rng = np.random.RandomState(0)
    prompts = [jnp.asarray(rng.randint(0, cfg.vocab_size, size=n), jnp.int32)
               for n in (5, 9, 3, 7, 6)]
    mat, lens = serving.pad_prompts(prompts)
    assert mat.shape == (5, 9) and lens.tolist() == [5, 9, 3, 7, 6]
    gen, _ = serving.serve_requests(
        step, params, lambda b, s: T.init_cache(cfg, b, s), mat, lens,
        tokens=N, slots=2)
    assert gen.shape == (5, N)
    for i, p in enumerate(prompts):
        _, _, _, solo = serving.serve_loop(
            step, params, T.init_cache(cfg, 1, len(p) + N), p[None, :], N)
        np.testing.assert_array_equal(np.asarray(gen[i]),
                                      np.asarray(solo[0]))


def test_scheduler_slot_count_invariance(lm):
    """Greedy generations must not depend on the slot partitioning."""
    cfg, params, step = lm
    prompt = serving.random_prompts(3, 4, 8, cfg.vocab_size)
    lens = jnp.full((4,), 8, jnp.int32)
    mk = lambda b, s: T.init_cache(cfg, b, s)                # noqa: E731
    outs = [serving.serve_requests(step, params, mk, prompt, lens,
                                   tokens=5, slots=k)[0]
            for k in (1, 3, 4)]
    np.testing.assert_array_equal(np.asarray(outs[0]), np.asarray(outs[1]))
    np.testing.assert_array_equal(np.asarray(outs[0]), np.asarray(outs[2]))


def test_prompt_glue():
    p = serving.random_prompts(0, 3, 7, 32)
    assert p.shape == (3, 7) and int(p.max()) < 32 and int(p.min()) >= 0
    assert serving.decode_tok_s(10, 4, 2.0) == 20.0
    assert serving.decode_tok_s(10, 4, 0.0) > 0          # no div-by-zero
    tok = serving.greedy_token(jnp.asarray([[[0.0, 2.0, 1.0]]]))
    assert tok.shape == (1,) and int(tok[0]) == 1


# ---------------------------------------------------------------------------
# Scheduler edge cases — must return cleanly, not rely on untested paths
# ---------------------------------------------------------------------------

def _mk(cfg):
    return lambda b, s: T.init_cache(cfg, b, s)


def test_zero_prompts_returns_empty(lm):
    cfg, params, step = lm
    out = serving.serve_requests(step, params, _mk(cfg), [], tokens=4)
    gen, secs = out                                      # still unpacks
    assert gen.shape == (0, 4)
    assert secs >= 0.0
    assert out.report.ok and out.report.rounds == 0


def test_prompt_longer_than_pad_window_rejected(lm):
    """A prompt that exceeds the pinned pad window must raise up front —
    silently truncating it would serve a different request."""
    cfg, params, step = lm
    rng = np.random.RandomState(4)
    prompts = [jnp.asarray(rng.randint(0, cfg.vocab_size, size=n), jnp.int32)
               for n in (3, 12)]
    with pytest.raises(ValueError, match="longest"):
        serving.pad_prompts(prompts, pad_to=8)
    # served with an adequate window, the long prompt round-trips exactly
    mat, lens = serving.pad_prompts(prompts, pad_to=12)
    gen, _ = serving.serve_requests(step, params, _mk(cfg), mat, lens,
                                    tokens=4, slots=2)
    _, _, _, solo = serving.serve_loop(
        step, params, T.init_cache(cfg, 1, 16), prompts[1][None, :], 4)
    np.testing.assert_array_equal(np.asarray(gen[1]), np.asarray(solo[0]))


def test_all_slots_retired_early(lm):
    """Fewer requests than slots: the round pads with filler, retires
    every real request in one pass, and reports them all completed."""
    cfg, params, step = lm
    prompts = [serving.random_prompts(5, 1, 6, cfg.vocab_size)[0]]
    out = serving.serve_requests(step, params, _mk(cfg), prompts,
                                 tokens=5, slots=8)
    gen, _ = out
    assert gen.shape == (1, 5)
    assert out.report.completed == [0] and out.report.rounds == 1


# ---------------------------------------------------------------------------
# Hardened serving — NaN slot abort, budgets, drain
# ---------------------------------------------------------------------------

def test_nan_slot_aborts_alone_others_token_identical(lm):
    """ISSUE acceptance: poisoning one slot's logits mid-decode retires
    that slot (zeroed from the failure index) while every other request
    is TOKEN-IDENTICAL to the fault-free run."""
    from repro.testing import faults

    cfg, params, step = lm
    N = 6
    prompt = serving.random_prompts(7, 4, 5, cfg.vocab_size)
    lens = jnp.full((4,), 5, jnp.int32)
    clean, _ = serving.serve_requests(step, params, _mk(cfg), prompt, lens,
                                      tokens=N, slots=4)
    # scan step 6 = generation index 2 for length-5 prompts (first
    # generated token is at step lengths-1 = 4)
    hook = faults.nan_logits_hook(slot=1, step=6)
    out = serving.serve_requests(step, params, _mk(cfg), prompt, lens,
                                 tokens=N, slots=4, logit_hook=hook)
    gen = out[0]
    assert out.report.aborted == {1: 2}
    assert sorted(out.report.completed) == [0, 2, 3]
    for r in (0, 2, 3):                                  # bit-untouched
        np.testing.assert_array_equal(np.asarray(gen[r]),
                                      np.asarray(clean[r]))
    np.testing.assert_array_equal(np.asarray(gen[1, :2]),
                                  np.asarray(clean[1, :2]))
    assert np.asarray(gen[1, 2:]).tolist() == [0] * (N - 2)


def test_nan_during_prefill_aborts_whole_slot(lm):
    from repro.testing import faults

    cfg, params, step = lm
    prompt = serving.random_prompts(8, 2, 5, cfg.vocab_size)
    lens = jnp.full((2,), 5, jnp.int32)
    hook = faults.nan_logits_hook(slot=0, step=1)        # teacher-forcing
    out = serving.serve_requests(step, params, _mk(cfg), prompt, lens,
                                 tokens=4, slots=2, logit_hook=hook)
    assert out.report.aborted == {0: 0}                  # clipped to 0
    assert np.asarray(out[0][0]).tolist() == [0, 0, 0, 0]


def test_token_budget_caps_generation(lm):
    cfg, params, step = lm
    prompt = serving.random_prompts(9, 3, 6, cfg.vocab_size)
    lens = jnp.full((3,), 6, jnp.int32)
    full, _ = serving.serve_requests(step, params, _mk(cfg), prompt, lens,
                                     tokens=6, slots=3)
    out = serving.serve_requests(step, params, _mk(cfg), prompt, lens,
                                 tokens=6, slots=3, token_budget=3)
    gen, _ = out
    assert gen.shape == (3, 3)
    assert out.report.tokens_per_request == 3
    # greedy decode is prefix-stable: the capped run is the full run's prefix
    np.testing.assert_array_equal(np.asarray(gen), np.asarray(full[:, :3]))


def test_time_budget_drains_cleanly(lm):
    cfg, params, step = lm
    prompt = serving.random_prompts(10, 3, 5, cfg.vocab_size)
    lens = jnp.full((3,), 5, jnp.int32)
    out = serving.serve_requests(step, params, _mk(cfg), prompt, lens,
                                 tokens=4, slots=1, warm=False,
                                 time_budget_s=0.0)
    gen, _ = out
    assert gen.shape == (3, 4)                           # shape preserved
    assert out.report.deadline_hit
    assert out.report.unserved == [0, 1, 2]
    assert np.asarray(gen).tolist() == [[0] * 4] * 3
    # a generous budget admits everything
    ok = serving.serve_requests(step, params, _mk(cfg), prompt, lens,
                                tokens=4, slots=1, time_budget_s=60.0)
    assert ok.report.ok and ok.report.rounds == 3


def test_deadline_enforced_per_chunk(lm):
    """ISSUE 7 satellite regression: the wall-clock budget used to be
    checked only between full decode rounds, so one long round could
    blow far past it.  With the deterministic TickClock (one tick per
    clock read) a 12-step round under ``deadline_chunk=4`` must stop
    after the second segment: the in-flight request keeps its 5 partial
    tokens as a ``deadline_miss``, the queued request is unserved."""
    from repro.testing.faults import TickClock

    cfg, params, step = lm
    rng = np.random.RandomState(12)
    prompts = [jnp.asarray(rng.randint(0, cfg.vocab_size, size=4),
                           jnp.int32) for _ in range(2)]
    mat, lens = serving.pad_prompts(prompts)
    full, _ = serving.serve_requests(step, params, _mk(cfg), mat, lens,
                                     tokens=9, slots=1)
    # clock reads: t0=0; round-0 admission check t=1 (<=2.5); segment
    # checks t=2 (ok), t=3 (> 2.5 ⇒ stop after 8 of 12 steps)
    out = serving.serve_requests(step, params, _mk(cfg), mat, lens,
                                 tokens=9, slots=1, warm=False,
                                 time_budget_s=2.5, deadline_chunk=4,
                                 clock=TickClock())
    gen = np.asarray(out[0])
    assert out.report.deadline_hit
    assert out.report.deadline_miss == {0: 5}    # 8 steps - (4-1) prompt
    assert out.report.unserved == [1]
    assert out.report.rounds == 1
    np.testing.assert_array_equal(gen[0, :5], np.asarray(full[0, :5]))
    assert gen[0, 5:].tolist() == [0] * 4
    assert gen[1].tolist() == [0] * 9


def test_chunked_deadline_path_matches_unchunked(lm):
    """Cutting a round into deadline segments must not change a single
    token when the budget is generous."""
    cfg, params, step = lm
    prompt = serving.random_prompts(3, 4, 8, cfg.vocab_size)
    lens = jnp.full((4,), 8, jnp.int32)
    plain = serving.serve_requests(step, params, _mk(cfg), prompt, lens,
                                   tokens=5, slots=2)
    chunked = serving.serve_requests(step, params, _mk(cfg), prompt, lens,
                                     tokens=5, slots=2, time_budget_s=60.0,
                                     deadline_chunk=3)
    np.testing.assert_array_equal(np.asarray(plain[0]),
                                  np.asarray(chunked[0]))
    assert chunked.report.ok
    assert sorted(chunked.report.completed) == sorted(
        plain.report.completed)


def test_legacy_serve_output_shape_pinned(lm):
    """ISSUE 7 back-compat satellite: PR-5/PR-6 callers unpack
    ``(gen, seconds)`` and read the PR-6 ServeReport fields; the
    overload-safety extension must not disturb either."""
    cfg, params, step = lm
    prompt = serving.random_prompts(2, 2, 4, cfg.vocab_size)
    lens = jnp.full((2,), 4, jnp.int32)
    out = serving.serve_requests(step, params, _mk(cfg), prompt, lens,
                                 tokens=3, slots=2)
    assert isinstance(out, tuple) and len(out) == 2
    gen, seconds = out                                   # tuple unpacking
    assert gen.shape == (2, 3) and seconds >= 0.0
    rep = out.report
    # PR-6 surface, semantics unchanged
    assert rep.completed == [0, 1]
    assert rep.aborted == {} and rep.unserved == []
    assert rep.rounds == 1 and rep.tokens_per_request == 3
    assert rep.deadline_hit is False and rep.ok
    # PR-7 fields exist and default empty on the legacy path
    assert rep.shed == [] and rep.deadline_miss == {}
    assert rep.quarantined_slots == [] and rep.queue_peak == 0
    assert rep.engine == "fixed"
    assert rep.dispositions == {0: "completed", 1: "completed"}
