"""LayerMerge core — the paper's contribution as a composable JAX module."""
from .plan import CompressionPlan, LayerDesc, Segment, identity_plan
from .segments import (SegmentEnumerator, pareto_prune_options,
                       subset_selection, table_entry_count)
from .dp import (solve_dp, solve_dp_reference, solve_knapsack, brute_force,
                 DPResult)
from .latency import (AnalyticTPUOracle, WallClockOracle, CostBreakdown,
                      conv2d_cost, matmul_cost, rank_ffn_cost)
from .importance import (ImportanceSpec, measure_importance,
                         magnitude_importance, adam_finetune_batched,
                         xent_loss, accuracy_perf, neg_loss_perf,
                         distill_loss)
from .probe_engine import (EngineStats, ProbeCallable, ProbeConfig,
                           ProbeTimeout, layer_latencies,
                           measure_latencies, measure_importances)
from .tables import Tables, build_tables, enumerate_probes, one_segment_plan
from .compress import CompressResult, compress, original_latency
from . import table_cache
from .dist_build import (DistBuildError, DistReport, WorkItem,
                         dist_build_tables, latency_work_items)

__all__ = [
    "CompressionPlan", "LayerDesc", "Segment", "identity_plan",
    "SegmentEnumerator", "pareto_prune_options", "subset_selection",
    "table_entry_count",
    "solve_dp", "solve_dp_reference", "solve_knapsack", "brute_force",
    "DPResult",
    "AnalyticTPUOracle", "WallClockOracle", "CostBreakdown",
    "conv2d_cost", "matmul_cost", "rank_ffn_cost",
    "ImportanceSpec", "measure_importance", "magnitude_importance",
    "adam_finetune_batched",
    "xent_loss", "accuracy_perf", "neg_loss_perf", "distill_loss",
    "EngineStats", "ProbeCallable", "ProbeConfig", "ProbeTimeout",
    "layer_latencies", "measure_latencies", "measure_importances",
    "Tables", "build_tables", "enumerate_probes", "one_segment_plan",
    "CompressResult", "compress", "original_latency",
    "table_cache",
    "DistBuildError", "DistReport", "WorkItem", "dist_build_tables",
    "latency_work_items",
]
