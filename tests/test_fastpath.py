"""Certification of this PR's performance fast paths.

Seeded ``numpy.random`` randomized equivalence (no hypothesis dependency):

* vectorized ``solve_dp`` == scalar ``solve_dp_reference`` — bit-identical
  plans, objectives, and latencies (same candidate order, same strict-max
  tie-breaking);
* both == ``brute_force`` on small instances (Theorem 3.1);
* Pareto-dominance pruning of the lookup tables preserves the DP optimum;
* the tiled merged-conv kernel (interpret mode; since PR 2 the tiles are
  DMA'd from an HBM-resident input) matches the jnp oracle across odd
  shapes, ragged halo tiles, and the fused bias+activation epilogue —
  strided/W-tiled coverage lives in test_merged_conv_general.py;
* ``solve_knapsack`` returns ``None`` on forced-infeasible instances.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.dp import (brute_force, solve_dp, solve_dp_reference,
                           solve_knapsack)
from repro.core.segments import pareto_prune_options, subset_selection
from repro.core.tables import Tables, pareto_prune
from repro import kernels
from repro.kernels.merged_conv import choose_tiles, merged_conv


def make_instance(rng, L, max_k_opts=3, max_lat=10):
    table = {}
    for i in range(L):
        for j in range(i + 1, L + 1):
            if j - i > 1 and rng.random() < 0.3:
                continue
            opts = {}
            for k in rng.choice(range(1, 12),
                                size=rng.integers(1, max_k_opts + 1),
                                replace=False):
                opts[int(k)] = (float(rng.random()),
                                float(rng.integers(1, max_lat + 1)), ())
            table[(i, j)] = opts
    return table


# ---------------------------------------------------------------------------
# vectorized DP == scalar reference == brute force
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("seed", range(40))
def test_solve_dp_bitidentical_to_reference(seed):
    rng = np.random.default_rng(seed)
    L = int(rng.integers(2, 7))
    budget = int(rng.integers(3, 41))
    table = make_instance(rng, L)
    fn = lambda i, j: table.get((i, j), {})
    fast = solve_dp(L, fn, float(budget), budget)
    slow = solve_dp_reference(L, fn, float(budget), budget)
    if slow is None:
        assert fast is None
        return
    assert fast is not None
    # bit-identical, not approximately equal
    assert fast.objective == slow.objective
    assert fast.latency == slow.latency
    assert fast.plan == slow.plan
    assert np.array_equal(fast.table_M, slow.table_M)


@pytest.mark.parametrize("seed", range(25))
def test_solve_dp_matches_brute_force(seed):
    rng = np.random.default_rng(seed + 10_000)
    L = int(rng.integers(2, 6))
    budget = int(rng.integers(3, 41))
    table = make_instance(rng, L)
    fn = lambda i, j: table.get((i, j), {})
    dp = solve_dp(L, fn, float(budget), budget)
    bf = brute_force(L, fn, float(budget), budget)
    if bf is None:
        assert dp is None
        return
    assert dp is not None
    assert dp.objective == pytest.approx(bf[0], rel=1e-12)


def test_solve_dp_fractional_latencies_match_reference():
    rng = np.random.default_rng(7)
    for _ in range(10):
        L = int(rng.integers(2, 6))
        P = int(rng.integers(5, 60))
        T0 = float(rng.uniform(2.0, 20.0))
        table = {}
        for i in range(L):
            for j in range(i + 1, L + 1):
                table[(i, j)] = {int(k): (float(rng.random()),
                                          float(rng.uniform(0.05, 6.0)), ())
                                 for k in range(1, 4)}
        fn = lambda i, j: table.get((i, j), {})
        fast = solve_dp(L, fn, T0, P)
        slow = solve_dp_reference(L, fn, T0, P)
        assert (fast is None) == (slow is None)
        if fast is not None:
            assert fast.objective == slow.objective
            assert fast.plan == slow.plan


# ---------------------------------------------------------------------------
# Pareto pruning preserves the optimum
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("seed", range(25))
def test_pareto_pruned_tables_same_objective(seed):
    rng = np.random.default_rng(seed + 20_000)
    L = int(rng.integers(2, 7))
    budget = int(rng.integers(3, 41))
    table = make_instance(rng, L, max_k_opts=5)
    pruned, dropped = pareto_prune(table)
    assert dropped >= 0
    assert sum(map(len, pruned.values())) + dropped == \
        sum(map(len, table.values()))
    full = solve_dp(L, lambda i, j: table.get((i, j), {}),
                    float(budget), budget)
    slim = solve_dp(L, lambda i, j: pruned.get((i, j), {}),
                    float(budget), budget)
    assert (full is None) == (slim is None)
    if full is not None:
        assert slim.objective == full.objective


def test_pareto_prune_drops_only_dominated():
    opts = {3: (0.9, 5.0, ()),     # dominates k=5
            5: (0.5, 7.0, ()),     # dominated: lower I, higher T
            7: (0.95, 9.0, ()),    # kept: best I
            9: (0.95, 9.5, ())}    # dominated by k=7 (equal I, higher T)
    out = pareto_prune_options(opts)
    assert set(out) == {3, 7}
    assert out[3] == opts[3] and out[7] == opts[7]


def test_tables_fn_roundtrip_with_pruning():
    entries = {(0, 1): {1: (1.0, 1.0, (1,)), 2: (0.5, 2.0, (1,))}}
    pruned, dropped = pareto_prune(entries)
    t = Tables(entries=pruned, num_pruned=dropped)
    assert dropped == 1
    assert t.num_entries == 1
    assert t.fn()(0, 1) == {1: (1.0, 1.0, (1,))}
    assert t.fn()(5, 6) == {}


# ---------------------------------------------------------------------------
# vectorized subset_selection (flat weight-axis arrays)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("seed", range(20))
def test_subset_selection_exact(seed):
    import itertools
    rng = np.random.default_rng(seed + 30_000)
    n = int(rng.integers(0, 8))
    items = [(i, int(rng.integers(0, 5)), float(rng.random()))
             for i in range(n)]
    forced = [i for i in range(n) if rng.random() < 0.25]
    cap = int(rng.integers(1, 10)) if rng.random() < 0.5 else None
    got = subset_selection(items, forced=forced, cap=cap)
    best = {}
    for r in range(n + 1):
        for sub in itertools.combinations(range(n), r):
            if not set(forced) <= set(sub):
                continue
            w = sum(items[i][1] for i in sub)
            v = sum(items[i][2] for i in sub)
            key = min(w, cap) if cap is not None else w
            if key not in best or v > best[key][0]:
                best[key] = (v, sub)
    assert set(got) == set(best)
    for w, (v, ids) in got.items():
        assert v == pytest.approx(best[w][0], rel=1e-12)
        ww = sum(items[i][1] for i in ids)
        assert (min(ww, cap) if cap is not None else ww) == w
        assert sum(items[i][2] for i in ids) == pytest.approx(v, rel=1e-12)
        assert set(forced) <= set(ids)


# ---------------------------------------------------------------------------
# knapsack: forced-infeasible returns None (regression)
# ---------------------------------------------------------------------------

def test_knapsack_forced_infeasible_returns_none():
    # single forced layer beyond the whole budget
    assert solve_knapsack(1, {1: 1.0}, {1: 100.0}, 10.0, 10,
                          forced=(1,)) is None
    # forced pair individually feasible, jointly infeasible
    assert solve_knapsack(2, {1: 1.0, 2: 1.0}, {1: 6.0, 2: 6.0}, 10.0, 10,
                          forced=(1, 2)) is None
    # forced infeasible even though a cheap optional layer exists
    assert solve_knapsack(2, {1: 5.0, 2: 1.0}, {1: 1.0, 2: 100.0}, 10.0, 10,
                          forced=(2,)) is None


def test_knapsack_feasible_forced_is_kept():
    sol = solve_knapsack(3, {1: 0.1, 2: 5.0, 3: 0.2},
                         {1: 4.0, 2: 4.0, 3: 4.0}, 8.0, 8, forced=(1,))
    assert sol is not None
    C, obj, lat = sol
    assert 1 in C
    assert obj == pytest.approx(5.1)
    assert lat <= 8.0


# ---------------------------------------------------------------------------
# tiled merged conv vs oracle — halo edge cases, fused epilogue
# ---------------------------------------------------------------------------

CONV_CASES = [
    # n, h, w, cin, cout, kh, kw, tile_ho, activation, bias
    (1, 13, 11, 5, 7, 3, 5, 4, "relu", True),      # odd dims, ragged last tile
    (2, 9, 9, 3, 6, 7, 7, 2, "relu6", True),       # halo taller than the tile
    (1, 8, 8, 4, 4, 1, 1, 3, "silu", False),       # 1x1 kernel, no bias
    (3, 10, 17, 2, 3, 5, 2, 1, None, True),        # tile_ho=1
    (1, 6, 6, 2, 2, 6, 6, None, "relu", True),     # single output row
    (1, 31, 29, 3, 5, 3, 3, 7, "relu", True),      # non-multiple-of-8 tile
    (1, 6, 41, 3, 4, 3, 3, 2, "relu", True),       # wide image, odd W
]


@pytest.mark.parametrize("n,h,w,cin,cout,kh,kw,tile_ho,act,bias", CONV_CASES)
def test_tiled_merged_conv_matches_oracle(n, h, w, cin, cout, kh, kw,
                                          tile_ho, act, bias):
    rng = np.random.default_rng(h * 31 + w * 7 + kh)
    x = jnp.asarray(rng.standard_normal((n, h, w, cin)), jnp.float32)
    wt = jnp.asarray(rng.standard_normal((kh, kw, cin, cout)) * 0.1,
                     jnp.float32)
    b = jnp.asarray(rng.standard_normal(cout), jnp.float32) if bias else None
    y = kernels.merged_conv_op(x, wt, b, activation=act, tile_ho=tile_ho,
                           interpret=True)
    yr = kernels.apply_activation(kernels.merged_conv_ref(x, wt, b), act)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr),
                               rtol=2e-5, atol=2e-5)


def test_tiled_equals_untiled_kernel():
    """Tiling is a pure scheduling change: same floats per output element."""
    rng = np.random.default_rng(3)
    x = jnp.asarray(rng.standard_normal((2, 16, 12, 8)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((3, 3, 8, 8)) * 0.1, jnp.float32)
    b = jnp.asarray(rng.standard_normal(8), jnp.float32)
    whole = merged_conv(x, w, b, bcout=8, tile_ho=14, activation="relu",
                        interpret=True)
    tiled = merged_conv(x, w, b, bcout=8, tile_ho=4, activation="relu",
                        interpret=True)
    np.testing.assert_array_equal(np.asarray(whole), np.asarray(tiled))


def test_merged_conv_bf16_tiled():
    rng = np.random.default_rng(11)
    x = jnp.asarray(rng.standard_normal((1, 14, 14, 8)), jnp.bfloat16)
    w = jnp.asarray(rng.standard_normal((5, 5, 8, 16)) * 0.1, jnp.bfloat16)
    y = merged_conv(x, w, bcout=16, tile_ho=3, interpret=True)
    yr = kernels.merged_conv_ref(x, w)
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(yr, np.float32),
                               rtol=2e-2, atol=2e-2)


def test_choose_tiles_bounds_vmem():
    # big image: the planner must tile rows (halo'd block within budget)
    tile, two = choose_tiles(224, 224, 64, 7, 7, 1, 4)
    assert 1 <= tile < 224 - 7 + 1 and two == 224 - 7 + 1
    # small image: degenerates to a single full-height tile
    assert choose_tiles(12, 12, 16, 3, 3, 1, 4) == (10, 10)


def test_merged_conv_op_channel_padding_with_fusion():
    """Cout not a multiple of the channel tile + fused bias/activation."""
    rng = np.random.default_rng(5)
    x = jnp.asarray(rng.standard_normal((1, 10, 10, 3)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((3, 3, 3, 130)) * 0.1, jnp.float32)
    b = jnp.asarray(rng.standard_normal(130), jnp.float32)
    y = kernels.merged_conv_op(x, w, b, activation="relu", interpret=True)
    yr = kernels.apply_activation(kernels.merged_conv_ref(x, w, b), "relu")
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr),
                               rtol=2e-5, atol=2e-5)
