"""Substrate tests: optimizer, data pipeline, checkpointing, compression."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp import given, settings, st

from repro.checkpoint import ckpt as C
from repro.data.pipeline import GlobalBatcher, SyntheticTokens, prefetch
from repro.optim.adamw import (AdamWConfig, adamw_update, cosine_lr,
                               init_opt_state)
from repro.optim.compress import ErrorFeedback, dequantize_int8, quantize_int8


# -- optimizer ----------------------------------------------------------------

def test_adamw_reduces_quadratic():
    cfg = AdamWConfig(lr=0.1, warmup_steps=0, total_steps=100,
                      weight_decay=0.0, grad_clip=100.0)
    params = {"w": jnp.array([3.0, -2.0])}
    state = init_opt_state(params)
    for _ in range(60):
        g = {"w": 2 * params["w"]}
        params, state, _ = adamw_update(cfg, g, state, params)
    assert float(jnp.abs(params["w"]).max()) < 0.2


def test_cosine_schedule_shape():
    cfg = AdamWConfig(lr=1.0, warmup_steps=10, total_steps=100,
                      min_lr_ratio=0.1)
    assert float(cosine_lr(cfg, 0)) == pytest.approx(0.0)
    assert float(cosine_lr(cfg, 10)) == pytest.approx(1.0)
    assert float(cosine_lr(cfg, 100)) == pytest.approx(0.1, abs=1e-6)
    assert float(cosine_lr(cfg, 55)) < 1.0


def test_grad_clip_bounds_update():
    from repro.optim.adamw import clip_by_global_norm
    g = {"a": jnp.full((4,), 100.0)}
    clipped, norm = clip_by_global_norm(g, 1.0)
    assert float(norm) == pytest.approx(200.0)
    total = jnp.sqrt(sum(jnp.sum(x ** 2) for x in jax.tree.leaves(clipped)))
    assert float(total) == pytest.approx(1.0, rel=1e-5)


# -- int8 compression ----------------------------------------------------------

@given(seed=st.integers(0, 100), scale=st.floats(1e-3, 1e3))
@settings(max_examples=20, deadline=None)
def test_int8_roundtrip_bound(seed, scale):
    x = jax.random.normal(jax.random.PRNGKey(seed), (64,)) * scale
    q, s = quantize_int8(x)
    err = jnp.abs(dequantize_int8(q, s) - x)
    assert float(err.max()) <= float(s) / 2 + 1e-6   # half-ulp bound


def test_error_feedback_telescopes():
    """Σ compressed ≈ Σ true gradients (errors telescope, not accumulate)."""
    key = jax.random.PRNGKey(0)
    grads = [{"w": jax.random.normal(jax.random.PRNGKey(i), (32,))}
             for i in range(50)]
    e = ErrorFeedback.init(grads[0])
    total_c = jnp.zeros(32)
    total_t = jnp.zeros(32)
    for g in grads:
        gq, e = ErrorFeedback.apply(g, e)
        total_c += gq["w"]
        total_t += g["w"]
    resid = float(jnp.abs(total_c - total_t).max())
    # the residual is exactly the final carried error — bounded by one ulp
    assert resid <= float(jnp.abs(e["w"]).max()) + 1e-5


# -- data pipeline ---------------------------------------------------------------

def test_data_determinism_and_structure():
    src = SyntheticTokens(vocab_size=64, batch=4, seq=32, seed=7)
    b1, b2 = src.batch_at(5), src.batch_at(5)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    assert not np.array_equal(src.batch_at(6)["tokens"], b1["tokens"])
    # learnable: targets are a deterministic function of (prev, branch):
    # entropy of the next token given context is << log(vocab)
    assert b1["targets"].max() < 64
    np.testing.assert_array_equal(b1["tokens"][:, 1:], b1["targets"][:, :-1])


def test_prefetch_yields_in_order():
    src = SyntheticTokens(vocab_size=16, batch=2, seq=8)
    it = prefetch(lambda i: src.batch_at(i), start=3, depth=2)
    idx, b = next(it)
    assert idx == 3
    idx2, _ = next(it)
    assert idx2 == 4


# -- checkpointing ------------------------------------------------------------------

def test_checkpoint_roundtrip(tmp_path):
    tree = {"a": jnp.arange(6.0).reshape(2, 3),
            "nest": {"b": jnp.ones((4,), jnp.int32)},
            "lst": [jnp.zeros(2), jnp.full((3,), 7.0)]}
    C.save(str(tmp_path), 10, tree)
    assert C.latest_step(str(tmp_path)) == 10
    out = C.restore(str(tmp_path), 10, tree)
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(out)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_gc_and_atomicity(tmp_path):
    tree = {"w": jnp.zeros(4)}
    for s in (1, 2, 3, 4, 5):
        C.save(str(tmp_path), s, tree, keep=2)
    steps = sorted(int(n.split("_")[1]) for n in os.listdir(tmp_path)
                   if n.startswith("step_") and not n.endswith(".tmp"))
    assert steps == [4, 5]
    # a stale .tmp dir (simulated crash) is ignored and cleaned
    os.makedirs(tmp_path / "step_99.tmp", exist_ok=True)
    assert C.latest_step(str(tmp_path)) == 5
    C.save(str(tmp_path), 6, tree, keep=2)
    assert not (tmp_path / "step_99.tmp").exists()


def test_async_checkpointer(tmp_path):
    saver = C.AsyncCheckpointer(str(tmp_path))
    saver.save(3, {"w": jnp.arange(4.0)})
    saver.wait()
    out = C.restore(str(tmp_path), 3, {"w": jnp.zeros(4)})
    np.testing.assert_array_equal(np.asarray(out["w"]), np.arange(4.0))
