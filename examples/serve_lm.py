"""Batched serving example: prefill + KV-cache decode on a small LM.

Demonstrates the serve path the decode_32k / long_500k dry-run cells lower:
build a cache from a prompt batch (teacher-forced prefill), then run the
jit'd one-token serve_step in a decode loop with greedy sampling.

Run:  PYTHONPATH=src python examples/serve_lm.py [--tokens 32] [--batch 4]
"""
import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.models import transformer as T
from repro.train.step import make_serve_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--tokens", type=int, default=32)
    ap.add_argument("--arch", default="smollm-135m")
    args = ap.parse_args()

    cfg = dataclasses.replace(
        get_config(args.arch).reduced(), num_layers=4, d_model=128,
        num_heads=4, num_kv_heads=2, head_dim=32, d_ff=256, vocab_size=512)
    params, _ = T.init_model(cfg, jax.random.PRNGKey(0))
    B, P = args.batch, args.prompt_len
    total = P + args.tokens
    prompt = jax.random.randint(jax.random.PRNGKey(1), (B, P), 0,
                                cfg.vocab_size)

    # prefill: feed the prompt token by token through the jit'd serve step
    # (production prefill is the prefill_32k dry-run cell; for the example a
    # decode-loop warm-up keeps one compiled program)
    serve = jax.jit(make_serve_step(cfg))
    cache = T.init_cache(cfg, B, total)
    logits = None
    t0 = time.perf_counter()
    for t in range(P):
        logits, cache = serve(params, cache, {"tokens": prompt[:, t:t + 1]})
    prefill_s = time.perf_counter() - t0

    # greedy decode
    tok = jnp.argmax(logits[:, -1], axis=-1)[:, None]
    out = [tok]
    t0 = time.perf_counter()
    for _ in range(args.tokens - 1):
        logits, cache = serve(params, cache, {"tokens": tok})
        tok = jnp.argmax(logits[:, -1], axis=-1)[:, None]
        out.append(tok)
    jax.block_until_ready(tok)
    decode_s = time.perf_counter() - t0
    seqs = jnp.concatenate(out, axis=1)
    tps = (args.tokens - 1) * B / decode_s
    print(f"[serve_lm] batch={B} prompt={P} generated={args.tokens}")
    print(f"[serve_lm] prefill {prefill_s*1e3:.1f} ms, decode "
          f"{decode_s*1e3:.1f} ms ({tps:.0f} tok/s on this host)")
    print(f"[serve_lm] sample continuation ids: {seqs[0, :12].tolist()}")


if __name__ == "__main__":
    main()
