"""DP-planner + merged-conv tiling benchmark — machine-readable output.

Measures the vectorized Algorithm-1 solver against the scalar reference at
production-depth instances (the fine budget grids of Kim et al. 2023's
two-stage DP, P up to 8192), plus the effect of Pareto-dominance pruning
and a merged-conv stride × (tile_ho, tile_wo) sweep with DMA-halo traffic
accounting.  Writes ``results/BENCH_dp.json`` so the perf trajectory is
trackable across PRs.

  PYTHONPATH=src python -m benchmarks.bench_dp [--full] [--out PATH]

``--full`` also times the scalar reference at the largest instance (slow:
the quadruple-nested Python loop is exactly what this PR deletes from the
hot path).
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(__file__), os.pardir, "src"))

from repro.core.dp import solve_dp, solve_dp_reference  # noqa: E402
from repro.core.segments import pareto_prune_options    # noqa: E402


def make_instance(rng, L, max_span=12, n_k=7, max_lat=30):
    table = {}
    for i in range(L):
        for j in range(i + 1, min(i + max_span, L) + 1):
            table[(i, j)] = {k: (float(rng.random()),
                                 float(rng.integers(1, max_lat + 1)), ())
                             for k in range(1, n_k + 1)}
    return table


def timeit(fn, repeats=3):
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        out = fn()
        best = min(best, time.perf_counter() - t0)
    return best, out


def bench_solver(L, P, *, scalar: bool, rng):
    table = make_instance(rng, L)
    fn = lambda i, j: table.get((i, j), {})
    t_vec, res_vec = timeit(lambda: solve_dp(L, fn, float(P), P))
    row = {
        "L": L, "P": P,
        "entries": sum(len(v) for v in table.values()),
        "vectorized_ms": t_vec * 1e3,
        "objective": res_vec.objective,
    }
    if scalar:
        t_ref, res_ref = timeit(lambda: solve_dp_reference(L, fn, float(P), P),
                                repeats=1)
        assert res_ref.objective == res_vec.objective
        assert res_ref.plan == res_vec.plan
        row.update(scalar_ms=t_ref * 1e3, speedup=t_ref / t_vec,
                   plans_identical=True)
    pruned = {sp: pareto_prune_options(o) for sp, o in table.items()}
    pfn = lambda i, j: pruned.get((i, j), {})
    t_pru, res_pru = timeit(lambda: solve_dp(L, pfn, float(P), P))
    assert res_pru.objective == res_vec.objective
    row.update(pruned_entries=sum(len(v) for v in pruned.values()),
               pruned_vectorized_ms=t_pru * 1e3,
               pruned_objective_identical=True)
    return row


def conv_tile_sweep(rng, *, ks=(5,), strides=(1, 2),
                    tiles=((4, None), (8, None), (16, 16), (32, 8),
                           (None, None)),
                    hw=56, cin=32, cout=32):
    """The canonical merged-conv (stride, k) × (tile_ho, tile_wo) sweep.

    One dict row per point: jnp-oracle wall time (``oracle_us``, timed once
    per (stride, k) — tiling cannot affect it), interpret-mode max|Δ|
    certifying the tiling against the oracle, and the traffic model's
    DMA-halo bytes saved over the deleted host-side gather.  Shared by
    this bench and ``benchmarks/run.py``'s ``conv_sweep`` so the two never
    drift.
    """
    import jax
    import jax.numpy as jnp
    from repro import kernels
    from repro.kernels.merged_conv import choose_tiles, input_traffic_model

    def timed_us(fn, n=10):
        jax.block_until_ready(fn())
        t0 = time.perf_counter()
        for _ in range(n):
            jax.block_until_ready(fn())
        return (time.perf_counter() - t0) / n * 1e6

    rows = []
    for stride in strides:
        for k in ks:
            x = jnp.asarray(rng.standard_normal((1, hw, hw, cin)),
                            jnp.float32)
            wt = jnp.asarray(rng.standard_normal((k, k, cin, cout)) * 0.1,
                             jnp.float32)
            b = jnp.asarray(rng.standard_normal(cout), jnp.float32)
            oracle = kernels.apply_activation(
                kernels.merged_conv_ref(x, wt, b, stride=stride), "relu")
            f = jax.jit(lambda x=x, wt=wt, b=b, s=stride: kernels.merged_conv_ref(
                x, wt, b, stride=s))
            oracle_us = timed_us(f)
            a_ho, a_wo = choose_tiles(hw, hw, cin, k, k, stride, 4,
                                      bcout=cout)
            for tile_ho, tile_wo in tiles:
                t0 = time.perf_counter()
                y = kernels.merged_conv_op(x, wt, b, stride=stride,
                                       activation="relu", tile_ho=tile_ho,
                                       tile_wo=tile_wo, interpret=True)
                dt = time.perf_counter() - t0
                traffic = input_traffic_model(hw, hw, cin, k, k, stride, 4,
                                              tile_ho=tile_ho or a_ho,
                                              tile_wo=tile_wo or a_wo)
                rows.append({
                    "shape": f"n1_h{hw}w{hw}_cin{cin}cout{cout}_k{k}",
                    "stride": stride,
                    "k": k,
                    "tile_ho": tile_ho or a_ho,
                    "tile_wo": tile_wo or a_wo,
                    "auto": tile_ho is None,
                    "oracle_us": oracle_us,
                    "interpret_s": dt,
                    "halo_bytes_saved": traffic["saved_bytes"],
                    "dma_bytes": traffic["dma_bytes"],
                    "maxdiff_vs_oracle": float(jnp.abs(y - oracle).max()),
                })
    return rows


def depthwise_tile_sweep(rng, *, ks=(3, 5), strides=(1, 2),
                         tiles=((8, None), (None, None)), hw=56, c=32):
    """The canonical depthwise (stride, k) × (tile_ho, tile_wo) sweep.

    MobileNetV2's merged segments are depthwise; each row times the jitted
    ``lax`` grouped conv this host would otherwise run (``lax_us`` — the
    deleted fallback path), certifies the Pallas depthwise kernel against
    the oracle in interpret mode, reports the traffic model's DMA-halo
    bytes reclaimed, and records the v5e roofline's predicted speedup of
    the DMA-halo model over the lax-gather traffic (compiled Pallas timing
    needs a real TPU; the analytic ratio is what the DP's table sees).
    Shared by this bench and ``benchmarks/run.py`` so the two never drift.
    """
    import jax
    import jax.numpy as jnp
    from repro import kernels
    from repro.core.latency import AnalyticTPUOracle, CostBreakdown
    from repro.kernels.depthwise_conv import choose_tiles_grouped
    from repro.kernels.merged_conv import input_traffic_model
    from repro.kernels.ops import channel_tile

    def timed_us(fn, n=10):
        jax.block_until_ready(fn())
        t0 = time.perf_counter()
        for _ in range(n):
            jax.block_until_ready(fn())
        return (time.perf_counter() - t0) / n * 1e6

    oracle_v5e = AnalyticTPUOracle()
    rows = []
    for stride in strides:
        for k in ks:
            x = jnp.asarray(rng.standard_normal((1, hw, hw, c)), jnp.float32)
            wt = jnp.asarray(rng.standard_normal((k, k, 1, c)) * 0.1,
                             jnp.float32)
            b = jnp.asarray(rng.standard_normal(c), jnp.float32)
            oracle = kernels.apply_activation(
                kernels.depthwise_conv_ref(x, wt, b, stride=stride), "relu6")
            f = jax.jit(lambda x=x, wt=wt, b=b, s=stride:
                        kernels.depthwise_conv_ref(x, wt, b, stride=s))
            lax_us = timed_us(f)
            bg = channel_tile(c, None)
            a_ho, a_wo = choose_tiles_grouped(hw, hw, 1, 1, k, k, stride, 4,
                                              bgroups=bg)
            ho = (hw - k) // stride + 1
            wo = (hw - k) // stride + 1
            flops = 2.0 * ho * wo * c * k * k
            fixed = (k * k * c + ho * wo * c) * 4.0
            for tile_ho, tile_wo in tiles:
                t0 = time.perf_counter()
                y = kernels.depthwise_conv_op(
                    x, wt, b, stride=stride, activation="relu6",
                    tile_ho=tile_ho, tile_wo=tile_wo, interpret=True)
                dt = time.perf_counter() - t0
                traffic = input_traffic_model(hw, hw, c, k, k, stride, 4,
                                              tile_ho=tile_ho or a_ho,
                                              tile_wo=tile_wo or a_wo,
                                              groups=c)
                lat_gather = oracle_v5e.segment_latency(CostBreakdown(
                    flops, fixed + traffic["gather_bytes"]))
                lat_dma = oracle_v5e.segment_latency(CostBreakdown(
                    flops, fixed + traffic["dma_bytes"]
                    + traffic["relayout_bytes"]))
                rows.append({
                    "shape": f"n1_h{hw}w{hw}_c{c}_dw_k{k}",
                    "stride": stride,
                    "k": k,
                    "tile_ho": tile_ho or a_ho,
                    "tile_wo": tile_wo or a_wo,
                    "auto": tile_ho is None,
                    "lax_us": lax_us,
                    "interpret_s": dt,
                    "halo_bytes_saved": traffic["halo_bytes_saved"],
                    "dma_bytes": traffic["dma_bytes"],
                    "relayout_bytes": traffic["relayout_bytes"],
                    "predicted_speedup_v5e": lat_gather / lat_dma,
                    "maxdiff_vs_oracle": float(jnp.abs(y - oracle).max()),
                })
    return rows


def quant_kernel_sweep(rng, *, modes=("int8", "w8a8"), ks=(3, 5),
                       strides=(1, 2), hw=28, cin=32, cout=64):
    """Quantized merged-kernel sweep: certification + traffic accounting.

    One row per (kernel, stride, k, mode): interpret-mode max|Δ| against
    the fp32 oracle *asserted* within the rigorous
    :func:`repro.kernels.quant.error_budget`, HBM weight bytes saved by
    the narrow storage (scales included — the honest number), and the
    v5e roofline's predicted segment speedup from the narrower weight
    traffic (``w_bytes``/``act_bytes`` through the same
    ``conv2d_cost``/``matmul_cost`` the DP's sibling derivation uses, so
    the bench reports exactly what the planner sees).
    """
    import jax.numpy as jnp
    from repro import kernels
    from repro.core.latency import (AnalyticTPUOracle, conv2d_cost,
                                    matmul_cost)
    from repro.kernels import quant

    oracle = AnalyticTPUOracle()
    rows = []
    for stride in strides:
        for k in ks:
            for mode in modes:
                x = jnp.asarray(rng.standard_normal((1, hw, hw, cin)),
                                jnp.float32)
                wt = jnp.asarray(
                    rng.standard_normal((k, k, cin, cout)) * 0.1,
                    jnp.float32)
                wq, ws = quant.quantize_weight(wt, mode, axis=3)
                lo, hi = (k - 1) // 2, k - 1 - (k - 1) // 2
                xp = jnp.pad(x, ((0, 0), (lo, hi), (lo, hi), (0, 0)))
                aq = mode if mode == "w8a8" else "none"
                t0 = time.perf_counter()
                y = kernels.merged_conv_op(xp, wq, None, stride=stride,
                                           w_scale=ws, act_quant=aq,
                                           interpret=True)
                dt = time.perf_counter() - t0
                yf = kernels.merged_conv_ref(xp, wt, None, stride=stride)
                maxdiff = float(jnp.abs(y - yf).max())
                budget = quant.error_budget(
                    mode, fan_in=k * k * cin,
                    x_absmax=float(jnp.abs(x).max()),
                    w_absmax=float(jnp.abs(wt).max()))
                assert maxdiff <= budget, (mode, k, stride, maxdiff, budget)
                wbytes_fp = wt.size * 4
                wbytes_q = wq.size + ws.size * 4
                cost_fp = conv2d_cost(hw, hw, cin, cout, k, stride,
                                      dtype_bytes=4)
                cost_q = conv2d_cost(hw, hw, cin, cout, k, stride,
                                     dtype_bytes=4, w_bytes=1,
                                     act_bytes=1 if aq == "w8a8" else None)
                rows.append({
                    "kernel": "merged_conv",
                    "shape": f"h{hw}w{hw}_cin{cin}cout{cout}_k{k}",
                    "stride": stride, "k": k, "mode": mode,
                    "interpret_s": dt,
                    "maxdiff_vs_fp32": maxdiff,
                    "error_budget": budget,
                    "within_budget": True,
                    "weight_bytes_fp32": wbytes_fp,
                    "weight_bytes_quant": wbytes_q,
                    "weight_bytes_saved": wbytes_fp - wbytes_q,
                    "predicted_speedup_v5e":
                        oracle.segment_latency(cost_fp)
                        / oracle.segment_latency(cost_q),
                })
    # merged rank-FFN (the transformer units the DP quantizes)
    d, r, tok = 256, 64, 32
    for mode in modes:
        x = jnp.asarray(rng.standard_normal((1, tok, d)), jnp.float32)
        u = jnp.asarray(rng.standard_normal((d, r)) * 0.1, jnp.float32)
        v = jnp.asarray(rng.standard_normal((r, d)) * 0.1, jnp.float32)
        uq, us = quant.quantize_weight(u, mode, axis=1)
        vq, vs = quant.quantize_weight(v, mode, axis=1)
        aq = mode if mode == "w8a8" else "none"
        t0 = time.perf_counter()
        y = kernels.merged_ffn_op(x, uq, vq, u_scale=us, v_scale=vs,
                                  act_quant=aq, interpret=True)
        dt = time.perf_counter() - t0
        yq = kernels.merged_ffn_qref(x, uq, vq, us, vs, act_quant=aq)
        maxdiff = float(jnp.abs(y - yq).max())
        wbytes_fp = (u.size + v.size) * 4
        wbytes_q = uq.size + vq.size + (us.size + vs.size) * 4
        ab = 1 if aq == "w8a8" else None
        cost_fp = (matmul_cost(tok, d, r, dtype_bytes=4)
                   + matmul_cost(tok, r, d, dtype_bytes=4))
        cost_q = (matmul_cost(tok, d, r, dtype_bytes=4, w_bytes=1,
                              act_bytes=ab)
                  + matmul_cost(tok, r, d, dtype_bytes=4, w_bytes=1,
                                act_bytes=ab))
        rows.append({
            "kernel": "merged_ffn",
            "shape": f"tok{tok}_d{d}_r{r}",
            "mode": mode,
            "interpret_s": dt,
            "maxdiff_vs_qref": maxdiff,
            "weight_bytes_fp32": wbytes_fp,
            "weight_bytes_quant": wbytes_q,
            "weight_bytes_saved": wbytes_fp - wbytes_q,
            "predicted_speedup_v5e":
                oracle.segment_latency(cost_fp)
                / oracle.segment_latency(cost_q),
        })
    return rows


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="also time the scalar reference at (L=128, P=8192)")
    ap.add_argument("--quantize", action="store_true",
                    help="add the quantized merged-kernel sweep "
                         "(certification vs fp32 budgets + weight-traffic "
                         "accounting; also merged into BENCH_kernels.json)")
    ap.add_argument("--out", default="results/BENCH_dp.json")
    args = ap.parse_args(argv)
    rng = np.random.default_rng(0)

    solver = [
        bench_solver(64, 2048, scalar=True, rng=rng),
        bench_solver(128, 8192, scalar=args.full, rng=rng),
    ]
    conv = conv_tile_sweep(rng)
    dw = depthwise_tile_sweep(rng)
    report = {"solver": solver, "merged_conv_tiles": conv,
              "depthwise_conv_tiles": dw}
    if args.quantize:
        report["quantized_kernels"] = quant_kernel_sweep(rng)

    from repro.launch.distributed import publish_json

    if publish_json(args.out, report) is not None:
        print(f"# wrote {args.out}", file=sys.stderr)
    if args.quantize:
        # merge the quantized rows into the kernel-bench ledger too, so
        # one file tracks every kernel's certification + perf trajectory
        kpath = "results/BENCH_kernels.json"
        try:
            with open(kpath) as f:
                ledger = json.load(f)
        except (OSError, json.JSONDecodeError):
            ledger = {}
        for row in report["quantized_kernels"]:
            key = (f"quant_sweep,{row['kernel']}_{row['mode']}"
                   + (f"_s{row['stride']}k{row['k']}"
                      if "stride" in row else ""))
            ledger[key] = row
        if publish_json(kpath, ledger) is not None:
            print(f"# merged quantized rows into {kpath}", file=sys.stderr)
    print(json.dumps(report, indent=2))


if __name__ == "__main__":
    main()
