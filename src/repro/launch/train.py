"""Production launcher: ``--arch <id> --shape <shape> --mode train|serve``.

On a real TPU pod this is the per-host entry point (jax.distributed
initialization → production mesh → sharded state → fault-tolerant loop).
On this CPU host it runs reduced configs end-to-end; the full configs go
through dryrun.py (lower+compile only).

Examples:
  PYTHONPATH=src python -m repro.launch.train --arch smollm-135m \
      --reduced --steps 50
  PYTHONPATH=src python -m repro.launch.train --arch qwen2-7b --reduced \
      --mode serve --tokens 16
"""
from __future__ import annotations

import argparse
import dataclasses
import os
import shutil

import jax
import jax.numpy as jnp

from repro.configs import SHAPES, get_config
from repro.data.pipeline import GlobalBatcher, SyntheticTokens
from repro.launch.mesh import make_host_mesh
from repro.models import transformer as T
from repro.optim.adamw import AdamWConfig
from repro.sharding.rules import make_rules, use_rules
from repro.train.loop import LoopConfig, train_loop
from repro.train.step import make_serve_step


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", default="train_4k", choices=list(SHAPES))
    ap.add_argument("--mode", default="train", choices=["train", "serve"])
    ap.add_argument("--reduced", action="store_true",
                    help="run the reduced config (CPU-sized)")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--tokens", type=int, default=16)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_launch_ckpt")
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--distributed", action="store_true",
                    help="initialize jax.distributed (multi-host pods)")
    args = ap.parse_args(argv)

    if args.distributed:                       # pragma: no cover
        jax.distributed.initialize()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    if cfg.frontend != "tokens" and args.mode == "train":
        raise SystemExit(f"{args.arch} uses an embeddings frontend stub; "
                         "train it through the dry-run cells")

    mesh = make_host_mesh()
    rules = make_rules(mesh, fsdp=False)
    params, _ = T.init_model(cfg, jax.random.PRNGKey(0))
    n = sum(x.size for x in jax.tree.leaves(params))
    print(f"[launch] {cfg.name} ({n/1e6:.2f}M params) on "
          f"{len(jax.devices())} device(s), mode={args.mode}")

    with use_rules(rules):
        if args.mode == "train":
            if not args.resume:
                shutil.rmtree(args.ckpt_dir, ignore_errors=True)
            data = SyntheticTokens(cfg.vocab_size, args.batch, args.seq)
            batcher = GlobalBatcher(data, mesh=mesh)
            res = train_loop(
                cfg, AdamWConfig(lr=1e-3, total_steps=args.steps),
                LoopConfig(total_steps=args.steps, ckpt_every=25,
                           ckpt_dir=args.ckpt_dir, log_every=10),
                params, batcher)
            print(f"[launch] final loss {res.losses[-1]:.4f} "
                  f"restarts={res.restarts}")
        else:
            serve = jax.jit(make_serve_step(cfg))
            cache = T.init_cache(cfg, args.batch, args.tokens + 1)
            tok = jnp.zeros((args.batch, 1), jnp.int32)
            for _ in range(args.tokens):
                logits, cache = serve(params, cache, {"tokens": tok})
                tok = jnp.argmax(logits[:, -1], axis=-1)[:, None]
            print(f"[launch] decoded {args.tokens} tokens/seq, sample: "
                  f"{tok[:4, 0].tolist()}")


if __name__ == "__main__":
    main()
