"""musicgen-large [arXiv:2306.05284; hf] — decoder-only over EnCodec tokens.

Backbone only (assignment): the EnCodec frontend is a stub — ``input_specs``
feeds precomputed frame embeddings (B, S, d_model); the LM head predicts the
2048-way codebook tokens.
"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="musicgen-large", family="audio",
    num_layers=48, d_model=2048, num_heads=32, num_kv_heads=32,
    d_ff=8192, vocab_size=2048,
    ffn_kind="gelu", temporal_pattern=("attn",),
    frontend="embeddings", rope_kind="none",
    source="arXiv:2306.05284; EnCodec-token decoder, frontend stubbed",
)
