"""RG-LRU recurrence block (RecurrentGemma / Griffin, arXiv:2402.19427).

The temporal mixing block is: linear in/out projections, a 1D depthwise
conv (width 4), and the Real-Gated Linear Recurrence Unit::

    r_t = σ(x_t W_a)                     (recurrence gate)
    i_t = σ(x_t W_x)                     (input gate)
    a_t = exp(-c · softplus(Λ) · r_t)    (per-channel decay, c = 8)
    h_t = a_t ⊙ h_{t-1} + √(1 − a_t²) ⊙ (i_t ⊙ x_t)

The recurrence is evaluated with ``jax.lax.associative_scan`` (O(log S)
depth) for train/prefill and as a single fused state update for decode.
A Pallas kernel (kernels/rglru_scan.py) provides the TPU-tiled version.

LayerMerge note: gates are input-dependent — the block is prunable, not
linearizable (DESIGN §2.3).
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

C_DECAY = 8.0


def rglru_axes():
    return {"w_in": ("embed", "ffn"), "w_out": ("ffn", "embed"),
            "conv_w": (None, "ffn"), "conv_b": ("ffn",),
            "w_a": ("ffn", "ffn_in"), "w_x": ("ffn", "ffn_in"),
            "lam": ("ffn",)}


def init_rglru(cfg, key, dtype):
    d = cfg.d_model
    dr = cfg.rnn_width or d
    ks = jax.random.split(key, 6)
    s = 1.0 / math.sqrt(d)
    p = {
        "w_in": jax.random.normal(ks[0], (d, dr), dtype) * s,
        "w_out": jax.random.normal(ks[1], (dr, d), dtype) / math.sqrt(dr),
        "conv_w": jax.random.normal(ks[2], (4, dr), dtype) * 0.1,
        "conv_b": jnp.zeros((dr,), dtype),
        "w_a": jax.random.normal(ks[3], (dr, dr), dtype) / math.sqrt(dr),
        "w_x": jax.random.normal(ks[4], (dr, dr), dtype) / math.sqrt(dr),
        # Λ init so that a spans (0.9, 0.999) as in the paper
        "lam": jnp.asarray(
            jnp.log(jnp.expm1(-jnp.log(
                jax.random.uniform(ks[5], (dr,), jnp.float32,
                                   0.9 ** C_DECAY, 0.999 ** C_DECAY)))),
            dtype),
    }
    return p, rglru_axes()


def _gates(p, u):
    r = jax.nn.sigmoid(u @ p["w_a"])
    i = jax.nn.sigmoid(u @ p["w_x"])
    log_a = -C_DECAY * jax.nn.softplus(p["lam"].astype(jnp.float32)) \
        * r.astype(jnp.float32)
    a = jnp.exp(log_a)
    gated = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12)) \
        * (i.astype(jnp.float32) * u.astype(jnp.float32))
    return a, gated


def _causal_conv1d(p, u, state=None):
    """Width-4 depthwise causal conv.  state: (B, 3, Dr) trailing inputs."""
    w, b = p["conv_w"], p["conv_b"]
    k = w.shape[0]
    if state is None:
        pad = jnp.pad(u, ((0, 0), (k - 1, 0), (0, 0)))
    else:
        pad = jnp.concatenate([state.astype(u.dtype), u], axis=1)
    out = sum(pad[:, i:i + u.shape[1]] * w[i] for i in range(k)) + b
    new_state = pad[:, -(k - 1):]
    return out, new_state


def rglru_scan(a, gated, h0=None):
    """Associative scan of h_t = a_t h_{t-1} + gated_t over axis 1."""
    if h0 is not None:
        gated = gated.at[:, 0].add(a[:, 0] * h0)

    def combine(c1, c2):
        a1, b1 = c1
        a2, b2 = c2
        return a2 * a1, a2 * b1 + b2
    _, h = jax.lax.associative_scan(combine, (a, gated), axis=1)
    return h


def rglru_block(p, x, cfg):
    """Full temporal block for train/prefill: (B, S, D) → (B, S, D)."""
    u = x @ p["w_in"]
    u, _ = _causal_conv1d(p, u)
    a, gated = _gates(p, u)
    h = rglru_scan(a, gated)
    return (h.astype(x.dtype) * jax.nn.gelu(u)) @ p["w_out"]


def rglru_decode(p, x, cfg, state):
    """One-step decode.  state: {"h": (B, Dr) f32, "conv": (B, 3, Dr)}."""
    u = x @ p["w_in"]                                   # (B, 1, Dr)
    u, conv_state = _causal_conv1d(p, u, state["conv"])
    a, gated = _gates(p, u)
    h = a[:, 0] * state["h"] + gated[:, 0]              # (B, Dr)
    y = (h[:, None].astype(x.dtype) * jax.nn.gelu(u)) @ p["w_out"]
    return y, {"h": h, "conv": conv_state}


def init_rglru_state(cfg, batch, dtype):
    dr = cfg.rnn_width or cfg.d_model
    return {"h": jnp.zeros((batch, dr), jnp.float32),
            "conv": jnp.zeros((batch, 3, dr), dtype)}


RGLRU_STATE_AXES = {"h": ("batch", "ffn"), "conv": ("batch", None, "ffn")}
