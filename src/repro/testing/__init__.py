"""``repro.testing`` — deterministic test instrumentation.

:mod:`repro.testing.faults` is the fault-injection registry consumed by
the crash-safety hooks in the production pipeline (probe engine, build
journal, table cache, serving).  It is stdlib-only and a no-op unless a
fault plan is explicitly activated, so production modules may import it
unconditionally.  (Not imported eagerly here: ``python -m
repro.testing.faults`` would otherwise re-execute the module under
runpy and split the fault-plan state across two module objects.)
"""
__all__ = ["faults"]
