"""``repro.runtime`` — plan execution as a subsystem, not a host detail.

The compression pipeline (tables → DP → replace → fine-tune → merge)
produces a *plan*; this package owns everything that happens after the
plan is frozen:

* :mod:`repro.runtime.ir` — a backend-neutral **unit IR**: typed records
  for merged-conv / depthwise-conv / low-rank-residual / attention /
  pool / upsample / sublayer units with explicit strides, activation
  epilogues, and skip wiring.  Both hosts lower plans into the same IR
  (``host.lower_plan(plan, params) → UnitGraph``), replacing the former
  per-host ``cnn.MergedUnit`` list and ``transformer_host`` tuple units.
* :mod:`repro.runtime.executor` — one shared interpreter over a
  ``UnitGraph`` that routes every unit through the public kernel entry
  points (:mod:`repro.kernels`: Pallas on TPU, jnp oracles elsewhere),
  including a KV-cache-aware decode path for serving transformers.
* :mod:`repro.runtime.artifact` — a portable **merged-model artifact**
  (``.npz``: plan JSON + unit-graph spec + merged weights) with atomic
  publish and a content fingerprint, so compression runs once and every
  consumer (serving, benchmarks, fine-tuning) loads the same certified
  object: ``CompressResult.save(path)`` / ``runtime.load(path)``.
"""
from .artifact import (ArtifactError, CompressedArtifact, fingerprint, load,
                       save)
from .executor import (execute, init_cache, decode_step, jit_apply,
                       make_serve_step, run_units)
from .ir import (AttnUnit, ConvUnit, LowRankUnit, PoolUnit, SublayerUnit,
                 UnitGraph, UpsampleUnit, bind_params, graph_params)
from .serving import serve_loop

__all__ = [
    "ArtifactError", "CompressedArtifact", "fingerprint", "load", "save",
    "execute", "init_cache", "decode_step", "jit_apply", "make_serve_step",
    "run_units",
    "AttnUnit", "ConvUnit", "LowRankUnit", "PoolUnit", "SublayerUnit",
    "UnitGraph", "UpsampleUnit", "bind_params", "graph_params",
    "serve_loop",
]
