"""``repro.testing`` — deterministic test instrumentation.

:mod:`repro.testing.faults` is the fault-injection registry consumed by
the crash-safety hooks in the production pipeline (probe engine, build
journal, table cache, serving).  It is stdlib-only and a no-op unless a
fault plan is explicitly activated, so production modules may import it
unconditionally.  (Not imported eagerly here: ``python -m
repro.testing.faults`` would otherwise re-execute the module under
runpy and split the fault-plan state across two module objects.)

:mod:`repro.testing.subproc` is THE way tests and smokes build
environments for child python processes (pinned CPU platform, forced
host device count, process identity, fault-plan env) — one stdlib-only
helper instead of a hand-rolled env dict per test file.

:mod:`repro.testing.hosts` holds deterministic host factories that can
be named across process boundaries (``"module:function"`` specs for the
distributed build workers) and shared by tests/smokes/benches.
"""
__all__ = ["faults", "hosts", "subproc"]
