"""Architecture config system — every assigned arch is an ``ArchConfig``.

``--arch <id>`` resolves through :func:`get_config`; each config file
registers itself.  ``reduced()`` returns a structurally-identical toy config
(same family, same block pattern, same frontends) for CPU smoke tests; the
full config is exercised only through the dry-run (ShapeDtypeStructs, no
allocation).
"""
from __future__ import annotations

import dataclasses
import importlib
from typing import Mapping


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                     # dense | moe | hybrid | ssm | audio | vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int | None = None     # default d_model // num_heads
    ffn_kind: str = "swiglu"        # swiglu | geglu | gelu
    qkv_bias: bool = False
    # MoE
    num_experts: int = 0
    experts_per_token: int = 0
    moe_dff: int = 0
    capacity_factor: float = 1.25
    # temporal structure: per-layer kinds, cycled/padded to num_layers
    temporal_pattern: tuple[str, ...] = ("attn",)
    local_window: int = 0           # for 'attn_local'
    rnn_width: int = 0              # for 'rglru' (0 → d_model)
    # embedding / modality frontend
    frontend: str = "tokens"        # tokens | embeddings (stub frontend)
    rope_kind: str = "rope"         # rope | mrope | none
    rope_theta: float = 10000.0
    tie_embeddings: bool = False
    norm_eps: float = 1e-6
    # runtime
    dtype: str = "bfloat16"
    remat: bool = True
    scan_layers: bool = True
    decode_flash: bool = False   # flash-decoding LSE combine (§Perf)
    source: str = ""                # provenance note

    def __post_init__(self):
        if self.head_dim is None:
            object.__setattr__(self, "head_dim",
                               self.d_model // self.num_heads)

    # -- derived -------------------------------------------------------------
    def layer_kinds(self) -> tuple[str, ...]:
        pat = self.temporal_pattern
        return tuple(pat[i % len(pat)] for i in range(self.num_layers))

    @property
    def has_ffn(self) -> bool:
        return self.d_ff > 0 or self.num_experts > 0

    @property
    def is_moe(self) -> bool:
        return self.num_experts > 0

    def param_count(self) -> int:
        """Approximate parameter count (reported in DESIGN/EXPERIMENTS)."""
        d, hd = self.d_model, self.head_dim
        n = 0
        kinds = self.layer_kinds()
        for kind in kinds:
            if kind in ("attn", "attn_local"):
                n += d * hd * (self.num_heads * 2 + self.num_kv_heads * 2)
            elif kind == "rglru":
                dr = self.rnn_width or d
                n += 2 * d * dr + 2 * dr * dr + 5 * dr
            elif kind in ("mlstm", "slstm"):
                n += 4 * d * d + d * d
            if self.is_moe:
                n += self.num_experts * 3 * d * self.moe_dff + d * self.num_experts
            elif self.d_ff > 0:
                mult = 3 if self.ffn_kind in ("swiglu", "geglu") else 2
                n += mult * d * self.d_ff
            n += 2 * d
        n += self.vocab_size * d * (1 if self.tie_embeddings else 2)
        return n

    def active_param_count(self) -> int:
        """Active params per token (MoE: top-k experts only)."""
        if not self.is_moe:
            return self.param_count()
        d = self.d_model
        dense = self.param_count() - self.num_layers * (
            self.num_experts * 3 * d * self.moe_dff)
        return dense + self.num_layers * (
            self.experts_per_token * 3 * d * self.moe_dff)

    def reduced(self) -> "ArchConfig":
        """Structurally identical toy config for CPU smoke tests."""
        pat = self.temporal_pattern
        n_layers = max(len(pat), 2)
        d = 32
        heads = 2
        kv = max(1, min(self.num_kv_heads, heads))
        return dataclasses.replace(
            self,
            name=self.name + "-reduced",
            num_layers=n_layers,
            d_model=d, num_heads=heads, num_kv_heads=kv, head_dim=d // heads,
            d_ff=(48 if self.d_ff > 0 else 0),
            vocab_size=64,
            num_experts=(4 if self.is_moe else 0),
            experts_per_token=(2 if self.is_moe else 0),
            moe_dff=(16 if self.is_moe else 0),
            local_window=(8 if self.local_window else 0),
            rnn_width=(32 if self.temporal_pattern.count("rglru") else 0),
            dtype="float32", remat=False, scan_layers=True,
        )


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    mode: str                       # 'train' | 'prefill' | 'decode'


SHAPES: Mapping[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}

# archs that may run long_500k (sub-quadratic state): ssm/hybrid only
LONG_CONTEXT_OK = ("recurrentgemma-2b", "xlstm-125m")

ARCH_IDS = (
    "granite-moe-1b-a400m", "qwen3-moe-30b-a3b", "gemma-7b",
    "command-r-plus-104b", "qwen2-7b", "smollm-135m", "recurrentgemma-2b",
    "musicgen-large", "qwen2-vl-7b", "xlstm-125m",
)

_MODULES = {
    "granite-moe-1b-a400m": "granite_moe_1b_a400m",
    "qwen3-moe-30b-a3b": "qwen3_moe_30b_a3b",
    "gemma-7b": "gemma_7b",
    "command-r-plus-104b": "command_r_plus_104b",
    "qwen2-7b": "qwen2_7b",
    "smollm-135m": "smollm_135m",
    "recurrentgemma-2b": "recurrentgemma_2b",
    "musicgen-large": "musicgen_large",
    "qwen2-vl-7b": "qwen2_vl_7b",
    "xlstm-125m": "xlstm_125m",
    # the paper's own networks ride along for completeness
    "resnet34": "resnet34",
    "mobilenetv2": "mobilenetv2",
    "ddpm-cifar10": "ddpm_cifar10",
}


def get_config(arch: str):
    if arch not in _MODULES:
        raise KeyError(f"unknown arch {arch!r}; known: {sorted(_MODULES)}")
    mod = importlib.import_module(f"repro.configs.{_MODULES[arch]}")
    return mod.CONFIG


def cells(include_skipped: bool = False):
    """All (arch, shape) dry-run cells, honouring the long_500k skip rule."""
    out = []
    for arch in ARCH_IDS:
        for shape in SHAPES.values():
            skipped = (shape.name == "long_500k"
                       and arch not in LONG_CONTEXT_OK)
            if skipped and not include_skipped:
                continue
            out.append((arch, shape.name) if not include_skipped
                       else (arch, shape.name, skipped))
    return out
