"""command-r-plus-104b [hf:CohereForAI/c4ai-command-r-v01; unverified]."""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="command-r-plus-104b", family="dense",
    num_layers=64, d_model=12288, num_heads=96, num_kv_heads=8,
    d_ff=33792, vocab_size=256000,
    ffn_kind="swiglu", qkv_bias=False, temporal_pattern=("attn",),
    source="hf:CohereForAI/c4ai-command-r-plus; GQA kv=8, no-bias",
)
