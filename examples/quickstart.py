"""Quickstart: LayerMerge end-to-end on a small CNN (the paper's pipeline).

Builds a tiny ResNet, pre-trains it briefly on a synthetic task, runs
Algorithm 2 (tables → DP → replace → fine-tune → merge) at a 60 % latency
budget with *measured* wall-clock latency tables, and reports the paper's
headline numbers: accuracy before/after and the real speed-up of the
merged network on this host.

Finally it exports the merged network as a portable artifact, reloads
it, and verifies the reloaded executor output is identical — the
compress-once / deploy-everywhere contract of repro.runtime.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import os
import tempfile
import time

import jax
import jax.numpy as jnp

from repro.core import (ImportanceSpec, WallClockOracle, accuracy_perf,
                        compress, xent_loss)
from repro.core.importance import _adam_finetune
from repro.models import cnn, cnn_host, zoo


def toy_task(key, n, hw, classes=4):
    x = jax.random.normal(key, (n, hw, hw, 3))
    q = hw // 2
    means = jnp.stack([x[:, :q, :q].mean((1, 2, 3)),
                       x[:, :q, q:].mean((1, 2, 3)),
                       x[:, q:, :q].mean((1, 2, 3)),
                       x[:, q:, q:].mean((1, 2, 3))], axis=1)
    return x, jnp.argmax(means, axis=1)


def main():
    net = zoo.tiny_resnet(num_classes=4, in_hw=16, width=8, blocks=(2, 2))
    params = cnn.init_params(net, jax.random.PRNGKey(0))
    xtr, ytr = toy_task(jax.random.PRNGKey(1), 256, 16)
    xev, yev = toy_task(jax.random.PRNGKey(2), 256, 16)
    apply0 = lambda p, x: cnn.apply_replaced(net, p, x)

    # 1. pre-train
    spec = ImportanceSpec(loss_fn=xent_loss, perf_fn=accuracy_perf,
                          train_batches=[(xtr, ytr)], eval_batches=[(xev, yev)],
                          steps=150, lr=3e-3)
    params = _adam_finetune(apply0, params, spec)
    base_acc = accuracy_perf(apply0, params, [(xev, yev)])
    print(f"pre-trained accuracy: {base_acc:.3f}")

    # 2. LayerMerge at 60% latency budget, measured latency tables
    host = cnn_host.CNNHost(net, params, batch=32)
    ispec = ImportanceSpec(loss_fn=xent_loss, perf_fn=accuracy_perf,
                           train_batches=[(xtr, ytr)],
                           eval_batches=[(xev, yev)], steps=5, lr=1e-3)
    res = compress(host, budget_ratio=0.6, P=200, method="layermerge",
                   latency_oracle=WallClockOracle(warmup=2, iters=5),
                   importance=ispec, base_perf=base_acc, params=params)
    plan = res.plan
    print(f"plan: A*={plan.A} |C*|={len(plan.C)}/{net.L} "
          f"ks={plan.ks}")

    # 3. fine-tune the replaced network (Algorithm 2, line before merge)
    ra, _ = host.replaced_apply(plan)
    ft = ImportanceSpec(loss_fn=xent_loss, perf_fn=accuracy_perf,
                        train_batches=[(xtr, ytr)],
                        eval_batches=[(xev, yev)], steps=150, lr=1e-3)
    params_ft = _adam_finetune(ra, params, ft)
    acc_ft = accuracy_perf(ra, params_ft, [(xev, yev)])

    # 4. merge at inference time and measure the real speed-up
    ma, _ = host.merged_apply(plan, params_ft)
    acc_merged = accuracy_perf(ma, params_ft, [(xev, yev)])

    def timeit(fn):
        fn()
        t0 = time.perf_counter()
        for _ in range(20):
            jax.block_until_ready(fn())
        return (time.perf_counter() - t0) / 20
    f_orig = jax.jit(lambda x: apply0(params, x))
    f_merged = jax.jit(lambda x: ma(params_ft, x))
    t_orig = timeit(lambda: f_orig(xev))
    t_merged = timeit(lambda: f_merged(xev))
    print(f"accuracy: original {base_acc:.3f} -> merged {acc_merged:.3f} "
          f"(replaced {acc_ft:.3f})")
    print(f"latency:  original {t_orig*1e3:.2f} ms -> merged "
          f"{t_merged*1e3:.2f} ms  ({t_orig/t_merged:.2f}x speed-up, "
          f"DP-predicted {res.speedup:.2f}x)")
    assert abs(acc_merged - acc_ft) < 1e-6, "merge must be exact"

    # 5. export the merged network as a portable artifact and reload it
    from repro import runtime
    res.params = params_ft          # publish the fine-tuned weights
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "tiny_resnet.npz")
        fp = res.save(path)
        art = runtime.load(path)
        y_live = ma(params_ft, xev)
        y_art = art.apply(xev)
        assert art.plan == plan, "artifact plan round-trip"
        assert float(jnp.abs(y_live - y_art).max()) < 1e-5, \
            "artifact reload must reproduce the merged network"
        print(f"artifact: {os.path.getsize(path)/1024:.1f} KiB, "
              f"fingerprint {fp[:16]}, reload exact")


if __name__ == "__main__":
    main()
