"""Mixture-of-Experts FFN — top-k routing with capacity-based dispatch.

Dispatch/combine use **scatter-add / gather** (O(N·k·D) memory) rather than
the classical GShard one-hot einsums (O(N·E·C) — intractable at production
shapes: qwen3-moe train_4k would need a ~10^13-element dispatch tensor).
Capacity semantics match GShard: per-expert buffers of
``C = ceil(N·k/E · capacity_factor)`` slots, first-come-first-served in
token order; overflowing (token, slot) pairs are dropped (their gate weight
is zeroed, the residual path carries the token).

Sharding: the ``experts`` logical axis maps to the mesh 'model' axis
(expert parallelism); tokens stay on 'data'.  XLA inserts the all-to-all
pair around the expert GEMMs.  A sort-based grouped-GEMM dispatch is the
§Perf upgrade path.

LayerMerge note (DESIGN §2.3): routed expert FFNs are *prunable but not
linearizable* — routing is input-dependent and discontinuous, so MoE
sublayers participate in the DP only as prune-or-keep units.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def moe_axes():
    return {
        "router": ("embed", "experts"),
        "w_gate": ("experts", "expert_embed", "expert_ffn"),
        "w_up": ("experts", "expert_embed", "expert_ffn"),
        "w_down": ("experts", "expert_ffn", "expert_embed"),
    }


def init_moe(cfg, key, dtype):
    d, e, dff = cfg.d_model, cfg.num_experts, cfg.moe_dff
    ks = jax.random.split(key, 4)
    s_in = 1.0 / math.sqrt(d)
    s_out = 1.0 / math.sqrt(dff)
    p = {
        "router": jax.random.normal(ks[0], (d, e), dtype) * s_in,
        "w_gate": jax.random.normal(ks[1], (e, d, dff), dtype) * s_in,
        "w_up": jax.random.normal(ks[2], (e, d, dff), dtype) * s_in,
        "w_down": jax.random.normal(ks[3], (e, dff, d), dtype) * s_out,
    }
    return p, moe_axes()


def route(p, xt, cfg):
    """Top-k gating.  xt: (N, D) → (gates (N,k), experts (N,k) int32)."""
    logits = (xt @ p["router"]).astype(jnp.float32)
    gates = jax.nn.softmax(logits, axis=-1)
    top_g, top_e = jax.lax.top_k(gates, cfg.experts_per_token)
    top_g = top_g / jnp.sum(top_g, axis=-1, keepdims=True)
    return top_g.astype(xt.dtype), top_e


def capacity_positions(top_e, num_experts, capacity):
    """FCFS slot index of each (token, slot) within its expert's buffer.

    Sort-based ranking: stable-argsort groups token-slots by expert, the
    within-group rank is ``arange − group_start``.  O(Nk log Nk) work and an
    O(E) cumsum — the naive one-hot cumsum is O(Nk·E) memory and lowers to
    quadratic reduce-window work (~10^14 FLOPs/chip at qwen3-moe train_4k).
    """
    n, k = top_e.shape
    flat = top_e.reshape(-1)
    order = jnp.argsort(flat, stable=True)
    sorted_e = flat[order]
    counts = jnp.zeros((num_experts,), jnp.int32).at[flat].add(1, mode="drop")
    starts = jnp.concatenate([jnp.zeros((1,), jnp.int32),
                              jnp.cumsum(counts)[:-1]])
    ranks_sorted = jnp.arange(n * k, dtype=jnp.int32) - starts[sorted_e]
    pos = jnp.zeros((n * k,), jnp.int32).at[order].set(ranks_sorted,
                                                       mode="drop")
    pos = pos.reshape(n, k)
    keep = pos < capacity
    return pos, keep


def capacity_positions_cumsum(top_e, num_experts, capacity):
    """Reference one-hot-cumsum ranking (GShard formulation) — kept as the
    oracle for the sort-based version; only safe at toy sizes."""
    n, k = top_e.shape
    onehot = jax.nn.one_hot(top_e.reshape(n * k), num_experts,
                            dtype=jnp.int32)
    pos = (jnp.cumsum(onehot, axis=0) - onehot)
    pos = jnp.sum(pos * onehot, axis=-1).reshape(n, k)
    keep = pos < capacity
    return pos, keep


def _moe_group(p, xt, cfg, capacity):
    """Single-group dispatch→experts→combine (vmapped over groups)."""
    e = cfg.num_experts
    top_g, top_e = route(p, xt, cfg)
    pos, keep = capacity_positions(top_e, e, capacity)
    gate_kept = top_g * keep.astype(top_g.dtype)
    safe_pos = jnp.where(keep, pos, capacity - 1)
    contrib = jnp.where(keep[..., None], 1.0, 0.0).astype(xt.dtype)
    expert_in = jnp.zeros((e, capacity, xt.shape[-1]), xt.dtype)
    expert_in = expert_in.at[top_e, safe_pos].add(
        xt[:, None, :] * contrib, mode="drop")
    return expert_in, (top_e, safe_pos, gate_kept)


def moe_ffn(p, x, cfg, *, capacity_factor: float = 1.25,
            num_groups: int | None = None):
    """x: (B, S, D) → (B, S, D).  Top-k, capacity-dropped, GShard-style
    GROUPED dispatch: tokens are grouped by data shard so the scatter and
    gather are chip-local; buffers are sharded (group→data, expert→model)
    and only the token-sized combine crosses the 'model' axis.

    §Perf lesson (EXPERIMENTS.md): an ungrouped global-capacity buffer makes
    XLA psum whole (E, C, D) buffers across data shards (~27 GB/chip/step at
    qwen3-moe train_4k); a capacity-dim sharding constraint is 22× worse
    (scatter targets are data-dependent, XLA falls back to full exchange).
    Grouping is what removes the buffer collectives entirely.
    """
    from repro.sharding.rules import current_rules, logical_constraint
    b, s, d = x.shape
    e, k = cfg.num_experts, cfg.experts_per_token
    n = b * s
    if num_groups is None:
        r = current_rules()
        num_groups = 1
        if r is not None and r.mesh is not None:
            num_groups = int(__import__("numpy").prod(
                [r.mesh.shape[a] for a in ("pod", "data")
                 if a in r.mesh.shape]))
    g = max(1, math.gcd(num_groups, n))
    xt = x.reshape(g, n // g, d)
    capacity = max(int(math.ceil(n / g * k / e * capacity_factor)), 1)
    expert_in, (top_e, safe_pos, gate_kept) = jax.vmap(
        lambda xg: _moe_group(p, xg, cfg, capacity))(xt)
    expert_in = logical_constraint(expert_in,
                                   ("moe_group", "experts", None, None))

    h = jax.nn.silu(jnp.einsum("gecd,edf->gecf", expert_in, p["w_gate"]))
    h = h * jnp.einsum("gecd,edf->gecf", expert_in, p["w_up"])
    expert_out = jnp.einsum("gecf,efd->gecd", h, p["w_down"])
    expert_out = logical_constraint(expert_out,
                                    ("moe_group", "experts", None, None))

    # group-local gather + gate-weighted combine
    out = jax.vmap(lambda eo, te, sp, gk:
                   jnp.sum(eo[te, sp] * gk[..., None], axis=1))(
        expert_out, top_e, safe_pos, gate_kept)
    out = logical_constraint(out.reshape(b, s, d),
                             ("batch", "seq", "act_embed"))
    return out


def moe_ffn_sharded(p, x, cfg, *, capacity_factor: float = 1.25, rules=None):
    """shard_map MoE (§Perf iteration 3): expert-local dispatch + one
    token-sized psum.

    Each (data, model) chip: routes its LOCAL tokens against the full router
    (512 KB gather), scatters only the slots destined to its LOCAL experts
    into an (E_loc, C, D) buffer (no communication), runs the expert GEMMs,
    gathers its partial token outputs, and psums (tokens × d_model) over the
    'model' axis — ~268 MB/layer at qwen3 train_4k instead of the
    ~15.8 GB/layer of buffer all-reduce XLA's SPMD chose for the gather/
    scatter formulation (EXPERIMENTS §Perf).

    Expert weights are TP-sharded over 'model' and replicated over data
    ('expert_embed' rule); optimizer moments stay fully sharded (ZeRO-1).
    """
    import numpy as np

    from repro.sharding.collectives import shard_map
    from jax.sharding import PartitionSpec as P

    mesh = rules.mesh
    b, s, d = x.shape
    e, k = cfg.num_experts, cfg.experts_per_token
    n_model = mesh.shape["model"]
    e_loc = e // n_model
    data_axes = tuple(a for a in ("pod", "data") if a in mesh.shape)
    n_data = int(np.prod([mesh.shape[a] for a in data_axes])) or 1
    n = b * s
    capacity = max(int(math.ceil(n / n_data * k / e * capacity_factor)), 1)
    bspec = data_axes if len(data_axes) > 1 else data_axes[0]

    def local(x_loc, router, wg, wu, wd):
        nt = x_loc.shape[0] * x_loc.shape[1]
        xt = x_loc.reshape(nt, d)
        logits = (xt @ router).astype(jnp.float32)
        gates = jax.nn.softmax(logits, axis=-1)
        top_g, top_e = jax.lax.top_k(gates, k)
        top_g = (top_g / jnp.sum(top_g, axis=-1, keepdims=True)
                 ).astype(xt.dtype)
        pos, keep = capacity_positions(top_e, e, capacity)
        ei = jax.lax.axis_index("model")
        local_slot = top_e - ei * e_loc
        is_local = (local_slot >= 0) & (local_slot < e_loc)
        contrib = keep & is_local
        safe_slot = jnp.where(contrib, local_slot, 0)
        safe_pos = jnp.where(contrib, pos, capacity - 1)
        cmask = contrib[..., None].astype(xt.dtype)
        buf = jnp.zeros((e_loc, capacity, d), xt.dtype)
        buf = buf.at[safe_slot, safe_pos].add(xt[:, None, :] * cmask,
                                              mode="drop")
        h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, wg))
        h = h * jnp.einsum("ecd,edf->ecf", buf, wu)
        out_buf = jnp.einsum("ecf,efd->ecd", h, wd)
        part = out_buf[safe_slot, safe_pos] * (top_g[..., None] * cmask)
        out = jax.lax.psum(jnp.sum(part, axis=1), "model")
        return out.reshape(x_loc.shape)

    return shard_map(
        local, mesh=mesh,
        in_specs=(P(bspec), P(), P("model"), P("model"), P("model")),
        out_specs=P(bspec),
        check_vma=False,
    )(x, p["router"], p["w_gate"], p["w_up"], p["w_down"])


def moe_dispatch(p, x, cfg, *, capacity_factor: float = 1.25):
    """Entry point used by the model: picks the shard_map path when an
    expert-divisible mesh is active, else the dense grouped path."""
    from repro.sharding.rules import current_rules
    r = current_rules()
    if r is not None and r.mesh is not None and "model" in r.mesh.shape \
            and cfg.num_experts % r.mesh.shape["model"] == 0 \
            and r.rules.get("moe_shard_map", True):
        return moe_ffn_sharded(p, x, cfg, capacity_factor=capacity_factor,
                               rules=r)
    return moe_ffn(p, x, cfg, capacity_factor=capacity_factor)


def aux_load_balance_loss(p, x, cfg):
    """Switch-style load-balancing auxiliary (fraction·prob dot product)."""
    b, s, d = x.shape
    xt = x.reshape(b * s, d)
    logits = (xt @ p["router"]).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    top_e = jnp.argmax(probs, axis=-1)
    frac = jnp.mean(jax.nn.one_hot(top_e, cfg.num_experts), axis=0)
    prob = jnp.mean(probs, axis=0)
    return cfg.num_experts * jnp.sum(frac * prob)
