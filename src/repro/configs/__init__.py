from .base import (ARCH_IDS, LONG_CONTEXT_OK, SHAPES, ArchConfig,
                   ShapeConfig, cells, get_config)

__all__ = ["ARCH_IDS", "LONG_CONTEXT_OK", "SHAPES", "ArchConfig",
           "ShapeConfig", "cells", "get_config"]
