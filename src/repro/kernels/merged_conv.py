"""Pallas TPU kernel: merged-segment convolution (VALID, stride 1, NHWC).

The paper's hot spot: after LayerMerge, a segment executes as ONE conv
whose kernel has grown (Eq. 1).  TPU adaptation: instead of im2col (which
materializes the k²-unrolled input in HBM), the kernel keeps the whole
input image tile resident in VMEM and accumulates the k_h·k_w shifted
GEMMs — (Ho·Wo, Cin) @ (Cin, bCout) per tap — on the MXU, so the grown
kernel costs FLOPs but no extra HBM traffic (that is exactly the trade the
DP's latency table models).

Grid: (batch, cout-tiles).  VMEM: image H·W·Cin ≤ ~2 MiB for the CNN-paper
shapes (56×56×256·bf16 ≈ 1.6 MiB), weights k²·Cin·bCout, fp32 acc.
Bias + activation are fused in ops.py's epilogue.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(x_ref, w_ref, o_ref, *, kh: int, kw: int):
    ho, wo = o_ref.shape[0], o_ref.shape[1]
    cin = x_ref.shape[-1]
    bcout = o_ref.shape[-1]
    acc = jnp.zeros((ho * wo, bcout), jnp.float32)
    for u in range(kh):
        for v in range(kw):
            xs = x_ref[u:u + ho, v:v + wo, :].astype(jnp.float32)
            ws = w_ref[u, v].astype(jnp.float32)          # (Cin, bCout)
            acc = acc + jnp.dot(xs.reshape(ho * wo, cin), ws,
                                preferred_element_type=jnp.float32)
    o_ref[...] = acc.reshape(ho, wo, bcout).astype(o_ref.dtype)


def merged_conv(x, w, *, bcout: int = 128, interpret: bool = False):
    """x: (N, H, W, Cin); w: (kh, kw, Cin, Cout) → (N, Ho, Wo, Cout)."""
    n, h, wdt, cin = x.shape
    kh, kw, _, cout = w.shape
    ho, wo = h - kh + 1, wdt - kw + 1
    bcout = min(bcout, cout)
    assert cout % bcout == 0, "pad channels at the ops layer"
    grid = (n, cout // bcout)
    return pl.pallas_call(
        functools.partial(_kernel, kh=kh, kw=kw),
        grid=grid,
        in_specs=[
            pl.BlockSpec((None, h, wdt, cin), lambda b, co: (b, 0, 0, 0)),
            pl.BlockSpec((kh, kw, cin, bcout), lambda b, co: (0, 0, 0, co)),
        ],
        out_specs=pl.BlockSpec((None, ho, wo, bcout),
                               lambda b, co: (b, 0, 0, co)),
        out_shape=jax.ShapeDtypeStruct((n, ho, wo, cout), x.dtype),
        interpret=interpret,
    )(x, w)
