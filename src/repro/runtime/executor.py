"""Shared UnitGraph interpreter — ONE execution path for every merged net.

Replaces the two per-host apply loops (``cnn.apply_merged`` over
``MergedUnit`` lists and ``transformer_host._apply_units`` /
``T.forward_compressed`` over tuple units): both hosts lower plans to
:class:`repro.runtime.ir.UnitGraph` and this module runs them.  Every
unit routes through the public kernel entry points in
:mod:`repro.kernels` — Pallas ``merged_conv`` / ``merged_ffn`` on TPU,
the jnp oracles elsewhere — so the serving path exercises exactly the
kernels the latency tables timed.

Entry points:

* :func:`execute` — full forward (CNN image / transformer prefill).
* :func:`run_units` — a bare unit chain, no embed/head (segment probes).
* :func:`init_cache` / :func:`decode_step` / :func:`make_serve_step` —
  KV-cache-aware one-token decode for serving compressed transformers;
  :func:`slot_state` stacks the per-unit cache into the per-slot state
  the continuous serve engine vmaps over.
* :func:`jit_apply` — jitted ``fn(params, inputs)`` with the graph's
  arrays exposed as a pytree (fine-tuning / sharding consumers).
* :class:`GraphExecutor` — the mesh-aware serving entry point: resolves
  the graph's logical-axis annotations (:mod:`repro.runtime.ir`) through
  a :class:`ShardingRules` into ``NamedSharding``s, places params and
  caches, and jits prefill/decode once under the mesh.  ``rules=None``
  (or a one-device mesh) is the SAME code path — every
  ``logical_constraint`` is a no-op without ambient rules — so the
  single-host executor is just the trivial mesh, not a second
  interpreter.

The unit loop is a python loop: compressed networks are shallow by
construction (that is the point of the paper), so trace cost is small
and every unit keeps its own fused kernel launch.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro import kernels
from repro.models import cnn as _cnn
from repro.models import layers as L
from repro.models import moe as MOE
from repro.models import rglru as RG
from repro.models import transformer as T
from repro.models import xlstm as XL
from repro.sharding.rules import (logical_constraint,
                                  param_shardings_with_shapes, use_rules)

from . import ir


def execute(graph: ir.UnitGraph, inputs, params=None):
    """Run a UnitGraph: NHWC image batch (cnn) or token batch (transformer).

    ``params`` optionally rebinds the graph's arrays (see
    :func:`repro.runtime.ir.graph_params`) — the pure-function form used
    under jit and by fine-tuning consumers.
    """
    if params is not None:
        graph = ir.bind_params(graph, params)
    if graph.family == "cnn":
        return _execute_cnn(graph, inputs)
    if graph.family == "transformer":
        return _execute_transformer(graph, inputs)
    raise ValueError(f"unknown graph family {graph.family!r}")


def jit_apply(graph: ir.UnitGraph):
    """(jitted ``fn(params, inputs)``, params pytree) for a graph."""
    params = ir.graph_params(graph)
    fn = jax.jit(lambda p, x: execute(graph, x, params=p))
    return fn, params


# ---------------------------------------------------------------------------
# CNN family
# ---------------------------------------------------------------------------

#: NHWC activation layout of the CNN unit loop: batch data-parallel,
#: channels on the model axis (the merged-conv analogue of 'act_ffn')
_CNN_ACT = ("batch", None, None, "act_channels")


def _execute_cnn(graph: ir.UnitGraph, x):
    saved: dict[int, jax.Array] = {}
    x = logical_constraint(x, _CNN_ACT)
    if graph.meta.get("save_input"):
        saved[0] = x
    for u in graph.units:
        if u.kind == "conv":
            w, b = u.params["w"], u.params["b"]
            K = w.shape[0]
            lo = (K - 1) // 2
            hi = K - 1 - lo
            if K > 1:
                x = jnp.pad(x, ((0, 0), (lo, hi), (lo, hi), (0, 0)))
            ws = u.params.get("w_scale")
            aq = u.quant if (ws is not None and u.quant == "w8a8") else "none"
            if u.depthwise:
                x = kernels.depthwise_conv_op(x, w, b, stride=u.stride,
                                              w_scale=ws, act_quant=aq)
            else:
                x = kernels.merged_conv_op(x, w, b, stride=u.stride,
                                           w_scale=ws, act_quant=aq)
            if u.add_from is not None:
                base = saved[u.add_from]
                if "proj" in u.params:
                    pr = u.params["proj"]
                    base = _cnn._conv(base, pr["w"], u.proj_stride, False,
                                      padding="SAME") + pr["b"]
                x = x + base
            if u.concat_from is not None:
                x = jnp.concatenate([x, saved[u.concat_from]], axis=-1)
            if "gn" in u.params:
                x = _cnn._gn(x, u.params["gn"], u.gn_groups)
            x = _cnn._act(x, u.act)
        elif u.kind == "pool":
            x = jax.lax.reduce_window(
                x, 0.0, jax.lax.add, (1, u.k, u.k, 1),
                (1, u.stride, u.stride, 1), "SAME") / (u.k * u.k)
            if u.concat_from is not None:
                x = jnp.concatenate([x, saved[u.concat_from]], axis=-1)
        elif u.kind == "upsample":
            n, h, w_, c = x.shape
            x = jax.image.resize(
                x, (n, h * u.factor, w_ * u.factor, c), "nearest")
            if u.concat_from is not None:
                x = jnp.concatenate([x, saved[u.concat_from]], axis=-1)
        elif u.kind == "attn":
            x = _cnn._tiny_self_attention(x, u.params)
        else:
            raise ValueError(f"unit kind {u.kind!r} in cnn graph")
        x = logical_constraint(x, _CNN_ACT)
        if u.save_at is not None:
            saved[u.save_at] = x
    if graph.meta.get("head") == "classifier":
        head = graph.params["head"]
        x = x.mean(axis=(1, 2))
        x = x @ head["w"] + head["b"]
    return x


# ---------------------------------------------------------------------------
# Transformer family
# ---------------------------------------------------------------------------

def _apply_unit(cfg, u, x, positions, mrope):
    """One prefill/probe unit: lowrank residual or kept sublayer."""
    if u.kind == "lowrank":
        us, vs = u.params.get("u_scale"), u.params.get("v_scale")
        aq = u.quant if (us is not None and u.quant == "w8a8") else "none"
        return logical_constraint(
            kernels.merged_ffn_op(x, u.params["u"], u.params["v"],
                                  u_scale=us, v_scale=vs, act_quant=aq),
            ("batch", "seq", "act_embed"))
    if u.kind != "sublayer":
        raise ValueError(f"unit kind {u.kind!r} in transformer graph")
    sub = u.params
    h = L.rms_norm(x, sub["norm"], cfg.norm_eps)
    kind = u.sub_kind
    if kind == "moe":
        t = MOE.moe_ffn(sub["p"], h, cfg, capacity_factor=cfg.capacity_factor)
    elif kind == "ffn":
        t = L.ffn(sub["p"], h, cfg.ffn_kind)
    else:
        t = T._temporal_apply(cfg, kind, sub["p"], h, positions, mrope)
    return logical_constraint(x + t, ("batch", "seq", "act_embed"))


def run_units(cfg, units, x, positions=None):
    """Bare unit chain, no embed/unembed — the segment-probe forward."""
    if positions is None:
        positions = jnp.arange(x.shape[1])[None, :]
    for u in units:
        x = _apply_unit(cfg, u, x, positions, None)
    return x


def _execute_transformer(graph: ir.UnitGraph, batch):
    cfg = graph.meta["config"]
    gp = graph.params
    x = T._embed_in(cfg, gp, batch)
    positions = batch.get("positions")
    if positions is None:
        positions = jnp.arange(x.shape[1])[None, :]
    mrope = batch.get("mrope_positions")
    for u in graph.units:
        x = _apply_unit(cfg, u, x, positions, mrope)
    x = L.rms_norm(x, gp["final_norm"], cfg.norm_eps)
    return T._unembed(cfg, gp, x)


# ---------------------------------------------------------------------------
# KV-cache decode (serving)
# ---------------------------------------------------------------------------

def _state_axes(u) -> dict:
    """Logical axes of one unit's decode state ('kv_seq' decode layout)."""
    if u.kind == "sublayer" and u.sub_kind in ir.TEMPORAL_KINDS:
        if u.sub_kind in ("attn", "attn_local"):
            return dict(L.CACHE_AXES)
        if u.sub_kind == "rglru":
            return dict(RG.RGLRU_STATE_AXES)
        if u.sub_kind == "mlstm":
            return dict(XL.MLSTM_STATE_AXES)
        return dict(XL.SLSTM_STATE_AXES)
    return {}


def cache_axes(graph: ir.UnitGraph) -> list:
    """Per-unit logical-axes pytree aligned with :func:`init_cache`."""
    return [_state_axes(u) for u in graph.units]


def _is_names(x):
    return isinstance(x, tuple) or x is None


def _constrain_state(c, ax):
    """logical_constraint over one unit's decode-state pytree."""
    if not ax:
        return c
    return jax.tree.map(
        lambda names, a: logical_constraint(a, names) if names else a,
        ax, c, is_leaf=_is_names)


def init_cache(graph: ir.UnitGraph, batch_size: int, seq_len: int):
    """Per-unit decode state: KV cache for attention sublayers, recurrent
    state for rglru/mlstm/slstm, ``{}`` for stateless units."""
    cfg = graph.meta["config"]
    dtype = jnp.dtype(cfg.dtype)
    caches = []
    for u in graph.units:
        if u.kind == "sublayer" and u.sub_kind in ir.TEMPORAL_KINDS:
            if u.sub_kind in ("attn", "attn_local"):
                window = cfg.local_window if u.sub_kind == "attn_local" else 0
                caches.append(L.init_cache(cfg, batch_size, seq_len, dtype,
                                           window=window))
            elif u.sub_kind == "rglru":
                caches.append(RG.init_rglru_state(cfg, batch_size, dtype))
            elif u.sub_kind == "mlstm":
                caches.append(XL.init_mlstm_state(cfg, batch_size))
            else:
                caches.append(XL.init_slstm_state(cfg, batch_size))
        else:
            caches.append({})
    return caches


def decode_step(graph: ir.UnitGraph, cache, batch):
    """One-token decode through the compressed unit chain.

    ``batch``: {'tokens': (B, 1)} (or 'embeds').  Returns (logits,
    new_cache).  Low-rank units are position-independent residual maps,
    so they apply to the single-token activation directly — the merged
    segments cost O(1) state, one of the serving wins of depth
    compression.
    """
    cfg = graph.meta["config"]
    gp = graph.params
    x = T._embed_in(cfg, gp, batch)
    mrope = batch.get("mrope_positions")
    new_cache = []
    for u, c in zip(graph.units, cache):
        if u.kind == "sublayer" and u.sub_kind in ir.TEMPORAL_KINDS:
            sub = u.params
            h = L.rms_norm(x, sub["norm"], cfg.norm_eps)
            kind = u.sub_kind
            if kind in ("attn", "attn_local"):
                window = cfg.local_window if kind == "attn_local" else 0
                t, c = L.attention_decode(sub["p"], h, cfg, c, window=window,
                                          mrope_positions=mrope)
            elif kind == "rglru":
                t, c = RG.rglru_decode(sub["p"], h, cfg, c)
            elif kind == "mlstm":
                t, c = XL.mlstm_decode(sub["p"], h, cfg, c)
            else:
                t, c = XL.slstm_decode(sub["p"], h, cfg, c)
            x = logical_constraint(x + t, ("batch", "seq", "act_embed"))
            c = _constrain_state(c, _state_axes(u))
        else:
            x = _apply_unit(cfg, u, x, None, mrope)
        new_cache.append(c)
    x = L.rms_norm(x, gp["final_norm"], cfg.norm_eps)
    return T._unembed(cfg, gp, x), new_cache


def make_serve_step(graph: ir.UnitGraph):
    """(``step(params, cache, batch) → (logits, cache)``, params pytree).

    The jittable one-token serve step for a compressed transformer —
    the artifact-backed analogue of
    :func:`repro.train.step.make_serve_step`.
    """
    params = ir.graph_params(graph)

    def step(p, cache, batch):
        return decode_step(ir.bind_params(graph, p), cache, batch)
    return step, params


def slot_state(graph: ir.UnitGraph, slots: int, seq_len: int):
    """Per-slot decode state for the continuous serve engine.

    One fresh single-request cache (:func:`init_cache` with batch 1)
    stacked so every leaf gains a leading ``(slots,)`` axis — including
    each attention cache's scalar ``pos``, which is what lets every slot
    advance its own sequence position independently under the engine's
    vmapped chunk step (see :func:`repro.runtime.serving.stack_cache`).
    """
    from .serving import stack_cache
    return stack_cache(init_cache(graph, 1, seq_len), slots)


# ---------------------------------------------------------------------------
# Mesh-aware execution (sharded serving)
# ---------------------------------------------------------------------------

def graph_shardings(rules, graph: ir.UnitGraph):
    """NamedSharding pytree for :func:`ir.graph_params` under ``rules``.

    Resolved from the graph's declarative axes annotations with per-leaf
    divisibility fallback (a dim the mesh does not divide is replicated,
    the GQA kv<TP contract) — sharding stays data in the artifact.
    """
    return param_shardings_with_shapes(rules, ir.graph_axes(graph),
                                       ir.graph_params(graph))


def cache_shardings(rules, graph: ir.UnitGraph, cache):
    """NamedSharding pytree for a decode cache ('kv_seq' layout)."""
    return param_shardings_with_shapes(rules, cache_axes(graph), cache)


class GraphExecutor:
    """Jitted, mesh-aware prefill/decode over one :class:`UnitGraph`.

    ``rules=None`` (or a rules object without a mesh) is the trivial
    single-device executor: the same traced programs, with every
    ``logical_constraint`` a no-op and params left where they are.  With
    rules, params are ``device_put`` onto the shardings their logical
    axes resolve to, prefill/decode are traced once under the ambient
    rules (so activation and KV-cache constraints bake into the jitted
    programs), and fresh caches come back mesh-placed.
    """

    def __init__(self, graph: ir.UnitGraph, rules=None):
        self.graph = graph
        self.rules = rules if (rules is not None
                               and rules.mesh is not None) else None
        params = ir.graph_params(graph)
        if self.rules is not None:
            params = jax.device_put(params, graph_shardings(self.rules,
                                                            graph))
        self.params = params
        self._prefill = jax.jit(
            lambda p, batch: execute(graph, batch, params=p))
        self._decode = jax.jit(
            lambda p, cache, batch: decode_step(ir.bind_params(graph, p),
                                                cache, batch))

    def apply(self, batch, params=None):
        """Full forward (CNN image batch / transformer prefill), jitted."""
        with use_rules(self.rules):
            return self._prefill(self.params if params is None else params,
                                 batch)

    def init_cache(self, batch_size: int, seq_len: int):
        cache = init_cache(self.graph, batch_size, seq_len)
        if self.rules is not None:
            cache = jax.device_put(
                cache, cache_shardings(self.rules, self.graph, cache))
        return cache

    def decode(self, cache, batch, params=None):
        """One-token decode step, jitted: ``(logits, new_cache)``."""
        with use_rules(self.rules):
            return self._decode(self.params if params is None else params,
                                cache, batch)

    def serve_step(self):
        """``(step(params, cache, batch), params)`` for the serve loops.

        The step is unjitted — :mod:`repro.runtime.serving` scans and
        jits it; callers must run it under ``use_rules(self.rules)``
        (the serving entry points take ``rules=`` and do this).
        """
        step, _ = make_serve_step(self.graph)
        return step, self.params

    def continuous_engine(self, *, slots: int, max_seq: int, **kw):
        """A :class:`repro.runtime.serving.ContinuousEngine` over this
        graph: mid-stream admission/retirement with per-slot failure
        isolation, using the executor's params and cache constructor.
        Keyword extras (``chunk``, ``eos_id``, ``max_queue``,
        ``slot_nan_limit``, ``clock``, ...) pass through.  Certified on
        a single device; under a mesh prefer the fixed scheduler.
        """
        from .serving import ContinuousEngine
        step, params = self.serve_step()
        return ContinuousEngine(
            step, params, lambda b, s: init_cache(self.graph, b, s),
            slots=slots, max_seq=max_seq, rules=self.rules, **kw)
