"""Backend-neutral unit IR for merged (compressed) networks.

A :class:`UnitGraph` is the executable form of a compression plan: an
ordered chain of typed *units*, each a record of STATIC configuration
(strides, kernel geometry, activation epilogue, skip wiring) plus a
``params`` pytree of arrays (merged weights).  Hosts lower plans into
this IR (``host.lower_plan(plan, params) → UnitGraph``); the shared
interpreter in :mod:`repro.runtime.executor` runs it; the artifact layer
in :mod:`repro.runtime.artifact` serializes it.

Design rules:

* Static fields are plain JSON-able Python values — they round-trip
  through the artifact spec unchanged.  Arrays live only in ``params``.
* Units never reference host objects (``ConvNet``, ``ArchConfig``
  instances, parameter dicts of the *uncompressed* network): everything
  the executor needs is in the unit record or ``UnitGraph.meta``.
* Skip/branch wiring is expressed through boundary ids: a unit may
  ``save_at`` a boundary and later units may ``add_from`` /
  ``concat_from`` it — the executor keeps the saved-activation table.

CNN unit semantics (epilogue order matches the merged forward that the
merge-equality tests certify): conv → skip-add → concat → group-norm →
boundary activation → save.
"""
from __future__ import annotations

import dataclasses
from typing import Any


@dataclasses.dataclass
class ConvUnit:
    """One merged conv segment: VALID conv at the merged kernel size.

    ``params``: ``w`` (Kh,Kw,Cin|1,Cout), ``b`` (Cout,), optional
    ``gn`` {gamma, beta} (group-norm moved to the segment end, paper
    Appendix A) and optional ``proj`` {w, b} (1×1 projection shortcut of
    a skip-add ending at this unit's boundary).
    """

    kind = "conv"
    stride: int = 1
    depthwise: bool = False
    act: str = "none"               # boundary activation σ_j ('none' at σ_L)
    gn_groups: int = 8
    proj_stride: int = 1
    add_from: int | None = None     # skip-add source boundary id
    concat_from: int | None = None  # U-Net concat source boundary id
    save_at: int | None = None      # boundary id to save the output under
    params: dict = dataclasses.field(default_factory=dict)


@dataclasses.dataclass
class PoolUnit:
    """Average-pool barrier unit (parameter-free)."""

    kind = "pool"
    k: int = 2
    stride: int = 2
    concat_from: int | None = None
    save_at: int | None = None
    params: dict = dataclasses.field(default_factory=dict)


@dataclasses.dataclass
class UpsampleUnit:
    """Nearest-neighbour upsample barrier unit (parameter-free)."""

    kind = "upsample"
    factor: int = 2
    concat_from: int | None = None
    save_at: int | None = None
    params: dict = dataclasses.field(default_factory=dict)


@dataclasses.dataclass
class AttnUnit:
    """Single-head spatial self-attention barrier (DDPM middle block).

    ``params``: ``wq``, ``wk``, ``wv``, ``wo`` — passed through unmerged
    (attention is never linearizable).
    """

    kind = "attn"
    save_at: int | None = None
    params: dict = dataclasses.field(default_factory=dict)


@dataclasses.dataclass
class LowRankUnit:
    """Rank-``r`` residual map ``x + (x·U)·V`` — a merged FFN segment.

    ``params``: ``u`` (D,r), ``v`` (r,D).  Runs through the Pallas
    ``merged_ffn`` kernel on TPU.
    """

    kind = "lowrank"
    params: dict = dataclasses.field(default_factory=dict)


@dataclasses.dataclass
class SublayerUnit:
    """One kept transformer sublayer: pre-norm → block → residual add.

    ``sub_kind``: 'attn' | 'attn_local' | 'ffn' | 'moe' | 'rglru' |
    'mlstm' | 'slstm'.  ``params``: {'norm': rmsnorm scale, 'p': the
    block's parameter pytree}.  Temporal kinds carry decode state (KV
    cache / recurrent state) in the executor's serve path.
    """

    kind = "sublayer"
    sub_kind: str = "ffn"
    params: dict = dataclasses.field(default_factory=dict)


UNIT_TYPES = {
    "conv": ConvUnit,
    "pool": PoolUnit,
    "upsample": UpsampleUnit,
    "attn": AttnUnit,
    "lowrank": LowRankUnit,
    "sublayer": SublayerUnit,
}

#: temporal sublayer kinds that carry decode state in the serve path
TEMPORAL_KINDS = ("attn", "attn_local", "rglru", "mlstm", "slstm")


@dataclasses.dataclass
class UnitGraph:
    """Executable form of a plan: ordered units + graph-level params.

    ``family``: 'cnn' | 'transformer' — selects the executor loop.

    ``params`` (graph-level, outside any unit):
      cnn          — optional ``head`` {w, b} (classifier);
      transformer  — optional ``embed``, ``final_norm``, optional
                     ``unembed``.

    ``meta`` (static):
      cnn          — ``save_input`` (bool: boundary 0 feeds a skip),
                     ``head`` ('classifier' | 'none');
      transformer  — ``config`` (the :class:`ArchConfig`; serialized as
                     a plain dict in the artifact spec).
    """

    family: str
    units: tuple
    params: dict = dataclasses.field(default_factory=dict)
    meta: dict = dataclasses.field(default_factory=dict)


# ---------------------------------------------------------------------------
# Static spec <-> unit records (artifact serialization support)
# ---------------------------------------------------------------------------

def unit_static(unit) -> dict:
    """JSON-able static record of one unit (everything but ``params``)."""
    out = {"kind": unit.kind}
    for f in dataclasses.fields(unit):
        if f.name == "params":
            continue
        out[f.name] = getattr(unit, f.name)
    return out


def unit_from_static(static: dict, params: dict):
    cls = UNIT_TYPES[static["kind"]]
    kwargs = {k: v for k, v in static.items() if k != "kind"}
    return cls(params=params, **kwargs)


# ---------------------------------------------------------------------------
# Params as a pytree (jit / fine-tune / checkpoint support)
# ---------------------------------------------------------------------------

def graph_params(graph: UnitGraph) -> dict:
    """The graph's arrays as one pytree: {'units': [...], 'globals': {...}}."""
    return {"units": [u.params for u in graph.units],
            "globals": graph.params}


def bind_params(graph: UnitGraph, params: dict) -> UnitGraph:
    """A structurally-identical graph with its arrays replaced.

    ``params`` must match :func:`graph_params` of the same graph — this
    is how the executor exposes a pure ``fn(params, x)`` signature while
    unit records stay the single source of static truth.
    """
    units = tuple(dataclasses.replace(u, params=p)
                  for u, p in zip(graph.units, params["units"]))
    return UnitGraph(family=graph.family, units=units,
                     params=params["globals"], meta=graph.meta)


def count_units(graph: UnitGraph) -> dict[str, int]:
    """Unit census (for benchmarks / reports): kind → count."""
    out: dict[str, int] = {}
    for u in graph.units:
        key = u.kind if u.kind != "sublayer" else f"sublayer:{u.sub_kind}"
        out[key] = out.get(key, 0) + 1
    return out
