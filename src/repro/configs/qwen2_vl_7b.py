"""qwen2-vl-7b [arXiv:2409.12191; hf] — M-RoPE, dynamic resolution.

Backbone only (assignment): the ViT frontend is a stub — ``input_specs``
feeds precomputed patch/text embeddings (B, S, d_model) plus 3-stream M-RoPE
position ids (3, B, S).
"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="qwen2-vl-7b", family="vlm",
    num_layers=28, d_model=3584, num_heads=28, num_kv_heads=4,
    d_ff=18944, vocab_size=152064,
    ffn_kind="swiglu", qkv_bias=True, temporal_pattern=("attn",),
    frontend="embeddings", rope_kind="mrope",
    source="arXiv:2409.12191; M-RoPE, ViT frontend stubbed",
)
