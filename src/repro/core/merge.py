"""Layer merging — the paper's ``θ_j * … * θ_i`` composition, in JAX.

Conventions: conv weights are ``(kh, kw, cin, cout)`` (HWIO) and act by
*cross-correlation* (``jax.lax.conv_general_dilated`` default) with VALID
padding inside a merged group; depthwise convs are ``(kh, kw, 1, c)`` with
``feature_group_count = c``.

Facts implemented here (each certified by an allclose test against the
composed original in ``tests/test_merge.py``):

* ``merge_conv_pair``   — Eq. 1: composing two stride-``s`` correlations is a
  single correlation with kernel ``(k2−1)·s1 + k1`` and stride ``s1·s2``; the
  merged weight is the *convolution* (flipped correlation) of the kernels
  with the middle channel contracted, with ``rhs_dilation = s1``.
* ``identity_kernel``   — the paper's ``θ_id``: 1×1 depthwise ones.
* ``fuse_skip_add``     — RepVGG-style: ``x + conv(x)`` == a single conv whose
  kernel has a centred Dirac added (valid when shapes are preserved).
* ``fold_batchnorm``    — inference-time BN folding.
* ``merge_linear_residual_pair`` — the transformer rank-merge (DESIGN §2.1):
  ``(I + V2U2)(I + V1U1) = I + [V1 V2]·[U1 ; U2(I + V1U1)]`` — an exact
  factored merge whose rank grows additively, the analogue of Eq. 1.
* ``truncate_rank``     — optional SVD truncation of a merged (U, V) at
  ``d_model`` (or any smaller rank), used when the additive rank saturates.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax


# ---------------------------------------------------------------------------
# Convolution composition (Eq. 1)
# ---------------------------------------------------------------------------

def identity_kernel(c: int, dtype=jnp.float32) -> jax.Array:
    """θ_id — 1×1 depthwise conv of ones ((1, 1, 1, c) HWIO grouped)."""
    return jnp.ones((1, 1, 1, c), dtype=dtype)


def _dw_to_full(w: jax.Array) -> jax.Array:
    """Expand a depthwise kernel (kh, kw, 1, c) to a full (kh, kw, c, c)."""
    kh, kw, _, c = w.shape
    eye = jnp.eye(c, dtype=w.dtype)                       # (c, c)
    return w[:, :, 0, :][:, :, None, :] * eye[None, None]  # (kh, kw, c, c)


def merge_conv_pair(w1: jax.Array, w2: jax.Array, *, stride1: int = 1,
                    dw1: bool = False, dw2: bool = False
                    ) -> tuple[jax.Array, bool]:
    """Merged kernel for ``conv2 ∘ conv1`` (correlation, VALID, HWIO).

    Returns ``(w_merged, merged_is_depthwise)``.  Merged kernel size is
    ``(k2 − 1)·stride1 + k1`` per spatial dim (paper Appendix A).  Only the
    depthwise∘depthwise composition stays depthwise.
    """
    both_dw = dw1 and dw2
    if dw1 and not both_dw:
        w1 = _dw_to_full(w1)
        dw1 = False
    if dw2 and not both_dw:
        w2 = _dw_to_full(w2)
        dw2 = False

    if both_dw:
        # per-channel 1-D composition over each spatial dim: correlate the
        # flipped second kernel over the (padded, dilated) first.
        c = w1.shape[-1]
        k1h, k1w = w1.shape[0], w1.shape[1]
        k2h, k2w = w2.shape[0], w2.shape[1]
        mh = (k2h - 1) * stride1 + k1h
        mw = (k2w - 1) * stride1 + k1w
        out = jnp.zeros((mh, mw, 1, c), w1.dtype)
        for u in range(k2h):
            for v in range(k2w):
                out = out.at[u * stride1:u * stride1 + k1h,
                             v * stride1:v * stride1 + k1w].add(
                    w1 * w2[u, v, 0, :][None, None, None, :])
        return out, True

    # General case.  Derivation (1-D, stride1=s):
    #   y1[m, p] = Σ_{c,u} x[c, s·p + u] · w1[u, c, m]
    #   y2[o, q] = Σ_{m,v} y1[m, q·s2 + v] · w2[v, m, o]
    #            = Σ_{c,s'} x[c, (s·s2)·q + s'] · wm[s', c, o],
    #   wm[s', c, o] = Σ_m Σ_{v·s + u = s'} w2[v, m, o] · w1[u, c, m].
    # I.e. a *convolution* of the kernels over space (contract m), with w2
    # spatially dilated by s.  Implemented as a correlation of w1 (as the
    # "image", batch = cin, features = mid) with the flipped w2.
    k1h, k1w, cin, mid = w1.shape
    k2h, k2w, mid2, cout = w2.shape
    assert mid == mid2, (w1.shape, w2.shape)
    lhs = jnp.transpose(w1, (2, 3, 0, 1))            # (cin, mid, k1h, k1w)
    rhs = jnp.flip(w2, axis=(0, 1))                  # flip spatial
    rhs = jnp.transpose(rhs, (3, 2, 0, 1))           # (cout, mid, k2h, k2w)
    pad_h = (k2h - 1) * stride1
    pad_w = (k2w - 1) * stride1
    out = lax.conv_general_dilated(
        lhs, rhs,
        window_strides=(1, 1),
        padding=((pad_h, pad_h), (pad_w, pad_w)),
        rhs_dilation=(stride1, stride1),
        dimension_numbers=("NCHW", "OIHW", "NCHW"),
    )                                                 # (cin, cout, mh, mw)
    return jnp.transpose(out, (2, 3, 0, 1)), False


def merge_conv_chain(weights, strides, depthwise_flags):
    """Fold a whole chain ``f_n ∘ … ∘ f_1`` into one kernel.

    Args:
      weights: list of HWIO kernels (depthwise ones as (kh, kw, 1, c)).
      strides: per-layer input strides.
      depthwise_flags: per-layer bool.

    Returns ``(w_merged, total_stride, merged_is_depthwise)``.
    """
    w, dw = weights[0], depthwise_flags[0]
    s_acc = strides[0]
    for wn, sn, dn in zip(weights[1:], strides[1:], depthwise_flags[1:]):
        w, dw = merge_conv_pair(w, wn, stride1=s_acc, dw1=dw, dw2=dn)
        s_acc *= sn
    return w, s_acc, dw


def merge_bias_through(w2: jax.Array, b1: jax.Array, b2: jax.Array | None,
                       dw2: bool = False) -> jax.Array:
    """Bias of ``conv2 ∘ (conv1 + b1)``: ``b2 + Σ_spatial w2 · b1``."""
    if dw2:
        contrib = jnp.sum(w2, axis=(0, 1))[0] * b1      # (c,)
    else:
        contrib = jnp.einsum("hwio,i->o", w2, b1)
    return contrib if b2 is None else b2 + contrib


def fuse_skip_add(w: jax.Array, depthwise: bool = False) -> jax.Array:
    """Fold ``x + conv(x)`` into one conv by adding a centred Dirac kernel.

    Requires odd kernel, stride 1, cin == cout (shape preserving) — exactly
    the condition under which the paper merges across a skip-addition.
    """
    kh, kw = w.shape[0], w.shape[1]
    assert kh % 2 == 1 and kw % 2 == 1, "Dirac fusion needs odd kernels"
    if depthwise:
        return w.at[kh // 2, kw // 2, 0, :].add(1.0)
    cin, cout = w.shape[2], w.shape[3]
    assert cin == cout, "skip-add fusion needs cin == cout"
    return w.at[kh // 2, kw // 2].add(jnp.eye(cin, dtype=w.dtype))


def fold_batchnorm(w: jax.Array, b: jax.Array | None, gamma, beta, mean, var,
                   eps: float = 1e-5) -> tuple[jax.Array, jax.Array]:
    """Inference-time BN folding: ``BN(conv(x))`` → one conv."""
    scale = gamma / jnp.sqrt(var + eps)                # (cout,)
    w_f = w * scale[None, None, None, :]
    b0 = jnp.zeros_like(mean) if b is None else b
    return w_f, beta + (b0 - mean) * scale


# ---------------------------------------------------------------------------
# Transformer rank-merge (DESIGN §2.1) — the TPU analogue of Eq. 1
# ---------------------------------------------------------------------------

def merge_linear_residual_pair(u1: jax.Array, v1: jax.Array,
                               u2: jax.Array, v2: jax.Array
                               ) -> tuple[jax.Array, jax.Array]:
    """Exact factored merge of ``(I + U2·V2) ∘ (I + U1·V1)``.

    Shapes: ``u: (d, r)``, ``v: (r, d)`` with the block acting as
    ``x → x + (x @ u) @ v`` on row vectors.  The merged rank is ``r1 + r2``
    (the Eq. 1 analogue) and the merge is exact — no SVD needed:

      ``x(I + U1V1)(I + U2V2) = x(I + [U1 | (I + U1V1)U2] · [V1 ; V2])``.
    """
    d = u1.shape[0]
    assert v1.shape[1] == d and u2.shape[0] == d and v2.shape[1] == d
    u2_eff = u2 + u1 @ (v1 @ u2)          # (d, r2): (I + U1V1)·U2
    u_m = jnp.concatenate([u1, u2_eff], axis=1)
    v_m = jnp.concatenate([v1, v2], axis=0)
    return u_m, v_m


def merge_linear_residual_chain(factors) -> tuple[jax.Array, jax.Array]:
    """Fold ``(I + U_nV_n)∘…∘(I + U_1V_1)`` into one ``(U, V)`` pair."""
    u, v = factors[0]
    for un, vn in factors[1:]:
        u, v = merge_linear_residual_pair(u, v, un, vn)
    return u, v


def truncate_rank(u: jax.Array, v: jax.Array, max_rank: int
                  ) -> tuple[jax.Array, jax.Array]:
    """SVD-truncate a factored residual map at ``max_rank``.

    When the additive rank exceeds ``d_model`` the factored form is wasteful;
    the paper's kernel-size cap has no analogue, but on TPU we cap at the
    numerical rank ``d`` (beyond-paper optimization, see EXPERIMENTS §Perf).
    """
    r = u.shape[1]
    if r <= max_rank:
        return u, v
    m = u @ v                                          # (d, d) exact product
    uu, ss, vv = jnp.linalg.svd(m, full_matrices=False)
    k = max_rank
    return uu[:, :k] * ss[:k][None, :], vv[:k, :]


def dense_residual(u: jax.Array, v: jax.Array) -> jax.Array:
    """Materialize ``I + U·V`` (used when rank ≥ d: one GEMM beats two)."""
    d = u.shape[0]
    return jnp.eye(d, dtype=u.dtype) + u @ v
