"""Serving: jitted chunked prefill, ``lax.scan`` decode, slot batching.

ONE protocol for every consumer of a one-token serve step — the original
stack (:func:`repro.train.step.make_serve_step`) and the artifact-backed
compressed executor (:func:`repro.runtime.executor.make_serve_step` /
:meth:`GraphExecutor.serve_step`) — so ``examples/serve_lm.py`` and
``benchmarks/bench_serve.py`` measure exactly the same thing for both
stacks.

Three layers, each built on the one below:

* :func:`serve_loop` — single-batch prefill + greedy decode.  Prefill is
  ONE jitted chunked call (a ``lax.scan`` over the prompt — not a Python
  dispatch per token) and decode is one jitted ``lax.scan`` that feeds
  each greedy argmax back in; the host touches the device twice, not
  ``P + N`` times.  :func:`serve_loop_pertoken` keeps the PR-4-era
  unjitted per-token loop as the dispatch-bound reference the serve
  bench compares against.
* :func:`generate_fused` — ONE scan over a slot batch with *per-slot*
  prompt lengths: while slot ``b`` still has prompt left the scan
  teacher-forces ``prompt[b, t]``, afterwards it feeds the slot's own
  previous greedy token — so a padded batch of ragged prompts runs
  prefill and decode in the same compiled program with no pad token
  ever entering a KV cache (exactness is tested against single-prompt
  serving).
* :func:`serve_requests` — the fixed-size slot scheduler: admit up to
  ``slots`` prompts per round into a padded batch, run the fused scan,
  retire the round, admit the next.  Under a mesh the slot axis is the
  'data' axis — many concurrent prompts decode data-parallel.

Every entry point takes ``rules=`` (a :class:`ShardingRules`) and traces
under it, so the same code serves one CPU device and a sharded mesh.

The greedy-argmax / prompt-encoding glue the example and the bench used
to duplicate lives here too: :func:`greedy_token`, :func:`random_prompts`,
:func:`decode_tok_s`.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
from jax import lax

from repro.sharding.rules import use_rules


# ---------------------------------------------------------------------------
# Shared glue (hoisted from examples/serve_lm.py + benchmarks/bench_serve.py)
# ---------------------------------------------------------------------------

def greedy_token(logits):
    """Greedy sampling: ``(B, S, V)`` logits → ``(B,)`` next-token ids."""
    return jnp.argmax(logits[:, -1], axis=-1)


def random_prompts(seed: int, batch: int, prompt_len: int, vocab_size: int):
    """The example/bench prompt encoding: ``(B, P)`` random token ids."""
    return jax.random.randint(jax.random.PRNGKey(seed), (batch, prompt_len),
                              0, vocab_size)


def ragged_prompts(seed: int, n: int, min_len: int, max_len: int,
                   vocab_size: int):
    """``n`` random prompts of random lengths in ``[min_len, max_len]`` —
    the scheduler-workload encoding (list of 1-D int32 id arrays; feed
    through :func:`pad_prompts`)."""
    import numpy as np

    if not 1 <= min_len <= max_len:
        raise ValueError(f"need 1 <= min_len <= max_len, got "
                         f"[{min_len}, {max_len}]")
    rng = np.random.RandomState(seed)
    return [jnp.asarray(rng.randint(0, vocab_size,
                                    size=rng.randint(min_len, max_len + 1)),
                        jnp.int32)
            for _ in range(n)]


def decode_tok_s(tokens: int, batch: int, seconds: float) -> float:
    """Decode throughput; guards the div by tiny smoke timings."""
    return tokens * batch / max(seconds, 1e-9)


# ---------------------------------------------------------------------------
# Jitted single-batch serve loop (chunked prefill + scan decode)
# ---------------------------------------------------------------------------

def _prefill_chunk(step, params, cache, prompt):
    """One chunked prefill call: scan the step over the prompt axis.

    Returns the last-position logits ``(B, V)`` and the filled cache.
    """
    def body(cache, tok):
        logits, cache = step(params, cache, {"tokens": tok[:, None]})
        return cache, logits[:, -1]
    cache, logits = lax.scan(body, cache, prompt.T)
    return logits[-1], cache


def _decode_scan(step, params, cache, tok0, n: int):
    """Greedy decode scan: ``n`` tokens from ``tok0`` ``(B,)`` on."""
    def body(carry, _):
        tok, cache = carry
        logits, cache = step(params, cache, {"tokens": tok[:, None]})
        nxt = greedy_token(logits)
        return (nxt, cache), nxt
    (_, cache), toks = lax.scan(body, (tok0, cache), None, length=n)
    return toks.T, cache                                   # (B, n)


def serve_loop(step, params, cache, prompt, tokens: int, *, rules=None,
               warm: bool = True):
    """Drive ``step(params, cache, batch) → (logits, cache)``.

    Prefill is ONE jitted chunked call over the whole prompt; decode is
    ONE jitted ``lax.scan`` issuing ``tokens - 1`` greedy steps.  With
    ``warm`` (the benchmarking contract) both programs run once
    unmeasured first, so ``(prefill_s, decode_s)`` report steady-state
    serving, not compilation; pass ``warm=False`` to serve without the
    extra pass.  Returns
    ``(prefill_s, decode_s, last_logits (B, V), seqs (B, tokens))``.
    """
    prefill = jax.jit(lambda p, c, t: _prefill_chunk(step, p, c, t))
    decode = jax.jit(lambda p, c, t0: _decode_scan(step, p, c, t0,
                                                   tokens - 1))
    with use_rules(rules):
        if warm:
            jax.block_until_ready(prefill(params, cache, prompt))
        t0 = time.perf_counter()
        logits, cache = prefill(params, cache, prompt)
        jax.block_until_ready(logits)
        prefill_s = time.perf_counter() - t0

        tok = greedy_token(logits[:, None])
        if warm:
            jax.block_until_ready(decode(params, cache, tok))
        t0 = time.perf_counter()
        out, _ = decode(params, cache, tok)
        jax.block_until_ready(out)
        decode_s = time.perf_counter() - t0
    seqs = jnp.concatenate([tok[:, None], out], axis=1)
    return prefill_s, decode_s, logits, seqs


def serve_loop_pertoken(step, params, cache, prompt, tokens: int, *,
                        rules=None):
    """The PR-4 reference loop: a host round-trip per token, per prompt
    position (pass a ``jax.jit``-ed step to make each one exactly one
    XLA dispatch).  Kept so the serve bench can report how much the
    chunked/scan protocol buys on the same step."""
    logits = None
    with use_rules(rules):
        t0 = time.perf_counter()
        for t in range(prompt.shape[1]):
            logits, cache = step(params, cache,
                                 {"tokens": prompt[:, t:t + 1]})
        jax.block_until_ready(logits)
        prefill_s = time.perf_counter() - t0
        last = logits[:, -1]

        tok = greedy_token(logits)[:, None]
        out = [tok]
        t0 = time.perf_counter()
        for _ in range(tokens - 1):
            logits, cache = step(params, cache, {"tokens": tok})
            tok = greedy_token(logits)[:, None]
            out.append(tok)
        jax.block_until_ready(tok)
        decode_s = time.perf_counter() - t0
    return prefill_s, decode_s, last, jnp.concatenate(out, axis=1)


# ---------------------------------------------------------------------------
# Fused ragged-prompt generation (one scan = prefill + decode)
# ---------------------------------------------------------------------------

def generate_fused(step, params, cache, prompts, lengths, tokens: int):
    """One scan over a padded slot batch with per-slot prompt lengths.

    ``prompts``: ``(B, P)`` right-padded ids; ``lengths``: ``(B,)`` with
    ``1 <= lengths[b] <= P``.  At scan step ``t`` slot ``b`` consumes
    ``prompts[b, t]`` while ``t < lengths[b]`` (teacher-forced prefill)
    and its own previous greedy token afterwards (decode) — pad ids are
    never fed, so every slot's cache holds exactly its own sequence and
    the result matches serving that prompt alone.  Returns
    ``(gen (B, tokens), cache)``; the cache must cover ``P + tokens``
    positions.
    """
    prompts = prompts.astype(jnp.int32)    # match the argmax carry dtype
    B, P = prompts.shape
    steps = P + tokens - 1
    toks_in = jnp.pad(prompts, ((0, 0), (0, steps - P)))   # (B, steps)

    def body(carry, xs):
        prev, cache = carry
        tok_t, t = xs
        inp = jnp.where(t < lengths, tok_t, prev)
        logits, cache = step(params, cache, {"tokens": inp[:, None]})
        nxt = greedy_token(logits)
        return (nxt, cache), nxt

    init = (jnp.zeros((B,), prompts.dtype), cache)
    (_, cache), samples = lax.scan(
        body, init, (toks_in.T, jnp.arange(steps)))
    # slot b's generation starts at the step that consumed its last
    # prompt token: samples[lengths[b] - 1 + i, b]
    idx = (lengths - 1)[:, None] + jnp.arange(tokens)[None, :]
    gen = jnp.take_along_axis(samples.T, idx, axis=1)
    return gen, cache


# ---------------------------------------------------------------------------
# Fixed-slot batched request scheduler
# ---------------------------------------------------------------------------

def pad_prompts(prompts, pad_to: int | None = None):
    """Encode a list of 1-D id arrays as ``(R, P)`` padded ids + lengths.

    ``pad_to`` pins ``P`` (e.g. to keep one compiled scheduler program
    across calls); it must cover the longest prompt.
    """
    lengths = jnp.asarray([len(p) for p in prompts], jnp.int32)
    longest = int(lengths.max())
    P = longest if pad_to is None else pad_to
    if P < longest:
        raise ValueError(f"pad_to={pad_to} shorter than the longest "
                         f"prompt ({longest} tokens)")
    mat = jnp.stack([
        jnp.pad(jnp.asarray(p, jnp.int32), (0, P - len(p)))
        for p in prompts])
    return mat, lengths


def serve_requests(step, params, make_cache, prompts, lengths=None, *,
                   tokens: int, slots: int | None = None, rules=None,
                   warm: bool = True):
    """Serve many prompts through fixed-size slot batching.

    ``prompts``: ``(R, P)`` padded ids (or a list of 1-D id arrays, in
    which case ``lengths`` is derived).  Up to ``slots`` prompts are
    admitted per round into a padded batch; one jitted
    :func:`generate_fused` program serves every round (short final
    rounds re-admit slot 0's prompt as filler and drop the duplicate
    results), then the round retires and the next is admitted.
    ``make_cache(batch_size, seq_len)`` builds a fresh per-round cache.

    Under mesh ``rules`` the slot axis is the 'data' mesh axis — rounds
    decode data-parallel.  Returns ``(gen (R, tokens), seconds)`` where
    ``seconds`` is steady-state wall clock with ``warm`` (one unmeasured
    pass over round 0's shapes first — the benchmarking contract; pass
    ``warm=False`` to serve without it).
    """
    if lengths is None:
        if getattr(prompts, "ndim", None) == 2:
            # a padded matrix has no recoverable lengths — deriving them
            # here would silently teacher-force pad tokens into caches
            raise ValueError("pass lengths= with a padded (R, P) matrix "
                             "(or pass the list of 1-D prompts)")
        prompts, lengths = pad_prompts(prompts)
    R, P = prompts.shape
    slots = min(slots or R, R)

    fused = jax.jit(
        lambda p, c, pr, ln: generate_fused(step, p, c, pr, ln, tokens))

    def round_batch(start):
        # short final round: re-admit request 0 as filler, results dropped
        idx = [start + i if start + i < R else 0 for i in range(slots)]
        return prompts[jnp.asarray(idx)], lengths[jnp.asarray(idx)]

    outs = []
    with use_rules(rules):
        if warm:
            pr0, ln0 = round_batch(0)
            jax.block_until_ready(
                fused(params, make_cache(slots, P + tokens), pr0, ln0))
        t0 = time.perf_counter()
        for start in range(0, R, slots):
            pr, ln = round_batch(start)
            cache = make_cache(slots, P + tokens)
            gen, _ = fused(params, cache, pr, ln)
            outs.append(gen[: min(slots, R - start)])
        jax.block_until_ready(outs)
        seconds = time.perf_counter() - t0
    return jnp.concatenate(outs, axis=0), seconds
