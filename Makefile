# Convenience targets; everything pins JAX_PLATFORMS=cpu (see
# scripts/verify.sh for why).

PY := python
ENV := JAX_PLATFORMS=cpu PYTHONPATH=src

.PHONY: verify test bench bench-dp bench-tables bench-serve bench-smoke \
	fault-smoke serve-fault-smoke dist-fault-smoke

verify:
	bash scripts/verify.sh

test:
	$(ENV) $(PY) -m pytest -x -q

bench:
	$(ENV) $(PY) -m benchmarks.run

bench-dp:
	$(ENV) $(PY) -m benchmarks.bench_dp

bench-tables:
	$(ENV) $(PY) -m benchmarks.bench_tables

bench-serve:
	$(ENV) $(PY) -m benchmarks.bench_serve

# Seconds-scale regression gates (also part of `make verify`): probe-
# engine parity/accounting + serving-path artifact round-trip, KV-cache
# decode parity, and the sharded executor ≡ single-device gate on 8
# forced host devices — without the slow timing baselines.
bench-smoke:
	$(ENV) $(PY) -m benchmarks.bench_tables --smoke
	$(ENV) $(PY) -m benchmarks.bench_serve --smoke
	$(ENV) $(PY) -m benchmarks.bench_serve --smoke --quantize w8a8
	$(ENV) XLA_FLAGS=--xla_force_host_platform_device_count=8 \
		$(PY) -m benchmarks.bench_serve --smoke --mesh --model-par 2

# Crash-safety gate (also part of `make verify`): SIGKILL a journaled
# table build in a child process, resume it, and require the resumed
# tables to be bit-identical to an uninterrupted build.
fault-smoke:
	$(ENV) $(PY) -m repro.testing.faults --smoke

# Overload-safety gate (also part of `make verify`): the continuous
# serve engine under a REPRO_FAULTS delayed-arrival + per-request NaN +
# straggler-chunk spec — dispositions asserted, surviving requests
# bit-identical to the fault-free run.
serve-fault-smoke:
	$(ENV) $(PY) -m repro.testing.faults --serve-smoke

# Distributed-build gate (also part of `make verify`): 2 subprocess
# workers, worker 0 SIGKILLed mid-bucket; a survivor steals the expired
# lease and the merged tables must be bit-identical to a single-process
# build.  Plus the serve-failover replay smoke.
dist-fault-smoke:
	$(ENV) $(PY) -m repro.launch.distributed --fault-smoke
