"""Hypothesis shim for hosts without the ``hypothesis`` package.

The property tests in this repo use a small, fixed subset of the hypothesis
API: ``@given`` with keyword strategies, ``@settings(max_examples=..,
deadline=None)``, and the ``integers`` / ``floats`` / ``sampled_from``
strategies.  When hypothesis is installed (see requirements-dev.txt) we
re-export the real thing; otherwise this module provides a deterministic
fallback that draws ``max_examples`` seeded pseudo-random examples per test.
The fallback trades hypothesis's shrinking and edge-case bias for zero
dependencies — every draw is reproducible from the test's qualified name, so
failures are stable across runs.
"""
from __future__ import annotations

try:                                        # pragma: no cover - thin re-export
    from hypothesis import given, settings, strategies as st  # noqa: F401
    HAVE_HYPOTHESIS = True
except ImportError:
    import functools
    import random

    HAVE_HYPOTHESIS = False
    _DEFAULT_MAX_EXAMPLES = 20

    class _Strategy:
        def __init__(self, draw):
            self._draw = draw

        def example_for(self, rng: random.Random):
            return self._draw(rng)

    class _strategies:
        @staticmethod
        def integers(min_value, max_value):
            return _Strategy(lambda rng: rng.randint(min_value, max_value))

        @staticmethod
        def floats(min_value, max_value):
            # log-uniform when the range spans decades (matches how the
            # tests use it: scale factors 1e-3..1e3), uniform otherwise.
            import math
            if min_value > 0 and max_value / min_value > 1e3:
                lo, hi = math.log(min_value), math.log(max_value)
                return _Strategy(lambda rng: math.exp(rng.uniform(lo, hi)))
            return _Strategy(lambda rng: rng.uniform(min_value, max_value))

        @staticmethod
        def sampled_from(elements):
            seq = list(elements)
            return _Strategy(lambda rng: rng.choice(seq))

        @staticmethod
        def booleans():
            return _Strategy(lambda rng: rng.random() < 0.5)

    st = _strategies()

    def settings(max_examples: int = _DEFAULT_MAX_EXAMPLES, **_ignored):
        def deco(fn):
            fn._shim_max_examples = max_examples
            return fn
        return deco

    def given(**strategies):
        def deco(fn):
            @functools.wraps(fn)
            def wrapper():
                n = getattr(fn, "_shim_max_examples", _DEFAULT_MAX_EXAMPLES)
                for i in range(n):
                    rng = random.Random(f"{fn.__module__}.{fn.__qualname__}:{i}")
                    kwargs = {name: strat.example_for(rng)
                              for name, strat in sorted(strategies.items())}
                    try:
                        fn(**kwargs)
                    except Exception as e:
                        raise AssertionError(
                            f"falsifying example ({i + 1}/{n}): "
                            f"{fn.__name__}({kwargs!r})") from e
            # pytest resolves fixture names via inspect.signature, which
            # follows __wrapped__ — drop it so the strategy kwargs are not
            # mistaken for fixtures.
            del wrapper.__wrapped__
            return wrapper
        return deco
