"""Probe-engine certification: batched table construction must be
bit-identical to the sequential reference under the analytic oracle
(entries, Pareto drops, DP plans), within tolerance under the wall-clock
oracle, and the vmapped Dirac-masked importance batch must reproduce the
scalar Eq. 4 fine-tune exactly.  Plus: cache round-trips, mixed
conv/attn/pool barrier hosts, and the pmap-sharded fine-tune path."""
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.testing.subproc import run_code
from repro.core import (AnalyticTPUOracle, ImportanceSpec, WallClockOracle,
                        accuracy_perf, build_tables, compress,
                        layer_latencies, original_latency, solve_dp,
                        table_cache, xent_loss)
from repro.core.importance import _adam_finetune, adam_finetune_batched
from repro.models import cnn, cnn_host, zoo


def _host(net, key=0, batch=4):
    params = cnn.init_params(net, jax.random.PRNGKey(key))
    return cnn_host.CNNHost(net, params, batch=batch), params


@pytest.fixture(scope="module")
def resnet_host():
    return _host(zoo.tiny_resnet(num_classes=4, in_hw=8, width=4,
                                 blocks=(2,)))


@pytest.fixture(scope="module")
def unet_host():
    """Mixed-barrier chain: convs + attn + pool + upsample + GN."""
    return _host(zoo.tiny_unet(in_hw=8, base=4, norm="gn", attn=True))


def _spec(net, steps=2):
    x = jax.random.normal(jax.random.PRNGKey(1), (8, net.in_hw, net.in_hw, 3))
    y = jax.random.randint(jax.random.PRNGKey(2), (8,), 0, 4)
    return ImportanceSpec(loss_fn=xent_loss, perf_fn=accuracy_perf,
                          train_batches=[(x, y)], eval_batches=[(x, y)],
                          steps=steps, lr=1e-3)


# ---------------------------------------------------------------------------
# Analytic parity — bit-identical entries, drops, and DP plans
# ---------------------------------------------------------------------------

def test_analytic_bit_identical_and_plan_identical(resnet_host):
    host, params = resnet_host
    bat = build_tables(host, engine="batched")
    seq = build_tables(host, engine="sequential")
    assert bat.entries == seq.entries
    assert bat.num_pruned == seq.num_pruned
    assert bat.stats.num_latency_buckets < bat.stats.num_latency_probes
    L = len(host.descs())
    T0 = 0.7 * original_latency(host)
    rb = solve_dp(L, bat.fn(), T0, 100, original_k=host.original_k)
    rs = solve_dp(L, seq.fn(), T0, 100, original_k=host.original_k)
    assert rb.plan == rs.plan and rb.objective == rs.objective


def test_compress_engines_agree_analytic(resnet_host):
    host, params = resnet_host
    rb = compress(host, budget_ratio=0.7, P=100, engine="batched")
    rs = compress(host, budget_ratio=0.7, P=100, engine="sequential")
    assert rb.plan == rs.plan
    assert rb.original_latency == rs.original_latency


def test_layer_latencies_bucketed(resnet_host):
    host, params = resnet_host
    oracle = AnalyticTPUOracle()
    lb = layer_latencies(host, oracle, engine="batched")
    ls = layer_latencies(host, oracle, engine="sequential")
    assert lb == ls
    assert len(lb) == len(host.descs())


# ---------------------------------------------------------------------------
# Mixed conv/attn/pool/upsample barriers
# ---------------------------------------------------------------------------

def test_mixed_barrier_host_bit_identical(unet_host):
    host, params = unet_host
    bat = build_tables(host, engine="batched")
    seq = build_tables(host, engine="sequential")
    assert bat.entries == seq.entries
    # barrier kinds land in distinct buckets but still dedup across depth
    assert bat.stats.num_latency_buckets < bat.stats.num_latency_probes


def test_mixed_barrier_wallclock_runs(unet_host):
    host, params = unet_host
    oracle = WallClockOracle(warmup=1, iters=2, groups=1)
    tb = build_tables(host, latency_oracle=oracle, params=params,
                      engine="batched")
    assert tb.stats.num_compiles == tb.stats.num_latency_buckets
    assert tb.stats.num_timings == tb.stats.num_latency_buckets
    assert all(lat > 0.0 for row in tb.entries.values()
               for _, lat, _ in row.values())


# ---------------------------------------------------------------------------
# Wall-clock tolerance
# ---------------------------------------------------------------------------

def test_wallclock_within_tolerance(resnet_host):
    host, params = resnet_host
    oracle = WallClockOracle(warmup=2, iters=10, groups=2)
    bat = build_tables(host, latency_oracle=oracle, params=params,
                       engine="batched", prune=False)
    seq = build_tables(host, latency_oracle=oracle, params=params,
                       engine="sequential", prune=False)
    assert bat.stats.num_compiles == bat.stats.num_latency_buckets
    assert seq.stats.num_compiles == seq.stats.num_latency_probes
    for sp, row in seq.entries.items():
        for k, (_, lat_s, _) in row.items():
            lat_b = bat.entries[sp][k][1]
            # CI timing jitter on ~100µs probes is large; this bounds
            # gross attribution errors (wrong bucket, wrong units), not
            # timer noise.
            assert lat_b > 0.0
            assert lat_b / lat_s < 20.0 and lat_s / lat_b < 20.0


# ---------------------------------------------------------------------------
# Batched importance — exact vs the scalar fine-tune
# ---------------------------------------------------------------------------

def test_importance_batched_matches_sequential(resnet_host):
    host, params = resnet_host
    spec = _spec(host.net)
    base = accuracy_perf(lambda p, x: cnn.apply_replaced(host.net, p, x),
                         params, spec.eval_batches)
    bat = build_tables(host, importance=spec, base_perf=base,
                       engine="batched", prune=False)
    seq = build_tables(host, importance=spec, base_perf=base,
                       engine="sequential", prune=False)
    assert bat.stats.num_importance_batches > 0
    # singleton k-buckets route through the scalar path by design
    for sp, row in seq.entries.items():
        for k, (imp_s, _, _) in row.items():
            np.testing.assert_allclose(bat.entries[sp][k][0], imp_s,
                                       rtol=1e-6, atol=1e-7)


def test_importance_normed_host_falls_back(resnet_host):
    """BN inside a span changes the fine-tune parametrization — the host
    must decline the batch and the engine must fall back, still matching
    the sequential reference."""
    host, params = _host(zoo.tiny_resnet(num_classes=4, in_hw=8, width=4,
                                         blocks=(1,), norm="bn"))
    spec = _spec(host.net)
    base = accuracy_perf(lambda p, x: cnn.apply_replaced(host.net, p, x),
                         params, spec.eval_batches)
    bat = build_tables(host, importance=spec, base_perf=base,
                       engine="batched", prune=False)
    seq = build_tables(host, importance=spec, base_perf=base,
                       engine="sequential", prune=False)
    assert bat.stats.num_importance_sequential > 0
    for sp, row in seq.entries.items():
        for k, (imp_s, _, _) in row.items():
            np.testing.assert_allclose(bat.entries[sp][k][0], imp_s,
                                       rtol=1e-6, atol=1e-7)


def test_adam_finetune_batched_equals_scalar(resnet_host):
    """The vmapped masked Adam on a singleton batch reproduces the scalar
    fine-tune leaf-for-leaf (the mask is all-ones here)."""
    host, params = resnet_host
    spec = _spec(host.net, steps=3)
    apply_fn = lambda p, x: cnn.apply_replaced(host.net, p, x)
    scalar = _adam_finetune(apply_fn, params, spec)
    stacked = jax.tree.map(lambda x: x[None], params)
    batched = adam_finetune_batched(apply_fn, stacked, spec)
    jax.tree.map(lambda a, b: np.testing.assert_allclose(
        a, b[0], rtol=1e-6, atol=1e-7), scalar, batched)


def test_pmap_sharded_finetune_subprocess():
    """With >1 local device the batched fine-tune pmap-shards the probe
    axis; results must match the single-device vmap path."""
    code = textwrap.dedent("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.core.importance import (ImportanceSpec, _adam_finetune,
                                           adam_finetune_batched, xent_loss,
                                           accuracy_perf)
        from repro.models import cnn, zoo
        assert jax.local_device_count() == 2
        net = zoo.tiny_resnet(num_classes=4, in_hw=8, width=4, blocks=(1,))
        params = cnn.init_params(net, jax.random.PRNGKey(0))
        x = jax.random.normal(jax.random.PRNGKey(1), (8, 8, 8, 3))
        y = jax.random.randint(jax.random.PRNGKey(2), (8,), 0, 4)
        spec = ImportanceSpec(loss_fn=xent_loss, perf_fn=accuracy_perf,
                              train_batches=[(x, y)], eval_batches=[(x, y)],
                              steps=2, lr=1e-3)
        apply_fn = lambda p, xx: cnn.apply_replaced(net, p, xx)
        # 3 lanes on 2 devices: exercises padding + unpadding
        stacked = jax.tree.map(lambda a: jnp.stack([a, a * 1.5, a * 0.5]),
                               params)
        out = adam_finetune_batched(apply_fn, stacked, spec)
        for lane, scale in enumerate((1.0, 1.5, 0.5)):
            ref = _adam_finetune(
                apply_fn, jax.tree.map(lambda a: a * scale, params), spec)
            jax.tree.map(lambda r, o: np.testing.assert_allclose(
                r, o[lane], rtol=2e-5, atol=2e-6), ref,
                jax.tree.map(lambda t: t, out))
        print("PMAP_FT_OK")
    """)
    r = run_code(code, devices=2, timeout=300)
    assert "PMAP_FT_OK" in r.stdout, r.stdout + r.stderr


# ---------------------------------------------------------------------------
# On-disk table cache
# ---------------------------------------------------------------------------

def test_cache_roundtrip_hit(resnet_host, tmp_path):
    host, params = resnet_host
    cold = build_tables(host, engine="batched", cache_dir=str(tmp_path))
    warm = build_tables(host, engine="batched", cache_dir=str(tmp_path))
    assert not cold.stats.cache_hit and warm.stats.cache_hit
    assert warm.entries == cold.entries
    assert warm.num_pruned == cold.num_pruned


def test_cache_serves_across_engines(resnet_host, tmp_path):
    """Batched and sequential are certified to agree, so either build may
    serve the other's key."""
    host, params = resnet_host
    cold = build_tables(host, engine="sequential", cache_dir=str(tmp_path))
    warm = build_tables(host, engine="batched", cache_dir=str(tmp_path))
    assert warm.stats.cache_hit
    assert warm.entries == cold.entries


def test_cache_miss_on_param_and_oracle_change(tmp_path):
    net = zoo.tiny_resnet(num_classes=4, in_hw=8, width=4, blocks=(2,))
    host0, _ = _host(net, key=0)
    build_tables(host0, engine="batched", cache_dir=str(tmp_path))
    host1, _ = _host(net, key=1)          # different parameter content
    t1 = build_tables(host1, engine="batched", cache_dir=str(tmp_path))
    assert not t1.stats.cache_hit
    t2 = build_tables(host0, engine="batched", cache_dir=str(tmp_path),
                      latency_oracle=AnalyticTPUOracle(op_overhead=2e-6))
    assert not t2.stats.cache_hit          # oracle config is in the key
    t3 = build_tables(host0, engine="batched", cache_dir=str(tmp_path),
                      method="depth")
    assert not t3.stats.cache_hit          # method is in the key


def test_cache_disabled_for_unnamed_importance(resnet_host, tmp_path):
    """Measured ImportanceSpecs close over arbitrary callables — without
    an explicit cache_token the build must not be cached."""
    host, params = resnet_host
    spec = _spec(host.net)
    key = table_cache.cache_key(host, AnalyticTPUOracle(), "layermerge",
                                spec)
    assert key is None
    named = ImportanceSpec(**{**spec.__dict__, "cache_token": "toy-v1"})
    key2 = table_cache.cache_key(host, AnalyticTPUOracle(), "layermerge",
                                 named)
    assert key2 is not None


def test_cache_torn_file_is_miss(resnet_host, tmp_path):
    host, params = resnet_host
    oracle = AnalyticTPUOracle()
    build_tables(host, engine="batched", cache_dir=str(tmp_path))
    key = table_cache.cache_key(host, oracle, "layermerge", "magnitude")
    path = tmp_path / f"tables_{key}.json"
    path.write_text(path.read_text()[: 40])     # torn write
    again = build_tables(host, engine="batched", cache_dir=str(tmp_path))
    assert not again.stats.cache_hit            # corrupt entry → rebuild
    healed = build_tables(host, engine="batched", cache_dir=str(tmp_path))
    assert healed.stats.cache_hit               # rebuild re-published
