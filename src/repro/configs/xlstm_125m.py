"""xlstm-125m [arXiv:2405.04517; unverified] — sLSTM + mLSTM blocks.

xLSTM[7:1]-flavoured 12-layer stack: sLSTM at positions 3 and 9 (0-based),
mLSTM elsewhere; no separate FFN sublayer (d_ff=0 — the blocks carry their
own projections).

Note: our m/sLSTM blocks are the simplified variant without the paper's 2×
up-projection, so the assigned geometry lands at ~74M params (the temporal
recurrences, chunked-parallel forms and state semantics are faithful; see
models/xlstm.py and DESIGN §2.3).
"""
from .base import ArchConfig

_pattern = tuple("slstm" if i in (3, 9) else "mlstm" for i in range(12))

CONFIG = ArchConfig(
    name="xlstm-125m", family="ssm",
    num_layers=12, d_model=768, num_heads=4, num_kv_heads=4,
    d_ff=0, vocab_size=50304,
    temporal_pattern=_pattern, rope_kind="none",
    tie_embeddings=True,
    source="arXiv:2405.04517; sLSTM@{3,9}, mLSTM elsewhere",
)
