"""Distributed table-build certification (ISSUE 8 acceptance bars).

* Lease protocol: atomic claims, renewal, expiry-driven stealing with
  epoch bumps and read-back verification, done markers.
* Merge: deterministic first-wins shard merge, corrupt-record counting,
  repair of done-marked items whose shard evidence is missing.
* Bit-identity: 2- and 4-worker subprocess builds produce tables
  bit-identical to the sequential single-process reference — including
  a worker SIGKILLed mid-bucket whose lease is reassigned.
* Publish gating: a non-zero process index writes NO artifact, cache,
  journal, or bench file (the at-most-once publish contract).
"""
import json
import os
import time

import pytest

from repro.core import build_tables, dist_build_tables, table_cache
from repro.core.dist_build import (DistBuildError, LeaseStore, ShardJournal,
                                   latency_work_items, merge_shards,
                                   resolve_host_spec, write_manifest)
from repro.launch import distributed as dist
from repro.testing import faults
from repro.testing.hosts import tiny_resnet_host

HOST_SPEC = {"factory": "repro.testing.hosts:tiny_resnet_host",
             "kwargs": {}}


@pytest.fixture(scope="module")
def smoke_host():
    return tiny_resnet_host()


@pytest.fixture(scope="module")
def reference(smoke_host):
    host, params = smoke_host
    return build_tables(host, params=params)


# ---------------------------------------------------------------------------
# Lease protocol
# ---------------------------------------------------------------------------

def test_lease_claim_renew_release(tmp_path):
    a = LeaseStore(str(tmp_path), "w0", lease_s=30.0)
    b = LeaseStore(str(tmp_path), "w1", lease_s=30.0)
    got, stolen = a.claim(0)
    assert got and stolen is None
    # a live foreign lease cannot be claimed
    assert b.claim(0) == (False, None)
    # re-claiming our own lease renews it
    assert a.claim(0) == (True, None)
    assert a.renew(0)
    assert b.holder(0) == "w0"
    # release is owner-only
    b.release(0)
    assert a.holder(0) == "w0"
    a.release(0)
    assert a.holder(0) is None
    assert b.claim(0) == (True, None)


def test_lease_expiry_steal_and_epoch(tmp_path):
    a = LeaseStore(str(tmp_path), "w0", lease_s=0.05)
    b = LeaseStore(str(tmp_path), "w1", lease_s=30.0)
    assert a.claim(3) == (True, None)
    time.sleep(0.1)                          # w0's lease expires
    got, stolen = b.claim(3)
    assert got and stolen == "w0"
    rec = json.load(open(os.path.join(str(tmp_path), "leases", "3.json")))
    assert rec["owner"] == "w1" and rec["epoch"] == 2
    # the loser notices the steal on renew
    assert not a.renew(3)


def test_done_markers(tmp_path):
    s = LeaseStore(str(tmp_path), "w0", lease_s=30.0)
    assert not s.is_done(1)
    s.mark_done(1)
    assert s.is_done(1)
    assert s.count_done(3) == 1


# ---------------------------------------------------------------------------
# Shards and merge
# ---------------------------------------------------------------------------

def test_merge_shards_first_wins_and_corrupt(tmp_path):
    wd = str(tmp_path)
    w0 = ShardJournal(wd, "w0")
    w1 = ShardJournal(wd, "w1")
    w0.put("a", 1.0, "measured")
    w1.put("a", 2.0, "measured")             # duplicate: w0 wins
    w1.put("b", 3.0, "quarantined")
    w1.event("steal", item="b", id=1, prev="w0")
    with open(os.path.join(wd, "shards", "w1.jsonl"), "ab") as f:
        f.write(b"#garbled journal record#\n")
    records, events, corrupt = merge_shards(wd, ["w0", "w1"])
    assert records["a"] == (1.0, "measured", "w0")
    assert records["b"] == (3.0, "quarantined", "w1")
    assert corrupt == 1
    assert events == [{"evt": "steal", "item": "b", "id": 1, "prev": "w0",
                       "shard": "w1"}]
    # reversed order flips the winner: the order IS the determinism
    rev, _, _ = merge_shards(wd, ["w1", "w0"])
    assert rev["a"] == (2.0, "measured", "w1")


def test_manifest_idempotent_and_drift_loud(tmp_path, smoke_host):
    host, _params = smoke_host
    items = latency_work_items(host)
    wd = str(tmp_path)
    m1 = write_manifest(wd, "k1", items, engine="batched",
                        method="layermerge", host_fp="fp")
    m2 = write_manifest(wd, "k1", items, engine="batched",
                        method="layermerge", host_fp="fp")
    assert m1 == m2
    with pytest.raises(DistBuildError, match="different build"):
        write_manifest(wd, "k2", items, engine="batched",
                       method="layermerge", host_fp="fp")


def test_host_spec_roundtrip_same_fingerprint(smoke_host):
    host, _params = smoke_host
    rebuilt, _p = resolve_host_spec(HOST_SPEC)
    assert rebuilt.fingerprint() == host.fingerprint()
    with pytest.raises(DistBuildError, match="module:function"):
        resolve_host_spec({"factory": "nonsense"})


def test_worker_env_spec_translation():
    with faults.inject(
            faults.Fault("dist.item", "kill-worker", nth=2, widx=0),
            faults.Fault("dist.claim", "stall-worker", seconds=0.5,
                         widx=1),
            faults.Fault("", "corrupt-shard", widx=1)):
        assert faults.worker_env_spec(0) == "exit@dist.item:2x1"
        assert faults.worker_env_spec(1) == \
            "delay@dist.claim:1x1~0.5;garble@dist.shard.append:1x1"
        assert faults.worker_env_spec(2) is None
        # worker-targeted rules NEVER fire in the planning process
        faults.hit("dist.item")
        faults.hit("dist.item")
    assert faults.worker_env_spec(0) is None  # no active plan


# ---------------------------------------------------------------------------
# Bit-identity: subprocess fan-out vs sequential reference
# ---------------------------------------------------------------------------

def _dist(host, params, cache_dir, workers, **kw):
    return dist_build_tables(host, params=params, cache_dir=str(cache_dir),
                             workers=workers, host_spec=HOST_SPEC, **kw)


@pytest.mark.parametrize("workers", [2, 4])
def test_clean_fanout_bit_identical(smoke_host, reference, tmp_path,
                                    workers):
    host, params = smoke_host
    tables, rep = _dist(host, params, tmp_path, workers, lease_s=10.0)
    assert tables.entries == reference.entries
    assert tables.num_pruned == reference.num_pruned
    assert tables.provenance == reference.provenance
    assert rep.dead_workers == []
    assert not rep.cache_hit
    assert sum(rep.completed_by.values()) == rep.items
    # the published cache now serves a hit
    _t2, rep2 = _dist(host, params, tmp_path, workers)
    assert rep2.cache_hit


def test_sigkilled_worker_lease_reassigned(smoke_host, reference,
                                           tmp_path):
    """ISSUE acceptance: worker 0 dies mid-bucket (holding a lease, no
    result); worker 1 steals the expired lease, and the merged tables
    are bit-identical to the sequential build."""
    host, params = smoke_host
    with faults.inject(faults.Fault("dist.item", "kill-worker", nth=2,
                                    widx=0)):
        tables, rep = _dist(host, params, tmp_path, 2, lease_s=0.5,
                            serial_spawn=True)
    assert 0 in rep.dead_workers
    assert rep.reassigned, "the killed worker's lease was never stolen"
    assert tables.entries == reference.entries
    assert tables.num_pruned == reference.num_pruned
    assert tables.provenance == reference.provenance


def test_corrupt_shard_records_repaired(smoke_host, reference, tmp_path):
    """Garbled shard lines are counted, never trusted: the coordinator
    re-executes those items (repair) and the tables stay bit-identical."""
    host, params = smoke_host
    with faults.inject(faults.Fault("", "corrupt-shard", nth=1, times=2,
                                    widx=0)):
        tables, rep = _dist(host, params, tmp_path, 2, lease_s=10.0)
    assert rep.corrupt_records >= 1
    assert rep.repaired, "garbled records were not re-executed"
    assert tables.entries == reference.entries
    assert tables.provenance == reference.provenance


def test_relative_work_dir_from_foreign_cwd(smoke_host, reference,
                                            tmp_path, monkeypatch):
    """Workers run with cwd=REPO_ROOT; a RELATIVE coordinator cache/work
    dir must still reach them (regression: every worker died waiting for
    a manifest that lived under the coordinator's cwd), and each worker
    leaves a log file for post-mortems."""
    from repro.core.dist_build import worker_log_path

    host, params = smoke_host
    monkeypatch.chdir(tmp_path)
    tables, rep = _dist(host, params, "cache", 2, work_dir="wd",
                        keep_work_dir=True, lease_s=10.0)
    assert tables.entries == reference.entries
    assert rep.dead_workers == []
    assert sum(rep.completed_by.values()) == rep.items
    assert rep.coordinator_items == 0
    for w in range(2):
        assert os.path.exists(worker_log_path(str(tmp_path / "wd"), w))


def test_workers_zero_degenerates_to_local(smoke_host, reference,
                                           tmp_path):
    host, params = smoke_host
    tables, rep = dist_build_tables(host, params=params,
                                    cache_dir=str(tmp_path), workers=0)
    assert tables.entries == reference.entries
    assert rep.coordinator_items == 0 and rep.completed_by == {}


def test_uncacheable_build_is_loud(tmp_path):
    class NoFingerprint:
        pass

    with pytest.raises(DistBuildError, match="content-addressable"):
        dist_build_tables(NoFingerprint(), cache_dir=str(tmp_path),
                          workers=2)


# ---------------------------------------------------------------------------
# Publish gating: a non-main process writes NOTHING
# ---------------------------------------------------------------------------

def test_non_main_process_writes_nothing(smoke_host, reference, tmp_path,
                                         monkeypatch):
    """With a non-zero process index every publish path — table cache,
    build journal, artifact, gated text/JSON — is inert on disk while
    still returning its in-memory result."""
    from repro import runtime
    from repro.core.plan import identity_plan

    host, params = smoke_host
    graph = host.lower_plan(
        identity_plan(host.net.L, host.net.layer_descs(params)))
    main_fp = runtime.save(str(tmp_path / "main.npz"), graph)

    monkeypatch.setenv(dist.ENV_PROCESS_ID, "1")
    monkeypatch.setenv(dist.ENV_NUM_PROCESSES, "2")
    assert dist.process_index() == 1 and not dist.is_main()

    d = tmp_path / "nonmain"
    # table cache publish: path returned, file absent
    path = table_cache.save(str(d), "k" * 8, reference)
    assert not os.path.exists(path)
    # build journal: in-memory only
    j = table_cache.BuildJournal(str(d), "k" * 8)
    j.put("lat:0:1:1", 1.0)
    assert j.put_many([("a", 1.0, "measured")]) == 1
    assert j.get("a") == (1.0, "measured")
    assert not os.path.exists(j.path)
    # artifact: fingerprint computed (and equal to main's), file absent
    fp = runtime.save(str(d / "m.npz"), graph)
    assert fp == main_fp and not os.path.exists(str(d / "m.npz"))
    # gated text/JSON publishes
    assert dist.publish_text(str(d / "t.txt"), "x") is None
    assert dist.publish_json(str(d / "b.json"), {"x": 1}) is None
    assert not os.path.exists(str(d))

    monkeypatch.setenv(dist.ENV_PROCESS_ID, "0")
    assert dist.is_main()
    assert dist.publish_json(str(d / "b.json"), {"x": 1}) is not None
    assert json.load(open(d / "b.json")) == {"x": 1}
