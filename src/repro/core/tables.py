"""Lookup-table construction — ``T[i,j,k]`` and ``I[i,j,k]`` (paper §3.2).

The tables are built against a *host* — an adapter exposing the network to
the generic machinery.  Hosts implement:

* ``descs()``              → list[LayerDesc]
* ``enumerator(method)``   → SegmentEnumerator (span rules baked in)
* ``segment_cost(seg)``    → CostBreakdown (analytic latency oracle input)
* ``segment_callable(seg, params)`` → zero-arg jitted fn (wall-clock oracle)
* ``replaced_apply(plan)`` → (apply_fn, params) of the pruned-unmerged net
* ``original_k(l)``        → k-coordinate of the untouched layer l

Construction cost is ``O(L² K₀)`` entries (paper's bound); each importance
entry is independent — embarrassingly parallel in the paper; here they run
sequentially but against tiny fine-tune workloads.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Mapping

from .dp import TableFn
from .importance import ImportanceSpec, measure_importance, magnitude_importance
from .latency import AnalyticTPUOracle, LatencyOracle, WallClockOracle
from .plan import CompressionPlan, Segment, identity_plan
from .segments import pareto_prune_options


@dataclasses.dataclass
class Tables:
    """Materialized (i, j) → {k: (I, T, kept)} with build metadata."""

    entries: dict[tuple[int, int], dict[int, tuple[float, float, tuple[int, ...]]]]
    build_seconds_latency: float = 0.0
    build_seconds_importance: float = 0.0
    num_pruned: int = 0              # options dropped by Pareto dominance

    @property
    def num_entries(self) -> int:
        return sum(len(v) for v in self.entries.values())

    def fn(self) -> TableFn:
        return lambda i, j: self.entries.get((i, j), {})


def pareto_prune(
    entries: dict[tuple[int, int], dict[int, tuple[float, float, tuple[int, ...]]]],
) -> tuple[dict, int]:
    """Apply per-span Pareto-dominance pruning; returns (pruned, #dropped).

    Optimum-preserving for the DP (see
    :func:`repro.core.segments.pareto_prune_options`), so it runs before the
    solver ever sees the tables.
    """
    out: dict = {}
    dropped = 0
    for span, opts in entries.items():
        row = pareto_prune_options(opts)
        dropped += len(opts) - len(row)
        out[span] = row
    return out, dropped


def build_tables(
    host,
    *,
    method: str = "layermerge",
    latency_oracle: LatencyOracle | None = None,
    importance: ImportanceSpec | str = "magnitude",
    base_perf: float | None = None,
    params=None,
    progress: Callable[[str], None] | None = None,
    prune: bool = True,
) -> Tables:
    """Construct both lookup tables for ``host`` (Algorithm 2, lines 1-8).

    Latency and importance are filled in a single pass over the enumerated
    spans (one Segment build and one options walk per span instead of two);
    per-table build times are still accounted separately.  With ``prune``
    (default), options Pareto-dominated within their span are dropped before
    the tables reach the DP — provably optimum-preserving.
    """
    oracle = latency_oracle or AnalyticTPUOracle()
    enum = host.enumerator(method)
    entries: dict = {}
    t_lat = t_imp = 0.0
    total_value = sum(d.value for d in enum.descs)

    for i, j, opts in enum.all_spans():
        row = {}
        for k, (val, kept) in opts.items():
            seg = Segment(i=i, j=j, k=k, kept=kept,
                          original=(j - i == 1 and k == host.original_k(j)
                                    and set(kept) == set(seg_layers(i, j))))
            t0 = time.perf_counter()
            if isinstance(oracle, WallClockOracle):
                fn = host.segment_callable(seg, params)
                lat = oracle.time_callable(fn)
            else:
                lat = oracle.segment_latency(host.segment_cost(seg))
            t_lat += time.perf_counter() - t0

            t0 = time.perf_counter()
            if seg.original:
                imp = 1.0                      # exp(0): untouched layer
            elif importance == "magnitude":
                imp = magnitude_importance(val, max(total_value, 1e-9),
                                           len(seg.pruned))
            else:
                apply_fn, p = host.replaced_apply(
                    one_segment_plan(host, seg), params)
                imp = measure_importance(apply_fn, p, importance,
                                         base_perf or 0.0)
            t_imp += time.perf_counter() - t0
            row[k] = (imp, lat, kept)
        if row:
            entries[(i, j)] = row
        if progress:
            progress(f"table span ({i},{j}]: {len(row)} entries")

    dropped = 0
    if prune:
        entries, dropped = pareto_prune(entries)

    return Tables(entries=entries, build_seconds_latency=t_lat,
                  build_seconds_importance=t_imp, num_pruned=dropped)


def seg_layers(i: int, j: int) -> tuple[int, ...]:
    return tuple(range(i + 1, j + 1))


def one_segment_plan(host, seg: Segment) -> CompressionPlan:
    """Ã_ij / C̃_ijk of Eq. 4: everything original except segment (i, j]."""
    descs = host.descs()
    L = len(descs)
    segs = []
    for l in range(1, seg.i + 1):
        segs.append(Segment(i=l - 1, j=l, k=host.original_k(l), kept=(l,),
                            original=True))
    segs.append(seg)
    for l in range(seg.j + 1, L + 1):
        segs.append(Segment(i=l - 1, j=l, k=host.original_k(l), kept=(l,),
                            original=True))
    return CompressionPlan(num_layers=L, segments=tuple(segs),
                           method="probe")
