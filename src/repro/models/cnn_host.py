"""Host adapter: plan-aware CNNs → the generic LayerMerge core.

Implements the full batched-probe protocol of
:mod:`repro.core.probe_engine`: shape signatures for latency bucketing,
AOT-lowerable probe callables, Dirac-masked span batches for vmapped
importance fine-tunes, and a content fingerprint for the table cache.
"""
from __future__ import annotations

import dataclasses
import functools
import hashlib

import jax
import jax.numpy as jnp

from repro import kernels
from repro.core import table_cache
from repro.core.latency import CostBreakdown, conv2d_cost
from repro.core.plan import CompressionPlan, LayerDesc, Segment
from repro.core.probe_engine import ProbeCallable
from repro.core.segments import SegmentEnumerator
from repro.runtime import executor, ir

from . import cnn


def _dirac_like(w: jax.Array, depthwise: bool) -> jax.Array:
    """Identity stand-in for a pruned conv, at the conv's OWN kernel shape.

    A ``k×k`` kernel that is a centred delta (times the channel identity)
    computes *exactly* the input center-crop — every off-center tap
    multiplies by 0.0 and the center tap by 1.0, so the output is bitwise
    the input.  Substituting it for a pruned conv inside an all-kept span
    graph reproduces the true replaced network (which pads less and skips
    the layer) while keeping one shared trace for every kept-set of the
    span — the structural trick behind the vmapped importance batch.
    Requires odd ``k`` (centred delta) — the host's eligibility check.
    """
    kh, kw, cin, cout = w.shape
    c0, c1 = (kh - 1) // 2, (kw - 1) // 2
    if depthwise:
        return jnp.zeros_like(w).at[c0, c1, 0, :].set(1.0)
    return jnp.zeros_like(w).at[c0, c1].set(jnp.eye(cin, cout,
                                                    dtype=w.dtype))


@dataclasses.dataclass
class CNNHost:
    net: cnn.ConvNet
    params: dict                      # pre-trained parameters
    batch: int = 8                    # batch size for cost/latency accounting
    dtype_bytes: int = 2
    max_span: int | None = None
    # Split weight- vs. activation-byte widths for the cost model; None
    # defaults to ``dtype_bytes`` (the historical single-scalar behavior,
    # bit-identical).  Per-segment quantization overrides both via
    # ``segment_cost(seg, quant=...)``.
    w_bytes: int | None = None
    act_bytes: int | None = None

    def __post_init__(self):
        self._descs = self.net.layer_descs(self.params)
        self._shapes = self.net.boundary_shapes()

    # -- core protocol ---------------------------------------------------------
    def descs(self) -> list[LayerDesc]:
        return self._descs

    def enumerator(self, method: str = "layermerge") -> SegmentEnumerator:
        return SegmentEnumerator(
            self._descs, offset=1, cap=None,
            allowed_span=self.net.allowed_span,
            depth_mode=(method == "depth"),
            max_span=self.max_span)

    def original_k(self, l: int) -> int:
        return self._descs[l - 1].growth + 1

    def pruned_k(self, l: int) -> int:
        return 1

    # -- latency ----------------------------------------------------------------
    def segment_cost(self, seg: Segment, quant: str = "none"
                     ) -> CostBreakdown | None:
        """Analytic cost of the merged segment at its true input shape.

        ``quant`` (or ``seg.quant``) prices the segment at narrow byte
        widths — int8/fp8 weights, int8 activations under 'w8a8'.
        Returns ``None`` when a quantized cost is requested for a
        segment the quantized kernels cannot execute (non-conv barrier
        units), which is how the table builder skips ineligible spans.
        """
        q = quant if quant != "none" else seg.quant
        h, w, cin = self._shapes[seg.i]
        _, _, cout = self._shapes[seg.j]
        s_last = self.net.spec(seg.j)
        if s_last.kind != "conv":
            if q != "none":
                return None
            if s_last.kind == "attn":
                n = h * w
                c = cin
                flops = 4 * 2 * n * c * c + 2 * n * n * c * 2
                return CostBreakdown(flops * self.batch,
                                     4 * n * c * self.dtype_bytes * self.batch)
            return CostBreakdown(0.0, h * w * cin * self.dtype_bytes
                                 * self.batch * 2)
        K, S = cnn.segment_geometry(self.net, seg)
        kept = set(seg.kept)
        dw = all(self.net.spec(l).depthwise for l in seg.layers
                 if l in kept and self.net.spec(l).kind == "conv") and kept
        wb = kernels.quant.weight_bytes(q) or self.w_bytes
        ab = kernels.quant.act_bytes(q) or self.act_bytes
        return conv2d_cost(h, w, cin, cout, K, stride=S, depthwise=bool(dw),
                           dtype_bytes=self.dtype_bytes, batch=self.batch,
                           w_bytes=wb, act_bytes=ab)

    def probe_signature(self, seg: Segment):
        """Shape signature bucketing this segment's latency probe.

        Captures every input of both ``segment_cost`` and the wall-clock
        callable's trace — input shape, output channels, merged geometry
        ``(K, S)``, depthwise-ness, batch, and dtype width — so any two
        segments with equal signatures are latency-identical by
        construction and one measurement serves the whole bucket.
        """
        h, w, cin = self._shapes[seg.i]
        _, _, cout = self._shapes[seg.j]
        s_last = self.net.spec(seg.j)
        if s_last.kind != "conv":
            return (s_last.kind, h, w, cin, s_last.k, s_last.stride,
                    self.batch, self.dtype_bytes, self.w_bytes,
                    self.act_bytes)
        K, S = cnn.segment_geometry(self.net, seg)
        kept = set(seg.kept)
        dw = all(self.net.spec(l).depthwise for l in seg.layers
                 if l in kept and self.net.spec(l).kind == "conv") and kept
        # feature_group_count rides in the signature explicitly: depthwise
        # segments bucket by their group count (= cin under the phase-major
        # grouped kernel), never alongside dense segments of equal shape.
        groups = cin if dw else 1
        return ("conv", h, w, cin, cout, K, S, bool(dw), groups, self.batch,
                self.dtype_bytes, self.w_bytes, self.act_bytes)

    def segment_probe(self, seg: Segment, params=None) -> ProbeCallable:
        """Jitted merged-segment forward as (fn, args) — AOT-lowerable."""
        params = params or self.params
        h, w, cin = self._shapes[seg.i]
        x = jnp.zeros((self.batch, h, w, cin), jnp.float32)
        s_last = self.net.spec(seg.j)
        if s_last.kind != "conv":
            if s_last.kind == "attn":
                return ProbeCallable(jax.jit(cnn._tiny_self_attention),
                                     (x, params["layers"][seg.j - 1]))
            if s_last.kind == "pool":
                @jax.jit
                def pool_fn(x):
                    return jax.lax.reduce_window(
                        x, 0.0, jax.lax.add, (1, s_last.k, s_last.k, 1),
                        (1, s_last.stride, s_last.stride, 1),
                        "SAME") / (s_last.k * s_last.k)
                return ProbeCallable(pool_fn, (x,))

            @jax.jit
            def up_fn(x):
                n, hh, ww, c = x.shape
                return jax.image.resize(
                    x, (n, hh * s_last.stride, ww * s_last.stride, c),
                    "nearest")
            return ProbeCallable(up_fn, (x,))
        wgt, b, stride, dw = cnn.merge_segment(self.net, params["layers"], seg)
        K = wgt.shape[0]
        lo, hi = (K - 1) // 2, (K - 1) - (K - 1) // 2

        @jax.jit
        def fn(x, wgt, b):
            xp = jnp.pad(x, ((0, 0), (lo, hi), (lo, hi), (0, 0))) if K > 1 else x
            # Time the segment exactly as it deploys: through the Pallas
            # fast path on TPU (strided and depthwise segments included),
            # oracle off-TPU.
            if dw:
                return kernels.depthwise_conv_op(xp, wgt, b, stride=stride)
            return kernels.merged_conv_op(xp, wgt, b, stride=stride)
        return ProbeCallable(fn, (x, wgt, b))

    def segment_callable(self, seg: Segment, params=None):
        """Zero-arg jitted merged-segment forward for wall-clock timing."""
        probe = self.segment_probe(seg, params)
        return lambda: probe.fn(*probe.args)

    # -- batched importance probes ---------------------------------------------
    def importance_batch(self, segs: list[Segment], params=None):
        """One shared apply + stacked candidates for a span's Eq. 4 probes.

        Every probe of span ``(i, j]`` is expressed on ONE graph — the
        all-kept replaced network — by substituting a centred Dirac kernel
        (an exact identity, see :func:`_dirac_like`) for each pruned conv
        and zeroing its bias.  The candidates then differ only in leaf
        VALUES, so the engine can stack them and vmap the fine-tune.  The
        returned ``grad_mask`` freezes the Dirac leaves: updating them
        would turn "no layer" into a free extra conv and change Eq. 4's
        semantics.  Returns None (sequential fallback) when the span holds
        non-conv units, normed convs (BN/GN folding changes the fine-tune
        parametrization), or even kernels (no centred delta).
        """
        from repro.core.tables import one_segment_plan

        params = params or self.params
        seg0 = segs[0]
        span = tuple(range(seg0.i + 1, seg0.j + 1))
        for l in span:
            s = self.net.spec(l)
            if s.kind != "conv" or s.norm is not None or s.k % 2 == 0:
                return None
        probe = Segment(i=seg0.i, j=seg0.j, k=0, kept=span)
        K_all, _ = cnn.segment_geometry(self.net, probe)
        probe = Segment(i=seg0.i, j=seg0.j, k=K_all, kept=span)
        apply_fn, _ = self.replaced_apply(one_segment_plan(self, probe),
                                          params)
        ones = jax.tree.map(lambda x: jnp.ones((), x.dtype), params)
        cands, masks = [], []
        for seg in segs:
            kept = set(seg.kept)
            layers = list(params["layers"])
            mlayers = list(ones["layers"])
            for l in span:
                if l in kept:
                    continue
                s = self.net.spec(l)
                p, mp = dict(layers[l - 1]), dict(mlayers[l - 1])
                p["w"] = _dirac_like(p["w"], s.depthwise)
                mp["w"] = jnp.zeros((), p["w"].dtype)
                if "b" in p:
                    p["b"] = jnp.zeros_like(p["b"])
                    mp["b"] = jnp.zeros((), p["b"].dtype)
                layers[l - 1], mlayers[l - 1] = p, mp
            cands.append({**params, "layers": layers})
            masks.append({**ones, "layers": mlayers})
        stacked = jax.tree.map(lambda *xs: jnp.stack(xs), *cands)
        grad_mask = jax.tree.map(lambda *xs: jnp.stack(xs), *masks)
        return apply_fn, stacked, grad_mask

    def fingerprint(self) -> str:
        """Content digest for the on-disk table cache: network structure,
        probe workload, parameter bytes, and machine identity (wall-clock
        latencies do not transfer across hosts)."""
        h = hashlib.sha256()
        # w_bytes/act_bytes ride in the digest so tables priced under the
        # old single-scalar cost model are never silently reused.
        h.update(repr((self.net, self.batch, self.dtype_bytes,
                       self.max_span, self.w_bytes,
                       self.act_bytes)).encode())
        h.update(table_cache.pytree_digest(self.params).encode())
        h.update(table_cache.machine_token().encode())
        return h.hexdigest()

    # -- plan lowering / network builders -----------------------------------------
    def lower_plan(self, plan: CompressionPlan, params=None) -> ir.UnitGraph:
        """Lower a plan to the shared unit IR (Algorithm 2 final step).

        Folds every conv segment into one merged convolution
        (:func:`repro.models.cnn.merge_segment`: Eq. 1 composition, BN
        folding, skip-add Dirac fusion) and emits typed unit records with
        explicit skip/concat wiring, group-norm and boundary-activation
        epilogues — the executable, serializable form of the plan.
        """
        params = params or self.params
        net = self.net
        layers = params["layers"]
        need_save = {sk.start for sk in net.skips}
        add_end = {sk.end: (sk.start, i) for i, sk in enumerate(net.skips)
                   if sk.kind == "add"}
        cat_end = {sk.end: sk.start for sk in net.skips
                   if sk.kind == "concat"}
        units = []
        for seg in plan.segments:
            s_last = net.spec(seg.j)
            save_at = seg.j if seg.j in need_save else None
            if s_last.kind != "conv":
                assert seg.j - seg.i == 1, "barriers are singleton segments"
                if s_last.kind == "pool":
                    units.append(ir.PoolUnit(
                        k=s_last.k, stride=s_last.stride,
                        concat_from=cat_end.get(seg.j), save_at=save_at))
                elif s_last.kind == "upsample":
                    units.append(ir.UpsampleUnit(
                        factor=s_last.stride,
                        concat_from=cat_end.get(seg.j), save_at=save_at))
                else:
                    units.append(ir.AttnUnit(
                        save_at=save_at, params=dict(layers[seg.j - 1])))
                continue
            w, b, stride, dw = cnn.merge_segment(net, layers, seg)
            gn, gn_groups = cnn._segment_gn(net, layers, seg)
            act = s_last.act
            if net.act_after_merge and not seg.original and act == "none":
                act = "relu6"
            if seg.j >= net.L:
                act = "none"          # σ_L is the identity (paper §2)
            uparams = {"w": w, "b": b}
            if seg.quant != "none":
                # Narrow weights + symmetric per-output-channel scale; the
                # scale is data and serializes like any param (artifact v3).
                wq, wsc = kernels.quant.quantize_weight(w, seg.quant, axis=3)
                uparams = {"w": wq, "b": b, "w_scale": wsc}
            add_from = None
            proj_stride = 1
            if seg.j in add_end:
                # skip-adds whose block starts inside the segment were
                # Dirac-fused by merge_segment (proj blocks never fuse)
                src, ski = add_end[seg.j]
                sk = net.skips[ski]
                if src < seg.i or sk.proj:
                    add_from = src
                    if sk.proj:
                        uparams["proj"] = dict(params["skips"][ski])
                        proj_stride = cnn._skip_stride(net, sk)
            if gn is not None:
                uparams["gn"] = dict(gn)
            units.append(ir.ConvUnit(
                stride=stride, depthwise=dw, act=act, gn_groups=gn_groups,
                proj_stride=proj_stride, add_from=add_from,
                concat_from=cat_end.get(seg.j), save_at=save_at,
                quant=seg.quant, params=uparams))
        gparams = {}
        if net.head == "classifier":
            gparams["head"] = dict(params["head"])
        return ir.annotate_axes(ir.UnitGraph(
            family="cnn", units=tuple(units), params=gparams,
            meta={"save_input": 0 in need_save, "head": net.head}))

    def replaced_apply(self, plan: CompressionPlan, params=None):
        params = params or self.params

        def apply_fn(p, x):
            return cnn.apply_replaced(self.net, p, x, plan)
        return apply_fn, params

    def merged_apply(self, plan: CompressionPlan, params=None):
        """Merged forward through the shared runtime executor.

        ``apply_fn(p, x)`` re-lowers from ``p`` on every call (traced
        once under jit), so fine-tuned parameters flow straight into the
        merged weights exactly like the legacy closure did.
        """
        params = params or self.params

        def apply_fn(p, x):
            return executor.execute(self.lower_plan(plan, p), x)
        return apply_fn, params
