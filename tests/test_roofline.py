"""Roofline machinery unit tests: HLO collective parsing, shape-byte
accounting, depth-probe extrapolation, sharding-rule specs."""
import sys

import pytest

sys.path.insert(0, "benchmarks")

from repro.launch.dryrun import _shape_bytes, parse_collectives  # noqa: E402


def test_shape_bytes():
    assert _shape_bytes("f32[16,1024]") == 16 * 1024 * 4
    assert _shape_bytes("bf16[8,4096,2048]") == 8 * 4096 * 2048 * 2
    assert _shape_bytes("(f32[4], bf16[2,2])") == 16 + 8
    assert _shape_bytes("pred[10]") == 10
    assert _shape_bytes("token[]") == 0          # non-numeric types ignored


def test_parse_collectives():
    hlo = """
  ENTRY %main {
    %ar = f32[16,1024]{1,0} all-reduce(%x), replica_groups={{0,1}}
    %ag.1 = bf16[32,64]{1,0} all-gather(%y), dimensions={0}
    %p = f32[8]{0} collective-permute(%z), source_target_pairs={{0,1}}
    %d = f32[4,4]{1,0} dot(%a, %b)
    %ars = f32[2,2]{1,0} all-reduce-start(%w)
  }
    """
    out = parse_collectives(hlo)
    assert out["all-reduce"]["count"] == 2       # incl. -start form
    assert out["all-reduce"]["bytes"] == 16 * 1024 * 4 + 16
    assert out["all-gather"] == {"count": 1, "bytes": 32 * 64 * 2}
    assert out["collective-permute"]["bytes"] == 32
    assert out["total_bytes"] == sum(
        v["bytes"] for k, v in out.items() if isinstance(v, dict))


def test_depth_correct_extrapolation():
    import roofline
    rec = {"arch": "smollm-135m", "shape": "train_4k", "num_layers": 30,
           "cost": {"flops": 1.0, "bytes accessed": 1.0},
           "collectives": {"all-reduce": {"count": 1, "bytes": 100},
                           "total_bytes": 100}}
    p1 = {"num_layers": 1, "cost": {"flops": 10.0, "bytes accessed": 4.0},
          "collectives": {"all-reduce": {"count": 1, "bytes": 100}}}
    p2 = {"num_layers": 2, "cost": {"flops": 16.0, "bytes accessed": 6.0},
          "collectives": {"all-reduce": {"count": 2, "bytes": 150}}}
    key = ("smollm-135m", "train_4k")
    out = roofline.depth_correct(rec, ({key: p1}, {key: p2}))
    # body = 6, base = 4 → 4 + 30·6 = 184
    assert out["cost"]["flops"] == pytest.approx(184.0)
    assert out["cost"]["bytes accessed"] == pytest.approx(2 + 30 * 2)
    assert out["collectives"]["all-reduce"]["bytes"] == pytest.approx(
        50 + 30 * 50)


def test_rules_divisibility_fallback():
    """GQA kv heads < TP shards must fall back to replication."""
    import textwrap

    from repro.testing.subproc import run_code
    code = textwrap.dedent("""
        import jax
        from jax.sharding import PartitionSpec as P
        from repro.sharding.rules import make_rules
        mesh = jax.make_mesh((2, 4), ("data", "model"))
        r = make_rules(mesh, fsdp=True)
        # kv=2 doesn't divide model=4 → replicated; heads=8 divides → sharded
        assert r.spec(("embed", "kv", "head"), (64, 2, 16)) == P("data", None, None)
        assert r.spec(("embed", "heads", "head"), (64, 8, 16)) == P("data", "model", None)
        # axis dedup: experts takes model, ffn falls back
        assert r.spec(("experts", "ffn"), (8, 128)) == P("model", None)
        print("RULES_OK")
    """)
    res = run_code(code, devices=8, timeout=300)
    assert "RULES_OK" in res.stdout, res.stdout + res.stderr


def test_model_flops_conventions():
    import roofline
    rec_train = {"active_params": 1e9, "params": 2e9, "seq_len": 4096,
                 "global_batch": 256, "mode": "train"}
    assert roofline.model_flops(rec_train) == 6 * 1e9 * 4096 * 256
    rec_dec = dict(rec_train, mode="decode")
    assert roofline.model_flops(rec_dec) == 2 * 1e9 * 256
