"""Checkpointing — mesh-agnostic pytree save/restore with async writes.

Layout: ``<dir>/step_<N>/arrays.npz`` (host-gathered, flattened by
key-path) + ``meta.json`` (step, tree structure digest, user metadata).
Because arrays are saved *unsharded on host*, a restart may restore onto a
DIFFERENT mesh shape (elastic scaling): ``restore`` device_puts each leaf
with the sharding the new run requests.

Fault-tolerance contract exercised by tests/test_ft.py:
* atomic publish — write to ``step_N.tmp`` then rename;
* ``latest_step`` scans for the newest complete checkpoint;
* async save (background thread) never blocks the train step; a crash mid-
  write leaves only a ``.tmp`` dir which is ignored and GC'd;
* ``keep`` bounds disk usage (oldest complete checkpoints pruned).
"""
from __future__ import annotations

import contextlib
import json
import os
import re
import shutil
import threading
from typing import Any

import jax
import numpy as np


def _flatten(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    leaves = {}
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        leaves[key] = np.asarray(jax.device_get(leaf))
    return leaves, treedef


def flatten_leaves(tree) -> dict:
    """Key-path-flattened host arrays (``a/b/0/c`` keys).  THE key-path
    scheme for on-disk pytrees — shared with the merged-model artifacts
    (:mod:`repro.runtime.artifact`), so checkpoints and artifacts never
    diverge in layout."""
    return _flatten(tree)[0]


def atomic_write_text(path: str, text: str) -> str:
    """Atomic single-file publish: write ``path + '.tmp'``, then rename.

    The same crash contract as the checkpoint dirs below — a reader never
    observes a half-written file, and an interrupted write leaves only a
    ``.tmp`` orphan.  Shared with the lookup-table cache
    (:mod:`repro.core.table_cache`).
    """
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    tmp = path + ".tmp"
    with open(tmp, "w") as f:
        f.write(text)
    os.replace(tmp, path)
    return path


@contextlib.contextmanager
def atomic_writer(path: str):
    """Binary sibling of :func:`atomic_write_text`: yields a file object
    open on ``path + '.tmp'``; on clean exit the data is flushed +
    fsync'd and renamed over ``path``, so a reader observes the old file
    or the new one — never a torn write, even across power loss.  Shared
    with the merged-model artifacts (:mod:`repro.runtime.artifact`)."""
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    tmp = path + ".tmp"
    with open(tmp, "wb") as f:
        yield f
        f.flush()
        os.fsync(f.fileno())
    os.replace(tmp, path)


def atomic_write_bytes(path: str, data: bytes) -> str:
    """Atomic single-shot binary publish (see :func:`atomic_writer`)."""
    with atomic_writer(path) as f:
        f.write(data)
    return path


def append_journal_line(path: str, text: str, *,
                        point: str = "journal.append") -> str:
    """Crash-safe append of ONE journal record (write-ahead-log contract).

    ``text`` (newlines squashed) is written as a single ``\\n``-terminated
    line, flushed and fsync'd before return — once this function returns,
    the record survives a SIGKILL.  A crash *during* the write leaves a
    torn tail with no terminating newline, which
    :func:`read_journal_lines` truncates away on the next open, so a
    reader never parses half a record and subsequent appends never
    concatenate onto torn bytes.  Shared with the resumable table builds
    (:class:`repro.core.table_cache.BuildJournal`) and the distributed
    worker shards (:class:`repro.core.dist_build.ShardJournal`, which
    passes its own fault ``point`` so shard corruption is injectable
    independently of the build journal's).
    """
    from repro.testing import faults

    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    data = faults.mangle(point, (text.replace("\n", " ") + "\n").encode())
    with open(path, "ab") as f:
        f.write(data)
        f.flush()
        os.fsync(f.fileno())
    faults.hit(point + ".done")
    return path


def read_journal_lines(path: str) -> list[str]:
    """All COMPLETE lines of a journal; self-heals a torn tail.

    A record is complete iff its terminating newline reached the disk.
    Trailing bytes with no newline (a torn final append) are truncated
    off the file before returning, so the journal is again well-formed
    for subsequent appends.  A missing file is an empty journal.
    """
    try:
        with open(path, "rb") as f:
            raw = f.read()
    except FileNotFoundError:
        return []
    if not raw:
        return []
    cut = raw.rfind(b"\n") + 1               # 0 when no newline at all
    if cut != len(raw):                      # torn tail: truncate it away
        with open(path, "r+b") as f:
            f.truncate(cut)
        raw = raw[:cut]
    return raw.decode(errors="replace").splitlines()


def save(ckpt_dir: str, step: int, tree, *, metadata: dict | None = None,
         keep: int = 3):
    """Synchronous atomic save."""
    final = os.path.join(ckpt_dir, f"step_{step}")
    tmp = final + ".tmp"
    os.makedirs(tmp, exist_ok=True)
    leaves, treedef = _flatten(tree)
    np.savez(os.path.join(tmp, "arrays.npz"), **leaves)
    with open(os.path.join(tmp, "meta.json"), "w") as f:
        json.dump({"step": step, "keys": sorted(leaves),
                   "metadata": metadata or {}}, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    _gc(ckpt_dir, keep)
    return final


class AsyncCheckpointer:
    """Fire-and-forget saves on a background thread (one in flight).

    Usable as a context manager: ``__exit__`` joins the in-flight save —
    on clean exit AND on exception — so an interrupted run never leaves
    its newest checkpoint half-written::

        with AsyncCheckpointer(ckpt_dir) as ckpt:
            for step in ...:
                ckpt.save(step, state)
        # pending save has landed (or its error has been raised) here
    """

    def __init__(self, ckpt_dir: str, keep: int = 3):
        self.ckpt_dir = ckpt_dir
        self.keep = keep
        self._thread: threading.Thread | None = None
        self.error: Exception | None = None

    def save(self, step: int, tree, metadata=None):
        self.wait()
        host_tree = jax.tree.map(lambda x: np.asarray(jax.device_get(x)),
                                 tree)

        def run():
            try:
                save(self.ckpt_dir, step, host_tree, metadata=metadata,
                     keep=self.keep)
            except Exception as e:        # pragma: no cover
                self.error = e
        self._thread = threading.Thread(target=run, daemon=True)
        self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self.error:
            raise self.error

    def __enter__(self) -> "AsyncCheckpointer":
        return self

    def __exit__(self, exc_type, exc, tb) -> bool:
        if exc_type is None:
            self.wait()                      # surface any save error
        else:
            try:                             # still join the writer, but
                self.wait()                  # never mask the body's error
            except Exception:
                pass
        return False


def latest_step(ckpt_dir: str) -> int | None:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = []
    for name in os.listdir(ckpt_dir):
        m = re.fullmatch(r"step_(\d+)", name)
        if m and os.path.exists(os.path.join(ckpt_dir, name, "meta.json")):
            steps.append(int(m.group(1)))
    return max(steps) if steps else None


def restore(ckpt_dir: str, step: int, like, *, shardings=None):
    """Restore into the structure of ``like`` (a pytree of arrays or
    ShapeDtypeStructs).  ``shardings``: optional matching pytree of
    NamedShardings for the CURRENT mesh (elastic restore)."""
    path = os.path.join(ckpt_dir, f"step_{step}")
    with np.load(os.path.join(path, "arrays.npz")) as z:
        data = {k: z[k] for k in z.files}
    leaves_like, treedef = jax.tree_util.tree_flatten_with_path(like)
    shard_flat = None
    if shardings is not None:
        shard_flat = jax.tree.flatten(
            shardings, is_leaf=lambda x: isinstance(x, jax.sharding.Sharding)
        )[0]
    out = []
    for i, (pathk, leaf) in enumerate(leaves_like):
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in pathk)
        if key not in data:
            raise KeyError(f"checkpoint missing {key}")
        arr = data[key]
        if hasattr(leaf, "dtype"):
            arr = arr.astype(leaf.dtype)
        if shard_flat is not None:
            out.append(jax.device_put(arr, shard_flat[i]))
        else:
            out.append(jax.numpy.asarray(arr))
    return jax.tree.unflatten(treedef, out)


def _gc(ckpt_dir: str, keep: int):
    steps = []
    for name in os.listdir(ckpt_dir):
        m = re.fullmatch(r"step_(\d+)", name)
        if m and os.path.exists(os.path.join(ckpt_dir, name, "meta.json")):
            steps.append(int(m.group(1)))
        elif name.endswith(".tmp"):
            shutil.rmtree(os.path.join(ckpt_dir, name), ignore_errors=True)
    for s in sorted(steps)[:-keep]:
        shutil.rmtree(os.path.join(ckpt_dir, f"step_{s}"),
                      ignore_errors=True)
