"""Merge-math correctness: composed kernels ≡ composed layers (Eq. 1),
and network-level replaced ≡ merged equality — the cornerstone invariant.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp import given, settings, st
from jax import lax

from repro.core import merge as M
from repro.core import compress
from repro.models import cnn, cnn_host, zoo


def conv(x, w, s=1, dw=False):
    return lax.conv_general_dilated(
        x, w, (s, s), "VALID", dimension_numbers=("NHWC", "HWIO", "NHWC"),
        feature_group_count=w.shape[-1] if dw else 1)


@pytest.mark.parametrize("k1,k2,s1", [(3, 3, 1), (1, 3, 1), (3, 1, 1),
                                      (5, 3, 1), (3, 3, 2), (1, 1, 2),
                                      (2, 3, 1), (3, 2, 1)])
def test_conv_pair_composition(k1, k2, s1):
    key = jax.random.PRNGKey(k1 * 100 + k2 * 10 + s1)
    ks = jax.random.split(key, 3)
    x = jax.random.normal(ks[0], (2, 14, 14, 3))
    w1 = jax.random.normal(ks[1], (k1, k1, 3, 5))
    w2 = jax.random.normal(ks[2], (k2, k2, 5, 4))
    y = conv(conv(x, w1, s=s1), w2)
    wm, _ = M.merge_conv_pair(w1, w2, stride1=s1)
    assert wm.shape[0] == (k2 - 1) * s1 + k1
    np.testing.assert_allclose(y, conv(x, wm, s=s1), rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("dw1,dw2", [(True, True), (True, False),
                                     (False, True)])
def test_depthwise_composition(dw1, dw2):
    key = jax.random.PRNGKey(17)
    ks = jax.random.split(key, 3)
    c = 6
    x = jax.random.normal(ks[0], (2, 12, 12, c))
    w1 = jax.random.normal(ks[1], (3, 3, 1, c) if dw1 else (3, 3, c, c))
    w2 = jax.random.normal(ks[2], (3, 3, 1, c) if dw2 else (3, 3, c, c))
    y = conv(conv(x, w1, dw=dw1), w2, dw=dw2)
    wm, dwm = M.merge_conv_pair(w1, w2, dw1=dw1, dw2=dw2)
    assert dwm == (dw1 and dw2)
    np.testing.assert_allclose(y, conv(x, wm, dw=dwm), rtol=2e-4, atol=2e-4)


@given(n=st.integers(2, 4), seed=st.integers(0, 100))
@settings(max_examples=20, deadline=None)
def test_conv_chain_composition(n, seed):
    rng = np.random.default_rng(seed)
    key = jax.random.PRNGKey(seed)
    chans = [3] + [int(rng.integers(2, 6)) for _ in range(n)]
    ks = [int(rng.choice([1, 3])) for _ in range(n)]
    strides = [int(rng.choice([1, 1, 2])) for _ in range(n)]
    keys = jax.random.split(key, n + 1)
    x = jax.random.normal(keys[0], (1, 20, 20, 3))
    ws = [jax.random.normal(keys[i + 1], (ks[i], ks[i], chans[i], chans[i + 1]))
          * 0.5 for i in range(n)]
    y = x
    for w, s in zip(ws, strides):
        y = conv(y, w, s=s)
    wm, sm, _ = M.merge_conv_chain(ws, strides, [False] * n)
    np.testing.assert_allclose(y, conv(x, wm, s=sm), rtol=3e-4, atol=3e-4)


def test_bias_and_bn_folding():
    key = jax.random.PRNGKey(3)
    ks = jax.random.split(key, 6)
    x = jax.random.normal(ks[0], (2, 10, 10, 4))
    w = jax.random.normal(ks[1], (3, 3, 4, 4))
    b = jax.random.normal(ks[2], (4,))
    gamma = jax.random.normal(ks[3], (4,)) + 1.0
    beta = jax.random.normal(ks[4], (4,))
    mean = jax.random.normal(ks[5], (4,)) * 0.1
    var = jnp.abs(jax.random.normal(ks[0], (4,))) + 0.5
    y = conv(x, w) + b
    y = (y - mean) / jnp.sqrt(var + 1e-5) * gamma + beta
    wf, bf = M.fold_batchnorm(w, b, gamma, beta, mean, var)
    np.testing.assert_allclose(y, conv(x, wf) + bf, rtol=2e-4, atol=2e-4)


def test_dirac_skip_fusion():
    key = jax.random.PRNGKey(5)
    x = jax.random.normal(key, (2, 10, 10, 5))
    w = jax.random.normal(jax.random.PRNGKey(6), (3, 3, 5, 5))
    y = x[:, 1:-1, 1:-1, :] + conv(x, w)
    np.testing.assert_allclose(y, conv(x, M.fuse_skip_add(w)),
                               rtol=2e-4, atol=2e-4)


@given(n=st.integers(1, 4), seed=st.integers(0, 50))
@settings(max_examples=20, deadline=None)
def test_rank_merge_chain(n, seed):
    key = jax.random.PRNGKey(seed)
    d = 12
    keys = jax.random.split(key, 2 * n + 1)
    rng = np.random.default_rng(seed)
    factors = []
    for i in range(n):
        r = int(rng.integers(1, 6))
        factors.append((jax.random.normal(keys[2 * i], (d, r)) * 0.3,
                        jax.random.normal(keys[2 * i + 1], (r, d)) * 0.3))
    x = jax.random.normal(keys[-1], (5, d))
    y = x
    for u, v in factors:
        y = y + (y @ u) @ v
    um, vm = M.merge_linear_residual_chain(factors)
    assert um.shape[1] == sum(u.shape[1] for u, _ in factors)  # Eq.1 analogue
    np.testing.assert_allclose(y, x + (x @ um) @ vm, rtol=1e-4, atol=1e-4)
    # SVD truncation at full numerical rank is exact
    ut, vt = M.truncate_rank(um, vm, d)
    np.testing.assert_allclose(y, x + (x @ ut) @ vt, rtol=1e-3, atol=1e-3)


# ---------------------------------------------------------------------------
# Network-level equality: replaced(plan) == merged(plan) for DP plans
# ---------------------------------------------------------------------------

NETS = {
    "tiny_resnet": lambda: zoo.tiny_resnet(),
    "tiny_resnet_bn": lambda: zoo.tiny_resnet(norm="bn"),
    "tiny_mobilenet": lambda: zoo.tiny_mobilenet(),
    "tiny_unet": lambda: zoo.tiny_unet(),
    "tiny_unet_plain": lambda: zoo.tiny_unet(norm=None, attn=False),
}


@pytest.mark.parametrize("name", sorted(NETS))
@pytest.mark.parametrize("method", ["layermerge", "depth", "layeronly"])
def test_replaced_equals_merged(name, method):
    net = NETS[name]()
    params = cnn.init_params(net, jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1),
                          (2, net.in_hw, net.in_hw, net.in_ch))
    host = cnn_host.CNNHost(net, params, batch=2)
    tested = 0
    for ratio in (0.55, 0.75, 0.95):
        res = compress(host, budget_ratio=ratio, P=200, method=method)
        if res is None:
            continue
        ra, _ = host.replaced_apply(res.plan)
        ma, _ = host.merged_apply(res.plan)
        yr, ym = ra(params, x), ma(params, x)
        scale = float(jnp.abs(yr).max()) + 1e-9
        assert float(jnp.abs(yr - ym).max()) / scale < 1e-4, (name, method, ratio)
        tested += 1
    assert tested > 0, f"no feasible budget for {name}/{method}"


def test_original_equals_identity_plan():
    net = zoo.tiny_resnet()
    params = cnn.init_params(net, jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, 16, 3))
    y0 = cnn.apply_replaced(net, params, x)           # plan=None
    from repro.core.plan import identity_plan
    y1 = cnn.apply_replaced(net, params, x, identity_plan(net.L,
                                                          net.layer_descs()))
    np.testing.assert_allclose(y0, y1, rtol=1e-6, atol=1e-6)


def test_fully_pruned_segment_is_identity():
    """A segment with every conv pruned must merge to the identity."""
    from repro.core.plan import CompressionPlan, Segment
    net = zoo.tiny_resnet()
    params = cnn.init_params(net, jax.random.PRNGKey(2))
    # layers 2..5 are the two stage-1 residual blocks (all shape-preserving)
    segs = [Segment(i=0, j=1, k=3, kept=(1,), original=True),
            Segment(i=1, j=5, k=1, kept=())]
    for l in range(6, net.L + 1):
        segs.append(Segment(i=l - 1, j=l, k=net.spec(l).k, kept=(l,),
                            original=True))
    plan = CompressionPlan(num_layers=net.L, segments=tuple(segs))
    x = jax.random.normal(jax.random.PRNGKey(3), (2, 16, 16, 3))
    host = cnn_host.CNNHost(net, params, batch=2)
    ra, _ = host.replaced_apply(plan)
    ma, _ = host.merged_apply(plan)
    np.testing.assert_allclose(ra(params, x), ma(params, x),
                               rtol=1e-4, atol=1e-4)
