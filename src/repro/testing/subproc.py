"""Shared subprocess environment construction for multi-process tests.

Every subprocess-based test and smoke in this repo (forced-host-device
pmap tests, kill-and-resume fault smokes, distributed worker spawns)
needs the same carefully-pinned child environment:

* ``JAX_PLATFORMS=cpu`` — children simulate devices via XLA flags, so
  cpu is always the right platform, and it MUST be pinned explicitly:
  on hosts with libtpu installed an unset platform sends backend init
  into ~30-retry GCP metadata fetches (minutes per subprocess);
* ``XLA_FLAGS=--xla_force_host_platform_device_count=N`` — the CI
  stand-in for a multi-chip host;
* an absolute ``PYTHONPATH`` pointing at this repo's ``src`` (children
  are often spawned with a minimal env and an arbitrary cwd).

This module is THE single place that knowledge lives; test files and
production spawn paths (:mod:`repro.launch.distributed`) import it
instead of re-deriving the dict.  It is stdlib-only.
"""
from __future__ import annotations

import os
import subprocess
import sys
import textwrap

#: Absolute path of the ``src`` tree this module was imported from —
#: what children need on PYTHONPATH to import ``repro``.
SRC_ROOT = os.path.dirname(os.path.dirname(os.path.dirname(
    os.path.abspath(__file__))))

#: Repo root (``src``'s parent) — the default child cwd, so relative
#: paths inside children (e.g. ``results/``) resolve the same way the
#: parent's do.
REPO_ROOT = os.path.dirname(SRC_ROOT)


def subprocess_env(*, devices: int | None = None, platform: str = "cpu",
                   process_id: int | None = None,
                   num_processes: int | None = None,
                   faults_spec: str | None = None,
                   extra: dict | None = None) -> dict:
    """The pinned child environment for a subprocess test/worker.

    ``devices`` forces ``--xla_force_host_platform_device_count``;
    ``process_id``/``num_processes`` set the ``REPRO_PROCESS_ID`` /
    ``REPRO_NUM_PROCESSES`` variables consumed by
    :mod:`repro.launch.distributed` (subprocess-worker CI mode);
    ``faults_spec`` sets ``REPRO_FAULTS``; ``extra`` merges last.
    """
    env = {
        "PYTHONPATH": SRC_ROOT + (
            os.pathsep + os.environ["PYTHONPATH"]
            if os.environ.get("PYTHONPATH") else ""),
        "PATH": os.environ.get("PATH", "/usr/bin:/bin"),
        "HOME": os.environ.get("HOME", "/root"),
        "JAX_PLATFORMS": platform,
    }
    if devices is not None:
        env["XLA_FLAGS"] = (
            f"--xla_force_host_platform_device_count={devices}")
    if process_id is not None:
        env["REPRO_PROCESS_ID"] = str(process_id)
    if num_processes is not None:
        env["REPRO_NUM_PROCESSES"] = str(num_processes)
    if faults_spec is not None:
        env["REPRO_FAULTS"] = faults_spec
    if extra:
        env.update(extra)
    return env


def run_code(code: str, *, devices: int | None = None, timeout: float = 600,
             check: bool = True, env: dict | None = None,
             cwd: str | None = None) -> subprocess.CompletedProcess:
    """Run a dedented Python snippet in a pinned child interpreter.

    The device count is forced through the environment (not an in-code
    ``os.environ`` mutation), so the snippet may import jax on line one.
    With ``check`` (default) a non-zero exit raises with the child's
    tail of stdout/stderr in the message.
    """
    r = subprocess.run(
        [sys.executable, "-c", textwrap.dedent(code)],
        capture_output=True, text=True, timeout=timeout,
        env=env if env is not None else subprocess_env(devices=devices),
        cwd=cwd or REPO_ROOT)
    if check and r.returncode != 0:
        raise AssertionError(
            f"subprocess exited {r.returncode}:\n"
            f"{r.stdout[-2000:]}{r.stderr[-4000:]}")
    return r


def run_module(module: str, *args: str, devices: int | None = None,
               timeout: float = 600, check: bool = True,
               env: dict | None = None,
               cwd: str | None = None) -> subprocess.CompletedProcess:
    """``python -m module args...`` under the pinned child environment."""
    r = subprocess.run(
        [sys.executable, "-m", module, *args],
        capture_output=True, text=True, timeout=timeout,
        env=env if env is not None else subprocess_env(devices=devices),
        cwd=cwd or REPO_ROOT)
    if check and r.returncode != 0:
        raise AssertionError(
            f"{module} exited {r.returncode}:\n"
            f"{r.stdout[-2000:]}{r.stderr[-4000:]}")
    return r
