"""Latency oracles used to populate the ``T[i,j,k]`` lookup table.

The paper *measures* every table entry on the deployment device (RTX2080Ti).
Our deployment target is a TPU v5e pod while the build/test host is CPU-only,
so the oracle is pluggable:

* :class:`AnalyticTPUOracle` — a v5e roofline model.  Latency of one fused
  layer is ``overhead + max(flops/peak, hbm_bytes/bw) + ici_bytes/link_bw``.
  This reproduces the paper's qualitative phenomenon exactly: merged layers
  with grown kernel/rank cost more compute, while removing layers removes
  the per-layer overhead + memory pass.
* :class:`WallClockOracle` — times a jitted callable on the present host
  (the paper's measured pipeline, exercised end-to-end in tests/benchmarks
  on tiny networks: 300 warm-up + 200 timed calls in the paper; we scale the
  counts down for CI but keep the protocol shape).

Hardware constants (assignment): 197 TFLOP/s bf16/chip, 819 GB/s HBM,
~50 GB/s/link ICI.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable

import jax
import numpy as np

PEAK_FLOPS_BF16 = 197e12       # per v5e chip
HBM_BW = 819e9                 # bytes/s per chip
ICI_BW = 50e9                  # bytes/s per link


@dataclasses.dataclass(frozen=True)
class CostBreakdown:
    """Static cost of one (possibly merged) layer, per chip."""

    flops: float
    hbm_bytes: float
    ici_bytes: float = 0.0

    def __add__(self, other: "CostBreakdown") -> "CostBreakdown":
        return CostBreakdown(self.flops + other.flops,
                             self.hbm_bytes + other.hbm_bytes,
                             self.ici_bytes + other.ici_bytes)

    def __mul__(self, scale: float) -> "CostBreakdown":
        return CostBreakdown(self.flops * scale, self.hbm_bytes * scale,
                             self.ici_bytes * scale)

    __rmul__ = __mul__


ZERO_COST = CostBreakdown(0.0, 0.0, 0.0)


class LatencyOracle:
    def segment_latency(self, cost: CostBreakdown) -> float:  # pragma: no cover
        raise NotImplementedError


@dataclasses.dataclass
class AnalyticTPUOracle(LatencyOracle):
    peak_flops: float = PEAK_FLOPS_BF16
    hbm_bw: float = HBM_BW
    ici_bw: float = ICI_BW
    op_overhead: float = 1.0e-6   # fixed per-fused-layer dispatch cost

    def segment_latency(self, cost: CostBreakdown) -> float:
        compute = cost.flops / self.peak_flops
        memory = cost.hbm_bytes / self.hbm_bw
        network = cost.ici_bytes / self.ici_bw
        return self.op_overhead + max(compute, memory) + network

    def terms(self, cost: CostBreakdown) -> dict[str, float]:
        return {
            "compute_s": cost.flops / self.peak_flops,
            "memory_s": cost.hbm_bytes / self.hbm_bw,
            "collective_s": cost.ici_bytes / self.ici_bw,
        }


@dataclasses.dataclass
class WallClockOracle(LatencyOracle):
    """Times real jitted segment callables (paper Appendix C protocol).

    The ``iters`` timed calls are split into ``groups`` contiguous groups
    and the reported latency is the *median of the group means* — one
    host-jitter spike (page fault, GC, sibling process) corrupts at most
    one group instead of the whole mean, so table entries stay robust
    while the warmup + timed-calls protocol shape is unchanged.

    Under the batched probe engine (:mod:`repro.core.probe_engine`) this
    oracle is invoked once per *shape bucket* rather than once per table
    entry: probes are grouped by shape signature, the representative is
    pre-compiled on a worker thread while earlier buckets warm up, the
    timed loops run in a quiet window after the last compile, and the
    measured latency is attributed to every entry in the bucket.
    """

    warmup: int = 5
    iters: int = 20
    groups: int = 5

    def time_callable_stats(self, fn: Callable[[], jax.Array], *,
                            warmup: int | None = None
                            ) -> tuple[float, float]:
        """``(median-of-group-means, relative spread)`` for ``fn``.

        The relative spread — ``(max − min) / median`` over the group
        means — is the probe engine's outlier signal: a jitter spike that
        contaminated one group leaves the median usable but the spread
        large, triggering a variance-based re-timing
        (:class:`repro.core.probe_engine.ProbeConfig`).
        """
        for _ in range(self.warmup if warmup is None else warmup):
            jax.block_until_ready(fn())
        g = max(1, min(self.groups, self.iters))
        base, extra = divmod(self.iters, g)
        means = []
        for gi in range(g):
            n = base + (1 if gi < extra else 0)
            t0 = time.perf_counter()
            for _ in range(n):
                jax.block_until_ready(fn())
            means.append((time.perf_counter() - t0) / n)
        med = float(np.median(means))
        spread = float((max(means) - min(means)) / max(med, 1e-12))
        return med, spread

    def time_callable(self, fn: Callable[[], jax.Array], *,
                      warmup: int | None = None) -> float:
        """Measure ``fn``; ``warmup`` overrides the configured warmup count
        (the probe engine passes 0 for callables it already warmed while
        compilation of later buckets was still in flight)."""
        return self.time_callable_stats(fn, warmup=warmup)[0]

    def segment_latency(self, cost: CostBreakdown) -> float:
        raise TypeError(
            "WallClockOracle times callables; use time_callable via the host")


# ---------------------------------------------------------------------------
# Cost helpers shared by the hosts
# ---------------------------------------------------------------------------

def conv2d_cost(h: int, w: int, cin: int, cout: int, k: int, stride: int = 1,
                depthwise: bool = False, dtype_bytes: int = 2,
                batch: int = 1, w_bytes: int | None = None,
                act_bytes: int | None = None) -> CostBreakdown:
    """Analytic cost of one (possibly merged) conv layer.

    Activation traffic models the zero-copy DMA kernels — dense
    (``merged_conv``) and depthwise/grouped (``depthwise_conv``) alike:
    the input is read out of HBM exactly once plus the ``⌊(k−1)/s⌋``
    per-phase halo rows/cols re-read at tile seams (the planner's tiling
    decides how many seams there are; the depthwise grid's channel
    blocking does not change aggregate input traffic).  Stride-``s``
    segments additionally pay the one-off phase-major relayout transpose
    (``relayout_bytes``).  The host-side halo-gather term the PR-1 kernel
    paid — a full extra input-sized HBM write + read whenever more than
    one row tile was needed — is gone, as is the lax gather model the
    depthwise branch used while depthwise units bypassed Pallas, so the
    DP's latency table reflects the reclaimed bandwidth on both paths.

    ``w_bytes``/``act_bytes`` split the weight vs. activation byte
    widths for quantized units (int8 weights: ``w_bytes=1``; w8a8 also
    ``act_bytes=1``).  Both default to ``dtype_bytes`` — the historical
    single-scalar behavior, bit-identical.
    """
    wb = dtype_bytes if w_bytes is None else w_bytes
    ab = dtype_bytes if act_bytes is None else act_bytes
    ho, wo = -(-h // stride), -(-w // stride)
    if depthwise:
        flops = 2.0 * batch * ho * wo * cin * k * k
        wbytes = cin * k * k * wb
    else:
        flops = 2.0 * batch * ho * wo * cin * cout * k * k
        wbytes = cin * cout * k * k * wb
    in_bytes = float(h * w * cin * ab)
    if k > 1 or stride > 1:
        # layering note: the kernel package never imports core, so this
        # lazy import of its tile planner cannot cycle.
        from repro.kernels.merged_conv import input_traffic_model
        traffic = input_traffic_model(h + k - 1, w + k - 1, cin, k, k,
                                      stride, ab,
                                      groups=cin if depthwise else 1)
        in_bytes = (max(in_bytes, traffic["dma_bytes"])
                    + traffic["relayout_bytes"])
    abytes = batch * (in_bytes + ho * wo * cout * ab)
    return CostBreakdown(flops, wbytes + abytes)


def matmul_cost(m: int, kdim: int, n: int, dtype_bytes: int = 2,
                w_bytes: int | None = None,
                act_bytes: int | None = None) -> CostBreakdown:
    """``(m, kdim) @ (kdim, n)``; the ``(kdim, n)`` operand is the weight
    (``w_bytes``), the ``(m, kdim)`` input and ``(m, n)`` output are
    activations (``act_bytes``); both default to ``dtype_bytes``."""
    wb = dtype_bytes if w_bytes is None else w_bytes
    ab = dtype_bytes if act_bytes is None else act_bytes
    flops = 2.0 * m * kdim * n
    bytes_ = m * kdim * ab + kdim * n * wb + m * n * ab
    return CostBreakdown(flops, bytes_)


def rank_ffn_cost(tokens: int, d: int, rank: int,
                  dtype_bytes: int = 2, w_bytes: int | None = None,
                  act_bytes: int | None = None) -> CostBreakdown:
    """Merged rank-``r`` residual layer: ``x + (x·U)·V`` (two thin GEMMs)."""
    r = min(rank, d)
    return (matmul_cost(tokens, d, r, dtype_bytes, w_bytes, act_bytes)
            + matmul_cost(tokens, r, d, dtype_bytes, w_bytes, act_bytes))
