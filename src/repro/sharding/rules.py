"""Logical-axis sharding rules (MaxText-style) for pjit/shard_map.

Model code annotates params and activations with *logical* axis names
('embed', 'heads', 'ffn', 'vocab', 'experts', 'batch', 'seq', …); a
:class:`ShardingRules` maps those to mesh axes and builds PartitionSpecs.
An ambient context (``use_rules``) lets the model call
``logical_constraint(x, names)`` without threading the mesh through every
function — a no-op outside the context, so the same code runs on one CPU
device in tests.

Default production mapping (16×16 pod, see launch/mesh.py):

* ``batch``   → ('pod', 'data')  — data parallel (pod axis folds in)
* ``embed``   → 'data' for *parameters* (FSDP / ZeRO-3 style weight shard)
* ``heads`` / ``ffn`` / ``vocab`` / ``experts`` → 'model' (tensor/expert par.)
* ``kv``      → 'model' when divisible, else replicated (GQA)
* ``kv_seq``  → 'model' for decode caches (flash-decoding layout, §Perf)
* ``seq``     → 'data' in sequence-parallel prefill configs

Unit-graph artifacts (:mod:`repro.runtime`) carry these names as DATA:
every unit record ships an ``axes`` map {param keypath → logical names}
written at lowering time, so an artifact loader resolves placement with
nothing but a :class:`ShardingRules` — no family-specific code.  The
vocabulary extends to merged-CNN graphs (``conv_out`` / ``channels`` are
the model-parallel axes of a merged conv, ``conv_in`` stays replicated,
``act_channels`` shards NHWC activations) and to serving
(:func:`make_unit_rules`: weights replicated over 'data' for
data-parallel batches, tensor-parallel over 'model', decode KV caches on
the 'kv_seq' flash-decoding layout).  Names a rule set does not know
resolve to replicated, so v1 artifacts (no annotations) and single-device
meshes fall out of the same path.
"""
from __future__ import annotations

import contextlib
import dataclasses
import threading
from typing import Any, Mapping

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


@dataclasses.dataclass
class ShardingRules:
    mesh: Mesh | None
    rules: Mapping[str, Any]          # logical name -> mesh axis (or tuple)

    def spec(self, names, shape=None) -> P:
        """PartitionSpec for a tuple of logical axis names.

        ``shape`` (optional) enables divisibility fallback: a dim that does
        not divide by its mesh-axis size is replicated instead (GQA kv<TP).
        """
        if self.mesh is None:
            return P()
        parts = []
        used = set()
        for i, n in enumerate(names):
            ax = self.rules.get(n) if n is not None else None
            if ax is None:
                parts.append(None)
                continue
            axes = ax if isinstance(ax, tuple) else (ax,)
            axes = tuple(a for a in axes if a in self.mesh.shape
                         and a not in used)
            if not axes:
                parts.append(None)
                continue
            if shape is not None:
                size = int(np.prod([self.mesh.shape[a] for a in axes]))
                if shape[i] % size != 0:
                    parts.append(None)
                    continue
            used.update(axes)
            parts.append(axes if len(axes) > 1 else axes[0])
        return P(*parts)

    def named(self, names, shape=None) -> NamedSharding:
        return NamedSharding(self.mesh, self.spec(names, shape))


_ctx = threading.local()


def current_rules() -> ShardingRules | None:
    return getattr(_ctx, "rules", None)


@contextlib.contextmanager
def use_rules(rules: ShardingRules | None):
    prev = getattr(_ctx, "rules", None)
    _ctx.rules = rules
    try:
        yield rules
    finally:
        _ctx.rules = prev


def logical_constraint(x, names):
    """with_sharding_constraint by logical names; no-op without context."""
    r = current_rules()
    if r is None or r.mesh is None:
        return x
    return jax.lax.with_sharding_constraint(x, r.named(tuple(names), x.shape))


# ---------------------------------------------------------------------------
# Rule presets
# ---------------------------------------------------------------------------

def make_rules(mesh: Mesh | None, *, fsdp: bool = True,
               seq_parallel: bool = False,
               decode_kv_model: bool = True,
               opt_state: bool = False) -> ShardingRules:
    """The production mapping used by the dry-run and launcher."""
    data_axes = tuple(a for a in ("pod", "data") if mesh is not None
                      and a in mesh.shape) or ("data",)
    rules = {
        # activations
        "batch": data_axes,
        "seq": (data_axes if seq_parallel else None),
        "act_embed": None,
        "act_heads": "model",
        "act_ffn": "model",
        "act_vocab": "model",
        # parameters (FSDP shards the embed dim over the data axes)
        "embed": (data_axes if fsdp else None),
        "heads": "model",
        "kv": "model",
        "head": None,
        "ffn": "model",
        "ffn_in": None,
        "vocab": "model",
        "experts": "model",
        # expert weights live TP-sharded + data-replicated (the shard_map
        # MoE needs whole (E_loc, d, dff) blocks locally); their ZeRO-1
        # optimizer moments ARE data-sharded (opt_state=True rule set)
        "expert_embed": (data_axes if opt_state else None),
        "expert_ffn": None,
        "moe_group": data_axes,   # MoE token groups over data (GShard layout)
        "rank": "model",
        "layers": None,
        # decode KV cache: sequence over the model axis (flash-decoding)
        "kv_seq": ("model" if decode_kv_model else None),
        # merged-CNN unit graphs: channels are the model axis (LayerMerge)
        "conv_in": None,
        "conv_out": "model",
        "channels": "model",
        "act_channels": "model",
    }
    return ShardingRules(mesh=mesh, rules=rules)


def make_unit_rules(mesh: Mesh | None, *,
                    decode_kv_model: bool = True) -> ShardingRules:
    """The serving rule set for unit-graph artifacts (CNN + transformer).

    Identical to :func:`make_rules` except weights stay whole on the
    'data' axes (``fsdp=False``): serving shards the *batch* over 'data'
    and the model dims ('ffn'/'heads'/'vocab'/'conv_out'/'rank') over
    'model', so a decode step runs without the FSDP weight all-gathers
    that only pay off under training's optimizer-state memory pressure.
    """
    return make_rules(mesh, fsdp=False, decode_kv_model=decode_kv_model)


def param_shardings(rules: ShardingRules, axes_tree):
    """Map a tree of logical-axes tuples to NamedShardings (for in_shardings)."""
    def one(ax):
        if ax is None:
            return NamedSharding(rules.mesh, P())
        return NamedSharding(rules.mesh, rules.spec(tuple(ax)))
    return jax.tree.map(one, axes_tree,
                        is_leaf=lambda x: isinstance(x, tuple) or x is None)


def param_shardings_with_shapes(rules: ShardingRules, axes_tree, shape_tree):
    """Like :func:`param_shardings` but with divisibility fallback per leaf."""
    def one(ax, shaped):
        shape = shaped.shape if hasattr(shaped, "shape") else None
        if ax is None:
            return NamedSharding(rules.mesh, P())
        return NamedSharding(rules.mesh, rules.spec(tuple(ax), shape))
    return jax.tree.map(one, axes_tree, shape_tree,
                        is_leaf=lambda x: isinstance(x, tuple) or x is None)
