"""ShapeDtypeStruct input specs for every (arch × shape) dry-run cell.

Weak-type-correct, shardable, zero allocation.  For ``embeddings``-frontend
archs (musicgen, qwen2-vl) the modality frontend is a stub per the
assignment: the spec feeds precomputed frame/patch embeddings.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, ShapeConfig
from repro.models import transformer as T


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, jnp.dtype(dtype))


def batch_specs(cfg: ArchConfig, shape: ShapeConfig, *, with_targets: bool):
    B, S = shape.global_batch, shape.seq_len
    if shape.mode == "decode":
        S = 1
    specs = {"positions": _sds((B, S), "int32")}
    if cfg.frontend == "tokens":
        specs["tokens"] = _sds((B, S), "int32")
    else:
        specs["embeds"] = _sds((B, S, cfg.d_model), cfg.dtype)
    if cfg.rope_kind == "mrope":
        specs["mrope_positions"] = _sds((3, B, S), "int32")
    if with_targets:
        specs["targets"] = _sds((B, S), "int32")
    if shape.mode == "decode":
        specs.pop("positions")      # decode derives positions from the cache
    return specs


def batch_axes(cfg: ArchConfig, shape: ShapeConfig, *, with_targets: bool):
    """Logical axes matching batch_specs (for in_shardings)."""
    ax = {"positions": ("batch", "seq")}
    if cfg.frontend == "tokens":
        ax["tokens"] = ("batch", "seq")
    else:
        ax["embeds"] = ("batch", "seq", None)
    if cfg.rope_kind == "mrope":
        ax["mrope_positions"] = (None, "batch", "seq")
    if with_targets:
        ax["targets"] = ("batch", "seq")
    if shape.mode == "decode":
        ax.pop("positions")
    return ax


def cache_specs(cfg: ArchConfig, shape: ShapeConfig):
    return jax.eval_shape(
        lambda: T.init_cache(cfg, shape.global_batch, shape.seq_len))


def param_specs(cfg: ArchConfig):
    """(abstract params, logical axes) without allocating anything."""
    params = jax.eval_shape(
        lambda: T.init_model(cfg, jax.random.PRNGKey(0))[0])
    return params, T.model_axes(cfg)


def input_specs(cfg: ArchConfig, shape: ShapeConfig):
    """The full spec dict the dry-run lowers against."""
    if shape.mode == "train":
        return {"batch": batch_specs(cfg, shape, with_targets=True)}
    if shape.mode == "prefill":
        return {"batch": batch_specs(cfg, shape, with_targets=False)}
    if shape.mode == "decode":
        return {"batch": batch_specs(cfg, shape, with_targets=False),
                "cache": cache_specs(cfg, shape)}
    raise ValueError(shape.mode)
