#!/usr/bin/env bash
# Tier-1 verification + kernel equivalence, platform-pinned.
#
#   bash scripts/verify.sh [extra pytest args]
#   make verify
#
# JAX_PLATFORMS=cpu is pinned because on libtpu hosts an unpinned child
# process stalls for minutes in TPU metadata fetches; every test here is
# CPU/interpret-mode by design (real-TPU timing has its own benches).
set -euo pipefail
cd "$(dirname "$0")/.."

export JAX_PLATFORMS=cpu
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

# Kernel equivalence first: the fast, specific signal when iterating on
# Pallas code; the tier-1 pass below skips these files so nothing runs
# twice and the union still covers the whole suite.
KERNEL_SUITE="tests/test_kernels.py tests/test_merged_conv_general.py \
    tests/test_depthwise_conv.py tests/test_fastpath.py \
    tests/test_quant_kernels.py"

echo "== interpret-mode kernel equivalence (Pallas vs jnp oracles) =="
python -m pytest -q $KERNEL_SUITE

echo "== tier-1 suite (remainder) =="
IGNORES=""
for f in $KERNEL_SUITE; do IGNORES="$IGNORES --ignore=$f"; done
python -m pytest -x -q $IGNORES "$@"

echo "== probe-engine bench smoke (table-build parity + accounting) =="
# --workers 0: the dist-fault-smoke leg below covers the fan-out path.
python -m benchmarks.bench_tables --smoke --workers 0 > /dev/null

echo "== serve bench smoke (artifact round-trip + KV-cache parity) =="
python -m benchmarks.bench_serve --smoke > /dev/null

echo "== quantized serve smoke (DP-planned w8a8 leg, >=2x weight bytes) =="
python -m benchmarks.bench_serve --smoke --quantize w8a8 > /dev/null

echo "== serve bench smoke, sharded (forced host devices, data x model) =="
XLA_FLAGS=--xla_force_host_platform_device_count=8 \
    python -m benchmarks.bench_serve --smoke --mesh --model-par 2 > /dev/null

echo "== fault-injection smoke (SIGKILL mid-build, resume bit-identical) =="
python -m repro.testing.faults --smoke > /dev/null

echo "== serve fault smoke (continuous engine: NaN + straggler, exact) =="
python -m repro.testing.faults --serve-smoke > /dev/null

echo "== distributed fault smoke (worker SIGKILL -> lease reassignment; =="
echo "==   serve failover replay, zero lost requests, bit-identical)    =="
python -m repro.launch.distributed --fault-smoke > /dev/null

echo "verify: OK"
