"""Serving-path benchmark — compressed vs original prefill/decode tok/s.

Exercises the artifact-backed serve path end-to-end: compress a small LM
(analytic oracle + magnitude importance — deterministic, seconds-scale),
publish a merged-model artifact, reload it, and decode through the
shared unit-graph executor with a KV cache, side by side with the
uncompressed ``make_serve_step`` stack.  Writes
``results/BENCH_serve.json`` with prefill/decode throughput for both
paths plus the DP-predicted speedup (the measured ratio on a CPU build
host is reported, not asserted — the latency oracle targets the v5e).

  PYTHONPATH=src python -m benchmarks.bench_serve [--smoke] [--out PATH]

``--smoke`` (wired into ``make verify`` via scripts/verify.sh) runs the
correctness gates in seconds: artifact round-trip + fingerprint
stability, compressed decode ≡ compressed prefill (KV-cache parity),
and a genuinely shallower unit chain — so serving-path regressions fail
``make verify`` even where timing is meaningless.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os
import sys
import tempfile

sys.path.insert(0, os.path.join(os.path.dirname(__file__), os.pardir, "src"))

import jax                                              # noqa: E402
import jax.numpy as jnp                                 # noqa: E402
import numpy as np                                      # noqa: E402

from repro import runtime                               # noqa: E402
from repro.runtime import serve_loop                    # noqa: E402
from repro.configs import get_config                    # noqa: E402
from repro.core import compress                         # noqa: E402
from repro.models import transformer as T               # noqa: E402
from repro.models.transformer_host import (CostEnv,     # noqa: E402
                                           TransformerHost)
from repro.train.step import make_serve_step            # noqa: E402


def make_model(smoke: bool):
    base = get_config("smollm-135m").reduced()
    if smoke:
        cfg = dataclasses.replace(base, num_layers=4)
    else:
        cfg = dataclasses.replace(base, num_layers=8, d_model=128,
                                  num_heads=4, num_kv_heads=2, head_dim=32,
                                  d_ff=512, vocab_size=512)
    params, _ = T.init_model(cfg, jax.random.PRNGKey(0))
    return cfg, params


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="fast correctness pass (CI)")
    ap.add_argument("--budget-ratio", type=float, default=0.55)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=None)
    ap.add_argument("--tokens", type=int, default=None)
    ap.add_argument("--out", default=os.path.join(
        os.path.dirname(__file__), os.pardir, "results",
        "BENCH_serve.json"))
    args = ap.parse_args(argv)
    P = args.prompt_len or (8 if args.smoke else 32)
    N = args.tokens or (8 if args.smoke else 64)

    cfg, params = make_model(args.smoke)
    host = TransformerHost(cfg, params,
                           env=CostEnv(batch=args.batch, seq=P + N))
    res = compress(host, budget_ratio=args.budget_ratio, P=300)
    assert res is not None, "bench budget must be feasible"

    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "bench_lm.npz")
        fp = res.save(path)
        assert res.save(os.path.join(d, "again.npz")) == fp, \
            "artifact fingerprint must be content-stable"
        art = runtime.load(path)
        assert art.fingerprint == fp and art.plan == res.plan

    B = args.batch
    prompt = jax.random.randint(jax.random.PRNGKey(1), (B, P), 0,
                                cfg.vocab_size)

    # original stack
    step_o = jax.jit(make_serve_step(cfg))
    cache_o = T.init_cache(cfg, B, P + N)
    pre_o, dec_o, _, _ = serve_loop(step_o, params, cache_o, prompt, N)

    # compressed (artifact-backed executor)
    step_c, gp = art.make_serve_step()
    step_c = jax.jit(step_c)
    cache_c = art.init_cache(B, P + N)
    pre_c, dec_c, _, _ = serve_loop(step_c, gp, cache_c, prompt, N)

    # KV-cache parity gate: prefill-by-decode ≡ parallel prefill
    batch = {"tokens": prompt,
             "positions": jnp.broadcast_to(jnp.arange(P)[None], (B, P))}
    y_par = art.apply(batch)
    cache_v = art.init_cache(B, P)
    lv = None
    for t in range(P):
        lv, cache_v = step_c(gp, cache_v, {"tokens": prompt[:, t:t + 1]})
    delta = float(jnp.abs(y_par[:, -1] - lv[:, 0]).max())
    scale = float(jnp.abs(y_par[:, -1]).max()) + 1e-9
    assert delta / scale < 2e-4, f"decode/prefill diverged: {delta}"

    n_orig = len(T.sublayer_kinds(cfg))
    n_units = len(art.graph.units)
    assert n_units < n_orig, "compressed chain must be shallower"

    report = {
        "instance": {"layers": cfg.num_layers, "d_model": cfg.d_model,
                     "batch": B, "prompt": P, "tokens": N,
                     "budget_ratio": args.budget_ratio,
                     "smoke": args.smoke},
        "artifact": {"fingerprint": fp[:16],
                     "units": runtime.ir.count_units(art.graph),
                     "sublayers_original": n_orig,
                     "units_compressed": n_units,
                     "oracle": art.meta.get("oracle")},
        "original": {"prefill_s": pre_o, "decode_s": dec_o,
                     "decode_tok_s": (N - 1) * B / max(dec_o, 1e-9)},
        "compressed": {"prefill_s": pre_c, "decode_s": dec_c,
                       "decode_tok_s": (N - 1) * B / max(dec_c, 1e-9)},
        "measured_decode_speedup": dec_o / max(dec_c, 1e-9),
        "predicted_speedup_v5e": res.speedup,
        "kv_parity_rel_delta": delta / scale,
    }
    if not args.smoke:
        out = os.path.abspath(args.out)
        os.makedirs(os.path.dirname(out), exist_ok=True)
        with open(out, "w") as f:
            json.dump(report, f, indent=2)
        print(f"wrote {out}")
    print(json.dumps(report, indent=2))


if __name__ == "__main__":
    main()
