"""Generalized merged-conv kernel certification (this PR's tentpole).

The kernel now serves *every* segment shape the DP can emit: strided
segments (the downsampling convs that dominate MobileNetV2/ResNet34),
W-axis tiles for very wide images, and zero-copy DMA halos from an
HBM-resident input.  Everything here runs the Pallas kernel in interpret
mode on CPU against ``lax.conv_general_dilated``:

* the acceptance matrix — strides {1, 2} × kernel sizes {1, 3, 5, 7};
* a hypothesis property sweep over ``(stride, kh, kw, tile_ho, tile_wo,
  dtype)`` including ragged last tiles on both axes;
* the 2-D ``(tile_ho, tile_wo)`` VMEM planner's accounting;
* the lane-friendly output-channel tile (``bcout`` regression);
* the input-traffic model backing the halo-bytes-saved bench;
* the stride-aware segment enumerator (k coordinate == true merged
  kernel size on strided spans).
"""
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp import given, settings, st

from repro import kernels
from repro.kernels.merged_conv import (_VMEM_BUDGET, choose_tiles,
                                       input_traffic_model, merged_conv)

TOL = {jnp.float32: dict(rtol=2e-5, atol=2e-5),
       jnp.bfloat16: dict(rtol=2e-2, atol=2e-2)}


def _oracle(x, w, b, stride, act=None):
    return kernels.apply_activation(kernels.merged_conv_ref(x, w, b, stride=stride),
                                act)


# ---------------------------------------------------------------------------
# acceptance matrix: strides {1, 2} × kernel sizes {1, 3, 5, 7}
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("stride", [1, 2])
@pytest.mark.parametrize("k", [1, 3, 5, 7])
def test_strided_merged_conv_matrix(stride, k):
    rng = np.random.default_rng(stride * 100 + k)
    x = jnp.asarray(rng.standard_normal((2, 15, 13, 4)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((k, k, 4, 6)) * 0.1, jnp.float32)
    b = jnp.asarray(rng.standard_normal(6), jnp.float32)
    y = kernels.merged_conv_op(x, w, b, stride=stride, activation="relu",
                           interpret=True)
    yr = _oracle(x, w, b, stride, "relu")
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr),
                               rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("stride", [1, 2, 3])
def test_strided_no_oracle_fallback(stride):
    """With the backend forced to 'pallas', strided convs must go through
    pl.pallas_call (interpret on CPU) — not the jnp fallback."""
    rng = np.random.default_rng(7 + stride)
    x = jnp.asarray(rng.standard_normal((1, 12, 12, 3)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((3, 3, 3, 5)) * 0.1, jnp.float32)
    with kernels.force_backend("pallas"):
        y = kernels.merged_conv_op(x, w, stride=stride, interpret=True)
    np.testing.assert_allclose(np.asarray(y),
                               np.asarray(_oracle(x, w, None, stride)),
                               rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------------------------
# property sweep: (stride, kh, kw, tile_ho, tile_wo, dtype), ragged tiles
# ---------------------------------------------------------------------------

@given(stride=st.integers(1, 3), kh=st.sampled_from([1, 2, 3, 5, 7]),
       kw=st.sampled_from([1, 2, 3, 5]), tile_ho=st.integers(1, 6),
       tile_wo=st.integers(1, 6), h=st.integers(8, 20), w=st.integers(8, 20),
       bf16=st.booleans())
@settings(max_examples=24, deadline=None)
def test_merged_conv_property(stride, kh, kw, tile_ho, tile_wo, h, w, bf16):
    if h < kh or w < kw:
        return
    dtype = jnp.bfloat16 if bf16 else jnp.float32
    rng = np.random.default_rng(stride * 1009 + kh * 131 + kw * 17
                                + tile_ho * 7 + tile_wo * 3 + h * 29 + w)
    x = jnp.asarray(rng.standard_normal((1, h, w, 3)), dtype)
    wt = jnp.asarray(rng.standard_normal((kh, kw, 3, 5)) * 0.1, dtype)
    b = jnp.asarray(rng.standard_normal(5), dtype)
    y = kernels.merged_conv_op(x, wt, b, stride=stride, tile_ho=tile_ho,
                           tile_wo=tile_wo, activation="relu6",
                           interpret=True)
    yr = _oracle(x, wt, b, stride, "relu6")
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(yr, np.float32), **TOL[dtype])


def test_tiling_is_pure_scheduling_all_strides():
    """Any (tile_ho, tile_wo) split produces the same floats per output
    element — the accumulation order per element never changes."""
    rng = np.random.default_rng(3)
    x = jnp.asarray(rng.standard_normal((2, 17, 14, 4)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((3, 3, 4, 4)) * 0.1, jnp.float32)
    for s in (1, 2):
        whole = merged_conv(x, w, stride=s, bcout=4, tile_ho=64, tile_wo=64,
                            interpret=True)
        for tho, two in ((1, 64), (64, 1), (2, 3), (5, 4)):
            tiled = merged_conv(x, w, stride=s, bcout=4, tile_ho=tho,
                                tile_wo=two, interpret=True)
            np.testing.assert_array_equal(np.asarray(whole),
                                          np.asarray(tiled))


# ---------------------------------------------------------------------------
# 2-D VMEM planner
# ---------------------------------------------------------------------------

def _working_set(tho, two, cin, kh, kw, s, itemsize, bcout):
    shi = s * tho + kh - 1
    swi = s * two + kw - 1
    return (2 * shi * swi * cin * itemsize              # double-buffered in
            + kh * kw * cin * bcout * itemsize          # weight block
            + tho * two * bcout * (4 + itemsize))       # fp32 acc + out


@pytest.mark.parametrize("h,w,cin,k,s", [
    (224, 224, 64, 7, 1), (224, 224, 64, 7, 2), (112, 112, 128, 5, 2),
    (8, 8192, 32, 3, 1),                    # panorama: single very wide row
    (4096, 8, 16, 3, 1), (16, 16, 8, 3, 1),
])
def test_choose_tiles_bounds_working_set(h, w, cin, k, s):
    tho, two = choose_tiles(h, w, cin, k, k, s, 4, bcout=128)
    ho = (h - k) // s + 1
    wo = (w - k) // s + 1
    assert 1 <= tho <= ho and 1 <= two <= wo
    assert _working_set(tho, two, cin, k, k, s, 4, 128) <= _VMEM_BUDGET or (
        tho == 1 and two == 1)
    # small images degenerate to a single untiled step
    if h * w * cin <= 2048:
        assert (tho, two) == (ho, wo)


def test_choose_tiles_shrinks_width_for_panorama():
    """A single output row of a very wide image must not bound the block."""
    tho, two = choose_tiles(8, 65536, 64, 3, 3, 1, 4, bcout=128)
    assert tho == 1 and two < 65534
    assert _working_set(1, two, 64, 3, 3, 1, 4, 128) <= _VMEM_BUDGET


# ---------------------------------------------------------------------------
# lane-friendly channel tiling (bcout regression)
# ---------------------------------------------------------------------------

def test_channel_tile_is_multiple_of_8():
    # the old divisor walk degraded to bc=1 on primes; now every choice is
    # a multiple of 8 and the channel axis is padded up instead.
    for cout in (1, 7, 13, 97, 100, 127, 128, 130, 257):
        bc = kernels.channel_tile(cout, None)
        assert bc % 8 == 0
        assert bc <= 128
    assert kernels.channel_tile(130, None) == 128
    assert kernels.channel_tile(24, None) == 24
    # explicit lane-hostile requests are rounded up, never searched down
    assert kernels.channel_tile(100, 7) == 8
    assert kernels.channel_tile(100, 48) == 48


@pytest.mark.parametrize("cout", [7, 13, 100, 130])
def test_odd_channel_counts_correct(cout):
    rng = np.random.default_rng(cout)
    x = jnp.asarray(rng.standard_normal((1, 10, 10, 3)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((3, 3, 3, cout)) * 0.1, jnp.float32)
    b = jnp.asarray(rng.standard_normal(cout), jnp.float32)
    y = kernels.merged_conv_op(x, w, b, stride=2, activation="relu",
                           interpret=True)
    np.testing.assert_allclose(np.asarray(y),
                               np.asarray(_oracle(x, w, b, 2, "relu")),
                               rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------------------------
# input-traffic model (halo-bytes accounting behind the bench sweep)
# ---------------------------------------------------------------------------

def test_input_traffic_single_tile_is_one_read():
    t = input_traffic_model(16, 16, 8, 3, 3, 1, 4, tile_ho=14, tile_wo=14)
    assert t["dma_bytes"] == t["image_bytes"]
    assert t["saved_bytes"] == 0.0          # the old path was also one read


def test_input_traffic_multi_tile_saves_gather():
    t = input_traffic_model(64, 64, 32, 5, 5, 1, 4, tile_ho=8, tile_wo=60)
    # DMA reads the image once plus seam halos — strictly less than the
    # gather's image read + halo'd-tile write + read back.
    assert t["image_bytes"] <= t["dma_bytes"] < t["gather_bytes"]
    assert t["saved_bytes"] > t["image_bytes"]   # reclaimed ≥ one image read
    # halo re-reads are bounded: (k−1) rows per interior seam
    n_th = -(-60 // 8)
    halo_rows = (n_th - 1) * 4 * 64 * 32 * 4
    assert t["dma_bytes"] <= t["image_bytes"] + halo_rows + 4 * 68 * 32 * 4


# ---------------------------------------------------------------------------
# stride-aware enumeration: k == true merged kernel size
# ---------------------------------------------------------------------------

def test_enumerator_k_matches_segment_geometry_on_strided_spans():
    from repro.core.plan import Segment
    from repro.models import cnn

    net = cnn.ConvNet(specs=(
        cnn.ConvSpec(3, 8, 3, 1, act="relu"),
        cnn.ConvSpec(8, 8, 3, 2, act="relu"),      # strided, forced kept
        cnn.ConvSpec(8, 8, 3, 1, act="relu"),
        cnn.ConvSpec(8, 8, 3, 1, act="relu"),
    ), in_hw=16)
    import jax
    params = cnn.init_params(net, jax.random.PRNGKey(0))
    from repro.models.cnn_host import CNNHost
    host = CNNHost(net, params, batch=1)
    enum = host.enumerator()
    found_strided = False
    for i, j, opts in enum.all_spans():
        has_stride = any(net.spec(l).stride > 1 for l in range(i + 1, j + 1))
        for k, (_val, kept) in opts.items():
            K, S = cnn.segment_geometry(net, Segment(i=i, j=j, k=k, kept=kept))
            assert k == K, (i, j, k, kept, K)
            if has_stride and j - i > 1 and K > 3:
                found_strided = True
    # the previously banned strided-then-k>1 merges are now offered
    assert found_strided


def test_strided_merge_replaced_equals_merged():
    """Replaced ≡ merged must hold for a span that merges a stride-2 conv
    with a following 3×3 conv (previously gated out)."""
    import jax
    from repro.core.plan import CompressionPlan, Segment
    from repro.models import cnn
    from repro.models.cnn_host import CNNHost

    net = cnn.ConvNet(specs=(
        cnn.ConvSpec(3, 8, 3, 1, act="relu"),
        cnn.ConvSpec(8, 8, 3, 2, act="relu"),
        cnn.ConvSpec(8, 8, 3, 1, act="relu"),
    ), in_hw=16)
    params = cnn.init_params(net, jax.random.PRNGKey(1))
    host = CNNHost(net, params, batch=2)
    # merge layers 2..3 (stride 2 then k=3): K = 1 + 2 + 2·2 = 7, S = 2
    seg = Segment(i=1, j=3, k=7, kept=(2, 3))
    K, S = cnn.segment_geometry(net, seg)
    assert (K, S) == (7, 2)
    plan = CompressionPlan(num_layers=3, segments=(
        Segment(i=0, j=1, k=3, kept=(1,), original=True), seg))
    x = jax.random.normal(jax.random.PRNGKey(2), (2, 16, 16, 3))
    ra, _ = host.replaced_apply(plan)
    ma, _ = host.merged_apply(plan)
    np.testing.assert_allclose(np.asarray(ra(params, x)),
                               np.asarray(ma(params, x)),
                               rtol=1e-4, atol=1e-4)


def test_wallclock_oracle_median_of_groups():
    from repro.core.latency import WallClockOracle

    calls = {"n": 0}

    def fn():
        calls["n"] += 1
        return jnp.zeros(())

    o = WallClockOracle(warmup=2, iters=10, groups=5)
    lat = o.time_callable(fn)
    assert calls["n"] == 12                 # warmup + iters, protocol shape
    assert lat > 0.0
    # degenerate: fewer iters than groups still times every call once
    calls["n"] = 0
    o2 = WallClockOracle(warmup=1, iters=3, groups=5)
    assert o2.time_callable(fn) > 0.0
    assert calls["n"] == 4
