"""``repro.runtime`` — plan execution as a subsystem, not a host detail.

The compression pipeline (tables → DP → replace → fine-tune → merge)
produces a *plan*; this package owns everything that happens after the
plan is frozen:

* :mod:`repro.runtime.ir` — a backend-neutral **unit IR**: typed records
  for merged-conv / depthwise-conv / low-rank-residual / attention /
  pool / upsample / sublayer units with explicit strides, activation
  epilogues, and skip wiring.  Both hosts lower plans into the same IR
  (``host.lower_plan(plan, params) → UnitGraph``), replacing the former
  per-host ``cnn.MergedUnit`` list and ``transformer_host`` tuple units.
* :mod:`repro.runtime.executor` — one shared interpreter over a
  ``UnitGraph`` that routes every unit through the public kernel entry
  points (:mod:`repro.kernels`: Pallas on TPU, jnp oracles elsewhere),
  including a KV-cache-aware decode path for serving transformers.
* :mod:`repro.runtime.artifact` — a portable **merged-model artifact**
  (``.npz``: plan JSON + unit-graph spec + merged weights) with atomic
  publish and a content fingerprint, so compression runs once and every
  consumer (serving, benchmarks, fine-tuning) loads the same certified
  object: ``CompressResult.save(path)`` / ``runtime.load(path)``.
* :mod:`repro.runtime.serving` — the jitted serve protocol: chunked
  prefill + ``lax.scan`` greedy decode (:func:`serve_loop`), a
  fixed-slot batched request scheduler (:func:`serve_requests`) that
  runs ragged prompt batches through ONE fused prefill+decode scan, and
  the continuous-batching engine (:class:`ContinuousEngine` /
  :func:`serve_continuous`): per-slot generation state vmapped through a
  jitted multi-slot chunk, mid-stream admission into vacated slots, and
  individual retirement on EOS / budget / deadline / NaN-abort.

**The logical-axis contract.**  Artifacts carry their sharding as data:
every unit record (and the graph) ships an ``axes`` map {param keypath →
logical axis names} written by the host at lowering time
(:func:`repro.runtime.ir.annotate_axes`) — 'ffn'/'heads'/'vocab'/'rank'
for transformer units, 'conv_in'/'conv_out' for merged-conv units,
'embed'/'vocab' at graph level.  A consumer resolves the names through a
:class:`repro.sharding.rules.ShardingRules` to place weights
(``runtime.load(path, rules=...)`` device_puts each array straight to
its ``NamedSharding``), and :class:`GraphExecutor` jits prefill/decode
under the mesh with the matching activation and KV-cache ('kv_seq')
constraints.  No rules — or a one-device mesh — runs the identical code
fully replicated; v1 artifacts load with empty annotations and behave
the same way.

**The precision contract.**  Format-v3 artifacts carry per-unit
quantization *as data*: a unit's static record names its mode
(``quant`` ∈ {'none', 'int8', 'w8a8', 'fp8'}), its weights are stored
narrow (``int8`` / ``float8_e4m3fn``), and the symmetric
per-output-channel scales ride as ordinary param arrays (``w_scale`` on
conv units, ``u_scale``/``v_scale`` on low-rank units) with their own
logical-axes annotations — so the fingerprint, sharding, and quarantine
contracts cover them with zero new machinery.  The executor reads the
mode per unit and routes through the same kernel entry points with
``w_scale=…`` (dequant fused into the fp32 accumulator epilogue) and,
for 'w8a8', ``act_quant=…``; fp units in the same graph are untouched,
so mixed-precision graphs need no special casing anywhere downstream
(serving, fine-tuning consumers, benchmarks all just work).  v1/v2
artifacts have no ``quant`` statics and load with the dataclass default
'none' — exactly the fp semantics they were saved with.  The planner
side of the contract (how the DP chooses which units quantize) lives in
:func:`repro.core.tables.quant_sibling_entries`.

**Failure semantics.**  The runtime is the deployment surface, so its
failure contract is explicit:

* ``load`` never runs a questionable model: a torn, corrupt, or
  tampered artifact (fingerprint mismatch) raises
  :class:`ArtifactError` **after quarantining** the bad file to
  ``<path>.corrupt`` — the next load or re-publish of the same path
  starts clean, and the error names the quarantine file and the
  recovery command.  An unsupported format version raises but leaves
  the file in place (it may be valid under other code).
* ``serve_requests`` degrades per-request, never per-process: a slot
  whose logits go non-finite is aborted at that token (other slots of
  the round are bit-untouched), per-request token and wall-clock
  budgets bound runaway work — the wall-clock deadline is enforced per
  decode chunk, not per round — and on a blown deadline the scheduler
  drains cleanly.  The return still unpacks as ``(gen, seconds)``; the
  per-request outcome lives on ``.report`` (:class:`ServeReport`).
* The continuous engine adds the overload contract on top: every
  request ends in exactly one disposition
  (:data:`repro.runtime.serving.DISPOSITIONS` — ``completed`` /
  ``aborted`` / ``shed`` / ``deadline_miss`` / ``unserved``).  The
  admission queue is bounded and **sheds** up front — on overflow, or
  when the deadline-aware shedder predicts (from the EWMA sustained
  decode rate) that a request cannot finish by its deadline — rather
  than admitting work it will half-serve.  A slot that NaN-aborts
  ``slot_nan_limit`` times is quarantined (circuit breaker: the
  poisoned request is reported, never silently re-queued), and
  shutdown **drains**: in-flight requests finish, waiting ones come
  back ``unserved``.  Per-request latency, queue high-water mark, and
  sustained tok/s land on the same :class:`ServeReport`.
* The serving layer fails over across hosts: a worker loss mid-decode
  surfaces as :class:`repro.runtime.serving.WorkerLost`, and
  :func:`serve_with_failover` harvests the finished requests, re-forms
  the engine on the surviving capacity, and replays the in-flight
  requests from their recorded prompts — deterministic decode makes the
  replayed tokens bit-identical, and the :class:`ServeReport` records
  the event (``failovers`` / ``lost_workers`` / ``replayed``) so
  requests never silently vanish.
* Table builds journal their probes and resume bit-identically — that
  half of the contract is documented in :mod:`repro.core.table_cache`;
  the multi-process fan-out and lease/reassignment contract lives in
  :mod:`repro.core.dist_build` and :mod:`repro.launch.distributed`.
"""
from .artifact import (ArtifactError, CompressedArtifact, fingerprint, load,
                       save)
from .executor import (GraphExecutor, cache_shardings, execute,
                       graph_shardings, init_cache, decode_step, jit_apply,
                       make_serve_step, run_units, slot_state)
from .ir import (AttnUnit, ConvUnit, LowRankUnit, PoolUnit, SublayerUnit,
                 UnitGraph, UpsampleUnit, annotate_axes, bind_params,
                 graph_axes, graph_params)
from .serving import (DISPOSITIONS, ContinuousEngine, ServeOutput,
                      ServeReport, WorkerLost, decode_tok_s,
                      generate_fused, greedy_token, pad_prompts,
                      ragged_prompts, random_prompts, serve_continuous,
                      serve_loop, serve_loop_pertoken, serve_requests,
                      serve_with_failover, stack_cache)

__all__ = [
    "ArtifactError", "CompressedArtifact", "fingerprint", "load", "save",
    "GraphExecutor", "cache_shardings", "execute", "graph_shardings",
    "init_cache", "decode_step", "jit_apply", "make_serve_step",
    "run_units", "slot_state",
    "AttnUnit", "ConvUnit", "LowRankUnit", "PoolUnit", "SublayerUnit",
    "UnitGraph", "UpsampleUnit", "annotate_axes", "bind_params",
    "graph_axes", "graph_params",
    "DISPOSITIONS", "ContinuousEngine", "ServeOutput", "ServeReport",
    "WorkerLost", "decode_tok_s", "generate_fused", "greedy_token",
    "pad_prompts", "ragged_prompts", "random_prompts", "serve_continuous",
    "serve_loop", "serve_loop_pertoken", "serve_requests",
    "serve_with_failover", "stack_cache",
]
