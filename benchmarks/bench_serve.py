"""Serving-path benchmark — compressed vs original prefill/decode tok/s.

Exercises the artifact-backed serve path end-to-end: compress a small LM
(analytic oracle + magnitude importance — deterministic, seconds-scale),
publish a merged-model artifact, reload it, and serve it through the
jitted protocol of :mod:`repro.runtime.serving` side by side with the
uncompressed ``make_serve_step`` stack.  Three protocols are timed for
both stacks:

* the PR-4 per-token Python loop (one XLA dispatch per position) — the
  dispatch-bound reference;
* the jitted chunked-prefill + ``lax.scan`` decode loop;
* the fixed-slot batched scheduler (``serve_requests``) over many
  concurrent ragged prompts, batched vs served one prompt at a time;
* the continuous-batching engine (``serve_continuous``) under a seeded
  Poisson arrival trace (``--trace poisson --rate R`` requests/s) —
  per-request latency p50/p99 (submission → retirement, queueing
  included) and sustained tok/s across the whole trace.

``--quantize {int8,w8a8}`` adds a DP-planned quantized leg on its own
weight-traffic-bound decode instance: compress with precision
candidates, assert the planner picked quantized units and that their
narrow weights (+ scales) at least HALVE the weight bytes, then serve
the reloaded v3 artifact and report measured decode tok/s next to the
predicted v5e speedup.

Writes ``results/BENCH_serve.json`` with throughput for every protocol
plus ``mesh_info`` when ``--mesh`` shards the run over the host devices
(``data × model`` logical mesh; run under
``XLA_FLAGS=--xla_force_host_platform_device_count=N`` to get N>1 on
CPU).

  PYTHONPATH=src python -m benchmarks.bench_serve [--smoke] [--mesh]
      [--model-par K] [--trace poisson] [--rate R] [--out PATH]

``--smoke`` (wired into ``make verify`` via scripts/verify.sh) runs the
correctness gates in seconds: artifact round-trip + fingerprint
stability, compressed decode ≡ compressed prefill (KV-cache parity),
scan-loop ≡ per-token-loop token ids, continuous engine ≡ fixed-slot
scheduler ids under the arrival trace, a genuinely shallower unit chain
— and with ``--mesh`` additionally sharded-executor ≡ single-device
logits — so serving-path regressions fail ``make verify`` even where
timing is meaningless.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os
import sys
import tempfile

sys.path.insert(0, os.path.join(os.path.dirname(__file__), os.pardir, "src"))

import jax                                              # noqa: E402
import jax.numpy as jnp                                 # noqa: E402
import numpy as np                                      # noqa: E402

from repro import runtime                               # noqa: E402
from repro.runtime import serving                       # noqa: E402
from repro.configs import get_config                    # noqa: E402
from repro.core import compress                         # noqa: E402
from repro.launch.mesh import make_host_mesh, mesh_info  # noqa: E402
from repro.models import transformer as T               # noqa: E402
from repro.models.transformer_host import (CostEnv,     # noqa: E402
                                           TransformerHost)
from repro.sharding.rules import (make_unit_rules,      # noqa: E402
                                  use_rules)
from repro.train.step import make_serve_step            # noqa: E402


def make_model(smoke: bool):
    base = get_config("smollm-135m").reduced()
    if smoke:
        cfg = dataclasses.replace(base, num_layers=4)
    else:
        cfg = dataclasses.replace(base, num_layers=8, d_model=128,
                                  num_heads=4, num_kv_heads=2, head_dim=32,
                                  d_ff=512, vocab_size=512)
    params, _ = T.init_model(cfg, jax.random.PRNGKey(0))
    return cfg, params


def _stack_report(step, params, make_cache, prompt, N, rules):
    """Per-token vs jitted-scan decode for one serve stack.

    The per-token leg jits the step (the true PR-4 protocol: ONE XLA
    dispatch per token) so ``jit_loop_speedup`` measures what the
    chunked/scan loop buys over dispatch overhead, not over eager mode.
    """
    B, P = prompt.shape
    jstep = jax.jit(step)
    # warm the (B, 1) program so pertoken_prefill_s is steady-state like
    # the scan loop's warmed prefill_s (the decode columns never include
    # compile time — the program is shared); trace under the same rules
    # the timed loop uses, since jit caches by shape, not ambient context
    with use_rules(rules):
        jax.block_until_ready(
            jstep(params, make_cache(B, P + N), {"tokens": prompt[:, :1]})[0])
    pre_pt, dec_pt, _, seq_pt = serving.serve_loop_pertoken(
        jstep, params, make_cache(B, P + N), prompt, N, rules=rules)
    pre_j, dec_j, _, seq_j = serving.serve_loop(
        step, params, make_cache(B, P + N), prompt, N, rules=rules)
    if rules is None:
        # under a mesh the two programs shard reductions differently and
        # can flip greedy argmax ties on a random-init toy (same caveat
        # as the batched leg); the sharded run is gated at logits level
        assert np.array_equal(np.asarray(seq_pt), np.asarray(seq_j)), \
            "jitted scan loop must reproduce the per-token loop's ids"
    return {
        "prefill_s": pre_j, "decode_s": dec_j,
        "decode_tok_s": serving.decode_tok_s(N - 1, B, dec_j),
        "pertoken_prefill_s": pre_pt, "pertoken_decode_s": dec_pt,
        "decode_tok_s_pertoken": serving.decode_tok_s(N - 1, B, dec_pt),
        "jit_loop_speedup": dec_pt / max(dec_j, 1e-9),
    }


def _batched_report(step, params, make_cache, cfg, N, slots, n_prompts,
                    rules):
    """Fixed-slot scheduler over ragged prompts, batched vs one-at-a-time."""
    mat, lens = serving.pad_prompts(
        serving.ragged_prompts(7, n_prompts, 4, 16, cfg.vocab_size))
    gen_b, sec_b = serving.serve_requests(
        step, params, make_cache, mat, lens, tokens=N, slots=slots,
        rules=rules)
    gen_1, sec_1 = serving.serve_requests(
        step, params, make_cache, mat, lens, tokens=N, slots=1, rules=rules)
    if rules is None:
        # Under a mesh the slots=1 round runs replicated (batch 1 does not
        # divide 'data') while the full batch shards — the reordered float
        # reductions flip greedy ties on a random-init toy, so exact id
        # equality is only a gate on the unsharded protocol; the sharded
        # run is certified at the logits level (allclose gates below).
        assert np.array_equal(np.asarray(gen_b), np.asarray(gen_1)), \
            "slot batching must not change greedy generations"
    return {
        "prompts": n_prompts, "slots": slots, "tokens": N,
        "batched_s": sec_b,
        "batched_tok_s": serving.decode_tok_s(N, n_prompts, sec_b),
        "single_slot_s": sec_1,
        "single_slot_tok_s": serving.decode_tok_s(N, n_prompts, sec_1),
        "batch_speedup": sec_1 / max(sec_b, 1e-9),
    }


def _continuous_report(step, params, make_cache, cfg, N, slots, n_prompts,
                       rules, trace, rate):
    """Continuous-batching engine under a seeded arrival trace.

    ``trace='poisson'`` draws inter-arrival gaps from a seeded
    exponential (rate ``rate`` requests/s) so the run replays exactly;
    ``trace='none'`` submits everything up front.  Latency is
    submission → retirement per request (queueing included), reported
    as p50/p99; ``sustained_tok_s`` counts every retired token over the
    whole trace's wall clock.  When unsharded, the engine's ids are
    gated bit-identical against the fixed-slot scheduler: mid-stream
    admission into vacated slots must not change greedy generations.
    """
    mat, lens = serving.pad_prompts(
        serving.ragged_prompts(7, n_prompts, 4, 16, cfg.vocab_size))
    arrivals = None
    if trace == "poisson":
        rng = np.random.RandomState(11)
        arrivals = [float(a) for a in
                    np.cumsum(rng.exponential(1.0 / rate, size=n_prompts))]
    gen_c, sec_c = out = serving.serve_continuous(
        step, params, make_cache, mat, lens, tokens=N, slots=slots,
        rules=rules, arrivals=arrivals)
    rep = out.report
    assert rep.ok and len(rep.completed) == n_prompts, \
        f"trace leg must complete every request: {rep.dispositions}"
    if rules is None:
        gen_f, _ = serving.serve_requests(
            step, params, make_cache, mat, lens, tokens=N, slots=slots,
            rules=rules)
        assert np.array_equal(np.asarray(gen_c), np.asarray(gen_f)), \
            "continuous engine must reproduce the fixed scheduler's ids"
    lat = sorted(rep.latency_s.values())
    return {
        "prompts": n_prompts, "slots": slots, "tokens": N,
        "trace": trace,
        "rate_req_s": rate if trace == "poisson" else None,
        "seconds": sec_c,
        "sustained_tok_s": rep.sustained_tok_s,
        "latency_p50_s": float(np.percentile(lat, 50)),
        "latency_p99_s": float(np.percentile(lat, 99)),
        "queue_peak": rep.queue_peak,
        "admitted": rep.admitted,
    }


def _quantized_report(mode, N):
    """DP-planned quantized serve leg, end to end on its own instance.

    The main bench model is op-overhead-bound at CPU-toy sizes, where
    quantization (correctly) never wins the DP — so this leg runs a
    weight-traffic-bound, decode-shaped instance (wide d_model, batch 1)
    where narrow weights genuinely move the roofline.  It compresses
    with ``--quantize``, asserts the DP picked quantized units, publishes
    and reloads the v3 artifact, serves it through the shared executor,
    and reports weight bytes (fp32 vs narrow+scales, quantized units
    only — the honest reduction), predicted v5e speedup, and measured
    decode tok/s.  The ≥2× weight-byte reduction is asserted, so the
    quantized serve path is CI-gated wherever this leg runs.
    """
    cfg = dataclasses.replace(get_config("smollm-135m").reduced(),
                              d_model=256, d_ff=1024, head_dim=64,
                              num_heads=4, num_kv_heads=4)
    params, _ = T.init_model(cfg, jax.random.PRNGKey(0))
    host = TransformerHost(cfg, params, env=CostEnv(batch=1, seq=32))
    res_fp = compress(host, budget_ratio=0.45, P=300)
    res_q = compress(host, budget_ratio=0.45, P=300, quantize=mode)
    assert res_q is not None and res_fp is not None
    qsegs = [s for s in res_q.plan.segments if s.quant != "none"]
    assert qsegs, "quantized leg: DP must pick at least one quantized unit"

    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "bench_lm_q.npz")
        fp = res_q.save(path)
        art = runtime.load(path)
        assert art.fingerprint == fp and art.plan == res_q.plan

    # weight bytes of the quantized units vs the SAME plan lowered fp
    fp_plan = dataclasses.replace(
        res_q.plan, segments=tuple(dataclasses.replace(s, quant="none")
                                   for s in res_q.plan.segments))
    g_fp = host.lower_plan(fp_plan)
    bytes_fp = bytes_q = 0
    for uf, uq in zip(g_fp.units, art.graph.units):
        if getattr(uq, "quant", "none") == "none":
            continue
        bytes_fp += sum(v.size * v.dtype.itemsize
                        for v in jax.tree_util.tree_leaves(uf.params))
        bytes_q += sum(v.size * v.dtype.itemsize
                       for v in jax.tree_util.tree_leaves(uq.params))
    reduction = bytes_fp / max(bytes_q, 1)
    assert reduction >= 2.0, \
        f"quantized units must at least halve weight bytes: {reduction:.2f}x"

    ex = art.executor(None)
    step_q, gp = ex.serve_step()
    P = 8
    prompt = serving.random_prompts(3, 1, P, cfg.vocab_size)
    _, dec_q, _, _ = serving.serve_loop(step_q, gp, ex.init_cache(1, P + N),
                                        prompt, N)
    return {
        "mode": mode,
        "instance": {"layers": cfg.num_layers, "d_model": cfg.d_model,
                     "d_ff": cfg.d_ff, "batch": 1, "seq": 32,
                     "budget_ratio": 0.45},
        "quantized_units": len(qsegs),
        "weight_bytes_fp32": bytes_fp,
        "weight_bytes_quant": bytes_q,
        "weight_bytes_saved": bytes_fp - bytes_q,
        "weight_byte_reduction": reduction,
        "predicted_speedup_v5e": res_q.speedup,
        "predicted_speedup_v5e_fp_same_budget": res_fp.speedup,
        "decode_s": dec_q,
        "decode_tok_s": serving.decode_tok_s(N - 1, 1, dec_q),
        "artifact_fingerprint": fp[:16],
    }


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="fast correctness pass (CI)")
    ap.add_argument("--mesh", action="store_true",
                    help="shard over the host devices (data × model mesh)")
    ap.add_argument("--model-par", type=int, default=1,
                    help="tensor-parallel split of the host mesh")
    ap.add_argument("--budget-ratio", type=float, default=0.55)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=None)
    ap.add_argument("--tokens", type=int, default=None)
    ap.add_argument("--prompts", type=int, default=None,
                    help="ragged prompts for the batched-scheduler leg")
    ap.add_argument("--quantize", choices=["none", "int8", "w8a8"],
                    default="none",
                    help="add a DP-planned quantized serve leg on a "
                         "weight-bound instance (asserts ≥2× weight-byte "
                         "reduction; reports measured decode tok/s)")
    ap.add_argument("--trace", choices=["none", "poisson"],
                    default="poisson",
                    help="arrival trace for the continuous-engine leg")
    ap.add_argument("--rate", type=float, default=None,
                    help="Poisson arrival rate in requests/s")
    ap.add_argument("--out", default=os.path.join(
        os.path.dirname(__file__), os.pardir, "results",
        "BENCH_serve.json"))
    args = ap.parse_args(argv)
    P = args.prompt_len or (8 if args.smoke else 32)
    N = args.tokens or (8 if args.smoke else 64)
    R = args.prompts or (6 if args.smoke else 16)

    rules = None
    minfo = None
    if args.mesh:
        mesh = make_host_mesh(model=args.model_par)
        rules = make_unit_rules(mesh)
        minfo = mesh_info(mesh)

    cfg, params = make_model(args.smoke)
    host = TransformerHost(cfg, params,
                           env=CostEnv(batch=args.batch, seq=P + N))
    res = compress(host, budget_ratio=args.budget_ratio, P=300)
    assert res is not None, "bench budget must be feasible"

    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "bench_lm.npz")
        fp = res.save(path)
        assert res.save(os.path.join(d, "again.npz")) == fp, \
            "artifact fingerprint must be content-stable"
        art = runtime.load(path, rules=rules)
        assert art.fingerprint == fp and art.plan == res.plan
        # an UNSHARDED load of the same artifact: the single-device
        # reference the --mesh gate compares against
        art_1d = runtime.load(path) if rules is not None else art

    B = args.batch
    prompt = serving.random_prompts(1, B, P, cfg.vocab_size)

    # original stack
    step_o = make_serve_step(cfg)
    orig = _stack_report(step_o, params,
                         lambda b, s: T.init_cache(cfg, b, s), prompt, N,
                         rules)

    # compressed (artifact-backed, mesh-aware executor)
    ex = art.executor(rules)
    step_c, gp = ex.serve_step()
    comp = _stack_report(step_c, gp, ex.init_cache, prompt, N, rules)

    # batched scheduler (compressed stack — the serving product path)
    batched = _batched_report(step_c, gp, ex.init_cache, cfg, N, B, R,
                              rules)

    # continuous-batching engine under the seeded arrival trace — the
    # engine vmaps the per-slot step over a slot-stacked cache and is
    # certified on a single device; the sharded run keeps the fixed
    # scheduler above, so this leg is skipped under --mesh
    continuous = None
    if rules is None:
        rate = args.rate or (16.0 if args.smoke else 8.0)
        continuous = _continuous_report(step_c, gp, ex.init_cache, cfg, N,
                                        B, R, rules, args.trace, rate)

    # KV-cache parity gate: decode through the whole prompt ≡ parallel
    # prefill at the last position (under the mesh when --mesh)
    batch = {"tokens": prompt,
             "positions": jnp.broadcast_to(jnp.arange(P)[None], (B, P))}
    y_par = ex.apply(batch)
    _, _, lv, _ = serving.serve_loop(step_c, gp, ex.init_cache(B, P), prompt,
                                     1, rules=rules)
    delta = float(jnp.abs(y_par[:, -1] - lv).max())
    scale = float(jnp.abs(y_par[:, -1]).max()) + 1e-9
    assert delta / scale < 2e-4, f"decode/prefill diverged: {delta}"

    if rules is not None:
        # sharded ≡ single-device logits (the mesh smoke gate); art_1d
        # was loaded WITHOUT rules so its params really are unsharded
        y_single = runtime.execute(art_1d.graph, batch)
        sdelta = float(jnp.abs(y_par - y_single).max()) / scale
        assert sdelta < 2e-4, f"sharded executor diverged: {sdelta}"

    n_orig = len(T.sublayer_kinds(cfg))
    n_units = len(art.graph.units)
    assert n_units < n_orig, "compressed chain must be shallower"

    # DP-planned quantized leg (own weight-bound instance; single-device
    # — scales shard like any param, but the gate here is the precision
    # path, which --mesh does not change)
    quantized = None
    if args.quantize != "none":
        quantized = _quantized_report(args.quantize, N)

    report = {
        "instance": {"layers": cfg.num_layers, "d_model": cfg.d_model,
                     "batch": B, "prompt": P, "tokens": N,
                     "budget_ratio": args.budget_ratio,
                     "smoke": args.smoke},
        "mesh_info": minfo,
        "artifact": {"fingerprint": fp[:16],
                     "units": runtime.ir.count_units(art.graph),
                     "sublayers_original": n_orig,
                     "units_compressed": n_units,
                     "oracle": art.meta.get("oracle")},
        "original": orig,
        "compressed": comp,
        "batched": batched,
        "continuous": continuous,
        "quantized": quantized,
        "measured_decode_speedup":
            orig["decode_s"] / max(comp["decode_s"], 1e-9),
        "jit_loop_speedup_compressed": comp["jit_loop_speedup"],
        "predicted_speedup_v5e": res.speedup,
        "kv_parity_rel_delta": delta / scale,
    }
    if not args.smoke:
        from repro.launch.distributed import publish_json

        out = os.path.abspath(args.out)
        if publish_json(out, report) is not None:
            print(f"wrote {out}")
    print(json.dumps(report, indent=2))


if __name__ == "__main__":
    main()
