"""Deterministic fault injection for the crash-safe compression pipeline.

Table construction is an hours-long job on real networks, and the crash
paths it must survive — a SIGKILL mid-bucket, a torn journal append, a
flaky probe, a NaN'd serving slot — are exactly the ones ordinary tests
never reach.  This module gives the pipeline *named injection points*
whose behavior is deterministic and scriptable, so every recovery path in
:mod:`repro.core.probe_engine`, :mod:`repro.core.table_cache`,
:mod:`repro.checkpoint.ckpt`, and :mod:`repro.runtime.serving` is
exercised by a reproducible test instead of luck.

Design:

* Production code calls :func:`hit(point)` at an injection point (and
  :func:`mangle(point, data)` around journal writes).  With no plan
  active both are near-free no-ops — one module-global ``is None`` check
  — so the hooks stay in shipping code.
* A test activates a :class:`FaultPlan` via the :func:`inject` context
  manager.  Rules are counted per point: ``Fault(point, action, nth=3,
  times=2)`` fires on the 3rd and 4th hit of ``point`` only, which makes
  retry/backoff paths testable ("fail twice, then succeed").
* Actions: ``"raise"`` (a :class:`FaultError` — a *retryable* failure),
  ``"kill"`` (a :class:`FaultKill` — an in-process stand-in for SIGKILL;
  derives :class:`BaseException` so no retry loop may swallow it),
  ``"exit"`` (``os._exit`` — a REAL crash, for subprocess kill-and-resume
  tests), ``"delay"`` (``time.sleep`` — stragglers/timeouts), ``"torn"``
  (truncate the bytes of the guarded write, then kill at the matching
  ``<point>.done`` hit — a torn write only matters if the process died
  before completing it), and ``"garble"`` (replace a guarded journal
  line with complete-but-unparsable bytes — a corrupted record, as
  opposed to a torn one).
* **Process-level actions** target a *worker subprocess* of the
  distributed build by index: ``kill-worker:<idx>@<point>``,
  ``stall-worker:<idx>@<point>~seconds``, ``corrupt-shard:<idx>@<point>``.
  These never fire in the process holding the plan — the coordinator
  translates them into each worker's ``REPRO_FAULTS`` environment via
  :func:`worker_env_spec` (kill-worker → ``exit`` (a real crash, status
  17), stall-worker → ``delay``, corrupt-shard → ``garble``), so "kill
  worker 0 at its 2nd claimed item" is one declarative rule on the
  coordinator.
* ``REPRO_FAULTS="exit@tables.bucket:3"`` activates a plan from the
  environment — how a *separate process* is crashed for the true
  kill-and-resume smoke (``python -m repro.testing.faults --smoke``,
  wired into ``scripts/verify.sh``).

Injection points currently wired into the pipeline:

=====================  =====================================================
``probe.prepare``      before compiling/first-calling a latency-probe bucket
``probe.time``         before each timed measurement of a bucket
``tables.bucket``      after a bucket's result is journaled (kill here ⇒
                       resume must replay the journal bit-identically)
``tables.importance``  after an importance probe/batch is journaled
``journal.append``     ``mangle`` over the journal line bytes (torn writes)
``journal.append.done``after the journal bytes hit the disk
``table_cache.publish``before the built tables are atomically published
``serve.arrival``      per request ingested by the continuous serve engine
                       (``delay`` ⇒ a stalled frontend/network)
``serve.admit``        per request admitted into a decode slot
``serve.chunk``        before each multi-slot chunk dispatch (``delay`` ⇒
                       a slow-decode straggler iteration)
``serve.nan``          *declarative*: ``nan@serve.nan:rid=R,t=G`` poisons
                       request ``R``'s logits at generation index ``G``
                       inside the jitted chunk (read via
                       :func:`serve_nan_spec`, never :func:`hit`)
``serve.worker``       before each chunk dispatch, raised as a
                       :class:`~repro.runtime.serving.WorkerLost` (a lost
                       serving process → drain/re-form/replay failover)
``dist.claim``         after a distributed worker claims a work-item lease
``dist.item``          after claim, before execution — a kill here dies
                       holding the lease with no result (the canonical
                       mid-bucket worker death)
``dist.done``          after an item's done marker is written
``dist.shard.append``  ``mangle`` over a worker's shard-journal line
                       (``garble``/``torn`` ⇒ corrupt/torn shard records;
                       ``dist.shard.append.done`` after the fsync)
=====================  =====================================================

NaN injection for serving cannot go through :func:`hit` (it must run
inside a jitted ``lax.scan``); :func:`nan_logits_hook` builds the
deterministic ``logit_hook`` consumed by
:func:`repro.runtime.serving.serve_requests`, and the continuous engine
reads request-targeted ``nan`` rules through :func:`serve_nan_spec`
(slot↔request binding is dynamic there, so the rule names the request).
:class:`TickClock` is the virtual clock that makes the engine's
deadline/shedding behavior deterministic under test.
"""
from __future__ import annotations

import contextlib
import dataclasses
import os
import threading
import time

ACTIONS = ("raise", "kill", "exit", "delay", "torn", "nan", "garble",
           "kill-worker", "stall-worker", "corrupt-shard")

#: Actions that target a worker subprocess (carry a worker index and are
#: translated into that worker's environment by :func:`worker_env_spec`
#: instead of firing locally).
PROCESS_ACTIONS = ("kill-worker", "stall-worker", "corrupt-shard")

#: What a ``garble`` rule leaves on disk: a complete (newline-terminated)
#: but unparsable journal line — the reader must treat it as corrupt, not
#: torn.
GARBLED_LINE = b"#garbled journal record#\n"


class FaultError(RuntimeError):
    """An injected *retryable* failure (a flaky probe, a failed write)."""


class FaultKill(BaseException):
    """In-process stand-in for SIGKILL.

    Derives :class:`BaseException` so ``except Exception`` retry loops in
    the code under test can never swallow it — exactly like the real
    signal, the only valid reaction is to die with journals flushed.
    """


@dataclasses.dataclass(frozen=True)
class Fault:
    """One injection rule: at hits ``nth .. nth+times-1`` of ``point``,
    perform ``action``."""

    point: str
    action: str
    nth: int = 1            # 1-based hit index the rule first fires on
    times: int = 1          # consecutive hits it stays armed for
    seconds: float = 0.0    # "delay": sleep duration
    keep_bytes: int = 8     # "torn": bytes of the write that reach disk
    exit_code: int = 17     # "exit": status for the hard crash
    rid: int = -1           # "nan": target request id (serve.nan)
    at: int = -1            # "nan": generation index to poison
    widx: int = -1          # process actions: target worker index

    def __post_init__(self):
        if self.action not in ACTIONS:
            raise ValueError(f"unknown action {self.action!r}; "
                             f"expected one of {ACTIONS}")

    def armed(self, n: int) -> bool:
        return self.nth <= n < self.nth + self.times


class FaultPlan:
    """A set of :class:`Fault` rules with per-point hit counters.

    Thread-safe: probe pre-compilation runs on a worker thread, so
    counters are guarded.  ``fired`` records ``(point, hit_index,
    action)`` for post-mortem assertions in tests.
    """

    def __init__(self, *rules: Fault):
        self.rules = tuple(rules)
        self.fired: list[tuple[str, int, str]] = []
        self._counts: dict[str, int] = {}
        self._pending_kill: set[str] = set()
        self._lock = threading.Lock()

    def _arm(self, point: str) -> Fault | None:
        """Count one hit of ``point`` and return the rule it arms.

        Worker-targeted rules (``widx >= 0``) never arm locally: they
        are directives for :func:`worker_env_spec` to translate into the
        target worker's environment, and the coordinator hits the same
        points itself on its inline-fallback path.
        """
        n = self._counts[point] = self._counts.get(point, 0) + 1
        for rule in self.rules:
            if rule.widx >= 0:
                continue
            if rule.point == point and rule.armed(n):
                self.fired.append((point, n, rule.action))
                return rule
        return None

    def hit(self, point: str) -> None:
        with self._lock:
            rule = self._arm(point)
            kill_pending = point in self._pending_kill
            if kill_pending:
                self._pending_kill.discard(point)
        if kill_pending:                     # completes a torn write
            raise FaultKill(f"torn write at {point}")
        if rule is None:
            return
        if rule.action == "raise":
            raise FaultError(f"injected failure at {point}")
        if rule.action == "kill":
            raise FaultKill(f"injected kill at {point}")
        if rule.action == "exit":            # pragma: no cover — dies
            os._exit(rule.exit_code)
        if rule.action == "delay":
            time.sleep(rule.seconds)

    def mangle(self, point: str, data: bytes) -> bytes:
        """Apply a ``torn`` rule to the bytes of a guarded write.

        The truncated bytes ARE written by the caller; the matching
        ``<point>.done`` hit then kills the process — the on-disk state a
        crash mid-``write(2)`` leaves behind.
        """
        with self._lock:
            rule = self._arm(point)
            if rule is not None and rule.action == "torn":
                self._pending_kill.add(point + ".done")
                return data[: rule.keep_bytes]
        if rule is None:
            return data
        if rule.action == "garble":          # corrupt, not torn: the full
            return GARBLED_LINE              # line lands, but unparsable
        # non-torn rules on a mangle point behave like hit() rules
        if rule.action == "raise":
            raise FaultError(f"injected failure at {point}")
        if rule.action == "kill":
            raise FaultKill(f"injected kill at {point}")
        if rule.action == "exit":            # pragma: no cover — dies
            os._exit(rule.exit_code)
        if rule.action == "delay":
            time.sleep(rule.seconds)
        return data


_ACTIVE: FaultPlan | None = None
_ENV_PLAN: FaultPlan | None = None
_ENV_PARSED = False

ENV_VAR = "REPRO_FAULTS"


def parse_env_spec(spec: str) -> FaultPlan:
    """``"action@point:nth[xtimes][~seconds]"`` items, ``;``-separated.

    Examples: ``exit@tables.bucket:3`` (hard-crash on the 3rd bucket),
    ``raise@probe.prepare:1x2`` (fail the first two prepare attempts),
    ``delay@probe.time:1~0.5`` (0.5 s straggler on the first timing).

    Request-targeted serve rules use key=value counts instead:
    ``nan@serve.nan:rid=1,t=2`` poisons request 1's logits at generation
    index 2 (see :func:`serve_nan_spec`).  Process actions carry the
    target worker index on the action token:
    ``kill-worker:0@dist.item:2`` kills worker 0 at its 2nd claimed item.
    """
    rules = []
    for item in filter(None, (s.strip() for s in spec.split(";"))):
        action, _, rest = item.partition("@")
        point, _, counts = rest.partition(":")
        widx = -1
        base, sep, wid = action.partition(":")
        if sep and base in PROCESS_ACTIONS:
            action, widx = base, int(wid)
        if not (action and point):
            raise ValueError(f"bad {ENV_VAR} item {item!r} "
                             "(want action@point[:nth[xtimes][~seconds]])")
        if "=" in counts:                    # key=value form (serve.nan)
            kv = dict(p.split("=", 1) for p in counts.split(","))
            rules.append(Fault(point=point, action=action, widx=widx,
                               rid=int(kv.get("rid", -1)),
                               at=int(kv.get("t", kv.get("at", -1)))))
            continue
        counts, _, seconds = (counts or "1").partition("~")
        nth, _, times = counts.partition("x")
        rules.append(Fault(point=point, action=action, widx=widx,
                           nth=int(nth or 1), times=int(times or 1),
                           seconds=float(seconds or 0.0)))
    return FaultPlan(*rules)


def worker_env_spec(widx: int, plan: FaultPlan | None = None) -> str | None:
    """The ``REPRO_FAULTS`` spec for worker ``widx``, or ``None``.

    Translates the active plan's process-level rules targeting this
    worker into worker-local primitives: ``kill-worker`` → ``exit`` (a
    REAL crash, status 17), ``stall-worker`` → ``delay`` (the worker
    survives but its leases expire), ``corrupt-shard`` → ``garble`` at
    ``dist.shard.append`` (the record lands complete but unparsable).
    The coordinator's spawn path calls this for every worker it starts.
    """
    plan = plan if plan is not None else active()
    if plan is None:
        return None
    parts = []
    for r in plan.rules:
        if r.widx != widx:
            continue
        counts = f"{r.nth}x{r.times}"
        if r.action == "kill-worker":
            parts.append(f"exit@{r.point}:{counts}")
        elif r.action == "stall-worker":
            parts.append(f"delay@{r.point}:{counts}~{r.seconds}")
        elif r.action == "corrupt-shard":
            parts.append(f"garble@{r.point or 'dist.shard.append'}:{counts}")
    return ";".join(parts) or None


def active() -> FaultPlan | None:
    """The plan in effect: an :func:`inject` context, else ``REPRO_FAULTS``."""
    global _ENV_PLAN, _ENV_PARSED
    if _ACTIVE is not None:
        return _ACTIVE
    if not _ENV_PARSED:
        _ENV_PARSED = True
        spec = os.environ.get(ENV_VAR)
        if spec:
            _ENV_PLAN = parse_env_spec(spec)
    return _ENV_PLAN


def env_reload() -> FaultPlan | None:
    """Re-parse ``REPRO_FAULTS`` after the lazy parse already ran.

    :func:`active` caches the env parse on first use; a test or smoke
    that mutates the env var mid-process (e.g. the serve fault smoke,
    which runs a clean pass first) calls this to pick the change up.
    Returns the now-active plan.
    """
    global _ENV_PLAN, _ENV_PARSED
    _ENV_PLAN = None
    _ENV_PARSED = False
    return active()


def hit(point: str) -> None:
    """Injection point: no-op unless an active plan has a rule for it."""
    plan = active()
    if plan is not None:
        plan.hit(point)


def serve_nan_spec() -> dict[int, int]:
    """Request-targeted NaN rules of the active plan: ``{rid: gen_idx}``.

    The continuous serve engine reads this per chunk and poisons request
    ``rid``'s logits at generation index ``gen_idx`` inside the jitted
    multi-slot scan (the slot↔request binding is dynamic, so the rule
    names the request, not the slot).  Declared as
    ``nan@serve.nan:rid=R,t=G`` in ``REPRO_FAULTS`` or
    ``Fault("serve.nan", "nan", rid=R, at=G)`` under :func:`inject`.
    """
    plan = active()
    if plan is None:
        return {}
    return {r.rid: r.at for r in plan.rules
            if r.point == "serve.nan" and r.action == "nan" and r.rid >= 0}


class TickClock:
    """Deterministic virtual clock: every call returns the current time,
    then advances it by ``dt``.

    Injected as ``clock=`` into the serve engines, it decouples
    deadline/shedding/latency behavior from wall time — with the
    continuous engine's one-read-per-chunk discipline, a chunk of ``C``
    scan steps always "takes" exactly ``dt`` seconds, so shed decisions
    and deadline misses are bit-reproducible in tests.
    """

    def __init__(self, dt: float = 1.0, t0: float = 0.0):
        self.dt = float(dt)
        self.t = float(t0)

    def __call__(self) -> float:
        t = self.t
        self.t += self.dt
        return t


def mangle(point: str, data: bytes) -> bytes:
    """Write-guard injection point: may truncate ``data`` (torn write)."""
    plan = active()
    return data if plan is None else plan.mangle(point, data)


@contextlib.contextmanager
def inject(*rules: Fault):
    """Activate a fault plan for the dynamic extent of the context."""
    global _ACTIVE
    prev, _ACTIVE = _ACTIVE, FaultPlan(*rules)
    try:
        yield _ACTIVE
    finally:
        _ACTIVE = prev


def nan_logits_hook(slot: int, step: int):
    """Deterministic NaN injection for serving: a ``logit_hook`` that
    poisons ``slot``'s logits at scan step ``step`` (jit-compatible —
    runs inside the fused prefill+decode ``lax.scan``)."""
    import jax.numpy as jnp

    def hook(logits, t):
        poisoned = logits.at[slot].set(jnp.nan)
        return jnp.where(jnp.asarray(t) == step, poisoned, logits)
    return hook


# ---------------------------------------------------------------------------
# Kill-and-resume smoke: a REAL child-process crash mid-table-build, then
# a resume that must be bit-identical to an uninterrupted build.
# Wired into scripts/verify.sh; also usable standalone:
#
#   PYTHONPATH=src JAX_PLATFORMS=cpu python -m repro.testing.faults --smoke
# ---------------------------------------------------------------------------

def _smoke_host():
    import jax

    from repro.models import cnn, cnn_host, zoo

    net = zoo.tiny_resnet(num_classes=4, in_hw=8, width=4, blocks=(2,))
    params = cnn.init_params(net, jax.random.PRNGKey(0))
    return cnn_host.CNNHost(net, params, batch=4), params


def _smoke_build(cache_dir: str | None):
    from repro.core import build_tables

    host, params = _smoke_host()
    return build_tables(host, params=params, cache_dir=cache_dir)


def kill_resume_smoke(kill_at_bucket: int = 4) -> dict:
    """Crash a child's table build at the Nth journaled bucket (hard
    ``os._exit`` — no Python cleanup), resume in this process, and verify
    the resumed tables are bit-identical to an uninterrupted build."""
    import glob
    import tempfile

    from repro.testing.subproc import run_module, subprocess_env

    with tempfile.TemporaryDirectory() as d:
        env = subprocess_env(
            platform=os.environ.get("JAX_PLATFORMS", "cpu"),
            faults_spec=f"exit@tables.bucket:{kill_at_bucket}")
        r = run_module("repro.testing.faults", "--child", d,
                       env=env, check=False)
        if r.returncode != 17:
            raise AssertionError(
                f"child was expected to die at bucket {kill_at_bucket} "
                f"(exit 17), got {r.returncode}:\n{r.stdout}{r.stderr}")
        journals = glob.glob(os.path.join(d, "*.journal"))
        if len(journals) != 1:
            raise AssertionError(f"expected 1 journal after the crash, "
                                 f"found {journals}")
        resumed = _smoke_build(d)
        reference = _smoke_build(None)
        if resumed.entries != reference.entries:
            raise AssertionError("resumed tables diverged from the "
                                 "uninterrupted build")
        if resumed.num_pruned != reference.num_pruned:
            raise AssertionError("resumed Pareto drops diverged")
        if resumed.stats.num_journal_hits < kill_at_bucket - 1:
            raise AssertionError(
                f"resume replayed only {resumed.stats.num_journal_hits} "
                f"journaled buckets (expected >= {kill_at_bucket - 1})")
        if glob.glob(os.path.join(d, "*.journal")):
            raise AssertionError("journal not cleaned up after publish")
        return {
            "killed_at_bucket": kill_at_bucket,
            "journal_hits_on_resume": resumed.stats.num_journal_hits,
            "entries": resumed.num_entries,
            "bit_identical": True,
        }


# ---------------------------------------------------------------------------
# Continuous-serving fault smoke: one seeded arrival trace served clean,
# then re-served under a REPRO_FAULTS spec combining a request-targeted
# NaN, a delayed arrival, and a slow-decode straggler chunk — asserting
# the dispositions and that every surviving request is BIT-identical to
# the clean run.  Wired into scripts/verify.sh; also standalone:
#
#   PYTHONPATH=src JAX_PLATFORMS=cpu python -m repro.testing.faults \
#       --serve-smoke
# ---------------------------------------------------------------------------

def serve_fault_smoke() -> dict:
    """Continuous-engine overload/fault smoke (in-process, deterministic).

    Serves four staggered requests on two slots clean, then again under
    ``nan@serve.nan:rid=1,t=2`` + ``delay@serve.arrival`` +
    ``delay@serve.chunk`` — request 1 must abort at generation index 2
    while requests 0/2/3 complete with tokens bit-identical to the
    fault-free run, and both delay rules must actually fire.

    Module identity matters: the env plan is (re)loaded on the canonical
    ``repro.testing.faults`` module — the one the serving code imports —
    because under ``python -m`` this function may execute in
    ``__main__``, a *different* module object.
    """
    import dataclasses as _dc

    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.configs import get_config
    from repro.models import transformer as T
    from repro.runtime import serving
    from repro.testing import faults as canonical
    from repro.train.step import make_serve_step

    cfg = _dc.replace(
        get_config("smollm-135m").reduced(), num_layers=2, d_model=64,
        num_heads=4, num_kv_heads=2, head_dim=16, d_ff=128, vocab_size=128)
    params, _ = T.init_model(cfg, jax.random.PRNGKey(0))
    step = make_serve_step(cfg)

    def mk(b, s):
        return T.init_cache(cfg, b, s)

    N = 6
    prompt = serving.random_prompts(7, 4, 5, cfg.vocab_size)
    lens = jnp.full((4,), 5, jnp.int32)
    kw = dict(tokens=N, slots=2, chunk=3, arrivals=[0.0, 0.5, 1.0, 1.5])
    spec = ("nan@serve.nan:rid=1,t=2;delay@serve.arrival:2~0.02;"
            "delay@serve.chunk:3~0.02")
    prev_env = os.environ.get(ENV_VAR)
    os.environ.pop(ENV_VAR, None)
    canonical.env_reload()
    try:
        clean = serving.serve_continuous(
            step, params, mk, prompt, lens, clock=canonical.TickClock(),
            **kw)
        os.environ[ENV_VAR] = spec
        plan = canonical.env_reload()
        out = serving.serve_continuous(
            step, params, mk, prompt, lens, clock=canonical.TickClock(),
            **kw)
    finally:
        if prev_env is None:
            os.environ.pop(ENV_VAR, None)
        else:
            os.environ[ENV_VAR] = prev_env
        canonical.env_reload()
    gen, cg = np.asarray(out[0]), np.asarray(clean[0])
    report = out.report
    if report.aborted != {1: 2}:
        raise AssertionError(f"expected request 1 aborted at generation "
                             f"index 2, got {report.aborted}")
    if sorted(report.completed) != [0, 2, 3]:
        raise AssertionError(f"expected requests 0/2/3 completed, got "
                             f"{sorted(report.completed)}")
    for r in (0, 2, 3):
        if not (gen[r] == cg[r]).all():
            raise AssertionError(
                f"surviving request {r} diverged from the fault-free run: "
                f"{gen[r].tolist()} vs {cg[r].tolist()}")
    if not (gen[1, :2] == cg[1, :2]).all() or not (gen[1, 2:] == 0).all():
        raise AssertionError(f"aborted request 1 not truncated at index 2: "
                             f"{gen[1].tolist()}")
    delays = [f for f in plan.fired if f[2] == "delay"]
    if len(delays) < 2:
        raise AssertionError(f"expected the delayed-arrival AND straggler-"
                             f"chunk rules to fire, saw {plan.fired}")
    return {
        "dispositions": report.dispositions,
        "aborted": report.aborted,
        "queue_peak": report.queue_peak,
        "delay_rules_fired": [f"{p}:{n}" for p, n, _ in delays],
        "survivors_bit_identical": True,
    }


def main(argv=None):
    import argparse
    import json

    ap = argparse.ArgumentParser(prog="python -m repro.testing.faults")
    ap.add_argument("--smoke", action="store_true",
                    help="kill-and-resume table-build smoke (verify.sh leg)")
    ap.add_argument("--serve-smoke", action="store_true",
                    help="continuous-serving fault smoke: NaN + straggler "
                         "under REPRO_FAULTS, survivor exactness asserted")
    ap.add_argument("--child", metavar="CACHE_DIR", default=None,
                    help=argparse.SUPPRESS)   # internal: the crashed build
    args = ap.parse_args(argv)
    if args.child is not None:
        _smoke_build(args.child)
        print("CHILD_COMPLETED")               # only reached if not killed
        return
    if args.smoke:
        print(json.dumps(kill_resume_smoke(), indent=2))
        print("FAULT_SMOKE_OK")
        return
    if args.serve_smoke:
        print(json.dumps(serve_fault_smoke(), indent=2))
        print("SERVE_FAULT_SMOKE_OK")
        return
    ap.print_help()


if __name__ == "__main__":
    main()
