"""jit'd public wrappers for the Pallas kernels.

Each ``*_op``:
* pads inputs to tile boundaries, calls the kernel, slices back;
* dispatches to the Pallas path on TPU and to the jnp oracle elsewhere
  (``pl.pallas_call`` does not lower on the CPU backend; interpret=True is
  for tests only — far too slow inside real models);
* is differentiable: ``flash_attention_op`` uses ``jax.custom_vjp`` with the
  Pallas forward and the reference backward (recompute-style, consistent
  with the training remat policy); the other ops are linear/elementwise and
  get transparent AD via the oracle path off-TPU.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from . import quant, ref
from .depthwise_conv import choose_group_block, depthwise_conv
from .flash_attention import flash_attention
from .merged_conv import merged_conv
from .merged_ffn import merged_ffn
from .rglru_scan import rglru_scan
from .rmsnorm import rmsnorm

_FORCE = {"mode": None}       # tests can force 'pallas' | 'ref'


def _use_pallas() -> bool:
    if _FORCE["mode"] == "pallas":
        return True
    if _FORCE["mode"] == "ref":
        return False
    return jax.default_backend() == "tpu"


def _pad_to(x, axis, mult):
    pad = (-x.shape[axis]) % mult
    if pad == 0:
        return x, 0
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths), pad


# ---------------------------------------------------------------------------

def merged_ffn_op(x, u, v, *, u_scale=None, v_scale=None,
                  act_quant: str = "none", interpret: bool = False):
    """(..., D) rank-r residual; pads tokens/rank/features to 128.

    Quantized factors: ``u_scale`` (per-rank-column) + ``v_scale``
    (per-output-column) mark ``u``/``v`` as narrow (int8/fp8);
    ``act_quant="w8a8"`` additionally quantizes the activation panel
    per-tensor at the call site (its scale folds into ``u_scale`` —
    the kernel sees ONE scale pair; the residual stays exact fp).
    """
    if not (_use_pallas() or interpret):
        if u_scale is not None:
            return ref.merged_ffn_qref(x, u, v, u_scale, v_scale,
                                       act_quant=act_quant)
        return ref.merged_ffn_ref(x, u, v)
    shape = x.shape
    d = shape[-1]
    n = x.size // d
    x2 = x.reshape(n, d)
    x2, _ = _pad_to(x2, 0, 128)       # token rows
    x2, pd = _pad_to(x2, 1, 128)      # feature dim
    u_p, pr = _pad_to(u, 1, 128)      # rank
    v_p, _ = _pad_to(v, 0, 128)
    if pd:
        u_p = jnp.pad(u_p, ((0, pd), (0, 0)))
        v_p = jnp.pad(v_p, ((0, 0), (0, pd)))
    bm = 256 if x2.shape[0] % 256 == 0 else 128
    us = vs = xq = None
    if u_scale is not None:
        us = jnp.pad(u_scale.astype(jnp.float32), (0, pr))
        vs = jnp.pad(v_scale.astype(jnp.float32), (0, pd))
        if act_quant == "w8a8":
            xq, x_scale = quant.quantize_int8(x2)
            us = us * x_scale
    y = merged_ffn(x2, u_p, v_p, bm=bm, u_scale=us, v_scale=vs, xq=xq,
                   interpret=interpret)
    return y[:n, :d].reshape(shape)


def rmsnorm_op(x, g, *, eps: float = 1e-6, interpret: bool = False):
    if not (_use_pallas() or interpret):
        return ref.rmsnorm_ref(x, g, eps)
    shape = x.shape
    x2 = x.reshape(-1, shape[-1])
    x2, pm = _pad_to(x2, 0, 128)
    bm = 128 if shape[-1] >= 8192 else 256
    bm = min(bm, x2.shape[0])
    y = rmsnorm(x2, g, eps=eps, bm=bm, interpret=interpret)
    if pm:
        y = y[:-pm]
    return y.reshape(shape)


def channel_tile(cout: int, requested: int | None) -> int:
    """Lane-friendly output-channel tile: always a multiple of 8.

    The old divisor walk (``while cout_p % bc: bc -= 1``) could degrade to
    lane-hostile tiles like ``bc=1`` on odd channel counts; instead the
    channel axis is padded *up* to a multiple of the chosen tile (ideally
    the full 128-lane width), never searched down.  Explicit requests are
    rounded to [8, 128] — one lane width is the widest useful block.
    """
    if requested is not None:
        return max(8, min(-(-requested // 8) * 8, 128))
    if cout >= 128:
        return 128
    return -(-max(cout, 8) // 8) * 8


def merged_conv_op(x, w, b=None, *, stride: int = 1,
                   activation: str | None = None,
                   tile_ho: int | None = None, tile_wo: int | None = None,
                   bcout: int | None = None, w_scale=None,
                   act_quant: str = "none", interpret: bool = False):
    """Merged-segment conv (VALID, stride ``s``) with fused bias + boundary
    activation.

    ``tile_ho``/``tile_wo`` (output tile) and ``bcout`` (output-channel
    tile) default to the kernel's 2-D VMEM planner; pass explicit values to
    sweep.  Strided segments run through the Pallas kernel too — no
    jnp-oracle fallback on TPU.

    Quantized weights: ``w_scale`` (per-output-channel, ``(Cout,)``)
    marks ``w`` as narrow (int8/fp8); ``act_quant="w8a8"`` quantizes the
    activation per-tensor here, folding its scale into ``w_scale`` so
    the kernel applies ONE scale in the fp32 epilogue.
    """
    if not (_use_pallas() or interpret):
        if w_scale is not None:
            y = ref.merged_conv_qref(x, w, b, w_scale, stride=stride,
                                     act_quant=act_quant)
        else:
            y = ref.merged_conv_ref(x, w, b, stride=stride)
        return ref.apply_activation(y, activation)
    cout = w.shape[-1]
    bc = channel_tile(cout, bcout)
    w_p, pc = _pad_to(w, 3, bc)
    b_p = None if b is None else jnp.pad(b, (0, pc))
    ws = out_dtype = None
    if w_scale is not None:
        ws = jnp.pad(w_scale.astype(jnp.float32), (0, pc))
        out_dtype = x.dtype
        if act_quant == "w8a8":
            x, x_scale = quant.quantize_int8(x)
            ws = ws * x_scale
    y = merged_conv(x, w_p, b_p, stride=stride, bcout=bc, tile_ho=tile_ho,
                    tile_wo=tile_wo, activation=activation, w_scale=ws,
                    out_dtype=out_dtype, interpret=interpret)
    if pc:
        y = y[..., :cout]
    return y


def depthwise_conv_op(x, w, b=None, *, stride: int = 1,
                      groups: int | None = None,
                      activation: str | None = None,
                      tile_ho: int | None = None, tile_wo: int | None = None,
                      bgroups: int | None = None, w_scale=None,
                      act_quant: str = "none", interpret: bool = False):
    """Grouped/depthwise merged-segment conv (VALID, stride ``s``) with
    fused bias + boundary activation.

    ``groups`` is the ``feature_group_count``; it defaults to the
    depthwise reading ``Cin // Cin_g`` from the HWIO weight shape
    (``Cin_g = w.shape[2]``), so plain depthwise calls pass just
    ``(x, w, b, stride=s)``.  ``bgroups`` (groups per grid step) defaults
    to ``choose_group_block`` — a lane-friendly channel tile for
    depthwise shapes, one group per step for ``Cin_g > 1``.  The group
    axis is padded up inside the kernel wrapper; no fallback to lax on
    the TPU path.  ``w_scale``/``act_quant``: quantized path, same
    contract as :func:`merged_conv_op`.
    """
    if groups is None:
        groups = x.shape[-1] // w.shape[2]
    if not (_use_pallas() or interpret):
        if w_scale is not None:
            y = ref.depthwise_conv_qref(x, w, b, w_scale, stride=stride,
                                        groups=groups, act_quant=act_quant)
        else:
            y = ref.depthwise_conv_ref(x, w, b, stride=stride, groups=groups)
        return ref.apply_activation(y, activation)
    cin_g = w.shape[2]
    cout_g = w.shape[3] // groups
    bg = choose_group_block(groups, cin_g, cout_g, bgroups)
    ws = out_dtype = None
    if w_scale is not None:
        ws = w_scale.astype(jnp.float32)
        out_dtype = x.dtype
        if act_quant == "w8a8":
            x, x_scale = quant.quantize_int8(x)
            ws = ws * x_scale
    return depthwise_conv(x, w, b, stride=stride, groups=groups, bgroups=bg,
                          tile_ho=tile_ho, tile_wo=tile_wo,
                          activation=activation, w_scale=ws,
                          out_dtype=out_dtype, interpret=interpret)


def rglru_scan_op(a, b, *, interpret: bool = False):
    if not (_use_pallas() or interpret):
        return ref.rglru_scan_ref(a, b)
    bsz, s, c = a.shape
    a_p, pc = _pad_to(a, 2, 128)
    b_p, _ = _pad_to(b, 2, 128)
    # pad a with ones in time? channel padding only: zeros fine (h stays 0)
    bt = 256
    pt = (-s) % bt
    if pt:
        a_p = jnp.pad(a_p, ((0, 0), (0, pt), (0, 0)))
        b_p = jnp.pad(b_p, ((0, 0), (0, pt), (0, 0)))
    h = rglru_scan(a_p, b_p, bt=min(bt, a_p.shape[1]), interpret=interpret)
    return h[:, :s, :c]


# ---------------------------------------------------------------------------
# Flash attention with custom VJP (Pallas fwd, reference bwd)
# ---------------------------------------------------------------------------

@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4))
def flash_attention_op(q, k, v, causal: bool = True,
                       interpret: bool = False):
    """(B, S, H, D) causal attention; same heads for q/k/v (GQA expanded
    at the call site via repeat — see models/layers for the grouping)."""
    return _fa_fwd(q, k, v, causal, interpret)[0]


def _fa_fwd(q, k, v, causal, interpret):
    if not (_use_pallas() or interpret):
        return ref.flash_attention_ref(q, k, v, causal=causal), (q, k, v)
    b, s, h, d = q.shape
    qf = jnp.moveaxis(q, 2, 1).reshape(b * h, s, d)
    kf = jnp.moveaxis(k, 2, 1).reshape(b * h, s, d)
    vf = jnp.moveaxis(v, 2, 1).reshape(b * h, s, d)
    bq = 512 if s % 512 == 0 else (256 if s % 256 == 0 else s)
    o = flash_attention(qf, kf, vf, causal=causal, bq=bq, bk=bq,
                        interpret=interpret)
    o = jnp.moveaxis(o.reshape(b, h, s, d), 1, 2)
    return o, (q, k, v)


def _fa_bwd(causal, interpret, saved, g):
    q, k, v = saved
    # recompute-style backward via the reference implementation's VJP
    _, vjp = jax.vjp(lambda q, k, v: ref.flash_attention_ref(
        q, k, v, causal=causal), q, k, v)
    return vjp(g)


flash_attention_op.defvjp(_fa_fwd, _fa_bwd)


def force_backend(mode):
    """Context for tests: force 'pallas' (interpret on CPU) or 'ref'."""
    import contextlib

    @contextlib.contextmanager
    def ctx():
        prev = _FORCE["mode"]
        _FORCE["mode"] = mode
        try:
            yield
        finally:
            _FORCE["mode"] = prev
    return ctx()
