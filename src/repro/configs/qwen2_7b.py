"""qwen2-7b [arXiv:2407.10671; hf] — GQA kv=4, QKV bias."""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="qwen2-7b", family="dense",
    num_layers=28, d_model=3584, num_heads=28, num_kv_heads=4,
    d_ff=18944, vocab_size=152064,
    ffn_kind="swiglu", qkv_bias=True, temporal_pattern=("attn",),
    source="arXiv:2407.10671; GQA, QKV bias",
)
