"""End-to-end Algorithm 2 with the *measured* pipeline (Eq. 4 importance +
wall-clock latency oracle) on a micro network — the paper's full loop."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (ImportanceSpec, WallClockOracle, accuracy_perf,
                        compress, distill_loss, neg_loss_perf, xent_loss)
from repro.models import cnn, cnn_host, zoo


def _toy_data(key, n, hw, classes=4):
    """Deterministic synthetic classification: quadrant-mean task."""
    x = jax.random.normal(key, (n, hw, hw, 3))
    q = hw // 2
    means = jnp.stack([x[:, :q, :q].mean((1, 2, 3)), x[:, :q, q:].mean((1, 2, 3)),
                       x[:, q:, :q].mean((1, 2, 3)), x[:, q:, q:].mean((1, 2, 3))],
                      axis=1)
    y = jnp.argmax(means, axis=1)
    return x, y


@pytest.fixture(scope="module")
def setup():
    net = zoo.tiny_resnet(num_classes=4, in_hw=8, width=4, blocks=(2,))
    params = cnn.init_params(net, jax.random.PRNGKey(0))
    xtr, ytr = _toy_data(jax.random.PRNGKey(1), 64, 8)
    xev, yev = _toy_data(jax.random.PRNGKey(2), 64, 8)
    return net, params, [(xtr, ytr)], [(xev, yev)]


def test_measured_importance_compress(setup):
    net, params, train_b, eval_b = setup
    host = cnn_host.CNNHost(net, params, batch=4)
    spec = ImportanceSpec(loss_fn=xent_loss, perf_fn=accuracy_perf,
                          train_batches=train_b, eval_batches=eval_b,
                          steps=3, lr=1e-3)
    base = accuracy_perf(lambda p, x: cnn.apply_replaced(net, p, x),
                         params, eval_b)
    res = compress(host, budget_ratio=0.7, P=100, method="layermerge",
                   importance=spec, base_perf=base)
    assert res is not None
    assert res.plan.latency <= res.original_latency  # genuinely compressed
    # importance entries are positive (exp-normalized) and ≤ ~exp(1)
    for (i, j), row in res.tables.entries.items():
        for k, (imp, lat, kept) in row.items():
            assert imp > 0.0 and lat > 0.0


def test_wallclock_oracle_compress(setup):
    net, params, *_ = setup
    host = cnn_host.CNNHost(net, params, batch=4)
    oracle = WallClockOracle(warmup=1, iters=3)
    res = compress(host, budget_ratio=0.7, P=60, method="layermerge",
                   latency_oracle=oracle, params=params)
    assert res is not None and res.speedup > 1.0
    # merged network still runs and matches replaced
    x = jax.random.normal(jax.random.PRNGKey(5), (2, 8, 8, 3))
    ra, _ = host.replaced_apply(res.plan)
    ma, _ = host.merged_apply(res.plan)
    np.testing.assert_allclose(ra(params, x), ma(params, x),
                               rtol=1e-4, atol=1e-4)


def test_distill_importance_mode(setup):
    """Data-free self-distillation proxy (DESIGN §2.4) runs end to end."""
    net, params, train_b, eval_b = setup
    host = cnn_host.CNNHost(net, params, batch=4)
    teacher = jax.jit(lambda x: cnn.apply_replaced(net, params, x))
    loss = distill_loss(teacher)
    spec = ImportanceSpec(loss_fn=loss, perf_fn=neg_loss_perf(loss),
                          train_batches=[train_b[0][0]],
                          eval_batches=[eval_b[0][0]], steps=2, lr=1e-3)
    res = compress(host, budget_ratio=0.75, P=80, importance=spec,
                   base_perf=0.0)
    assert res is not None


def test_finetune_recovers_accuracy(setup):
    """Fine-tuning the replaced net improves the toy-task loss (sanity of the
    Algorithm 2 fine-tune step)."""
    net, params, train_b, eval_b = setup
    host = cnn_host.CNNHost(net, params, batch=4)
    res = compress(host, budget_ratio=0.6, P=100)
    ra, _ = host.replaced_apply(res.plan)
    from repro.core.importance import ImportanceSpec as IS, _adam_finetune
    spec = IS(loss_fn=xent_loss, perf_fn=accuracy_perf,
              train_batches=train_b * 8, eval_batches=eval_b, steps=25,
              lr=3e-3)
    before = float(xent_loss(ra, params, train_b[0]))
    tuned = _adam_finetune(ra, params, spec)
    after = float(xent_loss(ra, tuned, train_b[0]))
    assert after < before


def test_plan_serialization_roundtrip(setup):
    net, params, *_ = setup
    host = cnn_host.CNNHost(net, params, batch=4)
    res = compress(host, budget_ratio=0.7, P=100)
    from repro.core.plan import CompressionPlan
    plan2 = CompressionPlan.from_json(res.plan.to_json())
    assert plan2.segments == res.plan.segments
    assert plan2.A == res.plan.A and plan2.C == res.plan.C
