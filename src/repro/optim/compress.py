"""int8 gradient compression with error feedback for the DP all-reduce.

At multi-pod scale the gradient all-reduce crosses DCN (the 'pod' axis),
where bandwidth — not latency — dominates.  Quantizing gradients to int8
with per-leaf scales cuts cross-pod bytes 4× (fp32) / 2× (bf16); the
quantization residual is carried to the next step (error feedback), which
keeps SGD-style convergence (Karimireddy et al., 2019).

``compressed_psum(tree, axis)`` runs inside ``shard_map``: quantize →
``jax.lax.psum`` on int32 accumulators → dequantize.  ``ErrorFeedback``
wraps it statefully for the training loop.  tests/test_substrates.py checks
exactness bounds and the error-feedback telescoping property.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

# THE symmetric rounding semantics — shared with the quantized merged
# kernels so gradients and weights quantize identically.
from repro.kernels.quant import dequantize_int8, quantize_int8  # noqa: F401


def compressed_psum(tree, axis_name: str):
    """int8-quantized psum over ``axis_name`` (call inside shard_map).

    Accumulates int32 (no overflow for ≤ 2^23 participants) and psums the
    per-tensor scales' max so the dequant is consistent across shards.
    """
    def one(x):
        amax = jax.lax.pmax(jnp.max(jnp.abs(x)).astype(jnp.float32),
                            axis_name)
        scale = jnp.maximum(amax, 1e-30) / 127.0
        q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale),
                     -127, 127).astype(jnp.int32)
        total = jax.lax.psum(q, axis_name)
        return total.astype(jnp.float32) * scale
    return jax.tree.map(one, tree)


class ErrorFeedback:
    """Stateful wrapper: g_compressed = Q(g + e);  e ← (g + e) − g_compressed."""

    @staticmethod
    def init(params):
        return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)

    @staticmethod
    def apply(grads, error):
        def one(g, e):
            corrected = g.astype(jnp.float32) + e
            q, scale = quantize_int8(corrected)
            gq = dequantize_int8(q, scale)
            return gq, corrected - gq
        flat_g, tdef = jax.tree.flatten(grads)
        flat_e = tdef.flatten_up_to(error)
        pairs = [one(g, e) for g, e in zip(flat_g, flat_e)]
        return (tdef.unflatten([p[0] for p in pairs]),
                tdef.unflatten([p[1] for p in pairs]))
