"""Public kernel entry points — the ONE import surface for callers.

Models, the runtime executor, tests, and benchmarks import from
``repro.kernels`` directly (``from repro import kernels; kernels.
merged_conv_op(...)``) instead of deep-importing ``kernels.ops`` /
``kernels.ref`` module paths.  Each ``*_op`` dispatches to the Pallas
kernel on TPU and to the matching ``*_ref`` jnp oracle elsewhere; the
oracles are exported too — they are the semantic ground truth the
equivalence suites compare against.

Merged-segment convs and the phase-major layout contract
--------------------------------------------------------
Both conv kernels (``merged_conv_op`` for dense segments,
``depthwise_conv_op`` for depthwise/grouped ones) share one input
layout for stride-``s`` segments: the NHWC image is relaid
**phase-major** before the ``pallas_call`` —

    ``x_pm[n, p, q, t, r, c] = x[n, s·t + p, s·r + q, c]``

with ``p < min(s, k_h)``, ``q < min(s, k_w)`` — so each kernel tap
``(u, v)`` reads a *contiguous* per-phase window
``x_pm[n, u % s, v % s, u//s : u//s + tile_ho, v//s : v//s + tile_wo]``
instead of a strided gather.  DMA windows from HBM are therefore plain
rectangular slices, phase selection inside the kernel is a static VMEM
slice, and at ``s == 1`` the relayout is the identity (bit-for-bit the
dense path).  ``merged_conv.phase_major`` / ``phase_extents`` implement
the contract; ``input_traffic_model`` charges the one XLA transpose a
stride-``s`` segment pays as ``relayout_bytes``.

The depthwise/grouped kernel blocks the *channel* axis jointly with the
input (grid ``(batch, ho-tiles, wo-tiles, group-blocks)``, per-group
fp32 accumulators) — see ``depthwise_conv.py`` for the grid and
accumulator design.  ``depthwise_conv_ref`` is its certification
oracle.

Quantization contract (int8 / w8a8 / fp8 scaffolding)
-----------------------------------------------------
All three merged kernels accept narrow weights with per-channel fp32
scales (:mod:`repro.kernels.quant` is the ONE rounding semantics —
symmetric, zero-point-free, ``q·scale ≈ w``):

* **Scale layout** — conv weights quantize along the HWIO output-channel
  axis (``w_scale: (Cout,)``); low-rank factors along their output
  column (``u_scale: (R,)``, ``v_scale: (D,)``).  Because each scale is
  constant over its contraction, kernels apply it AFTER the fp32
  accumulation — mathematically identical to per-weight dequant before
  the dot, with the narrow blocks riding the same zero-copy DMA/halo
  pipeline as fp weights.
* **w8a8** — the ``*_op`` entry point quantizes the activation
  per-tensor at the call site and folds its scale into the weight scale,
  so kernels always see ONE scale operand; the FFN keeps the fp
  activation panel for an exact residual add.
* **Error budgets** — quantized outputs are certified against the plain
  fp32 oracles within :func:`repro.kernels.quant.error_budget` — a
  rigorous worst-case bound (half-ulp per weight times the reduction
  fan-in), not a tuned tolerance.  ``*_qref`` dequantizing oracles give
  the off-TPU dispatch path and tight (reassociation-only) agreement
  with the kernels.
* **Provenance** — scales are DATA: lowered units carry them in
  ``params`` (annotated axes, sharded/fingerprinted like weights) with a
  ``quant`` static record naming the mode — see
  :mod:`repro.runtime.ir`; artifact format v3.
"""
from . import ops, quant, ref
from .ops import (channel_tile, depthwise_conv_op, flash_attention_op,
                  force_backend, merged_conv_op, merged_ffn_op,
                  rglru_scan_op, rmsnorm_op)
from .ref import (apply_activation, depthwise_conv_qref, depthwise_conv_ref,
                  flash_attention_ref, merged_conv_qref, merged_conv_ref,
                  merged_ffn_qref, merged_ffn_ref, rglru_scan_ref,
                  rmsnorm_ref)

__all__ = [
    "ops", "quant", "ref",
    "channel_tile", "depthwise_conv_op", "flash_attention_op",
    "force_backend", "merged_conv_op", "merged_ffn_op", "rglru_scan_op",
    "rmsnorm_op",
    "apply_activation", "depthwise_conv_qref", "depthwise_conv_ref",
    "flash_attention_ref", "merged_conv_qref", "merged_conv_ref",
    "merged_ffn_qref", "merged_ffn_ref", "rglru_scan_ref", "rmsnorm_ref",
]
