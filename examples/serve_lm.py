"""Batched serving example: jitted prefill + scan decode on a small LM.

Serves through the shared protocol in :mod:`repro.runtime.serving`:
prefill is one jitted chunked call, decode one jitted ``lax.scan``, and
``--prompts R`` pushes R ragged prompts through the fixed-slot batched
scheduler (``serve_requests``) — the production shape of the serve path.
Adding ``--continuous`` serves the same prompts through the overload-safe
continuous-batching engine under a seeded Poisson arrival trace
(``--rate`` requests/s) and prints each request's disposition and
latency — mid-stream admission does not change the greedy ids.

With ``--artifact`` the example serves a LayerMerge-COMPRESSED model: it
loads a portable merged-model artifact (written by ``python -m
repro.compress`` or ``CompressResult.save``), decodes through the shared
unit-graph executor (KV-cache aware — merged low-rank segments carry no
decode state at all), and reports compressed-vs-original throughput side
by side.

With ``--mesh`` the run shards over the host devices as a
('data','model') mesh (``--model-par`` picks the tensor-parallel split):
artifact weights are ``device_put`` straight to the shardings their
recorded logical axes resolve to, and the slot batch decodes
data-parallel.  Force multiple CPU devices with
``XLA_FLAGS=--xla_force_host_platform_device_count=8``.

Run:  PYTHONPATH=src python examples/serve_lm.py [--tokens 32] [--batch 4]
      PYTHONPATH=src python -m repro.compress --arch smollm-135m \
          --budget-ratio 0.55 --out lm.npz
      PYTHONPATH=src python examples/serve_lm.py --artifact lm.npz \
          --prompts 8 --mesh
"""
import argparse
import dataclasses

import jax

from repro.configs import get_config
from repro.launch.mesh import make_host_mesh, mesh_info
from repro.models import transformer as T
from repro.runtime import serving
from repro.sharding.rules import make_unit_rules
from repro.train.step import make_serve_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--tokens", type=int, default=32)
    ap.add_argument("--arch", default="smollm-135m")
    ap.add_argument("--artifact", default=None,
                    help="merged-model artifact (.npz); serves the "
                         "compressed model and compares throughput")
    ap.add_argument("--prompts", type=int, default=0,
                    help="also serve N ragged prompts through the "
                         "fixed-slot batched scheduler")
    ap.add_argument("--continuous", action="store_true",
                    help="serve the --prompts trace through the "
                         "continuous-batching engine (Poisson arrivals)")
    ap.add_argument("--rate", type=float, default=8.0,
                    help="arrival rate (requests/s) for --continuous")
    ap.add_argument("--mesh", action="store_true",
                    help="shard over the host devices (data × model)")
    ap.add_argument("--model-par", type=int, default=1)
    ap.add_argument("--seed", type=int, default=0,
                    help="original-model init seed (overridden by the "
                         "artifact's recorded source seed)")
    args = ap.parse_args()

    rules = None
    if args.mesh:
        mesh = make_host_mesh(model=args.model_par)
        rules = make_unit_rules(mesh)
        print(f"[serve_lm] mesh {mesh_info(mesh)}")

    art = None
    if args.artifact:
        from repro import runtime

        art = runtime.load(args.artifact, rules=rules)
        if art.graph.family != "transformer":
            raise SystemExit("[serve_lm] --artifact must hold a "
                             "transformer-family graph")
        cfg = art.graph.meta["config"]
        seed = art.meta.get("source", {}).get("seed", args.seed)
        print(f"[serve_lm] artifact {args.artifact} "
              f"(fingerprint {art.fingerprint[:16]}, "
              f"oracle {art.meta.get('oracle')})")
    else:
        cfg = dataclasses.replace(
            get_config(args.arch).reduced(), num_layers=4, d_model=128,
            num_heads=4, num_kv_heads=2, head_dim=32, d_ff=256,
            vocab_size=512)
        seed = args.seed
    params, _ = T.init_model(cfg, jax.random.PRNGKey(seed))
    B, P = args.batch, args.prompt_len
    total = P + args.tokens
    prompt = serving.random_prompts(1, B, P, cfg.vocab_size)

    # original model: ONE chunked prefill call + one scan decode (the
    # shared jitted protocol; production prefill is the prefill_32k
    # dry-run cell)
    serve = make_serve_step(cfg)
    cache = T.init_cache(cfg, B, total)
    prefill_s, decode_s, _, seqs = serving.serve_loop(
        serve, params, cache, prompt, args.tokens, rules=rules)
    tps = serving.decode_tok_s(args.tokens - 1, B, decode_s)
    print(f"[serve_lm] batch={B} prompt={P} generated={args.tokens}")
    print(f"[serve_lm] original   prefill {prefill_s*1e3:.1f} ms, decode "
          f"{decode_s*1e3:.1f} ms ({tps:.0f} tok/s on this host)")

    if art is not None:
        ex = art.executor(rules)
        step, cparams = ex.serve_step()
        c_prefill_s, c_decode_s, _, cseqs = serving.serve_loop(
            step, cparams, ex.init_cache(B, total), prompt, args.tokens,
            rules=rules)
        ctps = serving.decode_tok_s(args.tokens - 1, B, c_decode_s)
        print(f"[serve_lm] compressed prefill {c_prefill_s*1e3:.1f} ms, "
              f"decode {c_decode_s*1e3:.1f} ms ({ctps:.0f} tok/s)")
        print(f"[serve_lm] decode speedup {decode_s / c_decode_s:.2f}x "
              f"(DP-predicted {art.meta.get('predicted_speedup', '?')}x)")
        print(f"[serve_lm] compressed continuation ids: "
              f"{cseqs[0, :12].tolist()}")

    if args.prompts:
        mat, lens = serving.pad_prompts(
            serving.ragged_prompts(2, args.prompts, min(4, P), P,
                                   cfg.vocab_size))
        if art is not None:
            bstep, bparams, mkcache = step, cparams, ex.init_cache
        else:
            bstep, bparams = serve, params
            mkcache = lambda b, s: T.init_cache(cfg, b, s)   # noqa: E731
        gen, secs = serving.serve_requests(
            bstep, bparams, mkcache, mat, lens, tokens=args.tokens,
            slots=B, rules=rules)
        btps = serving.decode_tok_s(args.tokens, args.prompts, secs)
        print(f"[serve_lm] scheduler: {args.prompts} ragged prompts in "
              f"{B}-slot rounds → {secs*1e3:.1f} ms ({btps:.0f} tok/s)")
        print(f"[serve_lm] slot-0 continuation ids: {gen[0, :12].tolist()}")
        if args.continuous:
            import numpy as np

            rng = np.random.RandomState(11)
            arrivals = [float(a) for a in np.cumsum(
                rng.exponential(1.0 / args.rate, size=args.prompts))]
            cgen, csecs = cout = serving.serve_continuous(
                bstep, bparams, mkcache, mat, lens, tokens=args.tokens,
                slots=B, rules=rules, arrivals=arrivals)
            rep = cout.report
            print(f"[serve_lm] continuous: {args.prompts} requests, "
                  f"Poisson rate {args.rate:g}/s, {B} slots → "
                  f"{csecs*1e3:.1f} ms wall "
                  f"({rep.sustained_tok_s:.0f} sustained tok/s, "
                  f"queue peak {rep.queue_peak})")
            for rid in sorted(rep.dispositions):
                lat = rep.latency_s.get(rid)
                lat_ms = "-" if lat is None else f"{lat*1e3:7.1f} ms"
                print(f"[serve_lm]   request {rid}: "
                      f"{rep.dispositions[rid]:<13s} latency {lat_ms}")
            same = bool(np.array_equal(np.asarray(cgen), np.asarray(gen)))
            print(f"[serve_lm] continuous ids == scheduler ids: {same}")
    print(f"[serve_lm] sample continuation ids: {seqs[0, :12].tolist()}")


if __name__ == "__main__":
    main()
