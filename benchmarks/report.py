"""Generate EXPERIMENTS.md from the dry-run/bench artifacts.

  PYTHONPATH=src python benchmarks/report.py > EXPERIMENTS.md
"""
from __future__ import annotations

import glob
import json
import os
import sys

sys.path.insert(0, os.path.dirname(__file__))
import roofline

PERF_LOG = """\
## §Perf — hillclimb log (hypothesis → change → before → after → verdict)

Methodology: every iteration re-lowers the cell, re-derives the
depth-corrected roofline terms, and compares against the previous state.
Terms are seconds per step on the target v5e pod (256 chips).  The
paper-faithful BASELINE rows are frozen artifacts
(`results/dryrun/<cell>.json`); optimized variants carry tags.
Stop rule: three consecutive <5 % changes on the dominant term.

### Cell 1 — `command-r-plus-104b × decode_32k` (worst roofline fraction)

| # | hypothesis | change | collective s | RF | verdict |
|---|---|---|---|---|---|
| 0 | baseline (FSDP+TP, seq-sharded cache) | — | 0.522 | 0.0010 | collective-dominant |
| 1 | the seq-sharded KV cache is all-gathered per token; a shard_map LSE combine (flash-decoding) removes it | `flash_decode_attention` v1 | 3.287 | 0.0002 | **REFUTED** — my in_specs replicated the batch dim over 'data', all-gathering the cache across the wrong axis (6× worse). Lesson: shard_map in_specs must mention *every* sharded dim, not just the interesting one. |
| 2 | same hypothesis, specs fixed | flash-decoding with batch kept on 'data' | 0.521 | 0.0010 | **REFUTED** (±0.1 %) — XLA was already computing partial attention locally + psum; the cache was never gathered. The real collective is elsewhere. |
| 3 | 13 GB of weights are FSDP-gathered for every single decoded token (104B·2B/16 TP shards per step) — weights should be TP-resident for decode | `--no-fsdp` (TP-only weights, ZeRO-1 moments stay sharded) | **0.0020** | **0.0252** | **CONFIRMED** — collective −99.6 %, RF ×25 (honest accounting: the memory term RISES to 21.6 ms because TP-resident weights are read at 1/16 sharding instead of 1/256 — and that read is the physical decode bandwidth floor). |
| 4 | with memory dominant at the weights-read floor, further RF needs lower-precision weights (int8 serving) — out of scope this pass | — | — | — | stop (next two candidates <5 % by napkin math; recorded for future work) |

### Cell 2 — `qwen3-moe-30b-a3b × train_4k` (most collective-bound)

| # | hypothesis | change | collective s | RF | verdict |
|---|---|---|---|---|---|
| 0 | baseline (scatter/gather MoE, XLA SPMD) | — | 26.28 | 0.0159 | 15.8 GB/layer of (E,C,D) buffer all-reduce |
| 1 | sharding the capacity dim over 'data' turns buffer psums into all-to-all | `moe_cap` constraint | 598.2 | 0.0007 | **REFUTED 22× worse** — scatter targets are data-dependent; XLA falls back to full exchange. Lesson: SPMD cannot infer locality through a data-dependent scatter. |
| 2 | GShard grouping (tokens grouped by data shard, group-local capacity) makes the scatter local | grouped `moe_ffn` | 16.58 | 0.0252 | **CONFIRMED** −37 % |
| 3 | remaining 15.8 GB/layer is the expert gather/scatter crossing 'model'; an explicit shard_map MoE (expert-local dispatch + one token-sized psum) removes it | `moe_ffn_sharded` | **4.00** | **0.1046** | **CONFIRMED** −76 % more (−85 % vs baseline, RF ×6.6) |
| 4 | grads all-reduce instead of reduce-scatter | grad sharding constraint (`zgrad`) | 4.00 | 0.1046 | refuted (<0.1 % — XLA already reduce-scatters through the donated opt update) |
| 5 | ZeRO-1 (params TP-only, moments sharded) | `zero1` | 3.96 | 0.1055 | +0.9 % (<5 %); kept — it is what makes iteration 3 of cell 1 memory-safe |
| 6 | the f32 loss cast promotes the whole backward to f32, doubling psum bytes | bf16-cotangent `upcast_for_loss` | 4.00 | 0.1046 | refuted on THIS host — HLO metadata shows the f32 psums are XLA:CPU's bf16-dot promotion (TPU reduces in bf16); the fix is kept (it is correct for TPU) but cannot be measured here. Recorded as a backend caveat. |
| — | stop rule hit (3 consecutive <5 %). Remaining collectives are the attention-out + MoE-combine activation psums — inherent to TP/EP at this mesh; the overlap schedule (latency-hiding scheduler) hides them behind the expert GEMMs on real hardware. | | | | |

### Cell 3 — `gemma-7b × prefill_32k` (most representative of the paper's technique)

| # | hypothesis | change | compute s | collective s | RF | verdict |
|---|---|---|---|---|---|---|
| 0 | baseline (uncompressed, seq-parallel prefill) | — | 0.676 | 0.961 | 0.3695 | collective-dominant |
| 1 | LayerMerge at a 55 % latency budget (DP over analytic v5e tables; merges linearized GeGLU FFNs across pruned attention blocks into rank-3072 fused layers) should cut BOTH terms ~budget-proportionally | `--budget 0.55` | 0.405 (−40 %) | 0.574 (−40 %) | **0.6186** | **CONFIRMED** — the paper's technique, applied at production scale, moves the cell from RF 0.37 to RF 0.62. DP-predicted speed-up 1.75×; observed dominant-term reduction 1.67×. |

The full optimized-vs-baseline roofline across every cell is in the tables
below (`opt` columns = flash-decoding + TP-resident decode weights +
shard_map MoE + ZeRO-1 + bf16 cotangents).

**Per-cell sharding policy finding:** TP-resident decode weights (cell 1's
win) HURT the tiny-state `long_500k` cells — at batch 1 the FSDP gather is
nearly free while the TP-resident weight read is 16× larger, so rf_opt for
recurrentgemma/xlstm long_500k keeps FSDP.  The launcher therefore selects
the decode weight layout per (model size × batch): gather-once-per-step
(FSDP) when `batch·2·P/chips ≪ HBM_bw·step`, TP-resident otherwise.
"""

CAVEATS = """\
## Measurement caveats (read before the tables)

* **CPU host, TPU target.**  The dry-run compiles the post-SPMD per-chip
  program with `--xla_force_host_platform_device_count=512`; cost/memory
  analyses come from the XLA:CPU backend.
* **Scan-body counting.**  `cost_analysis()` counts `while`-loop bodies
  once; every scanned cell is depth-corrected by unrolled probes at pattern
  depth p and 2p (`roofline.depth_correct`; exact for uniform stacks,
  ≤ one-cycle error for the 1:2 hybrid and the xlstm pattern, which is
  compiled fully unrolled).
* **Memory term.**  XLA:CPU fuses less than XLA:TPU, so `bytes accessed`
  over-counts HBM traffic ~5-10×.  Both views are reported: `hlo_memory_s`
  (as specified) and `tpu_memory_s` (fusion-aware analytic model:
  weights/pass + 8 residual-stream touches/layer + logits + decode cache).
  `rf_tpu` (headline) uses the analytic memory term; `rf_hlo` uses the raw
  HLO term.
* **f32 collectives.**  XLA:CPU promotes bf16 dot partial-sums to f32
  before the all-reduce; on TPU these reduce in bf16 → the reported
  collective term is a ~2× upper bound for activation psums.
* **MODEL_FLOPS** = 6·N_active·tokens (train), 2·N_active·tokens
  (prefill), 2·N_active·batch (decode).  `useful` = MODEL_FLOPS /
  (chips·HLO_FLOPs) — the remat/redundancy-waste detector (XLA counts
  dot FLOPs with the mnk convention, so ~0.5 ≈ clean for fwd-only and
  ~1.0 for train-with-remat; ≫1 or ≪0.1 flags an accounting or
  efficiency problem).
"""


def fmt_row(r, o=None):
    base = (f"| {r['arch']} | {r['shape']} | {r['compute_s']:.3e} | "
            f"{r['analytic_memory_s']:.3e} | {r['memory_s']:.3e} | "
            f"{r['collective_s']:.3e} | {r['dominant_tpu']} | "
            f"{r['useful_ratio']:.2f} | {r['roofline_fraction_tpu']:.4f} | "
            f"{r['roofline_fraction']:.4f} |")
    if o is not None:
        base += f" {o['roofline_fraction_tpu']:.4f} |"
    else:
        base += " — |"
    return base


def main():
    rows = roofline.load()
    opt = {(r["arch"], r["shape"]): r for r in roofline.load(tag="opt")}
    out = []
    out.append("# EXPERIMENTS\n")
    out.append("Companion artifacts: `results/dryrun/*.json` (one per cell "
               "× mesh × variant), `results/bench.csv`, `test_output.txt`, "
               "`bench_output.txt`.\n")
    out.append(CAVEATS)

    # -- dry-run section -------------------------------------------------------
    out.append("## §Dry-run\n")
    single = ok_cells("single")
    multi = ok_cells("multi")
    out.append(f"* single-pod mesh 16×16 ('data','model'): **{single}/32 "
               "cells compile** (every arch × applicable shape);")
    out.append(f"* multi-pod mesh 2×16×16 ('pod','data','model'): "
               f"**{multi}/32 cells compile** — the 'pod' axis shards "
               "(per-device FLOPs halve, checked per cell);")
    out.append("* `long_500k` runs for recurrentgemma-2b and xlstm-125m "
               "(bounded state) and is **skipped for the 8 pure "
               "full-attention archs** per the assignment (no sub-quadratic "
               "prefill path; decode would be linear-in-cache — noted in "
               "DESIGN §2.3);")
    out.append("* decode cells lower `serve_step` (one token against a "
               "seq_len KV cache/state), prefill cells lower `forward`, "
               "train cells lower the full loss→grad→clip→AdamW step with "
               "donated sharded state (ZeRO moments).\n")
    out.append("Example memory analysis (granite train_4k, per chip): "
               "arguments 97 MB (sharded params+moments), XLA-CPU temp "
               "66 GB (un-fused upper bound; the TPU analytic activation "
               "estimate with remat is ~2.1 GB/chip).\n")

    # -- roofline --------------------------------------------------------------
    out.append("## §Roofline — single-pod, paper-faithful BASELINE "
               "(+ optimized RF)\n")
    out.append("All terms are seconds/step on 256 v5e chips.  `rf_tpu` is "
               "the headline roofline fraction (ideal compute time / "
               "dominant term, fusion-aware memory model); `rf_opt` is the "
               "same cell after the §Perf beyond-paper optimizations "
               "(flash-decoding, TP-resident decode weights, shard_map MoE, "
               "ZeRO-1, bf16 cotangents).\n")
    out.append("| arch | shape | compute s | mem s (tpu) | mem s (hlo) | "
               "coll s | dominant | useful | rf_tpu | rf_hlo | rf_opt |")
    out.append("|---|---|---|---|---|---|---|---|---|---|---|")
    for r in sorted(rows, key=lambda r: (r["arch"], r["shape"])):
        out.append(fmt_row(r, opt.get((r["arch"], r["shape"]))))
    out.append("")
    out.append("Per-cell one-liners (what would move the dominant term):\n")
    for r in sorted(rows, key=lambda r: (r["arch"], r["shape"])):
        out.append(f"* **{r['arch']} × {r['shape']}** — dominant "
                   f"{r['dominant_tpu']}: {advice(r)}")
    out.append("")
    out.append(PERF_LOG)
    print("\n".join(out))


def ok_cells(mesh):
    n = 0
    for p in glob.glob(f"results/dryrun/*__{mesh}.json"):
        if json.load(open(p)).get("status") == "ok":
            n += 1
    return n


def advice(r):
    d = r["dominant_tpu"]
    mode = r["mode"]
    if d == "collective":
        if "moe" in r["arch"]:
            return ("shard_map expert-local dispatch (done in §Perf: −85 %); "
                    "rest is the EP token combine — overlap with expert GEMMs.")
        if mode == "decode":
            return ("TP-resident weights for decode (done in §Perf: −99.6 %); "
                    "then weight-quantized serving.")
        return ("activation psums from TP — overlap via latency-hiding "
                "scheduler; LayerMerge compression shrinks them "
                "budget-proportionally (§Perf cell 3).")
    if d == "memory":
        if mode == "decode":
            return ("weights+cache read per token is the physical floor; "
                    "int8 weights / grouped batches raise RF.")
        return ("remat policy tuning (fewer recomputed dots) and fused "
                "kernels (merged_ffn keeps the rank-r intermediate in VMEM).")
    return ("compute-bound — good; LayerMerge removes FLOPs directly "
            "(budget-proportional, §Perf cell 3); MXU-aligned Pallas tiles "
            "keep it there.")


if __name__ == "__main__":
    main()
