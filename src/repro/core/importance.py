"""Importance values ``I[i,j,k]`` (Eq. 4) — fine-tune-and-measure.

The paper defines the importance of a merged layer as::

    I[i,j,k] = exp( Perf(net with segment (i,j] replaced, few-step FT)
                    − Perf(pre-trained net) )

with performance = accuracy (classification) or −diffusion-loss (DDPM,
further divided by the pre-trained loss for stability — Appendix A).  The
``exp`` keeps importances positive, which the paper observes favours keeping
more activation layers.

Fine-tuning uses a small random subset of the training set (4 % ImageNet /
1 % CIFAR10 in the paper) and evaluates on a held-out subset of the same
size.  In this offline container the data pipeline supplies synthetic
batches, and an additional *self-distillation* mode (match the pre-trained
network's outputs on random inputs) is provided — a data-free proxy with the
same structure.  Both run through this module.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Callable, Sequence

import jax
import jax.numpy as jnp


@dataclasses.dataclass
class ImportanceSpec:
    """How to fine-tune and score a candidate replaced network."""

    loss_fn: Callable          # (apply_fn, params, batch) -> scalar loss
    perf_fn: Callable          # (apply_fn, params, batches) -> float (higher=better)
    train_batches: Sequence    # few batches for the short fine-tune
    eval_batches: Sequence
    steps: int = 8
    lr: float = 1e-3
    normalize_by_base: bool = False   # DDPM trick: divide by base loss


def _adam_finetune(apply_fn, params, spec: ImportanceSpec):
    """Minimal Adam used only for the few-step Eq. 4 fine-tune."""
    b1, b2, eps = 0.9, 0.999, 1e-8
    m = jax.tree.map(jnp.zeros_like, params)
    v = jax.tree.map(jnp.zeros_like, params)
    grad_fn = jax.jit(jax.grad(lambda p, b: spec.loss_fn(apply_fn, p, b)))

    for step in range(spec.steps):
        batch = spec.train_batches[step % len(spec.train_batches)]
        g = grad_fn(params, batch)
        t = step + 1
        m = jax.tree.map(lambda mm, gg: b1 * mm + (1 - b1) * gg, m, g)
        v = jax.tree.map(lambda vv, gg: b2 * vv + (1 - b2) * gg * gg, v, g)
        lr_t = spec.lr * math.sqrt(1 - b2 ** t) / (1 - b1 ** t)
        params = jax.tree.map(
            lambda p, mm, vv: p - lr_t * mm / (jnp.sqrt(vv) + eps),
            params, m, v)
    return params


def measure_importance(apply_fn, params, spec: ImportanceSpec,
                       base_perf: float) -> float:
    """One table entry: fine-tune the replaced net, return exp(ΔPerf)."""
    tuned = _adam_finetune(apply_fn, params, spec)
    perf = spec.perf_fn(apply_fn, tuned, spec.eval_batches)
    delta = perf - base_perf
    if spec.normalize_by_base and base_perf != 0:
        delta = delta / abs(base_perf)
    # clamp for numerical sanity (perf deltas are small by construction)
    return float(jnp.exp(jnp.clip(delta, -30.0, 30.0)))


def magnitude_importance(value_kept: float, value_total: float,
                         num_pruned: int, temperature: float = 1.0) -> float:
    """Cheap deterministic proxy (beyond-paper, for fast sweeps): exp of the
    negative pruned-ℓ1 fraction.  Clearly flagged — the paper's Eq. 4 path is
    the default everywhere correctness matters."""
    if value_total <= 0:
        return 1.0
    drop = (value_total - value_kept) / value_total
    return math.exp(-temperature * drop)


# -- ready-made loss/perf functions -----------------------------------------

def xent_loss(apply_fn, params, batch):
    x, y = batch
    logits = apply_fn(params, x)
    logp = jax.nn.log_softmax(logits)
    return -jnp.mean(jnp.take_along_axis(logp, y[:, None], axis=1))


def accuracy_perf(apply_fn, params, batches):
    correct = total = 0
    for x, y in batches:
        pred = jnp.argmax(apply_fn(params, x), axis=-1)
        correct += float(jnp.sum(pred == y))
        total += y.shape[0]
    return correct / max(total, 1)


def neg_loss_perf(loss_fn):
    def perf(apply_fn, params, batches):
        tot = 0.0
        for b in batches:
            tot += float(loss_fn(apply_fn, params, b))
        return -tot / max(len(batches), 1)
    return perf


def distill_loss(teacher_fn):
    """Self-distillation: match the pre-trained network's outputs (data-free)."""
    def loss(apply_fn, params, batch):
        x = batch[0] if isinstance(batch, tuple) else batch
        target = teacher_fn(x)
        out = apply_fn(params, x)
        return jnp.mean((out - target) ** 2)
    return loss
