"""DP-planned per-unit quantization: planner → artifact → serve pipeline.

The precision axis must be *free* when unused and *chosen by the DP* when
it pays:

* fp-only regression — with ``quantize`` off the widened machinery is a
  strict no-op: tables, DP visit order, plans, and saved artifacts are
  bit-identical to a run that has never heard of quantization;
* under a tightened budget on weight-traffic-bound configs the DP picks
  quantized siblings (int8 units on the CNN, w8a8 rank-FFN units on the
  transformer) and the lowered units carry narrow weights + per-channel
  scales;
* artifact format v3 round-trips quantized graphs bit-exactly (including
  a fresh-process reload), v2 artifacts (no ``quant`` statics) still
  load, and the table cache round-trips widened tuple keys.
"""
import dataclasses
import json
import os
import subprocess
import sys
import zipfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import runtime
from repro.configs import get_config
from repro.core import compress
from repro.core.tables import build_tables, with_quant_siblings
from repro.models import cnn, cnn_host, zoo
from repro.models import transformer as T
from repro.models.transformer_host import CostEnv, TransformerHost
from repro.runtime import artifact
from repro.testing.subproc import subprocess_env

_SUBPROC_ENV = subprocess_env()


def _cnn_setup(width=48, batch=1):
    """Weight-traffic-bound CNN: wide channels on a small feature map,
    batch=1 — HBM weight bytes dominate, so int8 siblings beat fp."""
    net = zoo.tiny_resnet(num_classes=4, in_hw=8, width=width,
                          blocks=(2, 2))
    params = cnn.init_params(net, jax.random.PRNGKey(0))
    host = cnn_host.CNNHost(net, params, batch=batch)
    x = jax.random.normal(jax.random.PRNGKey(1),
                          (batch, net.in_hw, net.in_hw, net.in_ch))
    return net, params, host, x


def _tf_setup():
    """Weight-bound decode-shaped transformer env (batch=1, short seq)."""
    cfg = dataclasses.replace(get_config("smollm-135m").reduced(),
                              d_model=256, d_ff=1024, head_dim=64,
                              num_heads=4, num_kv_heads=4)
    params, _ = T.init_model(cfg, jax.random.PRNGKey(0))
    host = TransformerHost(cfg, params, env=CostEnv(batch=1, seq=32))
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (1, 32), 0,
                                          cfg.vocab_size),
             "positions": jnp.broadcast_to(jnp.arange(32)[None], (1, 32))}
    return cfg, params, host, batch


# ---------------------------------------------------------------------------
# fp-only bit-identity
# ---------------------------------------------------------------------------

def test_fp_only_plans_bit_identical():
    """quantize=None / 'none' leave the planner untouched — same plan
    object graph, segment for segment, as never passing the knob."""
    _, _, host, _ = _cnn_setup(width=8)
    base = compress(host, budget_ratio=0.6, P=100)
    off = compress(host, budget_ratio=0.6, P=100, quantize=None)
    off2 = compress(host, budget_ratio=0.6, P=100, quantize="none")
    assert base.plan == off.plan == off2.plan
    assert all(s.quant == "none" for s in base.plan.segments)
    assert base.compressed_latency == off.compressed_latency


def test_fp_only_tables_unwidened():
    _, _, host, _ = _cnn_setup(width=8)
    tables = build_tables(host)
    assert all(isinstance(k, int) for row in tables.entries.values()
               for k in row)
    same = with_quant_siblings(tables, host, None)
    assert same is tables                               # literal no-op


def test_quant_widening_adds_tuple_siblings_only():
    """Widening never perturbs the fp rows: every original (key → entry)
    survives bit-identical; new keys are (k, mode) tuples."""
    _, _, host, _ = _cnn_setup(width=48)
    tables = build_tables(host)
    wide = with_quant_siblings(tables, host, "int8")
    for span, row in tables.entries.items():
        for k, entry in row.items():
            assert wide.entries[span][k] == entry
    tup = [k for row in wide.entries.values() for k in row
           if isinstance(k, tuple)]
    assert tup and all(k[1] == "int8" for k in tup)
    for span, row in wide.entries.items():
        for k in row:
            if isinstance(k, tuple):
                imp_q, lat_q, kept_q = row[k]
                imp_f, lat_f, kept_f = row[k[0]]
                assert lat_q < lat_f          # sibling only kept when faster
                assert imp_q < imp_f          # strictly less important
                assert kept_q == kept_f       # same merge structure


def test_invalid_quantize_mode_rejected():
    _, _, host, _ = _cnn_setup(width=8)
    with pytest.raises(ValueError):
        compress(host, budget_ratio=0.6, P=100, quantize="int4")
    with pytest.raises(ValueError):
        compress(host, budget_ratio=0.6, P=100, method="layeronly",
                 quantize="int8")


# ---------------------------------------------------------------------------
# DP selects quantized units when weight traffic dominates
# ---------------------------------------------------------------------------

def test_dp_selects_int8_units_cnn():
    _, params, host, x = _cnn_setup()
    res = compress(host, budget_ratio=0.45, P=200, quantize="int8")
    assert res is not None
    qsegs = [s for s in res.plan.segments if s.quant != "none"]
    assert qsegs and all(s.quant == "int8" for s in qsegs)
    graph = host.lower_plan(res.plan, params)
    qunits = [u for u in graph.units
              if getattr(u, "quant", "none") == "int8"]
    assert len(qunits) == len(qsegs)
    for u in qunits:
        w, ws = u.params["w"], u.params["w_scale"]
        assert w.dtype == jnp.int8
        assert ws.shape == (w.shape[3],)                # per-Cout scales
    # the mixed-precision graph executes, close to the all-fp lowering
    y = runtime.execute(graph, x)
    fp_plan = dataclasses.replace(
        res.plan, segments=tuple(dataclasses.replace(s, quant="none")
                                 for s in res.plan.segments))
    y_fp = runtime.execute(host.lower_plan(fp_plan, params), x)
    scale = float(jnp.abs(y_fp).max()) + 1e-9
    assert float(jnp.abs(y - y_fp).max()) / scale < 0.25


def test_dp_selects_w8a8_units_transformer():
    cfg, params, host, batch = _tf_setup()
    res = compress(host, budget_ratio=0.45, P=200, quantize="w8a8")
    assert res is not None
    qsegs = [s for s in res.plan.segments if s.quant != "none"]
    assert qsegs and all(s.quant == "w8a8" for s in qsegs)
    graph = host.lower_plan(res.plan, params)
    qunits = [u for u in graph.units
              if getattr(u, "quant", "none") == "w8a8"]
    assert qunits
    for u in qunits:
        assert u.params["u"].dtype == jnp.int8
        assert u.params["v"].dtype == jnp.int8
        assert u.params["u_scale"].shape == (u.params["u"].shape[1],)
        assert u.params["v_scale"].shape == (u.params["v"].shape[1],)
    y = runtime.execute(graph, batch)
    assert np.all(np.isfinite(np.asarray(y)))


def test_quantized_objective_dominates_fp_same_budget():
    """Widening only ADDS candidates, so the DP objective (importance
    under the budget) can only improve; the chosen plan still fits."""
    _, _, host, _ = _cnn_setup()
    fp = compress(host, budget_ratio=0.45, P=200)
    q = compress(host, budget_ratio=0.45, P=200, quantize="int8")
    assert q.plan.objective >= fp.plan.objective
    # Algorithm 1 floors each segment latency to a T0/P bucket, so true
    # latency may exceed T0 by at most one bucket per chosen segment.
    slack = q.plan.budget / 200 * len(q.plan.segments)
    assert q.compressed_latency <= q.plan.budget + slack


# ---------------------------------------------------------------------------
# Artifact v3 round trip + back compat
# ---------------------------------------------------------------------------

def _save_quant_artifact(tmp_path):
    _, params, host, x = _cnn_setup()
    res = compress(host, budget_ratio=0.45, P=200, quantize="int8")
    path = os.path.join(tmp_path, "q.npz")
    fp = res.save(path)
    return res, host, x, path, fp


def test_artifact_v3_roundtrip_quantized(tmp_path):
    res, host, x, path, fp = _save_quant_artifact(str(tmp_path))
    assert res.plan.segments and any(s.quant == "int8"
                                     for s in res.plan.segments)
    art = runtime.load(path)
    assert art.fingerprint == fp
    assert art.plan == res.plan                       # incl. quant fields
    assert art.meta["quantized_units"] == sum(
        1 for s in res.plan.segments if s.quant != "none")
    with np.load(path, allow_pickle=False) as z:
        spec = json.loads(z["__spec__"].item())
    assert spec["format"] == 3
    assert any(u.get("quant") == "int8" for u in spec["units"])
    # weights stored narrow, scales annotated for sharding
    for st, unit in zip(spec["units"], art.graph.units):
        if st.get("quant") == "int8":
            assert unit.params["w"].dtype == jnp.int8
            assert st["axes"]["w_scale"] == ["conv_out"]
    y_live = runtime.execute(host.lower_plan(res.plan), x)
    np.testing.assert_array_equal(np.asarray(y_live),
                                  np.asarray(art.apply(x)))


def test_artifact_v3_fresh_process_reload(tmp_path):
    """Quantized artifact certification: a FRESH interpreter reloads the
    v3 file and reproduces this process's outputs bit-exactly."""
    res, host, x, path, fp = _save_quant_artifact(str(tmp_path))
    y_live = np.asarray(runtime.execute(host.lower_plan(res.plan), x))
    xpath = os.path.join(str(tmp_path), "x.npy")
    np.save(xpath, np.asarray(x))
    code = (
        "import sys, numpy as np\n"
        "from repro import runtime\n"
        "art = runtime.load(sys.argv[1])\n"
        "q = [u for u in art.graph.units\n"
        "     if getattr(u, 'quant', 'none') != 'none']\n"
        "assert q, 'quantized units lost on reload'\n"
        "y = np.asarray(art.apply(np.load(sys.argv[2])))\n"
        "np.save(sys.argv[3], y)\n"
        "print('FP=' + art.fingerprint)\n"
    )
    ypath = os.path.join(str(tmp_path), "y.npy")
    r = subprocess.run([sys.executable, "-c", code, path, xpath, ypath],
                       capture_output=True, text=True, env=_SUBPROC_ENV,
                       cwd="/root/repo", timeout=300)
    assert r.returncode == 0, r.stdout + r.stderr
    assert f"FP={fp}" in r.stdout                     # artifact bytes exact
    # outputs: equivalent, not bit-exact — the fresh process may pick a
    # different XLA thread/fusion layout (same contract as the fp
    # fresh-process test in test_runtime.py)
    np.testing.assert_allclose(np.load(ypath), y_live, rtol=1e-5,
                               atol=1e-6)


def test_artifact_v2_backcompat_loads(tmp_path):
    """A v2 artifact (pre-quantization: no ``quant`` statics) must load
    with every unit defaulting to fp semantics."""
    net, params, host, x = _cnn_setup(width=8)
    res = compress(host, budget_ratio=0.6, P=100)
    path = os.path.join(str(tmp_path), "fp.npz")
    res.save(path)
    with np.load(path, allow_pickle=False) as z:
        data = {k: z[k] for k in z.files}
    spec = json.loads(data.pop("__spec__").item())
    data.pop("__fingerprint__")
    spec["format"] = 2
    for u in spec["units"]:
        u.pop("quant", None)
    v2 = os.path.join(str(tmp_path), "v2.npz")
    np.savez(v2, __spec__=np.array(json.dumps(spec)),
             __fingerprint__=np.array(artifact._digest(spec, data)), **data)
    art = runtime.load(v2)
    assert all(getattr(u, "quant", "none") == "none"
               for u in art.graph.units)
    y_live = runtime.execute(host.lower_plan(res.plan), x)
    np.testing.assert_array_equal(np.asarray(y_live),
                                  np.asarray(art.apply(x)))


def test_fp_artifact_fingerprint_unchanged_by_quant_knob(tmp_path):
    """quantize='none' must not leak into the artifact bytes."""
    _, _, host, _ = _cnn_setup(width=8)
    a = compress(host, budget_ratio=0.6, P=100)
    b = compress(host, budget_ratio=0.6, P=100, quantize="none")
    fpa = a.save(os.path.join(str(tmp_path), "a.npz"))
    fpb = b.save(os.path.join(str(tmp_path), "b.npz"))
    assert fpa == fpb


# ---------------------------------------------------------------------------
# Table cache + widened keys
# ---------------------------------------------------------------------------

def test_table_cache_fp_rows_shared_with_quant_run(tmp_path):
    """The cache stores fp-only rows: a quantize run derives siblings
    from the SAME cached table a plain run published (no double probe),
    and the cache file itself never contains tuple keys."""
    _, _, host, _ = _cnn_setup(width=8)
    cache = str(tmp_path)
    t_fp = build_tables(host, cache_dir=cache)
    t_q = build_tables(host, cache_dir=cache, quantize="int8")
    assert t_q.entries != t_fp.entries                 # widened in memory
    for span, row in t_fp.entries.items():
        for k, e in row.items():
            assert t_q.entries[span][k] == e
    files = [f for f in os.listdir(cache) if f.endswith(".json")]
    assert files
    for f in files:
        text = open(os.path.join(cache, f)).read()
        assert "int8" not in text                       # fp-only on disk
    # cold process over the same cache, quantized: identical widened table
    t_q2 = build_tables(host, cache_dir=cache, quantize="int8")
    assert t_q2.entries == t_q.entries
