"""Continuous-batching engine certification (ISSUE 7 acceptance bars).

* Exactness: mid-stream admission/retirement must be invisible in the
  tokens — every request served by the continuous engine under a
  staggered arrival trace with slot churn is TOKEN-IDENTICAL to serving
  its prompt alone, to the fixed-slot scheduler, and across slot counts.
* Isolation: an injected per-request NaN aborts exactly that request
  (correct disposition, truncated at the right generation index) while
  every surviving request stays bit-identical to the fault-free run; a
  slot that keeps aborting is quarantined (circuit breaker) instead of
  retrying forever.
* Overload safety: the bounded admission queue sheds on overflow, the
  deadline-aware shedder rejects requests that cannot finish in time at
  the observed decode rate, admitted-but-too-slow requests get
  ``deadline_miss`` with their partial tokens, and a wall-clock budget
  drains cleanly.  All timing runs on the deterministic
  :class:`repro.testing.faults.TickClock`.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import transformer as T
from repro.runtime import serving
from repro.testing import faults
from repro.train.step import make_serve_step


@pytest.fixture(scope="module")
def lm():
    cfg = dataclasses.replace(
        get_config("smollm-135m").reduced(), num_layers=2, d_model=64,
        num_heads=4, num_kv_heads=2, head_dim=16, d_ff=128, vocab_size=128)
    params, _ = T.init_model(cfg, jax.random.PRNGKey(0))
    return cfg, params, make_serve_step(cfg)


def _mk(cfg):
    return lambda b, s: T.init_cache(cfg, b, s)


def _ragged(cfg):
    rng = np.random.RandomState(0)
    prompts = [jnp.asarray(rng.randint(0, cfg.vocab_size, size=n), jnp.int32)
               for n in (5, 9, 3, 7, 6)]
    mat, lens = serving.pad_prompts(prompts)
    return prompts, mat, lens


ARRIVALS = [0.0, 0.5, 1.0, 2.5, 4.0]


# ---------------------------------------------------------------------------
# Exactness under churn
# ---------------------------------------------------------------------------

def test_exact_vs_single_prompt_under_arrival_trace(lm):
    """ISSUE acceptance: a staggered arrival trace with slot churn (5
    ragged requests through 2 slots, chunk 3 — requests are admitted
    mid-stream as slots vacate) must be token-for-token identical to
    serving each prompt alone."""
    cfg, params, step = lm
    N = 6
    prompts, mat, lens = _ragged(cfg)
    out = serving.serve_continuous(
        step, params, _mk(cfg), mat, lens, tokens=N, slots=2, chunk=3,
        arrivals=ARRIVALS, clock=faults.TickClock())
    gen = np.asarray(out[0])
    assert gen.shape == (5, N)
    assert out.report.engine == "continuous"
    assert out.report.ok and sorted(out.report.completed) == list(range(5))
    assert out.report.admitted == 5
    for i, p in enumerate(prompts):
        _, _, _, solo = serving.serve_loop(
            step, params, T.init_cache(cfg, 1, len(p) + N), p[None, :], N,
            warm=False)
        np.testing.assert_array_equal(gen[i], np.asarray(solo[0]))
    # per-request latency recorded for every completed request
    assert sorted(out.report.latency_s) == list(range(5))
    assert out.report.sustained_tok_s > 0


def test_matches_fixed_scheduler_and_slot_count_invariant(lm):
    """Same tokens as the fixed-slot scheduler, and invariant to the slot
    partitioning (2 vs 4 slots) under the same arrival trace."""
    cfg, params, step = lm
    N = 6
    _, mat, lens = _ragged(cfg)
    fixed, _ = serving.serve_requests(step, params, _mk(cfg), mat, lens,
                                      tokens=N, slots=2)
    outs = [serving.serve_continuous(
        step, params, _mk(cfg), mat, lens, tokens=N, slots=k, chunk=3,
        arrivals=ARRIVALS, clock=faults.TickClock())[0] for k in (2, 4)]
    np.testing.assert_array_equal(np.asarray(outs[0]), np.asarray(fixed))
    np.testing.assert_array_equal(np.asarray(outs[0]), np.asarray(outs[1]))


def test_token_budget_prefix_stable(lm):
    cfg, params, step = lm
    _, mat, lens = _ragged(cfg)
    full = serving.serve_continuous(step, params, _mk(cfg), mat, lens,
                                    tokens=6, slots=2, chunk=3,
                                    clock=faults.TickClock())
    capped = serving.serve_continuous(step, params, _mk(cfg), mat, lens,
                                      tokens=6, token_budget=3, slots=2,
                                      chunk=3, clock=faults.TickClock())
    gen = np.asarray(capped[0])
    assert gen.shape == (5, 3)
    assert capped.report.tokens_per_request == 3
    np.testing.assert_array_equal(gen, np.asarray(full[0])[:, :3])


def test_eos_retires_slot_early(lm):
    """EOS retirement: the row keeps tokens through the FIRST eos
    occurrence (zeroed after), the request completes, and the vacated
    slot is refilled — other requests unperturbed."""
    cfg, params, step = lm
    N = 6
    prompts, mat, lens = _ragged(cfg)
    clean = np.asarray(serving.serve_continuous(
        step, params, _mk(cfg), mat, lens, tokens=N, slots=2, chunk=3,
        clock=faults.TickClock())[0])
    eos = int(clean[0, 2])                  # retire request 0 mid-decode
    out = serving.serve_continuous(
        step, params, _mk(cfg), mat, lens, tokens=N, slots=2, chunk=3,
        eos_id=eos, clock=faults.TickClock())
    gen = np.asarray(out[0])
    assert sorted(out.report.completed) == list(range(5))
    row = clean[0].tolist()
    cut = row.index(eos) + 1
    assert gen[0].tolist() == row[:cut] + [0] * (N - cut)
    for r in range(1, 5):                   # rows without eos: untouched
        if eos not in clean[r].tolist():
            np.testing.assert_array_equal(gen[r], clean[r])


# ---------------------------------------------------------------------------
# Failure isolation
# ---------------------------------------------------------------------------

def test_injected_nan_isolated_to_one_stream(lm):
    """ISSUE acceptance: a per-request NaN injected mid-decode aborts
    that request at the right generation index while every surviving
    request's tokens are BIT-identical to the no-fault run — even though
    the abort vacates a slot early and reshuffles admission."""
    cfg, params, step = lm
    N = 6
    prompt = serving.random_prompts(7, 4, 5, cfg.vocab_size)
    lens = jnp.full((4,), 5, jnp.int32)
    kw = dict(tokens=N, slots=2, chunk=3, arrivals=[0.0, 0.5, 1.0, 1.5])
    clean = serving.serve_continuous(step, params, _mk(cfg), prompt, lens,
                                     clock=faults.TickClock(), **kw)
    with faults.inject(faults.Fault("serve.nan", "nan", rid=1, at=2)):
        out = serving.serve_continuous(step, params, _mk(cfg), prompt,
                                       lens, clock=faults.TickClock(), **kw)
    gen, cg = np.asarray(out[0]), np.asarray(clean[0])
    assert out.report.aborted == {1: 2}
    assert not out.report.ok
    assert out.report.dispositions[1] == "aborted"
    assert sorted(out.report.completed) == [0, 2, 3]
    for r in (0, 2, 3):
        np.testing.assert_array_equal(gen[r], cg[r])
    np.testing.assert_array_equal(gen[1, :2], cg[1, :2])
    assert gen[1, 2:].tolist() == [0] * (N - 2)


def test_nan_during_prefill_aborts_at_zero(lm):
    cfg, params, step = lm
    prompt = serving.random_prompts(8, 2, 5, cfg.vocab_size)
    lens = jnp.full((2,), 5, jnp.int32)
    with faults.inject(faults.Fault("serve.nan", "nan", rid=0, at=-2)):
        # generation index -2 ⇒ global step L-3: mid-prefill
        out = serving.serve_continuous(step, params, _mk(cfg), prompt,
                                       lens, tokens=4, slots=2, chunk=3,
                                       clock=faults.TickClock())
    assert out.report.aborted == {0: 0}
    assert np.asarray(out[0][0]).tolist() == [0, 0, 0, 0]


def test_circuit_breaker_quarantines_slot(lm):
    """Two NaN-aborts on the same slot trip the breaker: the slot is
    quarantined (never refilled), and with no slots left the remaining
    request is reported unserved instead of retried forever."""
    cfg, params, step = lm
    prompt = serving.random_prompts(7, 3, 5, cfg.vocab_size)
    lens = jnp.full((3,), 5, jnp.int32)
    with faults.inject(faults.Fault("serve.nan", "nan", rid=0, at=1),
                       faults.Fault("serve.nan", "nan", rid=1, at=1)):
        out = serving.serve_continuous(
            step, params, _mk(cfg), prompt, lens, tokens=6, slots=1,
            chunk=3, slot_nan_limit=2, clock=faults.TickClock())
    assert out.report.dispositions == {0: "aborted", 1: "aborted",
                                       2: "unserved"}
    assert out.report.quarantined_slots == [0]
    assert not out.report.ok


# ---------------------------------------------------------------------------
# Overload safety: shedding, deadlines, queue bound, drain
# ---------------------------------------------------------------------------

def test_deadline_aware_shedding(lm):
    """Once the EWMA decode rate is established (request 0 warms it), a
    request whose deadline cannot be met is shed UP FRONT; with a
    generous deadline the same request is served."""
    cfg, params, step = lm
    prompt = serving.random_prompts(10, 2, 5, cfg.vocab_size)
    lens = jnp.full((2,), 5, jnp.int32)
    kw = dict(tokens=5, slots=1, chunk=4, arrivals=[0.0, 3.0])
    # rate = 4 steps/tick; request 1 needs 9 steps ⇒ eta 3 + 2.25 > 3 + 2
    shed = serving.serve_continuous(
        step, params, _mk(cfg), prompt, lens, deadlines=[None, 2.0],
        clock=faults.TickClock(), **kw)
    assert shed.report.dispositions == {0: "completed", 1: "shed"}
    assert np.asarray(shed[0][1]).tolist() == [0] * 5      # zeroed row
    ok = serving.serve_continuous(
        step, params, _mk(cfg), prompt, lens, deadlines=[None, 50.0],
        clock=faults.TickClock(), **kw)
    assert ok.report.dispositions == {0: "completed", 1: "completed"}


def test_deadline_miss_mid_serve_keeps_partial_tokens(lm):
    """An admitted request that blows its deadline mid-decode retires
    with ``deadline_miss`` and keeps the tokens generated so far (a
    prefix of the unconstrained run)."""
    cfg, params, step = lm
    prompt = serving.random_prompts(11, 1, 5, cfg.vocab_size)
    lens = jnp.full((1,), 5, jnp.int32)
    kw = dict(tokens=6, slots=1, chunk=2)
    full = np.asarray(serving.serve_continuous(
        step, params, _mk(cfg), prompt, lens, clock=faults.TickClock(),
        **kw)[0])
    out = serving.serve_continuous(
        step, params, _mk(cfg), prompt, lens, deadlines=[3.0],
        clock=faults.TickClock(), **kw)
    assert out.report.deadline_miss == {0: 4}
    assert out.report.dispositions[0] == "deadline_miss"
    gen = np.asarray(out[0])
    np.testing.assert_array_equal(gen[0, :4], full[0, :4])
    assert gen[0, 4:].tolist() == [0, 0]


def test_bounded_queue_sheds_overflow(lm):
    cfg, params, step = lm
    prompt = serving.random_prompts(7, 4, 5, cfg.vocab_size)
    lens = jnp.full((4,), 5, jnp.int32)
    out = serving.serve_continuous(
        step, params, _mk(cfg), prompt, lens, tokens=6, slots=1, chunk=2,
        max_queue=1, clock=faults.TickClock())
    d = out.report.dispositions
    assert d[0] == "completed"
    assert [d[r] for r in (1, 2, 3)] == ["shed"] * 3
    assert out.report.queue_peak == 1


def test_time_budget_drains_cleanly(lm):
    cfg, params, step = lm
    prompt = serving.random_prompts(10, 3, 5, cfg.vocab_size)
    lens = jnp.full((3,), 5, jnp.int32)
    out = serving.serve_continuous(
        step, params, _mk(cfg), prompt, lens, tokens=4, slots=2,
        warm=False, time_budget_s=0.0, clock=faults.TickClock())
    gen, _ = out
    assert gen.shape == (3, 4)
    assert out.report.deadline_hit
    assert out.report.unserved == [0, 1, 2]
    assert np.asarray(gen).tolist() == [[0] * 4] * 3


def test_engine_drain_finishes_in_flight_only(lm):
    """Explicit drain: in-flight requests finish exactly, queued ones
    come back unserved."""
    cfg, params, step = lm
    N = 6
    prompts, mat, lens = _ragged(cfg)
    eng = serving.ContinuousEngine(
        step, params, _mk(cfg), slots=2, max_seq=int(mat.shape[1]) + N,
        chunk=3, clock=faults.TickClock())
    pn, ln = np.asarray(mat), np.asarray(lens)
    for r in range(5):
        eng.submit(pn[r, :ln[r]], tokens=N, rid=r)
    eng._pending.sort(key=lambda q: (q.arrival, q.rid))
    now = eng._now = eng._clock()
    eng._ingest(now)
    eng._admit(now)                          # requests 0 and 1 in flight
    report = eng.drain()
    assert sorted(report.completed) == [0, 1]
    assert sorted(report.unserved) == [2, 3, 4]
    for i in (0, 1):
        p = prompts[i]
        _, _, _, solo = serving.serve_loop(
            step, params, T.init_cache(cfg, 1, len(p) + N), p[None, :], N,
            warm=False)
        assert eng.requests[i].tokens == np.asarray(solo[0]).tolist()


# ---------------------------------------------------------------------------
# API edges
# ---------------------------------------------------------------------------

def test_submit_validation(lm):
    cfg, params, step = lm
    eng = serving.ContinuousEngine(step, params, _mk(cfg), slots=1,
                                   max_seq=8, warm=False)
    with pytest.raises(ValueError, match="max_seq"):
        eng.submit(np.arange(5), tokens=4)   # 5 + 4 > 8
    with pytest.raises(ValueError, match="empty"):
        eng.submit(np.zeros((0,)), tokens=2)
    eng.submit(np.arange(3), tokens=2, rid=7)
    with pytest.raises(ValueError, match="duplicate"):
        eng.submit(np.arange(3), tokens=2, rid=7)
    with pytest.raises(ValueError, match="slot"):
        serving.ContinuousEngine(step, params, _mk(cfg), slots=0,
                                 max_seq=8, warm=False)


def test_zero_requests(lm):
    cfg, params, step = lm
    out = serving.serve_continuous(step, params, _mk(cfg), [], tokens=4)
    gen, secs = out
    assert gen.shape == (0, 4) and secs >= 0.0
    assert out.report.ok and out.report.engine == "continuous"


def test_stack_cache_per_slot_positions(lm):
    """stack_cache gives every leaf a leading slot axis — the scalar
    cache position becomes per-slot, the enabling fact for mid-stream
    admission."""
    cfg, _, _ = lm
    cache = T.init_cache(cfg, 1, 12)
    stacked = serving.stack_cache(cache, 3)
    for base_leaf, slot_leaf in zip(jax.tree.leaves(cache),
                                    jax.tree.leaves(stacked)):
        assert slot_leaf.shape == (3,) + base_leaf.shape


def test_dispositions_cover_every_request(lm):
    assert serving.DISPOSITIONS == ("completed", "aborted", "shed",
                                    "deadline_miss", "unserved")
    rep = serving.ServeReport(completed=[0], aborted={1: 2}, shed=[3],
                              deadline_miss={4: 1}, unserved=[5])
    assert rep.dispositions == {0: "completed", 1: "aborted", 3: "shed",
                                4: "deadline_miss", 5: "unserved"}
    assert not rep.ok
    assert serving.ServeReport(completed=[0]).ok


# ---------------------------------------------------------------------------
# Cross-host failover (ISSUE 8: worker loss, replay, zero lost requests)
# ---------------------------------------------------------------------------

def test_worker_loss_failover_replays_bit_identical(lm):
    """ISSUE acceptance: a worker loss mid-decode triggers one failover;
    every request ends with a disposition (zero lost), the replayed
    requests are recorded, and the tokens are BIT-identical to an
    uninterrupted run."""
    cfg, params, step = lm
    prompt = serving.random_prompts(0, 5, 5, cfg.vocab_size)
    lens = jnp.full((5,), 5, jnp.int32)
    kw = dict(tokens=6, slots=2, chunk=3)
    clean = serving.serve_continuous(step, params, _mk(cfg), prompt, lens,
                                     clock=faults.TickClock(), **kw)
    with faults.inject(faults.Fault("serve.worker", "raise", nth=3)):
        out = serving.serve_with_failover(step, params, _mk(cfg), prompt,
                                          lens, clock=faults.TickClock(),
                                          **kw)
    rep = out.report
    assert rep.engine == "continuous+failover"
    assert rep.failovers == 1 and len(rep.lost_workers) == 1
    assert rep.replayed                      # in-flight requests replayed
    assert sorted(rep.dispositions) == list(range(5))   # zero lost
    assert sorted(rep.completed) == list(range(5))
    np.testing.assert_array_equal(np.asarray(out[0]), np.asarray(clean[0]))


def test_worker_loss_uncaught_leaves_no_disposition(lm):
    """A bare engine surfaces the loss as WorkerLost (with .lost ids from
    the health check); unfinished requests stay disposition-None —
    visibly incomplete, never silently completed."""
    cfg, params, step = lm
    eng = serving.ContinuousEngine(
        step, params, _mk(cfg), slots=1, max_seq=16, chunk=3,
        clock=faults.TickClock(), health_check=lambda: [2])
    eng.submit(np.arange(1, 6), tokens=4, rid=0)
    with pytest.raises(serving.WorkerLost) as ei:
        eng.run()
    assert ei.value.lost == [2]
    assert eng.requests[0].disposition is None


def test_failover_exhaustion_reports_unserved(lm):
    """Losses beyond max_failovers stop the retry loop; the remaining
    requests come back ``unserved`` — every rid still has a disposition."""
    cfg, params, step = lm
    prompt = serving.random_prompts(1, 3, 5, cfg.vocab_size)
    lens = jnp.full((3,), 5, jnp.int32)
    with faults.inject(faults.Fault("serve.worker", "raise", nth=1,
                                    times=99)):
        out = serving.serve_with_failover(
            step, params, _mk(cfg), prompt, lens, tokens=6, slots=2,
            chunk=3, max_failovers=1, clock=faults.TickClock())
    rep = out.report
    assert rep.failovers == 2                # initial + one re-formation
    assert sorted(rep.dispositions) == [0, 1, 2]
    assert sorted(rep.unserved) == [0, 1, 2]
    assert np.asarray(out[0]).tolist() == [[0] * 6] * 3


def test_health_check_failover_and_survivor_slots(lm):
    """A health_check that reports a loss once drives the same failover
    path as the fault hook; the re-formed engine runs on fewer slots
    (survivor capacity) and still completes everything identically."""
    cfg, params, step = lm
    prompt = serving.random_prompts(2, 4, 5, cfg.vocab_size)
    lens = jnp.full((4,), 5, jnp.int32)
    kw = dict(tokens=6, slots=2, chunk=3)
    clean = serving.serve_continuous(step, params, _mk(cfg), prompt, lens,
                                     clock=faults.TickClock(), **kw)
    calls = {"n": 0}

    def flaky_health():
        calls["n"] += 1
        return [1] if calls["n"] == 2 else []

    seen_slots = []

    def factory(attempt):
        kws = {"slots": max(1, 2 >> attempt)}
        seen_slots.append(kws["slots"])
        if attempt > 0:
            kws["health_check"] = lambda: []     # survivors are healthy
        return kws

    out = serving.serve_with_failover(
        step, params, _mk(cfg), prompt, lens, health_check=flaky_health,
        engine_factory=factory, clock=faults.TickClock(), **kw)
    rep = out.report
    assert rep.failovers == 1 and rep.lost_workers == [1]
    assert seen_slots == [2, 1]
    assert sorted(rep.completed) == list(range(4))
    np.testing.assert_array_equal(np.asarray(out[0]), np.asarray(clean[0]))


def test_failover_clean_run_untouched(lm):
    """No loss ⇒ serve_with_failover is serve_continuous with a different
    engine tag: same tokens, no failover bookkeeping."""
    cfg, params, step = lm
    _, mat, lens = _ragged(cfg)
    clean = serving.serve_continuous(step, params, _mk(cfg), mat, lens,
                                     tokens=6, slots=2, chunk=3,
                                     arrivals=ARRIVALS,
                                     clock=faults.TickClock())
    out = serving.serve_with_failover(step, params, _mk(cfg), mat, lens,
                                      tokens=6, slots=2, chunk=3,
                                      arrivals=ARRIVALS,
                                      clock=faults.TickClock())
    rep = out.report
    assert rep.failovers == 0 and not rep.replayed and not rep.lost_workers
    assert rep.ok and sorted(rep.completed) == list(range(5))
    np.testing.assert_array_equal(np.asarray(out[0]), np.asarray(clean[0]))


# ---------------------------------------------------------------------------
# Compressed-graph integration (GraphExecutor.continuous_engine)
# ---------------------------------------------------------------------------

def test_graph_executor_continuous_engine():
    """The continuous engine over a compressed artifact graph serves
    token-identically to the graph's own single-prompt serve loop, and
    slot_state stacks every per-unit cache leaf (incl. ``{}`` units)."""
    from repro import runtime
    from repro.core import compress
    from repro.models.transformer_host import CostEnv, TransformerHost

    cfg = dataclasses.replace(get_config("smollm-135m").reduced(),
                              num_layers=4)
    params, _ = T.init_model(cfg, jax.random.PRNGKey(0))
    host = TransformerHost(cfg, params, env=CostEnv(batch=2, seq=16))
    res = compress(host, budget_ratio=0.6, P=200)
    graph = host.lower_plan(res.plan)
    N, P = 5, 6
    state = runtime.slot_state(graph, 2, P + N)
    for base_leaf, slot_leaf in zip(
            jax.tree.leaves(runtime.init_cache(graph, 1, P + N)),
            jax.tree.leaves(state)):
        assert slot_leaf.shape == (2,) + base_leaf.shape

    ex = runtime.GraphExecutor(graph)
    prompts = serving.random_prompts(3, 3, P, cfg.vocab_size)
    eng = ex.continuous_engine(slots=2, max_seq=P + N, chunk=3,
                               clock=faults.TickClock())
    pn = np.asarray(prompts)
    for r in range(3):
        eng.submit(pn[r], tokens=N, arrival=0.5 * r, rid=r)
    report = eng.run()
    assert sorted(report.completed) == [0, 1, 2]
    step, gp = ex.serve_step()
    for r in range(3):
        _, _, _, solo = serving.serve_loop(
            step, gp, ex.init_cache(1, P + N), prompts[r][None, :], N,
            warm=False)
        assert eng.requests[r].tokens == np.asarray(solo[0]).tolist()
