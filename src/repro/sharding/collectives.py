"""shard_map building blocks for the distribution layer.

* :func:`flash_decode_attention` — decode attention with the KV cache
  sharded along *sequence* over the 'model' axis (flash-decoding): each
  shard computes a partial (m, l, o) softmax triple over its cache slice;
  the exact global softmax is reconstructed with one pmax + two psums of
  O(B·H·D) — instead of all-gathering the (B·S·KVH·D) cache.  This is the
  §Perf fix for decode cells (the XLA baseline all-gathers the cache).

* :func:`gpipe_forward` — GPipe-style pipelined forward over an axis
  ('pod'): stage p holds layers [p·L/P, (p+1)·L/P); microbatches stream
  through a collective_permute shift register.  Forward-only (serving /
  dry-run); the training path uses DP over 'pod' by default.

* :func:`compressed_allreduce` — int8 error-feedback gradient all-reduce
  (optim/compress.py) bound to a mesh axis.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

import inspect

try:                                    # jax >= 0.5 top-level export
    from jax import shard_map as _jax_shard_map
except ImportError:                     # jax 0.4.x: experimental namespace
    from jax.experimental.shard_map import shard_map as _jax_shard_map

if "check_vma" in inspect.signature(_jax_shard_map).parameters:
    shard_map = _jax_shard_map
else:
    def shard_map(f, **kwargs):
        """Map the modern ``check_vma`` kwarg onto jax 0.4.x's ``check_rep``."""
        if "check_vma" in kwargs:
            kwargs["check_rep"] = kwargs.pop("check_vma")
        return _jax_shard_map(f, **kwargs)

_shard_map = shard_map                  # module-internal alias

from repro.optim.compress import compressed_psum


# ---------------------------------------------------------------------------
# Flash-decoding: distributed LSE combine over a sequence-sharded cache
# ---------------------------------------------------------------------------

def _local_partial(q, k, v, valid, scale):
    """Partial attention over the local KV slice (GQA-aware).

    q: (B, KVH, G, D); k, v: (B, S_l, KVH, D); valid: (B, S_l) bool.
    Returns (o: (B, KVH, G, D) unnormalized, l: (B, KVH, G), m: same).
    """
    s = jnp.einsum("bkgd,bskd->bkgs", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    s = jnp.where(valid[:, None, None, :], s, -1e30)
    m = jnp.max(s, axis=-1)
    p = jnp.exp(s - m[..., None])
    l = jnp.sum(p, axis=-1)
    o = jnp.einsum("bkgs,bskd->bkgd", p, v.astype(jnp.float32))
    return o, l, m


def flash_decode_attention(q, k, v, valid, *, mesh: Mesh,
                           axis: str = "model"):
    """Exact decode attention with seq-sharded KV (GQA supported).

    q: (B, H, D) replicated over ``axis``; k, v: (B, S, KVH, D) sharded on
    S; valid: (B, S) bool sharded on S.  H must be a multiple of KVH.
    Returns (B, H, D).
    """
    b, h, d = q.shape
    kvh = k.shape[2]
    g = h // kvh
    scale = 1.0 / math.sqrt(d)
    qg = q.reshape(b, kvh, g, d)
    # keep the batch dim sharded over the data axes — only the kv-seq dim
    # participates in the LSE combine (replicating batch would all-gather
    # the entire cache across 'data': the refuted first attempt, see §Perf)
    batch_axes = tuple(a for a in ("pod", "data") if a in mesh.shape) or None
    if batch_axes:
        n_data = 1
        for a in batch_axes:
            n_data *= mesh.shape[a]
        if b % n_data != 0:           # e.g. long_500k batch=1: replicate
            batch_axes = None
    bspec = batch_axes if batch_axes and len(batch_axes) > 1 else \
        (batch_axes[0] if batch_axes else None)

    def local(qg, k, v, valid):
        o, l, m = _local_partial(qg, k, v, valid, scale)
        g_m = lax.pmax(m, axis)
        corr = jnp.exp(m - g_m)
        o = lax.psum(o * corr[..., None], axis)
        l = lax.psum(l * corr, axis)
        return (o / jnp.maximum(l, 1e-30)[..., None]).astype(q.dtype)

    out = _shard_map(
        local, mesh=mesh,
        in_specs=(P(bspec), P(bspec, axis), P(bspec, axis), P(bspec, axis)),
        out_specs=P(bspec),
    )(qg, k, v, valid)
    return out.reshape(b, h, d)


def flash_decode_reference(q, k, v, valid):
    """Oracle: plain masked softmax attention over the full cache (GQA)."""
    b, h, d = q.shape
    kvh = k.shape[2]
    qg = q.reshape(b, kvh, h // kvh, d)
    scale = 1.0 / math.sqrt(d)
    s = jnp.einsum("bkgd,bskd->bkgs", qg.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    s = jnp.where(valid[:, None, None, :], s, -1e30)
    w = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgs,bskd->bkgd", w, v.astype(jnp.float32))
    return out.reshape(b, h, d).astype(q.dtype)


# ---------------------------------------------------------------------------
# GPipe forward over an axis
# ---------------------------------------------------------------------------

def gpipe_forward(stage_fn, stage_params, x, *, mesh: Mesh,
                  axis: str = "pod", num_micro: int = 4):
    """Pipelined forward.

    stage_params: pytree stacked on a leading stage axis (size = mesh[axis]),
    sharded over ``axis``.  x: (B, ...) replicated.  stage_fn(params, x_mb)
    applies one stage.  Returns stage_{P-1}'s outputs for all microbatches.
    """
    n_stage = mesh.shape[axis]
    assert x.shape[0] % num_micro == 0

    def local(params_local, x_local):
        params_local = jax.tree.map(lambda t: t[0], params_local)
        idx = lax.axis_index(axis)
        mbs = x_local.reshape((num_micro, x_local.shape[0] // num_micro)
                              + x_local.shape[1:])
        buf = jnp.zeros_like(mbs[0])
        outs = jnp.zeros_like(mbs)
        perm = [(i, i + 1) for i in range(n_stage - 1)]
        for t in range(num_micro + n_stage - 1):
            inject = mbs[min(t, num_micro - 1)]
            buf_in = jnp.where(idx == 0,
                               jnp.where(t < num_micro, inject,
                                         jnp.zeros_like(inject)),
                               buf)
            y = stage_fn(params_local, buf_in)
            out_t = t - (n_stage - 1)
            if 0 <= out_t < num_micro:
                outs = outs.at[out_t].set(y)
            buf = lax.ppermute(y, axis, perm)
        # only the last stage's outs are meaningful — replicate them
        outs = lax.psum(jnp.where(idx == n_stage - 1, outs,
                                  jnp.zeros_like(outs)), axis)
        return outs.reshape(x_local.shape)

    return _shard_map(
        local, mesh=mesh,
        in_specs=(P(axis), P()),
        out_specs=P(),
        check_vma=False,
    )(stage_params, x)


def compressed_allreduce(grads, *, mesh: Mesh, axis: str = "data"):
    """int8 all-reduce of data-parallel gradients (call on replicated-over-
    axis grads; returns the summed result on every shard)."""
    fn = _shard_map(lambda g: compressed_psum(g, axis), mesh=mesh,
                    in_specs=P(axis), out_specs=P(axis))
    return fn(grads)
