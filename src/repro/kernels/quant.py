"""Shared symmetric quantization primitives — ONE rounding semantics.

Every quantized path in the repo routes through this module: the int8
gradient compression in :mod:`repro.optim.compress` (per-tensor) and the
quantized merged Pallas kernels (per-channel weight scales).  Keeping a
single clip-round definition means the DP planner's error budgets, the
kernels' dequant epilogues, and the gradient all-reduce all agree on what
"int8" means bit for bit.

Scale layout contract
---------------------
Symmetric, zero-point-free: ``x ≈ q.astype(f32) * scale`` with
``scale = max(amax, 1e-30) / 127`` (int8) so ``q ∈ [-127, 127]``.

* per-tensor (``axis=None``): ``scale`` is a scalar — identical semantics
  to the original ``optim/compress.py`` helpers.
* per-channel (``axis=i``): ``scale`` has shape ``(x.shape[i],)`` — one
  scale per slice along axis ``i``.  Merged-conv weights quantize along
  the output-channel axis (HWIO axis 3), low-rank factors along their
  contraction-output axis, so the kernel can apply the scale AFTER the
  fp32 accumulation (mathematically identical to dequantizing each weight
  before the dot, since the scale is constant over the contraction).

fp8 (``float8_e4m3fn``) uses the same machinery with ``amax / 448`` (the
e4m3 finite max); rounding is the hardware cast's round-to-nearest-even.
This is scaffolding for real-TPU fp8 MXU dots — numerics are exercised in
interpret mode today, see ROADMAP's real-TPU item.

Error budgets
-------------
``error_budget(w, mode, fan_in, x_absmax)`` returns a rigorous worst-case
absolute output-error bound for a dot/conv reduction of ``fan_in`` terms:
each int8 weight carries ≤ ``scale/2`` absolute error, so the output
error is ≤ ``fan_in · x_absmax · max(w_scale)/2``; w8a8 adds the
activation-quantization term ``fan_in · (w_absmax·x_scale/2 +
x_scale·w_scale/4)``.  fp8-e4m3 has ≤ 2^-4 relative error per weight
(3 mantissa bits ⇒ half-ulp 2^-4), giving ``fan_in · x_absmax ·
w_absmax · 2^-4``.  Certification tests assert |quantized − fp32 ref| is
within these budgets — they are bounds, not tolerances tuned to pass.
"""
from __future__ import annotations

import jax.numpy as jnp

INT8_QMAX = 127.0
FP8_E4M3_MAX = 448.0

#: Quantization modes understood by the planner/kernels.  "none" = fp.
MODES = ("none", "int8", "w8a8", "fp8")

#: Modes where the WEIGHT operand is narrow (all non-fp modes).
WEIGHT_NARROW = ("int8", "w8a8", "fp8")

#: Modes where the ACTIVATION operand is narrow too.
ACT_NARROW = ("w8a8",)


def _amax(x, axis):
    a = jnp.abs(x).astype(jnp.float32)
    if axis is None:
        return jnp.max(a)
    reduce_axes = tuple(i for i in range(x.ndim) if i != axis % x.ndim)
    return jnp.max(a, axis=reduce_axes)


def quantize_int8(x, axis: int | None = None):
    """Symmetric int8: returns ``(q, scale)``.

    ``axis=None`` → per-tensor scalar scale (bit-identical to the
    historical ``optim.compress.quantize_int8``); ``axis=i`` → one scale
    per slice along axis ``i`` (shape ``(x.shape[i],)``).
    """
    amax = _amax(x, axis)
    scale = jnp.maximum(amax, 1e-30) / INT8_QMAX
    if axis is None:
        div = scale
    else:
        shape = [1] * x.ndim
        shape[axis % x.ndim] = -1
        div = scale.reshape(shape)
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / div), -INT8_QMAX,
                 INT8_QMAX)
    return q.astype(jnp.int8), scale


def dequantize_int8(q, scale, axis: int | None = None):
    y = q.astype(jnp.float32)
    if axis is None:
        return y * scale
    shape = [1] * q.ndim
    shape[axis % q.ndim] = -1
    return y * scale.reshape(shape)


def quantize_fp8(x, axis: int | None = None):
    """Symmetric float8_e4m3fn: returns ``(q, scale)`` — same scale layout
    as int8; the cast's round-to-nearest-even does the rounding."""
    amax = _amax(x, axis)
    scale = jnp.maximum(amax, 1e-30) / FP8_E4M3_MAX
    if axis is None:
        div = scale
    else:
        shape = [1] * x.ndim
        shape[axis % x.ndim] = -1
        div = scale.reshape(shape)
    q = (x.astype(jnp.float32) / div).astype(jnp.float8_e4m3fn)
    return q, scale


def dequantize(q, scale, axis: int | None = None):
    """Dequantize any narrow dtype (int8 or fp8): ``q.astype(f32)*scale``."""
    return dequantize_int8(q, scale, axis)


def quantize_weight(w, mode: str, axis: int):
    """Quantize a weight tensor per-channel along ``axis`` for ``mode``.

    Returns ``(q, scale)``; mode "none" returns ``(w, None)``.
    """
    if mode == "none":
        return w, None
    if mode in ("int8", "w8a8"):
        return quantize_int8(w, axis=axis)
    if mode == "fp8":
        return quantize_fp8(w, axis=axis)
    raise ValueError(f"unknown quantization mode {mode!r}")


def error_budget(mode: str, *, fan_in: int, x_absmax: float,
                 w_absmax: float) -> float:
    """Worst-case |quantized − fp32| bound for one output of a reduction
    over ``fan_in`` multiply-accumulates (see module docstring)."""
    if mode == "none":
        return 0.0
    w_scale = max(w_absmax, 1e-30) / INT8_QMAX
    if mode == "int8":
        return fan_in * x_absmax * (w_scale / 2.0)
    if mode == "w8a8":
        x_scale = max(x_absmax, 1e-30) / INT8_QMAX
        return fan_in * (x_absmax * w_scale / 2.0
                         + w_absmax * x_scale / 2.0
                         + x_scale * w_scale / 4.0)
    if mode == "fp8":
        return fan_in * x_absmax * w_absmax * 2.0 ** -4
    raise ValueError(f"unknown quantization mode {mode!r}")


def weight_bytes(mode: str) -> int | None:
    """Weight byte width for the cost model (None = host default fp)."""
    return 1 if mode in WEIGHT_NARROW else None


def act_bytes(mode: str) -> int | None:
    """Activation byte width for the cost model (None = host default fp)."""
    return 1 if mode in ACT_NARROW else None
