"""Batched serving example: prefill + KV-cache decode on a small LM.

Demonstrates the serve path the decode_32k / long_500k dry-run cells lower:
build a cache from a prompt batch (teacher-forced prefill), then run the
jit'd one-token serve_step in a decode loop with greedy sampling.

With ``--artifact`` the example serves a LayerMerge-COMPRESSED model:
it loads a portable merged-model artifact (written by
``python -m repro.compress`` or ``CompressResult.save``), decodes through
the shared unit-graph executor (KV-cache aware — merged low-rank
segments carry no decode state at all), and reports compressed-vs-
original throughput side by side.

Run:  PYTHONPATH=src python examples/serve_lm.py [--tokens 32] [--batch 4]
      PYTHONPATH=src python -m repro.compress --arch smollm-135m \
          --budget-ratio 0.55 --out lm.npz
      PYTHONPATH=src python examples/serve_lm.py --artifact lm.npz
"""
import argparse
import dataclasses

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.models import transformer as T
from repro.runtime import serve_loop
from repro.train.step import make_serve_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--tokens", type=int, default=32)
    ap.add_argument("--arch", default="smollm-135m")
    ap.add_argument("--artifact", default=None,
                    help="merged-model artifact (.npz); serves the "
                         "compressed model and compares throughput")
    ap.add_argument("--seed", type=int, default=0,
                    help="original-model init seed (overridden by the "
                         "artifact's recorded source seed)")
    args = ap.parse_args()

    art = None
    if args.artifact:
        from repro import runtime

        art = runtime.load(args.artifact)
        if art.graph.family != "transformer":
            raise SystemExit("[serve_lm] --artifact must hold a "
                             "transformer-family graph")
        cfg = art.graph.meta["config"]
        seed = art.meta.get("source", {}).get("seed", args.seed)
        print(f"[serve_lm] artifact {args.artifact} "
              f"(fingerprint {art.fingerprint[:16]}, "
              f"oracle {art.meta.get('oracle')})")
    else:
        cfg = dataclasses.replace(
            get_config(args.arch).reduced(), num_layers=4, d_model=128,
            num_heads=4, num_kv_heads=2, head_dim=32, d_ff=256,
            vocab_size=512)
        seed = args.seed
    params, _ = T.init_model(cfg, jax.random.PRNGKey(seed))
    B, P = args.batch, args.prompt_len
    total = P + args.tokens
    prompt = jax.random.randint(jax.random.PRNGKey(1), (B, P), 0,
                                cfg.vocab_size)

    # original model: prefill the prompt token by token through the jit'd
    # serve step (production prefill is the prefill_32k dry-run cell; for
    # the example a decode-loop warm-up keeps one compiled program)
    serve = jax.jit(make_serve_step(cfg))
    cache = T.init_cache(cfg, B, total)
    prefill_s, decode_s, _, seqs = serve_loop(serve, params, cache, prompt,
                                              args.tokens)
    tps = (args.tokens - 1) * B / decode_s
    print(f"[serve_lm] batch={B} prompt={P} generated={args.tokens}")
    print(f"[serve_lm] original   prefill {prefill_s*1e3:.1f} ms, decode "
          f"{decode_s*1e3:.1f} ms ({tps:.0f} tok/s on this host)")

    if art is not None:
        step, cparams = art.make_serve_step()
        step = jax.jit(step)
        ccache = art.init_cache(B, total)
        c_prefill_s, c_decode_s, _, cseqs = serve_loop(
            step, cparams, ccache, prompt, args.tokens)
        ctps = (args.tokens - 1) * B / c_decode_s
        print(f"[serve_lm] compressed prefill {c_prefill_s*1e3:.1f} ms, "
              f"decode {c_decode_s*1e3:.1f} ms ({ctps:.0f} tok/s)")
        print(f"[serve_lm] decode speedup {decode_s / c_decode_s:.2f}x "
              f"(DP-predicted {art.meta.get('predicted_speedup', '?')}x)")
        print(f"[serve_lm] compressed continuation ids: "
              f"{cseqs[0, :12].tolist()}")
    print(f"[serve_lm] sample continuation ids: {seqs[0, :12].tolist()}")


if __name__ == "__main__":
    main()
