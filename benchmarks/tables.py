"""One benchmark per paper table/figure (see DESIGN.md §4 for the index).

Full-scale ImageNet runs are impossible offline, so each table is
reproduced at *structure-preserving* scale: identical skip/stride/depthwise
topology, measured wall-clock latency tables on this host, Eq. 4 importance
with short fine-tunes on synthetic tasks.  The claims being validated are
the paper's *relative* ones: LayerMerge dominates Depth and LayerOnly on
the speed-accuracy Pareto front; joint beats sequential; the DP runs in
seconds; merged-kernel growth erodes naive depth compression.

Each function returns CSV-ish rows: (name, us_per_call, derived).
"""
from __future__ import annotations

import dataclasses
import time

import jax
import jax.numpy as jnp

from repro.core import (AnalyticTPUOracle, ImportanceSpec, WallClockOracle,
                        accuracy_perf, compress, merge, table_entry_count,
                        xent_loss)
from repro.core.importance import _adam_finetune
from repro.models import cnn, cnn_host, zoo


_TEACHER = {}


def _toy(key, n, hw, classes=4, net=None):
    """Teacher-labelled task: realizable by construction (labels come from a
    frozen randomly-initialized copy of the same architecture)."""
    x = jax.random.normal(key, (n, hw, hw, 3))
    tkey = (net.L, hw, classes) if net is not None else (0, hw, classes)
    if tkey not in _TEACHER:
        tnet = net or zoo.tiny_resnet(num_classes=classes, in_hw=hw)
        tp = cnn.init_params(tnet, jax.random.PRNGKey(1234))
        _TEACHER[tkey] = (tnet, tp)
    tnet, tp = _TEACHER[tkey]
    logits = cnn.apply_replaced(tnet, tp, x)
    return x, jnp.argmax(logits, axis=1)


def _pretrain(net, params, data, steps=250):
    apply0 = lambda p, x: cnn.apply_replaced(net, p, x)
    spec = ImportanceSpec(loss_fn=xent_loss, perf_fn=accuracy_perf,
                          train_batches=[data[0]], eval_batches=[data[1]],
                          steps=steps, lr=3e-3)
    return _adam_finetune(apply0, params, spec), apply0


def _wallclock(fn, iters=15):
    fn()
    t0 = time.perf_counter()
    for _ in range(iters):
        jax.block_until_ready(fn())
    return (time.perf_counter() - t0) / iters


def _compression_pareto(name, net, budgets, methods, ft_steps=60,
                        importance="magnitude"):
    """Shared harness for tables 1–4: per (method, budget): accuracy after
    fine-tune + measured merged speed-up on this host.

    Importance defaults to the magnitude proxy here to keep the harness
    fast (~200 candidate fine-tune jits otherwise); the paper's measured
    Eq. 4 pipeline is exercised by table45_ddpm and tests/test_compress.py.
    Note the wall-clock `speedup` column is measured on THIS CPU host while
    the DP optimizes the analytic v5e oracle (`dp_pred`) — big merged
    kernels that win on the MXU can lose on CPU's conv path; compare
    dp_pred across methods for the paper's claims."""
    params = cnn.init_params(net, jax.random.PRNGKey(0))
    tr = _toy(jax.random.PRNGKey(1), 256, net.in_hw, net=net)
    ev = _toy(jax.random.PRNGKey(2), 256, net.in_hw, net=net)
    params, apply0 = _pretrain(net, params, (tr, ev))
    base_acc = accuracy_perf(apply0, params, [ev])
    host = cnn_host.CNNHost(net, params, batch=32)
    ispec = ImportanceSpec(loss_fn=xent_loss, perf_fn=accuracy_perf,
                           train_batches=[tr], eval_batches=[ev],
                           steps=4, lr=1e-3)
    f0 = jax.jit(lambda x: apply0(params, x))
    t0 = _wallclock(lambda: f0(ev[0]))
    rows = [(f"{name},original", t0 * 1e6,
             f"acc={base_acc:.3f};speedup=1.00")]
    for method in methods:
        for ratio in budgets:
            res = compress(host, budget_ratio=ratio, P=300, method=method,
                           importance=(ispec if importance == "measured"
                                       else "magnitude"),
                           base_perf=base_acc, params=params)
            if res is None:
                rows.append((f"{name},{method}-{int(ratio*100)}%", 0.0,
                             "infeasible"))
                continue
            ra, _ = host.replaced_apply(res.plan)
            ft = ImportanceSpec(loss_fn=xent_loss, perf_fn=accuracy_perf,
                                train_batches=[tr], eval_batches=[ev],
                                steps=ft_steps, lr=1e-3)
            tuned = _adam_finetune(ra, params, ft)
            ma, _ = host.merged_apply(res.plan, tuned)
            acc = accuracy_perf(ma, tuned, [ev])
            fm = jax.jit(lambda x: ma(tuned, x))
            tm = _wallclock(lambda: fm(ev[0]))
            rows.append((f"{name},{method}-{int(ratio*100)}%", tm * 1e6,
                         f"acc={acc:.3f};speedup={t0/tm:.2f};"
                         f"dp_pred={res.speedup:.2f};"
                         f"dp_s={res.dp_seconds:.2f}"))
    return rows


def fig1_kernel_growth():
    """Figure 1: merged-kernel growth erodes the latency win (conv chain),
    and the transformer rank-growth analogue (DESIGN §2.1)."""
    rows = []
    key = jax.random.PRNGKey(0)
    c, hw = 32, 24
    x = jax.random.normal(key, (16, hw, hw, c))
    ws = [jax.random.normal(jax.random.PRNGKey(i), (3, 3, c, c)) * 0.1
          for i in range(5)]
    oracle = AnalyticTPUOracle()
    from repro.core.latency import conv2d_cost, rank_ffn_cost
    for n in range(1, 6):
        wm, _, _ = merge.merge_conv_chain(ws[:n], [1] * n, [False] * n)
        k = wm.shape[0]

        @jax.jit
        def f(x, wm=wm):
            return jax.lax.conv_general_dilated(
                x, wm, (1, 1), "SAME",
                dimension_numbers=("NHWC", "HWIO", "NHWC"))
        t = _wallclock(lambda: f(x))
        tpu = oracle.segment_latency(conv2d_cost(hw, hw, c, c, k, batch=16))
        rows.append((f"fig1,conv_merge_n{n}_k{k}", t * 1e6,
                     f"kernel={k};tpu_model_us={tpu*1e6:.2f}"))
    d = 512
    for n in range(1, 6):
        r = min(n * 128, d)
        tpu = oracle.segment_latency(rank_ffn_cost(4096, d, r))
        rows.append((f"fig1,rank_merge_n{n}_r{r}", tpu * 1e6,
                     f"rank={r};eq1_analogue=true"))
    return rows


def table1_resnet34():
    net = zoo.tiny_resnet(num_classes=4, in_hw=16, width=8, blocks=(2, 2))
    return _compression_pareto("table1_resnet", net, (0.75, 0.55),
                               ("layermerge", "layeronly", "depth"))


def table23_mobilenetv2():
    net = zoo.tiny_mobilenet(num_classes=4, in_hw=16, width=8)
    return _compression_pareto("table23_mbv2", net, (0.75, 0.55),
                               ("layermerge", "layeronly", "depth"))


def table45_ddpm():
    """DDPM path: denoising objective on the skip-concat UNet (FID is not
    computable offline; eval = denoising MSE, lower is better)."""
    net = zoo.tiny_unet(in_hw=16, base=8)
    params = cnn.init_params(net, jax.random.PRNGKey(0))
    key = jax.random.PRNGKey(3)

    def diffusion_batch(key, n=64):
        k1, k2, k3 = jax.random.split(key, 3)
        img = jax.random.normal(k1, (n, 16, 16, 3))
        t = jax.random.uniform(k2, (n, 1, 1, 1))
        noise = jax.random.normal(k3, (n, 16, 16, 3))
        noisy = jnp.sqrt(1 - t) * img + jnp.sqrt(t) * noise
        inp = jnp.concatenate([noisy, jnp.broadcast_to(t, (n, 16, 16, 1))],
                              axis=-1)
        return inp, noise

    tr = diffusion_batch(jax.random.PRNGKey(1))
    ev = diffusion_batch(jax.random.PRNGKey(2))

    def loss_fn(apply_fn, p, batch):
        inp, noise = batch
        return jnp.mean((apply_fn(p, inp) - noise) ** 2)
    from repro.core import neg_loss_perf
    perf = neg_loss_perf(loss_fn)
    apply0 = lambda p, x: cnn.apply_replaced(net, p, x)
    spec = ImportanceSpec(loss_fn=loss_fn, perf_fn=perf, train_batches=[tr],
                          eval_batches=[ev], steps=100, lr=2e-3,
                          normalize_by_base=True)
    params = _adam_finetune(apply0, params, spec)
    base = perf(apply0, params, [ev])
    host = cnn_host.CNNHost(net, params, batch=16)
    ispec = dataclasses.replace(spec, steps=4)
    f0 = jax.jit(lambda x: apply0(params, x))
    t0 = _wallclock(lambda: f0(ev[0]))
    rows = [("table45_ddpm,original", t0 * 1e6, f"eval_mse={-base:.4f}")]
    for method in ("layermerge", "layeronly", "depth"):
        for ratio in (0.85, 0.7):
            res = compress(host, budget_ratio=ratio, P=300, method=method,
                           latency_oracle=WallClockOracle(warmup=1, iters=4),
                           importance=ispec, base_perf=base, params=params)
            if res is None:
                rows.append((f"table45_ddpm,{method}-{int(ratio*100)}%",
                             0.0, "infeasible"))
                continue
            ra, _ = host.replaced_apply(res.plan)
            tuned = _adam_finetune(ra, params,
                                   dataclasses.replace(spec, steps=60))
            ma, _ = host.merged_apply(res.plan, tuned)
            mse = -perf(ma, tuned, [ev])
            fm = jax.jit(lambda x: ma(tuned, x))
            tm = _wallclock(lambda: fm(ev[0]))
            rows.append((f"table45_ddpm,{method}-{int(ratio*100)}%",
                         tm * 1e6,
                         f"eval_mse={mse:.4f};speedup={t0/tm:.2f}"))
    return rows


def table6_ablation():
    """Joint (LayerMerge) vs sequential (Depth → LayerOnly) at matched
    latency — the paper's key ablation."""
    net = zoo.tiny_mobilenet(num_classes=4, in_hw=16, width=8)
    params = cnn.init_params(net, jax.random.PRNGKey(0))
    tr = _toy(jax.random.PRNGKey(1), 256, 16)
    ev = _toy(jax.random.PRNGKey(2), 256, 16)
    params, apply0 = _pretrain(net, params, (tr, ev))
    base = accuracy_perf(apply0, params, [ev])
    host = cnn_host.CNNHost(net, params, batch=32)
    ispec = ImportanceSpec(loss_fn=xent_loss, perf_fn=accuracy_perf,
                           train_batches=[tr], eval_batches=[ev], steps=4,
                           lr=1e-3)
    oracle = AnalyticTPUOracle()
    rows = []

    def finetune_acc(plan, base_params, steps=80):
        ra, _ = host.replaced_apply(plan)
        ft = ImportanceSpec(loss_fn=xent_loss, perf_fn=accuracy_perf,
                            train_batches=[tr], eval_batches=[ev],
                            steps=steps, lr=1e-3)
        tuned = _adam_finetune(ra, base_params, ft)
        return accuracy_perf(ra, tuned, [ev]), tuned

    # joint
    joint = compress(host, budget_ratio=0.55, P=300, method="layermerge",
                     latency_oracle=oracle, importance=ispec,
                     base_perf=base, params=params)
    acc_joint, _ = finetune_acc(joint.plan, params)
    rows.append(("table6,layermerge-55%", 0.0,
                 f"acc={acc_joint:.3f};speedup={joint.speedup:.2f}"))
    # sequential: depth at 75%, then layeronly to reach ~55% overall
    seq1 = compress(host, budget_ratio=0.75, P=300, method="depth",
                    latency_oracle=oracle, importance=ispec,
                    base_perf=base, params=params)
    acc1, tuned1 = finetune_acc(seq1.plan, params, steps=40)
    host2 = cnn_host.CNNHost(net, tuned1, batch=32)
    seq2 = compress(host2, budget_ratio=0.55 / 0.75, P=300,
                    method="layeronly", latency_oracle=oracle,
                    importance=ispec, base_perf=acc1, params=tuned1)
    if seq2 is not None:
        # compose: prune the layers LayerOnly dropped on top of seq1's plan
        from repro.core.plan import CompressionPlan, Segment
        kept2 = set(seq2.plan.C)
        segs = []
        for s in seq1.plan.segments:
            kept = tuple(l for l in s.kept if l in kept2)
            k = 1 + sum(net.spec(l).k - 1 for l in kept
                        if net.spec(l).kind == "conv")
            segs.append(Segment(i=s.i, j=s.j, k=k, kept=kept,
                                original=s.original and kept == s.kept))
        combo = CompressionPlan(num_layers=net.L, segments=tuple(segs),
                                method="depth->layeronly")
        acc2, _ = finetune_acc(combo, tuned1, steps=40)
        lat = sum(oracle.segment_latency(host.segment_cost(s))
                  for s in combo.segments)
        orig = sum(oracle.segment_latency(host.segment_cost(s))
                   for s in seq1.plan.segments) / (seq1.speedup /
                                                   seq1.speedup)
        from repro.core.compress import original_latency
        t_orig = original_latency(host, oracle)
        rows.append(("table6,depth75->layeronly", 0.0,
                     f"acc={acc2:.3f};speedup={t_orig/lat:.2f}"))
    return rows


def table78_cost():
    """Lookup-table construction cost + entry counts at FULL paper scale
    (analytic oracle: the measurement protocol without a 2080Ti)."""
    rows = []
    for name, net in (("resnet34", zoo.resnet34()),
                      ("mobilenetv2", zoo.mobilenetv2()),
                      ("ddpm_unet", zoo.ddpm_unet())):
        params = None
        host = cnn_host.CNNHost(net, {"layers": [{} for _ in net.specs],
                                      "skips": [], "head": {}}, batch=128)
        t0 = time.perf_counter()
        enum = host.enumerator("layermerge")
        n_lm = table_entry_count(enum)
        t_enum = time.perf_counter() - t0
        n_depth = table_entry_count(host.enumerator("depth"))
        rows.append((f"table78,{name}", t_enum * 1e6,
                     f"L={net.L};layermerge_entries={n_lm};"
                     f"depth_entries={n_depth};layeronly_entries={net.L}"))
    return rows


ALL = [fig1_kernel_growth, table1_resnet34, table23_mobilenetv2,
       table45_ddpm, table6_ablation, table78_cost]
