"""Roofline analysis from the dry-run artifacts (§Roofline deliverable).

Reads ``results/dryrun/*.json`` and derives, per (arch × shape) on the
single-pod mesh:

  compute term    = HLO_FLOPs_per_chip / peak_FLOP/s      (cost_analysis)
  memory term     = HLO_bytes_per_chip / HBM_bw           (cost_analysis)
  collective term = collective_bytes_per_chip / link_bw   (HLO parse)

(The dry-run compiles the post-SPMD per-chip program, so cost_analysis is
already per-chip — dividing a global count by chips, as in the assignment
formula, is the same number.)

Also: MODEL_FLOPS = 6·N_active·tokens (train) / 2·N_active·tokens
(prefill/decode), the useful-compute ratio MODEL_FLOPS / (chips·HLO_FLOPs),
the dominant term, and the roofline fraction
  RF = ideal_compute_time / max(term)  — the §Perf score.

CPU-backend caveats (recorded in EXPERIMENTS.md): XLA-CPU fuses less than
XLA-TPU, so HLO_bytes is an over-count (upper bound) and the memory term is
pessimistic; FLOP counts use XLA's mnk convention.  An analytic cross-check
(param + activation traffic) is emitted alongside.
"""
from __future__ import annotations

import glob
import json
import os

PEAK = 197e12
HBM = 819e9
LINK = 50e9
CHIPS = {"single": 256, "multi": 512}


def model_flops(rec) -> float:
    n = rec.get("active_params") or rec.get("params")
    s, b = rec["seq_len"], rec["global_batch"]
    if rec["mode"] == "train":
        return 6.0 * n * s * b
    if rec["mode"] == "prefill":
        return 2.0 * n * s * b
    return 2.0 * n * b          # decode: one token per sequence


def analytic_terms(rec, chips) -> dict:
    """TPU-analytic HBM-traffic model (the fusion-aware cross-check).

    XLA-CPU reports ~5-10× the HBM bytes a fused TPU program moves (every
    elementwise intermediate is counted).  This model charges, per chip:

    * weights: P_active·2 B, once per pass (fwd=1; train adds 2 bwd passes);
    * residual stream: ~8 reads+writes of (tokens·d_model) per layer-pass
      (norm/attn/ffn in+out, remat recompute counted in the ×3 passes);
    * decode: full KV cache (or recurrent state) read per emitted token;
    * logits: tokens·vocab·2 written once (+read in train for the xent).
    """
    n = rec.get("active_params") or rec.get("params")
    s, b = rec["seq_len"], rec["global_batch"]
    from repro.configs import get_config
    cfg = get_config(rec["arch"])
    passes = 3 if rec["mode"] == "train" else 1
    tokens = (b if rec["mode"] == "decode" else s * b) / chips
    # weight residency per chip depends on the sharding option: FSDP shards
    # over all axes (gathered at use — HBM reads the gathered copy), TP-only
    # leaves 1/TP of the weights resident and read per pass
    fsdp = rec.get("options", {}).get("fsdp", True)
    w_shards = min(chips, 256) if fsdp else 16
    wbytes = 2.0 * n * passes / w_shards
    act = 8.0 * cfg.num_layers * tokens * cfg.d_model * 2 * passes
    logits = tokens * cfg.vocab_size * 2 * (2 if rec["mode"] == "train" else 1)
    cache = 0.0
    if rec["mode"] == "decode":
        if cfg.family in ("ssm",):
            cache = cfg.num_layers * (b / chips) * cfg.d_model * \
                (cfg.d_model // cfg.num_heads) * 4
        else:
            window = min(cfg.local_window or s, s)
            kv_layers = sum(1 for k in cfg.layer_kinds()
                            if k.startswith("attn"))
            cache = kv_layers * (b / chips) * window * \
                cfg.num_kv_heads * cfg.head_dim * 2 * 2
    return {"bytes": wbytes + act + logits + cache,
            "memory_s": (wbytes + act + logits + cache) / HBM}


def _read(dirpath, mesh, tag):
    out = {}
    for path in sorted(glob.glob(os.path.join(dirpath, "*.json"))):
        rec = json.load(open(path))
        if rec.get("status") != "ok":
            continue
        parts = os.path.basename(path)[:-5].split("__")
        if parts[2] != mesh:
            continue
        this_tag = parts[3] if len(parts) > 3 else None
        if this_tag != tag:
            continue
        out[(parts[0], parts[1])] = rec
    return out


def depth_correct(rec, probes) -> dict:
    """Correct XLA's count-loop-body-once artifact via the depth probes.

    f(p) and f(2p) compiled *unrolled* at pattern depth p give
    ``body = f(2p) − f(p)`` and ``base = f(p) − body``; the true full-depth
    cost is ``base + (L/p)·body`` per metric.  Applied to flops, bytes and
    collective bytes.  Exact for uniform stacks; ≤ one-cycle error for the
    hybrid patterns (noted in EXPERIMENTS.md).
    """
    key = (rec["arch"], rec["shape"])
    p1 = probes[0].get(key)
    p2 = probes[1].get(key) if probes[1] else None
    if p1 is None:
        return rec
    if "num_layers" not in rec:
        import sys
        sys.path.insert(0, "src")
        from repro.configs import get_config
        rec = dict(rec)
        rec["num_layers"] = get_config(rec["arch"]).num_layers
    L = rec["num_layers"]
    p = p1["num_layers"]
    rec = dict(rec)
    cost = dict(rec["cost"])
    coll = json.loads(json.dumps(rec["collectives"]))
    if p2 is None:                      # probe == full depth (e.g. xlstm)
        rec["cost"], rec["collectives"] = p1["cost"], p1["collectives"]
        rec["depth_corrected"] = "exact-unrolled"
        return rec
    ratio = L / p

    def extrap(a, b):
        body = b - a
        return max(a - body, 0.0) + ratio * body
    for k in ("flops", "bytes accessed", "transcendentals"):
        if k in p1["cost"] and k in p2["cost"]:
            cost[k] = extrap(p1["cost"][k], p2["cost"][k])
    for op, v in coll.items():
        if isinstance(v, dict) and op in p1["collectives"]:
            v["bytes"] = extrap(p1["collectives"][op]["bytes"],
                                p2["collectives"][op]["bytes"])
    coll["total_bytes"] = sum(v["bytes"] for v in coll.values()
                              if isinstance(v, dict))
    rec["cost"], rec["collectives"] = cost, coll
    rec["depth_corrected"] = f"probe p={p} -> L={L}"
    return rec


def load(dirpath="results/dryrun", mesh="single", tag=None,
         correct: bool = True):
    recs = _read(dirpath, mesh, tag)
    if correct:
        # gather probes by depth order per cell; a tagged load uses
        # variant-matched probes (suffix "-<tag>"), baseline uses untagged
        p_all = {}
        import re as _re
        suffix = f"-{tag}" if tag else ""
        pat = _re.compile(rf"__probe\d+{_re.escape(suffix)}\.json$")
        for path in glob.glob(os.path.join(dirpath, f"*__{mesh}__probe*.json")):
            if not pat.search(path):
                continue
            rec = json.load(open(path))
            if rec.get("status") != "ok":
                continue
            key = (rec["arch"], rec["shape"])
            p_all.setdefault(key, []).append(rec)
        probes1, probes2 = {}, {}
        for key, lst in p_all.items():
            lst.sort(key=lambda r: r["num_layers"])
            probes1[key] = lst[0]
            if len(lst) > 1:
                probes2[key] = lst[1]
        rows = []
        for key, rec in recs.items():
            p2 = probes2.get(key)
            rows.append(analyse(
                depth_correct(rec, ({key: probes1[key]} if key in probes1
                                    else {}, {key: p2} if p2 else {})),
                mesh))
        return rows
    return [analyse(r, mesh) for r in recs.values()]


def analyse(rec, mesh="single") -> dict:
    chips = CHIPS[mesh]
    flops_dev = rec["cost"].get("flops", 0.0)
    bytes_dev = rec["cost"].get("bytes accessed", 0.0)
    coll_dev = rec["collectives"]["total_bytes"]
    compute_s = flops_dev / PEAK
    memory_s = bytes_dev / HBM
    coll_s = coll_dev / LINK
    mf = model_flops(rec)
    ideal_s = mf / (chips * PEAK)
    terms = {"compute": compute_s, "memory": memory_s,
             "collective": coll_s}
    dominant = max(terms, key=terms.get)
    denom = max(max(terms.values()), 1e-30)
    amem = analytic_terms(rec, chips)["memory_s"]
    terms_tpu = {"compute": compute_s, "memory": amem, "collective": coll_s}
    dom_tpu = max(terms_tpu, key=terms_tpu.get)
    return {
        "arch": rec["arch"], "shape": rec["shape"], "mesh": mesh,
        "mode": rec["mode"],
        "compute_s": compute_s, "memory_s": memory_s,
        "collective_s": coll_s, "dominant": dominant,
        "model_flops": mf,
        "useful_ratio": mf / max(chips * flops_dev, 1e-30),
        "roofline_fraction": ideal_s / denom,
        # TPU-analytic view: fusion-aware memory term (headline §Perf metric,
        # HLO-derived view kept alongside as the specified cross-check)
        "analytic_memory_s": amem,
        "dominant_tpu": dom_tpu,
        "roofline_fraction_tpu": ideal_s / max(max(terms_tpu.values()), 1e-30),
        "collectives": {k: v for k, v in rec["collectives"].items()
                        if isinstance(v, dict) and v["count"]},
    }


def markdown_table(rows) -> str:
    hdr = ("| arch | shape | compute s | memory s | collective s | dominant "
           "| useful HLO-FLOP ratio | roofline fraction |\n"
           "|---|---|---|---|---|---|---|---|")
    lines = [hdr]
    for r in sorted(rows, key=lambda r: (r["arch"], r["shape"])):
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['compute_s']:.3e} | "
            f"{r['memory_s']:.3e} | {r['collective_s']:.3e} | "
            f"{r['dominant']} | {r['useful_ratio']:.3f} | "
            f"{r['roofline_fraction']:.3f} |")
    return "\n".join(lines)


def csv_rows(rows):
    out = ["arch,shape,mesh,compute_s,hlo_memory_s,tpu_memory_s,"
           "collective_s,dominant_hlo,dominant_tpu,useful_ratio,"
           "rf_hlo,rf_tpu"]
    for r in sorted(rows, key=lambda r: (r["arch"], r["shape"])):
        out.append(",".join([
            r["arch"], r["shape"], r["mesh"], f"{r['compute_s']:.6e}",
            f"{r['memory_s']:.6e}", f"{r['analytic_memory_s']:.6e}",
            f"{r['collective_s']:.6e}",
            r["dominant"], r["dominant_tpu"], f"{r['useful_ratio']:.4f}",
            f"{r['roofline_fraction']:.4f}",
            f"{r['roofline_fraction_tpu']:.4f}"]))
    return "\n".join(out)


def main():
    rows = load()
    print(csv_rows(rows))
    worst = sorted(rows, key=lambda r: r["roofline_fraction"])[:5]
    print("\n# five worst roofline fractions (hillclimb candidates):")
    for r in worst:
        print(f"#   {r['arch']} × {r['shape']}: RF={r['roofline_fraction']:.3f}"
              f" dominant={r['dominant']}")


if __name__ == "__main__":
    main()
