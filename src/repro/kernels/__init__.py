"""Public kernel entry points — the ONE import surface for callers.

Models, the runtime executor, tests, and benchmarks import from
``repro.kernels`` directly (``from repro import kernels; kernels.
merged_conv_op(...)``) instead of deep-importing ``kernels.ops`` /
``kernels.ref`` module paths.  Each ``*_op`` dispatches to the Pallas
kernel on TPU and to the matching ``*_ref`` jnp oracle elsewhere; the
oracles are exported too — they are the semantic ground truth the
equivalence suites compare against.
"""
from . import ops, ref
from .ops import (channel_tile, flash_attention_op, force_backend,
                  merged_conv_op, merged_ffn_op, rglru_scan_op, rmsnorm_op)
from .ref import (apply_activation, flash_attention_ref, merged_conv_ref,
                  merged_ffn_ref, rglru_scan_ref, rmsnorm_ref)

__all__ = [
    "ops", "ref",
    "channel_tile", "flash_attention_op", "force_backend",
    "merged_conv_op", "merged_ffn_op", "rglru_scan_op", "rmsnorm_op",
    "apply_activation", "flash_attention_ref", "merged_conv_ref",
    "merged_ffn_ref", "rglru_scan_ref", "rmsnorm_ref",
]
