"""One-command compression + artifact export.

Runs the full pipeline (tables → DP → merge) on a named architecture and
publishes a portable merged-model artifact — no example-script surgery:

  PYTHONPATH=src python -m repro.compress --arch tiny_resnet \
      --budget-ratio 0.6 --out artifact.npz

  PYTHONPATH=src python -m repro.compress --arch smollm-135m \
      --budget-ratio 0.55 --out lm.npz
  PYTHONPATH=src python examples/serve_lm.py --artifact lm.npz

CNN archs come from :mod:`repro.models.zoo`; transformer archs resolve
through :func:`repro.configs.get_config` (reduced to the CPU-sized toy
variant unless ``--full``).  Parameters are seed-initialized — the CLI
demonstrates the plan→artifact path; a production run would restore
pre-trained params from a checkpoint before compressing.  The artifact
records the source (arch, seed, reduced) so consumers such as
``serve_lm --artifact`` can rebuild the matching original network for
side-by-side throughput numbers.
"""
from __future__ import annotations

import argparse
import json


CNN_ARCHS = {
    "tiny_resnet": lambda zoo: zoo.tiny_resnet(
        num_classes=4, in_hw=16, width=8, blocks=(2, 2)),
    "tiny_mobilenet": lambda zoo: zoo.tiny_mobilenet(
        num_classes=4, in_hw=16, width=8),
    "tiny_unet": lambda zoo: zoo.tiny_unet(in_hw=16, base=8),
    "resnet34": lambda zoo: zoo.resnet34(),
    "mobilenetv2": lambda zoo: zoo.mobilenetv2(),
    "ddpm_unet": lambda zoo: zoo.ddpm_unet(),
}


def build_host(arch: str, *, seed: int = 0, batch: int = 8, seq: int = 128,
               full: bool = False, max_span: int | None = None):
    """(host, source-dict) for a named CNN-zoo or transformer arch."""
    import jax

    key = jax.random.PRNGKey(seed)
    source = {"arch": arch, "seed": seed}
    if arch in CNN_ARCHS:
        from repro.models import cnn, cnn_host, zoo

        net = CNN_ARCHS[arch](zoo)
        params = cnn.init_params(net, key)
        host = cnn_host.CNNHost(net, params, batch=batch, max_span=max_span)
        source["family"] = "cnn"
        return host, source
    from repro.configs import get_config
    from repro.models import transformer as T
    from repro.models.transformer_host import CostEnv, TransformerHost

    cfg = get_config(arch)
    if not full:
        cfg = cfg.reduced()
    params, _ = T.init_model(cfg, key)
    host = TransformerHost(cfg, params,
                           env=CostEnv(batch=batch, seq=seq),
                           max_span=max_span)
    source.update(family="transformer", reduced=not full)
    return host, source


def main(argv=None):
    ap = argparse.ArgumentParser(
        prog="python -m repro.compress",
        description="LayerMerge compression → merged-model artifact")
    ap.add_argument("--arch", required=True,
                    help=f"CNN zoo ({', '.join(CNN_ARCHS)}) or a "
                         "transformer config id (e.g. smollm-135m)")
    ap.add_argument("--budget-ratio", type=float, default=0.6)
    ap.add_argument("--method", default="layermerge",
                    choices=("layermerge", "depth", "layeronly"))
    ap.add_argument("--oracle", default="analytic",
                    choices=("analytic", "wallclock"))
    ap.add_argument("--P", type=int, default=200,
                    help="latency discretization steps (Algorithm 1)")
    ap.add_argument("--quantize", default="none",
                    choices=("none", "int8", "w8a8"),
                    help="let the DP pick per-unit precision: widens the "
                         "tables with int8-weight (int8) or int8-weight+"
                         "activation (w8a8) candidates; chosen segments "
                         "lower to narrow-weight units (artifact v3)")
    ap.add_argument("--out", required=True, help="artifact path (.npz)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128,
                    help="sequence length for the transformer cost env")
    ap.add_argument("--max-span", type=int, default=None)
    ap.add_argument("--full", action="store_true",
                    help="transformer: full config, not .reduced()")
    ap.add_argument("--cache-dir", default=None,
                    help="lookup-table cache directory (optional)")
    ap.add_argument("--resume", action=argparse.BooleanOptionalAction,
                    default=True,
                    help="resume an interrupted table build from its "
                         "write-ahead journal in --cache-dir (default on; "
                         "--no-resume discards a stale journal)")
    ap.add_argument("--probe-timeout", type=float, default=None,
                    metavar="SECONDS",
                    help="per-probe wall-clock budget; over-budget probes "
                         "retry, then quarantine to the analytic estimate")
    ap.add_argument("--probe-retries", type=int, default=2,
                    help="attempts per failing probe before quarantine")
    ap.add_argument("--workers", type=int, default=0,
                    help="fan latency probes out across N subprocess "
                         "workers with lease-based reassignment (requires "
                         "--cache-dir; tables stay bit-identical)")
    ap.add_argument("--work-dir", default=None,
                    help="shared coordination directory for --workers "
                         "(default: under --cache-dir)")
    args = ap.parse_args(argv)

    from repro.core import ProbeConfig, WallClockOracle, compress
    from repro.core.dist_build import DistBuildError

    host, source = build_host(args.arch, seed=args.seed, batch=args.batch,
                              seq=args.seq, full=args.full,
                              max_span=args.max_span)
    oracle = WallClockOracle() if args.oracle == "wallclock" else None
    probe_config = ProbeConfig(timeout_s=args.probe_timeout,
                               retries=args.probe_retries)
    host_spec = {"factory": "repro.testing.hosts:cli_host",
                 "kwargs": {"arch": args.arch, "seed": args.seed,
                            "batch": args.batch, "seq": args.seq,
                            "full": args.full,
                            "max_span": args.max_span}}
    try:
        res = compress(host, budget_ratio=args.budget_ratio, P=args.P,
                       method=args.method, latency_oracle=oracle,
                       importance="magnitude", cache_dir=args.cache_dir,
                       probe_config=probe_config, resume=args.resume,
                       workers=args.workers, host_spec=host_spec,
                       work_dir=args.work_dir, quantize=args.quantize)
    except DistBuildError as e:
        print(f"[repro.compress] distributed build failed: {e}")
        raise SystemExit(3)
    if res is None:
        raise SystemExit(
            f"[repro.compress] infeasible: no plan fits "
            f"budget_ratio={args.budget_ratio} for {args.arch}")
    fp = res.save(args.out, extra_meta={"source": source})
    plan = res.plan
    summary = {
        "arch": args.arch,
        "method": args.method,
        "budget_ratio": args.budget_ratio,
        "layers": plan.num_layers,
        "kept_layers": len(plan.C),
        "segments": len(plan.segments),
        "predicted_speedup": round(res.speedup, 3),
        "quantize": args.quantize,
        "quantized_units": sum(1 for s in plan.segments
                               if s.quant != "none"),
        "flagged_probes": (len(res.tables.provenance)
                           if res.tables is not None else 0),
        "artifact": args.out,
        "fingerprint": fp[:16],
    }
    if res.dist_report is not None:
        rep = res.dist_report
        summary["dist"] = {"workers": rep.workers, "items": rep.items,
                           "reassigned": len(rep.reassigned),
                           "dead_workers": rep.dead_workers,
                           "cache_hit": rep.cache_hit}
    print(json.dumps(summary, indent=2))


if __name__ == "__main__":
    main()
