"""Batched, device-parallel probe engine for ``T[i,j,k]`` / ``I[i,j,k]``.

The paper's dominant offline cost is table construction: every latency
probe and every fine-tune probe is independent ("embarrassingly parallel",
§3.2), yet a naive builder walks all ``O(L² K₀)`` entries one at a time —
one XLA compile + one warmup/timing loop per latency entry and one scalar
Adam fine-tune per importance entry.  This module replaces that inner loop:

* **Latency bucketing** — a metadata-only pass enumerates all probes and
  buckets them by *shape signature* (``host.probe_signature(seg)``: for
  CNNs ``(h, w, cin, cout, K, stride, depthwise, …)``).  Latency depends on
  the signature only — never on the weight values — so one callable per
  bucket is compiled and timed and the result is attributed to every entry
  in the bucket, dropping compiles + timings from ``O(L² K₀)`` to
  ``O(#shape buckets)``.
* **Compile/timing overlap** — wall-clock bucket representatives are
  pre-compiled ahead of time on a single worker thread (a warm jit call;
  see :func:`_prepare_probe` for why not AOT ``lower().compile()``), so
  bucket ``b+1`` compiles while bucket ``b`` warms up; the timed loops
  run in a quiet window after the last compile retires.
* **Batched importance** — hosts that implement ``importance_batch`` hand
  the engine one shared ``apply_fn`` plus stacked candidate params (same
  pytree structure within a span bucket); the few-step Eq. 4 Adam
  fine-tune then runs **vmapped** over the probe axis (``pmap``-sharded
  across local devices when more than one is present).  Hosts without a
  batchable formulation fall back to the sequential per-probe path.

Crash safety (the table build is an hours-long, preemption-exposed job):

* **Write-ahead journal** — pass ``journal=`` (a
  :class:`repro.core.table_cache.BuildJournal`) and every completed
  bucket/probe is durably recorded before the build moves on; a killed
  build resumes from the journal bit-identically (the resume contract is
  documented in :mod:`repro.core.table_cache`).
* **Probe hardening** (:class:`ProbeConfig`) — each wall-clock probe gets
  a post-hoc wall-clock timeout, bounded retry with exponential backoff,
  and variance-based outlier re-timing (the oracle's group spread is the
  signal); a bucket that keeps failing is **quarantined** to the
  deterministic :class:`~repro.core.latency.AnalyticTPUOracle` estimate
  with provenance ``"quarantined"`` recorded in the tables (and from
  there the cache and the artifact spec) — one flaky probe can no longer
  kill an otherwise-complete build.
* **Fault points** — ``probe.prepare`` / ``probe.time`` /
  ``tables.bucket`` / ``tables.importance`` hooks from
  :mod:`repro.testing.faults` make every one of these paths
  deterministically testable.

``engine="sequential"`` preserves the original entry-at-a-time walk as the
certified reference; ``tests/test_probe_engine.py`` asserts the batched
path is *bit-identical* to it under the analytic oracle and within
tolerance under :class:`~repro.core.latency.WallClockOracle`.
"""
from __future__ import annotations

import dataclasses
import time
from concurrent.futures import ThreadPoolExecutor
from typing import Callable, Sequence

import jax

from repro.testing import faults

from .importance import (adam_finetune_batched, measure_importance,
                         perf_to_importance)
from .latency import AnalyticTPUOracle, LatencyOracle, WallClockOracle
from .plan import Segment

ENGINES = ("batched", "sequential")

# Provenance flags attached to every latency entry (see Tables.provenance;
# only non-"measured" flags are recorded — "measured" is the default).
PROBE_MEASURED = "measured"        # the configured oracle's own value
PROBE_RETIMED = "retimed"          # outlier spread triggered a re-timing
PROBE_QUARANTINED = "quarantined"  # persistent failure → analytic estimate


class ProbeTimeout(RuntimeError):
    """A probe exceeded its configured wall-clock budget."""


@dataclasses.dataclass(frozen=True)
class ProbeConfig:
    """Hardening policy for wall-clock latency probes.

    ``timeout_s`` is *post hoc*: a running XLA dispatch cannot be
    interrupted, so the budget is checked against the measured duration
    of the compile/warm/timing phases and an over-budget attempt counts
    as a failure (a straggler).  Failures retry up to ``retries`` times
    with exponential backoff (``backoff_s · 2^attempt``); a bucket still
    failing afterwards is quarantined to the deterministic analytic
    estimate (``fallback_oracle`` or a default
    :class:`~repro.core.latency.AnalyticTPUOracle`) with provenance
    ``"quarantined"`` — unless ``quarantine=False``, in which case the
    last error propagates.  ``outlier_rel_spread`` bounds the oracle's
    group-mean spread; a noisier measurement is re-timed once and tagged
    ``"retimed"``.
    """

    timeout_s: float | None = None
    retries: int = 2
    backoff_s: float = 0.05
    outlier_rel_spread: float | None = 1.0
    quarantine: bool = True
    fallback_oracle: LatencyOracle | None = None

    def fallback(self) -> LatencyOracle:
        return self.fallback_oracle or AnalyticTPUOracle()


@dataclasses.dataclass(frozen=True)
class ProbeCallable:
    """One batchable latency probe: a jittable ``fn`` plus example ``args``.

    Exposing the function and its arguments separately (instead of a
    zero-arg closure) is what lets the engine pre-compile the probe on a
    worker thread (and would equally support AOT
    ``jax.jit(fn).lower(*args).compile()`` — see :func:`_prepare_probe`
    for why the warm-call path is used instead).
    """

    fn: Callable
    args: tuple


@dataclasses.dataclass
class EngineStats:
    """Build accounting surfaced through :class:`repro.core.tables.Tables`."""

    engine: str = "batched"
    num_latency_probes: int = 0
    num_latency_buckets: int = 0
    num_compiles: int = 0            # XLA compiles issued (wall-clock path)
    num_timings: int = 0             # warmup/timing loops run
    num_importance_probes: int = 0
    num_importance_batches: int = 0  # vmapped fine-tune launches
    num_importance_sequential: int = 0
    cache_hit: bool = False
    num_journal_hits: int = 0        # buckets/probes resumed from the WAL
    num_probe_retries: int = 0       # failed attempts retried with backoff
    num_retimed: int = 0             # outlier-spread re-timings
    num_quarantined: int = 0         # buckets fallen back to the analytic

    def as_dict(self) -> dict:
        return dataclasses.asdict(self)


def _signature(host, seg: Segment):
    """Bucketing key for ``seg``; hosts without ``probe_signature`` get a
    unique key per entry (no batching win, but the engine still runs)."""
    sig_fn = getattr(host, "probe_signature", None)
    if sig_fn is None:
        return ("_unbucketed", seg.i, seg.j, seg.k, seg.kept)
    return sig_fn(seg)


def _prepare_probe(host, seg: Segment, params):
    """Build + pre-compile one bucket representative (worker-thread safe).

    Compilation goes through a warm jit call rather than AOT
    ``fn.lower(*args).compile()``: on current JAX the AOT executable does
    not share the jit dispatch cache (the first ``fn()`` call would
    compile a second time) and ``Compiled.__call__`` bypasses the C++
    dispatch fastpath, inflating sub-millisecond probes by ~2× relative
    to the sequential reference.  One warm call compiles the same
    executable once and leaves timing on the exact dispatch path the
    sequential engine uses.
    """
    probe_fn = getattr(host, "segment_probe", None)
    if probe_fn is None:
        call = host.segment_callable(seg, params)
    else:
        probe = probe_fn(seg, params)
        call = lambda: probe.fn(*probe.args)
    jax.block_until_ready(call())
    return call


def _prepare_guarded(host, seg: Segment, params):
    """One prepare attempt: ``(warmed callable, seconds it took)``.

    The fault point sits inside the timed window so an injected
    straggler delay is indistinguishable from a real slow compile.
    """
    t0 = time.perf_counter()
    faults.hit("probe.prepare")
    call = _prepare_probe(host, seg, params)
    return call, time.perf_counter() - t0


def _backoff(cfg: ProbeConfig, attempt: int, stats: EngineStats) -> None:
    stats.num_probe_retries += 1
    time.sleep(cfg.backoff_s * (2 ** (attempt - 1)))


def _timed_guarded(call, oracle: WallClockOracle, cfg: ProbeConfig,
                   stats: EngineStats, *, warmup: int = 0):
    """Guarded timing of a prepared callable: ``(value | None, flag)``.

    ``None`` means the bucket timed out / kept failing and must be
    quarantined by the caller (``cfg.quarantine=False`` raises instead).
    """
    last: Exception | None = None
    for attempt in range(cfg.retries + 1):
        if attempt:
            _backoff(cfg, attempt, stats)
        try:
            t0 = time.perf_counter()
            faults.hit("probe.time")       # inside the timed window: an
            # injected delay reads as a real straggler to the timeout
            val, spread = oracle.time_callable_stats(call, warmup=warmup)
            if cfg.timeout_s is not None and \
                    time.perf_counter() - t0 > cfg.timeout_s:
                raise ProbeTimeout(
                    f"timing exceeded the {cfg.timeout_s}s probe budget")
            if cfg.outlier_rel_spread is not None \
                    and spread > cfg.outlier_rel_spread:
                stats.num_retimed += 1
                val2, spread2 = oracle.time_callable_stats(call,
                                                           warmup=warmup)
                return (val2 if spread2 <= spread else val), PROBE_RETIMED
            return val, PROBE_MEASURED
        except Exception as e:           # FaultKill is BaseException: dies
            last = e
    if not cfg.quarantine:
        raise last
    stats.num_quarantined += 1
    return None, PROBE_QUARANTINED


def _sequential_wallclock(host, seg: Segment, params,
                          oracle: WallClockOracle, cfg: ProbeConfig,
                          stats: EngineStats):
    """Guarded prepare + time of ONE entry (the sequential reference path).

    ``_prepare_probe`` already issues one warm call, so timing warms
    ``oracle.warmup - 1`` more — the same total number of pre-timing
    calls as the pre-engine behavior.
    """
    last: Exception | None = None
    for attempt in range(cfg.retries + 1):
        if attempt:
            _backoff(cfg, attempt, stats)
        try:
            call, prep_s = _prepare_guarded(host, seg, params)
            if cfg.timeout_s is not None and prep_s > cfg.timeout_s:
                raise ProbeTimeout(
                    f"prepare exceeded the {cfg.timeout_s}s probe budget")
            val, flag = _timed_guarded(call, oracle, cfg, stats,
                                       warmup=max(0, oracle.warmup - 1))
            stats.num_compiles += 1
            stats.num_timings += 1
            return val, flag
        except Exception as e:
            last = e
    if not cfg.quarantine:
        raise last
    stats.num_quarantined += 1
    return None, PROBE_QUARANTINED


def probe_segment(host, seg: Segment, params, oracle: LatencyOracle, *,
                  probe_config: ProbeConfig | None = None,
                  stats: EngineStats | None = None):
    """Measure ONE segment — the distributed build's unit of work.

    Returns ``(value | None, provenance_flag)`` exactly as a journal
    record stores them: analytic oracles evaluate the segment cost
    directly; wall-clock oracles run the guarded sequential prepare+time
    path (retry/timeout/quarantine per ``probe_config``), where ``None``
    means quarantined — the journal replay re-derives the deterministic
    analytic estimate on the coordinator.
    """
    cfg = probe_config or ProbeConfig()
    stats = stats if stats is not None else EngineStats()
    if isinstance(oracle, WallClockOracle):
        return _sequential_wallclock(host, seg, params, oracle, cfg, stats)
    return oracle.segment_latency(host.segment_cost(seg)), PROBE_MEASURED


def measure_latencies(
    host,
    segs: Sequence[Segment],
    oracle: LatencyOracle,
    params=None,
    *,
    engine: str = "batched",
    stats: EngineStats | None = None,
    progress: Callable[[str], None] | None = None,
    journal=None,
    probe_config: ProbeConfig | None = None,
    provenance: list | None = None,
) -> list[float]:
    """``T`` value for every segment in ``segs`` (order preserved).

    ``batched``: one oracle evaluation per distinct shape signature —
    analytic costs are computed once per bucket; wall-clock callables are
    compiled once per bucket (the next bucket pre-compiling on a worker
    thread while the current one warms up) and timed once per bucket in a
    quiet window after the last compile.
    ``sequential``: the certified reference — one evaluation per entry.

    ``journal``: write-ahead journal (``get``/``put``) — completed
    buckets are durably recorded and replayed on resume.
    ``probe_config``: retry/timeout/quarantine policy (wall-clock only).
    ``provenance``: optional caller-owned list (``len(segs)``) filled
    with the per-entry provenance flag.
    """
    if engine not in ENGINES:
        raise ValueError(f"unknown engine {engine!r}; expected {ENGINES}")
    stats = stats if stats is not None else EngineStats(engine=engine)
    cfg = probe_config or ProbeConfig()
    stats.num_latency_probes += len(segs)
    wallclock = isinstance(oracle, WallClockOracle)

    def set_prov(n: int, flag: str):
        if provenance is not None:
            provenance[n] = flag

    def quarantine_value(seg: Segment) -> float:
        return cfg.fallback().segment_latency(host.segment_cost(seg))

    def journal_get(key: str):
        if journal is None:
            return None
        rec = journal.get(key)
        if rec is not None:
            stats.num_journal_hits += 1
        return rec

    def journal_put(key: str, val, flag: str):
        if journal is not None:
            journal.put(key, None if val is None else float(val), flag)

    if engine == "sequential":
        out = []
        for n, seg in enumerate(segs):
            key = f"lat:{seg.i}:{seg.j}:{seg.k}"
            rec = journal_get(key)
            if rec is not None:
                val, flag = rec
            elif wallclock:
                val, flag = _sequential_wallclock(host, seg, params, oracle,
                                                  cfg, stats)
                journal_put(key, val, flag)
                faults.hit("tables.bucket")
                if progress and (n % 10 == 9 or n == len(segs) - 1):
                    progress(f"latency probe {n + 1}/{len(segs)}")
            else:
                val, flag = oracle.segment_latency(
                    host.segment_cost(seg)), PROBE_MEASURED
                journal_put(key, val, flag)
                faults.hit("tables.bucket")
            if val is None:                       # journaled quarantine
                val = quarantine_value(seg)
            set_prov(n, flag)
            out.append(val)
        stats.num_latency_buckets += len(segs)
        return out

    order: list = []                       # first-appearance bucket order
    buckets: dict = {}                     # sig -> representative Segment
    sigs = []
    for seg in segs:
        sig = _signature(host, seg)
        sigs.append(sig)
        if sig not in buckets:
            buckets[sig] = seg
            order.append(sig)
    stats.num_latency_buckets += len(order)

    per_bucket: dict = {}                  # sig -> (value | None, flag)
    pending: list = []
    for sig in order:
        rec = journal_get(f"latb:{sig!r}")
        if rec is not None:
            per_bucket[sig] = rec
        else:
            pending.append(sig)

    def finish_bucket(sig, val, flag):
        per_bucket[sig] = (val, flag)
        journal_put(f"latb:{sig!r}", val, flag)
        faults.hit("tables.bucket")

    if not wallclock:
        for sig in pending:
            finish_bucket(sig, oracle.segment_latency(
                host.segment_cost(buckets[sig])), PROBE_MEASURED)
    elif pending:
        # Overlap compilation with warmup: a single worker thread lowers
        # and compiles bucket representatives while the main thread warms
        # the already-compiled ones.  The *timed* loops only start once
        # the last compile has retired — warmup calls tolerate the CPU
        # contention of a concurrent XLA compile, timed calls do not (a
        # compile running beside the timing loop inflates cheap buckets
        # by integer factors).  A failed prepare retries inline on the
        # main thread; persistent failure quarantines the bucket.
        warmed = []                        # (sig, call | None)
        with ThreadPoolExecutor(max_workers=1) as ex:
            futures = [(sig, ex.submit(_prepare_guarded, host, buckets[sig],
                                       params)) for sig in pending]
            for bi, (sig, fut) in enumerate(futures):
                call, last = None, None
                for attempt in range(cfg.retries + 1):
                    try:
                        if attempt == 0:
                            call, prep_s = fut.result()
                        else:
                            _backoff(cfg, attempt, stats)
                            call, prep_s = _prepare_guarded(
                                host, buckets[sig], params)
                        if cfg.timeout_s is not None \
                                and prep_s > cfg.timeout_s:
                            raise ProbeTimeout(
                                f"prepare exceeded the {cfg.timeout_s}s "
                                "probe budget")
                        break
                    except Exception as e:
                        call, last = None, e
                if call is None:
                    if not cfg.quarantine:
                        raise last
                    stats.num_quarantined += 1
                else:
                    stats.num_compiles += 1
                    for _ in range(oracle.warmup):
                        jax.block_until_ready(call())
                warmed.append((sig, call))
                if progress:
                    progress(f"compiled+warmed bucket {bi + 1}/"
                             f"{len(pending)} ({len(segs)} probes)")
        for sig, call in warmed:           # quiet window: compiles done
            if call is None:
                finish_bucket(sig, None, PROBE_QUARANTINED)
                continue
            val, flag = _timed_guarded(call, oracle, cfg, stats)
            stats.num_timings += 1
            finish_bucket(sig, val, flag)

    out = []
    for n, (seg, sig) in enumerate(zip(segs, sigs)):
        val, flag = per_bucket[sig]
        if val is None:                    # quarantined: analytic estimate
            val = quarantine_value(seg)
        set_prov(n, flag)
        out.append(val)
    return out


def layer_latencies(
    host,
    oracle: LatencyOracle,
    params=None,
    *,
    engine: str = "batched",
    stats: EngineStats | None = None,
    probe_config: ProbeConfig | None = None,
) -> list[float]:
    """Per-layer latency of the untouched network via one engine pass.

    Shared by ``original_latency`` and the layer-only knapsack so each
    layer is probed exactly once per call instead of once per caller.
    """
    segs = [Segment(i=l - 1, j=l, k=host.original_k(l), kept=(l,),
                    original=True)
            for l in range(1, len(host.descs()) + 1)]
    return measure_latencies(host, segs, oracle, params, engine=engine,
                             stats=stats, probe_config=probe_config)


# Single-device vmapped fine-tunes win only while probes are dispatch-
# bound: the shared all-kept graph pays real FLOPs for every Dirac
# stand-in that a scalar probe would simply skip, so once the per-step
# workload is compute-bound, batching buys nothing and costs the pruned
# layers' compute.  Above this many input elements per fine-tune step the
# engine prefers scalar probes unless local devices can shard the lanes.
DISPATCH_BOUND_ELEMS = 65536


def _batching_pays(spec) -> bool:
    if jax.local_device_count() > 1:
        return True                       # pmap shards lanes: parallel win
    try:
        first = spec.train_batches[0]
        elems = sum(getattr(leaf, "size", 0)
                    for leaf in jax.tree.leaves(first))
    except Exception:                     # unsized workload: assume tiny
        return True
    return elems <= DISPATCH_BOUND_ELEMS


def measure_importances(
    host,
    segs: Sequence[Segment],
    spec,
    base_perf: float,
    params=None,
    *,
    engine: str = "batched",
    stats: EngineStats | None = None,
    force_batching: bool | None = None,
    progress: Callable[[str], None] | None = None,
    journal=None,
) -> list[float]:
    """Eq. 4 importance for every (non-original) segment in ``segs``.

    ``batched``: segments are grouped by span ``(i, j]`` and handed to
    ``host.importance_batch`` — if the host can express the whole span
    bucket as one shared ``apply_fn`` over stacked candidate params, the
    few-step Adam fine-tune runs vmapped (and pmap-sharded across local
    devices) over the probe axis; the tuned candidates are then unstacked
    and scored through the (jitted) ``perf_fn`` path.  Buckets the host
    declines — and, unless ``force_batching`` overrides the
    :func:`_batching_pays` heuristic, compute-bound single-device
    workloads — fall back to the sequential per-probe path.

    With a ``journal``, each completed probe is durably recorded; on
    resume, fully-journaled span groups are replayed without re-tuning,
    while a *partially* journaled group reruns whole — the vmap width
    never changes across a resume, so replayed and recomputed lanes are
    both bit-identical to the uninterrupted build.
    """
    from .tables import one_segment_plan   # local import: tables imports us

    if engine not in ENGINES:
        raise ValueError(f"unknown engine {engine!r}; expected {ENGINES}")
    stats = stats if stats is not None else EngineStats(engine=engine)
    stats.num_importance_probes += len(segs)
    out: list[float | None] = [None] * len(segs)

    jkeys = [f"imp:{s.i}:{s.j}:{s.k}" for s in segs]
    done: set[int] = set()
    if journal is not None:
        for n, key in enumerate(jkeys):
            rec = journal.get(key)
            if rec is not None:
                out[n] = rec[0]
                done.add(n)
                stats.num_journal_hits += 1

    def journal_put(n: int):
        if journal is not None:
            journal.put(jkeys[n], float(out[n]))

    def sequential(indices):
        for n in indices:
            if n in done:
                continue
            seg = segs[n]
            apply_fn, p = host.replaced_apply(
                one_segment_plan(host, seg), params)
            out[n] = measure_importance(apply_fn, p, spec, base_perf)
            stats.num_importance_sequential += 1
            journal_put(n)
            faults.hit("tables.importance")
            if progress:
                progress(f"importance probe ({seg.i},{seg.j}] k={seg.k}")

    batch_fn = getattr(host, "importance_batch", None)
    use_batches = force_batching if force_batching is not None \
        else _batching_pays(spec)
    if engine == "sequential" or batch_fn is None or not use_batches:
        sequential(range(len(segs)))
        return out

    groups: dict[tuple[int, int], list[int]] = {}
    for n, seg in enumerate(segs):
        groups.setdefault((seg.i, seg.j), []).append(n)
    for span, indices in groups.items():
        if all(n in done for n in indices):
            continue                      # whole group replayed from journal
        if len(indices) < 2:
            # A vmap of one lane only adds overhead over the scalar probe
            # (and the Dirac stand-ins cost real FLOPs) — not worth it.
            sequential(indices)
            continue
        # NOTE: a partially-journaled group reruns EVERY lane (identical
        # stacked width ⇒ identical XLA program ⇒ bit-identical values);
        # the recomputed values overwrite equal journal records.
        batch = batch_fn([segs[n] for n in indices], params)
        if batch is None:
            done.difference_update(indices)
            sequential(indices)
            continue
        apply_fn, stacked, grad_mask = batch
        tuned = adam_finetune_batched(apply_fn, stacked, spec,
                                      grad_mask=grad_mask)
        stats.num_importance_batches += 1
        for lane, n in enumerate(indices):
            p_n = jax.tree.map(lambda x: x[lane], tuned)
            perf = spec.perf_fn(apply_fn, p_n, spec.eval_batches)
            out[n] = perf_to_importance(perf, base_perf, spec)
            journal_put(n)
        faults.hit("tables.importance")
        if progress:
            progress(f"importance batch ({span[0]},{span[1]}]: "
                     f"{len(indices)} lanes vmapped")
    return out
