"""Content-addressed on-disk cache for the ``T``/``I`` lookup tables.

Table construction is the pipeline's dominant offline cost, and its inputs
are fully content-addressable: the host (network structure + shapes +
parameter bytes + probe workload), the latency oracle configuration, the
table method, and the importance mode.  A build keyed by the digest of all
of those can therefore be reused verbatim — repeated ``compress()`` calls
at different budgets, benchmark reruns, and sweep restarts become
incremental instead of rebuilding ``O(L² K₀)`` entries from scratch.

Keys
----
``cache_key`` hashes together:

* the **host fingerprint** (``host.fingerprint()`` — structure, boundary
  shapes, probe workload, parameter digest, and for wall-clock builds the
  machine identity, since measured latencies do not transfer);
* the **oracle config** (class name + dataclass fields);
* the **method** and the **importance token** (``"magnitude"``, or
  ``ImportanceSpec.cache_token`` — measured-importance specs close over
  arbitrary callables/data, so they are only cacheable when the caller
  names the workload explicitly);
* a format version, so stale layouts miss instead of mis-parse.

Returns ``None`` (caching disabled) whenever any component is not
content-addressable.  Entries publish atomically via the checkpoint
package's tmp-then-rename contract, so a crashed build never leaves a
half-written table behind.

Failure semantics (the crash-safety contract)
---------------------------------------------
* **Write-ahead journal** — while a build runs with a ``cache_dir`` and an
  addressable key, every completed probe bucket appends one JSON record
  to ``tables_<key>.journal`` (:class:`BuildJournal`; fsync'd line
  appends via :func:`repro.checkpoint.ckpt.append_journal_line`).
  Records: ``{"k": <key>, "v": <value>, "p": <provenance>}`` where the
  key namespaces are ``latb:<shape-signature>`` (batched latency bucket),
  ``lat:<i>:<j>:<k>`` (sequential latency entry), and ``imp:<i>:<j>:<k>``
  (importance probe).  A killed build resumes from the journal: journaled
  buckets are attributed without re-probing, so the resumed tables are
  **bit-identical** to an uninterrupted build (measured buckets replay
  their recorded floats exactly — JSON round-trips IEEE doubles via
  shortest-repr; quarantined buckets re-derive the deterministic analytic
  estimate).  The journal is deleted only after the tables publish.
* **Torn appends** — a crash mid-append leaves a record with no
  terminating newline; the journal reader truncates that torn tail away
  before parsing (and before any further append), so half a record is
  never parsed and never concatenated onto.
* **Quarantine on load** — a torn/corrupt/unparsable cache file is
  renamed to ``<file>.corrupt`` and reported as a miss, so one bad file
  can neither poison the caller nor wedge every subsequent build; the
  rebuild re-publishes under the original name.  A stale format version
  is a plain miss (the file is valid, just old).
* **At-most-once publish** — cache publishes and journal appends are
  gated on :func:`repro.launch.distributed.is_main`: in a multi-process
  run only process 0 writes here (workers report results through their
  own shards in :mod:`repro.core.dist_build`), so concurrent processes
  can never interleave writes to one cache entry or journal.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import os

import jax
import numpy as np

from repro.testing import faults

FORMAT_VERSION = 2


def pytree_digest(tree) -> str:
    """sha256 over every leaf's path, dtype, shape, and raw bytes."""
    h = hashlib.sha256()
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    for path, leaf in flat:
        arr = np.asarray(jax.device_get(leaf))
        h.update(jax.tree_util.keystr(path).encode())
        h.update(str(arr.dtype).encode())
        h.update(str(arr.shape).encode())
        h.update(np.ascontiguousarray(arr).tobytes())
    return h.hexdigest()


def machine_token() -> str:
    """Identity of the timing host — wall-clock tables do not transfer."""
    import platform

    dev = jax.devices()[0]
    return "|".join((platform.machine(), jax.default_backend(),
                     str(getattr(dev, "device_kind", "?"))))


def oracle_token(oracle) -> str:
    cfg = dataclasses.asdict(oracle) if dataclasses.is_dataclass(oracle) \
        else {}
    return json.dumps({"cls": type(oracle).__name__, "cfg": cfg},
                      sort_keys=True)


def importance_token(importance) -> str | None:
    """Stable name of the importance workload, or None (not cacheable).

    For a measured :class:`~repro.core.importance.ImportanceSpec`, the
    user's ``cache_token`` only needs to name the non-addressable parts
    (loss/perf closures and their data); the hashable fine-tune
    hyperparameters are folded in here so changing ``steps``/``lr``/
    ``normalize_by_base`` under the same token misses instead of serving
    stale importances."""
    if isinstance(importance, str):
        return importance
    token = getattr(importance, "cache_token", None)
    if token is None:
        return None
    return "|".join((token, f"steps={importance.steps}",
                     f"lr={importance.lr!r}",
                     f"norm={importance.normalize_by_base}"))


def cache_key(host, oracle, method: str, importance, *,
              prune: bool = True, base_perf: float | None = None,
              engine: str = "batched") -> str | None:
    """Digest of every table-build input, or None when not addressable.

    ``engine`` is deliberately EXCLUDED: batched and sequential builds are
    certified to agree (tests/test_probe_engine.py), so either may serve a
    hit for the other.  ``prune`` and ``base_perf`` ARE included — both
    change the stored table contents.
    """
    fp_fn = getattr(host, "fingerprint", None)
    imp = importance_token(importance)
    if fp_fn is None or imp is None:
        return None
    h = hashlib.sha256()
    h.update(f"v{FORMAT_VERSION}".encode())
    h.update(fp_fn().encode())
    h.update(oracle_token(oracle).encode())
    h.update(method.encode())
    h.update(imp.encode())
    h.update(repr((bool(prune), base_perf)).encode())
    return h.hexdigest()


def _key_sort(k) -> tuple[int, str]:
    """Deterministic sort key over mixed int / ``(k, mode)`` option keys.

    The compress pipeline only ever caches fp tables (precision siblings
    are derived after the cache publish — :mod:`repro.core.tables`), but
    direct ``save``/``load`` callers may hold widened tables; both key
    shapes round-trip (ints as-is, tuples as 2-element JSON lists)."""
    if isinstance(k, tuple):
        return int(k[0]), str(k[1])
    return int(k), ""


def _path(cache_dir: str, key: str) -> str:
    return os.path.join(cache_dir, f"tables_{key}.json")


def quarantine(path: str) -> str | None:
    """Move a corrupt file out of the read path (``<path>.corrupt``).

    Numbered suffixes avoid clobbering earlier evidence; returns the
    destination, or ``None`` when the file vanished / can't be moved
    (in which case the caller just treats it as a miss).
    """
    base = path + ".corrupt"
    dst, n = base, 0
    while os.path.exists(dst):
        n += 1
        dst = f"{base}.{n}"
    try:
        os.replace(path, dst)
    except OSError:
        return None
    return dst


def save(cache_dir: str, key: str, tables) -> str:
    """Atomically publish a built :class:`~repro.core.tables.Tables`.

    At-most-once publish: in a multi-process run only the main process
    (:func:`repro.launch.distributed.is_main`) writes — a worker that
    reaches this call is a no-op, so a job of any size publishes each
    cache entry exactly once.
    """
    from repro.checkpoint.ckpt import atomic_write_text
    from repro.launch.distributed import is_main

    path = _path(cache_dir, key)
    if not is_main():
        return path
    payload = {
        "format": FORMAT_VERSION,
        "build_seconds_latency": tables.build_seconds_latency,
        "build_seconds_importance": tables.build_seconds_importance,
        "num_pruned": tables.num_pruned,
        "stats": tables.stats.as_dict() if tables.stats else None,
        "provenance": [{"i": i, "j": j, "k": k, "flag": flag}
                       for (i, j, k), flag
                       in sorted(tables.provenance.items())],
        "spans": [
            {"i": i, "j": j,
             "opts": [{"k": list(k) if isinstance(k, tuple) else k,
                       "imp": imp, "lat": lat, "kept": list(kept)}
                      for k, (imp, lat, kept)
                      in sorted(row.items(), key=lambda kv: _key_sort(kv[0]))]}
            for (i, j), row in sorted(tables.entries.items())
        ],
    }
    faults.hit("table_cache.publish")
    return atomic_write_text(path, json.dumps(payload))


def load(cache_dir: str, key: str):
    """Cached :class:`~repro.core.tables.Tables`, or None on a miss.

    A torn or corrupt file is quarantined to ``<file>.corrupt`` and
    reported as a miss — it can neither poison the caller nor keep
    failing every future build from the same key.
    """
    from .probe_engine import EngineStats
    from .tables import Tables

    path = _path(cache_dir, key)
    if not os.path.exists(path):
        return None
    try:
        with open(path) as f:
            payload = json.load(f)
        if payload.get("format") != FORMAT_VERSION:
            return None                       # valid but stale: plain miss
        entries = {
            (sp["i"], sp["j"]): {
                (tuple(o["k"]) if isinstance(o["k"], list) else o["k"]):
                    (o["imp"], o["lat"], tuple(o["kept"]))
                for o in sp["opts"]}
            for sp in payload["spans"]
        }
        provenance = {(p["i"], p["j"], p["k"]): p["flag"]
                      for p in payload.get("provenance", [])}
        stats = EngineStats(**payload["stats"]) if payload.get("stats") \
            else EngineStats()
    except (OSError, json.JSONDecodeError, KeyError, TypeError, ValueError):
        quarantine(path)                      # torn/corrupt entry: miss
        return None
    stats.cache_hit = True
    return Tables(entries=entries,
                  build_seconds_latency=payload["build_seconds_latency"],
                  build_seconds_importance=payload[
                      "build_seconds_importance"],
                  num_pruned=payload["num_pruned"],
                  stats=stats, provenance=provenance)


# ---------------------------------------------------------------------------
# Write-ahead journal for resumable builds
# ---------------------------------------------------------------------------

def journal_path(cache_dir: str, key: str) -> str:
    return os.path.join(cache_dir, f"tables_{key}.journal")


def discard_journal(cache_dir: str, key: str) -> None:
    """Remove a journal that is no longer needed (tables published, or a
    crash landed between publish and cleanup)."""
    try:
        os.remove(journal_path(cache_dir, key))
    except OSError:
        pass


class BuildJournal:
    """Append-only record of completed probe buckets for ONE build key.

    ``get(key)`` returns the journaled ``(value, provenance)`` for a
    bucket (``None`` on a miss); ``put`` durably appends one record
    (fsync'd — once it returns, the bucket survives SIGKILL).  Records
    whose line was torn by a crash are dropped (and truncated away) on
    open.  The journal's resume contract lives in the module docstring.
    """

    def __init__(self, cache_dir: str, key: str):
        from repro.checkpoint.ckpt import read_journal_lines

        self.path = journal_path(cache_dir, key)
        self._records: dict[str, tuple] = {}
        for line in read_journal_lines(self.path):
            try:
                rec = json.loads(line)
                self._records[rec["k"]] = (rec["v"], rec.get("p", "measured"))
            except (json.JSONDecodeError, KeyError, TypeError):
                continue                      # unparsable record: skip

    def __len__(self) -> int:
        return len(self._records)

    def get(self, key: str) -> tuple | None:
        """``(value, provenance)`` for a completed bucket, else ``None``."""
        return self._records.get(key)

    def put(self, key: str, value, provenance: str = "measured") -> None:
        from repro.checkpoint.ckpt import append_journal_line
        from repro.launch.distributed import is_main

        if is_main():                     # at-most-once durable journal:
            append_journal_line(self.path, json.dumps(
                {"k": key, "v": value, "p": provenance}))
        self._records[key] = (value, provenance)  # non-main: memory only

    def put_many(self, records) -> int:
        """Durably append many ``(key, value, provenance)`` records in
        ONE fsync — the distributed merge path
        (:mod:`repro.core.dist_build`) lands a whole build's worth of
        worker results here.  Already-journaled keys are skipped;
        returns the number appended.  Same at-most-once gate as
        :meth:`put`."""
        from repro.launch.distributed import is_main

        fresh = [(k, v, p) for k, v, p in records if k not in self._records]
        if fresh and is_main():
            os.makedirs(os.path.dirname(self.path) or ".", exist_ok=True)
            data = b"".join(
                (json.dumps({"k": k, "v": v, "p": p}) + "\n").encode()
                for k, v, p in fresh)
            with open(self.path, "ab") as f:
                f.write(data)
                f.flush()
                os.fsync(f.fileno())
        for k, v, p in fresh:
            self._records[k] = (v, p)
        return len(fresh)

    def discard(self) -> None:
        try:
            os.remove(self.path)
        except OSError:
            pass
