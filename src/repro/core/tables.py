"""Lookup-table construction — ``T[i,j,k]`` and ``I[i,j,k]`` (paper §3.2).

The tables are built against a *host* — an adapter exposing the network to
the generic machinery.  Hosts implement:

* ``descs()``              → list[LayerDesc]
* ``enumerator(method)``   → SegmentEnumerator (span rules baked in)
* ``segment_cost(seg)``    → CostBreakdown (analytic latency oracle input)
* ``segment_callable(seg, params)`` → zero-arg jitted fn (wall-clock oracle)
* ``replaced_apply(plan)`` → (apply_fn, params) of the pruned-unmerged net
* ``original_k(l)``        → k-coordinate of the untouched layer l

and optionally the batched-probe protocol consumed by
:mod:`repro.core.probe_engine`:

* ``probe_signature(seg)`` → hashable shape signature (latency bucketing)
* ``segment_probe(seg, params)`` → ProbeCallable (AOT pre-lowering)
* ``importance_batch(segs, params)`` → (apply_fn, stacked_params, grad_mask)
* ``fingerprint()``        → content digest (on-disk table cache)

Construction cost is ``O(L² K₀)`` entries (paper's bound); each entry is
independent — embarrassingly parallel in the paper.  With
``engine="batched"`` (default) the probe engine exploits that: latency
probes collapse to one compile + one timing per distinct shape signature,
and importance probes run as vmapped (device-sharded) fine-tune batches.
``engine="sequential"`` keeps the certified one-entry-at-a-time reference
walk.  ``cache_dir`` adds a content-addressed on-disk cache
(:mod:`repro.core.table_cache`) so repeated builds are incremental.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Mapping

from . import probe_engine, table_cache
from .dp import TableFn
from .importance import ImportanceSpec, measure_importance, magnitude_importance
from .latency import AnalyticTPUOracle, LatencyOracle, WallClockOracle
from .plan import CompressionPlan, Segment, identity_plan
from .segments import pareto_prune_options


@dataclasses.dataclass
class Tables:
    """Materialized (i, j) → {k: (I, T, kept)} with build metadata."""

    entries: dict[tuple[int, int], dict[int, tuple[float, float, tuple[int, ...]]]]
    build_seconds_latency: float = 0.0
    build_seconds_importance: float = 0.0
    num_pruned: int = 0              # options dropped by Pareto dominance
    stats: probe_engine.EngineStats | None = None   # probe-engine accounting
    # (i, j, k) -> probe provenance for every entry whose latency did NOT
    # come straight from the configured oracle ("retimed"/"quarantined" —
    # see repro.core.probe_engine).  Sparse: "measured" is implied.
    provenance: dict[tuple[int, int, int], str] = \
        dataclasses.field(default_factory=dict)

    @property
    def num_entries(self) -> int:
        return sum(len(v) for v in self.entries.values())

    def fn(self) -> TableFn:
        return lambda i, j: self.entries.get((i, j), {})


def pareto_prune(
    entries: dict[tuple[int, int], dict[int, tuple[float, float, tuple[int, ...]]]],
) -> tuple[dict, int]:
    """Apply per-span Pareto-dominance pruning; returns (pruned, #dropped).

    Optimum-preserving for the DP (see
    :func:`repro.core.segments.pareto_prune_options`), so it runs before the
    solver ever sees the tables.
    """
    out: dict = {}
    dropped = 0
    for span, opts in entries.items():
        row = pareto_prune_options(opts)
        dropped += len(opts) - len(row)
        out[span] = row
    return out, dropped


# Relative importance penalty a precision sibling carries: strictly below
# its fp twin, so the DP prefers fp whenever the budget is slack and only
# trades precision when latency binds (the pair is mutually non-dominated:
# the sibling has strictly lower latency, marginally lower importance).
QUANT_IMPORTANCE_PENALTY = 1e-4


def quant_sibling_entries(host, entries, quantize: str,
                          ) -> tuple[dict, int]:
    """Widen per-span candidate rows with ``(k, mode)`` precision siblings.

    Each fp entry whose segment the host can quantize (``host.
    segment_cost(seg, quant=mode)`` returns a cost; ``None`` marks
    barrier/ineligible segments) gains one sibling keyed ``(k, mode)``:

    * ``T_q = T_fp × (analytic quantized / analytic fp latency)`` — the
      narrow-byte ratio the v5e roofline predicts, applied
      multiplicatively so wall-clock-measured fp entries keep their
      measurement and only the *relative* precision effect is modeled;
    * ``I_q = I_fp − |I_fp|·penalty − ε`` (strictly below the fp twin).

    Siblings are derived, not probed: the probe manifest, the build
    journal, and the on-disk cache all stay fp-only, so resume/dist
    builds remain bit-identical and fp-only runs never see widened keys.
    """
    if not quantize or quantize == "none":
        return entries, 0
    from repro.kernels.quant import MODES
    if quantize not in MODES:
        raise ValueError(f"unknown quantization mode {quantize!r}")
    ora = AnalyticTPUOracle()
    added = 0
    out: dict = {}
    for (i, j), row in entries.items():
        new_row = dict(row)
        for key, (imp, lat, kept) in row.items():
            if isinstance(key, tuple):
                continue                      # already a sibling
            seg = Segment(i=i, j=j, k=key, kept=kept)
            cost_q = host.segment_cost(seg, quant=quantize)
            if cost_q is None:
                continue
            lat_f = ora.segment_latency(host.segment_cost(seg))
            lat_q = ora.segment_latency(cost_q)
            if not lat_q < lat_f:
                continue                      # no predicted win → no sibling
            imp_q = imp - abs(imp) * QUANT_IMPORTANCE_PENALTY - 1e-12
            new_row[(key, quantize)] = (imp_q, lat * (lat_q / lat_f), kept)
            added += 1
        out[(i, j)] = new_row
    return out, added


def with_quant_siblings(tables: Tables, host, quantize: str | None) -> Tables:
    """Return ``tables`` widened with precision siblings (no-op for fp)."""
    if not quantize or quantize == "none":
        return tables
    entries, _added = quant_sibling_entries(host, tables.entries, quantize)
    return dataclasses.replace(tables, entries=entries)


def build_tables(
    host,
    *,
    method: str = "layermerge",
    latency_oracle: LatencyOracle | None = None,
    importance: ImportanceSpec | str = "magnitude",
    base_perf: float | None = None,
    params=None,
    progress: Callable[[str], None] | None = None,
    prune: bool = True,
    engine: str = "batched",
    cache_dir: str | None = None,
    probe_config: probe_engine.ProbeConfig | None = None,
    resume: bool = True,
    quantize: str | None = None,
) -> Tables:
    """Construct both lookup tables for ``host`` (Algorithm 2, lines 1-8).

    A metadata-only pass enumerates every ``(i, j, k)`` probe first; the
    probe engine then fills the latency column (bucketed by shape
    signature under ``engine="batched"``, entry-at-a-time under
    ``"sequential"``) and the importance column (vmapped span batches
    where the host supports them).  With ``prune`` (default), options
    Pareto-dominated within their span are dropped before the tables
    reach the DP — provably optimum-preserving.  With ``cache_dir``, a
    content-addressed hit skips the build entirely.

    Crash safety: when the build is cacheable, every completed probe
    bucket is journaled to ``cache_dir`` *before* the build moves on, and
    (with ``resume``, the default) a killed build replays the journal and
    produces tables **bit-identical** to an uninterrupted run (contract:
    :mod:`repro.core.table_cache`).  ``probe_config`` sets the wall-clock
    hardening policy — per-probe timeout, bounded retry with backoff,
    outlier re-timing, and quarantine-to-analytic for persistently
    failing buckets; non-default provenance lands in
    ``Tables.provenance`` and survives the cache and artifact round-trip.
    ``resume=False`` discards any stale journal and starts clean.

    ``quantize`` (``'int8'``/``'w8a8'``) widens each span's candidate row
    with derived ``(k, mode)`` precision siblings after the fp build — see
    :func:`quant_sibling_entries`; ``None``/``'none'`` leaves the tables
    (and therefore the DP's plans) bit-identical to an fp-only build.
    """
    oracle = latency_oracle or AnalyticTPUOracle()

    key = None
    journal = None
    if cache_dir is not None:
        key = table_cache.cache_key(host, oracle, method, importance,
                                    prune=prune, base_perf=base_perf,
                                    engine=engine)
        if key is not None:
            cached = table_cache.load(cache_dir, key)
            if cached is not None:
                # A journal can outlive a publish only when the build
                # crashed in the publish→cleanup window; it is fully
                # subsumed by the published tables.
                table_cache.discard_journal(cache_dir, key)
                if progress:
                    progress(f"tables: cache hit ({cached.num_entries} "
                             "entries)")
                return with_quant_siblings(cached, host, quantize)
            if not resume:
                table_cache.discard_journal(cache_dir, key)
            journal = table_cache.BuildJournal(cache_dir, key)
            if progress and len(journal):
                progress(f"tables: resuming from journal "
                         f"({len(journal)} completed probes)")

    enum = host.enumerator(method)
    total_value = sum(d.value for d in enum.descs)
    stats = probe_engine.EngineStats(engine=engine)

    # Pass 1 — metadata only: enumerate every (i, j, k) probe.
    probes = enumerate_probes(host, method, enum=enum)

    # Pass 2 — latency column through the probe engine.
    t0 = time.perf_counter()
    prov_flags: list[str] = [probe_engine.PROBE_MEASURED] * len(probes)
    lats = probe_engine.measure_latencies(
        host, [p[5] for p in probes], oracle, params, engine=engine,
        stats=stats, progress=progress, journal=journal,
        probe_config=probe_config, provenance=prov_flags)
    t_lat = time.perf_counter() - t0

    # Pass 3 — importance column (analytic entries inline, measured
    # entries through the engine's batched fine-tune).
    t0 = time.perf_counter()
    imps: list[float | None] = [None] * len(probes)
    measured: list[int] = []
    for n, (i, j, k, val, kept, seg) in enumerate(probes):
        if seg.original:
            imps[n] = 1.0                  # exp(0): untouched layer
        elif importance == "magnitude":
            imps[n] = magnitude_importance(val, max(total_value, 1e-9),
                                           len(seg.pruned))
        else:
            measured.append(n)
    if measured:
        vals = probe_engine.measure_importances(
            host, [probes[n][5] for n in measured], importance,
            base_perf or 0.0, params, engine=engine, stats=stats,
            progress=progress, journal=journal)
        for n, v in zip(measured, vals):
            imps[n] = v
    t_imp = time.perf_counter() - t0

    entries: dict = {}
    for (i, j, k, val, kept, seg), lat, imp in zip(probes, lats, imps):
        entries.setdefault((i, j), {})[k] = (imp, lat, kept)
    if progress:
        for (i, j), row in entries.items():
            progress(f"table span ({i},{j}]: {len(row)} entries")

    dropped = 0
    if prune:
        entries, dropped = pareto_prune(entries)

    # Provenance survives pruning only for entries the DP can still see.
    provenance = {
        (i, j, k): flag
        for (i, j, k, _val, _kept, _seg), flag in zip(probes, prov_flags)
        if flag != probe_engine.PROBE_MEASURED
        and k in entries.get((i, j), {})
    }

    tables = Tables(entries=entries, build_seconds_latency=t_lat,
                    build_seconds_importance=t_imp, num_pruned=dropped,
                    stats=stats, provenance=provenance)
    if key is not None:
        table_cache.save(cache_dir, key, tables)
        # Only after a durable publish is the journal redundant.
        table_cache.discard_journal(cache_dir, key)
    # Precision siblings are injected after the (fp-only) cache publish:
    # the cache, the journal, and the probe manifest never see widened
    # keys, so fp and quantized builds share one cached table.
    return with_quant_siblings(tables, host, quantize)


def enumerate_probes(
    host, method: str = "layermerge", enum=None,
) -> list[tuple[int, int, int, float, tuple[int, ...], Segment]]:
    """Metadata-only enumeration of every ``(i, j, k)`` probe.

    THE probe list: the single-process build above and the distributed
    work-item manifest (:mod:`repro.core.dist_build`) both derive from
    this function, which is what makes a worker's bucket list provably
    the coordinator's.  Each element is ``(i, j, k, value, kept, Segment)``.
    """
    enum = enum or host.enumerator(method)
    probes: list[tuple[int, int, int, float, tuple[int, ...], Segment]] = []
    for i, j, opts in enum.all_spans():
        for k, (val, kept) in opts.items():
            seg = Segment(i=i, j=j, k=k, kept=kept,
                          original=(j - i == 1 and k == host.original_k(j)
                                    and set(kept) == set(seg_layers(i, j))))
            probes.append((i, j, k, val, kept, seg))
    return probes


def seg_layers(i: int, j: int) -> tuple[int, ...]:
    return tuple(range(i + 1, j + 1))


def one_segment_plan(host, seg: Segment) -> CompressionPlan:
    """Ã_ij / C̃_ijk of Eq. 4: everything original except segment (i, j]."""
    descs = host.descs()
    L = len(descs)
    segs = []
    for l in range(1, seg.i + 1):
        segs.append(Segment(i=l - 1, j=l, k=host.original_k(l), kept=(l,),
                            original=True))
    segs.append(seg)
    for l in range(seg.j + 1, L + 1):
        segs.append(Segment(i=l - 1, j=l, k=host.original_k(l), kept=(l,),
                            original=True))
    return CompressionPlan(num_layers=L, segments=tuple(segs),
                           method="probe")
