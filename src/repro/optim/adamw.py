"""AdamW + schedules + clipping — pure-pytree implementation (no optax).

State layout is dry-run-faithful: fp32 first/second moments regardless of
param dtype (the realistic HBM picture for bf16 training), parameters
updated in fp32 then cast back.  ``grad_compress`` hooks int8 gradient
all-reduce with error feedback (optim/compress.py).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1


def cosine_lr(cfg: AdamWConfig, step):
    step = jnp.asarray(step, jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip((step - cfg.warmup_steps)
                    / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
                    0.0, 1.0)
    cos = 0.5 * (1.0 + jnp.cos(jnp.pi * prog))
    return cfg.lr * warm * (cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * cos)


def init_opt_state(params):
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {"mu": jax.tree.map(zeros, params),
            "nu": jax.tree.map(zeros, params),
            "step": jnp.zeros((), jnp.int32)}


def opt_state_axes(param_axes):
    """Optimizer-state logical axes mirror the parameter axes (so moments
    shard identically — the ZeRO picture)."""
    return {"mu": param_axes, "nu": param_axes, "step": ()}


def global_norm(tree):
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def clip_by_global_norm(grads, max_norm):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-12))
    return jax.tree.map(lambda g: (g.astype(jnp.float32) * scale), grads), norm


def adamw_update(cfg: AdamWConfig, grads, state, params):
    """One AdamW step.  Returns (new_params, new_state, metrics)."""
    grads32, gnorm = clip_by_global_norm(grads, cfg.grad_clip)
    step = state["step"] + 1
    lr = cosine_lr(cfg, step)
    t = step.astype(jnp.float32)
    bc1 = 1.0 - cfg.b1 ** t
    bc2 = 1.0 - cfg.b2 ** t

    def upd(p, g, m, v):
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * g * g
        mh = m / bc1
        vh = v / bc2
        p32 = p.astype(jnp.float32)
        p32 = p32 - lr * (mh / (jnp.sqrt(vh) + cfg.eps)
                          + cfg.weight_decay * p32)
        return p32.astype(p.dtype), m, v

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = tdef.flatten_up_to(grads32)
    flat_m = tdef.flatten_up_to(state["mu"])
    flat_v = tdef.flatten_up_to(state["nu"])
    new = [upd(p, g, m, v) for p, g, m, v in
           zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = tdef.unflatten([x[0] for x in new])
    new_m = tdef.unflatten([x[1] for x in new])
    new_v = tdef.unflatten([x[2] for x in new])
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_p, {"mu": new_m, "nu": new_v, "step": step}, metrics
