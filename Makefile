# Convenience targets; everything pins JAX_PLATFORMS=cpu (see
# scripts/verify.sh for why).

PY := python
ENV := JAX_PLATFORMS=cpu PYTHONPATH=src

.PHONY: verify test bench bench-dp bench-tables bench-smoke

verify:
	bash scripts/verify.sh

test:
	$(ENV) $(PY) -m pytest -x -q

bench:
	$(ENV) $(PY) -m benchmarks.run

bench-dp:
	$(ENV) $(PY) -m benchmarks.bench_dp

bench-tables:
	$(ENV) $(PY) -m benchmarks.bench_tables

# Seconds-scale probe-engine regression gate (also part of `make verify`):
# asserts batched/sequential parity, bucket accounting, and cache
# round-trips without the slow sequential wall-clock baseline.
bench-smoke:
	$(ENV) $(PY) -m benchmarks.bench_tables --smoke
