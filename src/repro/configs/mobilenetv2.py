"""The paper's own MobileNetV2-1.0 (Sandler et al. 2018) — CNN path."""
from repro.models import zoo

CONFIG = zoo.mobilenetv2(width_mult=1.0)
CONFIG_14 = zoo.mobilenetv2(width_mult=1.4)
