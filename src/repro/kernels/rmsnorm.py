"""Pallas TPU kernel: fused RMSNorm  y = x · rsqrt(mean(x²)+ε) · (1+g).

One pass per row tile: the row stays in VMEM between the reduction and the
scale, so HBM traffic is exactly read-x + write-y (XLA sometimes spills the
normalized intermediate for wide rows).  Grid: (row-tiles,); feature dim is
kept whole per tile (d ≤ ~16k fits easily: 512×12288×2 ≈ 12 MiB at bm=512 —
use bm=128 for d=12288, see ops.py heuristics).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(x_ref, g_ref, o_ref, *, eps: float):
    x = x_ref[...].astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(var + eps)
    o_ref[...] = (y * (1.0 + g_ref[...].astype(jnp.float32))).astype(
        o_ref.dtype)


def rmsnorm(x, g, *, eps: float = 1e-6, bm: int = 256,
            interpret: bool = False):
    """x: (M, D); g: (D,) → (M, D).  M % bm == 0."""
    m, d = x.shape
    bm = min(bm, m)
    assert m % bm == 0, "pad rows at the ops layer"
    return pl.pallas_call(
        functools.partial(_kernel, eps=eps),
        grid=(m // bm,),
        in_specs=[pl.BlockSpec((bm, d), lambda i: (i, 0)),
                  pl.BlockSpec((d,), lambda i: (0,))],
        out_specs=pl.BlockSpec((bm, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((m, d), x.dtype),
        interpret=interpret,
    )(x, g)
