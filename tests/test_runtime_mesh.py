"""Mesh-aware runtime tests — sharded executor ≡ single-device executor.

Subprocess children force 8 host devices via XLA_FLAGS (the pattern from
tests/test_distributed.py; the main test process must keep seeing 1
device) and certify:

* sharded ``GraphExecutor`` logits ≡ single-device ``runtime.execute``
  on a zoo CNN and on an attention-transformer artifact graph;
* decode-through-the-prompt ≡ parallel prefill under the mesh (KV-cache
  parity with the 'kv_seq' constraints active);
* ``runtime.load(path, rules=)`` places arrays on real NamedShardings
  (at least one weight genuinely split over 'model');
* ``make_host_mesh(model=K)`` exposes the tensor-parallel split.

v1-artifact backward compatibility (no axes annotations → fully
replicated load) runs in-process — it needs no devices.
"""
import json
import os
import subprocess
import sys
import textwrap

import numpy as np

ENV = {"PYTHONPATH": "src", "PATH": "/usr/bin:/bin", "HOME": "/root",
       "JAX_PLATFORMS": "cpu"}


def run_sub(code, devices=8, timeout=600):
    pre = textwrap.dedent(f"""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count={devices}"
    """)
    r = subprocess.run([sys.executable, "-c", pre + textwrap.dedent(code)],
                       capture_output=True, text=True, env=ENV,
                       cwd="/root/repo", timeout=timeout)
    assert r.returncode == 0, r.stdout[-2000:] + r.stderr[-4000:]
    return r.stdout


def test_host_mesh_model_split():
    out = run_sub("""
        import jax, pytest
        from repro.launch.mesh import make_host_mesh, mesh_info
        m = make_host_mesh()
        assert mesh_info(m)["shape"] == {"data": 8, "model": 1}
        m = make_host_mesh(model=2)
        assert mesh_info(m)["shape"] == {"data": 4, "model": 2}
        m = make_host_mesh(model=8)
        assert mesh_info(m)["shape"] == {"data": 1, "model": 8}
        try:
            make_host_mesh(model=3)
        except ValueError:
            print("MESH_OK")
    """)
    assert "MESH_OK" in out


def test_sharded_cnn_executor_matches_single_device():
    """Mesh-sharded CNN unit graph (channels on 'model', batch on 'data')
    produces the single-device executor's logits."""
    out = run_sub("""
        import jax, jax.numpy as jnp, numpy as np
        from repro import runtime
        from repro.core import compress
        from repro.launch.mesh import make_host_mesh
        from repro.models import cnn, cnn_host, zoo
        from repro.sharding.rules import make_unit_rules

        net = zoo.tiny_resnet(num_classes=4, in_hw=8, width=8, blocks=(2,))
        params = cnn.init_params(net, jax.random.PRNGKey(0))
        host = cnn_host.CNNHost(net, params, batch=4)
        x = jax.random.normal(jax.random.PRNGKey(1), (4, 8, 8, net.in_ch))
        res = compress(host, budget_ratio=0.7, P=100)
        graph = host.lower_plan(res.plan)
        y1 = np.asarray(runtime.execute(graph, x))

        rules = make_unit_rules(make_host_mesh(model=2))
        ex = runtime.GraphExecutor(graph, rules)
        y2 = np.asarray(ex.apply(x))
        scale = np.abs(y1).max() + 1e-9
        assert np.abs(y1 - y2).max() / scale < 2e-4, np.abs(y1 - y2).max()
        print("CNN_MESH_OK")
    """)
    assert "CNN_MESH_OK" in out


def test_sharded_transformer_artifact_decode_parity():
    """Artifact → sharded load → GraphExecutor: prefill ≡ single-device,
    decode-through-the-prompt ≡ prefill under the mesh, and at least one
    weight is genuinely split over 'model'."""
    out = run_sub("""
        import dataclasses, os, tempfile
        import jax, jax.numpy as jnp, numpy as np
        from repro import runtime
        from repro.configs import get_config
        from repro.core import compress
        from repro.launch.mesh import make_host_mesh
        from repro.models import transformer as T
        from repro.models.transformer_host import CostEnv, TransformerHost
        from repro.runtime import serving
        from repro.sharding.rules import make_unit_rules

        cfg = dataclasses.replace(get_config("smollm-135m").reduced(),
                                  num_layers=4)
        params, _ = T.init_model(cfg, jax.random.PRNGKey(0))
        host = TransformerHost(cfg, params, env=CostEnv(batch=4, seq=16))
        res = compress(host, budget_ratio=0.6, P=200)
        with tempfile.TemporaryDirectory() as d:
            path = os.path.join(d, "lm.npz")
            res.save(path)
            art_1d = runtime.load(path)
            rules = make_unit_rules(make_host_mesh(model=2))
            art = runtime.load(path, rules=rules)

        # sharded load put at least one weight on a real 'model' split
        leaves = jax.tree.leaves(runtime.graph_params(art.graph))
        specs = [l.sharding.spec for l in leaves if hasattr(l, "sharding")]
        assert any("model" in str(s) for s in specs), specs

        B, P = 4, 16
        prompt = serving.random_prompts(1, B, P, cfg.vocab_size)
        batch = {"tokens": prompt,
                 "positions": jnp.broadcast_to(jnp.arange(P)[None], (B, P))}
        y1 = np.asarray(runtime.execute(art_1d.graph, batch))
        ex = art.executor(rules)
        y2 = np.asarray(ex.apply(batch))
        scale = np.abs(y1).max() + 1e-9
        assert np.abs(y1 - y2).max() / scale < 2e-4, np.abs(y1 - y2).max()

        # KV parity under the mesh: serve the prompt, compare last logits
        step, gp = ex.serve_step()
        _, _, lv, _ = serving.serve_loop(step, gp, ex.init_cache(B, P),
                                         prompt, 1, rules=rules)
        d2 = np.abs(y1[:, -1] - np.asarray(lv)).max() / scale
        assert d2 < 2e-4, d2
        print("TF_MESH_OK")
    """)
    assert "TF_MESH_OK" in out


def test_batched_scheduler_under_mesh_matches_unsharded():
    """serve_requests over the 'data' axis generates the same greedy ids
    as the unsharded scheduler (data-parallel slot batching)."""
    out = run_sub("""
        import dataclasses
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs import get_config
        from repro.launch.mesh import make_host_mesh
        from repro.models import transformer as T
        from repro.runtime import serving
        from repro.sharding.rules import make_unit_rules
        from repro.train.step import make_serve_step

        cfg = dataclasses.replace(
            get_config("smollm-135m").reduced(), num_layers=2, d_model=64,
            num_heads=4, num_kv_heads=2, head_dim=16, d_ff=128,
            vocab_size=128)
        params, _ = T.init_model(cfg, jax.random.PRNGKey(0))
        step = make_serve_step(cfg)
        rng = np.random.RandomState(0)
        prompts = [jnp.asarray(rng.randint(0, 128, size=n), jnp.int32)
                   for n in (5, 9, 3, 7, 6, 8)]
        mat, lens = serving.pad_prompts(prompts)
        mk = lambda b, s: T.init_cache(cfg, b, s)
        g1, _ = serving.serve_requests(step, params, mk, mat, lens,
                                       tokens=5, slots=4)
        rules = make_unit_rules(make_host_mesh())
        g2, _ = serving.serve_requests(step, params, mk, mat, lens,
                                       tokens=5, slots=4, rules=rules)
        np.testing.assert_array_equal(np.asarray(g1), np.asarray(g2))
        print("SCHED_MESH_OK")
    """)
    assert "SCHED_MESH_OK" in out


# ---------------------------------------------------------------------------
# v1 artifact backward compatibility (no devices needed)
# ---------------------------------------------------------------------------

def _rewrite_as_v1(path):
    """Strip the v2 sharding contract from an artifact on disk: format 1,
    no per-unit axes, no global_axes — the PR-4 layout."""
    from repro.runtime import artifact as A

    with np.load(path, allow_pickle=False) as z:
        data = {k: z[k] for k in z.files}
    spec = json.loads(data.pop("__spec__").item())
    data.pop("__fingerprint__")
    spec["format"] = 1
    spec.pop("global_axes", None)
    for u in spec["units"]:
        u.pop("axes", None)
    arrays = {k: np.asarray(v) for k, v in data.items()}
    with open(path, "wb") as f:
        np.savez(f, __spec__=np.array(json.dumps(spec)),
                 __fingerprint__=np.array(A._digest(spec, arrays)), **arrays)


def test_v1_artifact_loads_fully_replicated(tmp_path):
    import jax
    from repro import runtime
    from repro.core import compress
    from repro.models import cnn, cnn_host, zoo

    net = zoo.tiny_resnet(num_classes=4, in_hw=8, width=4, blocks=(2,))
    params = cnn.init_params(net, jax.random.PRNGKey(0))
    host = cnn_host.CNNHost(net, params, batch=2)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, 8, net.in_ch))
    res = compress(host, budget_ratio=0.7, P=100)
    path = os.path.join(str(tmp_path), "v1.npz")
    res.save(path)
    y2 = np.asarray(runtime.load(path).apply(x))

    _rewrite_as_v1(path)
    art = runtime.load(path)
    assert all(not u.axes for u in art.graph.units)       # fully replicated
    assert art.graph.axes == {}
    np.testing.assert_array_equal(np.asarray(art.apply(x)), y2)
    # and loading v1 WITH rules must still work (replicated placement)
    from repro.launch.mesh import make_host_mesh
    from repro.sharding.rules import make_unit_rules
    rules = make_unit_rules(make_host_mesh())             # 1 device here
    art_r = runtime.load(path, rules=rules)
    np.testing.assert_array_equal(np.asarray(art_r.apply(x)), y2)
